"""Serving-path benchmarks: static step-locked batches vs the
continuous-batching engine (ISSUE 9).

One mixed-length arrival trace — a few long-output requests scattered
among short ones, more requests than decode slots — served two ways:

* ``bench.serve.static`` — FIFO groups of ``slots`` requests through the
  static ``Engine``: prompts padded to the group max, every slot decodes
  to the group's max max_new (finished slots burn masked scratch steps).
  A group is as slow as its longest member, and the next group waits.
* ``bench.serve.continuous`` — the same trace through
  ``ContinuousEngine``: a slot frees the moment its request finishes and
  is refilled from the queue mid-flight over the paged KV pool.

``us_per_call`` is microseconds per *useful* generated token (each
request's own max_new — the tokens the client asked for, not the padded
work the static engine burns), so the two rows are directly comparable;
``derived`` carries the p50/p99 request latency (nearest-rank via the
shared ``repro.obs.percentile`` — p99 of a <100-request trace is the
worst OBSERVED latency, not an interpolation past it).  Both engines run
engine="jnp" (portable timings; the Pallas decode kernel's interpret
mode off-TPU is an emulator, not a measurement) and both are timed on a
second full pass so compilation is excluded.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.sparsity import SparsityConfig

# trace shape: LONG_EVERY-th request wants a long output, the rest short.
SLOTS = 4
LONG_EVERY = 4


def _cfg():
    return ArchConfig(
        name="bench-serve", family="dense", n_layers=2, d_model=128,
        n_heads=4, kv_heads=2, head_dim=32, d_ff=256, vocab=128,
        act="silu", max_seq=128, attn_chunk=32, dtype="float32",
        sparsity=SparsityConfig(density=0.25, block=32, where="ffn"),
        engine="jnp")


def _trace(fast: bool):
    n_req = 12 if fast else 32
    long_new, short_new = (24, 4) if fast else (48, 8)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(n_req):
        plen = int(rng.integers(8, 25))
        new = long_new if i % LONG_EVERY == 0 else short_new
        reqs.append((i, rng.integers(1, 128, size=plen).astype(np.int32),
                     new))
    return reqs


def _run_static(eng, reqs):
    """FIFO groups of SLOTS; returns per-request completion latencies."""
    lat = []
    t0 = time.perf_counter()
    for g in range(0, len(reqs), SLOTS):
        grp = reqs[g:g + SLOTS]
        S = max(len(p) for _, p, _ in grp)
        new = max(n for _, _, n in grp)
        prompts = np.zeros((len(grp), S), np.int32)
        for j, (_, p, _) in enumerate(grp):
            prompts[j, S - len(p):] = p        # right-aligned
        eng.scfg.max_new_tokens = new
        eng.generate(prompts)
        done = time.perf_counter() - t0
        lat.extend([done] * len(grp))          # whole group lands together
    return time.perf_counter() - t0, lat


def bench(fast=True):
    import dataclasses

    import jax

    from repro.models import model as M
    from repro.obs import percentile
    from repro.serve.engine import (ContinuousEngine, Engine, Request,
                                    ServeConfig)

    cfg = _cfg()
    params = M.init(cfg, jax.random.PRNGKey(0))
    reqs = _trace(fast)
    useful = sum(n for _, _, n in reqs)
    n_long = sum(1 for i, _, _ in reqs if i % LONG_EVERY == 0)

    # ---- static: FIFO groups, padded to group max, group-max max_new
    eng = Engine(cfg, params, ServeConfig(eos_token=-1))
    _run_static(eng, reqs)                     # warmup pass (compiles)
    dt_s, lat_s = _run_static(eng, reqs)

    # ---- continuous: same trace, all arrivals at tick 0
    scfg = ServeConfig(eos_token=-1, slots=SLOTS, page_size=16,
                       prefill_chunk=32, max_seq=max(len(p) + n
                                                     for _, p, n in reqs))
    ce = ContinuousEngine(cfg, params, scfg)
    requests = [Request(rid=i, prompt=p, max_new_tokens=n)
                for i, p, n in reqs]
    ce.serve(list(requests))                   # warmup pass (compiles)
    t0 = time.perf_counter()
    ce.serve(list(requests))
    dt_c = time.perf_counter() - t0
    lat_c = [v["wall_s"] for v in ce.stats["latency"].values()]

    def row(name, dt, lat, extra):
        return {
            "name": name,
            "us_per_call": dt / useful * 1e6,
            "derived": f"{len(reqs)} reqs ({n_long} long) {useful} tokens "
                       f"slots={SLOTS} p50_lat={percentile(lat, 50) * 1e3:.0f}ms "
                       f"p99_lat={percentile(lat, 99) * 1e3:.0f}ms {extra}",
        }

    st = ce.stats
    return [
        row("bench.serve.static", dt_s, lat_s,
            f"{len(reqs) // SLOTS} FIFO groups padded to group max"),
        row("bench.serve.continuous", dt_c, lat_c,
            f"ticks={st['decode_ticks']} chunks={st['prefill_chunks']} "
            f"peak_pages={st['peak_pages']}/{st['num_pages']} "
            f"traces={st['decode_traces']}/{st['prefill_traces']}"),
    ]
