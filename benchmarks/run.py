"""Benchmark harness: one entry per paper table/figure + the roofline table.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only table2,fig8]

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale epochs/samples (slow)")
    ap.add_argument("--only", default="",
                    help="comma list of bench names (default: all)")
    args = ap.parse_args()

    from benchmarks import paper_benches, roofline_table

    benches = dict(paper_benches.BENCHES)
    benches["roofline"] = roofline_table.bench
    only = [s for s in args.only.split(",") if s]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        try:
            rows = fn(fast=not args.full)
        except Exception as e:  # keep the harness running
            print(f"{name},-1,ERROR {type(e).__name__}: {str(e)[:160]}")
            failures += 1
            continue
        for r in rows:
            derived = str(r["derived"]).replace(",", ";")
            print(f"{r['name']},{r['us_per_call']:.2f},{derived}")
        sys.stderr.write(f"[bench] {name}: {len(rows)} rows "
                         f"in {time.perf_counter() - t0:.1f}s\n")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
