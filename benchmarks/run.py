"""Benchmark harness: one entry per paper table/figure + the roofline table
+ the engine-comparison benches.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only table2,engine]
                                            [--json PATH] [--tag TAG]

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract); with
``--json PATH`` also writes a ``BENCH_<tag>.json`` artifact so the perf
trajectory is machine-trackable across PRs (diff two artifacts to see
the movement).  The artifact schema is

    {"meta": {git_sha, backend, jax_version, tag, timestamp},
     "results": {name: us_per_call}}

— the meta stamp makes artifacts from different PRs comparable (same
backend? which commit?).  ``--tag`` sets ``meta.tag`` explicitly
(default: derived from the --json filename), the same contract the
sweep ledger uses (``repro.launch.sweep --tag``).  Readers should use
:func:`load_artifact`, which round-trips the meta (tag included) and
also accepts the pre-stamp flat ``{name: us_per_call}`` schema.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def artifact_meta(tag: str) -> dict:
    # the stamp schema (+ git -dirty detection) is shared with the sweep
    # ledger — one implementation, repro.artifacts
    from repro.artifacts import artifact_meta as _meta
    return _meta(tag)


def load_artifact(path: str) -> tuple[dict, dict[str, float]]:
    """(meta, results) from a BENCH_*.json of either schema: the stamped
    {"meta": ..., "results": ...} form or the legacy flat name->us map."""
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, dict) and "results" in data:
        return data.get("meta", {}), data["results"]
    return {}, data


def _tag_from_path(path: str) -> str:
    import os
    base = os.path.basename(path)
    if base.startswith("BENCH_") and base.endswith(".json"):
        return base[len("BENCH_"):-len(".json")]
    return base


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale epochs/samples (slow)")
    ap.add_argument("--only", default="",
                    help="comma list of bench names (default: all)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write a BENCH_<tag>.json artifact "
                         "(name -> us_per_call) at PATH")
    ap.add_argument("--tag", default="",
                    help="artifact meta.tag (default: derived from the "
                         "--json filename)")
    args = ap.parse_args()

    from benchmarks import (engine_benches, obs_benches, paper_benches,
                            roofline_table, serve_benches)

    benches = dict(paper_benches.BENCHES)
    benches["roofline"] = roofline_table.bench
    benches["engine"] = engine_benches.bench
    benches["serve"] = serve_benches.bench
    benches["obs"] = obs_benches.bench
    only = [s for s in args.only.split(",") if s]
    unknown = sorted(set(only) - set(benches))
    if unknown:
        # a typo'd --only used to print the CSV header, run nothing, exit 0
        # and (with --json) write an empty artifact — fail loudly instead
        sys.stderr.write(
            f"[bench] unknown bench name(s): {', '.join(unknown)}\n"
            f"[bench] valid names: {', '.join(sorted(benches))}\n")
        raise SystemExit(2)
    print("name,us_per_call,derived")
    failures = 0
    results: dict[str, float] = {}
    for name, fn in benches.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        try:
            rows = fn(fast=not args.full)
        except Exception as e:  # keep the harness running
            print(f"{name},-1,ERROR {type(e).__name__}: {str(e)[:160]}")
            failures += 1
            continue
        for r in rows:
            derived = str(r["derived"]).replace(",", ";")
            print(f"{r['name']},{r['us_per_call']:.2f},{derived}")
            results[r["name"]] = round(float(r["us_per_call"]), 2)
        sys.stderr.write(f"[bench] {name}: {len(rows)} rows "
                         f"in {time.perf_counter() - t0:.1f}s\n")
    if args.json:
        meta = artifact_meta(args.tag or _tag_from_path(args.json))
        with open(args.json, "w") as f:
            json.dump({"meta": meta, "results": results}, f,
                      indent=2, sort_keys=True)
            f.write("\n")
        sys.stderr.write(f"[bench] wrote {len(results)} entries "
                         f"to {args.json} (sha {meta['git_sha']}, "
                         f"{meta['backend']})\n")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
