"""Roofline aggregation: results/dryrun/*.json -> the EXPERIMENTS.md tables.

Deliverable (g): per (arch x shape x mesh) the three roofline terms from
the compiled dry-run, dominant bottleneck, MODEL_FLOPS / HLO_FLOPs ratio,
per-device memory fit.  Usable as a library (EXPERIMENTS.md generation) and
as a bench entry (prints summary rows).
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cells(pattern: str = "*.json") -> list[dict]:
    rows = []
    for f in sorted(glob.glob(str(RESULTS / pattern))):
        r = json.loads(Path(f).read_text())
        rows.append(r)
    return rows


def table_rows(cells=None) -> list[dict]:
    out = []
    for r in cells or load_cells():
        if not r.get("ok"):
            out.append({"cell": r["cell"], "ok": False,
                        "error": r.get("error", "?")[:120]})
            continue
        rl = r["roofline"]
        t = {"compute": rl["t_compute"], "memory": rl["t_memory"],
             "collective": rl["t_collective"]}
        dom = rl["dominant"]
        bound = max(t.values())
        out.append({
            "cell": r["cell"], "ok": True, "mesh": r["mesh"],
            "arch": r["arch"], "shape": r["shape"],
            "variant": r.get("variant", "dense"),
            "t_compute_s": round(t["compute"], 4),
            "t_memory_s": round(t["memory"], 4),
            "t_collective_s": round(t["collective"], 4),
            "dominant": dom,
            "roofline_fraction": round(t["compute"] / bound, 4) if bound else 0.0,
            "useful_fraction": round(r.get("useful_fraction", 0.0), 4),
            "per_device_gb": r.get("per_device_gb"),
            "fits_16gb": r.get("fits_16gb"),
            "microbatches": r.get("microbatches", 1),
            "collectives": {k: v["count"] for k, v in rl["coll_detail"].items()},
        })
    return out


def bench(fast=True):
    rows = []
    for r in table_rows():
        if not r.get("ok"):
            rows.append({"name": f"roofline.{r['cell']}", "us_per_call": -1,
                         "derived": f"FAILED {r['error']}"})
            continue
        bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        rows.append({
            "name": f"roofline.{r['cell']}",
            "us_per_call": bound * 1e6,
            "derived": (f"dom={r['dominant']} frac={r['roofline_fraction']} "
                        f"useful={r['useful_fraction']} "
                        f"perdev={r['per_device_gb']}GB fit={r['fits_16gb']}"),
        })
    return rows
