"""Telemetry-overhead benchmark (ISSUE 10): the flight recorder on vs off.

``bench.obs.overhead`` times the two hot producer paths with a real
Recorder (JSONL sink on disk, events + histograms + gauges live) against
the identical run with no recorder:

* the guardian-instrumented regression train loop (train/train_loop.py —
  per-step TrainStep events, the guardian's host-side sentinel checks
  riding along), and
* a continuous-serve trace (serve/engine.ContinuousEngine — per-request
  spans, TTFT/ITL observations, occupancy gauges every tick).

``us_per_call`` is the recorder-ON wall time; ``derived`` carries the
per-path and overall on/off ratios — the acceptance gate's number.  By
the no-extra-device-sync contract the recorder adds only host dict/deque
work and one json line per event, so the ratio should sit near 1.0; a
regression here means someone put device work (or a sync) on the
telemetry path.
"""
from __future__ import annotations

import time


def bench(fast=True):
    import tempfile

    import jax
    import numpy as np

    from repro.configs.base import ArchConfig
    from repro.core.sparsity import SparsityConfig
    from repro.models import model as M
    from repro.obs import Recorder
    from repro.serve.engine import ContinuousEngine, Request, ServeConfig

    tmp = tempfile.mkdtemp(prefix="obs_bench_")

    # ---- train path: guardian loop on the MNIST-sized regression step
    import sys
    sys.path.insert(0, "tests")     # reuse the guardian e2e fixtures
    try:
        from test_guardian import (PoisonPipeline, _junction,
                                   _make_regression_step, _w_true)
    finally:
        sys.path.pop(0)
    from repro.train.train_loop import (GuardianConfig, TrainLoopConfig,
                                        run)

    w_true = _w_true()
    params = _junction()
    opt, train_step = _make_regression_step("jnp")
    STEPS = 12 if fast else 60

    def train_pass(recorder, tag):
        cfg = TrainLoopConfig(total_steps=STEPS,
                              ckpt_dir=f"{tmp}/ck_{tag}",
                              ckpt_every=10 ** 6, log_every=10 ** 6,
                              guardian=GuardianConfig())
        t0 = time.perf_counter()
        run(cfg, train_step, params, opt.init(params),
            PoisonPipeline(w_true), log=lambda s: None, recorder=recorder)
        return time.perf_counter() - t0

    train_pass(None, "warm")                    # compile excluded
    dt_train_off = train_pass(None, "off")
    rec = Recorder(f"{tmp}/train.jsonl")
    dt_train_on = train_pass(rec, "on")
    rec.close()

    # ---- serve path: a continuous trace with spans/hists/gauges live
    cfg = ArchConfig(
        name="bench-obs", family="dense", n_layers=2, d_model=128,
        n_heads=4, kv_heads=2, head_dim=32, d_ff=256, vocab=128,
        act="silu", max_seq=64, attn_chunk=32, dtype="float32",
        sparsity=SparsityConfig(density=0.25, block=32, where="ffn"),
        engine="jnp")
    mparams = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    n_req = 8 if fast else 24
    NEW = 8
    prompts = rng.integers(1, cfg.vocab, size=(n_req, 12)).astype(np.int32)
    scfg = ServeConfig(max_new_tokens=NEW, eos_token=-1, slots=2,
                       page_size=8, prefill_chunk=8, max_seq=32)
    reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=NEW)
            for i in range(n_req)]

    def serve_pass(recorder):
        eng = ContinuousEngine(cfg, mparams, scfg, recorder=recorder)
        eng.serve(list(reqs))                   # warmup pass (compiles)
        t0 = time.perf_counter()
        eng.serve(list(reqs))
        return time.perf_counter() - t0

    dt_serve_off = serve_pass(None)
    rec = Recorder(f"{tmp}/serve.jsonl")
    dt_serve_on = serve_pass(rec)
    rec.close()

    r_train = dt_train_on / max(dt_train_off, 1e-12)
    r_serve = dt_serve_on / max(dt_serve_off, 1e-12)
    r_all = ((dt_train_on + dt_serve_on)
             / max(dt_train_off + dt_serve_off, 1e-12))
    return [{
        "name": "bench.obs.overhead",
        "us_per_call": (dt_train_on + dt_serve_on) * 1e6,
        "derived": f"train {STEPS} steps + serve {n_req} reqs x {NEW} tok "
                   f"recorder on/off: train_ratio={r_train:.3f} "
                   f"serve_ratio={r_serve:.3f} ratio={r_all:.3f}",
    }]
