"""Engine-comparison benchmarks: jnp gather+einsum vs fused Pallas engine.

Two junction shapes anchor the perf trajectory from this PR onward:

* ``engine.mnist.*`` — the paper's MNIST junction in block form
  (1024 -> 512 @ density 0.25, the TPU-native analogue of the 1024x64
  d_out=8 junction the FPGA implements).
* ``engine.ffn.*``   — a transformer FFN up-projection
  (1024 -> 4096 @ density 0.25), the shape the ROADMAP north-star cares
  about.

Each row times one jit'd forward+backward (loss = sum(y)) per engine.
Off-TPU the Pallas rows run in interpret mode — an emulator, so their
absolute numbers only become meaningful on real hardware; the jnp rows
are the portable baseline.  ``BENCH_*.json`` (benchmarks/run.py --json)
makes the trajectory machine-trackable.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import sparse_linear as sl
from repro.core.sparsity import SparsityConfig, make_block_pattern
from repro.kernels import block_sparse_matmul as bsm

SHAPES = {
    # name: (n_in, n_out, density, block, M_fast, M_full)
    "mnist": (1024, 512, 0.25, 128, 256, 12544),
    "ffn": (1024, 4096, 0.25, 128, 256, 4096),
}


def _junction_params(n_in, n_out, density, block):
    sp = SparsityConfig(density=density, block=block, where="ffn")
    return sl.init_sparse(jax.random.PRNGKey(0), n_in, n_out, sp, bias=True)


def _time_fwd_bwd(params, x, engine, n=3):
    @jax.jit
    def step(params, x):
        def loss(w, x):
            return jnp.sum(sl.apply(dict(params, w=w), x,
                                    engine=engine, act="sigmoid"))
        l, gw = jax.value_and_grad(loss)(params["w"], x)
        return l, gw

    out = step(params, x)           # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = step(params, x)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def bench(fast=True):
    on_tpu = jax.default_backend() == "tpu"
    rows = []
    for name, (n_in, n_out, density, block, m_fast, m_full) in SHAPES.items():
        M = m_fast if fast else m_full
        params = _junction_params(n_in, n_out, density, block)
        x = jax.random.normal(jax.random.PRNGKey(1), (M, n_in), jnp.float32)
        pat = make_block_pattern(n_in, n_out, density, block)
        grid = bsm.fwd_grid(M, pat.n_out_blocks, pat.fan_in_blocks, block,
                            pat.n_in_blocks, 4)
        # interpret-mode emulation is O(seconds); keep CI fast with n=1
        n = 3 if on_tpu else 1
        for engine in ("jnp", "pallas"):
            dt = _time_fwd_bwd(params, x, engine, n=n)
            mode = "compiled" if (on_tpu or engine == "jnp") else "interpret"
            rows.append({
                "name": f"engine.{name}.{engine}",
                "us_per_call": dt * 1e6,
                "derived": f"M={M} {n_in}->{n_out} d={density} bs={block} "
                           f"grid={grid[0]}x{grid[1]} mode={mode}",
            })
    return rows
