"""Engine-comparison benchmarks: jnp gather+einsum vs fused Pallas engine.

Three junction shapes anchor the perf trajectory from this PR onward:

* ``engine.mnist.*`` — the paper's MNIST junction in block form
  (1024 -> 512 @ density 0.25, the TPU-native analogue of the 1024x64
  d_out=8 junction the FPGA implements).
* ``engine.ffn.*``   — a transformer FFN up-projection
  (1024 -> 4096 @ density 0.25), the shape the ROADMAP north-star cares
  about.
* ``engine.moe.*``   — a full sparse-expert MoE layer (4 experts, top-2,
  1024 -> 512 per expert @ density 0.25) through ``moe_apply``: routing +
  dispatch identical per engine, the expert FFNs either through the
  unified junction engine (E-batched grid (E, M/bm, nob/bn), SwiGLU gate
  in one pass) or the reference gather+einsum loop.

Each row times one jit'd forward+backward (loss = sum(y)) per engine.

``engine.update.*`` rows (ISSUE 4) time the full train-update cycle —
fwd + bwd + SGD-momentum update: the ``jnp`` rows run the two-pass
reference (materialized dw, tree-mapped update), the ``pallas`` rows the
fused BP+UP path (update applied in the backward kernels' epilogue,
params donated through input_output_aliasing — the dw HBM round-trip the
fused path exists to delete).  ``engine.update.adam.*`` rows (ISSUE 7)
run the same cycle under the in-kernel Adam epilogue: a second fp32
accumulator (vel) aliased in place and a full ``(HYP_K,)`` registry row
instead of the legacy (2,) [lr, momentum] pair.

``bench.guard.overhead`` (ISSUE 6) times the fused MNIST update cycle
with the in-kernel [E] divergence-flag output (the guardian's detector)
against the plain fused cycle; the row's ``derived`` field carries the
with/without ratio.

``bench.sweep.mnist.*`` rows (ISSUE 5) time the population engine: one
E-batched population train step (E MNIST candidates with distinct
learning rates advancing in single kernel launches via the [E, 2] hyp
table) against E sequential single-model steps doing the same total
work — the resource-vs-training-time trade the sweep subsystem
(src/repro/search/) turns into a user-facing knob.

``engine.infer.int8.{mnist,moe}.*`` rows (ISSUE 8) time the quantized
inference datapath: the same MNIST junction / MoE layer forwards with
int8 weight codes + per-block scales (core/quantize.py) through the
quantized kernels (``pallas``) or their op-for-op jnp sims (``jnp``) —
forward-only, since the quantized specs are inference-only by contract.
``bench.quant.sweep`` times the quant sweep's inner loop: one E=4
stacked quantized population (four int8 configs sharing one cohort)
evaluated in a single E-batched launch.

Off-TPU the Pallas rows run in interpret mode — an emulator, so their
absolute numbers only become meaningful on real hardware; the jnp rows
are the portable baseline.  ``BENCH_*.json`` (benchmarks/run.py --json)
makes the trajectory machine-trackable.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.core import sparse_linear as sl
from repro.core.sparsity import SparsityConfig, make_block_pattern
from repro.kernels import block_sparse_matmul as bsm
from repro.models import moe as moe_mod
from repro.optim import constant_schedule, fused_adam, fused_sgd

SHAPES = {
    # name: (n_in, n_out, density, block, M_fast, M_full)
    "mnist": (1024, 512, 0.25, 128, 256, 12544),
    "ffn": (1024, 4096, 0.25, 128, 256, 4096),
}

# MoE bench: (E, top_k, d_model, d_expert, density, block, tok_fast, tok_full)
MOE_SHAPE = (4, 2, 1024, 512, 0.25, 128, 128, 2048)


def _junction_params(n_in, n_out, density, block):
    sp = SparsityConfig(density=density, block=block, where="ffn")
    return sl.init_sparse(jax.random.PRNGKey(0), n_in, n_out, sp, bias=True)


def _time_fwd_bwd(params, x, engine, n=3):
    @jax.jit
    def step(params, x):
        def loss(w, x):
            return jnp.sum(sl.apply(dict(params, w=w), x,
                                    engine=engine, act="sigmoid"))
        l, gw = jax.value_and_grad(loss)(params["w"], x)
        return l, gw

    out = step(params, x)           # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = step(params, x)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


_UPDATE_LR, _UPDATE_BETA = 1e-3, 0.9
_UPDATE_B2, _UPDATE_EPS = 0.95, 1e-8


def _time_junction_update(params, x, mode, n=3, with_health=False,
                          optim="sgd"):
    """One full junction train step — fwd + bwd + in-kernel update.
    mode "jnp": two-pass reference (dw materialized, update tree-mapped);
    mode "pallas": fused BP+UP (ops.junction_train_update, dw consumed by
    the in-kernel update, params/accumulators aliased in place).  optim
    picks the epilogue rule — "sgd" (momentum) rides the legacy (2,) hyp
    pair, "adam" a full (HYP_K,) registry row plus the second (vel) fp32
    accumulator.  with_health additionally rides the [E] divergence-flag
    output through the update kernels' flush epilogue (the guardian's
    in-kernel detector)."""
    from repro.kernels import ops as kops

    if optim == "adam":
        hyp = (jnp.zeros((bsm.HYP_K,), jnp.float32)
               .at[bsm.COL_LR].set(_UPDATE_LR)
               .at[bsm.COL_B1].set(_UPDATE_BETA)
               .at[bsm.COL_B2].set(_UPDATE_B2)
               .at[bsm.COL_EPS].set(_UPDATE_EPS)
               .at[bsm.COL_T].set(1.0)
               .at[bsm.COL_GS].set(1.0))
    else:
        hyp = jnp.asarray([_UPDATE_LR, _UPDATE_BETA], jnp.float32)
    pat = (params["idx"], params["rev_ob"], params["rev_t"],
           params["rev_cnt"])
    mom = jnp.zeros(params["w"].shape, jnp.float32)
    mom_b = jnp.zeros(params["b"].shape, jnp.float32)
    vel = jnp.zeros(params["w"].shape, jnp.float32)
    vel_b = jnp.zeros(params["b"].shape, jnp.float32)

    if mode == "pallas" and optim == "adam":
        @jax.jit
        def step(w, b, mom, mom_b, x):
            def loss(w, b, m, mb, v, vb):
                return jnp.sum(kops.junction_train_update(
                    x, w, *pat, bias=b, act="sigmoid", hyp=hyp,
                    mom=m, mom_b=mb, vel=v, vel_b=vb))
            return jax.grad(loss, (0, 1, 2, 3, 4, 5))(
                w, b, mom, mom_b, vel, vel_b)
    elif mode == "jnp" and optim == "adam":
        c1 = 1.0 - _UPDATE_BETA         # bias correction at t = 1
        c2 = 1.0 - _UPDATE_B2

        @jax.jit
        def step(w, b, mom, mom_b, x):
            def loss(w, b):
                return jnp.sum(sl.apply(dict(params, w=w, b=b), x,
                                        engine="jnp", act="sigmoid"))
            gw, gb = jax.grad(loss, (0, 1))(w, b)
            m = _UPDATE_BETA * mom + (1 - _UPDATE_BETA) * gw
            v = _UPDATE_B2 * vel + (1 - _UPDATE_B2) * gw * gw
            mb_ = _UPDATE_BETA * mom_b + (1 - _UPDATE_BETA) * gb
            vb_ = _UPDATE_B2 * vel_b + (1 - _UPDATE_B2) * gb * gb
            nw = w - _UPDATE_LR * (m / c1) / (jnp.sqrt(v / c2) + _UPDATE_EPS)
            nb = b - _UPDATE_LR * (mb_ / c1) / (jnp.sqrt(vb_ / c2)
                                                + _UPDATE_EPS)
            return nw, nb, m, mb_, v, vb_
    elif mode == "pallas" and with_health:
        h0 = jnp.zeros((1,), jnp.float32)

        @jax.jit
        def step(w, b, mom, mom_b, x):
            def loss(w, b, m, mb, h):
                return jnp.sum(kops.junction_train_update(
                    x, w, *pat, bias=b, act="sigmoid", hyp=hyp,
                    mom=m, mom_b=mb, health=h))
            return jax.grad(loss, (0, 1, 2, 3, 4))(w, b, mom, mom_b, h0)
    elif mode == "pallas":
        @jax.jit
        def step(w, b, mom, mom_b, x):
            def loss(w, b, m, mb):
                return jnp.sum(kops.junction_train_update(
                    x, w, *pat, bias=b, act="sigmoid", hyp=hyp,
                    mom=m, mom_b=mb))
            return jax.grad(loss, (0, 1, 2, 3))(w, b, mom, mom_b)
    else:
        @jax.jit
        def step(w, b, mom, mom_b, x):
            def loss(w, b):
                return jnp.sum(sl.apply(dict(params, w=w, b=b), x,
                                        engine="jnp", act="sigmoid"))
            gw, gb = jax.grad(loss, (0, 1))(w, b)
            mv = _UPDATE_BETA * mom + gw
            mbv = _UPDATE_BETA * mom_b + gb
            return (w - _UPDATE_LR * mv, b - _UPDATE_LR * mbv, mv, mbv)

    out = step(params["w"], params["b"], mom, mom_b, x)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = step(params["w"], params["b"], mom, mom_b, x)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def _time_moe_update(params, x, mode, n=3, optim="sgd"):
    """Full MoE layer train-update cycle through the inject/merge plumbing
    the fused train step uses (core/sparse_linear.inject_update_ctx +
    optim.FusedOptimizer.merge) vs the two-pass optimizer.update
    reference.  optim "adam" swaps in fused_adam (second vel accumulator
    per junction, (HYP_K,) registry row)."""
    cfg = _moe_cfg("pallas" if mode == "pallas" else "jnp")
    if optim == "adam":
        opt = fused_adam(constant_schedule(_UPDATE_LR), b1=_UPDATE_BETA,
                         b2=_UPDATE_B2, eps=_UPDATE_EPS)
    else:
        opt = fused_sgd(constant_schedule(_UPDATE_LR),
                        momentum=_UPDATE_BETA)
    st = opt.init(params)
    step0 = jnp.zeros((), jnp.int32)

    def loss(p):
        y, aux = moe_mod.moe_apply(p, x, cfg)
        return jnp.sum(y) + aux

    if mode == "pallas":
        @jax.jit
        def step(params, st, x):
            aug = sl.inject_update_ctx(params, opt.slots(st),
                                       opt.hyp(step0))
            grads = jax.grad(loss, allow_int=True)(aug)
            return opt.merge(grads, st, params, step0)
    else:
        @jax.jit
        def step(params, st, x):
            grads = jax.grad(loss, allow_int=True)(params)
            return opt.update(grads, st, params, step0)

    out = step(params, st, x)
    jax.block_until_ready(jax.tree.leaves(out))
    t0 = time.perf_counter()
    for _ in range(n):
        out = step(params, st, x)
    jax.block_until_ready(jax.tree.leaves(out))
    return (time.perf_counter() - t0) / n


def _moe_cfg(engine: str) -> ArchConfig:
    E, K, d, f, density, block, _, _ = MOE_SHAPE
    return ArchConfig(
        name="bench-moe", family="moe", n_layers=1, d_model=d, n_heads=8,
        kv_heads=8, head_dim=d // 8, d_ff=4 * d, vocab=256, dtype="float32",
        moe=MoEConfig(num_experts=E, top_k=K, d_expert=f, group_size=2048),
        sparsity=SparsityConfig(density=density, block=block, where="ffn"),
        engine=engine)


def _time_moe_fwd_bwd(params, x, engine, n=1):
    cfg = _moe_cfg(engine)

    @jax.jit
    def step(params, x):
        def loss(p, x):
            y, aux = moe_mod.moe_apply(p, x, cfg)
            return jnp.sum(y) + aux
        # allow_int: the shared block pattern rides in int32 param leaves
        return jax.value_and_grad(loss, allow_int=True)(params, x)

    out = step(params, x)           # compile
    jax.block_until_ready(jax.tree.leaves(out))
    t0 = time.perf_counter()
    for _ in range(n):
        out = step(params, x)
    jax.block_until_ready(jax.tree.leaves(out))
    return (time.perf_counter() - t0) / n


def bench(fast=True):
    on_tpu = jax.default_backend() == "tpu"
    rows = []
    for name, (n_in, n_out, density, block, m_fast, m_full) in SHAPES.items():
        M = m_fast if fast else m_full
        params = _junction_params(n_in, n_out, density, block)
        x = jax.random.normal(jax.random.PRNGKey(1), (M, n_in), jnp.float32)
        pat = make_block_pattern(n_in, n_out, density, block)
        grid = bsm.fwd_grid(M, pat.n_out_blocks, pat.fan_in_blocks, block,
                            pat.n_in_blocks, 4)
        # n=1 off-TPU proved too noisy for the ci.sh baseline comparison
        # (single-call jitter looked like a 3x regression); 3 calls of the
        # fast shapes stay well under a second per row
        n = 3
        for engine in ("jnp", "pallas"):
            dt = _time_fwd_bwd(params, x, engine, n=n)
            mode = "compiled" if (on_tpu or engine == "jnp") else "interpret"
            rows.append({
                "name": f"engine.{name}.{engine}",
                "us_per_call": dt * 1e6,
                "derived": f"M={M} {n_in}->{n_out} d={density} bs={block} "
                           f"grid={grid[0]}x{grid[1]} mode={mode}",
            })

    # MoE expert FFNs through the expert-batched engine (ISSUE 2 tentpole)
    E, K, d, f, density, block, tok_fast, tok_full = MOE_SHAPE
    T = tok_fast if fast else tok_full
    cfg0 = _moe_cfg("jnp")
    moe_params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg0)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, T, d), jnp.float32)
    _, G, C = moe_mod.moe_dispatch_dims(cfg0.moe, T)
    M_e = G * C                                    # capacity rows per expert
    kb = moe_params["idx_in"].shape[1]
    ebm, ebn = bsm.choose_tiles(M_e, f // block, kb, block, d // block, 4,
                                E=E, n_weight_operands=2)
    n = 3
    for engine in ("jnp", "pallas"):
        dt = _time_moe_fwd_bwd(moe_params, x, engine, n=n)
        mode = "compiled" if (on_tpu or engine == "jnp") else "interpret"
        rows.append({
            "name": f"engine.moe.{engine}",
            "us_per_call": dt * 1e6,
            "derived": f"T={T} E={E} top{K} {d}->{f} d={density} bs={block} "
                       f"C={C} tiles={ebm}x{ebn} mode={mode}",
        })

    # fused BP+UP vs two-pass train-update cycle (ISSUE 4 tentpole):
    # MNIST junction fwd+bwd+sgd-momentum ...
    n_in, n_out, density, block, m_fast, m_full = (*SHAPES["mnist"],)
    Mu = m_fast if fast else m_full
    up_params = _junction_params(n_in, n_out, density, block)
    xu = jax.random.normal(jax.random.PRNGKey(2), (Mu, n_in), jnp.float32)
    for engine in ("jnp", "pallas"):
        dt = _time_junction_update(up_params, xu, engine, n=3)
        mode = "compiled" if (on_tpu or engine == "jnp") else "interpret"
        rows.append({
            "name": f"engine.update.mnist.{engine}",
            "us_per_call": dt * 1e6,
            "derived": f"M={Mu} {n_in}->{n_out} d={density} bs={block} "
                       f"sgd-momentum {'fused' if engine == 'pallas' else 'two-pass'} "
                       f"mode={mode}",
        })
    # ... the same cycle under the in-kernel Adam epilogue (ISSUE 7):
    # second fp32 accumulator (vel) aliased in place, (HYP_K,) hyp row
    for engine in ("jnp", "pallas"):
        dt = _time_junction_update(up_params, xu, engine, n=3, optim="adam")
        mode = "compiled" if (on_tpu or engine == "jnp") else "interpret"
        rows.append({
            "name": f"engine.update.adam.mnist.{engine}",
            "us_per_call": dt * 1e6,
            "derived": f"M={Mu} {n_in}->{n_out} d={density} bs={block} "
                       f"adam {'fused' if engine == 'pallas' else 'two-pass'} "
                       f"mode={mode}",
        })
    # divergence-guard overhead (ISSUE 6): the fused MNIST update cycle
    # with the in-kernel [E] health output riding the flush epilogue vs
    # without — the cost of always-on non-finite detection
    dt_plain = _time_junction_update(up_params, xu, "pallas", n=3)
    dt_guard = _time_junction_update(up_params, xu, "pallas", n=3,
                                     with_health=True)
    mode = "compiled" if on_tpu else "interpret"
    rows.append({
        "name": "bench.guard.overhead",
        "us_per_call": dt_guard * 1e6,
        "derived": f"M={Mu} {n_in}->{n_out} d={density} bs={block} "
                   f"fused+health vs fused "
                   f"ratio={dt_guard / max(dt_plain, 1e-12):.3f} "
                   f"mode={mode}",
    })
    # ... and the full sparse-expert MoE layer through inject/merge
    for engine in ("jnp", "pallas"):
        dt = _time_moe_update(moe_params, x, engine, n=3)
        mode = "compiled" if (on_tpu or engine == "jnp") else "interpret"
        rows.append({
            "name": f"engine.update.moe.{engine}",
            "us_per_call": dt * 1e6,
            "derived": f"T={T} E={E} top{K} {d}->{f} d={density} bs={block} "
                       f"sgd-momentum {'fused' if engine == 'pallas' else 'two-pass'} "
                       f"mode={mode}",
        })
    for engine in ("jnp", "pallas"):
        dt = _time_moe_update(moe_params, x, engine, n=3, optim="adam")
        mode = "compiled" if (on_tpu or engine == "jnp") else "interpret"
        rows.append({
            "name": f"engine.update.adam.moe.{engine}",
            "us_per_call": dt * 1e6,
            "derived": f"T={T} E={E} top{K} {d}->{f} d={density} bs={block} "
                       f"adam {'fused' if engine == 'pallas' else 'two-pass'} "
                       f"mode={mode}",
        })
    rows.extend(_quant_rows(fast, on_tpu))
    rows.extend(_sweep_rows(fast, on_tpu))
    return rows


# --------------------------------------------- quantized-inference rows
def _time_infer(step, args, n=3):
    out = step(*args)               # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = step(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def _quant_rows(fast, on_tpu):
    """engine.infer.int8.* (quantized forwards per engine, ISSUE 8) and
    bench.quant.sweep (one E-batched quantized-population eval)."""
    from repro.core import quantize as qz

    rows = []
    n_in, n_out, density, block, m_fast, m_full = (*SHAPES["mnist"],)
    M = m_fast if fast else m_full
    params = _junction_params(n_in, n_out, density, block)
    x = jax.random.normal(jax.random.PRNGKey(3), (M, n_in), jnp.float32)
    qp = qz.quantize_junction(params, qz.QuantConfig(mode="int8"))
    for engine in ("jnp", "pallas"):
        step = jax.jit(lambda p, x, e=engine: sl.apply(p, x, engine=e,
                                                       act="sigmoid"))
        dt = _time_infer(step, (qp, x))
        mode = "compiled" if (on_tpu or engine == "jnp") else "interpret"
        rows.append({
            "name": f"engine.infer.int8.mnist.{engine}",
            "us_per_call": dt * 1e6,
            "derived": f"M={M} {n_in}->{n_out} d={density} bs={block} "
                       f"int8 fwd-only mode={mode}",
        })

    E, K, d, f, density, block, tok_fast, tok_full = MOE_SHAPE
    T = tok_fast if fast else tok_full
    moe_params = moe_mod.moe_init(jax.random.PRNGKey(0), _moe_cfg("jnp"))
    moe_q = qz.quantize_tree(moe_params, qz.QuantConfig(mode="int8"))
    xm = jax.random.normal(jax.random.PRNGKey(4), (1, T, d), jnp.float32)
    for engine in ("jnp", "pallas"):
        cfg = _moe_cfg(engine)

        @jax.jit
        def step(p, x, cfg=cfg):
            y, aux = moe_mod.moe_apply(p, x, cfg)
            return y

        dt = _time_infer(step, (moe_q, xm))
        mode = "compiled" if (on_tpu or engine == "jnp") else "interpret"
        rows.append({
            "name": f"engine.infer.int8.moe.{engine}",
            "us_per_call": dt * 1e6,
            "derived": f"T={T} E={E} top{K} {d}->{f} d={density} bs={block} "
                       f"int8 fwd-only mode={mode}",
        })

    # one cohort of the PTQ sweep (launch/quant_sweep.py): four int8
    # configs stacked on the member axis, one E-batched quantized eval
    Eq = 4
    configs = [qz.QuantConfig(mode="int8", bits=b, granularity=g)
               for b, g in ((8, "block"), (6, "block"), (4, "block"),
                            (8, "unit"))]
    members = [qz.quantize_junction(params, q) for q in configs]
    popq = {k: members[0][k] for k in sl.PATTERN_LEAVES}
    for k in ("wq", "w_scale", "b"):
        popq[k] = jnp.stack([m[k] for m in members])
    Ms = 256 if fast else 1024
    xs = jnp.broadcast_to(x[:Ms][None], (Eq, Ms, n_in))
    engine = sl.resolve_engine("auto")
    mode = "compiled" if (on_tpu or engine == "jnp") else "interpret"
    step = jax.jit(lambda p, x: sl.apply(p, x, engine=engine, act="sigmoid"))
    dt = _time_infer(step, (popq, xs))
    rows.append({
        "name": "bench.quant.sweep",
        "us_per_call": dt * 1e6,
        "derived": f"E={Eq} M={Ms} {n_in}->{n_out} d={density} bs={block} "
                   f"one E-batched int8 cohort eval engine={engine} "
                   f"mode={mode}",
    })
    return rows


# ------------------------------------------------- population-sweep rows
def _time_population_steps(step_fns, states, xb, tb, n=3):
    """Mean wall time of one 'generation': every (step, state) pair
    advanced once — ONE call for the E-batched population, E calls for
    the sequential baseline."""
    def run(states):
        out = []
        for fn, (p, m, h, k) in zip(step_fns, states):
            out.append(fn(p, m, h, k, xb, tb))
        jax.block_until_ready([o[2] for o in out])
        return [(p, m, h, k) for (p, m, _), (_, _, h, k) in zip(out, states)]

    states = run(states)            # compile
    t0 = time.perf_counter()
    for _ in range(n):
        states = run(states)
    return (time.perf_counter() - t0) / n


def _sweep_rows(fast, on_tpu):
    """bench.sweep.mnist.{population,sequential}: E=4 MNIST candidates,
    distinct lrs, one E-batched step vs E sequential single-model steps
    (same structure, same data, same update math)."""
    from repro.search import CandidateSpec, hyp_table, init_population
    from repro.search import population as pop

    E = 4
    layers = (1024, 512, 128)
    M = 256 if fast else 12544
    engine = sl.resolve_engine("auto")
    mode = "compiled" if (on_tpu or engine == "jnp") else "interpret"
    specs = [CandidateSpec(lr=0.02 * (i + 1), momentum=0.9, density=0.25,
                           layers=layers, block=128, init_seed=i)
             for i in range(E)]
    key = jax.random.PRNGKey(0)
    xb = jax.random.uniform(jax.random.PRNGKey(1), (M, layers[0]))
    tb = jax.nn.one_hot(
        jax.random.randint(jax.random.PRNGKey(2), (M,), 0, 10), layers[-1])

    pop_params = init_population(key, specs)
    batched = [(pop_params, pop.init_momentum(pop_params), hyp_table(specs),
                jnp.ones((E,), jnp.float32))]
    step = pop.make_population_step(engine=engine, donate=False)
    dt = _time_population_steps([step], batched, xb, tb)
    rows = [{
        "name": "bench.sweep.mnist.population",
        "us_per_call": dt * 1e6,
        "derived": f"E={E} M={M} layers={'x'.join(map(str, layers))} "
                   f"one E-batched step engine={engine} mode={mode}",
    }]

    seq = []
    for i in range(E):
        p1 = init_population(key, specs[i:i + 1])
        seq.append((p1, pop.init_momentum(p1), hyp_table(specs[i:i + 1]),
                    jnp.ones((1,), jnp.float32)))
    step1 = pop.make_population_step(engine=engine, donate=False)
    dt = _time_population_steps([step1] * E, seq, xb, tb)
    rows.append({
        "name": "bench.sweep.mnist.sequential",
        "us_per_call": dt * 1e6,
        "derived": f"E={E} M={M} layers={'x'.join(map(str, layers))} "
                   f"{E} sequential single-model steps engine={engine} "
                   f"mode={mode}",
    })
    return rows
