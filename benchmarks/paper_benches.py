"""One benchmark per paper table/figure (EXPERIMENTS.md index).

Each function returns a list of result dicts and is registered in
``BENCHES``; benchmarks/run.py prints the ``name,us_per_call,derived`` CSV.
``fast=True`` (default for CI) trims epochs/samples; ``--full`` reproduces
the paper-scale runs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixed_point as fxp
from repro.core import junction_pipeline as JP
from repro.core import paper_net as PN
from repro.data.mnist import paper_dataset


def _timed(fn, *args, n=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n, out


def _train(cfg, xs, ys, epochs, pipelined=False):
    p = PN.init(cfg)
    if pipelined:
        step = jax.jit(lambda p: PN.train_epoch_pipelined(p, xs, ys, 2.0 ** -3, cfg))
        for _ in range(epochs):
            p, corr = step(p)
        return p, float(corr[-1000:].mean())
    step = jax.jit(lambda p: PN.train_epoch(p, xs, ys, 2.0 ** -3, cfg))
    corr = None
    for _ in range(epochs):
        p, _, corr = step(p)
    return p, float(corr[-1000:].mean())


# ------------------------------------------------------- Table I + timing
def table1_throughput(fast=True):
    """Implemented network config + block-cycle throughput model vs measured
    software step time (the model is the paper's Sec. III-D-6 claim)."""
    cfg = PN.PaperNetConfig()
    xs, ys, _ = paper_dataset(1024 if fast else 12544)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    p = PN.init(cfg)
    ep = jax.jit(lambda p: PN.train_epoch(p, xs, ys, 2.0 ** -3, cfg))
    dt, _ = _timed(ep, p, n=2)
    per_input_sw = dt / xs.shape[0]
    rows = [{
        "name": "table1.block_cycle_model_us",
        "us_per_call": JP.block_cycle_s(cfg) * 1e6,     # paper: 2.27 us
        "derived": f"W/z+2 cycles @15MHz; paper reports 2.27us",
    }, {
        "name": "table1.sw_per_input_us",
        "us_per_call": per_input_sw * 1e6,
        "derived": f"jax cpu online-SGD per input; params={cfg.n_params()}",
    }, {
        "name": "table1.overall_density",
        "us_per_call": 0.0,
        "derived": f"{cfg.overall_density():.5f} (paper 0.07576)",
    }]
    return rows


# ------------------------------------------------------- Table II bit width
def table2_bitwidth(fast=True):
    xs, ys, _ = paper_dataset(2048 if fast else 12544)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    epochs = (1, 3) if fast else (1, 15)
    rows = []
    for fmt in fxp.PAPER_TRIPLETS:
        cfg = PN.PaperNetConfig(fmt=fmt)
        t0 = time.perf_counter()
        _, acc1 = _train(cfg, xs, ys, epochs[0])
        _, accN = _train(cfg, xs, ys, epochs[1])
        rows.append({
            "name": f"table2.b{fmt.bw}_{fmt.bn}_{fmt.bf}",
            "us_per_call": (time.perf_counter() - t0) * 1e6,
            "derived": f"acc@{epochs[0]}ep={acc1:.3f} acc@{epochs[1]}ep={accN:.3f}",
        })
    return rows


# ------------------------------------------------------- Fig. 4 ranges
def fig4_ranges(fast=True):
    xs, ys, _ = paper_dataset(2048 if fast else 12544)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    cfg = PN.PaperNetConfig(fmt=None)
    p = PN.init(cfg)
    rows = []
    step = jax.jit(lambda p: PN.train_epoch(p, xs, ys, 2.0 ** -3, cfg))
    for ep in range(3 if fast else 15):
        p, _, corr = step(p)
        w_max = max(float(jnp.max(jnp.abs(j["w"]))) for j in p["junctions"])
        b_max = max(float(jnp.max(jnp.abs(j["b"]))) for j in p["junctions"])
        rows.append({
            "name": f"fig4.epoch{ep + 1}",
            "us_per_call": 0.0,
            "derived": f"max|w|={w_max:.3f} max|b|={b_max:.3f} "
                       f"acc={float(corr[-500:].mean()):.3f} (paper: stays < 8)",
        })
    return rows


# ------------------------------------------------------- Fig. 5 clipping
def fig5_dynamic_range(fast=True):
    """Sparse vs FC pre-activation |sum w*a + b| distribution and clip %."""
    xs, ys, _ = paper_dataset(1024 if fast else 12544)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    rows = []
    for name, d_out in [("sparse", (4, 16)), ("fc", (64, 32))]:
        cfg = PN.PaperNetConfig(d_out=d_out, fmt=None,
                                z=(128, 32) if name == "sparse" else (1024, 64))
        p, acc = _train(cfg, xs, ys, 2 if fast else 15)
        acts, _ = PN.forward(p, xs[:512], cfg)
        pre = jnp.take(p["junctions"][0]["w"] * 0, jnp.array([0]))  # placeholder
        # recompute junction-1 pre-activation explicitly
        jp = cfg, p
        j0 = p["junctions"][0]
        gathered = jnp.take(xs[:512], j0["idx"], axis=-1)
        s = jnp.sum(j0["w"] * gathered, axis=-1) + j0["b"]
        clip_pct = float(jnp.mean((jnp.abs(s) > 8.0)))
        rows.append({
            "name": f"fig5.{name}",
            "us_per_call": 0.0,
            "derived": f"clip%={100 * clip_pct:.1f} max|s|={float(jnp.max(jnp.abs(s))):.2f} "
                       f"std={float(jnp.std(s)):.2f} (paper: sparse 17% vs FC 57%)",
        })
    return rows


# ------------------------------------------------------- Fig. 6 activations
def fig6_activations(fast=True):
    xs, ys, _ = paper_dataset(2048 if fast else 12544)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    rows = []
    for act in ["sigmoid", "relu8", "relu1"]:
        cfg = PN.PaperNetConfig(fmt=fxp.PAPER_FMT, activation=act)
        _, acc = _train(cfg, xs, ys, 2 if fast else 10)
        rows.append({"name": f"fig6.{act}", "us_per_call": 0.0,
                     "derived": f"acc={acc:.3f} (paper: sigmoid ~ relu8 > relu1 early)"})
    return rows


# ------------------------------------------------------- Fig. 7 density
def fig7_density(fast=True):
    xs, ys, _ = paper_dataset(2048 if fast else 12544)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    rows = []
    for d2_out in [2, 4, 8, 16, 32]:
        cfg = PN.PaperNetConfig(d_out=(4, d2_out), z=(128, 32))
        _, acc = _train(cfg, xs, ys, 2 if fast else 15)
        dens = d2_out / 32
        rows.append({"name": f"fig7.j2_density_{dens:.3f}",
                     "us_per_call": 0.0,
                     "derived": f"acc={acc:.3f} (paper: 50% optimal for junction 2)"})
    return rows


# ------------------------------------------------------- Fig. 8 z sweep
def fig8_z_sweep(fast=True):
    rows = []
    for r in JP.z_sweep_configs(PN.PaperNetConfig()):
        rows.append({
            "name": f"fig8.total_z_{r['total_z']}",
            "us_per_call": r["block_cycle_s"] * 1e6,
            "derived": f"throughput={r['throughput_per_s']:.0f}/s "
                       f"multipliers={r['multipliers']} "
                       f"(paper: 2.27us @ z=160, 0.4us at max z)",
        })
    return rows


# ------------------------------------------------------- pipeline parity
def pipeline_parity(fast=True):
    """Junction pipelining (stale updates) vs sequential — the Fig. 1 / 3L
    claim: same accuracy, 3L ops in flight."""
    xs, ys, _ = paper_dataset(2048 if fast else 12544)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    cfg = PN.PaperNetConfig(fmt=fxp.PAPER_FMT)
    t0 = time.perf_counter()
    _, acc_seq = _train(cfg, xs, ys, 2 if fast else 14)
    t1 = time.perf_counter()
    _, acc_pipe = _train(cfg, xs, ys, 2 if fast else 14, pipelined=True)
    t2 = time.perf_counter()
    return [{
        "name": "pipeline.sequential", "us_per_call": (t1 - t0) * 1e6,
        "derived": f"acc={acc_seq:.3f}",
    }, {
        "name": "pipeline.junction_pipelined", "us_per_call": (t2 - t1) * 1e6,
        "derived": f"acc={acc_pipe:.3f} speedup_model=3L={3 * cfg.n_junctions}x "
                   f"bubble=0",
    }]


BENCHES = {
    "table1": table1_throughput,
    "table2": table2_bitwidth,
    "fig4": fig4_ranges,
    "fig5": fig5_dynamic_range,
    "fig6": fig6_activations,
    "fig7": fig7_density,
    "fig8": fig8_z_sweep,
    "pipeline": pipeline_parity,
}
