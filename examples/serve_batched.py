"""Batched serving example: prefill a request batch, decode step-locked,
report per-token latency — the serving-side counterpart of the paper's
1-input-per-block-cycle pipeline.

    PYTHONPATH=src python examples/serve_batched.py --arch zamba2-2.7b
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np
import jax

from repro.configs import registry
from repro.models import model as M
from repro.serve.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b",
                    choices=list(registry.ARCHS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.7)
    args = ap.parse_args()

    cfg = registry.get(args.arch).reduced()   # CPU-sized
    params = M.init(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=args.max_new,
                                          temperature=args.temperature,
                                          seed=17))
    rng = np.random.default_rng(0)
    V = cfg.raw_vocab or cfg.vocab
    prompts = rng.integers(0, V, size=(args.requests, args.prompt_len)
                           ).astype(np.int32)
    extra = {}
    if cfg.family == "vlm":
        extra["patches"] = rng.standard_normal(
            (args.requests, min(cfg.num_patches, args.prompt_len // 2),
             cfg.d_model)).astype(np.float32)
    if cfg.family == "audio":
        extra["frames"] = rng.standard_normal(
            (args.requests, cfg.enc_frames, cfg.d_model)).astype(np.float32)

    t0 = time.perf_counter()
    out = eng.generate(prompts, extra or None)
    dt = time.perf_counter() - t0
    total = args.requests * args.max_new
    print(f"arch={args.arch} ({cfg.family}) generated {out.shape[0]}x"
          f"{out.shape[1]} tokens in {dt:.2f}s -> {total / dt:.1f} tok/s, "
          f"{dt / args.max_new * 1e3:.1f} ms/step")
    print("sample:", out[0][:12].tolist())


if __name__ == "__main__":
    main()
