"""End-to-end driver: train a ~100M-param LM with the paper's pre-defined
block sparsity on its FFNs, with checkpointing and auto-resume.

    PYTHONPATH=src python examples/train_sparse_lm.py --steps 300

The config is a scaled-down stablelm-family decoder (d_model 512, 8 layers,
vocab 50304 -> ~100M params syntax); ``--dense`` trains the FC baseline the
paper compares against — at density 0.25 the sparse FFN does 4x less FFN
compute for a near-identical loss curve (EXPERIMENTS.md Sec. paper-claims).
"""
import argparse
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core.sparsity import SparsityConfig
from repro.data.pipeline import LMTokenPipeline
from repro.models import model as M
from repro.optim import adam, cosine_schedule
from repro.train.steps import make_train_step
from repro.train.train_loop import TrainLoopConfig, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--dense", action="store_true", help="FC baseline")
    ap.add_argument("--density", type=float, default=0.25)
    ap.add_argument("--ckpt", default="/tmp/repro_sparse_lm")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        registry.get("stablelm-3b"),
        n_layers=8, d_model=512, n_heads=8, kv_heads=8, head_dim=64,
        d_ff=1536, max_seq=2048, attn_chunk=128,
    )
    if not args.dense:
        cfg = cfg.with_sparsity(SparsityConfig(
            density=args.density, block=128, where="ffn"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params)
                   if jnp.issubdtype(p.dtype, jnp.inexact))
    print(f"{'dense' if args.dense else 'sparse'} model: {n_params / 1e6:.1f}M "
          f"trainable params")

    opt = adam(cosine_schedule(3e-4, warmup=20, total=args.steps))
    opt_state = opt.init(params)
    ts = make_train_step(cfg, opt)   # jitted with params/opt donated
    pipe = LMTokenPipeline(cfg, args.batch, args.seq)
    t0 = time.time()
    res = run(TrainLoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                              ckpt_every=100, log_every=20),
              ts, params, opt_state, pipe)
    h = res["history"]
    print(f"done in {time.time() - t0:.0f}s: loss {h[0]['loss']:.3f} -> "
          f"{h[-1]['loss']:.3f} over {res['step']} steps")


if __name__ == "__main__":
    main()
