"""Quickstart: train the paper's exact network (Table I) on MNIST-class
data in (12,3,8) fixed point with pre-defined sparsity, then compare the
junction-pipelined schedule.

    PYTHONPATH=src python examples/quickstart.py [--epochs 3] [--full]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.core import junction_pipeline as JP
from repro.core import paper_net as PN
from repro.data.mnist import paper_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--full", action="store_true",
                    help="full 12544-sample epochs (paper scale)")
    args = ap.parse_args()

    n = 12544 if args.full else 3072
    x, y, _ = paper_dataset(n)
    xs, ys = jnp.asarray(x), jnp.asarray(y)

    cfg = PN.PaperNetConfig(fmt=fxp.PAPER_FMT)
    print(f"network 1024-64-32, params={cfg.n_params()}, "
          f"overall density={cfg.overall_density():.4f}")
    print(f"block cycle = {JP.block_cycle_s(cfg) * 1e6:.2f} us "
          f"(paper: 2.27 us at 15 MHz)")
    print(f"arithmetic units: {JP.resources(cfg)}")

    # eta halving schedule (Sec. III-B), starting at 2^-3
    params = PN.init(cfg)
    epoch = jax.jit(lambda p, eta: PN.train_epoch(p, xs, ys, eta, cfg))
    t0 = time.time()
    for e in range(args.epochs):
        halvings = 0 if e < 2 else 1 + (e - 2) // 4
        eta = 2.0 ** -min(3 + halvings, 7)
        params, losses, corr = epoch(params, eta)
        print(f"epoch {e + 1}: eta=2^{-(3 + min(halvings, 4))} "
              f"acc(last1000)={float(corr[-1000:].mean()):.4f}")
    print(f"sequential training: {time.time() - t0:.1f}s")

    # the paper's junction-pipelined schedule (Fig. 1): FF/BP/UP overlapped
    params2 = PN.init(cfg)
    pipe = jax.jit(lambda p: PN.train_epoch_pipelined(p, xs, ys, 2.0 ** -3, cfg))
    for e in range(args.epochs):
        params2, corr2 = pipe(params2)
    print(f"junction-pipelined acc(last1000)={float(corr2[-1000:].mean()):.4f} "
          f"(zero-bubble, {3 * cfg.n_junctions} ops in flight)")


if __name__ == "__main__":
    main()
