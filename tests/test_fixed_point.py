"""Fixed-point arithmetic properties (paper Sec. III-C)."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core import fixed_point as fxp

FMT = fxp.PAPER_FMT


@given(st.floats(-100, 100, allow_nan=False))
@settings(max_examples=200, deadline=None)
def test_quantize_range_and_grid(x):
    q = float(fxp.quantize(jnp.float32(x), FMT))
    assert FMT.min_val <= q <= FMT.max_val
    scaled = q * FMT.scale
    assert abs(scaled - round(scaled)) < 1e-4, "on the 2^-bf grid"


@given(st.floats(-8, 7.99, allow_nan=False))
@settings(max_examples=100, deadline=None)
def test_quantize_idempotent(x):
    q1 = fxp.quantize(jnp.float32(x), FMT)
    q2 = fxp.quantize(q1, FMT)
    assert float(q1) == float(q2)


@given(st.floats(-7.9, 7.9), st.integers(0, 2 ** 12 - 1))
@settings(max_examples=100, deadline=None)
def test_encode_decode_roundtrip(x, code):
    q = fxp.quantize(jnp.float32(x), FMT)
    assert float(fxp.decode(fxp.encode(q, FMT), FMT)) == float(q)
    # codes roundtrip too (decode is the left inverse on valid codes)
    v = fxp.decode(jnp.int32(code), FMT)
    assert int(fxp.encode(v, FMT)) == code


def test_clipping_saturates():
    assert float(fxp.quantize(jnp.float32(10.0), FMT)) == FMT.max_val  # 7.996
    assert float(fxp.quantize(jnp.float32(-10.0), FMT)) == FMT.min_val  # -8
    assert abs(FMT.max_val - 7.99609375) < 1e-9


def test_tree_sum_clipping_matters():
    """Per-node clipping differs from clip-at-end — the hardware semantics."""
    x = jnp.array([7.0, 7.0, -7.0, -6.0])
    tree = float(fxp.tree_sum_clipped(x, FMT))
    # tree: (7+7 -> clip 7.996) + (-7-6 -> clip -8) = -0.00390625
    plain = float(fxp.quantize(jnp.sum(x), FMT))  # 1.0
    assert tree != plain
    assert abs(tree - fxp.quantize(jnp.float32(7.99609375 - 8.0), FMT)) < 1e-6


@given(st.lists(st.floats(-1, 1), min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_tree_sum_no_clip_equals_sum(vals):
    """When nothing clips, the tree adder equals an exact sum of grid values."""
    x = fxp.quantize(jnp.array(vals, jnp.float32), FMT)
    if abs(float(jnp.sum(jnp.abs(x)))) < FMT.max_val:  # no clipping possible
        got = float(fxp.tree_sum_clipped(x, FMT))
        want = float(jnp.sum(x))
        assert abs(got - want) < 1e-4


def test_sigmoid_tables_match_ideal():
    sig, dsig = fxp.sigmoid_tables(FMT)
    assert sig.shape == (4096,)       # all 12-bit codes (paper III-D-1)
    codes = np.arange(4096)
    vals = np.where(codes >= 2048, codes - 4096, codes) / 256.0
    ideal = 1 / (1 + np.exp(-vals))
    assert np.max(np.abs(sig - ideal)) <= 2 ** -9 + 1e-9  # half-ulp of b_f=8
    assert dsig.min() >= 0.0 and dsig.max() <= 0.25 + 1e-9


def test_lut_sigmoid_on_grid():
    x = fxp.quantize(jnp.linspace(-8, 7.9, 100), FMT)
    s, ds = fxp.lut_sigmoid(x, FMT)
    ideal = 1 / (1 + np.exp(-np.asarray(x)))
    assert np.max(np.abs(np.asarray(s) - ideal)) < 2 ** -8
