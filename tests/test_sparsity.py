"""Invariants of pre-defined sparsity (paper Sec. II-A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core import sparse_linear as sl
from repro.core.sparsity import (SparsityConfig, make_block_pattern,
                                 make_neuron_pattern)


@given(st.sampled_from([(1024, 64, 64), (64, 32, 32), (256, 128, 16)]),
       st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_neuron_pattern_paper_identity(cfg, seed):
    """N_{i-1} * d_out = N_i * d_in = W_i, nobody disconnected."""
    n_in, n_out, d_in = cfg
    pat = make_neuron_pattern(n_in, n_out, d_in, seed=seed)
    W = n_out * d_in
    assert pat.d_out * n_in == W
    counts = np.bincount(pat.idx.reshape(-1), minlength=n_in)
    assert np.all(counts == pat.d_out), "every left neuron contributes equally"
    for j in range(n_out):
        assert len(np.unique(pat.idx[j])) == d_in, "no duplicate edges"


def test_table1_densities():
    """The exact Table-I junctions."""
    j1 = make_neuron_pattern(1024, 64, 64)
    j2 = make_neuron_pattern(64, 32, 32)
    assert j1.density == 0.0625 and j1.d_out == 4 and j1.n_weights == 4096
    assert j2.density == 0.5 and j2.d_out == 16 and j2.n_weights == 1024
    overall = (j1.n_weights + j2.n_weights) / (1024 * 64 + 64 * 32)
    assert abs(overall - 0.07576) < 1e-4


def test_block_pattern_density_selection():
    pat = make_block_pattern(1024, 512, density=0.25, block=128)
    assert pat.n_weights <= 1024 * 512
    assert 0.1 <= pat.density <= 0.5


def test_sparse_linear_matches_dense_at_full_density():
    key = jax.random.PRNGKey(0)
    sp = SparsityConfig(density=1.01, block=32, where="ffn")  # kb == nib
    # density > 1 clamps to full fan-in: block-sparse == dense reshuffled
    p = sl.init_sparse(key, 128, 96, sp, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (7, 128))
    y = sl.apply_jnp(p, x)
    # dense equivalent: scatter blocks back into a [128, 96] matrix
    w = np.zeros((128, 96), np.float32)
    wq = np.asarray(p["w"])
    idx = np.asarray(p["idx"])
    for ob in range(idx.shape[0]):
        for t in range(idx.shape[1]):
            ib = idx[ob, t]
            w[ib * 32:(ib + 1) * 32, ob * 32:(ob + 1) * 32] = wq[ob, t]
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) @ w,
                               rtol=2e-4, atol=2e-4)


def test_init_linear_falls_back_to_dense():
    key = jax.random.PRNGKey(0)
    sp = SparsityConfig(density=0.25, block=128, where="ffn")
    p = sl.init_linear(key, 100, 64, family="ffn", sp=sp)  # not tileable
    assert not sl.is_sparse(p)
    p2 = sl.init_linear(key, 512, 256, family="attn", sp=sp)  # family off
    assert not sl.is_sparse(p2)
    p3 = sl.init_linear(key, 512, 256, family="ffn", sp=sp)
    assert sl.is_sparse(p3)


def test_sparse_params_not_trainable_ints():
    from repro.optim.optimizers import _is_trainable
    key = jax.random.PRNGKey(0)
    sp = SparsityConfig(density=0.5, block=32)
    p = sl.init_sparse(key, 128, 128, sp)
    assert not _is_trainable(p["idx"])
    assert _is_trainable(p["w"])
