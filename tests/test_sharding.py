"""Sharding-rule invariants over every assigned arch x both meshes.

Uses AbstractMesh — no devices needed, so the production 512-chip layouts
are checkable in the normal test process.
"""
import functools

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import registry
from repro.models import model as M
from repro.parallel import sharding as sh

try:
    _leaves_with_path = jax.tree.leaves_with_path
except AttributeError:  # jax 0.4.x
    from jax.tree_util import tree_leaves_with_path as _leaves_with_path

def _abstract_mesh(sizes, names):
    try:  # jax >= 0.5: AbstractMesh(axis_sizes, axis_names)
        return AbstractMesh(sizes, names)
    except TypeError:  # jax 0.4.x: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(names, sizes)))


MESHES = {
    "single": _abstract_mesh((16, 16), ("data", "model")),
    "multi": _abstract_mesh((2, 16, 16), ("pod", "data", "model")),
}
ARCHS = list(registry.ARCHS)


@functools.lru_cache(maxsize=None)
def _pshapes(arch):
    cfg = registry.get(arch)
    return cfg, jax.eval_shape(functools.partial(M.init, cfg),
                               jax.random.PRNGKey(0))


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("mesh_name", list(MESHES))
def test_param_specs_divisible(arch, mesh_name):
    """Every sharded dim divides its mesh axis; spec rank == leaf rank."""
    cfg, pshapes = _pshapes(arch)
    mesh = MESHES[mesh_name]
    sizes = dict(mesh.shape)
    specs = sh.param_specs(cfg, pshapes, mesh)

    leaves = _leaves_with_path(pshapes)
    spec_leaves = {jax.tree_util.keystr(k): v
                   for k, v in _leaves_with_path(
                       specs, is_leaf=lambda x: isinstance(x, P))}
    for key, leaf in leaves:
        spec = spec_leaves[jax.tree_util.keystr(key)]
        assert len(spec) <= len(leaf.shape), (key, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 10):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= sizes[a]
            assert dim % n == 0, (key, spec, leaf.shape)


@pytest.mark.parametrize("arch", ["whisper-base"])
def test_sp_strategy_never_model_shards_weights(arch):
    cfg, pshapes = _pshapes(arch)
    specs = sh.param_specs(cfg, pshapes, MESHES["single"])
    for k, spec in _leaves_with_path(
            specs, is_leaf=lambda x: isinstance(x, P)):
        assert "model" not in [a for a in spec if isinstance(a, str)], (k, spec)


@pytest.mark.parametrize("arch", ARCHS)
def test_cache_specs_shard_sequence(arch):
    cfg = registry.get(arch)
    cshapes = jax.eval_shape(lambda: M.make_cache(cfg, 128, 32768))
    specs = sh.cache_specs(cfg, cshapes, MESHES["single"])
    # at least one leaf must shard on model (seq or state channels)
    found = any("model" in [a for a in spec if isinstance(a, str)]
                for _, spec in _leaves_with_path(
                    specs, is_leaf=lambda x: isinstance(x, P)))
    assert found, f"{arch}: cache entirely replicated on model axis"


def test_batch_specs_b1_replicates():
    cfg = registry.get("falcon-mamba-7b")
    spec = sh.batch_specs(cfg, {"tokens": jax.ShapeDtypeStruct((1, 524288), jnp.int32)},
                          MESHES["multi"])
    assert spec["tokens"][0] is None     # batch 1 cannot shard


def test_junction_matmul_shard_map_smoke():
    """ROADMAP follow-up: the unified junction engine composes with
    shard_map — on a 1-device mesh the wrapped kernel (batch rows sharded
    over "data") matches the unwrapped result forward AND backward (the
    custom_vjp, including the in-kernel reverse-weight DMA, traces under
    shard_map)."""
    import numpy as np
    from jax.sharding import Mesh
    try:
        from jax.experimental.shard_map import shard_map
    except ImportError:  # newer jax: promoted out of experimental
        from jax.sharding import shard_map

    from repro.core.sparsity import make_block_pattern
    from repro.kernels import ops

    bs = 8
    pat = make_block_pattern(6 * bs, 4 * bs, 0.34, bs)
    idx, rob, rt, rc = (jnp.asarray(pat.idx), jnp.asarray(pat.rev_ob),
                        jnp.asarray(pat.rev_t), jnp.asarray(pat.rev_cnt))
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    M = 32
    x = jax.random.normal(ks[0], (M, 6 * bs))
    w = jax.random.normal(ks[1], (pat.n_out_blocks, pat.fan_in_blocks,
                                  bs, bs)) * 0.1
    b = jax.random.normal(ks[2], (4 * bs,)) * 0.3
    co = jax.random.normal(ks[3], (M, 4 * bs))

    def apply_fn(x, w, b):
        return ops.junction_matmul(x, w, idx, rob, rt, rc, bias=b, act="silu")

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    wrapped = shard_map(apply_fn, mesh=mesh,
                        in_specs=(P("data"), P(), P()), out_specs=P("data"),
                        check_rep=False)

    y_ref = apply_fn(x, w, b)
    y_map = wrapped(x, w, b)
    np.testing.assert_allclose(np.asarray(y_map), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)

    loss_ref = lambda x, w, b: jnp.sum(apply_fn(x, w, b) * co)
    loss_map = lambda x, w, b: jnp.sum(wrapped(x, w, b) * co)
    g_ref = jax.grad(loss_ref, (0, 1, 2))(x, w, b)
    g_map = jax.grad(loss_map, (0, 1, 2))(x, w, b)
    for a, gm, name in zip(g_ref, g_map, ("dx", "dw", "db")):
        np.testing.assert_allclose(np.asarray(gm), np.asarray(a),
                                   rtol=1e-4, atol=1e-4, err_msg=name)


def test_attention_head_guard():
    """whisper q/k/v/o replicate (8 heads < 16); qwen2 q shards, kv replicate."""
    cfgw, pw = _pshapes("whisper-base")
    cfgq, pq = _pshapes("qwen2-72b")
    mesh = MESHES["single"]
    sw = sh.param_specs(cfgw, pw, mesh)
    sq = sh.param_specs(cfgq, pq, mesh)
    assert sw["layers"]["attn"]["wq"]["w"] == P(None, "data", None)
    assert sq["layers"]["attn"]["wq"]["w"] == P(None, "data", "model")
    assert sq["layers"]["attn"]["wk"]["w"] == P(None, "data", None)
