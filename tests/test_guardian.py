"""Divergence guardian (ISSUE 6): in-kernel health flags, checkpoint
rollback with lr backoff, cohort quarantine, serve-side logit guard.

Layers under test, bottom-up:
  * kernels/ops — the update kernels' [E] health output: zero on clean
    updates (and numerically inert), > 0 the moment an update writes
    non-finite parameters in place;
  * search/population — per-member health isolation: one diverged member
    flags ONLY its own slot, on both the fused (in-kernel flags) and
    two-pass (materialized-grad scan) paths;
  * train/steps + train_loop — lr_scale equivalence (hyp-table fold vs
    delta interpolation) and the full trip -> rollback -> backoff ->
    skip -> recover loop against a NaN/inf-poisoned data stream;
  * search/scheduler — mid-round quarantine leaves the survivors'
    parameter trajectories BITWISE identical to a cohort that never
    contained the diverged member;
  * serve/engine — a slot whose logits go non-finite is EOS-terminated
    while every other slot's output is untouched.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SweepConfig
from repro.core import sparse_linear as sl
from repro.core.sparsity import SparsityConfig
from repro.kernels import ops
from repro.search import CandidateSpec, run_sweep
from repro.search import population as pop
from repro.train import checkpoint as ckpt_mod
from repro.train import steps as steps_mod
from repro.optim import constant_schedule, fused_sgd
from repro.train.train_loop import (GuardianConfig, GuardianTripped,
                                    TrainLoopConfig, run)

N_IN, N_OUT, BATCH = 128, 64, 32
_SP = SparsityConfig(density=0.5, block=32, where="all")


def _junction(seed=0):
    return sl.init_sparse(jax.random.PRNGKey(seed), N_IN, N_OUT, _SP,
                          bias=True)


# ------------------------------------------------------------ kernel level
def test_health_flags_zero_and_inert_on_clean_update():
    """Clean update: health == 0 AND riding the health operand changes no
    numerics (same updated params/momenta as the plain fused call)."""
    p = _junction()
    pat = (p["idx"], p["rev_ob"], p["rev_t"], p["rev_cnt"])
    hyp = jnp.asarray([0.05, 0.9], jnp.float32)
    mom = jnp.zeros(p["w"].shape, jnp.float32)
    mom_b = jnp.zeros(p["b"].shape, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (BATCH, N_IN))

    def loss_h(w, b, m, mb, h):
        y = ops.junction_train_update(x, w, *pat, bias=b, act="sigmoid",
                                      hyp=hyp, mom=m, mom_b=mb, health=h)
        return jnp.sum(y)

    def loss_plain(w, b, m, mb):
        y = ops.junction_train_update(x, w, *pat, bias=b, act="sigmoid",
                                      hyp=hyp, mom=m, mom_b=mb)
        return jnp.sum(y)

    h0 = jnp.zeros((1,), jnp.float32)
    w_h, b_h, m_h, mb_h, h = jax.grad(loss_h, (0, 1, 2, 3, 4))(
        p["w"], p["b"], mom, mom_b, h0)
    w_p, b_p, m_p, mb_p = jax.grad(loss_plain, (0, 1, 2, 3))(
        p["w"], p["b"], mom, mom_b)
    assert float(h[0]) == 0.0
    for a, b in [(w_h, w_p), (b_h, b_p), (m_h, m_p), (mb_h, mb_p)]:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_health_flags_fire_on_nonfinite_update():
    """NaN in the input -> NaN dw -> the in-kernel update writes
    non-finite parameters -> the flushed health count goes positive."""
    p = _junction()
    pat = (p["idx"], p["rev_ob"], p["rev_t"], p["rev_cnt"])
    hyp = jnp.asarray([0.05, 0.9], jnp.float32)
    mom = jnp.zeros(p["w"].shape, jnp.float32)
    mom_b = jnp.zeros(p["b"].shape, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (BATCH, N_IN))
    x = x.at[0, 0].set(jnp.nan)

    def loss(w, b, m, mb, h):
        y = ops.junction_train_update(x, w, *pat, bias=b, act="sigmoid",
                                      hyp=hyp, mom=m, mom_b=mb, health=h)
        return jnp.sum(jnp.where(jnp.isfinite(y), y, 0.0))

    h0 = jnp.zeros((1,), jnp.float32)
    w, b, m, mb, h = jax.grad(loss, (0, 1, 2, 3, 4))(
        p["w"], p["b"], mom, mom_b, h0)
    assert float(h[0]) > 0.0
    assert not bool(jnp.all(jnp.isfinite(w)))


# ------------------------------------------------------- population level
@pytest.mark.parametrize("engine", ["jnp", "pallas"])
def test_population_health_isolates_bad_member(engine):
    """One member with a poisoned weight flags ONLY its own slot."""
    specs = [CandidateSpec(lr=0.05, momentum=0.0, density=0.5,
                           layers=(N_IN, N_OUT), block=32, init_seed=i)
             for i in range(3)]
    params = pop.init_population(jax.random.PRNGKey(0), specs)
    mom = pop.init_momentum(params, specs)
    hyp = pop.hyp_table(specs)
    mask = jnp.ones((3,), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (BATCH, N_IN))
    t = jax.nn.one_hot(
        jax.random.randint(jax.random.PRNGKey(2), (BATCH,), 0, N_OUT), N_OUT)
    step = pop.make_population_step(engine=engine, with_health=True,
                                    donate=False)

    _, _, losses, health = step(params, mom, hyp, mask, x, t)
    assert np.asarray(health).tolist() == [0.0, 0.0, 0.0]

    params[0]["w"] = params[0]["w"].at[1, 0, 0, 0, 0].set(jnp.nan)
    new_params, _, losses, health = step(params, mom, hyp, mask, x, t)
    health = np.asarray(health)
    assert health[1] > 0.0
    assert health[0] == 0.0 and health[2] == 0.0
    # the clean members' updates stayed finite
    for e in (0, 2):
        for layer in pop.member_slice(new_params, e):
            assert bool(jnp.all(jnp.isfinite(layer["w"])))


# -------------------------------------------------- guardian loop (e2e)
@dataclasses.dataclass
class PoisonPipeline:
    """Deterministic (seed, step) regression stream — targets are a
    learnable function t = sigmoid(x @ W_true) — with chosen data steps
    poisoned by a non-finite input value."""
    w_true: np.ndarray
    poison_steps: frozenset = frozenset()
    poison_value: float = np.inf
    seed: int = 0
    step: int = 0

    def state(self):
        return {"seed": self.seed, "step": self.step}

    def __iter__(self):
        return self

    def __next__(self):
        rng = np.random.default_rng((self.seed << 20) ^ self.step)
        x = rng.standard_normal((BATCH, N_IN)).astype(np.float32)
        t = 1.0 / (1.0 + np.exp(-(x @ self.w_true)))
        if self.step in self.poison_steps:
            x[0, 0] = self.poison_value
        self.step += 1
        return {"x": x, "t": t.astype(np.float32)}


def _make_regression_step(engine, lr=0.2, momentum=0.9):
    """A train_step honouring the 5-arg (params, opt, batch, step,
    lr_scale) contract on a single junction: the fused path mirrors
    steps._make_fused_train_step (hyp-table fold, in-kernel health),
    the two-pass path mirrors the reference (delta interpolation,
    materialized-grad scan)."""
    opt = fused_sgd(constant_schedule(lr), momentum=momentum)

    if engine == "pallas":
        def train_step(params, opt_state, batch, step, lr_scale=None):
            from repro.kernels import block_sparse_matmul as bsm
            hyp = opt.hyp(step)
            if lr_scale is not None:
                hyp = hyp.at[bsm.COL_LR].multiply(jnp.float32(lr_scale))
            aug = sl.inject_update_ctx(params, opt.slots(opt_state), hyp)

            def loss(aug):
                y = sl.apply(aug, batch["x"], engine="pallas", act="sigmoid")
                return jnp.mean(jnp.square(y - batch["t"]))

            l, grads = jax.value_and_grad(loss, allow_int=True)(aug)
            new_params, new_opt = opt.merge(grads, opt_state, params, step,
                                            lr_scale=lr_scale)
            return new_params, new_opt, {
                "loss": l,
                "nonfinite": steps_mod.collect_junction_health(grads)}
    else:
        def train_step(params, opt_state, batch, step, lr_scale=None):
            def loss(params):
                y = sl.apply(params, batch["x"], engine="jnp", act="sigmoid")
                return jnp.mean(jnp.square(y - batch["t"]))

            l, grads = jax.value_and_grad(loss, allow_int=True)(params)
            new_params, new_opt = opt.update(grads, opt_state, params, step)
            if lr_scale is not None:
                new_params = steps_mod.scale_params_delta(params, new_params,
                                                          lr_scale)
            return new_params, new_opt, {
                "loss": l,
                "nonfinite": steps_mod.count_nonfinite_grads(grads)}

    return opt, jax.jit(train_step)


def _w_true():
    return np.asarray(jax.random.normal(jax.random.PRNGKey(7),
                                        (N_IN, N_OUT))) * 0.1


@pytest.mark.parametrize("engine", ["jnp", "pallas"])
def test_lr_scale_matches_true_lr(engine):
    """Backed-off lr via the lr_scale operand == actually running at the
    scaled lr: exact on two-pass (delta interpolation), kernel round-off
    on fused (hyp-table fold)."""
    params = _junction()
    batch = jax.tree.map(jnp.asarray, next(PoisonPipeline(_w_true())))
    opt, step_scaled = _make_regression_step(engine, lr=0.2)
    _, step_half = _make_regression_step(engine, lr=0.1)
    st = opt.init(params)
    p1, _, _ = step_scaled(params, st, batch, jnp.asarray(0),
                           jnp.float32(0.5))
    p2, _, _ = step_half(params, st, batch, jnp.asarray(0))
    for k in ("w", "b"):
        np.testing.assert_allclose(np.asarray(p1[k]), np.asarray(p2[k]),
                                   rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("engine", ["jnp", "pallas"])
def test_guardian_rollback_recovers_poisoned_run(engine, tmp_path):
    """Acceptance e2e: a poisoned batch trips the guardian (finite loss,
    non-finite update — the health-flag sentinel, not the loss one),
    training rolls back to the last healthy checkpoint, the offending
    batch is skipped, lr is backed off, and the run finishes with finite
    params and a loss close to the clean run's.  Without the guardian the
    same stream ends with non-finite parameters."""
    w_true = _w_true()
    params = _junction()
    opt, train_step = _make_regression_step(engine)
    quiet = lambda s: None
    total, poison_at = 30, 12

    # clean reference
    clean = run(TrainLoopConfig(total, str(tmp_path / "clean"),
                                ckpt_every=5, log_every=5),
                train_step, params, opt.init(params),
                PoisonPipeline(w_true), log=quiet)
    clean_loss = clean["history"][-1]["loss"]

    # guarded run over the poisoned stream (+ keep_last_k retention and
    # full-checksum saves riding the same loop)
    g = GuardianConfig(health_window=5, lr_backoff=0.5, max_retries=3,
                       min_history=4)
    res = run(TrainLoopConfig(total, str(tmp_path / "guard"), ckpt_every=5,
                              log_every=5, guardian=g, keep_last_k=3,
                              full_checksum=True),
              train_step, params, opt.init(params),
              PoisonPipeline(w_true, frozenset([poison_at])), log=quiet)
    assert res["step"] == total
    info = res["guardian"]
    assert len(info["trips"]) == 1
    trip = info["trips"][0]
    assert trip["data_step"] == poison_at
    assert "health" in trip["reason"] or "non-finite update" in trip["reason"]
    assert info["lr_scale"] == 0.5
    assert info["skipped_data_steps"] == [poison_at]
    for leaf in jax.tree.leaves(res["params"]):
        if jnp.issubdtype(leaf.dtype, jnp.inexact):
            assert bool(jnp.all(jnp.isfinite(leaf)))
    final_loss = res["history"][-1]["loss"]
    assert np.isfinite(final_loss)
    assert abs(final_loss - clean_loss) < 0.05, (final_loss, clean_loss)
    # retention honoured the healthy floor
    steps_left = ckpt_mod.complete_steps(tmp_path / "guard")
    assert ckpt_mod.latest_healthy_step(tmp_path / "guard") in steps_left

    # no guardian: the poisoned update is adopted and params go non-finite
    bare = run(TrainLoopConfig(total, str(tmp_path / "bare"),
                               ckpt_every=50, log_every=50),
               train_step, params, opt.init(params),
               PoisonPipeline(w_true, frozenset([poison_at])), log=quiet)
    assert not all(bool(jnp.all(jnp.isfinite(l)))
                   for l in jax.tree.leaves(bare["params"])
                   if jnp.issubdtype(l.dtype, jnp.inexact))


def test_guardian_exhausts_retries(tmp_path):
    """An unrecoverable stream (every step poisoned) raises
    GuardianTripped with the full trip history after max_retries."""
    w_true = _w_true()
    params = _junction()
    opt, train_step = _make_regression_step("jnp")
    g = GuardianConfig(max_retries=2, health_window=2)
    with pytest.raises(GuardianTripped) as ei:
        run(TrainLoopConfig(20, str(tmp_path), ckpt_every=5, log_every=5,
                            guardian=g),
            train_step, params, opt.init(params),
            PoisonPipeline(w_true, frozenset(range(2, 20)),
                           poison_value=np.nan), log=lambda s: None)
    assert len(ei.value.trips) == 3        # max_retries + the final straw


# -------------------------------------------------- scheduler quarantine
@pytest.mark.parametrize("engine", ["jnp", "pallas"])
def test_quarantine_leaves_survivors_bitwise_identical(engine, tmp_path):
    """Acceptance: a cohort with a diverging (lr=inf) member, quarantined
    mid-round, produces BITWISE identical survivor parameters to a cohort
    that never contained it — and still names a finite winner."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((256, N_IN)).astype(np.float32)
    t = np.eye(N_OUT, dtype=np.float32)[rng.integers(0, N_OUT, 256)]
    xe = rng.standard_normal((64, N_IN)).astype(np.float32)
    te = np.eye(N_OUT, dtype=np.float32)[rng.integers(0, N_OUT, 64)]

    def spec(lr, i):
        return CandidateSpec(lr=lr, momentum=0.0, density=0.5,
                             layers=(N_IN, N_OUT), block=32, init_seed=i)

    good = [spec(0.05, 0), spec(0.1, 1)]
    bad = spec(float("inf"), 2)
    cfg = SweepConfig(rounds=2, steps_per_round=4, batch_size=32,
                      eval_samples=64, keep_fraction=1.0, engine=engine,
                      fused=(engine == "pallas"))

    r_with = run_sweep(good + [bad], x, t, xe, te, cfg)
    r_without = run_sweep(good, x, t, xe, te, cfg)

    qrec = r_with.ledger.members[2]
    assert qrec.quarantined_at is not None
    assert qrec.pruned_at == qrec.quarantined_at["round"]
    assert r_with.ledger.meta["quarantined"] == 1
    for m in r_with.ledger.members[:2]:
        assert m.quarantined_at is None and m.pruned_at is None

    # survivors' parameter trajectories: bitwise equal
    for e in range(2):
        with_l = pop.member_slice(r_with.states[0].params, e)
        wo_l = pop.member_slice(r_without.states[0].params, e)
        for lw, lo in zip(with_l, wo_l):
            for k in ("w", "b"):
                assert np.asarray(lw[k]).tobytes() == \
                    np.asarray(lo[k]).tobytes(), (e, k)

    w1, w2 = r_with.ledger.winner(), r_without.ledger.winner()
    assert w1 is not None and w1.member == w2.member
    assert np.isfinite(w1.eval_losses[-1])


# ------------------------------------------------------------ serve guard
def _toy_model():
    from repro.configs import registry
    from repro.models import model as M
    cfg = registry.get("stablelm-3b").reduced()
    return cfg, M.init(cfg, jax.random.PRNGKey(0))


def test_serve_guard_terminates_nonfinite_slot():
    """Non-finite logits in one slot: that slot is EOS-filled from the
    poisoned tick on and counted; every other slot's output is untouched
    (greedy decode, bit-identical)."""
    from repro.serve.engine import Engine, ServeConfig
    cfg, params = _toy_model()
    eos = 5
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=6, temperature=0.0,
                                          eos_token=eos))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, size=(3, 8)).astype(np.int32)
    clean = eng.generate(prompts)
    assert eng.nonfinite_terminated == 0

    orig, calls = eng._decode, {"n": 0}

    def poisoned(params, cache, tok, pos):
        logits, cache = orig(params, cache, tok, pos)
        calls["n"] += 1
        if calls["n"] >= 2:                 # poison slot 0 from tick 2 on
            logits = logits.at[0].set(jnp.nan)
        return logits, cache

    eng._decode = poisoned
    out = eng.generate(prompts)
    assert eng.nonfinite_terminated == 1
    # decode call #2 yields output column 2: slot 0 EOS-filled from there
    assert (out[0, 2:] == eos).all()
    np.testing.assert_array_equal(out[1:], clean[1:])


def test_serve_guard_without_eos_masks_slot():
    """eos_token < 0 (never stop early): the guard must still be able to
    terminate a poisoned slot — filled with token 0."""
    from repro.serve.engine import Engine, ServeConfig
    cfg, params = _toy_model()
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=5, temperature=0.0))
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab, size=(2, 8)).astype(np.int32)
    clean = eng.generate(prompts)

    orig = eng._decode

    def poisoned(params, cache, tok, pos):
        logits, cache = orig(params, cache, tok, pos)
        return logits.at[1].set(jnp.inf), cache

    eng._decode = poisoned
    out = eng.generate(prompts)
    assert eng.nonfinite_terminated == 1
    assert (out[1, 1:] == 0).all()
    np.testing.assert_array_equal(out[0], clean[0])
