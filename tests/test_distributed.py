"""Distributed correctness via subprocess (forced host devices).

These spawn fresh interpreters because device count locks at jax init.
Covers: pipeline parallelism (gpipe exactness + async convergence), sharded
train step == single-device train step, sequence-parallel whisper anchor.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(ndev: int, body: str) -> str:
    script = textwrap.dedent(f"""
        import os, sys
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
        sys.path.insert(0, {SRC!r})
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import compat_mesh
    """) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=dict(os.environ), timeout=600)
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    return r.stdout


def test_gpipe_forward_exact_and_async_converges():
    out = _run(4, """
        from repro.parallel import pipeline as PP
        mesh = compat_mesh((4,), ("stage",), devices=jax.devices())
        D = 16
        def stage_fn(p, x): return jnp.tanh(x @ p["w"] + p["b"])
        k = jax.random.PRNGKey(0)
        params = {"w": jax.random.normal(k, (4, D, D)) * 0.5,
                  "b": jnp.zeros((4, D))}
        xs = jax.random.normal(k, (8, 4, D))
        ys = jax.random.normal(jax.random.PRNGKey(1), (8, 4, D)) * 0.1
        outs = PP.gpipe_forward(stage_fn, params, xs, mesh)
        def seq(x):
            for s in range(4):
                x = stage_fn({"w": params["w"][s], "b": params["b"][s]}, x)
            return x
        assert jnp.allclose(outs, jax.vmap(seq)(xs), atol=1e-5)
        def lg(y, yt): return 2*(y-yt)/y.size, jnp.mean((y-yt)**2)
        p = params
        first = last = None
        for ep in range(25):
            p, losses = PP.async_pipeline_epoch(stage_fn, lg, p, xs, ys, mesh, 0.05)
            warm = losses[losses > 0]
            if ep == 0: first = float(warm.mean())
            last = float(warm.mean())
        assert last < 0.7 * first, (first, last)
        print("PIPE_OK")
    """)
    assert "PIPE_OK" in out


def test_sharded_train_matches_single_device():
    out = _run(8, """
        from repro.configs import registry
        from repro.models import model as M
        from repro.optim import adam, constant_schedule
        from repro.parallel import sharding as sh, hints
        from repro.train.steps import make_train_step
        from repro.launch.mesh import make_local_mesh
        from repro.launch.specs import concrete_batch

        cfg = registry.get("deepseek-7b").reduced()
        params = M.init(cfg, jax.random.PRNGKey(0))
        opt = adam(constant_schedule(1e-3), grad_clip=None)
        st = opt.init(params)
        batch = concrete_batch(cfg, 4, 64, jax.random.PRNGKey(3))
        fn = make_train_step(cfg, opt, jit=False)  # shardings jit below

        # single device reference
        p1, s1, m1 = jax.jit(fn)(params, st, batch, jnp.asarray(0))

        # 2x4 mesh
        mesh = make_local_mesh(2, 4)
        pspecs = sh.param_specs(cfg, params, mesh)
        psh = sh.to_shardings(pspecs, mesh)
        params_d = jax.tree.map(jax.device_put, params, psh)
        st_d = opt.init(params_d)
        with mesh, hints.use_mesh_hints(mesh):
            p2, s2, m2 = jax.jit(fn)(params_d, st_d, batch, jnp.asarray(0))
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3, \
            (float(m1["loss"]), float(m2["loss"]))
        # parameters agree after one update
        l1 = jax.tree.leaves(p1); l2 = jax.tree.leaves(p2)
        worst = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                    for a, b in zip(l1, l2)
                    if jnp.issubdtype(a.dtype, jnp.inexact))
        assert worst < 5e-3, worst
        print("SHARD_OK", worst)
    """)
    assert "SHARD_OK" in out


def test_grad_compression_cross_pod():
    out = _run(4, """
        from repro.train import grad_compress as GC
        g = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 0.01
        err = jnp.zeros_like(g)
        restored, err2 = GC.compress_decompress(g, err)
        rel = float(jnp.linalg.norm(restored - g) / jnp.linalg.norm(g))
        assert rel < 0.02, rel
        # error feedback: two-step accumulated error stays bounded
        r2, err3 = GC.compress_decompress(g, err2)
        assert float(jnp.linalg.norm(err3)) <= float(jnp.linalg.norm(err2)) * 1.5 + 1e-6
        print("GC_OK")
    """)
    assert "GC_OK" in out
