"""Model-layer correctness: attention variants, SSM scans, MoE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import ssm as S
from repro.models import moe as MoE


def naive_attention(q, k, v, causal=True, window=0):
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    kf = jnp.repeat(k, rep, axis=2)
    vf = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kf) / np.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask[None, None], s, -1e30)
    if window:
        qp = jnp.arange(Sq)[:, None] + (Sk - Sq)
        kp = jnp.arange(Sk)[None, :]
        s = jnp.where((qp - kp < window)[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf)


@pytest.mark.parametrize("H,Hkv,window", [(4, 4, 0), (8, 2, 0), (4, 2, 7)])
def test_chunked_attention_vs_naive(H, Hkv, window):
    key = jax.random.PRNGKey(0)
    B, Sq, D = 2, 33, 16
    q = jax.random.normal(key, (B, Sq, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Sq, Hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Sq, Hkv, D))
    got = A.chunked_attention(q, k, v, causal=True, window=window, chunk=8)
    want = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_matches_full_recompute():
    key = jax.random.PRNGKey(0)
    B, S, H, Hkv, D = 2, 16, 4, 2, 8
    k = jax.random.normal(key, (B, S, Hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, D))
    q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, H, D))
    pos = 9   # cache positions 0..9 valid
    got = A.decode_attention(q, k, v, jnp.asarray(pos))
    want = naive_attention(q, k[:, :pos + 1], v[:, :pos + 1], causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_gqa_prefill_then_decode_consistent():
    """Decoding token t with the prefill cache == prefilling t+1 tokens."""
    cfg = registry.get("llava-next-mistral-7b").reduced()
    key = jax.random.PRNGKey(0)
    p = A.attn_init(key, cfg)
    B, S = 2, 12
    x = jax.random.normal(key, (B, S + 1, cfg.d_model), jnp.float32)
    full, _ = A.gqa_forward(p, x, cfg, positions=jnp.arange(S + 1))
    # prefill on first S tokens
    _, (k, v) = A.gqa_forward(p, x[:, :S], cfg, positions=jnp.arange(S))
    W = min(cfg.window, S + 8) if cfg.attn_kind == "sliding" else S + 8
    cache = {"k": jnp.zeros((B, W, cfg.kv_heads, cfg.head_dim)),
             "v": jnp.zeros((B, W, cfg.kv_heads, cfg.head_dim))}
    if cfg.attn_kind == "sliding":
        sl = jnp.arange(S) % W
        cache = {"k": cache["k"].at[:, sl].set(k), "v": cache["v"].at[:, sl].set(v)}
    else:
        cache = {"k": cache["k"].at[:, :S].set(k), "v": cache["v"].at[:, :S].set(v)}
    out, _ = A.gqa_decode(p, x[:, S:S + 1], cfg, cache, jnp.asarray(S))
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, S]),
                               rtol=2e-3, atol=2e-3)


def test_mla_decode_absorbed_matches_expanded():
    cfg = registry.get("deepseek-v2-lite-16b").reduced()
    key = jax.random.PRNGKey(0)
    p = A.attn_init(key, cfg)
    B, S = 2, 9
    x = jax.random.normal(key, (B, S + 1, cfg.d_model), jnp.float32)
    full, (latent, k_rope) = A.mla_forward(p, x, cfg, positions=jnp.arange(S + 1))
    m = cfg.mla
    cache = {"latent": jnp.zeros((B, S + 4, m.kv_lora_rank)),
             "k_rope": jnp.zeros((B, S + 4, m.qk_rope_head_dim))}
    cache["latent"] = cache["latent"].at[:, :S].set(latent[:, :S])
    cache["k_rope"] = cache["k_rope"].at[:, :S].set(k_rope[:, :S])
    out, _ = A.mla_decode(p, x[:, S:S + 1], cfg, cache, jnp.asarray(S))
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, S]),
                               rtol=3e-3, atol=3e-3)


def _naive_mamba1(p, x, cfg):
    """Step-by-step recurrence oracle."""
    import repro.core.sparse_linear as sl
    B, S, _ = x.shape
    di, N, R = cfg.d_inner_, cfg.ssm_state, cfg.dt_rank_
    h = jnp.zeros((B, di, N))
    conv = jnp.zeros((B, cfg.conv_width - 1, di))
    ys = []
    for t in range(S):
        y, cache = S_mod_apply_one(p, x[:, t:t+1], cfg, {"conv": conv, "ssm": h})
        conv, h = cache["conv"], cache["ssm"]
        ys.append(y)
    return jnp.concatenate(ys, axis=1)


def S_mod_apply_one(p, xt, cfg, cache):
    return S.mamba1_apply(p, xt, cfg, cache=cache, decode=True)


def test_mamba1_chunked_scan_matches_stepwise():
    cfg = registry.get("falcon-mamba-7b").reduced()
    key = jax.random.PRNGKey(0)
    p = S.mamba1_init(key, cfg)
    B, Sq = 2, 32
    x = jax.random.normal(key, (B, Sq, cfg.d_model), jnp.float32)
    y_chunked, cache = S.mamba1_apply(
        p, x, cfg, cache={"conv": jnp.zeros((B, cfg.conv_width - 1, cfg.d_inner_)),
                          "ssm": jnp.zeros((B, cfg.d_inner_, cfg.ssm_state))})
    y_naive = _naive_mamba1(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_naive),
                               rtol=2e-3, atol=2e-3)


def test_mamba2_ssd_matches_stepwise():
    cfg = registry.get("zamba2-2.7b").reduced()
    key = jax.random.PRNGKey(0)
    p = S.mamba2_init(key, cfg)
    B, Sq = 2, 32
    x = jax.random.normal(key, (B, Sq, cfg.d_model), jnp.float32)
    zero = {"conv": jnp.zeros((B, cfg.conv_width - 1, cfg.d_inner_ + 2 * cfg.ssm_state)),
            "ssm": jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state))}
    y_ssd, _ = S.mamba2_apply(p, x, cfg, cache=zero)
    conv, h = zero["conv"], zero["ssm"]
    ys = []
    for t in range(Sq):
        y, c2 = S.mamba2_apply(p, x[:, t:t + 1], cfg,
                               cache={"conv": conv, "ssm": h}, decode=True)
        conv, h = c2["conv"], c2["ssm"]
        ys.append(y)
    y_naive = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_ssd), np.asarray(y_naive),
                               rtol=3e-3, atol=3e-3)


def test_moe_routing_properties():
    cfg = registry.get("qwen3-moe-30b-a3b").reduced()
    key = jax.random.PRNGKey(0)
    p = MoE.moe_init(key, cfg)
    x = jax.random.normal(key, (2, 64, cfg.d_model), jnp.float32)
    y, aux = MoE.moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert jnp.all(jnp.isfinite(y))
    # aux loss near its uniform-routing value (E * sum f*p ~ 1) * weight
    assert 0.0 < float(aux) < 10 * cfg.moe.aux_loss_weight


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= 1 and near-uniform routing, most tokens land."""
    cfg = registry.get("deepseek-v2-lite-16b").reduced()
    p = MoE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    y, _ = MoE.moe_apply(p, x, cfg)
    # routed output should be nonzero for the overwhelming majority of tokens
    nz = jnp.mean((jnp.abs(y).sum(-1) > 1e-6).astype(jnp.float32))
    assert float(nz) > 0.9
