"""Engine switch: the fused Pallas path as the model-level execution path.

Verifies the acceptance criteria of the edge-bundle engine PRs: the whole
model forward/backward runs through engine="pallas" (interpret mode on
CPU) and matches engine="jnp" to tolerance; "auto" resolves to pallas
exactly on TPU backends; serving decodes through the kernels; density()
no longer host-syncs or under-reports; MoE expert FFNs run through the
expert-batched kernels (ISSUE 2) with routing/capacity semantics
identical to the reference loop; plus regression tests for the serving
PRNG-reuse, cache-growth-heuristic and bench --only silent-no-op fixes,
serve edge cases (early-EOS slot masking stays shape-stable, seeded
temperature sampling is deterministic), and the bench --tag meta stamp.
"""
import dataclasses
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, MoEConfig
from repro.configs import registry
from repro.core import sparse_linear as sl
from repro.core.sparsity import SparsityConfig
from repro.models import model as M
from repro.models import moe as moe_mod


def _sparse_cfg(engine="auto", act="silu"):
    return ArchConfig(
        name="engine-test", family="dense", n_layers=2, d_model=128,
        n_heads=4, kv_heads=4, head_dim=32, d_ff=256, vocab=128,
        act=act, max_seq=64, attn_chunk=32, dtype="float32",
        sparsity=SparsityConfig(density=0.25, block=32, where="ffn"),
        engine=engine)


def _loss_and_grads(cfg, params, batch):
    def loss(p):
        l, _ = M.loss_fn(cfg, p, batch)
        return l
    return jax.value_and_grad(loss, allow_int=True)(params)


@pytest.mark.parametrize("act", ["silu", "gelu"])
def test_model_forward_backward_pallas_vs_jnp(act):
    """Full train-path loss + grads agree between engines (fused epilogue
    included: silu exercises the gated MLP, gelu the plain one)."""
    cfg = _sparse_cfg(engine="jnp", act=act)
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                          (2, 16), 0, cfg.vocab)}
    l_jnp, g_jnp = _loss_and_grads(cfg, params, batch)
    cfg_p = dataclasses.replace(cfg, engine="pallas")
    l_pal, g_pal = _loss_and_grads(cfg_p, params, batch)
    np.testing.assert_allclose(float(l_jnp), float(l_pal), rtol=1e-5)
    flat1 = jax.tree.leaves(g_jnp)
    flat2 = jax.tree.leaves(g_pal)
    for a, b in zip(flat1, flat2):
        if jnp.issubdtype(a.dtype, jnp.inexact):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-3)


def test_serve_decode_pallas_matches_jnp():
    """Prefill + a few decode steps through the kernel engine produce the
    same tokens as the jnp path (serve plumbing: ServeConfig.engine)."""
    from repro.serve.engine import Engine, ServeConfig

    cfg = _sparse_cfg(engine="jnp")
    params = M.init(cfg, jax.random.PRNGKey(0))
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab))
    tok_jnp = Engine(cfg, params, ServeConfig(max_new_tokens=4)).generate(prompts)
    tok_pal = Engine(cfg, params, ServeConfig(max_new_tokens=4,
                                              engine="pallas")).generate(prompts)
    assert np.array_equal(tok_jnp, tok_pal)


def test_auto_resolves_by_backend():
    want = "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert sl.resolve_engine("auto") == want
    assert sl.resolve_engine("pallas") == "pallas"
    assert sl.resolve_engine("jnp") == "jnp"
    with pytest.raises(ValueError):
        sl.resolve_engine("fpga")


# ------------------------------------------------------- MoE engine port
def _moe_cfg(engine="jnp", capacity_factor=1.25, top_k=2, d_expert=64,
             where="ffn"):
    return ArchConfig(
        name="moe-engine-test", family="moe", n_layers=1, d_model=128,
        n_heads=4, kv_heads=4, head_dim=32, d_ff=256, vocab=128,
        act="silu", max_seq=64, attn_chunk=32, dtype="float32",
        moe=MoEConfig(num_experts=4, top_k=top_k, d_expert=d_expert,
                      group_size=32, capacity_factor=capacity_factor),
        sparsity=SparsityConfig(density=0.5, block=32, where=where),
        engine=engine)


def _moe_loss_and_grads(cfg, params, x, co):
    def loss(p):
        y, aux = moe_mod.moe_apply(p, x, cfg)
        return jnp.sum(y * co) + aux
    return jax.value_and_grad(loss, allow_int=True)(params)


@pytest.mark.parametrize("top_k,capacity_factor", [
    (1, 1.25),
    (2, 1.25),
    (2, 0.5),    # over-capacity: tokens drop, residual-path semantics
])
def test_moe_pallas_vs_jnp_fwd_bwd(top_k, capacity_factor):
    """Expert FFNs through the expert-batched fused kernels match the
    reference gather+einsum loop — loss, input grads and per-expert
    weight grads — including capacity-drop routing and top-k > 1."""
    cfg = _moe_cfg("jnp", capacity_factor, top_k)
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    assert "idx_in" in params and "rev_in_ob" in params
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    co = jax.random.normal(jax.random.PRNGKey(2), x.shape)
    if capacity_factor < 1.0:   # confirm drops actually happen
        y, _ = moe_mod.moe_apply(params, x, cfg)
        nz = jnp.mean((jnp.abs(y).sum(-1) > 1e-6).astype(jnp.float32))
        assert float(nz) < 1.0, "no over-capacity drops — shape choice bad"
    l_jnp, g_jnp = _moe_loss_and_grads(cfg, params, x, co)
    cfg_p = dataclasses.replace(cfg, engine="pallas")
    l_pal, g_pal = _moe_loss_and_grads(cfg_p, params, x, co)
    np.testing.assert_allclose(float(l_jnp), float(l_pal), rtol=1e-5)
    for k in sorted(g_jnp):
        if jnp.issubdtype(g_jnp[k].dtype, jnp.inexact):
            np.testing.assert_allclose(np.asarray(g_jnp[k]),
                                       np.asarray(g_pal[k]),
                                       rtol=2e-3, atol=2e-3, err_msg=k)


def test_moe_pallas_nob_ne_kb():
    """d_expert chosen so the expert junction has nob != kb — the shape
    class where the seed's _expert_apply weight slicing (axis 1, the
    output-block axis) would have shape-errored or silently transposed."""
    cfg = _moe_cfg("jnp", d_expert=128)
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    nob, kb = params["wi"].shape[1], params["wi"].shape[2]
    assert nob != kb
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y_jnp, _ = moe_mod.moe_apply(params, x, cfg)
    y_pal, _ = moe_mod.moe_apply(params, x,
                                 dataclasses.replace(cfg, engine="pallas"))
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_pal),
                               rtol=2e-4, atol=2e-4)


def test_moe_dense_expert_fallback():
    """When _expert_sparse_ok is false (sparsity scoped to attn only) the
    experts are dense einsums and the engine switch is a no-op — both
    engines run the identical dense path."""
    cfg = _moe_cfg("jnp", where="attn")
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    assert "idx_in" not in params and params["wi"].ndim == 3
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y_jnp, aux_jnp = moe_mod.moe_apply(params, x, cfg)
    y_pal, aux_pal = moe_mod.moe_apply(params, x,
                                       dataclasses.replace(cfg, engine="pallas"))
    assert jnp.all(jnp.isfinite(y_jnp))
    np.testing.assert_array_equal(np.asarray(y_jnp), np.asarray(y_pal))
    assert float(aux_jnp) == float(aux_pal)


def test_moe_model_level_pallas_vs_jnp():
    """Whole moe-family train path (attn + routed experts through
    M.loss_fn) agrees between engines — exercises the stacked-layer scan
    over the int32 pattern/reverse-pattern param leaves."""
    cfg = _moe_cfg("jnp")
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                          (2, 16), 0, cfg.vocab)}
    l_jnp, g_jnp = _loss_and_grads(cfg, params, batch)
    cfg_p = dataclasses.replace(cfg, engine="pallas")
    l_pal, g_pal = _loss_and_grads(cfg_p, params, batch)
    np.testing.assert_allclose(float(l_jnp), float(l_pal), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g_jnp), jax.tree.leaves(g_pal)):
        if jnp.issubdtype(a.dtype, jnp.inexact):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-3)


# ------------------------------------------------- serving bugfix regressions
def test_generate_uses_fresh_subkey_per_sample():
    """PRNG hygiene: every sampling call gets a distinct subkey and the
    root PRNGKey(seed) is only ever split, never consumed (the seed
    sampled the first token with the root key and then split it again)."""
    from repro.serve.engine import Engine, ServeConfig

    cfg = _sparse_cfg(engine="jnp")
    params = M.init(cfg, jax.random.PRNGKey(0))
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab))
    scfg = ServeConfig(max_new_tokens=4, temperature=1.0, seed=3)
    eng = Engine(cfg, params, scfg)
    seen = []
    orig = eng._sample

    def spy(logits, key):
        seen.append(tuple(np.asarray(key).tolist()))
        return orig(logits, key)

    eng._sample = spy
    tok1 = eng.generate(prompts)
    assert len(seen) == scfg.max_new_tokens
    assert len(set(seen)) == len(seen), "a PRNG key was consumed twice"
    root = tuple(np.asarray(jax.random.PRNGKey(scfg.seed)).tolist())
    assert root not in set(seen), "root key consumed by sampling"
    # deterministic per seed: a second generate reproduces the tokens
    tok2 = eng.generate(prompts)
    np.testing.assert_array_equal(tok1, tok2)


@pytest.mark.parametrize("name", [
    "stablelm-3b", "deepseek-v2-lite-16b", "falcon-mamba-7b",
    "zamba2-2.7b", "whisper-base",
])
def test_cache_seq_axes_metadata(name):
    """cache_seq_axes mirrors make_cache's structure exactly; seq-axis
    leaves scale with the seq argument on exactly that axis and state
    leaves (conv/ssm, cross-attn KV) are seq-independent."""
    cfg = registry.get(name).reduced()
    c8 = M.make_cache(cfg, 1, 8)
    c16 = M.make_cache(cfg, 1, 16)
    axes = M.cache_seq_axes(cfg)
    assert jax.tree.structure(axes) == jax.tree.structure(c8)

    def check(ax, a, b):
        if ax < 0:
            assert a.shape == b.shape
        else:
            assert a.shape[ax] == 8 and b.shape[ax] == 16
            sa, sb = list(a.shape), list(b.shape)
            sa[ax] = sb[ax] = 0
            assert sa == sb
    jax.tree.map(check, axes, c8, c16)


def test_grow_cache_places_by_metadata():
    """Attention leaves land at position 0 of their declared seq axis
    (zeros beyond), state leaves are copied wholesale — no shape
    guessing."""
    from repro.serve.engine import Engine, ServeConfig

    cfg = _sparse_cfg(engine="jnp")
    params = M.init(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=4))
    src = jax.tree.map(lambda t: jnp.ones_like(t), M.make_cache(cfg, 2, 8))
    grown = eng._grow_cache(src, 2, 12, 8)

    def check_attn(ax, dst):
        assert ax >= 0 and dst.shape[ax] == 12
        d = np.moveaxis(np.asarray(dst), ax, 0)
        np.testing.assert_array_equal(d[:8], 1.0)
        np.testing.assert_array_equal(d[8:], 0.0)
    jax.tree.map(check_attn, M.cache_seq_axes(cfg), grown)

    # ssm family: conv/ssm are same-shape state leaves, copied exactly
    cfg2 = registry.get("falcon-mamba-7b").reduced()
    eng2 = Engine(cfg2, {})   # jit steps are built lazily; only cfg is used
    src2 = jax.tree.map(lambda t: jnp.full_like(t, 2.0),
                        M.make_cache(cfg2, 2, 8))
    grown2 = eng2._grow_cache(src2, 2, 12, 8)

    def check_state(ax, dst, s):
        assert ax < 0 and dst.shape == s.shape
        np.testing.assert_array_equal(np.asarray(dst), np.asarray(s))
    jax.tree.map(check_state, M.cache_seq_axes(cfg2), grown2, src2)


def test_eos_slot_masking_keeps_decode_shape_stable():
    """Early EOS must not change ANY shape: a finished slot keeps
    decoding into scratch and is masked to eos, the step-locked loop
    runs all max_new_tokens ticks, and unfinished slots are unaffected
    (the fixed-shape serving contract the population scheduler borrows
    its slot masking from)."""
    from repro.serve.engine import Engine, ServeConfig

    cfg = _sparse_cfg(engine="jnp")
    params = M.init(cfg, jax.random.PRNGKey(0))
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(5), (3, 8), 0, cfg.vocab))
    n_new = 6
    free = Engine(cfg, params, ServeConfig(max_new_tokens=n_new)).generate(
        prompts)
    # force an early stop: sequence 0's second token becomes the EOS
    eos = int(free[0, 1])
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=n_new,
                                          eos_token=eos))
    calls = []
    orig = eng._decode

    def spy(params, cache, tok, pos):
        calls.append(tuple(tok.shape))
        return orig(params, cache, tok, pos)

    eng._decode = spy
    tok = eng.generate(prompts)
    assert tok.shape == (3, n_new)                  # output shape stable
    assert len(calls) == n_new - 1                  # no early loop exit
    assert all(s == (3, 1) for s in calls)          # per-tick shape stable
    for b in range(3):
        row = tok[b]
        hits = np.flatnonzero(row == eos)
        if hits.size:                               # after first eos: all eos
            np.testing.assert_array_equal(row[hits[0]:], eos)
        # up to (and including) each row's first eos, greedy decode is
        # unchanged by the masking
        stop = hits[0] + 1 if hits.size else n_new
        np.testing.assert_array_equal(row[:stop], free[b, :stop])


def test_temperature_sampling_deterministic_under_seed():
    """temperature > 0 sampling is a pure function of the seed: same
    seed -> identical tokens across fresh Engine instances, different
    seed -> a different draw."""
    from repro.serve.engine import Engine, ServeConfig

    cfg = _sparse_cfg(engine="jnp")
    params = M.init(cfg, jax.random.PRNGKey(0))
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(6), (2, 8), 0, cfg.vocab))

    def gen(seed):
        scfg = ServeConfig(max_new_tokens=8, temperature=1.0, seed=seed)
        return Engine(cfg, params, scfg).generate(prompts)

    np.testing.assert_array_equal(gen(3), gen(3))
    assert not np.array_equal(gen(3), gen(4))


def test_bench_only_unknown_name_exits_nonzero(monkeypatch, tmp_path):
    """benchmarks/run.py --only with a typo'd name must exit nonzero and
    write no artifact (it used to print the CSV header, run nothing,
    exit 0 and write an empty --json artifact)."""
    monkeypatch.syspath_prepend(str(Path(__file__).resolve().parents[1]))
    import benchmarks.run as br

    art = tmp_path / "BENCH_typo.json"
    monkeypatch.setattr(sys, "argv",
                        ["run", "--only", "engin", "--json", str(art)])
    with pytest.raises(SystemExit) as ei:
        br.main()
    assert ei.value.code not in (0, None)
    assert not art.exists()


def test_bench_tag_threads_into_artifact_meta(monkeypatch, tmp_path):
    """--tag must land in the artifact's meta and round-trip through
    load_artifact; without --tag the filename-derived tag is kept (the
    stamp contract the sweep ledger shares)."""
    monkeypatch.syspath_prepend(str(Path(__file__).resolve().parents[1]))
    import benchmarks.engine_benches as eb
    import benchmarks.run as br

    monkeypatch.setattr(
        eb, "bench",
        lambda fast=True: [{"name": "engine.stub", "us_per_call": 1.0,
                            "derived": "stub"}])
    art = tmp_path / "BENCH_fromfile.json"
    monkeypatch.setattr(sys, "argv", ["run", "--only", "engine",
                                      "--json", str(art), "--tag", "pr5"])
    br.main()
    meta, results = br.load_artifact(str(art))
    assert meta["tag"] == "pr5"
    assert results == {"engine.stub": 1.0}
    # no --tag: derived from the BENCH_<tag>.json filename
    monkeypatch.setattr(sys, "argv", ["run", "--only", "engine",
                                      "--json", str(art)])
    br.main()
    meta, _ = br.load_artifact(str(art))
    assert meta["tag"] == "fromfile"


def test_density_static_and_exact():
    """density() must not depend on idx *values* (no host sync, exact even
    when the top input block is unused by the pattern)."""
    sp = SparsityConfig(density=0.25, block=32)
    p = sl.init_sparse(jax.random.PRNGKey(0), 256, 128, sp)
    nib, kb = p["rev_ob"].shape[0], p["w"].shape[1]
    assert sl.density(p) == kb / nib
    # drop every reference to the last input block: density unchanged
    # (the junction still spans 256 inputs, some now unconnected)
    p2 = dict(p, idx=jnp.zeros_like(p["idx"]))
    assert sl.density(p2) == sl.density(p)
    # and it works under trace (would raise ConcretizationTypeError if the
    # implementation synced idx values to host)
    @jax.jit
    def f(p):
        return jnp.float32(sl.density(p))
    assert float(f(p)) == pytest.approx(kb / nib)
