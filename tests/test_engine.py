"""Engine switch: the fused Pallas path as the model-level execution path.

Verifies the acceptance criteria of the edge-bundle engine PR: the whole
model forward/backward runs through engine="pallas" (interpret mode on
CPU) and matches engine="jnp" to tolerance; "auto" resolves to pallas
exactly on TPU backends; serving decodes through the kernels; density()
no longer host-syncs or under-reports.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import sparse_linear as sl
from repro.core.sparsity import SparsityConfig
from repro.models import model as M


def _sparse_cfg(engine="auto", act="silu"):
    return ArchConfig(
        name="engine-test", family="dense", n_layers=2, d_model=128,
        n_heads=4, kv_heads=4, head_dim=32, d_ff=256, vocab=128,
        act=act, max_seq=64, attn_chunk=32, dtype="float32",
        sparsity=SparsityConfig(density=0.25, block=32, where="ffn"),
        engine=engine)


def _loss_and_grads(cfg, params, batch):
    def loss(p):
        l, _ = M.loss_fn(cfg, p, batch)
        return l
    return jax.value_and_grad(loss, allow_int=True)(params)


@pytest.mark.parametrize("act", ["silu", "gelu"])
def test_model_forward_backward_pallas_vs_jnp(act):
    """Full train-path loss + grads agree between engines (fused epilogue
    included: silu exercises the gated MLP, gelu the plain one)."""
    cfg = _sparse_cfg(engine="jnp", act=act)
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                          (2, 16), 0, cfg.vocab)}
    l_jnp, g_jnp = _loss_and_grads(cfg, params, batch)
    cfg_p = dataclasses.replace(cfg, engine="pallas")
    l_pal, g_pal = _loss_and_grads(cfg_p, params, batch)
    np.testing.assert_allclose(float(l_jnp), float(l_pal), rtol=1e-5)
    flat1 = jax.tree.leaves(g_jnp)
    flat2 = jax.tree.leaves(g_pal)
    for a, b in zip(flat1, flat2):
        if jnp.issubdtype(a.dtype, jnp.inexact):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-3)


def test_serve_decode_pallas_matches_jnp():
    """Prefill + a few decode steps through the kernel engine produce the
    same tokens as the jnp path (serve plumbing: ServeConfig.engine)."""
    from repro.serve.engine import Engine, ServeConfig

    cfg = _sparse_cfg(engine="jnp")
    params = M.init(cfg, jax.random.PRNGKey(0))
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab))
    tok_jnp = Engine(cfg, params, ServeConfig(max_new_tokens=4)).generate(prompts)
    tok_pal = Engine(cfg, params, ServeConfig(max_new_tokens=4,
                                              engine="pallas")).generate(prompts)
    assert np.array_equal(tok_jnp, tok_pal)


def test_auto_resolves_by_backend():
    want = "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert sl.resolve_engine("auto") == want
    assert sl.resolve_engine("pallas") == "pallas"
    assert sl.resolve_engine("jnp") == "jnp"
    with pytest.raises(ValueError):
        sl.resolve_engine("fpga")


def test_density_static_and_exact():
    """density() must not depend on idx *values* (no host sync, exact even
    when the top input block is unused by the pattern)."""
    sp = SparsityConfig(density=0.25, block=32)
    p = sl.init_sparse(jax.random.PRNGKey(0), 256, 128, sp)
    nib, kb = p["rev_ob"].shape[0], p["w"].shape[1]
    assert sl.density(p) == kb / nib
    # drop every reference to the last input block: density unchanged
    # (the junction still spans 256 inputs, some now unconnected)
    p2 = dict(p, idx=jnp.zeros_like(p["idx"]))
    assert sl.density(p2) == sl.density(p)
    # and it works under trace (would raise ConcretizationTypeError if the
    # implementation synced idx values to host)
    @jax.jit
    def f(p):
        return jnp.float32(sl.density(p))
    assert float(f(p)) == pytest.approx(kb / nib)
