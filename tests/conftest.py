import os
import sys
from pathlib import Path

# tests run on the single real CPU device (dryrun.py alone forces 512)
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")
