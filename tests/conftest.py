import os
import sys
from pathlib import Path

# tests run on the single real CPU device (dryrun.py alone forces 512)
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")


def optional_hypothesis():
    """(given, settings, st) — real hypothesis when installed, otherwise
    stubs that skip only the property tests (plain tests in the same
    module still run)."""
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        import pytest

        def given(*a, **k):
            return pytest.mark.skip(reason="hypothesis not installed")

        def settings(*a, **k):
            return lambda f: f

        class _StrategyStub:
            def __getattr__(self, name):
                return lambda *a, **k: None

        return given, settings, _StrategyStub()
