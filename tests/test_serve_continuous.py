"""Continuous-batching serve engine (ISSUE 9): paged flash-decode kernel
vs reference, continuous-vs-static greedy parity, the compile-once
(fixed-shape) contract, page-pool accounting / memory-bounding, arrival
traces with EOS early-free, and the stale nonfinite_terminated
regression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.sparsity import SparsityConfig
from repro.models import model as M
from repro.serve.engine import (ContinuousEngine, Engine, Request,
                                ServeConfig)
from repro.serve.paged import PagePool


def _cfg(engine="jnp", **kw):
    base = dict(
        name="cont-test", family="dense", n_layers=2, d_model=128,
        n_heads=4, kv_heads=2, head_dim=32, d_ff=256, vocab=128,
        act="silu", max_seq=64, attn_chunk=32, dtype="float32",
        sparsity=SparsityConfig(density=0.25, block=32, where="ffn"),
        engine=engine)
    base.update(kw)
    return ArchConfig(**base)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab, size=(5, 12)).astype(np.int32)
    return cfg, params, prompts


# ------------------------------------------------------ flash_decode kernel
@pytest.mark.parametrize("lens", [
    [0, 1, 7, 8, 23, 24],       # ragged incl. zero-length and page edges
    [5, 16, 24],    # full-capacity slot (maxp * ps tokens exactly)
])
def test_flash_decode_matches_reference(lens):
    """Pallas paged-decode kernel vs the gather+masked-softmax reference
    on ragged per-slot lengths; a zero-length slot returns exact zeros."""
    from repro.kernels.flash_attention import flash_decode, paged_decode_ref
    B, Hkv, rep, D, ps = len(lens), 2, 2, 32, 8
    maxp = 3
    P = 1 + B * maxp
    ks = jax.random.split(jax.random.PRNGKey(len(lens)), 3)
    q = jax.random.normal(ks[0], (B, Hkv, rep, D), jnp.float32)
    k_pool = jax.random.normal(ks[1], (P, ps, Hkv, D), jnp.float32)
    v_pool = jax.random.normal(ks[2], (P, ps, Hkv, D), jnp.float32)
    pt = np.zeros((B, maxp), np.int32)
    nxt = 1
    for b, n in enumerate(lens):
        for j in range(-(-max(n, 1) // ps)):
            pt[b, j] = nxt
            nxt += 1
    pt = jnp.asarray(pt)
    sl = jnp.asarray(lens, jnp.int32)
    got = flash_decode(q, k_pool, v_pool, pt, sl, interpret=True)
    want = paged_decode_ref(q, k_pool, v_pool, pt, sl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    assert not np.any(np.asarray(got)[np.asarray(sl) == 0])


# -------------------------------------------------------- engine semantics
@pytest.mark.parametrize("engine", ["jnp", "pallas"])
def test_continuous_matches_static_greedy(setup, engine):
    """Token-identical greedy outputs per request vs the static engine —
    uniform prompt lengths (the static engine attends prompt padding, so
    ragged prompts aren't comparable), more requests than slots, through
    both the reference and the flash_decode paged attention."""
    cfg, params, prompts = setup
    NEW = 8
    static = Engine(cfg, params,
                    ServeConfig(max_new_tokens=NEW, eos_token=-1)
                    ).generate(prompts)
    ce = ContinuousEngine(
        dataclasses.replace(cfg, engine=engine), params,
        ServeConfig(max_new_tokens=NEW, eos_token=-1, slots=2, page_size=8,
                    prefill_chunk=8, max_seq=32))
    outs = ce.serve([Request(rid=i, prompt=prompts[i], max_new_tokens=NEW)
                     for i in range(len(prompts))])
    for i in range(len(prompts)):
        np.testing.assert_array_equal(outs[i], static[i])


def test_decode_compiles_once(setup):
    """Slot refill and page-table swap change integers, never shapes: the
    decode tick and prefill chunk each trace exactly once per engine even
    across multiple serve() calls with different traces."""
    cfg, params, prompts = setup
    ce = ContinuousEngine(cfg, params, ServeConfig(
        max_new_tokens=6, eos_token=-1, slots=2, page_size=8,
        prefill_chunk=8, max_seq=32))
    ce.serve([Request(rid=i, prompt=prompts[i], max_new_tokens=6)
              for i in range(4)])
    assert (ce.decode_traces, ce.prefill_traces) == (1, 1)
    # a second trace with different prompt lengths / arrivals / counts
    ce.serve([Request(rid=i, prompt=prompts[i][: 5 + i],
                      max_new_tokens=2 + i, arrival=i) for i in range(3)])
    assert (ce.decode_traces, ce.prefill_traces) == (1, 1)


def test_mixed_arrival_trace_completes(setup):
    """Staggered arrivals with mixed prompt/output lengths: every request
    completes with exactly its asked-for token count, and per-request
    latency stats cover every rid."""
    cfg, params, prompts = setup
    reqs = [Request(rid=i, prompt=prompts[i][: 4 + 2 * i],
                    max_new_tokens=3 + i, arrival=2 * i) for i in range(5)]
    ce = ContinuousEngine(cfg, params, ServeConfig(
        max_new_tokens=8, eos_token=-1, slots=2, page_size=8,
        prefill_chunk=8, max_seq=32))
    outs = ce.serve(reqs)
    assert set(outs) == set(range(5))
    assert [len(outs[i]) for i in range(5)] == [3 + i for i in range(5)]
    st = ce.stats
    assert set(st["latency"]) == set(range(5))
    assert all(st["latency"][r.rid]["admitted"] >= r.arrival for r in reqs)


def test_eos_frees_slot_early(setup):
    """A request hitting EOS ends there (eos is the last token, emitted
    once) and its slot is refilled — the run takes fewer decode ticks
    than the no-EOS run of the same trace."""
    cfg, params, prompts = setup
    NEW = 8
    base = Engine(cfg, params, ServeConfig(max_new_tokens=NEW, eos_token=-1)
                  ).generate(prompts)
    # pick a token greedy decode actually emits mid-stream
    eos = int(base[2][0])
    mk = lambda: [Request(rid=i, prompt=prompts[i], max_new_tokens=NEW)
                  for i in range(len(prompts))]
    scfg = dict(max_new_tokens=NEW, slots=2, page_size=8, prefill_chunk=8,
                max_seq=32)
    ce_free = ContinuousEngine(cfg, params,
                               ServeConfig(eos_token=eos, **scfg))
    outs = ce_free.serve(mk())
    ticks_eos = ce_free.stats["decode_ticks"]
    assert any(len(outs[i]) < NEW for i in outs)
    for o in outs.values():
        if eos in o:
            assert o[-1] == eos and eos not in o[:-1]
    ce_full = ContinuousEngine(cfg, params,
                               ServeConfig(eos_token=-1, **scfg))
    ce_full.serve(mk())
    assert ticks_eos < ce_full.stats["decode_ticks"]


# ------------------------------------------------------ page-pool accounting
def test_page_pool_accounting():
    pool = PagePool(num_pages=8, page_size=4)
    assert pool.free_pages == 7                 # page 0 reserved
    assert pool.pages_for(1) == 1 and pool.pages_for(9) == 3
    a = pool.alloc(3)
    b = pool.alloc(4)
    assert pool.alloc(1) is None                # exhausted, not an error
    assert 0 not in a + b and len(set(a + b)) == 7
    assert (pool.in_use, pool.peak_in_use) == (7, 7)
    pool.release(a)
    assert pool.free_pages == 3 and pool.in_use == 4
    assert pool.peak_in_use == 7                # high-water mark sticks
    with pytest.raises(ValueError):
        PagePool(num_pages=1, page_size=4)


def test_peak_pages_track_tokens_not_slots(setup):
    """Memory-bound contract: short requests through a wide engine leave
    the peak page footprint at ceil(tokens/page) per live request, far
    under the slots x max-capacity worst case, and a pool sized to that
    peak still completes the trace (admission queues, never fails)."""
    cfg, params, prompts = setup
    scfg = ServeConfig(max_new_tokens=4, eos_token=-1, slots=4, page_size=8,
                       prefill_chunk=8, max_seq=32)
    reqs = [Request(rid=i, prompt=prompts[i][:8], max_new_tokens=4)
            for i in range(5)]
    ce = ContinuousEngine(cfg, params, scfg)
    ce.serve(list(reqs))
    # each live request spans ceil((8+4)/8)=2 pages; 4 slots -> peak 8,
    # while full residency would claim 4 slots x 4 pages = 16
    assert ce.stats["peak_pages"] <= 8
    assert ce.stats["peak_pages"] < scfg.slots * ce.pages_per_slot
    # rerun with the pool clamped to that peak (+scratch): admission must
    # queue on pool pressure and still finish everything
    tight = dataclasses.replace(scfg, num_pages=5)   # 2 live requests max
    ce2 = ContinuousEngine(cfg, params, tight)
    outs = ce2.serve(list(reqs))
    assert set(outs) == set(range(5))
    assert ce2.stats["peak_pages"] <= 4
    for i in range(5):
        np.testing.assert_array_equal(outs[i], ce.serve([reqs[i]])[i])


def test_admission_rejects_oversized_request(setup):
    cfg, params, prompts = setup
    ce = ContinuousEngine(cfg, params, ServeConfig(
        max_new_tokens=4, slots=2, page_size=8, max_seq=16))
    with pytest.raises(ValueError, match="exceeds"):
        ce.serve([Request(rid=0, prompt=prompts[0], max_new_tokens=8)])


def test_paged_refused_for_unsupported_families(setup):
    _, params, _ = setup
    cfg = _cfg(family="ssm", attn_kind="ssm")
    ok, why = M.paged_supported(cfg)
    assert not ok
    with pytest.raises(ValueError, match="static engine only"):
        ContinuousEngine(cfg, M.init(cfg, jax.random.PRNGKey(0)),
                         ServeConfig())


# --------------------------------------------------------------- regression
def test_nonfinite_counter_resets_per_call(setup):
    """Engine.generate() used to leave nonfinite_terminated stale when the
    guard was disabled — a prior guarded call's count survived into
    guard-off calls.  The counter is refreshed-per-call now."""
    cfg, params, prompts = setup
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=2, eos_token=-1,
                                          guard_nonfinite=False))
    eng.nonfinite_terminated = 7        # simulate a stale guarded call
    eng.generate(prompts[:2])
    assert eng.nonfinite_terminated == 0
