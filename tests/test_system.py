"""End-to-end behaviour: train loop with restart, serving, grad compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data.pipeline import LMTokenPipeline
from repro.models import model as M
from repro.optim import adam, constant_schedule, cosine_schedule
from repro.serve.engine import Engine, ServeConfig
from repro.train import grad_compress
from repro.train.steps import make_train_step
from repro.train.train_loop import TrainLoopConfig, run


@pytest.fixture()
def small():
    # function-scoped: some tests donate the param buffers
    cfg = registry.get("stablelm-3b").reduced()
    params = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_train_loop_loss_falls(small, tmp_path):
    cfg, params = small
    opt = adam(cosine_schedule(3e-4, 10, 60))
    st = opt.init(params)
    ts = make_train_step(cfg, opt)   # jitted + donating by default now
    pipe = LMTokenPipeline(cfg, 8, 128)
    res = run(TrainLoopConfig(total_steps=60, ckpt_dir=str(tmp_path),
                              ckpt_every=30, log_every=10),
              ts, params, st, pipe, log=lambda s: None)
    hist = res["history"]
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3


def test_crash_and_resume(small, tmp_path):
    """Crash at step 20, resume, reach the same total steps with a final
    loss close to the uninterrupted run (same data order by construction)."""
    cfg, params = small

    def fresh():
        opt = adam(constant_schedule(1e-3))
        # donate=False: the fixture params tree is reused across runs
        return opt.init(params), make_train_step(cfg, opt, donate=False)

    st, ts = fresh()
    r1 = run(TrainLoopConfig(40, str(tmp_path / "a"), ckpt_every=10,
                             log_every=5), ts, params, st,
             LMTokenPipeline(cfg, 4, 64), log=lambda s: None)

    st, ts = fresh()
    with pytest.raises(RuntimeError):
        run(TrainLoopConfig(40, str(tmp_path / "b"), ckpt_every=10,
                            log_every=5, fail_at_step=20),
            ts, params, st, LMTokenPipeline(cfg, 4, 64), log=lambda s: None)

    st, ts = fresh()
    r2 = run(TrainLoopConfig(40, str(tmp_path / "b"), ckpt_every=10,
                             log_every=5), ts, params, st,
             LMTokenPipeline(cfg, 4, 64), log=lambda s: None)
    assert r2["step"] == 40
    assert abs(r1["history"][-1]["loss"] - r2["history"][-1]["loss"]) < 0.15


def test_straggler_monitor():
    from repro.train.train_loop import StragglerMonitor
    hits = []
    m = StragglerMonitor(window=20, factor=3.0,
                         on_straggler=lambda s, dt, med: hits.append(s))
    for i in range(20):
        m.observe(i, 0.01)
    m.observe(20, 0.2)     # 20x median
    assert m.count == 1 and hits == [20]


def test_grad_compression_training_parity(small):
    cfg, params = small
    losses = {}
    for name, wrap in [("plain", lambda o: o),
                       ("int8", grad_compress.compressed)]:
        opt = wrap(adam(constant_schedule(1e-3)))
        st = opt.init(params)
        ts = make_train_step(cfg, opt, donate=False)  # params reused per wrap
        pipe = LMTokenPipeline(cfg, 4, 64)
        p = params
        m = None
        for step in range(30):
            batch = jax.tree.map(jnp.asarray, next(pipe))
            p, st, m = ts(p, st, batch, jnp.asarray(step))
        losses[name] = float(m["loss"])
    assert abs(losses["plain"] - losses["int8"]) < 0.25, losses


def test_serving_batched(small):
    cfg, params = small
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=8))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, size=(4, 16)).astype(np.int32)
    out = eng.generate(prompts)
    assert out.shape == (4, 8)
    assert (out >= 0).all() and (out < cfg.vocab).all()


def test_serving_deterministic_greedy(small):
    cfg, params = small
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=6, temperature=0.0))
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab, size=(2, 12)).astype(np.int32)
    a = eng.generate(prompts)
    b = eng.generate(prompts)
    assert np.array_equal(a, b)
