"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparsity import make_block_pattern
from repro.kernels import ops, ref


@pytest.mark.parametrize("n_in,n_out,density", [
    (512, 256, 0.25), (1024, 512, 0.125), (256, 256, 0.5), (384, 640, 0.34),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_sparse_fwd(n_in, n_out, density, dtype):
    pat = make_block_pattern(n_in, n_out, density, 128)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (130, n_in)).astype(dtype)   # non-multiple rows
    w = (jax.random.normal(jax.random.PRNGKey(1),
                           (pat.n_out_blocks, pat.fan_in_blocks, 128, 128))
         * 0.05).astype(dtype)
    y = ops.block_sparse_matmul(x, w, jnp.asarray(pat.idx),
                                jnp.asarray(pat.rev_ob), jnp.asarray(pat.rev_t),
                                jnp.asarray(pat.rev_cnt))
    yr = ref.block_sparse_matmul(x, w, jnp.asarray(pat.idx))
    tol = 1e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), rtol=tol, atol=tol)


def test_block_sparse_grads_vs_oracle():
    pat = make_block_pattern(512, 384, 0.25, 128)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (128, 512))
    w = jax.random.normal(jax.random.PRNGKey(1),
                          (pat.n_out_blocks, pat.fan_in_blocks, 128, 128)) * 0.05
    idx = jnp.asarray(pat.idx)
    rob, rt, rc = (jnp.asarray(pat.rev_ob), jnp.asarray(pat.rev_t),
                   jnp.asarray(pat.rev_cnt))
    co = jax.random.normal(jax.random.PRNGKey(2), (128, 384))

    f = lambda x, w: jnp.sum(ops.block_sparse_matmul(x, w, idx, rob, rt, rc) * co)
    g = lambda x, w: jnp.sum(ref.block_sparse_matmul(x, w, idx) * co)
    dx1, dw1 = jax.grad(f, (0, 1))(x, w)
    dx2, dw2 = jax.grad(g, (0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(dx1), np.asarray(dx2), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(dw1), np.asarray(dw2), rtol=1e-3, atol=1e-3)


def test_block_sparse_bias_and_lead_dims():
    pat = make_block_pattern(256, 128, 0.5, 128)
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 5, 256))
    w = jax.random.normal(jax.random.PRNGKey(1),
                          (pat.n_out_blocks, pat.fan_in_blocks, 128, 128)) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(2), (128,))
    y = ops.block_sparse_matmul(x, w, jnp.asarray(pat.idx),
                                jnp.asarray(pat.rev_ob), jnp.asarray(pat.rev_t),
                                jnp.asarray(pat.rev_cnt), bias=b)
    yr = ref.block_sparse_matmul(x.reshape(15, 256), w, jnp.asarray(pat.idx)) + b
    np.testing.assert_allclose(np.asarray(y).reshape(15, 128), np.asarray(yr),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (100, 200, 96), (257, 130, 50)])
@pytest.mark.parametrize("bf,bn", [(8, 3), (5, 2), (11, 4)])
def test_fxp_qmatmul_sweep(M, K, N, bf, bn):
    key = jax.random.PRNGKey(M * K + N)
    lim = 1 << (bn + bf)
    a = jax.random.randint(key, (M, K), -lim, lim)
    w = jax.random.randint(jax.random.PRNGKey(1), (K, N), -lim, lim)
    y = ops.fxp_qmatmul(a, w, bf=bf, bn=bn)
    yr = ref.fxp_qmatmul(a, w, bf, bn)
    assert jnp.array_equal(y, yr), "fixed-point matmul must be bit-exact"


def test_sigmoid_lut_kernel():
    from repro.core import fixed_point as fxp
    t, _ = fxp.sigmoid_tables(fxp.PAPER_FMT)
    codes = jax.random.randint(jax.random.PRNGKey(0), (300, 77), 0, 4096)
    y = ops.sigmoid_lut(codes, jnp.asarray(t))
    assert jnp.array_equal(y, ref.sigmoid_lut(codes, jnp.asarray(t)))


@pytest.mark.parametrize("B,S,di,N,chunk,bd", [
    (2, 128, 512, 16, 64, 256), (1, 256, 256, 8, 128, 256), (3, 64, 1024, 32, 32, 512),
])
def test_selective_scan_kernel(B, S, di, N, chunk, bd):
    """Fused Mamba-1 scan kernel (§Perf F4) vs sequential oracle."""
    from repro.kernels.selective_scan import selective_scan
    ks = jax.random.split(jax.random.PRNGKey(B * S + di), 6)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, di))) * 0.1
    x = jax.random.normal(ks[1], (B, S, di))
    bc = jax.random.normal(ks[2], (B, S, N))
    cc = jax.random.normal(ks[3], (B, S, N))
    a = -jnp.exp(jax.random.normal(ks[4], (di, N)) * 0.3)
    h0 = jax.random.normal(ks[5], (B, di, N)) * 0.1
    y1, h1 = selective_scan(dt, x, bc, cc, a, h0, chunk=chunk, bd=bd,
                            interpret=True)
    y2, h2 = ref.selective_scan(dt, x, bc, cc, a, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=3e-4, rtol=3e-4)


def test_selective_scan_traffic_model():
    from repro.kernels.selective_scan import hbm_bytes
    # falcon-mamba train_4k per-device slice: B=16, S=4096, di=512, N=16
    per_layer = hbm_bytes(16, 4096, 512, 16)
    assert per_layer < 0.5 * 2**30     # < 0.5 GiB per layer pass


@pytest.mark.parametrize("H,Hkv,Sq,window", [
    (4, 4, 128, 0), (8, 2, 128, 0), (4, 2, 256, 96), (2, 1, 64, 0),
])
def test_flash_attention_kernel(H, Hkv, Sq, window):
    """Pallas flash attention vs naive oracle (causal + sliding window, GQA)."""
    from repro.kernels.flash_attention import mha
    B, D = 2, 32
    ks = jax.random.split(jax.random.PRNGKey(H * Sq), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sq, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sq, Hkv, D), jnp.float32)
    got = mha(q, k, v, causal=True, window=window, interpret=True, bq=64, bk=64)

    rep = H // Hkv
    kf = jnp.repeat(k, rep, axis=2)
    vf = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kf) / np.sqrt(D)
    mask = jnp.tril(jnp.ones((Sq, Sq), bool))
    if window:
        qp = jnp.arange(Sq)[:, None]
        kp = jnp.arange(Sq)[None, :]
        mask = mask & (qp - kp < window)
    s = jnp.where(mask[None, None], s, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def _attn_oracle(q, k, v, causal, window):
    rep = q.shape[2] // k.shape[2]
    kf = jnp.repeat(k, rep, axis=2)
    vf = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kf) / np.sqrt(q.shape[-1])
    qp = jnp.arange(q.shape[1])[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones_like(qp >= kp) if not causal else (qp >= kp)
    if window:
        mask = mask & (qp - kp < window)
    s = jnp.where(mask[None, None], s, -1e30)
    return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vf)


@pytest.mark.parametrize("Sq,Sk,causal,window", [
    (37, 37, True, 0),    # off-tile square
    (37, 53, False, 0),   # off-tile rectangular non-causal: the padded KV
                          # rows are only excluded by the explicit
                          # kv_len mask, not the causal one
    (100, 100, True, 48), # off-tile windowed
    (1, 64, False, 0),    # single query row
])
def test_flash_attention_ragged_shapes(Sq, Sk, causal, window):
    """flash_attention pads ragged Sq/Sk to the tile internally (used to
    assert) and the pad rows/cols never leak into the output."""
    from repro.kernels.flash_attention import mha
    B, H, D = 2, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(Sq * Sk), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sk, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sk, H, D), jnp.float32)
    got = mha(q, k, v, causal=causal, window=window,
              interpret=True, bq=64, bk=64)
    assert got.shape == q.shape
    want = _attn_oracle(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("H,Hkv,window", [
    (4, 4, 0), (8, 2, 0), (4, 2, 96),
])
def test_mha_matches_chunked_attention(H, Hkv, window):
    """The Pallas kernel and the models/attention.chunked_attention
    reference (the path the model actually serves through on jnp) agree
    — causal, GQA and windowed variants."""
    from repro.kernels.flash_attention import mha
    from repro.models.attention import chunked_attention
    B, S, D = 2, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(H * 7 + window), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    got = mha(q, k, v, causal=True, window=window, interpret=True,
              bq=64, bk=64)
    want = chunked_attention(q, k, v, causal=True, window=window, chunk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------- fused engine autodiff
def _ragged_pattern(n_in, n_out, density, bs):
    """Pattern whose fan-out is ragged (+-1) — exercises the rev_cnt mask."""
    pat = make_block_pattern(n_in, n_out, density, bs)
    assert pat.rev_cnt.min() != pat.rev_cnt.max(), \
        "shape choice no longer ragged — rev_cnt mask untested"
    return pat


@pytest.mark.parametrize("bs", [8, 128])
@pytest.mark.parametrize("act", ["none", "relu", "sigmoid", "silu", "gelu"])
def test_block_sparse_vjp_fused_epilogue(bs, act):
    """custom_vjp (dx/dw/db through the fused kernels, activation grad
    recomputed in the backward prologue) vs jax.grad of apply_jnp + the
    same epilogue — ragged fan-out, non-multiple-of-bm row count."""
    from repro.core import sparse_linear as sl

    n_in, n_out = 10 * bs, 6 * bs          # nib=10, nob=6
    pat = _ragged_pattern(n_in, n_out, 0.34, bs)   # kb=3 over nib=10: ragged
    key = jax.random.PRNGKey(bs)
    M = 45                                  # non-multiple of any bm
    x = jax.random.normal(key, (M, n_in))
    w = jax.random.normal(jax.random.PRNGKey(1),
                          (pat.n_out_blocks, pat.fan_in_blocks, bs, bs)) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(2), (n_out,)) * 0.3
    co = jax.random.normal(jax.random.PRNGKey(3), (M, n_out))
    idx, rob, rt, rc = (jnp.asarray(pat.idx), jnp.asarray(pat.rev_ob),
                        jnp.asarray(pat.rev_t), jnp.asarray(pat.rev_cnt))

    def f_pallas(x, w, b):
        y = ops.block_sparse_matmul(x, w, idx, rob, rt, rc, bias=b, act=act)
        return jnp.sum(y * co)

    def f_jnp(x, w, b):
        p = {"w": w, "idx": idx, "b": b}
        return jnp.sum(sl._with_act(sl.apply_jnp(p, x), act) * co)

    l1, g1 = jax.value_and_grad(f_pallas, (0, 1, 2))(x, w, b)
    l2, g2 = jax.value_and_grad(f_jnp, (0, 1, 2))(x, w, b)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
    for got, want, name in zip(g1, g2, ("dx", "dw", "db")):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3, err_msg=name)


@pytest.mark.parametrize("act", ["none", "sigmoid", "silu"])
def test_expert_block_sparse_matmul_vs_vmap_oracle(act):
    """Expert-batched custom_vjp (grid (E, M/bm, nob/bn), shared pattern,
    per-expert weights + bias) vs a vmap of the jnp reference — ragged
    fan-out, non-multiple-of-bm rows."""
    from repro.core import sparse_linear as sl

    E, bs = 3, 32
    pat = _ragged_pattern(10 * bs, 6 * bs, 0.34, bs)
    idx, rob, rt, rc = (jnp.asarray(pat.idx), jnp.asarray(pat.rev_ob),
                        jnp.asarray(pat.rev_t), jnp.asarray(pat.rev_cnt))
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    M = 45
    x = jax.random.normal(ks[0], (E, M, 10 * bs))
    w = jax.random.normal(ks[1], (E, pat.n_out_blocks, pat.fan_in_blocks,
                                  bs, bs)) * 0.1
    b = jax.random.normal(ks[2], (E, 6 * bs)) * 0.3
    co = jax.random.normal(ks[3], (E, M, 6 * bs))

    def f_pallas(x, w, b):
        y = ops.expert_block_sparse_matmul(x, w, idx, rob, rt, rc,
                                           bias=b, act=act)
        return jnp.sum(y * co)

    def f_jnp(x, w, b):
        one = lambda x1, w1, b1: sl._with_act(
            sl.apply_jnp({"w": w1, "idx": idx, "b": b1}, x1), act)
        return jnp.sum(jax.vmap(one)(x, w, b) * co)

    l1, g1 = jax.value_and_grad(f_pallas, (0, 1, 2))(x, w, b)
    l2, g2 = jax.value_and_grad(f_jnp, (0, 1, 2))(x, w, b)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
    for got, want, name in zip(g1, g2, ("dx", "dw", "db")):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3, err_msg=name)


def test_expert_gated_matmul_vs_vmap_oracle():
    """Fused SwiGLU expert kernel — silu(x@wg) * (x@wi) in one pass, both
    branch grads through the fused two-branch dx/dw kernels — vs a vmap
    of the two-matmul jnp formula."""
    from repro.core import sparse_linear as sl

    E, bs = 3, 32
    pat = _ragged_pattern(10 * bs, 6 * bs, 0.34, bs)
    idx, rob, rt, rc = (jnp.asarray(pat.idx), jnp.asarray(pat.rev_ob),
                        jnp.asarray(pat.rev_t), jnp.asarray(pat.rev_cnt))
    ks = jax.random.split(jax.random.PRNGKey(8), 4)
    M = 45
    x = jax.random.normal(ks[0], (E, M, 10 * bs))
    wg = jax.random.normal(ks[1], (E, pat.n_out_blocks, pat.fan_in_blocks,
                                   bs, bs)) * 0.1
    wi = jax.random.normal(ks[2], (E, pat.n_out_blocks, pat.fan_in_blocks,
                                   bs, bs)) * 0.1
    co = jax.random.normal(ks[3], (E, M, 6 * bs))

    def f_pallas(x, wg, wi):
        h = ops.expert_gated_matmul(x, wg, wi, idx, rob, rt, rc)
        return jnp.sum(h * co)

    def f_jnp(x, wg, wi):
        def one(x1, g1, i1):
            g = sl.apply_jnp({"w": g1, "idx": idx}, x1)
            u = sl.apply_jnp({"w": i1, "idx": idx}, x1)
            return jax.nn.silu(g) * u
        return jnp.sum(jax.vmap(one)(x, wg, wi) * co)

    l1, g1 = jax.value_and_grad(f_pallas, (0, 1, 2))(x, wg, wi)
    l2, g2 = jax.value_and_grad(f_jnp, (0, 1, 2))(x, wg, wi)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
    for got, want, name in zip(g1, g2, ("dx", "dwg", "dwi")):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3, err_msg=name)


def test_single_junction_is_e1_wrapper_no_expert_family():
    """Acceptance: exactly one kernel family — no expert_-prefixed
    duplicate bodies survive in the kernel module; the E-generic kernels
    take the leading expert dim; ops exposes the one junction_matmul."""
    from repro.kernels import block_sparse_matmul as bsm

    dupes = [n for n in dir(bsm) if n.startswith("expert_")]
    assert not dupes, f"expert_* duplicate kernel family resurfaced: {dupes}"
    assert not hasattr(bsm, "EXPERT_TUNE_TABLE"), "second tune table resurfaced"
    assert callable(ops.junction_matmul)
    # the compat aliases must be thin (no separate custom_vjp cores)
    for n in ("_bsm_core", "_ebsm_core", "_egated_core"):
        assert not hasattr(ops, n), f"pre-unification custom_vjp {n} survives"


def test_gated_single_junction_e1_parity():
    """The fused SwiGLU gate through the E=1 squeeze path (a configuration
    the pre-unification engine could not express: gated was expert-only)
    matches the two-matmul jnp formula fwd + bwd."""
    from repro.core import sparse_linear as sl

    bs = 32
    pat = _ragged_pattern(10 * bs, 6 * bs, 0.34, bs)
    idx, rob, rt, rc = (jnp.asarray(pat.idx), jnp.asarray(pat.rev_ob),
                        jnp.asarray(pat.rev_t), jnp.asarray(pat.rev_cnt))
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    M = 45
    x = jax.random.normal(ks[0], (M, 10 * bs))
    wg = jax.random.normal(ks[1], (pat.n_out_blocks, pat.fan_in_blocks,
                                   bs, bs)) * 0.1
    wi = jax.random.normal(ks[2], (pat.n_out_blocks, pat.fan_in_blocks,
                                   bs, bs)) * 0.1
    co = jax.random.normal(ks[3], (M, 6 * bs))

    def f_pallas(x, wg, wi):
        return jnp.sum(ops.junction_matmul(x, wg, idx, rob, rt, rc, wi=wi) * co)

    def f_jnp(x, wg, wi):
        g = sl.apply_jnp({"w": wg, "idx": idx}, x)
        u = sl.apply_jnp({"w": wi, "idx": idx}, x)
        return jnp.sum(jax.nn.silu(g) * u * co)

    l1, g1 = jax.value_and_grad(f_pallas, (0, 1, 2))(x, wg, wi)
    l2, g2 = jax.value_and_grad(f_jnp, (0, 1, 2))(x, wg, wi)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
    for got, want, name in zip(g1, g2, ("dx", "dwg", "dwi")):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3, err_msg=name)


def test_tune_table_key_migration():
    """Every pre-refactor tune-table key resolves to the same tiles
    through the merged table: PR 1's 4-key (M, nob, kb, bs) schema, the
    transitional 5-key, and PR 2's 6-key expert schema."""
    from repro.kernels import block_sparse_matmul as bsm

    pre_refactor = {
        # PR 1 TUNE_TABLE entries (single junction, one weight operand)
        (12544, 4, 2, 128): (512, 4),
        (4096, 32, 2, 128): (256, 8),
        # PR 2 EXPERT_TUNE_TABLE entry (gated: two weight operands)
        (4, 1280, 4, 2, 128, 2): (256, 4),
    }
    for key, want in pre_refactor.items():
        canon = bsm.canonical_tune_key(key)
        assert len(canon) == 6
        assert bsm.TUNE_TABLE[canon] == want, (key, canon)
    # the chooser actually hits them through its canonical lookup
    assert bsm.choose_tiles(12544, 4, 2, 128, 8, 4) == (512, 4)
    assert bsm.choose_tiles(4096, 32, 2, 128, 8, 4) == (256, 8)
    assert bsm.choose_tiles(1280, 4, 2, 128, 8, 4,
                            E=4, n_weight_operands=2) == (256, 4)
    # 5-key transitional schema pins n_weight_operands=1
    assert bsm.canonical_tune_key((4, 1280, 4, 2, 128)) == (4, 1280, 4, 2, 128, 1)
    with pytest.raises(ValueError):
        bsm.canonical_tune_key((1, 2, 3))


def test_dx_zero_fanout_rows_exact_zero():
    """A row block with rev_cnt == 0 (input block with zero fan-out under
    the reverse pattern) must produce exact-zero dx rows — even when the
    upstream gradient is non-finite (inf/nan) — rather than garbage from
    the (0, 0) sentinel bundles the padded reverse slots point at."""
    from repro.core.interleaver import reverse_block_pattern

    bs, nib, nob, kb = 8, 6, 2, 2
    # blocks 4 and 5 are referenced by no output block -> rev_cnt == 0
    idx_np = np.array([[0, 1], [2, 3]], np.int32)
    rev_ob, rev_t, rev_cnt = reverse_block_pattern(idx_np, nib)
    assert (rev_cnt == 0).sum() == 2
    idx, rob, rt, rc = (jnp.asarray(idx_np), jnp.asarray(rev_ob),
                        jnp.asarray(rev_t), jnp.asarray(rev_cnt))
    M = 16
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    x = jax.random.normal(ks[0], (M, nib * bs))
    w = jax.random.normal(ks[1], (nob, kb, bs, bs)) * 0.1
    b = jax.random.normal(ks[2], (nob * bs,))

    for act in ("none", "sigmoid", "silu"):
        f = lambda x: ops.junction_matmul(x, w, idx, rob, rt, rc,
                                          bias=b, act=act)
        _, vjp = jax.vjp(f, x)
        # non-finite upstream grad: 0 * inf = nan would leak through a
        # multiply-style mask — the where-mask must keep structural zeros
        dy_bad = jnp.full((M, nob * bs), jnp.inf)
        (dxv,) = vjp(dy_bad)
        dead = np.asarray(dxv).reshape(M, nib, bs)[:, rev_cnt == 0, :]
        np.testing.assert_array_equal(dead, 0.0, err_msg=f"act={act}")

    # gated configuration masks the same way
    wi = jax.random.normal(jax.random.PRNGKey(9), (nob, kb, bs, bs)) * 0.1
    _, vjp = jax.vjp(
        lambda x: ops.junction_matmul(x, w, idx, rob, rt, rc, wi=wi), x)
    (dxv,) = vjp(jnp.full((M, nob * bs), jnp.nan))
    dead = np.asarray(dxv).reshape(M, nib, bs)[:, rev_cnt == 0, :]
    np.testing.assert_array_equal(dead, 0.0)


def test_fused_forward_grid_bound():
    """Acceptance bound: the fused forward runs in exactly
    (M/bm) * ceil(nob/bn) grid steps — the kb reduction never appears as a
    grid dimension (the seed kernel's grid was (M/bm, nob, kb))."""
    from repro.kernels import block_sparse_matmul as bsm

    for (M, nob, kb, bs, nib) in [(256, 4, 2, 128, 8), (12544, 4, 2, 128, 8),
                                  (64, 10, 3, 32, 10), (4096, 32, 2, 128, 8)]:
        bm, bn = bsm.choose_tiles(M, nob, kb, bs, nib, 4)
        gm, gn = bsm.fwd_grid(M, nob, kb, bs, nib, 4)
        Mp = -(-M // bm) * bm
        assert gm * gn <= (Mp // bm) * (-(-nob // bn)), (M, nob, kb)
        assert nob % bn == 0 and gn == nob // bn
        assert bm % 16 == 0 and bm >= 16
