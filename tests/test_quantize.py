"""Quantized inference datapath (ISSUE 8): int8/fxp junction kernels.

Pins the PR's acceptance criteria: int8 forwards sit within the analytic
quantization tolerance of fp32 and the two engines agree to float
rounding; the fxp path is ENGINE-EXACT and bit-exact against the
core/fixed_point.py clipping-tree reference on the paper's Table II
triplets (on data where no intermediate adder clips, so the two
semantics provably coincide); MoE expert junctions quantize per expert;
every train entry point refuses integer-code weights; quantize-at-load
serving decodes greedily like fp32 and its decode jaxpr contains ONLY
the quantized forward kernels; and the ragged-shape padding that
replaced the hard tile asserts in fxp_qmatmul / sigmoid_lut round-trips.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ArchConfig, MoEConfig
from repro.core import fixed_point as fp
from repro.core import quantize as qz
from repro.core import sparse_linear as sl
from repro.core.sparsity import SparsityConfig, make_block_pattern
from repro.kernels import ops


def _junction(n_in=256, n_out=128, density=0.5, block=32, bias=True, seed=0):
    sp = SparsityConfig(density=density, block=block)
    p = sl.init_sparse(jax.random.PRNGKey(seed), n_in, n_out, sp, bias=bias)
    if bias:
        p["b"] = jax.random.normal(jax.random.PRNGKey(seed + 1),
                                   (n_out,)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(seed + 2), (70, n_in))
    return p, x


# ------------------------------------------------------------ weight codes
@pytest.mark.parametrize("granularity", ["block", "unit"])
def test_int8_codes_dequantize_within_half_step(granularity):
    w = jax.random.normal(jax.random.PRNGKey(0), (4, 3, 32, 32))
    codes, scale = qz.quantize_weights(w, bits=8, granularity=granularity)
    assert codes.dtype == jnp.int8 and scale.shape == (4, 3)
    deq = codes.astype(jnp.float32) * scale[..., None, None]
    # symmetric round-to-nearest: error bounded by half a quantization step
    err = np.abs(np.asarray(deq) - np.asarray(w))
    assert np.all(err <= np.asarray(scale)[..., None, None] / 2 + 1e-7)
    if granularity == "unit":
        assert len(np.unique(np.asarray(scale))) == 1


def test_int8_sub8_bits_clip_tighter():
    w = jax.random.normal(jax.random.PRNGKey(0), (4, 3, 32, 32))
    codes4, _ = qz.quantize_weights(w, bits=4)
    assert int(jnp.max(jnp.abs(codes4.astype(jnp.int32)))) <= 7
    codes2, _ = qz.quantize_weights(w, bits=2)
    assert int(jnp.max(jnp.abs(codes2.astype(jnp.int32)))) <= 1
    for bad in (1, 9):
        with pytest.raises(ValueError):
            qz.QuantConfig(mode="int8", bits=bad)


def test_zero_block_scale_stays_finite():
    w = jnp.zeros((2, 2, 32, 32))
    codes, scale = qz.quantize_weights(w)
    assert np.all(np.asarray(scale) == 1.0)     # no 0/0 in the dequant
    assert np.all(np.asarray(codes) == 0)


# --------------------------------------------------------------- int8 path
def test_int8_fwd_within_analytic_tolerance_of_fp32():
    p, x = _junction()
    pq = qz.quantize_junction(p, qz.QuantConfig(mode="int8"))
    assert "w" not in pq and "wq" in pq          # fp leaf provably gone
    y_fp = sl.apply(p, x, engine="jnp", act="none")
    y_q = sl.apply(pq, x, engine="jnp", act="none")
    err = np.max(np.abs(np.asarray(y_q) - np.asarray(y_fp)))
    # 8-bit symmetric weight+activation quantization over a kb*bs=64 fan-in
    # at unit-scale activations: observed ~0.01, bound generously
    assert 0.0 < err < 0.08


@pytest.mark.parametrize("granularity", ["block", "unit"])
@pytest.mark.parametrize("static_x", [False, True])
def test_int8_engine_parity(granularity, static_x):
    """The jnp sim mirrors the kernel op-for-op (same scale grouping, same
    per-slot accumulation order) — parity is float rounding, not an
    approximation tolerance."""
    p, x = _junction()
    xs = float(jnp.max(jnp.abs(x))) / 127.0 if static_x else None
    pq = qz.quantize_junction(
        p, qz.QuantConfig(mode="int8", granularity=granularity), x_scale=xs)
    assert ("x_scale" in pq) == static_x
    y_jnp = sl.apply(pq, x, engine="jnp", act="sigmoid")
    y_pal = sl.apply(pq, x, engine="pallas", act="sigmoid")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_jnp),
                               atol=1e-5, rtol=1e-5)


def test_gated_int8_matches_two_branch_sim():
    """gated_fwd_int8 (shared activation codes, silu(g)*u epilogue) vs the
    two plain int8 sims composed — same quantization formula, so the only
    difference is float rounding."""
    sp = SparsityConfig(density=0.5, block=32)
    pg = sl.init_sparse(jax.random.PRNGKey(0), 256, 128, sp)
    pi = sl.init_sparse(jax.random.PRNGKey(1), 256, 128, sp)
    x = jax.random.normal(jax.random.PRNGKey(2), (45, 256))
    wgq, wg_s = qz.quantize_weights(pg["w"])
    wiq, wi_s = qz.quantize_weights(pi["w"])
    y = ops.junction_matmul(x, wgq, pg["idx"], pg["rev_ob"], pg["rev_t"],
                            pg["rev_cnt"], wi=wiq, w_scale=wg_s,
                            wi_scale=wi_s)
    g = qz._int8_apply(x, wgq, pg["idx"], wg_s)
    u = qz._int8_apply(x, wiq, pg["idx"], wi_s)
    want = jax.nn.silu(g) * u
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_calibrated_scales_positive_and_layerwise():
    layers = [sl.init_sparse(jax.random.PRNGKey(i), 256, 256,
                             SparsityConfig(density=0.5, block=32))
              for i in range(2)]
    x = jax.random.normal(jax.random.PRNGKey(9), (64, 256))
    scales = qz.calibrate_layer_scales(layers, x, act="sigmoid")
    assert len(scales) == 2 and all(s > 0.0 for s in scales)
    # layer 1 sees sigmoid outputs in (0, 1): its absmax/127 is below
    # the raw-input scale
    assert scales[1] < scales[0]


# ---------------------------------------------------------------- fxp path
def test_fxp_engine_exact():
    """No tolerance: the fxp pipeline is integer end to end, so the Pallas
    kernel and the jnp sim must agree bit for bit."""
    p, x = _junction()
    pq = qz.quantize_junction(p, qz.QuantConfig(mode="fxp", act="sigmoid"))
    assert "qfmt" in pq and pq["qlut"].shape == (fp.PAPER_FMT.n_codes,)
    y_jnp = sl.apply(pq, x, engine="jnp")
    y_pal = sl.apply(pq, x, engine="pallas")
    assert jnp.array_equal(y_jnp, y_pal)
    # and the LUT epilogue actually ran: outputs are sigmoid-range codes
    assert float(jnp.min(y_jnp)) >= 0.0 and float(jnp.max(y_jnp)) <= 1.0


@pytest.mark.parametrize("fmt", fp.PAPER_TRIPLETS,
                         ids=lambda f: f"bw{f.bw}bn{f.bn}bf{f.bf}")
def test_fxp_bitexact_vs_clipping_tree(fmt):
    """Bit-exact against the paper's clipping-tree semantics on data where
    the two provably coincide: activations on the 2^-5 grid in
    [-0.25, 0.25] (exact in every Table II triplet), integer weights in
    {-1, 0, 1} with <= 4 live rows per block (|partial sums| <= 2 plus a
    bias in [-0.5, 0.5] stays under every triplet's max_val, so no adder
    clips and every product lands on the grid)."""
    bs, nib, nob, kb = 8, 8, 2, 2
    pat = make_block_pattern(nib * bs, nob * bs, kb / nib, bs)
    assert pat.fan_in_blocks == kb
    rng = np.random.default_rng(fmt.bw * 100 + fmt.bf)
    M = 24
    x = jnp.asarray(rng.integers(-8, 9, size=(M, nib * bs)) / 32.0,
                    jnp.float32)
    w_int = rng.integers(-1, 2, size=(nob, kb, bs, bs)).astype(np.float32)
    w_int[:, :, 4:, :] = 0.0                      # <= 4 live rows per block
    w = jnp.asarray(w_int)
    b = jnp.asarray(rng.integers(-16, 17, size=(nob * bs,)) / 32.0,
                    jnp.float32)
    p = {"w": w, "b": b, "idx": jnp.asarray(pat.idx),
         "rev_ob": jnp.asarray(pat.rev_ob), "rev_t": jnp.asarray(pat.rev_t),
         "rev_cnt": jnp.asarray(pat.rev_cnt)}

    # the clipping-tree reference: q_mul every edge, tree-sum with clipping
    # at every adder node, q_add the bias, sigmoid LUT on the result code
    xb = x.reshape(M, nib, bs)
    terms = []
    for k in range(kb):
        xk = xb[:, pat.idx[:, k], :]                        # [M, nob, bs]
        terms.append(fp.q_mul(xk[:, :, :, None], w[None, :, k], fmt))
    terms = jnp.concatenate(terms, axis=2)          # [M, nob, kb*bs, bs]
    s = fp.tree_sum_clipped(terms, fmt, axis=2).reshape(M, nob * bs)
    s = fp.q_add(s, fp.quantize(b, fmt), fmt)
    want = fp.lut_sigmoid(s, fmt)[0]

    pq = qz.quantize_junction(p, qz.QuantConfig(mode="fxp", fmt=fmt,
                                                act="sigmoid"))
    for engine in ("jnp", "pallas"):
        got = sl.apply(pq, x, engine=engine)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"engine={engine}")


def test_fxp_refuses_gated_and_moe():
    with pytest.raises(ValueError, match="plain junctions only"):
        qz.quantize_junction({"idx_in": jnp.zeros((2, 2), jnp.int32),
                              "wg": jnp.zeros((2, 2, 2, 32, 32))},
                             qz.QuantConfig(mode="fxp"))
    w = jnp.zeros((2, 2, 32, 32), jnp.int32)
    with pytest.raises(ValueError, match="plain junctions"):
        ops.junction_matmul(jnp.zeros((4, 64)), w,
                            jnp.zeros((2, 2), jnp.int32), None, None, None,
                            wi=w, qfmt=jnp.asarray([8, 3], jnp.int32),
                            qlut=jnp.zeros((4096,)))


# ----------------------------------------------------------- MoE junctions
def _moe_cfg(engine="jnp"):
    return ArchConfig(
        name="quant-moe-test", family="moe", n_layers=1, d_model=128,
        n_heads=4, kv_heads=4, head_dim=32, d_ff=256, vocab=128,
        act="silu", max_seq=64, attn_chunk=32, dtype="float32",
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64, group_size=32,
                      capacity_factor=1.25),
        sparsity=SparsityConfig(density=0.5, block=32, where="ffn"),
        engine=engine)


def test_moe_expert_int8_parity_and_tolerance():
    """Per-expert [E, nob, kb] scales through both expert junctions: the
    quantized jnp twin tracks fp32 within quantization error, and the
    Pallas expert kernels match the twin to float rounding."""
    from repro.models import moe as moe_mod

    cfg = _moe_cfg("jnp")
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    assert "idx_in" in params
    pq = qz.quantize_tree(params, qz.QuantConfig(mode="int8"))
    assert "wgq" in pq and pq["wg_scale"].shape == params["wg"].shape[:3]
    for k in ("wg", "wi", "wo"):
        assert k not in pq
    assert jnp.array_equal(pq["router"], params["router"])  # dense stays fp

    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y_fp, aux_fp = moe_mod.moe_apply(params, x, cfg)
    y_q, aux_q = moe_mod.moe_apply(pq, x, cfg)
    assert float(aux_q) == float(aux_fp)         # routing untouched
    rel = (np.linalg.norm(np.asarray(y_q) - np.asarray(y_fp))
           / np.linalg.norm(np.asarray(y_fp)))
    assert 0.0 < rel < 0.05

    y_pal, _ = moe_mod.moe_apply(pq, x, dataclasses.replace(cfg,
                                                            engine="pallas"))
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_q),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------- train refusals
def test_train_update_refuses_integer_codes():
    p, x = _junction(bias=False)
    pq = qz.quantize_junction(p, qz.QuantConfig(mode="int8"))
    hyp = jnp.asarray([0.1, 0.9], jnp.float32)
    with pytest.raises(ValueError, match="inference-only"):
        ops.junction_train_update(x, pq["wq"], pq["idx"], pq["rev_ob"],
                                  pq["rev_t"], pq["rev_cnt"], hyp=hyp)
    # and the fp path refuses bare integer codes without their scales
    with pytest.raises(ValueError, match="quantization leaves"):
        ops.junction_matmul(x, pq["wq"], pq["idx"], pq["rev_ob"],
                            pq["rev_t"], pq["rev_cnt"])


def test_inject_update_ctx_refuses_quantized_junction():
    p, _ = _junction()
    pq = qz.quantize_junction(p, qz.QuantConfig(mode="int8"))
    tree = {"layer0": pq}
    with pytest.raises(ValueError, match="inference-only"):
        sl.inject_update_ctx(tree, None, jnp.asarray([0.1, 0.9]))


def test_apply_refuses_fused_ctx_on_quantized_junction():
    p, x = _junction()
    pq = qz.quantize_junction(p, qz.QuantConfig(mode="int8"))
    pq[sl.UPDATE_HYP_LEAF] = jnp.asarray([0.1, 0.9])
    for engine in ("jnp", "pallas"):
        with pytest.raises(ValueError, match="inference-only"):
            sl.apply(pq, x, engine=engine)


# ------------------------------------------------------------ tree / serve
def test_quantize_tree_scopes_to_junctions_and_is_idempotent():
    tree = {
        "dense": {"w": jnp.ones((8, 8))},                 # no pattern: stays
        "junction": _junction()[0],
        "nested": [{"inner": _junction(seed=3)[0]}],
    }
    out = qz.quantize_tree(tree, qz.QuantConfig(mode="int8"))
    assert "w" in out["dense"] and "wq" not in out["dense"]
    assert "wq" in out["junction"] and "w" not in out["junction"]
    assert "wq" in out["nested"][0]["inner"]
    # second pass: nothing fp left to quantize, tree passes through
    again = qz.quantize_tree(out, qz.QuantConfig(mode="int8"))
    assert jax.tree.structure(again) == jax.tree.structure(out)


def test_serve_quantize_at_load_greedy_stable_and_jaxpr():
    """Acceptance: serving end to end with ServeConfig.quantize — greedy
    decode stays in agreement with fp32, the quantized decode step's
    jaxpr contains the int8 forward kernel and NO fp junction forward,
    and fxp is refused at the serve boundary."""
    from repro.models import model as M
    from repro.serve.engine import Engine, ServeConfig
    from repro.train.steps import make_decode_step

    cfg = registry.get("stablelm-3b").reduced().with_sparsity(
        SparsityConfig(density=0.5, block=32, where="ffn"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (2, 8),
                                            0, cfg.vocab))
    n_new = 6
    tok_fp = Engine(cfg, params,
                    ServeConfig(max_new_tokens=n_new)).generate(prompts)
    tok_q = Engine(cfg, params,
                   ServeConfig(max_new_tokens=n_new,
                               quantize="int8")).generate(prompts)
    agreement = float(np.mean(tok_fp == tok_q))
    assert agreement >= 0.75, (tok_fp, tok_q)

    with pytest.raises(ValueError, match="int8"):
        Engine(cfg, params, ServeConfig(quantize="fxp"))

    # the quantized decode step lowers to the int8 kernels ONLY: no fp
    # junction forward survives in the jaxpr (the fp weight leaf is gone)
    cfg_p = dataclasses.replace(cfg, engine="pallas")
    pq = qz.quantize_tree(params, qz.QuantConfig(mode="int8"))
    step = make_decode_step(cfg_p)
    cache = M.make_cache(cfg_p, 2, 16)
    tok = jnp.zeros((2, 1), jnp.int32)
    txt = str(jax.make_jaxpr(step)(pq, cache, tok,
                                   jnp.asarray(8, jnp.int32)))
    assert "fwd_int8_kernel" in txt
    assert "fwd_kernel" not in txt.replace("fwd_int8_kernel", "")


# ------------------------------------------------- config / cohort plumbing
def test_quant_config_validation_and_structure_keys():
    from repro.search import bucket_quant

    with pytest.raises(ValueError):
        qz.QuantConfig(mode="int4")
    with pytest.raises(ValueError):
        qz.QuantConfig(granularity="tensor")
    with pytest.raises(ValueError):
        qz.QuantConfig(mode="fxp", act="gelu")

    configs = [qz.QuantConfig(mode="int8", bits=b, granularity=g)
               for b in (8, 6, 4) for g in ("block", "unit")]
    configs += [qz.QuantConfig(mode="fxp", fmt=f) for f in fp.PAPER_TRIPLETS]
    cohorts = bucket_quant(configs)
    # all int8 configs share one cohort (codes share the int8 container,
    # scales the [nob, kb] layout); each fxp triplet is structural
    assert len(cohorts) == 1 + len(fp.PAPER_TRIPLETS)
    assert cohorts[0].key == ("int8",) and cohorts[0].size == 6
    assert cohorts[0].member_ids == tuple(range(6))
    for co in cohorts[1:]:
        assert co.key[0] == "fxp" and co.size == 1


# -------------------------------------------- ragged-tile kernel regressions
def test_qmatmul_ragged_shapes_pad_to_tile():
    """fxp_qmatmul used to hard-assert M % bm == 0 — ragged M/K/N must now
    pad to the tile and slice back, bit-exact vs the oracle."""
    from repro.kernels import fxp_qmatmul as fxpk
    from repro.kernels import ref

    lim = 1 << 7
    a = jax.random.randint(jax.random.PRNGKey(0), (75, 33), -lim, lim)
    w = jax.random.randint(jax.random.PRNGKey(1), (33, 50), -lim, lim)
    y = fxpk.qmatmul(a, w, bf=5, bn=2, interpret=True)
    assert y.shape == (75, 50)
    assert jnp.array_equal(y, ref.fxp_qmatmul(a, w, 5, 2))


def test_lut_lookup_ragged_rows_pad_to_tile():
    from repro.kernels import sigmoid_lut as slutk

    table, _ = fp.sigmoid_tables(fp.PAPER_FMT)
    codes = jax.random.randint(jax.random.PRNGKey(0), (37, 77), 0, 4096)
    y = slutk.lut_lookup(codes, jnp.asarray(table), interpret=True)
    assert y.shape == (37, 77)
    assert jnp.array_equal(y, jnp.take(jnp.asarray(table), codes, axis=0))
