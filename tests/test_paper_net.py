"""The paper's Table-I network: structure, fixed point, pipelining."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fixed_point as fxp
from repro.core import junction_pipeline as JP
from repro.core import paper_net as PN
from repro.data.mnist import paper_dataset


@pytest.fixture(scope="module")
def data():
    x, y, labels = paper_dataset(2048, seed=0)
    return jnp.asarray(x), jnp.asarray(y)


def test_table1_structure():
    cfg = PN.PaperNetConfig()
    assert cfg.n_params() == 5216                     # Sec. III-B
    assert abs(cfg.overall_density() - 0.07576) < 1e-4
    assert [cfg.weights(i) for i in range(2)] == [4096, 1024]
    assert [cfg.d_in(i) for i in range(2)] == [64, 32]
    assert [cfg.block_cycles(i) for i in range(2)] == [34, 34]  # W/z + 2
    # equal block cycles across junctions -> full pipeline, no stalls
    assert cfg.block_cycles(0) == cfg.block_cycles(1)


def test_resource_model():
    r = JP.resources(PN.PaperNetConfig())
    # Sec. III-D-3: 224 DSP multipliers for FF+BP (z1+z2 + 2*z2)
    assert r.ff_multipliers + r.bp_multipliers == 224
    assert r.up_multipliers == 160
    assert r.sigmoid_luts == 3
    assert abs(JP.block_cycle_s(PN.PaperNetConfig()) - 34 / 15e6) < 1e-12


def test_fxp_training_learns(data):
    xs, ys = data
    cfg = PN.PaperNetConfig(fmt=fxp.PAPER_FMT)
    p = PN.init(cfg)
    p, losses, corr = jax.jit(
        lambda p: PN.train_epoch(p, xs, ys, 2.0 ** -3, cfg))(p)
    assert float(corr[-256:].mean()) > 0.8


def test_float_vs_fxp_parity(data):
    """Paper Sec. III-D-6: fixed point within 1.5pp of ideal float."""
    xs, ys = data
    accs = {}
    for name, fmt in [("float", None), ("fxp", fxp.PAPER_FMT)]:
        cfg = PN.PaperNetConfig(fmt=fmt)
        p = PN.init(cfg)
        step = jax.jit(lambda p: PN.train_epoch(p, xs, ys, 2.0 ** -3, cfg))
        for _ in range(2):
            p, _, corr = step(p)
        accs[name] = float(corr[-512:].mean())
    assert abs(accs["float"] - accs["fxp"]) < 0.05   # 5pp margin on 2 epochs


def test_pipelined_matches_sequential_convergence(data):
    """Junction pipelining (stale updates) converges like sequential SGD."""
    xs, ys = data
    cfg = PN.PaperNetConfig(fmt=fxp.PAPER_FMT)
    p_seq = PN.init(cfg)
    p_pipe = PN.init(cfg)
    seq = jax.jit(lambda p: PN.train_epoch(p, xs, ys, 2.0 ** -3, cfg))
    pipe = jax.jit(lambda p: PN.train_epoch_pipelined(p, xs, ys, 2.0 ** -3, cfg))
    for _ in range(2):
        p_seq, _, corr_s = seq(p_seq)
        p_pipe, corr_p = pipe(p_pipe)
    a_s, a_p = float(corr_s[-512:].mean()), float(corr_p[-512:].mean())
    assert a_p > 0.75 and abs(a_s - a_p) < 0.08


def test_shared_init_mode_trains(data):
    """Sec. III-C-1: W_i/z_i shared unique init values don't hurt."""
    xs, ys = data
    cfg = PN.PaperNetConfig(fmt=fxp.PAPER_FMT, init_mode="shared")
    p = PN.init(cfg)
    p, _, corr = jax.jit(lambda p: PN.train_epoch(p, xs, ys, 2.0 ** -3, cfg))(p)
    assert float(corr[-256:].mean()) > 0.7


@pytest.mark.parametrize("act", ["relu8", "relu1"])
def test_relu_variants_run(data, act):
    xs, ys = data
    cfg = PN.PaperNetConfig(fmt=fxp.PAPER_FMT, activation=act)
    p = PN.init(cfg)
    p, _, corr = jax.jit(lambda p: PN.train_epoch(p, xs[:512], ys[:512],
                                                  2.0 ** -3, cfg))(p)
    assert np.isfinite(float(corr.mean()))


def test_weights_stay_on_grid(data):
    """Every parameter remains on the (12,3,8) grid after training."""
    xs, ys = data
    cfg = PN.PaperNetConfig(fmt=fxp.PAPER_FMT)
    p = PN.init(cfg)
    p, _, _ = jax.jit(lambda p: PN.train_epoch(p, xs[:512], ys[:512],
                                               2.0 ** -3, cfg))(p)
    for jp in p["junctions"]:
        for leaf in (jp["w"], jp["b"]):
            v = np.asarray(leaf) * cfg.fmt.scale
            assert np.allclose(v, np.round(v), atol=1e-4)
            assert v.max() <= cfg.fmt.max_val * cfg.fmt.scale + 1e-6
            assert v.min() >= cfg.fmt.min_val * cfg.fmt.scale - 1e-6


def test_z_sweep_model():
    rows = JP.z_sweep_configs(PN.PaperNetConfig())
    assert len(rows) >= 4
    # throughput rises with z, resources rise with z (Fig. 8 trend)
    tz = [r["total_z"] for r in rows]
    bc = [r["block_cycle_s"] for r in rows]
    mult = [r["multipliers"] for r in rows]
    assert all(a < b for a, b in zip(tz, tz[1:]))
    assert all(a >= b for a, b in zip(bc, bc[1:]))
    assert all(a <= b for a, b in zip(mult, mult[1:]))
