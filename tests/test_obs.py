"""Flight-recorder telemetry (ISSUE 10): the obs subsystem end to end.

Layers under test, bottom-up:
  * obs/telemetry — nearest-rank percentile (p99 of <100 samples is the
    max, never an interpolation past it), histogram/recorder mechanics,
    JSONL round-trip, and the no-extra-device-sync guard (recording a
    live jax.Array is a TypeError);
  * repro/artifacts — the one meta stamp round-trips through BOTH
    consumer schemas (BENCH via benchmarks.run.load_artifact, stamped
    and legacy flat, and the sweep Ledger);
  * train/train_loop — a poisoned run emits trip → rollback → backoff →
    recovery in order, step ids matching the loop's own guardian state,
    plus checkpoint save/promote events;
  * search/scheduler — a quarantined member's event carries its
    cohort/slot, matching the ledger record;
  * serve/engine — every completed request reconstructs a full span
    (validated by launch/obs_report.check_span) and the compile-once
    contract holds with the recorder attached (decode_traces ==
    prefill_traces == 1);
  * no-retrace regression — the jaxpr of the fused train step is
    IDENTICAL with and without a recorder attached to the loop.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import artifacts
from repro.obs import (Guardian, Histogram, NOT_SAMPLED, Recorder,
                       RequestSpan, SweepRound, TrainStep, percentile,
                       read_events)

# shared e2e fixtures: the guardian's poisoned-stream regression setup
from test_guardian import (PoisonPipeline, _junction, _make_regression_step,
                           _w_true)

from repro.configs.base import ArchConfig, SweepConfig
from repro.core.sparsity import SparsityConfig
from repro.launch.obs_report import build_report, check_span
from repro.models import model as M
from repro.search import CandidateSpec, run_sweep
from repro.serve.engine import ContinuousEngine, Request, ServeConfig
from repro.train.train_loop import GuardianConfig, TrainLoopConfig, run


# ------------------------------------------------------- percentile helper
def test_percentile_single_sample():
    """n=1: every percentile is that sample (the ISSUE's 1-sample case)."""
    for q in (1, 50, 99, 100):
        assert percentile([7.25], q) == 7.25


def test_percentile_two_samples():
    """n=2: p50 is the smaller (rank ceil(0.5*2)=1), p99/p100 the max —
    NOT a value interpolated past the larger observation (np.percentile's
    linear default returns 1.98 for p99 of [1, 2])."""
    assert percentile([2.0, 1.0], 50) == 1.0
    assert percentile([2.0, 1.0], 99) == 2.0
    assert percentile([2.0, 1.0], 100) == 2.0


def test_percentile_hundred_samples():
    """n=100: nearest rank lands on exact order statistics."""
    xs = list(range(1, 101))            # 1..100
    assert percentile(xs, 1) == 1
    assert percentile(xs, 50) == 50
    assert percentile(xs, 99) == 99
    assert percentile(xs, 100) == 100


def test_percentile_small_sample_p99_is_max():
    """p99 of any <100-sample set is the worst OBSERVED value."""
    for n in (1, 2, 5, 50, 99):
        xs = np.random.default_rng(n).standard_normal(n).tolist()
        assert percentile(xs, 99) == max(xs)


def test_percentile_rejects_bad_input():
    with pytest.raises(ValueError):
        percentile([], 50)
    with pytest.raises(ValueError):
        percentile([1.0], 0)
    with pytest.raises(ValueError):
        percentile([1.0], 101)


# ------------------------------------------------------- recorder mechanics
def test_histogram_summary_and_window():
    h = Histogram(cap=4)
    for v in (5.0, 1.0, 2.0, 3.0, 4.0):     # 5.0 evicted by the window
        h.observe(v)
    s = h.summary()
    assert s["count"] == 5                  # lifetime count
    assert s["mean"] == pytest.approx(3.0)  # lifetime mean
    assert s["min"] == 1.0 and s["max"] == 4.0  # windowed extrema
    assert h.percentile(99) == 4.0


def test_recorder_ring_and_jsonl_round_trip(tmp_path):
    p = str(tmp_path / "obs.jsonl")
    with Recorder(p, ring=3, meta={"launcher": "test", "tag": "t"}) as r:
        r.count("steps", 2)
        r.count("steps")
        r.gauge("lr", 0.5)
        r.observe("dt", 0.25)
        for i in range(5):
            r.emit(TrainStep(step=i, loss=float(i), nonfinite=NOT_SAMPLED,
                             lr_scale=1.0, dt_s=0.1, dt_ema_s=0.1,
                             tokens_per_s=10.0))
    assert r.counters["steps"] == 3
    # ring keeps only the newest 3 events; the sink keeps all 5
    assert [e.step for e in r.events("train.step")] == [2, 3, 4]
    meta, events = read_events(p)
    assert meta["launcher"] == "test" and meta["tag"] == "t"
    steps = [e for e in events if e["kind"] == "train.step"]
    assert [e["step"] for e in steps] == [0, 1, 2, 3, 4]
    assert [e["seq"] for e in events] == sorted(e["seq"] for e in events)
    # close() appended the summary frame with the aggregates
    assert events[-1]["kind"] == "summary"
    assert events[-1]["counters"]["steps"] == 3
    assert events[-1]["histograms"]["dt"]["count"] == 1


def test_recorder_emit_rejects_untyped_events():
    with pytest.raises(TypeError):
        Recorder().emit({"kind": "train.step"})


def test_recorder_rejects_device_arrays():
    """The no-extra-device-sync contract is enforced, not advisory:
    recording a live jax.Array (which would force a D2H transfer) raises
    instead of silently syncing."""
    r = Recorder()
    dev = jnp.float32(1.5)
    with pytest.raises(TypeError, match="no-extra-device-sync"):
        r.gauge("lr", dev)
    with pytest.raises(TypeError, match="no-extra-device-sync"):
        r.observe("dt", dev)
    with pytest.raises(TypeError, match="no-extra-device-sync"):
        r.emit(TrainStep(step=0, loss=dev, nonfinite=0.0, lr_scale=1.0,
                         dt_s=0.1, dt_ema_s=0.1, tokens_per_s=1.0))
    r.gauge("lr", float(dev))               # host float: fine


# -------------------------------------------------- artifact meta stamping
def test_artifact_meta_round_trips_bench_schemas(tmp_path):
    """The one repro.artifacts stamp survives both BENCH_*.json schemas:
    the stamped {"meta", "results"} form round-trips meta exactly, the
    legacy flat form loads with empty meta."""
    from benchmarks.run import load_artifact

    meta = artifacts.artifact_meta("pr10")
    assert set(meta) == {"git_sha", "backend", "jax_version", "tag",
                         "timestamp"}
    assert meta["tag"] == "pr10"

    stamped = tmp_path / "BENCH_stamped.json"
    stamped.write_text(json.dumps(
        {"meta": meta, "results": {"bench.x": 1.5}}))
    got_meta, got_results = load_artifact(str(stamped))
    assert got_meta == meta
    assert got_results == {"bench.x": 1.5}

    legacy = tmp_path / "BENCH_legacy.json"
    legacy.write_text(json.dumps({"bench.x": 2.5}))
    got_meta, got_results = load_artifact(str(legacy))
    assert got_meta == {}
    assert got_results == {"bench.x": 2.5}


def test_artifact_meta_round_trips_sweep_ledger(tmp_path):
    """The sweep Ledger writes the SAME stamp schema and round-trips it
    through save/load."""
    from repro.search.ledger import Ledger, MemberRecord, make_meta

    led = Ledger(meta=dict(make_meta("pr10-sweep"), rounds=2))
    led.add(MemberRecord(member=0, config={"lr": 0.1}, cohort=0, slot=0))
    p = str(tmp_path / "SWEEP_t.json")
    led.save(p)
    back = Ledger.load(p)
    assert back.meta == led.meta
    assert set(back.meta) >= {"git_sha", "backend", "jax_version", "tag",
                              "timestamp"}
    assert back.meta["tag"] == "pr10-sweep"
    assert back.members[0].member == 0 and back.members[0].slot == 0


# ---------------------------------------------------- guardian event stream
def test_guardian_event_stream_matches_loop_state(tmp_path):
    """A poisoned-batch run emits trip → rollback → backoff → recovery in
    order, with step ids matching the train loop's own guardian state
    (the same scenario as test_guardian_rollback_recovers_poisoned_run:
    poison at data step 12, ckpt_every=5 → trip at 12, rollback to 5)."""
    w_true = _w_true()
    params = _junction()
    opt, train_step = _make_regression_step("jnp")
    total, poison_at = 30, 12
    g = GuardianConfig(health_window=5, lr_backoff=0.5, max_retries=3,
                       min_history=4)
    rec = Recorder(str(tmp_path / "obs.jsonl"))
    res = run(TrainLoopConfig(total, str(tmp_path / "ck"), ckpt_every=5,
                              log_every=5, guardian=g),
              train_step, params, opt.init(params),
              PoisonPipeline(w_true, frozenset([poison_at])),
              log=lambda s: None, recorder=rec)
    rec.close()

    assert res["step"] == total
    trips = res["guardian"]["trips"]
    assert len(trips) == 1

    gev = rec.events("guardian")
    assert [e.action for e in gev] == ["trip", "rollback", "backoff",
                                      "recovery"]
    trip, rollback, backoff, recovery = gev
    # trip carries the discarded step + the loop's own trip record fields
    assert trip.step == trips[0]["step"] == poison_at
    assert trip.detail["data_step"] == poison_at
    assert trip.detail["reason"] == trips[0]["reason"]
    # rollback landed on the latest HEALTHY checkpoint: step 5 (the step-10
    # checkpoint existed but hadn't survived its health window at trip time)
    assert rollback.step == 5
    assert rollback.detail["from_step"] == poison_at
    # backoff halved the lr; recovery is the first adopted step after
    assert backoff.detail["lr_scale"] == res["guardian"]["lr_scale"] == 0.5
    assert recovery.step == rollback.step
    assert recovery.detail["lr_scale"] == 0.5

    # events are causally ordered around the trip in the one timeline
    meta, events = read_events(str(tmp_path / "obs.jsonl"))
    kinds = [(e["kind"], e.get("action")) for e in events]
    i_trip = kinds.index(("guardian", "trip"))
    i_rec = kinds.index(("guardian", "recovery"))
    assert i_trip < i_rec
    # the step before the trip was adopted at the pre-rollback step id;
    # the first step after recovery resumes from the rollback target
    pre = [e for e in events[:i_trip] if e["kind"] == "train.step"]
    post = [e for e in events[i_rec:] if e["kind"] == "train.step"]
    assert pre[-1]["step"] == poison_at - 1
    assert post[0]["step"] == rollback.step
    assert all(e["lr_scale"] == 0.5 for e in post)
    # per-step records carry the guardian-path nonfinite (0 on clean
    # steps, never the NOT_SAMPLED sentinel when the guardian is on)
    assert all(e["nonfinite"] == 0.0 for e in pre + post)

    # checkpoint lifecycle rode the same stream: saves at ckpt_every and
    # promotions only for checkpoints that survived the health window
    saves = [e["step"] for e in events
             if e["kind"] == "checkpoint" and e["action"] == "save"]
    promotes = [e["step"] for e in events
                if e["kind"] == "checkpoint" and e["action"] == "promote"]
    assert 5 in saves and 10 in saves and total in saves
    assert promotes == sorted(promotes) and len(promotes) >= 1
    assert all(s in saves for s in promotes)


def test_train_steps_without_guardian_use_sentinel(tmp_path):
    """Guardian off: the loop never fetched metrics['nonfinite'], so the
    per-step record carries NOT_SAMPLED rather than forcing a D2H
    transfer the step didn't already pay for."""
    params = _junction()
    opt, train_step = _make_regression_step("jnp")
    rec = Recorder()
    run(TrainLoopConfig(6, str(tmp_path / "ck"), ckpt_every=50),
        train_step, params, opt.init(params), PoisonPipeline(_w_true()),
        log=lambda s: None, recorder=rec)
    steps = rec.events("train.step")
    assert len(steps) == 6
    assert all(e.nonfinite == NOT_SAMPLED for e in steps)
    assert all(e.tokens_per_s > 0 for e in steps)


# ------------------------------------------------- sweep quarantine events
def test_sweep_quarantine_event_carries_cohort_slot():
    """A quarantined member's event carries its cohort/slot, matching the
    ledger record — sweep telemetry and ledger share one timeline."""
    N_IN, N_OUT = 128, 64
    rng = np.random.default_rng(0)
    x = rng.standard_normal((256, N_IN)).astype(np.float32)
    t = np.eye(N_OUT, dtype=np.float32)[rng.integers(0, N_OUT, 256)]
    xe = rng.standard_normal((64, N_IN)).astype(np.float32)
    te = np.eye(N_OUT, dtype=np.float32)[rng.integers(0, N_OUT, 64)]

    def spec(lr, i):
        return CandidateSpec(lr=lr, momentum=0.0, density=0.5,
                             layers=(N_IN, N_OUT), block=32, init_seed=i)

    rec = Recorder()
    result = run_sweep([spec(0.05, 0), spec(0.1, 1), spec(float("inf"), 2)],
                       x, t, xe, te,
                       SweepConfig(rounds=2, steps_per_round=4,
                                   batch_size=32, eval_samples=64,
                                   keep_fraction=1.0, engine="jnp",
                                   fused=False),
                       recorder=rec)
    qrec = result.ledger.members[2]
    assert qrec.quarantined_at is not None

    qev = [e for e in rec.events("sweep.round") if e.action == "quarantine"]
    assert len(qev) == 1
    assert qev[0].member == qrec.member == 2
    assert qev[0].cohort == qrec.cohort
    assert qev[0].slot == qrec.slot
    assert qev[0].round == qrec.quarantined_at["round"]
    assert qev[0].detail["step"] == qrec.quarantined_at["step"]

    # every round ranked; the winner event names the ledger's winner
    ranks = [e for e in rec.events("sweep.round") if e.action == "rank"]
    assert [e.round for e in ranks] == [0, 1]
    assert ranks[0].detail["live"] == 2     # quarantined before 1st eval
    winner = [e for e in rec.events("sweep.round") if e.action == "winner"]
    assert len(winner) == 1
    assert winner[0].member == result.ledger.winner().member


# ------------------------------------------------------ serve request spans
def _serve_cfg(engine="jnp"):
    return ArchConfig(
        name="obs-serve", family="dense", n_layers=2, d_model=128,
        n_heads=4, kv_heads=2, head_dim=32, d_ff=256, vocab=128,
        act="silu", max_seq=64, attn_chunk=32, dtype="float32",
        sparsity=SparsityConfig(density=0.25, block=32, where="ffn"),
        engine=engine)


def test_serve_spans_full_lifecycle_compile_once(tmp_path):
    """Every completed request reconstructs a full span (enqueue ≤ admit
    ≤ first token ≤ finish, chunks and tokens counted) AND the engine
    still compiles each step exactly once with the recorder attached —
    the no-retrace half of the no-extra-device-sync contract."""
    cfg = _serve_cfg()
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab, size=(5, 12)).astype(np.int32)
    NEW = 8
    p = str(tmp_path / "serve.jsonl")
    rec = Recorder(p)
    ce = ContinuousEngine(
        cfg, params,
        ServeConfig(max_new_tokens=NEW, eos_token=-1, slots=2, page_size=8,
                    prefill_chunk=8, max_seq=32),
        recorder=rec)
    outs = ce.serve([Request(rid=i, prompt=prompts[i], max_new_tokens=NEW,
                             arrival=2 * i)
                     for i in range(len(prompts))])
    rec.close()

    st = ce.stats
    assert st["decode_traces"] == 1 and st["prefill_traces"] == 1
    assert set(outs) == set(range(5))

    spans = rec.events("serve.span")
    assert sorted(s.rid for s in spans) == list(range(5))
    for s in spans:
        assert s.outcome == "max_new"
        assert (s.enqueue_tick <= s.admit_tick <= s.first_token_tick
                <= s.finish_tick)
    # spans validate through the SAME checker the CI smoke gate uses
    meta, events = read_events(p)
    ev_spans = [e for e in events if e["kind"] == "serve.span"]
    assert len(ev_spans) == 5
    for e in ev_spans:
        assert check_span(e) is None, check_span(e)
        assert e["n_tokens"] == NEW
        assert e["prefill_chunks"] >= 2     # 12-token prompt, 8-wide chunks
        assert e["ttft_s"] >= 0

    # latency dict mirrors the span fields (stats consumers see one truth)
    for rid, v in st["latency"].items():
        assert v["outcome"] == "max_new"
        assert v["n_tokens"] == NEW and v["ttft_s"] >= 0

    # histograms: one ttft per request; itl for the later tokens
    assert rec.hists["serve.ttft_s"].count == 5
    assert rec.hists["serve.itl_s"].count == 5 * (NEW - 1)
    # occupancy gauges refreshed on the final tick: everything drained
    assert rec.gauges["serve.pages_in_use"] == 0
    assert rec.gauges["serve.slots_free"] == 2
    assert rec.counters["serve.finish.max_new"] == 5

    # the report builder renders the run and agrees with the checker
    report = build_report(events)
    assert report["serve"]["requests"] == 5
    assert report["serve"]["outcomes"] == {"max_new": 5}
    assert report["serve"]["ttft_p99_s"] is not None


def test_serve_guard_span_outcome(tmp_path):
    """A guard-terminated request's span carries outcome='guard' and is
    still a valid lifecycle (first token may be missing)."""
    cfg = _serve_cfg()
    params = M.init(cfg, jax.random.PRNGKey(0))
    # poison the final-norm scale so every logit row goes non-finite
    params = jax.tree_util.tree_map_with_path(
        lambda kp, x: (jnp.full_like(x, jnp.nan)
                       if "final" in jax.tree_util.keystr(kp) else x),
        params)
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab, size=(2, 12)).astype(np.int32)
    rec = Recorder()
    ce = ContinuousEngine(
        cfg, params,
        ServeConfig(max_new_tokens=4, eos_token=-1, slots=2, page_size=8,
                    prefill_chunk=8, max_seq=32),
        recorder=rec)
    ce.serve([Request(rid=i, prompt=prompts[i], max_new_tokens=4)
              for i in range(2)])
    spans = rec.events("serve.span")
    assert len(spans) == 2
    for s in spans:
        assert s.outcome == "guard"
        assert s.first_token_tick == -1 and s.ttft_s == -1.0
        d = {f: getattr(s, f) for f in s.__dataclass_fields__}
        d["kind"] = s.KIND
        assert check_span(d) is None
    assert rec.counters["serve.finish.guard"] == 2
    assert ce.nonfinite_terminated == 2


# ------------------------------------------------------ no-retrace contract
def test_fused_train_step_jaxpr_unchanged_by_recorder(tmp_path):
    """The acceptance gate: the jaxpr of the (fused-capable) train step
    is IDENTICAL whether or not a recorder is attached to the loop — the
    recorder adds no traced ops, no new operands, no retraces."""
    params = _junction()
    opt, train_step = _make_regression_step("pallas")
    batch = jax.tree.map(jnp.asarray, next(PoisonPipeline(_w_true())))
    args = (params, opt.init(params), batch, jnp.asarray(0),
            jnp.float32(1.0))
    jaxpr_before = str(jax.make_jaxpr(train_step)(*args))

    rec = Recorder(str(tmp_path / "obs.jsonl"))
    run(TrainLoopConfig(4, str(tmp_path / "ck"), ckpt_every=50,
                        guardian=GuardianConfig()),
        train_step, params, opt.init(params), PoisonPipeline(_w_true()),
        log=lambda s: None, recorder=rec)
    rec.close()
    assert len(rec.events("train.step")) == 4

    jaxpr_after = str(jax.make_jaxpr(train_step)(*args))
    assert jaxpr_after == jaxpr_before
