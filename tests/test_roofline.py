"""HLO walker correctness: scan trip-count multiplication, collectives."""
import jax
import jax.numpy as jnp

from repro.roofline import hlo as H


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_flops_multiplied():
    """cost_analysis counts a while body once; the walker multiplies."""
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = _compile(f, xs, ws)
    one = 2 * 128 * 128 * 128
    ca = c.cost_analysis()
    if isinstance(ca, list):  # jax 0.4.x returns [dict]
        ca = ca[0]
    raw = ca["flops"]
    assert raw < 2 * one                      # XLA undercounts
    costs = H.analyze(c.as_text())
    assert abs(costs.dot_flops - 10 * one) / (10 * one) < 0.05
    assert 10 in costs.trip_counts


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = _compile(f, xs, ws)
    costs = H.analyze(c.as_text())
    one = 2 * 64 * 64 * 64
    assert abs(costs.dot_flops - 12 * one) / (12 * one) < 0.05


def test_unrolled_matches_walker():
    def f(x, w):
        for _ in range(5):
            x = x @ w
        return x
    xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = _compile(f, xs, xs)
    costs = H.analyze(c.as_text())
    one = 2 * 128 ** 3
    assert abs(costs.dot_flops - 5 * one) / (5 * one) < 0.05


def test_collective_bytes_parsed():
    import subprocess, sys, textwrap, os
    from pathlib import Path
    src = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, {src!r})
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.roofline import hlo as H
        from repro.launch.mesh import compat_mesh
        mesh = compat_mesh((4,), ("d",), devices=jax.devices())
        def f(x):
            return jnp.sum(x * 2.0)
        xs = jax.ShapeDtypeStruct((1024, 256), jnp.float32,
                                  sharding=NamedSharding(mesh, P("d", None)))
        c = jax.jit(f).lower(xs).compile()
        costs = H.analyze(c.as_text())
        assert "all-reduce" in costs.coll_detail, costs.coll_detail
        b, n = costs.coll_detail["all-reduce"]
        assert n >= 1 and b >= 4.0, (b, n)     # scalar f32 all-reduce, 2x factor
        print("COLL_OK")
    """)], capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "COLL_OK" in out.stdout


def test_type_bytes():
    assert H.type_bytes("bf16[64,256]{1,0}") == 64 * 256 * 2
    assert H.type_bytes("f32[]") == 4
    assert H.type_bytes("(s32[], bf16[8,8]{1,0})") == 4 + 128
    assert H.type_bytes("pred[16]") == 16
