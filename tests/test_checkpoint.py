"""Checkpointing: atomicity, bitwise restart, elastic reshard, async."""
import json
import os
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as C


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (17, 5)),
            "b": {"c": jnp.arange(7, dtype=jnp.int32),
                  "d": jax.random.normal(jax.random.fold_in(k, 1), (3,),
                                         jnp.bfloat16)}}


def test_bitwise_roundtrip(tmp_path):
    t = _tree()
    C.save(tmp_path, 5, t, extra={"step": 5, "data_state": {"seed": 1, "step": 9}})
    got, extra = C.restore(tmp_path, 5, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        assert jnp.array_equal(a, b)
    assert extra["data_state"] == {"seed": 1, "step": 9}


def test_latest_skips_partial(tmp_path):
    C.save(tmp_path, 1, _tree())
    C.save(tmp_path, 2, _tree(1))
    # a partial (crashed) checkpoint: directory without manifest
    (tmp_path / "step_0000000003").mkdir()
    assert C.latest_step(tmp_path) == 2


def test_checksum_detects_corruption(tmp_path):
    C.save(tmp_path, 1, _tree())
    npz = tmp_path / "step_0000000001" / "arrays.npz"
    data = dict(np.load(npz))
    data["leaf_0"] = data["leaf_0"] + 1.0
    np.savez(npz, **data)
    with pytest.raises(IOError):
        C.restore(tmp_path, 1, _tree())


def test_async_saver(tmp_path):
    s = C.AsyncSaver()
    t = _tree()
    s.save(tmp_path, 7, t, extra={"step": 7})
    s.wait()
    assert C.latest_step(tmp_path) == 7
    got, _ = C.restore(tmp_path, 7, t)
    assert jnp.array_equal(jax.tree.leaves(got)[0], jax.tree.leaves(t)[0])


def test_elastic_reshard_subprocess(tmp_path):
    """Save on an 8-device mesh, restore onto a 4-device mesh (elastic)."""
    import subprocess, sys, textwrap
    script = textwrap.dedent(f"""
        import os, sys
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + sys.argv[1]
        sys.path.insert(0, {str(Path(__file__).resolve().parents[1] / 'src')!r})
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as C
        n = int(sys.argv[1])
        from repro.launch.mesh import compat_mesh
        mesh = compat_mesh((n,), ("data",), devices=jax.devices())
        sh = NamedSharding(mesh, P("data"))
        t = {{"w": jax.device_put(jnp.arange(32, dtype=jnp.float32), sh)}}
        if sys.argv[2] == "save":
            C.save({str(tmp_path)!r}, 1, t)
        else:
            got, _ = C.restore({str(tmp_path)!r}, 1, t, shardings={{"w": sh}})
            assert got["w"].sharding.num_devices == n, got["w"].sharding
            assert jnp.array_equal(got["w"], jnp.arange(32, dtype=jnp.float32))
            print("RESHARD_OK")
    """)
    env = dict(os.environ)
    r1 = subprocess.run([sys.executable, "-c", script, "8", "save"],
                        capture_output=True, text=True, env=env)
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = subprocess.run([sys.executable, "-c", script, "4", "load"],
                        capture_output=True, text=True, env=env)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "RESHARD_OK" in r2.stdout
