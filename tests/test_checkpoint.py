"""Checkpointing: atomicity, bitwise restart, elastic reshard, async."""
import json
import os
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as C


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (17, 5)),
            "b": {"c": jnp.arange(7, dtype=jnp.int32),
                  "d": jax.random.normal(jax.random.fold_in(k, 1), (3,),
                                         jnp.bfloat16)}}


def test_bitwise_roundtrip(tmp_path):
    t = _tree()
    C.save(tmp_path, 5, t, extra={"step": 5, "data_state": {"seed": 1, "step": 9}})
    got, extra = C.restore(tmp_path, 5, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        assert jnp.array_equal(a, b)
    assert extra["data_state"] == {"seed": 1, "step": 9}


def test_latest_skips_partial(tmp_path):
    C.save(tmp_path, 1, _tree())
    C.save(tmp_path, 2, _tree(1))
    # a partial (crashed) checkpoint: directory without manifest
    (tmp_path / "step_0000000003").mkdir()
    assert C.latest_step(tmp_path) == 2


def test_checksum_detects_corruption(tmp_path):
    C.save(tmp_path, 1, _tree())
    npz = tmp_path / "step_0000000001" / "arrays.npz"
    data = dict(np.load(npz))
    data["leaf_0"] = data["leaf_0"] + 1.0
    np.savez(npz, **data)
    with pytest.raises(IOError):
        C.restore(tmp_path, 1, _tree())


def test_async_saver(tmp_path):
    s = C.AsyncSaver()
    t = _tree()
    s.save(tmp_path, 7, t, extra={"step": 7})
    s.wait()
    assert C.latest_step(tmp_path) == 7
    got, _ = C.restore(tmp_path, 7, t)
    assert jnp.array_equal(jax.tree.leaves(got)[0], jax.tree.leaves(t)[0])


def test_restore_latest_falls_back_past_corruption(tmp_path):
    """A corrupted newest checkpoint must not kill auto-resume: fallback
    to the next-newest verifiable one, logged."""
    t = _tree()
    C.save(tmp_path, 1, t, extra={"step": 1})
    C.save(tmp_path, 2, _tree(1), extra={"step": 2})
    npz = tmp_path / "step_0000000002" / "arrays.npz"
    npz.write_bytes(npz.read_bytes()[:50])          # torn write
    logs = []
    s, tree, extra = C.restore_latest(tmp_path, t, log=logs.append)
    assert s == 1 and extra["step"] == 1
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(tree)):
        assert jnp.array_equal(a, b)
    assert any("falling back" in l for l in logs)
    # every candidate corrupt -> (None, None, None), no exception
    (tmp_path / "step_0000000001" / "arrays.npz").write_bytes(b"junk")
    s, tree, extra = C.restore_latest(tmp_path, t, log=logs.append)
    assert s is None and tree is None and extra is None


def test_full_checksum_catches_tail_corruption(tmp_path):
    """Head-mode digests only the first MiB per leaf: tail corruption in
    a >1MiB leaf slips through.  full_checksum=True catches it."""
    big = {"w": jnp.arange(600_000, dtype=jnp.float32)}   # 2.4 MB leaf

    def tamper(d):
        npz = d / "step_0000000001" / "arrays.npz"
        data = {k: v.copy() for k, v in np.load(npz).items()}
        data["leaf_0"][-1] += 1.0
        np.savez(npz, **data)

    C.save(tmp_path / "head", 1, big)
    tamper(tmp_path / "head")
    got, _ = C.restore(tmp_path / "head", 1, big)   # head digest misses it
    assert float(np.asarray(got["w"])[-1]) != 599_999.0

    C.save(tmp_path / "full", 1, big, full_checksum=True)
    tamper(tmp_path / "full")
    with pytest.raises(IOError):
        C.restore(tmp_path / "full", 1, big)


def test_kill_between_npz_write_and_rename(tmp_path, monkeypatch):
    """Hard kill after the npz/manifest writes but before the rename (no
    cleanup runs): the leftover .tmp dir must not shadow or corrupt the
    previous checkpoint."""
    t = _tree()
    C.save(tmp_path, 1, t, extra={"step": 1})

    def die(*a, **k):
        raise KeyboardInterrupt("simulated kill")

    monkeypatch.setattr(C.os, "rename", die)
    monkeypatch.setattr(C.shutil, "rmtree", lambda *a, **k: None)
    with pytest.raises(KeyboardInterrupt):
        C.save(tmp_path, 2, _tree(1), extra={"step": 2})
    monkeypatch.undo()

    leftovers = [d for d in tmp_path.iterdir() if d.name.startswith(".tmp_")]
    assert leftovers, "kill before rename should leave the tmp dir behind"
    assert C.latest_step(tmp_path) == 1
    s, tree, extra = C.restore_latest(tmp_path, t)
    assert s == 1 and extra["step"] == 1
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(tree)):
        assert jnp.array_equal(a, b)


def test_async_save_failure_surfaces_on_wait(tmp_path, monkeypatch):
    """A crash inside an in-flight AsyncSaver.save must surface on wait()
    and leave latest_step pointing at the previous good checkpoint —
    and the saver must stay usable afterwards."""
    s = C.AsyncSaver()
    s.save(tmp_path, 1, _tree(), extra={"step": 1})
    s.wait()

    def die(*a, **k):
        raise IOError("simulated disk failure")

    monkeypatch.setattr(C.np, "savez", die)
    s.save(tmp_path, 2, _tree(1), extra={"step": 2})
    with pytest.raises(IOError):
        s.wait()
    monkeypatch.undo()
    assert C.latest_step(tmp_path) == 1
    s.save(tmp_path, 3, _tree(2), extra={"step": 3})
    s.wait()
    assert C.latest_step(tmp_path) == 3


def test_gc_keeps_healthy_floor(tmp_path):
    """Retention never deletes the latest healthy mark: steps 1..5,
    step 2 healthy, keep_last_k=2 -> {2, 4, 5} remain."""
    for st in range(1, 6):
        C.save(tmp_path, st, _tree(st))
    C.mark_healthy(tmp_path, 2)
    assert C.is_healthy(tmp_path, 2)
    removed = C.gc_checkpoints(tmp_path, keep_last_k=2)
    assert removed == [1, 3]
    assert C.complete_steps(tmp_path) == [2, 4, 5]
    assert C.latest_healthy_step(tmp_path) == 2
    # idempotent: nothing further to delete
    assert C.gc_checkpoints(tmp_path, keep_last_k=2) == []


def test_elastic_reshard_subprocess(tmp_path):
    """Save on an 8-device mesh, restore onto a 4-device mesh (elastic)."""
    import subprocess, sys, textwrap
    script = textwrap.dedent(f"""
        import os, sys
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + sys.argv[1]
        sys.path.insert(0, {str(Path(__file__).resolve().parents[1] / 'src')!r})
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as C
        n = int(sys.argv[1])
        from repro.launch.mesh import compat_mesh
        mesh = compat_mesh((n,), ("data",), devices=jax.devices())
        sh = NamedSharding(mesh, P("data"))
        t = {{"w": jax.device_put(jnp.arange(32, dtype=jnp.float32), sh)}}
        if sys.argv[2] == "save":
            C.save({str(tmp_path)!r}, 1, t)
        else:
            got, _ = C.restore({str(tmp_path)!r}, 1, t, shardings={{"w": sh}})
            assert got["w"].sharding.num_devices == n, got["w"].sharding
            assert jnp.array_equal(got["w"], jnp.arange(32, dtype=jnp.float32))
            print("RESHARD_OK")
    """)
    env = dict(os.environ)
    r1 = subprocess.run([sys.executable, "-c", script, "8", "save"],
                        capture_output=True, text=True, env=env)
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = subprocess.run([sys.executable, "-c", script, "4", "load"],
                        capture_output=True, text=True, env=env)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "RESHARD_OK" in r2.stdout
