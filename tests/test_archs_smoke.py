"""Per-assigned-architecture smoke tests: reduced config, one forward +
train step + decode step on CPU; asserts shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.configs.base import SHAPES, valid_cells, long_context_ok
from repro.launch.specs import concrete_batch
from repro.models import model as M
from repro.optim import adam, constant_schedule
from repro.train.steps import make_train_step

ARCHS = list(registry.ARCHS)


def _batch(cfg, B=2, S=64):
    return concrete_batch(cfg, B, S, jax.random.PRNGKey(7))


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = registry.get(arch).reduced()
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = M.loss_fn(cfg, params, batch)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    logits, _, _ = M.forward(cfg, params, batch)
    assert logits.shape[-1] == cfg.vocab
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = registry.get(arch).reduced()
    params = M.init(cfg, jax.random.PRNGKey(0))
    opt = adam(constant_schedule(1e-3))
    st = opt.init(params)
    ts = make_train_step(cfg, opt, donate=False)  # params compared after
    batch = _batch(cfg)
    p2, st2, metrics = ts(params, st, batch, jnp.asarray(0))
    assert jnp.isfinite(metrics["loss"])
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b))
                     if jnp.issubdtype(a.dtype, jnp.inexact) else False,
                     params, p2))
    assert moved, f"{arch}: no parameter changed after a step"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = registry.get(arch).reduced()
    params = M.init(cfg, jax.random.PRNGKey(0))
    B = 2
    cache = M.make_cache(cfg, B, 96)
    logits, cache2 = M.decode_step(cfg, params, cache,
                                   jnp.zeros((B, 1), jnp.int32),
                                   jnp.asarray(3))
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCHS)
def test_sparse_variant_train_step(arch):
    """The paper's technique must be applicable (or cleanly inert) on every
    assigned architecture (DESIGN.md Sec. 4)."""
    from repro.core.sparsity import SparsityConfig
    cfg = registry.get(arch).reduced().with_sparsity(
        SparsityConfig(density=0.5, block=32, where="ffn"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    # at least one sparse junction must exist for every family
    n_sparse = len([k for k in jax.tree_util.tree_leaves_with_path(params)
                    if "idx" in jax.tree_util.keystr(k[0])])
    assert n_sparse > 0, f"{arch}: technique not applied anywhere"
    loss, _ = M.loss_fn(cfg, params, _batch(cfg))
    assert jnp.isfinite(loss)


def test_cell_validity_table():
    """long_500k runs exactly for the sub-quadratic archs."""
    runs_long = {a for a in ARCHS
                 if any(s.name == "long_500k"
                        for s in valid_cells(registry.get(a)))}
    assert runs_long == {"falcon-mamba-7b", "zamba2-2.7b",
                         "llava-next-mistral-7b"}
    total = sum(len(list(valid_cells(registry.get(a)))) for a in ARCHS)
    assert total == 33  # 10*4 minus 7 full-attention long_500k skips
