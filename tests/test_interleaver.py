"""Property tests for the clash-free interleavers and block patterns."""
import numpy as np
import pytest
from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core import interleaver as il


@given(st.sampled_from([32, 64, 128, 256]), st.sampled_from([4, 8, 16, 32]),
       st.integers(0, 10))
@settings(max_examples=40, deadline=None)
def test_affine_clash_free(w_mult, z, seed):
    W = w_mult * z
    pi = il.affine_interleaver(W, z, seed)
    assert sorted(pi.tolist()) == list(range(W)), "must be a permutation"
    assert il.is_clash_free(pi, z)


@given(st.sampled_from([64, 128, 512]), st.sampled_from([8, 16, 32]),
       st.integers(0, 10))
@settings(max_examples=30, deadline=None)
def test_sv_ss_clash_free_permutation(w_mult, z, seed):
    W = w_mult * z
    pi = il.sv_ss_interleaver(W, z, seed)
    assert sorted(pi.tolist()) == list(range(W))
    assert il.is_clash_free(pi, z)


@given(st.integers(2, 24), st.integers(2, 24), st.integers(0, 5))
@settings(max_examples=60, deadline=None)
def test_block_pattern_balanced(nib, nob, seed):
    # pick a fan-in that admits integral fan-out
    import math
    step = nib // math.gcd(nob, nib)
    kb = min(nib, max(step, (nib // 2 // step) * step or step))
    idx = il.block_circulant_pattern(nib, nob, kb, seed=seed)
    fan_in, fan_out = il.pattern_fan_counts(idx, nib)
    assert np.all(fan_in == kb), "fixed fan-in per output block"
    assert np.all(fan_out == nob * kb // nib), "fixed fan-out per input block"
    for r in range(nob):
        assert len(np.unique(idx[r])) == kb, "no duplicate inputs per output"


def test_reverse_pattern_roundtrip():
    idx = il.block_circulant_pattern(16, 8, 4, seed=3)
    rev_ob, rev_t, rev_cnt = il.reverse_block_pattern(idx, 16)
    # every (ob, t) edge appears exactly once among the valid reverse slots
    edges = set()
    for ib in range(16):
        for f in range(int(rev_cnt[ib])):
            ob, t = int(rev_ob[ib, f]), int(rev_t[ib, f])
            assert idx[ob, t] == ib
            edges.add((ob, t))
    assert len(edges) == 8 * 4
    assert int(rev_cnt.sum()) == 8 * 4


def test_reverse_pattern_strict_rejects_unbalanced():
    idx = np.array([[0, 1], [0, 1]], dtype=np.int32)  # block 2,3 unused
    with pytest.raises(ValueError):
        il.reverse_block_pattern(idx, 4, strict=True)


def test_ragged_pattern_near_balanced():
    """Coprime dims (qwen2 FFN: 64 in-blocks, 231 out-blocks): fan-out is
    balanced to +-1, fan-in stays exact — no density quantization."""
    idx = il.block_circulant_pattern(64, 231, 8, seed=0)
    assert idx.shape == (231, 8)
    for r in range(231):
        assert len(np.unique(idx[r])) == 8
    counts = np.bincount(idx.reshape(-1), minlength=64)
    assert counts.max() - counts.min() <= 1
    rev_ob, rev_t, rev_cnt = il.reverse_block_pattern(idx, 64)
    assert int(rev_cnt.sum()) == 231 * 8
