"""Population engine (ISSUE 5): exploration riding the E axis.

The acceptance contract: a population of E >= 4 MNIST candidates with
DISTINCT per-member learning rates trains in one fused E-batched step
whose per-member losses and parameters match E independently-trained
single models (SGD ± momentum, including the fused BP+UP path indexing
the per-unit [E, 2] hyp table), and the successive-halving scheduler
runs a density x lr sweep end to end producing a ledger that names a
winning config.  Plus (ISSUE 7): Adam populations — distinct per-member
lr/b1/weight_decay riding the [E, HYP_K] registry table with (m, v)
slot pairs — fused vs two-pass, the (2,)/(HYP_K,) broadcast vs
explicit-table equivalence at the ops level, opt as a structural cohort
axis, cohort bucketing rules, in-place prune freezing, and ledger JSON
round-tripping.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SweepConfig
from repro.core import sparse_linear as sl
from repro.core.sparsity import make_block_pattern
from repro.data.mnist import paper_dataset
from repro.kernels import ops
from repro.search import (CandidateSpec, Ledger, bucket, hyp_table,
                          init_population, make_population_step,
                          member_slice, run_sweep, structure_key)
from repro.search import population as pop


def _mnist_batch(m, n_in, n_out, seed=0):
    """A real (synthetic-MNIST) batch: x sliced to the input width, one-
    hot targets zero-padded to the output width."""
    x, t, _ = paper_dataset(n=m, seed=seed)
    tp = np.zeros((m, n_out), np.float32)
    tp[:, :t.shape[1]] = t[:, :n_out]
    return jnp.asarray(x[:, :n_in]), jnp.asarray(tp)


def _specs(E=4, momentum=0.0, layers=(256, 128, 32), block=32, density=0.5):
    lrs = [0.02, 0.05, 0.08, 0.12, 0.15, 0.2][:E]
    return [CandidateSpec(lr=lr, momentum=momentum, density=density,
                          layers=layers, block=block, init_seed=i)
            for i, lr in enumerate(lrs)]


def _single_fused_step(params, mom, hyp_pair, x, t, act="sigmoid"):
    """One fused BP+UP train step of a standalone single model (4-D
    squeeze path) — the independent-training reference."""
    aug = sl.inject_update_ctx(params, mom, hyp_pair)

    def loss_fn(aug):
        y = x
        for layer in aug:
            y = sl.apply(layer, y, engine="pallas", act=act)
        return jnp.mean(jnp.square(y - t))

    loss, grads = jax.value_and_grad(loss_fn, allow_int=True)(aug)
    new_p, new_m = [], []
    for g, p, m in zip(grads, params, mom):
        lp, lm = dict(p), dict(m)
        for k, mk in sl.FUSED_MOM.items():
            if k in p and not isinstance(p[k], dict):
                lp[k] = g[k]
                lm[k] = g[mk]
        new_p.append(lp)
        new_m.append(lm)
    return new_p, new_m, loss


def _single_jnp_step(params, mom, lr, beta, x, t, act="sigmoid"):
    """Two-pass jnp reference single-model step (materialized grads,
    per-leaf SGD+momentum)."""
    def loss_fn(params):
        y = x
        for layer in params:
            y = sl.apply(layer, y, engine="jnp", act=act)
        return jnp.mean(jnp.square(y - t))

    loss, grads = jax.value_and_grad(loss_fn, allow_int=True)(params)
    new_p, new_m = [], []
    for g, p, m in zip(grads, params, mom):
        lp, lm = dict(p), dict(m)
        for k in ("w", "b"):
            mv = beta * m[k] + g[k].astype(jnp.float32)
            lp[k] = (p[k].astype(jnp.float32) - lr * mv).astype(p[k].dtype)
            lm[k] = mv
        new_p.append(lp)
        new_m.append(lm)
    return new_p, new_m, loss


# --------------------------------------------------------------- acceptance
@pytest.mark.parametrize("momentum", [0.0, 0.9])
def test_population_fused_matches_independent_singles(momentum):
    """Acceptance: E=4 candidates with distinct lrs advance in fused
    E-batched steps (per-unit [E, 2] hyp table in the update kernels)
    exactly as E independently-trained single models do through the 4-D
    squeeze path — losses and parameters, SGD +- momentum, 3 steps."""
    specs = _specs(momentum=momentum)
    E = len(specs)
    params = init_population(jax.random.PRNGKey(0), specs)
    x, t = _mnist_batch(48, specs[0].layers[0], specs[0].layers[-1])

    step = make_population_step(engine="pallas", fused=True, donate=False)
    p, m = params, pop.init_momentum(params)
    hyp, mask = hyp_table(specs), jnp.ones((E,), jnp.float32)
    pop_losses = []
    for _ in range(3):
        p, m, losses = step(p, m, hyp, mask, x, t)
        pop_losses.append(np.asarray(losses))

    for e, spec in enumerate(specs):
        sp = member_slice(params, e)
        sm = pop.init_momentum(sp)
        for i in range(3):
            sp, sm, loss = _single_fused_step(sp, sm, hyp[e], x, t)
            np.testing.assert_allclose(float(loss), pop_losses[i][e],
                                       rtol=2e-5,
                                       err_msg=f"member {e} step {i}")
        for li in range(len(sp)):
            np.testing.assert_allclose(
                np.asarray(p[li]["w"][e]), np.asarray(sp[li]["w"]),
                rtol=1e-4, atol=1e-5, err_msg=f"member {e} layer {li} w")
            np.testing.assert_allclose(
                np.asarray(p[li]["b"][e]), np.asarray(sp[li]["b"]),
                rtol=1e-4, atol=1e-5, err_msg=f"member {e} layer {li} b")


def test_population_mnist_shape_fused_vs_independent_jnp():
    """The paper-shape population (1024 -> 512 -> 128, bs=128, E=4,
    distinct lrs + momentum) through the fused pallas path vs E
    independent two-pass jnp single models — cross-engine, cross-grain
    parity on real (synthetic-MNIST) data."""
    specs = _specs(momentum=0.9, layers=(1024, 512, 128), block=128,
                   density=0.25)
    E = len(specs)
    params = init_population(jax.random.PRNGKey(1), specs)
    x, t = _mnist_batch(64, 1024, 128)

    step = make_population_step(engine="pallas", fused=True, donate=False)
    p, m = params, pop.init_momentum(params)
    hyp, mask = hyp_table(specs), jnp.ones((E,), jnp.float32)
    pop_losses = []
    for _ in range(2):
        p, m, losses = step(p, m, hyp, mask, x, t)
        pop_losses.append(np.asarray(losses))

    for e, spec in enumerate(specs):
        sp = member_slice(params, e)
        sm = pop.init_momentum(sp)
        for i in range(2):
            sp, sm, loss = _single_jnp_step(sp, sm, spec.lr, spec.momentum,
                                            x, t)
            np.testing.assert_allclose(float(loss), pop_losses[i][e],
                                       rtol=1e-4,
                                       err_msg=f"member {e} step {i}")
        for li in range(len(sp)):
            np.testing.assert_allclose(
                np.asarray(p[li]["w"][e]), np.asarray(sp[li]["w"]),
                rtol=1e-3, atol=1e-4, err_msg=f"member {e} layer {li} w")


def test_population_two_pass_matches_fused():
    """Engine parity of the population step itself: jnp two-pass (per-
    member lr broadcast over materialized grads) == pallas fused."""
    specs = _specs(momentum=0.9)
    E = len(specs)
    params = init_population(jax.random.PRNGKey(2), specs)
    x, t = _mnist_batch(32, specs[0].layers[0], specs[0].layers[-1])
    hyp, mask = hyp_table(specs), jnp.ones((E,), jnp.float32)

    sf = make_population_step(engine="pallas", fused=True, donate=False)
    sj = make_population_step(engine="jnp", donate=False)
    pf, mf = params, pop.init_momentum(params)
    pj, mj = params, pop.init_momentum(params)
    for _ in range(2):
        pf, mf, lf = sf(pf, mf, hyp, mask, x, t)
        pj, mj, lj = sj(pj, mj, hyp, mask, x, t)
        np.testing.assert_allclose(np.asarray(lf), np.asarray(lj), rtol=1e-4)
    for li in range(len(pf)):
        np.testing.assert_allclose(np.asarray(pf[li]["w"]),
                                   np.asarray(pj[li]["w"]),
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(mf[li]["w"]),
                                   np.asarray(mj[li]["w"]),
                                   rtol=1e-3, atol=1e-4)


def test_population_adam_fused_matches_two_pass():
    """Acceptance (ISSUE 7): an Adam population with DISTINCT per-member
    lr / b1 / weight_decay rides the same [E, HYP_K] contract — pallas
    fused (in-kernel m/v slot pairs) == jnp two-pass reference over 3
    steps, the bias-correction time stamped into COL_T each step."""
    from repro.kernels import block_sparse_matmul as bsm
    specs = [CandidateSpec(lr=lr, momentum=b1, opt="adam", weight_decay=wd,
                           density=0.5, layers=(256, 128, 32), block=32,
                           init_seed=i)
             for i, (lr, b1, wd) in enumerate(
                 [(1e-3, 0.9, 0.0), (2e-3, 0.8, 0.01),
                  (5e-4, 0.95, 0.0), (1e-3, 0.85, 0.02)])]
    E = len(specs)
    params = init_population(jax.random.PRNGKey(9), specs)
    x, t = _mnist_batch(32, specs[0].layers[0], specs[0].layers[-1])
    hyp, mask = hyp_table(specs), jnp.ones((E,), jnp.float32)

    sf = make_population_step(engine="pallas", fused=True, donate=False)
    sj = make_population_step(engine="jnp", donate=False)
    pf = pj = params
    slf = slj = pop.init_slots(params, specs)
    assert len(slf) == 2                      # (mom, vel)
    for i in range(3):
        hyp_t = hyp.at[:, bsm.COL_T].set(jnp.float32(i + 1))
        pf, slf, lf = sf(pf, slf, hyp_t, mask, x, t)
        pj, slj, lj = sj(pj, slj, hyp_t, mask, x, t)
        np.testing.assert_allclose(np.asarray(lf), np.asarray(lj), rtol=1e-4)
    for li in range(len(pf)):
        for k in ("w", "b"):
            np.testing.assert_allclose(np.asarray(pf[li][k]),
                                       np.asarray(pj[li][k]),
                                       rtol=1e-3, atol=1e-5)
        for s_f, s_j in zip(slf, slj):
            np.testing.assert_allclose(np.asarray(s_f[li]["w"]),
                                       np.asarray(s_j[li]["w"]),
                                       rtol=1e-3, atol=1e-5)


# ------------------------------------------------------- [E, k] hyp table
def test_hyp_pair_broadcasts_to_table():
    """A shared (2,) pair on 5-D expert weights computes exactly what the
    explicitly tiled [E, 2] table does."""
    bs, E = 32, 3
    pat = make_block_pattern(8 * bs, 4 * bs, 0.5, bs)
    args = tuple(map(jnp.asarray, (pat.idx, pat.rev_ob, pat.rev_t,
                                   pat.rev_cnt)))
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(ks[0], (E, 32, 8 * bs))
    w = jax.random.normal(ks[1], (E, pat.n_out_blocks, pat.fan_in_blocks,
                                  bs, bs)) * 0.1
    co = jax.random.normal(ks[2], (E, 32, 4 * bs))
    mom = jnp.full(w.shape, 0.02, jnp.float32)
    pair = jnp.asarray([0.05, 0.9], jnp.float32)

    def upd(hyp):
        def loss(w, m):
            y = ops.junction_train_update(x, w, *args, act="relu", hyp=hyp,
                                          mom=m)
            return jnp.sum(y * co)
        return jax.grad(loss, (0, 1))(w, mom)

    nw1, nm1 = upd(pair)
    nw2, nm2 = upd(jnp.tile(pair, (E, 1)))
    np.testing.assert_array_equal(np.asarray(nw1), np.asarray(nw2))
    np.testing.assert_array_equal(np.asarray(nm1), np.asarray(nm2))


def test_hyp_row_broadcasts_to_table():
    """A shared (HYP_K,) registry row on 5-D expert weights with Adam
    slots computes exactly what the explicitly tiled [E, HYP_K] table
    does."""
    bs, E = 32, 3
    pat = make_block_pattern(8 * bs, 4 * bs, 0.5, bs)
    args = tuple(map(jnp.asarray, (pat.idx, pat.rev_ob, pat.rev_t,
                                   pat.rev_cnt)))
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    x = jax.random.normal(ks[0], (E, 32, 8 * bs))
    w = jax.random.normal(ks[1], (E, pat.n_out_blocks, pat.fan_in_blocks,
                                  bs, bs)) * 0.1
    co = jax.random.normal(ks[2], (E, 32, 4 * bs))
    mom = jnp.full(w.shape, 0.02, jnp.float32)
    vel = jnp.full(w.shape, 0.003, jnp.float32)
    #                 lr,   b1,  b2,   eps,  wd,  t,   gs
    row = jnp.asarray([1e-3, 0.9, 0.95, 1e-8, 0.01, 2.0, 1.0], jnp.float32)

    def upd(hyp):
        def loss(w, m, v):
            y = ops.junction_train_update(x, w, *args, act="relu", hyp=hyp,
                                          mom=m, vel=v)
            return jnp.sum(y * co)
        return jax.grad(loss, (0, 1, 2))(w, mom, vel)

    for a, b in zip(upd(row), upd(jnp.tile(row, (E, 1)))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hyp_bad_shape_raises():
    bs, E = 32, 3
    pat = make_block_pattern(4 * bs, 2 * bs, 0.5, bs)
    args = tuple(map(jnp.asarray, (pat.idx, pat.rev_ob, pat.rev_t,
                                   pat.rev_cnt)))
    x = jnp.zeros((E, 16, 4 * bs))
    w = jnp.zeros((E, pat.n_out_blocks, pat.fan_in_blocks, bs, bs))
    with pytest.raises(ValueError,
                       match=r"per-unit \[E=3, 2\] / \[E=3, 7\] table"):
        ops.junction_train_update(x, w, *args,
                                  hyp=jnp.zeros((2, 2), jnp.float32))
    # a single (4-D) junction cannot take a multi-row table
    with pytest.raises(ValueError, match="per-unit"):
        ops.junction_train_update(x[0], w[0], *args,
                                  hyp=jnp.zeros((3, 2), jnp.float32))


# --------------------------------------------------------- cohort bucketing
def test_cohort_bucketing_rules():
    """Same quantized structure -> one cohort; any structural difference
    splits; candidate order is preserved as slot order."""
    base = dict(layers=(256, 128, 32), block=32)
    specs = [
        CandidateSpec(lr=0.1, density=0.50, **base),            # kb=(4,2)
        CandidateSpec(lr=0.2, density=0.55, **base),            # same kb
        CandidateSpec(lr=0.1, density=0.25, **base),            # kb=(2,1)
        CandidateSpec(lr=0.1, density=0.50, layers=(256, 64, 32),
                      block=32),                                # widths
        CandidateSpec(lr=0.1, density=0.50, seed=7, **base),    # pattern
        CandidateSpec(lr=0.3, density=0.52, momentum=0.9,
                      init_seed=9, **base),                     # same kb
    ]
    cohorts = bucket(specs)
    by_ids = {c.member_ids: c for c in cohorts}
    assert (0, 1, 5) in by_ids          # densities quantizing to one kb
    assert (2,) in by_ids and (3,) in by_ids and (4,) in by_ids
    c = by_ids[(0, 1, 5)]
    assert [s.lr for s in c.specs] == [0.1, 0.2, 0.3]
    assert structure_key(specs[0]) == structure_key(specs[5])
    assert structure_key(specs[0]) != structure_key(specs[2])


def test_opt_is_structural_cohort_axis():
    """opt splits cohorts (the slot layout and the kernels' optimizer
    switch are static per launch) and init_slots refuses a mixed-kind
    spec list outright."""
    import dataclasses

    base = dict(lr=0.1, density=0.5, layers=(256, 128, 32), block=32)
    s_sgd = CandidateSpec(**base)
    s_adam = CandidateSpec(opt="adam", momentum=0.9, **base)
    assert structure_key(s_sgd) != structure_key(s_adam)
    assert len(bucket([s_sgd, s_adam])) == 2
    params = init_population(
        jax.random.PRNGKey(0),
        [s_sgd, dataclasses.replace(s_sgd, init_seed=1)])
    with pytest.raises(ValueError, match="optimizer kinds"):
        pop.init_slots(params, [s_sgd, s_adam])


def test_member_slice_recovers_standalone_init():
    """Each stacked slot is bit-for-bit the standalone single-model init
    for its spec (what makes the parity tests non-tautological)."""
    specs = _specs(E=3)
    key = jax.random.PRNGKey(5)
    params = init_population(key, specs)
    for e, s in enumerate(specs):
        solo = pop._init_member(jax.random.fold_in(key, s.init_seed), s)
        for li in range(len(solo)):
            np.testing.assert_array_equal(
                np.asarray(params[li]["w"][e]), np.asarray(solo[li]["w"]))
            np.testing.assert_array_equal(
                np.asarray(params[li]["idx"]), np.asarray(solo[li]["idx"]))


def test_mixed_structure_population_refused():
    specs = _specs(E=2) + [CandidateSpec(lr=0.1, density=0.25,
                                         layers=(256, 128, 32), block=32)]
    with pytest.raises(ValueError, match="share structure"):
        init_population(jax.random.PRNGKey(0), specs)


# -------------------------------------------------------------- slot prune
@pytest.mark.parametrize("engine,fused", [("jnp", False), ("pallas", True)])
def test_pruned_slot_frozen_in_place(engine, fused):
    """Zero mask entry + zero hyp row freezes that member exactly (w, b
    AND momentum stop moving) while the survivors keep training — the
    fixed-shape prune of the scheduler, on both execution paths."""
    specs = _specs(momentum=0.9)
    E = len(specs)
    params = init_population(jax.random.PRNGKey(3), specs)
    x, t = _mnist_batch(32, specs[0].layers[0], specs[0].layers[-1])
    step = make_population_step(engine=engine, fused=fused, donate=False)
    hyp = hyp_table(specs)
    mom = pop.init_momentum(params)
    # one live step so momentum is nonzero when the prune lands
    p1, m1, _ = step(params, mom, hyp, jnp.ones((E,)), x, t)
    pruned = 1
    mask = jnp.ones((E,)).at[pruned].set(0.0)
    hyp2 = hyp.at[pruned].set(0.0)
    p2, m2, losses = step(p1, m1, hyp2, mask, x, t)
    assert losses.shape == (E,)         # eval stays vectorized over all slots
    for li in range(len(p2)):
        np.testing.assert_array_equal(np.asarray(p2[li]["w"][pruned]),
                                      np.asarray(p1[li]["w"][pruned]))
        np.testing.assert_array_equal(np.asarray(p2[li]["b"][pruned]),
                                      np.asarray(p1[li]["b"][pruned]))
        for e in range(E):
            if e != pruned:
                assert not np.array_equal(np.asarray(p2[li]["w"][e]),
                                          np.asarray(p1[li]["w"][e]))


# ---------------------------------------------------- scheduler + ledger
def test_run_sweep_end_to_end(tmp_path):
    """Acceptance: a density x lr successive-halving sweep runs end to
    end and the ledger names a winning config; halving prunes globally
    across cohorts; the JSON artifact round-trips."""
    specs = [CandidateSpec(lr=lr, density=d, layers=(256, 128, 32),
                           block=32, init_seed=i)
             for i, (d, lr) in enumerate((d, lr)
                                         for d in (0.25, 0.5)
                                         for lr in (0.05, 0.2))]
    x, t, _ = paper_dataset(n=160, seed=0)
    x = x[:, :256]
    cfg = SweepConfig(rounds=2, steps_per_round=2, batch_size=32,
                      eval_samples=32, engine="jnp")
    result = run_sweep(specs, x[:128], t[:128], x[128:], t[128:], cfg,
                       tag="test")
    led = result.ledger
    assert len(led.members) == 4
    w = led.winner()
    assert w is not None and w.config["lr"] in (0.05, 0.2)
    assert w.pruned_at is None and w.rounds_survived == 2
    # halving: 2 of 4 pruned after round 0, each with one fewer round
    pruned = [m for m in led.members if m.pruned_at is not None]
    assert len(pruned) == 2 and all(m.pruned_at == 0 for m in pruned)
    assert all(m.rounds_survived == 1 for m in pruned)
    live = [m for m in led.members if m.pruned_at is None]
    assert all(len(m.loss_curve) == 4 for m in live)      # 2 rounds x 2 steps
    assert all(len(m.loss_curve) == 2 for m in pruned)    # round 0 only
    # winner's standalone params come back at the right shapes
    wp = result.winning_params()
    assert wp is not None and wp[0]["w"].ndim == 4

    # JSON round-trip (the meta.tag contract shared with BENCH artifacts)
    path = tmp_path / "SWEEP_test.json"
    led.save(str(path))
    led2 = Ledger.load(str(path))
    assert led2.meta["tag"] == "test"
    assert led2.meta["git_sha"]        # commit-attributable, like BENCH meta
    assert led2.winner().member == w.member
    assert led2.winner().config == w.config
    raw = json.loads(path.read_text())
    assert raw["winner"]["member"] == w.member


def test_run_sweep_adam_lr_x_b1_fused():
    """Acceptance (ISSUE 7): a FUSED Adam lr × b1 sweep through the
    scheduler — per-member Adam rows in the [E, HYP_K] table, COL_T
    stamped each step, quarantine riding the same in-kernel health
    flags — and the ledger names a winner."""
    specs = [CandidateSpec(lr=lr, momentum=b1, opt="adam", density=0.5,
                           layers=(256, 128, 32), block=32, init_seed=i)
             for i, (lr, b1) in enumerate((lr, b1)
                                          for lr in (1e-3, 5e-3)
                                          for b1 in (0.8, 0.9))]
    x, t, _ = paper_dataset(n=160, seed=0)
    x = x[:, :256]
    cfg = SweepConfig(rounds=2, steps_per_round=2, batch_size=32,
                      eval_samples=32, engine="pallas")
    result = run_sweep(specs, x[:128], t[:128], x[128:], t[128:], cfg,
                       tag="adam-smoke")
    led = result.ledger
    assert len(led.members) == 4
    w = led.winner()
    assert w is not None and w.config["opt"] == "adam"
    assert w.config["momentum"] in (0.8, 0.9)
    assert result.winning_params()[0]["w"].ndim == 4


def test_momentum_free_population_skips_buffers():
    """An all-momentum-0 population carries NO momentum state (the
    plain-SGD kernels run — no weight-sized fp32 stream per junction)
    and computes exactly what the zeros-buffer beta-0 variant does."""
    specs = _specs(momentum=0.0)
    E = len(specs)
    params = init_population(jax.random.PRNGKey(7), specs)
    assert pop.init_momentum(params, specs) is None
    assert pop.init_momentum(params, _specs(momentum=0.9)) is not None
    x, t = _mnist_batch(32, specs[0].layers[0], specs[0].layers[-1])
    hyp, mask = hyp_table(specs), jnp.ones((E,), jnp.float32)
    step = make_population_step(engine="pallas", fused=True, donate=False)
    p1, m1, l1 = step(params, None, hyp, mask, x, t)
    assert m1 is None
    p2, _, l2 = step(params, pop.init_momentum(params), hyp, mask, x, t)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-6)
    for li in range(len(p1)):
        np.testing.assert_allclose(np.asarray(p1[li]["w"]),
                                   np.asarray(p2[li]["w"]),
                                   rtol=1e-5, atol=1e-6)


def test_rank_score_nan_and_width_policy():
    """Ranking policy: a diverged (non-finite) eval loss scores +inf —
    pruned first, never winner — and scores are width-normalized (per-
    sample TOTAL squared error), so a wider zero-padded output doesn't
    dilute its way past a narrow cohort."""
    import math

    from repro.search.scheduler import _score

    assert _score(float("nan"), 32) == math.inf
    assert _score(float("inf"), 32) == math.inf
    # identical per-sample total error ranks equal across widths: a
    # 128-wide cohort's MSE mean is 4x diluted vs a 32-wide one
    assert _score(0.01, 128) == pytest.approx(_score(0.04, 32))
    assert _score(0.02, 32) < _score(0.01, 128)


def test_sweep_single_candidate_wins():
    """Degenerate sweep: one candidate survives every round and wins."""
    specs = _specs(E=1)
    x, t, _ = paper_dataset(n=96, seed=1)
    x = x[:, :256]
    cfg = SweepConfig(rounds=2, steps_per_round=1, batch_size=32,
                      eval_samples=32, engine="jnp")
    result = run_sweep(specs, x[:64], t[:64], x[64:], t[64:], cfg)
    w = result.ledger.winner()
    assert w is not None and w.member == 0 and w.rounds_survived == 2
