"""Fused BP+UP (ISSUE 4/7): the in-kernel weight update vs the two-pass
reference.

The contract under test: with ``ArchConfig.fused_update`` + a
``FusedOptimizer`` (fused_sgd / fused_adam) on the pallas engine, the
backward kernels apply the optimizer update in their epilogue — the hyp
row is the (HYP_K,) registry row of kernels/block_sparse_matmul.HYP_COLS
— and the train step's "grads" tree carries UPDATED params at junction
leaves; dw never materializes in HBM (the kernel-name jaxpr checks
below), and the resulting params/opt state match the two-pass reference
that materializes gradients and tree-maps the update.  Plus: Adam's
3-step bias-correction carry, bf16 params with fp32 accumulator slots,
grad-clip (norm pre-pass folded into the gs column) and microbatch
(full-batch identity) configs now running FUSED against their two-pass
references, the remaining refusals, the coalesced reverse-DMA pattern
with contiguous runs, and the make_train_step donation default.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, MoEConfig
from repro.core import sparse_linear as sl
from repro.core.interleaver import reverse_block_pattern
from repro.core.sparsity import SparsityConfig, make_block_pattern
from repro.kernels import ops
from repro.models import model as M
from repro.optim import (FusedSGD, adam, constant_schedule, fused_adam,
                         fused_sgd)
from repro.train.steps import fused_update_eligible, make_train_step


def _dense_cfg(**kw):
    base = dict(
        name="fused-test", family="dense", n_layers=2, d_model=128,
        n_heads=4, kv_heads=4, head_dim=32, d_ff=256, vocab=128,
        act="silu", max_seq=64, attn_chunk=32, dtype="float32",
        param_dtype="float32",
        sparsity=SparsityConfig(density=0.25, block=32, where="ffn"),
        engine="pallas", fused_update=True)
    base.update(kw)
    return ArchConfig(**base)


def _moe_cfg(**kw):
    base = dict(
        name="fused-moe-test", family="moe", n_layers=1, d_model=128,
        n_heads=4, kv_heads=4, head_dim=32, d_ff=256, vocab=128,
        act="silu", max_seq=64, attn_chunk=32, dtype="float32",
        param_dtype="float32",
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64, group_size=32),
        sparsity=SparsityConfig(density=0.5, block=32, where="ffn"),
        engine="pallas", fused_update=True)
    base.update(kw)
    return ArchConfig(**base)


def _batch(cfg, key=1):
    return {"tokens": jax.random.randint(jax.random.PRNGKey(key), (2, 16),
                                         0, cfg.vocab)}


def _assert_trees_close(t1, t2, rtol, atol):
    kv1 = jax.tree_util.tree_flatten_with_path(t1)[0]
    kv2 = jax.tree_util.tree_flatten_with_path(t2)[0]
    assert [k for k, _ in kv1] == [k for k, _ in kv2]
    for (k, a), (_, b) in zip(kv1, kv2):
        if jnp.issubdtype(jnp.asarray(a).dtype, jnp.inexact):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=rtol, atol=atol, err_msg=str(k))


# ----------------------------------------------------------- junction level
def _mnist_junction(dtype=jnp.float32):
    """The paper's MNIST junction in block form (1024 -> 512 @ kb=2)."""
    sp = SparsityConfig(density=0.25, block=128, where="ffn")
    p = sl.init_sparse(jax.random.PRNGKey(0), 1024, 512, sp, bias=True,
                       dtype=dtype)
    return p


@pytest.mark.parametrize("momentum", [0.0, 0.9])
@pytest.mark.parametrize("act", ["none", "sigmoid"])
def test_mnist_junction_fused_matches_two_pass(momentum, act):
    """Acceptance: fused params == two-pass sgd/momentum reference on the
    paper MNIST junction (fwd+bwd+update), to fp32 round-off."""
    p = _mnist_junction()
    x = jax.random.normal(jax.random.PRNGKey(1), (96, 1024))
    co = jax.random.normal(jax.random.PRNGKey(2), (96, 512))
    lr = 0.05
    hyp = jnp.asarray([lr, momentum], jnp.float32)
    mom = jnp.zeros(p["w"].shape, jnp.float32) if momentum else None
    mom_b = jnp.zeros(p["b"].shape, jnp.float32) if momentum else None
    pat = (p["idx"], p["rev_ob"], p["rev_t"], p["rev_cnt"])

    def loss_ref(w, b):
        y = ops.junction_matmul(x, w, *pat, bias=b, act=act)
        return jnp.sum(y * co)

    gw, gb = jax.grad(loss_ref, (0, 1))(p["w"], p["b"])
    mv = momentum * mom + gw if momentum else gw
    mbv = momentum * mom_b + gb if momentum else gb
    ref_w = p["w"] - lr * mv
    ref_b = p["b"] - lr * mbv

    def loss_fused(w, b, m, mb):
        y = ops.junction_train_update(x, w, *pat, bias=b, act=act, hyp=hyp,
                                      mom=m, mom_b=mb)
        return jnp.sum(y * co)

    argnums = (0, 1, 2, 3) if momentum else (0, 1)
    got = jax.grad(loss_fused, argnums)(p["w"], p["b"], mom, mom_b)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(ref_w),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(ref_b),
                               rtol=1e-5, atol=1e-6)
    if momentum:
        np.testing.assert_allclose(np.asarray(got[2]), np.asarray(mv),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(got[3]), np.asarray(mbv),
                                   rtol=1e-5, atol=1e-6)


def test_mnist_junction_fused_adam_three_step_carry():
    """Acceptance (ISSUE 7): in-kernel Adam on the paper MNIST junction
    matches the two-pass reference formula over 3 steps — the m/v slots
    and the bias-correction time t carry across steps through the
    aliased-cotangent contract."""
    p = _mnist_junction()
    x = jax.random.normal(jax.random.PRNGKey(1), (96, 1024))
    co = jax.random.normal(jax.random.PRNGKey(2), (96, 512))
    lr, b1, b2, eps, wd = 1e-3, 0.9, 0.95, 1e-8, 0.01
    pat = (p["idx"], p["rev_ob"], p["rev_t"], p["rev_cnt"])
    w, b = p["w"], p["b"]
    m = jnp.zeros(w.shape, jnp.float32)
    v = jnp.zeros(w.shape, jnp.float32)
    mb = jnp.zeros(b.shape, jnp.float32)
    vb = jnp.zeros(b.shape, jnp.float32)
    rw, rb, rm, rv, rmb, rvb = w, b, m, v, mb, vb

    def loss_ref(w, b):
        y = ops.junction_matmul(x, w, *pat, bias=b, act="sigmoid")
        return jnp.sum(y * co)

    def loss_fused(w, b, m, mb, v, vb, hyp):
        y = ops.junction_train_update(x, w, *pat, bias=b, act="sigmoid",
                                      hyp=hyp, mom=m, mom_b=mb,
                                      vel=v, vel_b=vb)
        return jnp.sum(y * co)

    for t in range(1, 4):
        hyp = jnp.asarray([lr, b1, b2, eps, wd, t, 1.0], jnp.float32)
        w, b, m, mb, v, vb = jax.grad(loss_fused, (0, 1, 2, 3, 4, 5))(
            w, b, m, mb, v, vb, hyp)
        gw, gb = jax.grad(loss_ref, (0, 1))(rw, rb)
        c1, c2 = 1.0 - b1 ** t, 1.0 - b2 ** t
        rm = b1 * rm + (1 - b1) * gw
        rv = b2 * rv + (1 - b2) * jnp.square(gw)
        rw = rw - lr * ((rm / c1) / (jnp.sqrt(rv / c2) + eps) + wd * rw)
        rmb = b1 * rmb + (1 - b1) * gb
        rvb = b2 * rvb + (1 - b2) * jnp.square(gb)
        rb = rb - lr * ((rmb / c1) / (jnp.sqrt(rvb / c2) + eps) + wd * rb)
    for got, ref in ((w, rw), (b, rb), (m, rm), (v, rv), (mb, rmb),
                     (vb, rvb)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-6)


def test_expert_gated_junction_fused_matches_two_pass():
    """Expert-batched gated configuration: both weight streams updated in
    one fused pass, shared pattern, E > 1."""
    bs, E = 32, 3
    pat = make_block_pattern(8 * bs, 6 * bs, 0.34, bs)
    idx, rob, rt, rc = map(jnp.asarray, (pat.idx, pat.rev_ob, pat.rev_t,
                                         pat.rev_cnt))
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (E, 40, 8 * bs))
    wg = jax.random.normal(ks[1], (E, pat.n_out_blocks, pat.fan_in_blocks,
                                   bs, bs)) * 0.1
    wi = jax.random.normal(ks[2], wg.shape) * 0.1
    co = jax.random.normal(ks[3], (E, 40, 6 * bs))
    lr, beta = 0.05, 0.9
    hyp = jnp.asarray([lr, beta], jnp.float32)
    mg = jnp.ones(wg.shape, jnp.float32) * 0.01
    mi = jnp.ones(wi.shape, jnp.float32) * 0.02

    def loss_ref(wg, wi):
        return jnp.sum(ops.junction_matmul(x, wg, idx, rob, rt, rc, wi=wi) * co)

    gwg, gwi = jax.grad(loss_ref, (0, 1))(wg, wi)

    def loss_fused(wg, wi, mg, mi):
        return jnp.sum(ops.junction_train_update(
            x, wg, idx, rob, rt, rc, wi=wi, hyp=hyp, mom=mg, mom_wi=mi) * co)

    nwg, nwi, nmg, nmi = jax.grad(loss_fused, (0, 1, 2, 3))(wg, wi, mg, mi)
    np.testing.assert_allclose(np.asarray(nmg), np.asarray(beta * mg + gwg),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(nwg),
                               np.asarray(wg - lr * (beta * mg + gwg)),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(nwi),
                               np.asarray(wi - lr * (beta * mi + gwi)),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(nmi), np.asarray(beta * mi + gwi),
                               rtol=1e-5, atol=1e-6)


def test_bf16_params_fp32_momentum():
    """bf16 junction weights update through an fp32 momentum accumulator:
    the fused path keeps dw in fp32 end-to-end (the two-pass path rounds
    dw to bf16 at the custom_vjp boundary, hence the loose tolerance —
    the fused result is the MORE precise one)."""
    bs = 32
    pat = make_block_pattern(8 * bs, 4 * bs, 0.5, bs)
    idx, rob, rt, rc = map(jnp.asarray, (pat.idx, pat.rev_ob, pat.rev_t,
                                         pat.rev_cnt))
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 8 * bs)).astype(jnp.bfloat16)
    w = (jax.random.normal(jax.random.PRNGKey(3),
                           (pat.n_out_blocks, pat.fan_in_blocks, bs, bs))
         * 0.1).astype(jnp.bfloat16)
    co = jax.random.normal(jax.random.PRNGKey(4), (64, 4 * bs))
    mom = jnp.zeros(w.shape, jnp.float32)
    hyp = jnp.asarray([0.05, 0.9], jnp.float32)

    def loss_fused(w, mom):
        y = ops.junction_train_update(x, w, idx, rob, rt, rc, act="relu",
                                      hyp=hyp, mom=mom)
        return jnp.sum(y.astype(jnp.float32) * co)

    nw, nm = jax.grad(loss_fused, (0, 1))(w, mom)
    assert nw.dtype == jnp.bfloat16          # params stay bf16
    assert nm.dtype == jnp.float32           # accumulator stays fp32

    def loss_ref(w):
        y = ops.junction_matmul(x, w, idx, rob, rt, rc, act="relu")
        return jnp.sum(y.astype(jnp.float32) * co)

    gw = jax.grad(loss_ref)(w).astype(jnp.float32)
    mv = 0.9 * mom + gw
    ref_w = (w.astype(jnp.float32) - 0.05 * mv).astype(jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(nm), np.asarray(mv),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(nw, np.float32),
                               np.asarray(ref_w, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_fused_requires_matching_dtypes():
    p = _mnist_junction()
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 1024)).astype(jnp.bfloat16)
    with pytest.raises(ValueError, match="param dtype"):
        ops.junction_train_update(
            x, p["w"], p["idx"], p["rev_ob"], p["rev_t"], p["rev_cnt"],
            hyp=jnp.asarray([0.1, 0.0], jnp.float32))


# -------------------------------------------------------------- model level
@pytest.mark.parametrize("momentum", [0.0, 0.9])
def test_model_fused_step_matches_two_pass(momentum):
    """Full dense-model train step (stacked layers under lax.scan +
    remat): fused params/opt state match the two-pass reference."""
    cfg = _dense_cfg()
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    opt = fused_sgd(constant_schedule(1e-2), momentum=momentum)
    st = opt.init(params)
    ok, why = fused_update_eligible(cfg, opt)
    assert ok, why
    ts_f = make_train_step(cfg, opt, donate=False)
    ts_r = make_train_step(dataclasses.replace(cfg, fused_update=False),
                           opt, donate=False)
    p1, s1, m1 = ts_f(params, st, batch, jnp.asarray(0))
    p2, s2, m2 = ts_r(params, st, batch, jnp.asarray(0))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-6)
    _assert_trees_close(p1, p2, rtol=2e-4, atol=2e-5)
    if momentum:
        _assert_trees_close(s1, s2, rtol=2e-4, atol=2e-5)


def test_model_fused_momentum_carries_across_steps():
    cfg = _dense_cfg()
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    opt = fused_sgd(constant_schedule(1e-2), momentum=0.9)
    ts_f = make_train_step(cfg, opt, donate=False)
    ts_r = make_train_step(dataclasses.replace(cfg, fused_update=False),
                           opt, donate=False)
    pf = pr = params
    sf = sr = opt.init(params)
    for i in range(3):
        pf, sf, _ = ts_f(pf, sf, batch, jnp.asarray(i))
        pr, sr, _ = ts_r(pr, sr, batch, jnp.asarray(i))
    _assert_trees_close(pf, pr, rtol=5e-4, atol=5e-5)
    _assert_trees_close(sf, sr, rtol=5e-4, atol=5e-5)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_model_fused_adam_three_steps_matches_two_pass(dtype):
    """Acceptance (ISSUE 7): fused Adam on the dense model matches the
    two-pass ``adam`` reference over 3 steps — bias correction, weight
    decay and the fp32 m/v slots all carry.  bf16 params keep fp32
    slots; the two-pass path rounds dw to bf16 at the custom_vjp
    boundary, hence the looser bf16 tolerance (the fused result is the
    more precise one)."""
    cfg = _dense_cfg(dtype=dtype, param_dtype=dtype)
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    opt = fused_adam(constant_schedule(1e-3), weight_decay=0.01)
    ok, why = fused_update_eligible(cfg, opt)
    assert ok, why
    ts_f = make_train_step(cfg, opt, donate=False)
    ts_r = make_train_step(dataclasses.replace(cfg, fused_update=False),
                           opt, donate=False)
    pf = pr = params
    sf = sr = opt.init(params)
    for i in range(3):
        pf, sf, _ = ts_f(pf, sf, batch, jnp.asarray(i))
        pr, sr, _ = ts_r(pr, sr, batch, jnp.asarray(i))
    if dtype == "bfloat16":
        for t in jax.tree.leaves(sf):
            assert t.dtype == jnp.float32    # m/v slots stay fp32
        rtol, atol = 2e-2, 2e-2
    else:
        rtol, atol = 5e-4, 5e-5
    _assert_trees_close(pf, pr, rtol=rtol, atol=atol)
    _assert_trees_close(sf, sr, rtol=rtol, atol=atol)


def test_moe_fused_step_matches_two_pass():
    """Acceptance: the MoE expert FFN (gated in-junction + wo junction,
    shared patterns, router/shared leaves dense) through the fused step
    matches the two-pass reference."""
    cfg = _moe_cfg()
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    opt = fused_sgd(constant_schedule(1e-2), momentum=0.9)
    st = opt.init(params)
    ts_f = make_train_step(cfg, opt, donate=False)
    ts_r = make_train_step(dataclasses.replace(cfg, fused_update=False),
                           opt, donate=False)
    p1, s1, m1 = ts_f(params, st, batch, jnp.asarray(0))
    p2, s2, m2 = ts_r(params, st, batch, jnp.asarray(0))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-6)
    _assert_trees_close(p1, p2, rtol=2e-4, atol=2e-5)
    _assert_trees_close(s1, s2, rtol=2e-4, atol=2e-5)


def test_moe_fused_adam_three_steps_matches_two_pass():
    """Acceptance (ISSUE 7): fused Adam through the MoE expert FFN — the
    gated in-junction (wg/wi) and the wo junction each carry their own
    m/v slot pairs; 3 steps against the two-pass reference."""
    cfg = _moe_cfg()
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    opt = fused_adam(constant_schedule(1e-3), weight_decay=0.01)
    ts_f = make_train_step(cfg, opt, donate=False)
    ts_r = make_train_step(dataclasses.replace(cfg, fused_update=False),
                           opt, donate=False)
    pf = pr = params
    sf = sr = opt.init(params)
    for i in range(3):
        pf, sf, mf = ts_f(pf, sf, batch, jnp.asarray(i))
        pr, sr, mr = ts_r(pr, sr, batch, jnp.asarray(i))
    np.testing.assert_allclose(float(mf["loss"]), float(mr["loss"]),
                               rtol=1e-5)
    _assert_trees_close(pf, pr, rtol=5e-4, atol=5e-5)
    _assert_trees_close(sf, sr, rtol=5e-4, atol=5e-5)


# ------------------------------------------------- no-dw-in-HBM acceptance
@pytest.mark.parametrize("make_opt", [
    lambda: fused_sgd(constant_schedule(1e-2), momentum=0.9),
    lambda: fused_adam(constant_schedule(1e-3)),
], ids=["sgd", "adam"])
def test_fused_step_jaxpr_has_no_dw_kernel(make_opt):
    """Acceptance: dw is absent from the fused step's jaxpr — the only
    weight-gradient consumers are the fused update kernels (whose outputs
    alias the parameter inputs), for the plain AND gated configurations,
    under both fused optimizers."""
    for cfg in (_dense_cfg(), _moe_cfg()):
        params = M.init(cfg, jax.random.PRNGKey(0))
        opt = make_opt()
        raw = make_train_step(cfg, opt, jit=False)
        txt = str(jax.make_jaxpr(raw)(params, opt.init(params), _batch(cfg),
                                      jnp.asarray(0)))
        assert "fused_update_dw" in txt, cfg.name
        # "dw_kernel" also catches "gated_dw_kernel"
        assert "dw_kernel" not in txt, cfg.name
        if cfg.family == "moe":
            assert "fused_update_gated_dw" in txt
        # two-pass sanity: the reference step still runs the dw kernels
        raw_ref = make_train_step(
            dataclasses.replace(cfg, fused_update=False), opt, jit=False)
        txt_ref = str(jax.make_jaxpr(raw_ref)(params, opt.init(params),
                                              _batch(cfg), jnp.asarray(0)))
        assert "dw_kernel" in txt_ref and "fused_update_dw" not in txt_ref


# ------------------------------------- newly-eligible configs (ISSUE 7)
@pytest.mark.parametrize("make_opt", [
    lambda: fused_sgd(constant_schedule(1e-2), momentum=0.9, grad_clip=0.5),
    lambda: fused_adam(constant_schedule(1e-3), grad_clip=0.5),
], ids=["sgd", "adam"])
def test_grad_clip_runs_fused_and_matches_clipped_reference(make_opt):
    """Regression flip (ISSUE 7): grad_clip no longer refuses the fused
    path — a norm pre-pass over the plain loss computes the SAME global
    norm the two-pass reference clips with (optim.global_norm_scale is
    the one shared formula) and folds its scale into the hyp row's gs
    column.  The pre-pass costs a second backward, so dw kernels DO
    appear in this jaxpr — alongside, not instead of, the fused update
    kernels."""
    cfg = _dense_cfg()
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    opt = make_opt()
    ok, why = fused_update_eligible(cfg, opt)
    assert ok, why
    st = opt.init(params)
    txt = str(jax.make_jaxpr(make_train_step(cfg, opt, jit=False))(
        params, st, batch, jnp.asarray(0)))
    assert "fused_update_dw" in txt and "dw_kernel" in txt
    ts = make_train_step(cfg, opt, donate=False)
    ts_ref = make_train_step(dataclasses.replace(cfg, fused_update=False),
                             opt, donate=False)
    pf = pr = params
    sf = sr = st
    for i in range(2):
        pf, sf, _ = ts(pf, sf, batch, jnp.asarray(i))
        pr, sr, _ = ts_ref(pr, sr, batch, jnp.asarray(i))
    _assert_trees_close(pf, pr, rtol=2e-4, atol=2e-5)
    _assert_trees_close(sf, sr, rtol=2e-4, atol=2e-5)


def test_microbatch_runs_fused_and_matches_accumulated_reference():
    """Regression flip (ISSUE 7): microbatches > 1 no longer refuses the
    fused path — the fused step runs the FULL batch (mean of equal-sized
    microbatch means == full-batch mean; the kernels' M-innermost flush
    applies the update exactly once per tile) and must match the
    two-pass scan-accumulated reference."""
    cfg = _dense_cfg()
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 16),
                                          0, cfg.vocab)}
    opt = fused_sgd(constant_schedule(1e-2), momentum=0.9)
    ok, why = fused_update_eligible(cfg, opt, microbatches=4)
    assert ok, why
    ts = make_train_step(cfg, opt, microbatches=4, donate=False)
    ts_ref = make_train_step(dataclasses.replace(cfg, fused_update=False),
                             opt, microbatches=4, donate=False)
    st = opt.init(params)
    p1, s1, m1 = ts(params, st, batch, jnp.asarray(0))
    p2, s2, m2 = ts_ref(params, st, batch, jnp.asarray(0))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    _assert_trees_close(p1, p2, rtol=2e-4, atol=2e-5)
    _assert_trees_close(s1, s2, rtol=2e-4, atol=2e-5)


# ----------------------------------------------------- refusal / fallback


@pytest.mark.parametrize("break_it,frag", [
    (dict(engine="jnp"), "engine"),
    (dict(fused_update=False), "off"),
    (dict(param_dtype="bfloat16"), "param_dtype"),
    (dict(cast_params_once=True), "cast_params_once"),
])
def test_fused_eligibility_refusals(break_it, frag):
    cfg = _dense_cfg(**break_it)
    opt = fused_sgd(constant_schedule(1e-2), momentum=0.9)
    ok, why = fused_update_eligible(cfg, opt)
    assert not ok and frag in why, why


def test_fused_refuses_weight_shared_hybrid():
    """The hybrid family applies ONE shared attn/MLP block per super-layer
    — cotangents sum across uses, which would corrupt a fused junction's
    updated-params cotangent.  Eligibility must refuse."""
    from repro.configs import registry
    cfg = dataclasses.replace(
        registry.get("zamba2-2.7b").reduced(),
        sparsity=SparsityConfig(density=0.25, block=32, where="ffn"),
        engine="pallas", fused_update=True,
        dtype="float32", param_dtype="float32")
    opt = fused_sgd(constant_schedule(1e-2), momentum=0.9)
    ok, why = fused_update_eligible(cfg, opt)
    assert not ok and "hybrid" in why


def test_fused_rejects_non_fp32_momentum():
    """The momentum state must stay fp32 (the documented accumulator
    contract) — a bf16 buffer must raise, not silently degrade."""
    bs = 32
    pat = make_block_pattern(8 * bs, 4 * bs, 0.5, bs)
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 8 * bs)).astype(jnp.bfloat16)
    w = jnp.zeros((pat.n_out_blocks, pat.fan_in_blocks, bs, bs), jnp.bfloat16)
    with pytest.raises(ValueError, match="fp32 accumulator"):
        ops.junction_train_update(
            x, w, jnp.asarray(pat.idx), jnp.asarray(pat.rev_ob),
            jnp.asarray(pat.rev_t), jnp.asarray(pat.rev_cnt),
            hyp=jnp.asarray([0.1, 0.9], jnp.float32),
            mom=jnp.zeros_like(w))


def test_fused_eligibility_wrong_optimizer():
    """A plain (non-Fused) optimizer still refuses — it has no hyp row /
    slot contract for the kernels to consume."""
    cfg = _dense_cfg()
    ok, why = fused_update_eligible(cfg, adam(constant_schedule(1e-3)))
    assert not ok and "FusedOptimizer" in why


def test_two_pass_fused_sgd_matches_plain_sgd():
    """fused_sgd without momentum IS eq. (3): parity with optim.sgd."""
    from repro.optim import sgd
    cfg = _dense_cfg(engine="jnp", fused_update=False)
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    for opt in (sgd(constant_schedule(1e-2)),
                fused_sgd(constant_schedule(1e-2))):
        ts = make_train_step(cfg, opt, donate=False)
        p, _, _ = ts(params, opt.init(params), batch, jnp.asarray(0))
        if opt.__class__ is FusedSGD:
            _assert_trees_close(p, p_ref, rtol=1e-6, atol=1e-7)
        else:
            p_ref = p


# ------------------------------------------------ coalesced reverse DMA
def test_dx_coalesces_contiguous_reverse_runs():
    """A pattern whose reverse slots form contiguous runs in the flat
    (ob, t) weight layout (input block i ends one output block's fan-in
    list and starts the next's) exercises the two-tile descriptor path;
    parity vs the jnp oracle."""
    from repro.kernels import ref

    idx_np = np.array([[0, 1], [1, 2], [2, 3]], np.int32)
    rob, rt, rc = reverse_block_pattern(idx_np, 4)
    # input 1 occupies linear slots 1 and 2; input 2 slots 3 and 4 — runs
    s = rob * idx_np.shape[1] + rt
    assert (np.diff(s[1, :rc[1]]) == 1).all()
    bs = 32
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 4 * bs))
    w = jax.random.normal(jax.random.PRNGKey(4), (3, 2, bs, bs)) * 0.1
    co = jax.random.normal(jax.random.PRNGKey(5), (64, 3 * bs))
    args = (jnp.asarray(idx_np), jnp.asarray(rob), jnp.asarray(rt),
            jnp.asarray(rc))

    def f(x, w):
        return jnp.sum(ops.block_sparse_matmul(x, w, *args) * co)

    def g(x, w):
        return jnp.sum(ref.block_sparse_matmul(x, w, args[0]) * co)

    d1 = jax.grad(f, (0, 1))(x, w)
    d2 = jax.grad(g, (0, 1))(x, w)
    for a, b in zip(d1, d2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


# --------------------------------------------------------- donation default
def test_make_train_step_donates_by_default():
    """Satellite: the jitted step donates params/opt_state so XLA reuses
    the buffers (no doubled peak memory across the update)."""
    cfg = _dense_cfg(engine="jnp", fused_update=False)
    params = M.init(cfg, jax.random.PRNGKey(0))
    opt = fused_sgd(constant_schedule(1e-2), momentum=0.9)
    st = opt.init(params)
    ts = make_train_step(cfg, opt)
    p2, s2, _ = ts(params, st, _batch(cfg), jnp.asarray(0))
    donated = jax.tree.leaves(params)[0].is_deleted()
    assert donated, "params were not donated by the default train step"
    # and donate=False keeps the inputs alive
    params = M.init(cfg, jax.random.PRNGKey(0))
    st = opt.init(params)
    ts2 = make_train_step(cfg, opt, donate=False)
    ts2(params, st, _batch(cfg), jnp.asarray(0))
    assert not jax.tree.leaves(params)[0].is_deleted()
