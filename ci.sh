#!/usr/bin/env bash
# CI entry point: tier-1 test suite + the fast machine-trackable benches.
#
#   ./ci.sh            # tests + engine/roofline benches, BENCH_ci.json
#   BENCH_TAG=pr42 ./ci.sh
#
# Fails on test failures or bench harness errors (benchmarks/run.py exits
# nonzero when any bench raises).
set -euo pipefail
cd "$(dirname "$0")"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

TAG="${BENCH_TAG:-ci}"
echo "== fast benches (engine, roofline) =="
python -m benchmarks.run --only engine,roofline --json "BENCH_${TAG}.json"

echo "== ci.sh OK =="
