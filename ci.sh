#!/usr/bin/env bash
# CI entry point: tier-1 test suite + the fast machine-trackable benches.
#
#   ./ci.sh            # tests + engine/roofline benches, BENCH_ci.json
#   BENCH_TAG=pr42 ./ci.sh
#
# Fails on test failures, bench harness errors (benchmarks/run.py exits
# nonzero when any bench raises or --only names an unknown bench), or an
# empty bench artifact (guards the silent-no-op class of regressions).
set -euo pipefail
cd "$(dirname "$0")"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

TAG="${BENCH_TAG:-ci}"
echo "== fast benches (engine incl. MoE rows, roofline) =="
python -m benchmarks.run --only engine,roofline --json "BENCH_${TAG}.json"

python - "BENCH_${TAG}.json" <<'PY'
import json, sys
path = sys.argv[1]
data = json.load(open(path))
if not data:
    sys.exit(f"[ci] empty bench artifact {path} — benches ran nothing")
print(f"[ci] {path}: {len(data)} bench entries")
PY

echo "== ci.sh OK =="
