#!/usr/bin/env bash
# CI entry point: tier-1 test suite + the fast machine-trackable benches.
#
#   ./ci.sh                     # tests + engine/roofline benches, BENCH_ci.json
#   ./ci.sh --fail-on-regress   # exit nonzero when engine.* rows regress
#   BENCH_TAG=pr42 ./ci.sh
#
# Fails on test failures, bench harness errors (benchmarks/run.py exits
# nonzero when any bench raises or --only names an unknown bench), or an
# empty bench artifact (guards the silent-no-op class of regressions).
# Additionally compares the fresh artifact against the committed
# benchmarks/BENCH_baseline.json: by default it WARNS (non-fatal —
# interpret-mode timings are noisy off-TPU) when any engine.* row slows
# past its threshold; with --fail-on-regress the comparison is fatal.
# Per-row thresholds live in the THRESHOLDS table below (default 1.2x;
# noisier rows get more headroom).
set -euo pipefail
cd "$(dirname "$0")"

FAIL_ON_REGRESS=0
for arg in "$@"; do
  case "$arg" in
    --fail-on-regress) FAIL_ON_REGRESS=1 ;;
    *) echo "ci.sh: unknown argument $arg" >&2; exit 2 ;;
  esac
done

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

TAG="${BENCH_TAG:-ci}"
echo "== fast benches (engine incl. MoE + fused-update rows, roofline) =="
python -m benchmarks.run --only engine,roofline --json "BENCH_${TAG}.json"

python - "BENCH_${TAG}.json" benchmarks/BENCH_baseline.json "$FAIL_ON_REGRESS" <<'PY'
import sys
from benchmarks.run import load_artifact

# Per-row slowdown thresholds (new/old ratio).  The single-call-dominated
# MoE rows jitter more off-TPU than the plain junction rows; fused-update
# rows time a whole train step and inherit that noise.
DEFAULT_THRESHOLD = 1.2
THRESHOLDS = {
    "engine.moe.jnp": 1.35,
    "engine.moe.pallas": 1.35,
    "engine.update.moe.jnp": 1.4,
    "engine.update.moe.pallas": 1.4,
}

path, base_path, fail_on_regress = sys.argv[1], sys.argv[2], sys.argv[3] == "1"
meta, results = load_artifact(path)
if not results:
    sys.exit(f"[ci] empty bench artifact {path} — benches ran nothing")
print(f"[ci] {path}: {len(results)} bench entries "
      f"(sha {meta.get('git_sha', 'unstamped')}, "
      f"backend {meta.get('backend', '?')})")

try:
    _, base = load_artifact(base_path)
except (OSError, ValueError) as e:  # missing OR unreadable: stay non-fatal
    print(f"[ci] no usable baseline at {base_path} ({e.__class__.__name__}) "
          f"— skipping perf comparison")
    sys.exit(0)
slow = []
for name in sorted(base):
    if not name.startswith("engine.") or name not in results:
        continue
    new, old = results[name], base[name]
    thresh = THRESHOLDS.get(name, DEFAULT_THRESHOLD)
    ratio = new / old if old else float("inf")
    flag = f"  <-- {'FAIL' if fail_on_regress else 'WARN'} >{thresh:.2f}x" \
        if ratio > thresh else ""
    print(f"[ci]   {name}: {old:.0f} -> {new:.0f} us ({ratio:.2f}x){flag}")
    if ratio > thresh:
        slow.append(name)
if slow:
    msg = (f"{len(slow)} engine.* row(s) slower than their baseline "
           f"threshold ({', '.join(slow)})")
    if fail_on_regress:
        sys.exit(f"[ci] FAIL: {msg}")
    print(f"[ci] WARNING: {msg} — non-fatal, investigate before "
          f"refreshing benchmarks/BENCH_baseline.json")
PY

echo "== ci.sh OK =="
