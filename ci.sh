#!/usr/bin/env bash
# CI entry point: tier-1 test suite + sweep smoke + fast benches.
#
#   ./ci.sh                     # tests + sweep smoke + engine/roofline benches
#   ./ci.sh --fail-on-regress   # exit nonzero when engine.* rows regress
#   BENCH_TAG=pr42 ./ci.sh
#
# Fails on test failures, a population sweep that names no winner (the
# tiny 2-round MNIST density x lr smoke, E=4 candidates — guards the
# search subsystem end to end; an lr x b1 smoke under --optim adam does
# the same for the in-kernel Adam epilogue), bench harness errors
# (benchmarks/run.py
# exits nonzero when any bench raises or --only names an unknown bench),
# or an empty bench artifact (guards the silent-no-op class of
# regressions).
# Additionally compares the fresh artifact against the committed
# benchmarks/BENCH_baseline.json: by default it WARNS (non-fatal —
# interpret-mode timings are noisy off-TPU) when any engine.*/bench.*
# row slows past its threshold; with --fail-on-regress the comparison is
# fatal.
# Per-row thresholds live in the THRESHOLDS table below (default 1.2x;
# noisier rows get more headroom).
set -euo pipefail
cd "$(dirname "$0")"

FAIL_ON_REGRESS=0
for arg in "$@"; do
  case "$arg" in
    --fail-on-regress) FAIL_ON_REGRESS=1 ;;
    *) echo "ci.sh: unknown argument $arg" >&2; exit 2 ;;
  esac
done

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

TAG="${BENCH_TAG:-ci}"

echo "== sweep smoke (population engine: 2-round MNIST density x lr, E=4) =="
python -m repro.launch.sweep --densities 0.25,0.5 --lrs 0.05,0.2 \
  --rounds 2 --steps-per-round 2 --batch 32 --samples 256 --eval-samples 64 \
  --block 32 --hidden 128 --engine jnp --tag "$TAG" --out "SWEEP_${TAG}.json"
python - "SWEEP_${TAG}.json" <<'PY'
import json, sys
led = json.load(open(sys.argv[1]))
w = led.get("winner")
if not (w and w.get("config") and w.get("eval_losses")):
    sys.exit(f"[ci] sweep ledger {sys.argv[1]} names no winner")
pruned = sum(1 for m in led["members"] if m["pruned_at"] is not None)
print(f"[ci] sweep winner: density={w['config']['density']} "
      f"lr={w['config']['lr']} eval_loss={w['eval_losses'][-1]:.4f} "
      f"({pruned}/{len(led['members'])} pruned)")
PY

echo "== adam sweep smoke (in-kernel Adam epilogue: 2-round lr x b1, E=4) =="
# same harness under --optim adam: every member updates through the
# [E, HYP_K] registry rows (distinct lr/b1 per member) and the ledger
# must still name a winner
python -m repro.launch.sweep --optim adam --densities 0.5 \
  --lrs 0.001,0.005 --b1s 0.8,0.9 \
  --rounds 2 --steps-per-round 2 --batch 32 --samples 256 --eval-samples 64 \
  --block 32 --hidden 128 --engine jnp --tag "${TAG}-adam" \
  --out "SWEEP_${TAG}_adam.json"
python - "SWEEP_${TAG}_adam.json" <<'PY'
import json, sys
led = json.load(open(sys.argv[1]))
w = led.get("winner")
if not (w and w.get("config") and w.get("eval_losses")):
    sys.exit(f"[ci] adam sweep ledger {sys.argv[1]} names no winner")
if w["config"].get("opt") != "adam":
    sys.exit(f"[ci] adam sweep winner is not an adam member: {w['config']}")
print(f"[ci] adam sweep winner: lr={w['config']['lr']} "
      f"b1={w['config']['momentum']} "
      f"eval_loss={w['eval_losses'][-1]:.4f}")
PY

echo "== fault injection (guardian, crash recovery, quarantine smoke) =="
# the divergence-guardian + crash-shaped checkpoint tests, run as their
# own stage so a fault-tolerance regression is named even when someone
# trims the tier-1 run above
python -m pytest -x -q tests/test_guardian.py tests/test_checkpoint.py
# sweep smoke with a deliberately diverging member (lr=inf): the ledger
# must show it quarantined mid-round while a finite winner is still named
python -m repro.launch.sweep --densities 0.25 --lrs 0.05,0.2,inf \
  --rounds 2 --steps-per-round 2 --batch 32 --samples 256 --eval-samples 64 \
  --block 32 --hidden 128 --engine jnp --tag "${TAG}-fault" \
  --out "SWEEP_${TAG}_fault.json"
python - "SWEEP_${TAG}_fault.json" <<'PY'
import json, math, sys
led = json.load(open(sys.argv[1]))
q = [m for m in led["members"] if m.get("quarantined_at") is not None]
if not q:
    sys.exit(f"[ci] {sys.argv[1]}: diverge-seeded sweep quarantined nobody")
w = led.get("winner")
if not (w and math.isfinite(w["eval_losses"][-1])):
    sys.exit(f"[ci] {sys.argv[1]}: no finite winner despite quarantine")
if any(m["member"] == w["member"] for m in q):
    sys.exit(f"[ci] {sys.argv[1]}: quarantined member named winner")
print(f"[ci] fault smoke: member(s) {[m['member'] for m in q]} quarantined "
      f"at {q[0]['quarantined_at']}, winner lr={w['config']['lr']} "
      f"eval_loss={w['eval_losses'][-1]:.4f}")
PY

echo "== quantized smoke (int8 quantize-at-load serve + bit-width sweep) =="
# quantize-at-load serving end to end: every sparse junction decodes
# through the int8 kernels (ServeConfig.quantize drops the fp weight
# leaves at load — core/quantize.quantize_tree)
python -m repro.launch.serve --arch stablelm-3b --reduce --sparse \
  --quantize int8 --requests 2 --prompt-len 8 --max-new 4
# E=4 bit-width quality-vs-speed sweep riding the population engine: one
# stacked int8 cohort (4 configs, one E-batched eval) whose ledger must
# name a finite winner
python -m repro.launch.quant_sweep --bits 8,6,4,3 --granularities block \
  --steps 4 --batch 32 --samples 256 --eval-samples 64 --calib-samples 64 \
  --hidden 128 --block 32 --engine jnp --tag "${TAG}-quant" \
  --out "QUANT_${TAG}.json"
python - "QUANT_${TAG}.json" <<'PY'
import json, math, sys
led = json.load(open(sys.argv[1]))
w = led.get("winner")
if not (w and math.isfinite(w["eval_loss"])):
    sys.exit(f"[ci] quant sweep ledger {sys.argv[1]} names no finite winner")
if len(led["records"]) != 4:
    sys.exit(f"[ci] quant sweep ran {len(led['records'])} configs, wanted 4")
print(f"[ci] quant sweep winner: {w['config']} "
      f"eval_loss={w['eval_loss']:.4f} "
      f"(delta vs fp32 {w['delta_vs_fp32']:+.4f})")
PY

echo "== serve smoke (continuous batching: arrival trace, compile-once) =="
# synthetic staggered-arrival trace through the continuous engine: every
# admitted request must complete with exactly its asked-for token count,
# and the fixed-shape contract must hold — the decode tick and prefill
# chunk each trace exactly once across the whole run (slot refills and
# page-table swaps change integers, never shapes)
python - "OBS_${TAG}_serve.jsonl" <<'PY'
import sys
import jax, numpy as np
from repro.configs import registry
from repro.core.sparsity import SparsityConfig
from repro.models import model as M
from repro.obs import Recorder
from repro.serve.engine import ContinuousEngine, Request, ServeConfig

cfg = registry.get("stablelm-3b").reduced().with_sparsity(
    SparsityConfig(density=0.25, block=32, where="ffn"))
params = M.init(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
reqs = [Request(rid=i, prompt=rng.integers(1, 64, size=6 + 3 * (i % 3))
                .astype(np.int32), max_new_tokens=3 + (i % 4), arrival=i)
        for i in range(6)]
# the flight recorder rides the whole trace — the compile-once assert
# below also guards the recorder's no-retrace contract (ISSUE 10)
rec = Recorder(sys.argv[1], meta={"launcher": "ci-serve-smoke"})
eng = ContinuousEngine(cfg, params, ServeConfig(
    eos_token=-1, slots=2, page_size=8, prefill_chunk=8, max_seq=32),
    recorder=rec)
outs = eng.serve(reqs)
rec.close()
st = eng.stats
if set(outs) != set(range(6)):
    raise SystemExit(f"[ci] serve smoke: incomplete requests {sorted(outs)}")
bad = [r.rid for r in reqs if len(outs[r.rid]) != r.max_new_tokens]
if bad:
    raise SystemExit(f"[ci] serve smoke: wrong token counts for {bad}")
if st["decode_traces"] != 1 or st["prefill_traces"] != 1:
    raise SystemExit(f"[ci] serve smoke: retraced — decode={st['decode_traces']} "
                     f"prefill={st['prefill_traces']} (fixed-shape contract broken)")
print(f"[ci] serve smoke: 6/6 requests, decode_ticks={st['decode_ticks']} "
      f"prefill_chunks={st['prefill_chunks']} "
      f"peak_pages={st['peak_pages']}/{st['num_pages']} traces=1/1, "
      f"telemetry -> {sys.argv[1]}")
PY

echo "== obs smoke (flight recorder: train telemetry + span reconstruction) =="
# short telemetry-on train run, then obs_report renders the merged train +
# serve timeline: exits nonzero unless every completed request in the
# serve trace above reconstructs a full span (enqueue <= admit <= first
# token <= finish) — the ISSUE 10 acceptance gate
python -m repro.launch.train --reduce --steps 8 --batch 2 --seq 64 \
  --ckpt "/tmp/obs_ci_ckpt_${TAG}" --ckpt-every 4 \
  --obs "OBS_${TAG}_train.jsonl"
python -m repro.launch.obs_report "OBS_${TAG}_train.jsonl" \
  "OBS_${TAG}_serve.jsonl" --check-spans --tag "$TAG" \
  --json "OBS_report_${TAG}.json"
python - "OBS_report_${TAG}.json" <<'PY'
import json, sys
rep = json.load(open(sys.argv[1]))
meta, report = rep["meta"], rep["report"]
if not meta.get("git_sha") or "backend" not in meta:
    sys.exit(f"[ci] obs report {sys.argv[1]} is missing the artifact stamp")
if report.get("train", {}).get("steps") != 8:
    sys.exit(f"[ci] obs report: expected 8 train steps, got "
             f"{report.get('train', {}).get('steps')}")
if report.get("serve", {}).get("requests") != 6:
    sys.exit(f"[ci] obs report: expected 6 serve spans, got "
             f"{report.get('serve', {}).get('requests')}")
print(f"[ci] obs smoke: {report['n_events']} events -> train "
      f"{report['train']['steps']} steps + {report['serve']['requests']} "
      f"full spans (sha {meta['git_sha']})")
PY

echo "== fast benches (engine incl. MoE + fused-update rows, sweep, serve, roofline, obs) =="
python -m benchmarks.run --only engine,roofline,serve,obs \
  --json "BENCH_${TAG}.json" --tag "$TAG"

python - "BENCH_${TAG}.json" benchmarks/BENCH_baseline.json "$FAIL_ON_REGRESS" <<'PY'
import sys
from benchmarks.run import load_artifact

# Per-row slowdown thresholds (new/old ratio).  The single-call-dominated
# MoE rows jitter more off-TPU than the plain junction rows; fused-update
# and sweep rows time whole train steps and inherit that noise.
DEFAULT_THRESHOLD = 1.2
THRESHOLDS = {
    "engine.moe.jnp": 1.35,
    "engine.moe.pallas": 1.35,
    "engine.update.moe.jnp": 1.4,
    "engine.update.moe.pallas": 1.4,
    "engine.update.adam.moe.jnp": 1.4,
    "engine.update.adam.moe.pallas": 1.4,
    "bench.sweep.mnist.population": 1.5,
    "bench.sweep.mnist.sequential": 1.5,
    "engine.infer.int8.moe.jnp": 1.35,
    "engine.infer.int8.moe.pallas": 1.35,
    "bench.quant.sweep": 1.5,
    # whole-trace serving rows: host scheduler + many small dispatches,
    # the noisiest rows in the table off-TPU (~2x spread across idle
    # runs of this box against the per-row-MIN baseline)
    "bench.serve.static": 2.5,
    "bench.serve.continuous": 2.5,
    # whole train-loop + serve-trace timing (recorder-on wall time);
    # same host-dispatch noise class as the serve rows
    "bench.obs.overhead": 2.5,
}

path, base_path, fail_on_regress = sys.argv[1], sys.argv[2], sys.argv[3] == "1"
meta, results = load_artifact(path)
if not results:
    sys.exit(f"[ci] empty bench artifact {path} — benches ran nothing")
print(f"[ci] {path}: {len(results)} bench entries "
      f"(sha {meta.get('git_sha', 'unstamped')}, "
      f"backend {meta.get('backend', '?')})")

try:
    _, base = load_artifact(base_path)
except (OSError, ValueError) as e:  # missing OR unreadable: stay non-fatal
    print(f"[ci] no usable baseline at {base_path} ({e.__class__.__name__}) "
          f"— skipping perf comparison")
    sys.exit(0)
slow = []
for name in sorted(base):
    # engine.* kernel rows AND bench.* subsystem rows (the population
    # sweep) are both ratcheted against the committed baseline
    if not name.startswith(("engine.", "bench.")) or name not in results:
        continue
    new, old = results[name], base[name]
    thresh = THRESHOLDS.get(name, DEFAULT_THRESHOLD)
    ratio = new / old if old else float("inf")
    flag = f"  <-- {'FAIL' if fail_on_regress else 'WARN'} >{thresh:.2f}x" \
        if ratio > thresh else ""
    print(f"[ci]   {name}: {old:.0f} -> {new:.0f} us ({ratio:.2f}x){flag}")
    if ratio > thresh:
        slow.append(name)
if slow:
    msg = (f"{len(slow)} tracked bench row(s) slower than their baseline "
           f"threshold ({', '.join(slow)})")
    if fail_on_regress:
        sys.exit(f"[ci] FAIL: {msg}")
    print(f"[ci] WARNING: {msg} — non-fatal, investigate before "
          f"refreshing benchmarks/BENCH_baseline.json")
PY

echo "== ci.sh OK =="
