#!/usr/bin/env bash
# CI entry point: tier-1 test suite + the fast machine-trackable benches.
#
#   ./ci.sh            # tests + engine/roofline benches, BENCH_ci.json
#   BENCH_TAG=pr42 ./ci.sh
#
# Fails on test failures, bench harness errors (benchmarks/run.py exits
# nonzero when any bench raises or --only names an unknown bench), or an
# empty bench artifact (guards the silent-no-op class of regressions).
# Additionally compares the fresh artifact against the committed
# benchmarks/BENCH_baseline.json and WARNS (non-fatal — interpret-mode
# timings are noisy off-TPU) when any engine.* row slowed >20%, so the
# perf trajectory is visible in CI output.
set -euo pipefail
cd "$(dirname "$0")"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

TAG="${BENCH_TAG:-ci}"
echo "== fast benches (engine incl. MoE rows, roofline) =="
python -m benchmarks.run --only engine,roofline --json "BENCH_${TAG}.json"

python - "BENCH_${TAG}.json" benchmarks/BENCH_baseline.json <<'PY'
import sys
from benchmarks.run import load_artifact

path, base_path = sys.argv[1], sys.argv[2]
meta, results = load_artifact(path)
if not results:
    sys.exit(f"[ci] empty bench artifact {path} — benches ran nothing")
print(f"[ci] {path}: {len(results)} bench entries "
      f"(sha {meta.get('git_sha', 'unstamped')}, "
      f"backend {meta.get('backend', '?')})")

try:
    _, base = load_artifact(base_path)
except (OSError, ValueError) as e:  # missing OR unreadable: stay non-fatal
    print(f"[ci] no usable baseline at {base_path} ({e.__class__.__name__}) "
          f"— skipping perf comparison")
    sys.exit(0)
slow = []
for name in sorted(base):
    if not name.startswith("engine.") or name not in results:
        continue
    new, old = results[name], base[name]
    ratio = new / old if old else float("inf")
    flag = "  <-- WARN >20% slower" if ratio > 1.2 else ""
    print(f"[ci]   {name}: {old:.0f} -> {new:.0f} us ({ratio:.2f}x){flag}")
    if ratio > 1.2:
        slow.append(name)
if slow:
    print(f"[ci] WARNING: {len(slow)} engine.* row(s) >20% slower than "
          f"baseline ({', '.join(slow)}) — non-fatal, investigate before "
          f"refreshing benchmarks/BENCH_baseline.json")
PY

echo "== ci.sh OK =="
