"""jit-able train / serve step functions — the units the launcher lowers.

These are pure functions of (params, opt_state, batch); distribution comes
entirely from the in/out shardings the launcher attaches (parallel/sharding.py),
so the same step runs on 1 CPU device (smoke tests) or a 512-chip mesh
(dry-run) unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import sparse_linear as sl
from repro.kernels import block_sparse_matmul as bsm
from repro.models import model as M
from repro.optim import FusedOptimizer, Optimizer, global_norm_scale


def _resolve_engine(cfg: ArchConfig) -> ArchConfig:
    """Pin engine="auto" to a concrete path once, at step-build time, so
    the traced graph never depends on a backend query mid-trace and the
    jit cache key is stable."""
    eng = sl.resolve_engine(cfg.engine)
    return cfg if eng == cfg.engine else dataclasses.replace(cfg, engine=eng)


def fused_update_eligible(cfg: ArchConfig, optimizer: Optimizer,
                          microbatches: int = 1) -> tuple[bool, str]:
    """(ok, reason) — whether the fused BP+UP path can serve this step.
    Resolved ONCE at step-build time; every refusal falls back to the
    two-pass reference path (grads materialized, optimizer.update), never
    to silently different numerics."""
    cfg = _resolve_engine(cfg)
    if not cfg.fused_update:
        return False, "ArchConfig.fused_update is off"
    if cfg.engine != "pallas":
        return False, "engine is not pallas (jnp keeps the two-pass reference)"
    if not isinstance(optimizer, FusedOptimizer):
        return False, ("optimizer is not a FusedOptimizer "
                       "(optim.fused_sgd / optim.fused_adam)")
    if cfg.family == "hybrid":
        # the shared attn/MLP block is applied once per super-layer, and
        # JAX SUMS cotangents across uses — but a fused junction's
        # cotangent IS the updated parameter, so summing corrupts any
        # weight-shared junction.  Refuse, don't corrupt.
        return False, ("hybrid shares one attn/MLP block across "
                       "super-layers — reused junction weights break the "
                       "updated-params cotangent contract")
    # grad_clip: served fused via a norm pre-pass folded into the hyp
    # row's gs column.  microbatches > 1: served fused by running the
    # full batch — mean of equal-sized microbatch means IS the full-batch
    # mean, and the kernels' M-innermost flush applies the update exactly
    # once per tile regardless.  Neither refuses anymore.
    if cfg.cast_params_once:
        return False, "cast_params_once re-materializes the weights"
    if cfg.param_dtype != cfg.dtype:
        return False, ("fused update requires param_dtype == dtype (the "
                       "kernels update the compute-dtype weights in place)")
    return True, "fused"


def collect_junction_health(grads) -> jax.Array:
    """Sum the injected health leaves' cotangents out of a fused step's
    grads tree — each is the update kernels' per-unit count of non-finite
    parameter tiles (kernels/block_sparse_matmul.py with_health contract).
    Returns a f32 scalar; > 0 ⇔ at least one junction unit just wrote
    non-finite parameters in-place."""
    total = jnp.zeros((), jnp.float32)

    def rec(t):
        nonlocal total
        if isinstance(t, dict):
            for k, v in t.items():
                if k in sl.HEALTH_LEAVES and not isinstance(v, dict):
                    total = total + jnp.sum(v.astype(jnp.float32))
                elif isinstance(v, (dict, list, tuple)):
                    rec(v)
        elif isinstance(t, (list, tuple)):
            for v in t:
                rec(v)

    rec(grads)
    return total


def count_nonfinite_grads(grads) -> jax.Array:
    """Two-pass detector: number of trainable gradient leaves carrying any
    non-finite value.  The materialized-gradient twin of the fused path's
    in-kernel health flags — same metrics["nonfinite"] contract, > 0 ⇔
    this update would poison the parameters."""
    total = jnp.zeros((), jnp.float32)
    for g in jax.tree.leaves(grads):
        if hasattr(g, "dtype") and jnp.issubdtype(g.dtype, jnp.inexact):
            total = total + jnp.any(~jnp.isfinite(g)).astype(jnp.float32)
    return total


def scale_params_delta(params, new_params, lr_scale):
    """Exact lr backoff for an already-applied first-order update:
    p' = p + s * (p_new - p).  For SGD(+momentum) the delta IS -lr * mv,
    so scaling it equals running the step at lr * s; optimizer state
    (momenta / Adam moments) is lr-free and needs no rescaling.  The
    interpolation runs in f32 and casts back, touching only inexact
    leaves (patterns ride through from new_params)."""
    def blend(p0, p1):
        if not jnp.issubdtype(p1.dtype, jnp.inexact):
            return p1
        d = p1.astype(jnp.float32) - p0.astype(jnp.float32)
        return (p0.astype(jnp.float32) + lr_scale * d).astype(p1.dtype)
    return jax.tree.map(blend, params, new_params)


def _make_fused_train_step(cfg: ArchConfig, optimizer: FusedOptimizer):
    """The fused BP+UP step: the paper's concurrent backprop+update made
    literal.  The optimizer's accumulator slots and its (HYP_K,) registry
    row are injected into every junction dict before differentiating; the
    junction custom_vjp applies the update inside the backward kernels
    (weight gradients never reach HBM) and returns the UPDATED params /
    slot buffers as those leaves' cotangents; optimizer.merge adopts them
    and tree-maps only the dense leaves.

    ``lr_scale`` (guardian backoff) multiplies the lr column of the hyp
    row BEFORE injection — the backed-off rate rides the existing
    hyp-table operand into the kernels, no retrace of the kernel graph.
    ``grad_clip`` is served by a norm pre-pass: an extra backward over
    the PLAIN (non-injected) loss computes the same global norm the
    two-pass reference clips with, and its scale folds into the gs
    column (and merge's grad_scale) — exact, at the cost of a second
    backward.  metrics["nonfinite"] sums the junctions' in-kernel health
    flags (the only divergence signal on this path: gradients never
    reach HBM)."""
    def loss(aug_params, batch):
        return M.loss_fn(cfg, aug_params, batch)

    vg = jax.value_and_grad(loss, has_aux=True, allow_int=True)

    plain_vg = None
    if optimizer.grad_clip is not None:
        plain_vg = jax.value_and_grad(
            lambda params, batch: M.loss_fn(cfg, params, batch),
            has_aux=True, allow_int=True)

    def train_step(params, opt_state, batch, step, lr_scale=None):
        hyp = optimizer.hyp(step)
        grad_scale = None
        if plain_vg is not None:
            _, raw = plain_vg(params, batch)
            grad_scale, _ = global_norm_scale(raw, optimizer.grad_clip)
            hyp = hyp.at[bsm.COL_GS].multiply(grad_scale)
        if lr_scale is not None:
            hyp = hyp.at[bsm.COL_LR].multiply(jnp.float32(lr_scale))
        aug = sl.inject_update_ctx(params, optimizer.slots(opt_state), hyp)
        (l, metrics), grads = vg(aug, batch)
        new_params, new_opt = optimizer.merge(grads, opt_state, params, step,
                                              lr_scale=lr_scale,
                                              grad_scale=grad_scale)
        metrics = dict(metrics, loss=l,
                       nonfinite=collect_junction_health(grads))
        return new_params, new_opt, metrics

    return train_step


def make_train_step(cfg: ArchConfig, optimizer: Optimizer,
                    microbatches: int = 1, *, jit: bool = True,
                    donate: bool = True):
    """Returns train_step(params, opt_state, batch, step[, lr_scale])
    -> (params, opt_state, metrics).

    ``lr_scale`` (optional, guardian backoff) scales the effective
    learning rate of this one step: the fused path folds it into the
    hyp-table operand, the two-pass path rescales the applied parameter
    delta (exact for first-order rules).  metrics["nonfinite"] > 0 flags
    an update that wrote (fused: in-kernel health flags) or would write
    (two-pass: materialized-grad scan) non-finite parameters.

    By default the step comes back jit-compiled with params/opt_state
    DONATED (donate_argnums=(0, 1)): the caller's buffers are reused for
    the outputs instead of doubling peak memory across the update.  Pass
    donate=False when the caller must keep its input trees alive, or
    jit=False to get the raw function (launchers that attach shardings /
    lower explicitly).

    With microbatches > 1 the two-pass path splits the batch and
    accumulates gradients in a scan — per-microbatch psums overlap with
    the next microbatch's compute (the paper's operational
    parallelization applied at the pod scale).  The fused path instead
    runs the full batch in one shot: mean of equal-sized microbatch
    means equals the full-batch mean, and the kernels' M-innermost flush
    applies the update exactly once per tile.

    When ``cfg.fused_update`` holds and the config/optimizer are eligible
    (fused_update_eligible), the returned step runs the fused BP+UP path;
    otherwise the two-pass reference below."""
    cfg = _resolve_engine(cfg)
    fused, _ = fused_update_eligible(cfg, optimizer, microbatches)
    if fused:
        step_fn = _make_fused_train_step(cfg, optimizer)
        if jit:
            return jax.jit(step_fn, donate_argnums=(0, 1) if donate else ())
        return step_fn

    def loss(params, batch):
        if cfg.cast_params_once:
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if p.dtype == jnp.float32 else p, params)
        return M.loss_fn(cfg, params, batch)

    # allow_int: sparse layers carry int32 pattern arrays in params — their
    # "gradients" are float0 placeholders the optimizer never touches
    vg = jax.value_and_grad(loss, has_aux=True, allow_int=True)

    def _inexact(t):
        return jnp.issubdtype(t.dtype, jnp.inexact)

    def train_step(params, opt_state, batch, step, lr_scale=None):
        if microbatches == 1:
            (l, metrics), grads = vg(params, batch)
        else:
            mb = jax.tree.map(
                lambda t: t.reshape(microbatches, t.shape[0] // microbatches,
                                    *t.shape[1:]), batch)

            def acc_fn(carry, b):
                (l_a, g_a) = carry
                (l, m), g = vg(params, b)
                g_acc = jax.tree.map(
                    lambda a, gg: a + gg if _inexact(gg) else a, g_a, g)
                return (l_a + l, g_acc), m

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32)
                if _inexact(p) else jnp.zeros((), jnp.float32), params)
            (l, grads), ms = jax.lax.scan(acc_fn, (0.0, zeros), mb)
            l = l / microbatches
            grads = jax.tree.map(
                lambda g: g / microbatches if _inexact(g) else g, grads)
            metrics = jax.tree.map(lambda t: t[-1], ms)
        new_params, new_opt = optimizer.update(grads, opt_state, params, step)
        if lr_scale is not None:
            # optimizer.update has no lr hook; scaling the applied delta is
            # exact for first-order rules (delta = -lr * mv) and leaves the
            # lr-free optimizer state untouched
            new_params = scale_params_delta(params, new_params, lr_scale)
        metrics = dict(metrics, loss=l,
                       nonfinite=count_nonfinite_grads(grads))
        return new_params, new_opt, metrics

    if jit:
        return jax.jit(train_step, donate_argnums=(0, 1) if donate else ())
    return train_step


def make_prefill_step(cfg: ArchConfig):
    cfg = _resolve_engine(cfg)

    def prefill(params, batch):
        logits, cache, _ = M.forward(cfg, params, batch, return_cache=True,
                                     last_only=True)
        return logits, cache
    return prefill


def make_decode_step(cfg: ArchConfig, *, paged: bool = False):
    """Decode tick builder.  The default (static) step carries the
    step-locked scalar position; ``paged=True`` returns the
    continuous-batching tick, where per-slot position counters and the
    page table replace the scalar ``S + i`` argument:
    decode(params, pool, token [B,1], positions [B], page_table [B,maxp])
    -> (logits [B,1,V], pool)."""
    cfg = _resolve_engine(cfg)

    if paged:
        def decode_paged(params, pool, token, positions, page_table):
            return M.paged_decode_step(cfg, params, pool, token, positions,
                                       page_table)
        return decode_paged

    def decode(params, cache, token, pos):
        return M.decode_step(cfg, params, cache, token, pos)
    return decode


def make_paged_prefill_step(cfg: ArchConfig):
    """Chunked-prefill step for the continuous-batching engine:
    prefill(params, pool, tokens [1,C], base, page_table_row [maxp],
    chunk_len) -> (last_logits [1,1,V], pool)."""
    cfg = _resolve_engine(cfg)

    def prefill_chunk(params, pool, tokens, base, page_table_row, chunk_len):
        return M.paged_prefill_chunk(cfg, params, pool, tokens, base,
                                     page_table_row, chunk_len)
    return prefill_chunk


def make_eval_step(cfg: ArchConfig):
    cfg = _resolve_engine(cfg)

    def evaluate(params, batch):
        l, metrics = M.loss_fn(cfg, params, batch)
        return dict(metrics, loss=l)
    return evaluate
