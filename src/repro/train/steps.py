"""jit-able train / serve step functions — the units the launcher lowers.

These are pure functions of (params, opt_state, batch); distribution comes
entirely from the in/out shardings the launcher attaches (parallel/sharding.py),
so the same step runs on 1 CPU device (smoke tests) or a 512-chip mesh
(dry-run) unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import sparse_linear as sl
from repro.models import model as M
from repro.optim import Optimizer


def _resolve_engine(cfg: ArchConfig) -> ArchConfig:
    """Pin engine="auto" to a concrete path once, at step-build time, so
    the traced graph never depends on a backend query mid-trace and the
    jit cache key is stable."""
    eng = sl.resolve_engine(cfg.engine)
    return cfg if eng == cfg.engine else dataclasses.replace(cfg, engine=eng)


def make_train_step(cfg: ArchConfig, optimizer: Optimizer,
                    microbatches: int = 1):
    """Returns train_step(params, opt_state, batch, step) -> (params, opt_state, metrics).

    With microbatches > 1 the batch is split and gradients accumulated in a
    scan — per-microbatch psums overlap with the next microbatch's compute
    (the paper's operational parallelization applied at the pod scale)."""
    cfg = _resolve_engine(cfg)

    def loss(params, batch):
        if cfg.cast_params_once:
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if p.dtype == jnp.float32 else p, params)
        return M.loss_fn(cfg, params, batch)

    # allow_int: sparse layers carry int32 pattern arrays in params — their
    # "gradients" are float0 placeholders the optimizer never touches
    vg = jax.value_and_grad(loss, has_aux=True, allow_int=True)

    def _inexact(t):
        return jnp.issubdtype(t.dtype, jnp.inexact)

    def train_step(params, opt_state, batch, step):
        if microbatches == 1:
            (l, metrics), grads = vg(params, batch)
        else:
            mb = jax.tree.map(
                lambda t: t.reshape(microbatches, t.shape[0] // microbatches,
                                    *t.shape[1:]), batch)

            def acc_fn(carry, b):
                (l_a, g_a) = carry
                (l, m), g = vg(params, b)
                g_acc = jax.tree.map(
                    lambda a, gg: a + gg if _inexact(gg) else a, g_a, g)
                return (l_a + l, g_acc), m

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32)
                if _inexact(p) else jnp.zeros((), jnp.float32), params)
            (l, grads), ms = jax.lax.scan(acc_fn, (0.0, zeros), mb)
            l = l / microbatches
            grads = jax.tree.map(
                lambda g: g / microbatches if _inexact(g) else g, grads)
            metrics = jax.tree.map(lambda t: t[-1], ms)
        new_params, new_opt = optimizer.update(grads, opt_state, params, step)
        metrics = dict(metrics, loss=l)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig):
    cfg = _resolve_engine(cfg)

    def prefill(params, batch):
        logits, cache, _ = M.forward(cfg, params, batch, return_cache=True,
                                     last_only=True)
        return logits, cache
    return prefill


def make_decode_step(cfg: ArchConfig):
    cfg = _resolve_engine(cfg)

    def decode(params, cache, token, pos):
        return M.decode_step(cfg, params, cache, token, pos)
    return decode


def make_eval_step(cfg: ArchConfig):
    cfg = _resolve_engine(cfg)

    def evaluate(params, batch):
        l, metrics = M.loss_fn(cfg, params, batch)
        return dict(metrics, loss=l)
    return evaluate
