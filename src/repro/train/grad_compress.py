"""int8 error-feedback gradient compression for slow (cross-pod) links.

The multi-pod mesh all-reduces gradients across the pod axis over DCI —
the slowest hop.  Compressing to int8 with per-tensor scale cuts that
traffic 4x (vs fp32 masters); the quantization residual is fed back into
the next step's gradient (error feedback), which keeps SGD/Adam convergence
(Karimireddy et al.-style argument; validated empirically in
tests/test_grad_compress.py on a small model).

Usage: wrap an Optimizer — state grows an ``err`` buffer per leaf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer, _is_trainable


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(g: jax.Array, err: jax.Array):
    """Returns (compressed-then-restored gradient, new error residual)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    restored = dequantize_int8(q, scale)
    return restored, corrected - restored


def compressed(base: Optimizer) -> Optimizer:
    def init(params):
        err = jax.tree.map(
            lambda p: jnp.zeros_like(p, jnp.float32) if _is_trainable(p)
            else jnp.zeros((), jnp.float32), params)
        return {"base": base.init(params), "err": err}

    def update(grads, state, params, step):
        pairs = jax.tree.map(
            lambda g, e: compress_decompress(g, e) if _is_trainable(g)
            else (g, e), grads, state["err"],
            is_leaf=lambda x: isinstance(x, jax.Array) or hasattr(x, "dtype"))
        new_grads = jax.tree.map(lambda t: t[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda t: t[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        new_params, new_base = base.update(new_grads, state["base"], params, step)
        return new_params, {"base": new_base, "err": new_err}

    return Optimizer(init, update)
