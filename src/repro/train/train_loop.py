"""Production train loop: checkpoint/restart, straggler watch, metrics.

Fault-tolerance contract:
  * auto-resume from the latest complete checkpoint (params, optimizer,
    data-iterator state, step — bitwise identical continuation),
  * async checkpoint every ``ckpt_every`` steps + always on exit,
  * crash injection hook for tests (``fail_at_step``),
  * straggler mitigation: per-step wall-times tracked in a rolling window;
    steps slower than ``straggler_factor`` x median raise an alarm through
    ``on_straggler`` (at fleet scale this triggers hot-spare swap; here it
    is logged and counted — the decision logic is what we can test without
    hardware).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.train import checkpoint as ckpt_mod


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 100
    log_every: int = 10
    straggler_window: int = 50
    straggler_factor: float = 3.0
    fail_at_step: Optional[int] = None      # test hook: simulated crash


class StragglerMonitor:
    def __init__(self, window: int, factor: float,
                 on_straggler: Callable[[int, float, float], None] | None = None):
        self.times = deque(maxlen=window)
        self.factor = factor
        self.count = 0
        self.on_straggler = on_straggler or (lambda *a: None)

    def observe(self, step: int, dt: float):
        if len(self.times) >= 8:
            med = float(np.median(self.times))
            if dt > self.factor * med:
                self.count += 1
                self.on_straggler(step, dt, med)
        self.times.append(dt)


def run(cfg: TrainLoopConfig, train_step, params, opt_state, pipeline,
        log: Callable[[str], None] = print) -> dict:
    """Returns {params, opt_state, step, metrics_history, straggler_count}.

    ``train_step(params, opt_state, batch, step) -> (params, opt_state, metrics)``
    must be jit-compiled by the caller (with shardings attached for
    multi-device runs).  ``pipeline`` is a restartable iterator with
    ``state()`` / ``from_state`` (data/pipeline.py).
    """
    saver = ckpt_mod.AsyncSaver()
    start_step = 0
    state_like = {"params": params, "opt": opt_state}
    found = ckpt_mod.latest_step(cfg.ckpt_dir)
    if found is not None:
        tree, extra = ckpt_mod.restore(cfg.ckpt_dir, found, state_like)
        params, opt_state = tree["params"], tree["opt"]
        start_step = extra["step"]
        pipeline.step = extra["data_state"]["step"]
        pipeline.seed = extra["data_state"]["seed"]
        log(f"[train] resumed from step {start_step}")

    mon = StragglerMonitor(cfg.straggler_window, cfg.straggler_factor,
                           on_straggler=lambda s, dt, med: log(
                               f"[straggler] step {s}: {dt*1e3:.1f}ms vs median {med*1e3:.1f}ms"))
    history = []
    step = start_step
    try:
        while step < cfg.total_steps:
            if cfg.fail_at_step is not None and step == cfg.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            batch = next(pipeline)
            t0 = time.perf_counter()
            params, opt_state, metrics = train_step(
                params, opt_state, jax.tree.map(jax.numpy.asarray, batch),
                jax.numpy.asarray(step))
            loss = float(metrics["loss"])   # blocks: honest step timing
            dt = time.perf_counter() - t0
            mon.observe(step, dt)
            step += 1
            if step % cfg.log_every == 0 or step == cfg.total_steps:
                history.append({"step": step, "loss": loss, "dt_s": dt})
                log(f"[train] step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
            if step % cfg.ckpt_every == 0:
                saver.save(cfg.ckpt_dir, step,
                           {"params": params, "opt": opt_state},
                           extra={"step": step, "data_state": pipeline.state()})
    finally:
        saver.wait()
        ckpt_mod.save(cfg.ckpt_dir, step,
                      {"params": params, "opt": opt_state},
                      extra={"step": step, "data_state": pipeline.state()})
    return {"params": params, "opt_state": opt_state, "step": step,
            "history": history, "straggler_count": mon.count}
