"""Production train loop: checkpoint/restart, divergence guardian,
straggler watch, metrics.

Fault-tolerance contract:
  * auto-resume from the newest VERIFIABLE checkpoint (params, optimizer,
    data-iterator state, step — bitwise identical continuation; a
    corrupted latest checkpoint falls back to the next-newest),
  * async checkpoint every ``ckpt_every`` steps + always on exit, with
    optional ``keep_last_k`` retention GC,
  * crash injection hook for tests (``fail_at_step``),
  * straggler mitigation: per-step wall-times tracked in a rolling window;
    steps slower than ``straggler_factor`` x median raise an alarm through
    ``on_straggler`` (at fleet scale this triggers hot-spare swap; here it
    is logged and counted — the decision logic is what we can test without
    hardware).

Divergence guardian (``GuardianConfig``): the fused BP+UP path updates
weights in-place inside the kernels — one non-finite dw destroys the
parameter state with no HBM gradient left to inspect.  The guardian
closes the loop around the in-kernel detector (metrics["nonfinite"],
kernels/block_sparse_matmul.py health flags) plus loss sentinels:

  * **sentinels** — trip on a non-finite loss, on nonfinite > 0 (the
    update just wrote non-finite parameters), or on a loss spike beyond
    ``spike_factor`` x the rolling-window median;
  * **healthy promotion** — a checkpoint becomes a rollback target only
    after SURVIVING ``health_window`` further steps without a trip
    (a checkpoint written next to silent corruption must never be
    restored into);
  * **rollback + backoff** — on trip: restore the latest healthy-marked
    checkpoint, shrink the effective lr by ``lr_backoff`` (threaded
    through the train step's ``lr_scale`` operand — the fused path folds
    it into the existing hyp table, no retrace), skip the offending
    batch on replay, and retry;
  * **bounded retries** — after ``max_retries`` trips the loop raises
    ``GuardianTripped`` with the full trip history instead of looping
    forever on an unrecoverable run.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.obs import telemetry as obs
from repro.train import checkpoint as ckpt_mod


@dataclasses.dataclass
class GuardianConfig:
    window: int = 32            # rolling loss window for the spike sentinel
    spike_factor: float = 10.0  # trip when loss > factor * window median
    min_history: int = 8        # spike sentinel armed after this many losses
    health_window: int = 10     # steps a checkpoint must survive → healthy
    lr_backoff: float = 0.5     # lr_scale multiplier per trip
    max_retries: int = 3        # trips before giving up
    skip_offending_batch: bool = True


class GuardianTripped(RuntimeError):
    """Raised when the guardian exhausts ``max_retries`` — the run is not
    recoverable by rollback + backoff alone."""

    def __init__(self, msg: str, trips: list[dict]):
        super().__init__(msg)
        self.trips = trips


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 100
    log_every: int = 10
    straggler_window: int = 50
    straggler_factor: float = 3.0
    fail_at_step: Optional[int] = None      # test hook: simulated crash
    guardian: Optional[GuardianConfig] = None
    keep_last_k: Optional[int] = None       # retention GC (None = keep all)
    full_checksum: bool = False             # digest every byte at save time


class StragglerMonitor:
    def __init__(self, window: int, factor: float,
                 on_straggler: Callable[[int, float, float], None] | None = None):
        self.times = deque(maxlen=window)
        self.factor = factor
        self.count = 0
        self.on_straggler = on_straggler or (lambda *a: None)

    def observe(self, step: int, dt: float):
        if len(self.times) >= 8:
            med = float(np.median(self.times))
            if dt > self.factor * med:
                self.count += 1
                self.on_straggler(step, dt, med)
        self.times.append(dt)


def _batch_tokens(batch) -> int:
    """Token count of a host-side batch for tokens/s: the ``tokens``
    field's element count when present (LM pipelines), else the leading
    dim of the first array leaf (generic supervised batches)."""
    if isinstance(batch, dict) and "tokens" in batch:
        return int(np.asarray(batch["tokens"]).size)
    leaves = jax.tree.leaves(batch)
    return int(np.asarray(leaves[0]).shape[0]) if leaves else 0


def _restore_into(cfg, step, state_like, pipeline):
    tree, extra = ckpt_mod.restore(cfg.ckpt_dir, step, state_like)
    pipeline.step = extra["data_state"]["step"]
    pipeline.seed = extra["data_state"]["seed"]
    return tree["params"], tree["opt"], extra["step"]


def run(cfg: TrainLoopConfig, train_step, params, opt_state, pipeline,
        log: Callable[[str], None] = print,
        recorder: "obs.Recorder | None" = None) -> dict:
    """Returns {params, opt_state, step, history, straggler_count, guardian}.

    ``train_step(params, opt_state, batch, step[, lr_scale]) ->
    (params, opt_state, metrics)`` must be jit-compiled by the caller
    (with shardings attached for multi-device runs); the 5-arg form
    (train/steps.make_train_step provides it) is required only when a
    ``GuardianConfig`` is set.  ``pipeline`` is a restartable iterator
    with ``state()`` / seed+step attributes (data/pipeline.py).

    ``recorder`` (obs.Recorder) gets one ``TrainStep`` event per ADOPTED
    step plus ``Guardian`` (trip/rollback/backoff/recovery) and
    ``Checkpoint`` (save/promote/gc) lifecycle events.  No-extra-device-
    sync: every recorded value is one the loop already fetched for its
    own logic — ``loss`` is synced for honest step timing regardless,
    ``nonfinite`` only on the guardian path (``obs.NOT_SAMPLED`` when
    the guardian is off rather than forcing a transfer).
    """
    g = cfg.guardian
    saver = ckpt_mod.AsyncSaver()
    state_like = {"params": params, "opt": opt_state}

    def _save_extra():
        return {"step": step, "data_state": pipeline.state()}

    start_step = 0
    found, tree, extra = ckpt_mod.restore_latest(cfg.ckpt_dir, state_like,
                                                 log=log)
    if found is not None:
        params, opt_state = tree["params"], tree["opt"]
        start_step = extra["step"]
        pipeline.step = extra["data_state"]["step"]
        pipeline.seed = extra["data_state"]["seed"]
        log(f"[train] resumed from step {start_step}")

    step = start_step
    # guardian state
    lr_scale = 1.0
    trips: list[dict] = []
    bad_data_steps: set[int] = set()
    loss_win: deque = deque(maxlen=g.window) if g else deque()
    pending_healthy: list[int] = []
    if g is not None and ckpt_mod.latest_healthy_step(cfg.ckpt_dir) is None:
        # anchor: the pre-training (or just-resumed) state is the rollback
        # floor until a later checkpoint survives the health window
        if found is None:
            ckpt_mod.save(cfg.ckpt_dir, step,
                          {"params": params, "opt": opt_state},
                          extra=_save_extra(),
                          full_checksum=cfg.full_checksum)
        ckpt_mod.mark_healthy(cfg.ckpt_dir, step)

    mon = StragglerMonitor(cfg.straggler_window, cfg.straggler_factor,
                           on_straggler=lambda s, dt, med: log(
                               f"[straggler] step {s}: {dt*1e3:.1f}ms vs median {med*1e3:.1f}ms"))
    history = []
    rec = recorder
    dt_ema: float | None = None
    awaiting_recovery = False
    try:
        while step < cfg.total_steps:
            if cfg.fail_at_step is not None and step == cfg.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            data_step = pipeline.state()["step"] if g is not None else None
            batch = next(pipeline)
            if g is not None and data_step in bad_data_steps:
                log(f"[guardian] skipping poisoned batch "
                    f"(data step {data_step})")
                continue
            t0 = time.perf_counter()
            args = (params, opt_state,
                    jax.tree.map(jax.numpy.asarray, batch),
                    jax.numpy.asarray(step))
            if g is not None:
                new_params, new_opt, metrics = train_step(
                    *args, jax.numpy.float32(lr_scale))
            else:
                new_params, new_opt, metrics = train_step(*args)
            loss = float(metrics["loss"])   # blocks: honest step timing
            dt = time.perf_counter() - t0

            if g is not None:
                nonfinite = float(metrics.get("nonfinite", 0.0))
                why = None
                if not np.isfinite(loss):
                    why = f"non-finite loss {loss}"
                elif nonfinite > 0:
                    why = (f"{int(nonfinite)} non-finite update "
                           "leaves/tiles (in-kernel health flags)")
                elif len(loss_win) >= g.min_history:
                    med = float(np.median(loss_win))
                    if loss > g.spike_factor * max(med, 1e-12):
                        why = (f"loss spike {loss:.4g} > "
                               f"{g.spike_factor}x median {med:.4g}")
                if why is not None:
                    # the offending update is DISCARDED (new_params never
                    # adopted); roll back to the last healthy checkpoint
                    trips.append({"step": step, "data_step": data_step,
                                  "reason": why, "lr_scale": lr_scale})
                    if rec is not None:
                        rec.count("train.guardian.trips")
                        rec.emit(obs.Guardian(
                            action="trip", step=step,
                            detail={"reason": why, "data_step": data_step,
                                    "lr_scale": lr_scale}))
                    if g.skip_offending_batch:
                        bad_data_steps.add(data_step)
                    if len(trips) > g.max_retries:
                        raise GuardianTripped(
                            f"guardian exhausted {g.max_retries} retries; "
                            f"last trip at step {step}: {why} "
                            f"(trip history: {trips})", trips)
                    saver.wait()
                    h = ckpt_mod.latest_healthy_step(cfg.ckpt_dir)
                    if h is None:
                        raise GuardianTripped(
                            f"guardian tripped at step {step} ({why}) with "
                            "no healthy checkpoint to roll back to", trips)
                    tripped_at = step
                    params, opt_state, step = _restore_into(
                        cfg, h, state_like, pipeline)
                    lr_scale *= g.lr_backoff
                    loss_win.clear()
                    pending_healthy.clear()
                    if rec is not None:
                        rec.emit(obs.Guardian(
                            action="rollback", step=step,
                            detail={"from_step": tripped_at}))
                        rec.emit(obs.Guardian(
                            action="backoff", step=step,
                            detail={"lr_scale": lr_scale}))
                        rec.gauge("train.lr_scale", lr_scale)
                    awaiting_recovery = True
                    log(f"[guardian] TRIP: {why} — rolled back to healthy "
                        f"step {step}, lr_scale -> {lr_scale:.4g}, retry "
                        f"{len(trips)}/{g.max_retries}")
                    continue
                loss_win.append(loss)

            params, opt_state = new_params, new_opt
            mon.observe(step, dt)
            if rec is not None:
                if awaiting_recovery:
                    # first step adopted after a rollback: the run is live
                    # again at the reduced lr
                    rec.emit(obs.Guardian(
                        action="recovery", step=step,
                        detail={"trips": len(trips),
                                "lr_scale": lr_scale}))
                    awaiting_recovery = False
                dt_ema = dt if dt_ema is None else 0.9 * dt_ema + 0.1 * dt
                n_tok = _batch_tokens(batch)
                rec.count("train.steps")
                rec.observe("train.dt_s", dt)
                rec.emit(obs.TrainStep(
                    step=step, loss=loss,
                    nonfinite=(nonfinite if g is not None
                               else obs.NOT_SAMPLED),
                    lr_scale=lr_scale, dt_s=dt, dt_ema_s=dt_ema,
                    tokens_per_s=(n_tok / dt if dt > 0 else 0.0)))
            step += 1
            if step % cfg.log_every == 0 or step == cfg.total_steps:
                history.append({"step": step, "loss": loss, "dt_s": dt})
                log(f"[train] step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
            if step % cfg.ckpt_every == 0:
                saver.save(cfg.ckpt_dir, step,
                           {"params": params, "opt": opt_state},
                           extra=_save_extra(),
                           full_checksum=cfg.full_checksum)
                if rec is not None:
                    rec.count("train.ckpt.saves")
                    rec.emit(obs.Checkpoint(action="save", step=step,
                                            detail={"async": True}))
                if g is not None:
                    pending_healthy.append(step)
                if cfg.keep_last_k is not None:
                    removed = ckpt_mod.gc_checkpoints(
                        cfg.ckpt_dir, cfg.keep_last_k, log=log)
                    if rec is not None and removed:
                        rec.emit(obs.Checkpoint(
                            action="gc", step=step,
                            detail={"removed": list(removed)}))
            if g is not None:
                # promote checkpoints that survived the health window
                while pending_healthy and (
                        pending_healthy[0] + g.health_window <= step):
                    s = pending_healthy[0]
                    comp = ckpt_mod.complete_steps(cfg.ckpt_dir)
                    if s in comp:
                        ckpt_mod.mark_healthy(cfg.ckpt_dir, s)
                        pending_healthy.pop(0)
                        if rec is not None:
                            rec.emit(obs.Checkpoint(
                                action="promote", step=s,
                                detail={"survived": g.health_window}))
                    elif comp and s < comp[-1]:
                        pending_healthy.pop(0)   # overwritten or GC'd
                    else:
                        break                    # async write still in flight
    finally:
        saver.wait()
        ckpt_mod.save(cfg.ckpt_dir, step,
                      {"params": params, "opt": opt_state},
                      extra=_save_extra(), full_checksum=cfg.full_checksum)
        if rec is not None:
            rec.emit(obs.Checkpoint(action="save", step=step,
                                    detail={"final": True}))
        if cfg.keep_last_k is not None:
            removed = ckpt_mod.gc_checkpoints(cfg.ckpt_dir, cfg.keep_last_k,
                                              log=log)
            if rec is not None and removed:
                rec.emit(obs.Checkpoint(action="gc", step=step,
                                        detail={"removed": list(removed)}))
    guardian_info = {"trips": trips, "lr_scale": lr_scale,
                     "skipped_data_steps": sorted(bad_data_steps)}
    return {"params": params, "opt_state": opt_state, "step": step,
            "history": history, "straggler_count": mon.count,
            "guardian": guardian_info if g is not None else None}
