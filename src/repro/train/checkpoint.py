"""Fault-tolerant checkpointing (no orbax in this environment).

Guarantees targeted at thousand-node operation:
  * **atomic + durable** — write to <dir>.tmp-<rand>, fsync arrays.npz
    AND manifest.json, rename, fsync the parent directory; a crash at any
    point never corrupts the latest checkpoint.
  * **fallback restore** — ``restore_latest`` verifies each candidate
    (checksum; ``full_checksum=True`` at save time digests every byte,
    head-MiB per leaf otherwise) and falls back past unreadable
    checkpoints to the newest verifiable one.
  * **healthy promotion + retention** — ``mark_healthy`` flags rollback
    targets (the guardian promotes only checkpoints that survived a
    health window); ``gc_checkpoints(keep_last_k)`` bounds disk while
    never deleting the latest healthy mark.
  * **mesh-agnostic / elastic** — leaves are saved as full host arrays
    (gathered); restore re-places onto *any* mesh/sharding, so the job can
    come back on a different device count (elastic scaling test:
    tests/test_checkpoint.py::test_elastic_reshard).
  * **self-describing** — manifest.json carries step, pytree structure,
    data-iterator state and a content checksum; ``latest_step`` scans for
    the newest complete checkpoint, skipping partial ones.
  * **async** — ``save_async`` hands the (already host-transferred) arrays
    to a writer thread so the train loop never blocks on disk.
  * **bitwise restart** — params + opt state + data state round-trip
    exactly (test_checkpoint.py::test_bitwise_restart).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np

_SENTINEL = "manifest.json"


def _flatten(tree) -> tuple[list[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


def _to_native(a: np.ndarray) -> tuple[np.ndarray, str]:
    """np.savez can't serialize ml_dtypes (bfloat16 etc.) — store the raw
    bytes as uint8 and remember the logical dtype."""
    dt = str(a.dtype)
    try:
        np.dtype(dt)
        native = True
    except TypeError:
        native = False
    if native and a.dtype.kind != "V":
        return a, dt
    return np.frombuffer(a.tobytes(), np.uint8).reshape(a.shape + (a.dtype.itemsize,)), dt


def _from_native(a: np.ndarray, dtype_str: str) -> np.ndarray:
    try:
        want = np.dtype(dtype_str)
        if str(a.dtype) == dtype_str:
            return a
    except TypeError:
        pass
    import jax.numpy as jnp
    want = jnp.dtype(dtype_str)
    return np.frombuffer(a.tobytes(), want).reshape(a.shape[:-1])


def _checksum(arrays: list[np.ndarray], full: bool = False) -> str:
    h = hashlib.sha256()
    for a in arrays:
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        # head mode hashes the first MiB per leaf (fast); full=True hashes
        # every byte — tail corruption in large weight leaves is invisible
        # to the head digest
        h.update(a.tobytes() if full else a.tobytes()[:1 << 20])
    return h.hexdigest()[:16]


def _fsync_dir(path: str | Path):
    """Best-effort directory fsync — makes the rename itself durable, not
    just the file contents (a crash after rename but before the metadata
    flush could otherwise lose the whole entry)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def save(ckpt_dir: str | Path, step: int, tree: Any,
         extra: dict | None = None, full_checksum: bool = False) -> Path:
    """Atomic synchronous save of an arbitrary pytree.  Durability order:
    arrays.npz is fsynced, then the fsynced manifest (the completeness
    sentinel), then the rename into place, then the parent directory —
    a crash at any point leaves either the old state or a complete new
    checkpoint, never a torn one.  ``full_checksum=True`` digests every
    byte of every leaf (slower; head-of-leaf MiB otherwise) — recorded
    in the manifest so restore verifies in the same mode."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    arrays, treedef = _flatten(tree)
    natives, dtypes = zip(*[_to_native(a) for a in arrays]) if arrays else ((), ())
    final = ckpt_dir / f"step_{step:010d}"
    tmp = Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_{step}_"))
    try:
        with open(tmp / "arrays.npz", "wb") as f:
            np.savez(f, **{f"leaf_{i}": a for i, a in enumerate(natives)})
            f.flush()
            os.fsync(f.fileno())
        manifest = {
            "step": step,
            "n_leaves": len(arrays),
            "dtypes": list(dtypes),
            "treedef": str(treedef),
            "checksum": _checksum(list(natives), full=full_checksum),
            "checksum_mode": "full" if full_checksum else "head",
            "extra": extra or {},
        }
        with open(tmp / _SENTINEL, "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        _fsync_dir(ckpt_dir)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


class AsyncSaver:
    """Background writer: snapshot to host synchronously (cheap), write to
    disk off-thread.  ``wait()`` joins outstanding saves (call before exit
    and before reading a checkpoint you just wrote)."""

    def __init__(self):
        self._thread: threading.Thread | None = None
        self.last_path: Path | None = None
        self.error: BaseException | None = None

    def save(self, ckpt_dir, step, tree, extra=None, full_checksum=False):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # device->host now

        def _run():
            try:
                self.last_path = save(ckpt_dir, step, host_tree, extra,
                                      full_checksum=full_checksum)
            except BaseException as e:  # surfaced on wait()
                self.error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error is not None:
            err, self.error = self.error, None
            raise err


def complete_steps(ckpt_dir: str | Path) -> list[int]:
    """Ascending steps of every COMPLETE checkpoint (manifest present —
    partial .tmp dirs and manifest-less crash leftovers are skipped)."""
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    steps = []
    for d in ckpt_dir.iterdir():
        if d.name.startswith("step_") and (d / _SENTINEL).exists():
            try:
                steps.append(int(d.name.split("_")[1]))
            except ValueError:
                continue
    return sorted(steps)


def latest_step(ckpt_dir: str | Path) -> int | None:
    steps = complete_steps(ckpt_dir)
    return steps[-1] if steps else None


# ------------------------------------------------- healthy-promotion marks
_HEALTHY = "HEALTHY"


def mark_healthy(ckpt_dir: str | Path, step: int):
    """Promote a checkpoint to rollback-eligible.  The guardian
    (train/train_loop.py) promotes a checkpoint only after it has
    SURVIVED a health window of further training — a checkpoint written
    moments before (or after) silent corruption must never become a
    rollback target."""
    d = Path(ckpt_dir) / f"step_{step:010d}"
    with open(d / _HEALTHY, "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(d)


def is_healthy(ckpt_dir: str | Path, step: int) -> bool:
    return (Path(ckpt_dir) / f"step_{step:010d}" / _HEALTHY).exists()


def latest_healthy_step(ckpt_dir: str | Path) -> int | None:
    healthy = [s for s in complete_steps(ckpt_dir) if is_healthy(ckpt_dir, s)]
    return healthy[-1] if healthy else None


def gc_checkpoints(ckpt_dir: str | Path, keep_last_k: int,
                   log=None) -> list[int]:
    """Retention GC: delete complete checkpoints beyond the newest
    ``keep_last_k``, but NEVER the latest healthy-marked one — the
    guardian's rollback floor must survive any retention policy.
    Returns the deleted steps."""
    steps = complete_steps(ckpt_dir)
    if keep_last_k is None or len(steps) <= keep_last_k:
        return []
    protect = set(steps[-keep_last_k:])
    h = latest_healthy_step(ckpt_dir)
    if h is not None:
        protect.add(h)
    removed = []
    for s in steps:
        if s in protect:
            continue
        shutil.rmtree(Path(ckpt_dir) / f"step_{s:010d}", ignore_errors=True)
        removed.append(s)
    if removed and log:
        log(f"[ckpt] gc removed steps {removed} (keep_last_k={keep_last_k})")
    return removed


def restore(ckpt_dir: str | Path, step: int, like: Any,
            shardings: Any = None, verify: bool = True) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (values ignored).  With
    ``shardings`` (a matching pytree of NamedSharding) the leaves land
    directly on the target mesh — the elastic-rescale path."""
    d = Path(ckpt_dir) / f"step_{step:010d}"
    manifest = json.loads((d / _SENTINEL).read_text())
    data = np.load(d / "arrays.npz")
    natives = [data[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
    # pre-checksum_mode manifests were always head-digested
    full = manifest.get("checksum_mode", "head") == "full"
    if verify and _checksum(natives, full=full) != manifest["checksum"]:
        raise IOError(f"checkpoint {d} failed checksum verification")
    arrays = [_from_native(a, dt)
              for a, dt in zip(natives, manifest["dtypes"])]
    _, treedef = jax.tree.flatten(like)
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(shardings)
        arrays = [jax.device_put(a, s) if s is not None else jax.numpy.asarray(a)
                  for a, s in zip(arrays, flat_sh)]
    else:
        arrays = [jax.numpy.asarray(a) for a in arrays]
    return jax.tree.unflatten(treedef, arrays), manifest["extra"]


def restore_latest(ckpt_dir, like, shardings=None, log=None):
    """(step, tree, extra) from the newest VERIFIABLE checkpoint.

    A corrupted / checksum-failing / truncated latest checkpoint no
    longer kills auto-resume: each candidate is verified on load and an
    unreadable one falls back to the next-newest complete checkpoint
    (logged through ``log``), so one torn write costs at most
    ``ckpt_every`` steps of progress.  (None, None, None) when nothing
    restorable exists."""
    for s in reversed(complete_steps(ckpt_dir)):
        try:
            tree, extra = restore(ckpt_dir, s, like, shardings)
            return s, tree, extra
        except Exception as e:   # torn npz, bad json, failed checksum, ...
            if log:
                log(f"[ckpt] step {s} unreadable ({type(e).__name__}: {e}) "
                    "— falling back to an older checkpoint")
    return None, None, None
