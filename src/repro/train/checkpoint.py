"""Fault-tolerant checkpointing (no orbax in this environment).

Guarantees targeted at thousand-node operation:
  * **atomic** — write to <dir>.tmp-<rand>, fsync, rename; a crash mid-save
    never corrupts the latest checkpoint.
  * **mesh-agnostic / elastic** — leaves are saved as full host arrays
    (gathered); restore re-places onto *any* mesh/sharding, so the job can
    come back on a different device count (elastic scaling test:
    tests/test_checkpoint.py::test_elastic_reshard).
  * **self-describing** — manifest.json carries step, pytree structure,
    data-iterator state and a content checksum; ``latest_step`` scans for
    the newest complete checkpoint, skipping partial ones.
  * **async** — ``save_async`` hands the (already host-transferred) arrays
    to a writer thread so the train loop never blocks on disk.
  * **bitwise restart** — params + opt state + data state round-trip
    exactly (test_checkpoint.py::test_bitwise_restart).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np

_SENTINEL = "manifest.json"


def _flatten(tree) -> tuple[list[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


def _to_native(a: np.ndarray) -> tuple[np.ndarray, str]:
    """np.savez can't serialize ml_dtypes (bfloat16 etc.) — store the raw
    bytes as uint8 and remember the logical dtype."""
    dt = str(a.dtype)
    try:
        np.dtype(dt)
        native = True
    except TypeError:
        native = False
    if native and a.dtype.kind != "V":
        return a, dt
    return np.frombuffer(a.tobytes(), np.uint8).reshape(a.shape + (a.dtype.itemsize,)), dt


def _from_native(a: np.ndarray, dtype_str: str) -> np.ndarray:
    try:
        want = np.dtype(dtype_str)
        if str(a.dtype) == dtype_str:
            return a
    except TypeError:
        pass
    import jax.numpy as jnp
    want = jnp.dtype(dtype_str)
    return np.frombuffer(a.tobytes(), want).reshape(a.shape[:-1])


def _checksum(arrays: list[np.ndarray]) -> str:
    h = hashlib.sha256()
    for a in arrays:
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes()[:1 << 20])   # first MiB per leaf — fast + strong
    return h.hexdigest()[:16]


def save(ckpt_dir: str | Path, step: int, tree: Any,
         extra: dict | None = None) -> Path:
    """Atomic synchronous save of an arbitrary pytree."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    arrays, treedef = _flatten(tree)
    natives, dtypes = zip(*[_to_native(a) for a in arrays]) if arrays else ((), ())
    final = ckpt_dir / f"step_{step:010d}"
    tmp = Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_{step}_"))
    try:
        np.savez(tmp / "arrays.npz",
                 **{f"leaf_{i}": a for i, a in enumerate(natives)})
        manifest = {
            "step": step,
            "n_leaves": len(arrays),
            "dtypes": list(dtypes),
            "treedef": str(treedef),
            "checksum": _checksum(list(natives)),
            "extra": extra or {},
        }
        with open(tmp / _SENTINEL, "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


class AsyncSaver:
    """Background writer: snapshot to host synchronously (cheap), write to
    disk off-thread.  ``wait()`` joins outstanding saves (call before exit
    and before reading a checkpoint you just wrote)."""

    def __init__(self):
        self._thread: threading.Thread | None = None
        self.last_path: Path | None = None
        self.error: BaseException | None = None

    def save(self, ckpt_dir, step, tree, extra=None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # device->host now

        def _run():
            try:
                self.last_path = save(ckpt_dir, step, host_tree, extra)
            except BaseException as e:  # surfaced on wait()
                self.error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error is not None:
            err, self.error = self.error, None
            raise err


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        if d.name.startswith("step_") and (d / _SENTINEL).exists():
            try:
                steps.append(int(d.name.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, step: int, like: Any,
            shardings: Any = None, verify: bool = True) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (values ignored).  With
    ``shardings`` (a matching pytree of NamedSharding) the leaves land
    directly on the target mesh — the elastic-rescale path."""
    d = Path(ckpt_dir) / f"step_{step:010d}"
    manifest = json.loads((d / _SENTINEL).read_text())
    data = np.load(d / "arrays.npz")
    natives = [data[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
    if verify and _checksum(natives) != manifest["checksum"]:
        raise IOError(f"checkpoint {d} failed checksum verification")
    arrays = [_from_native(a, dt)
              for a, dt in zip(natives, manifest["dtypes"])]
    _, treedef = jax.tree.flatten(like)
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(shardings)
        arrays = [jax.device_put(a, s) if s is not None else jax.numpy.asarray(a)
                  for a, s in zip(arrays, flat_sh)]
    else:
        arrays = [jax.numpy.asarray(a) for a in arrays]
    return jax.tree.unflatten(treedef, arrays), manifest["extra"]


def restore_latest(ckpt_dir, like, shardings=None):
    s = latest_step(ckpt_dir)
    if s is None:
        return None, None, None
    tree, extra = restore(ckpt_dir, s, like, shardings)
    return s, tree, extra
