"""Logical-axis sharding rules → PartitionSpec trees.

Two mesh layouts (launch/mesh.py):
  single-pod  (data=16, model=16)
  multi-pod   (pod=2, data=16, model=16)  — "pod" is hierarchical DP.

Parameters are 2-D sharded (TP on "model" + FSDP on "data") so the
104B-param arch fits: per-device bytes = total/(data*model).  Every rule is
guarded by divisibility — a dim that doesn't divide its mesh axis is
replicated instead (whisper's 8 heads vs model=16, batch=1 long-context).

The KV cache shards its *sequence* dim over "model": decode attention then
lowers to local partial softmax + scalar-sized all-reduces (flash-decoding,
DESIGN.md Sec. 5).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.sparse_linear import MOE_PATTERN_LEAVES, PATTERN_LEAVES

# linear containers whose w is [in, out]: out-dim -> "model", in-dim -> "data"
_OUT_MODEL = {"wq", "wk", "wv", "wi", "wg", "in_proj", "wkv_b",
              "in_z", "in_xbc", "in_dt", "dt_proj"}
# linear containers whose w is [in, out]: out-dim -> "data", in-dim -> "model"
_OUT_DATA = {"wo", "out_proj"}
# replicated small projections
_REPL = {"wkv_a", "x_proj"}


def _fit(dim: int, axis: str | None, mesh: Mesh):
    """Use axis only if dim divides its size."""
    if axis is None:
        return None
    sizes = dict(mesh.shape)
    ax = sizes.get(axis)
    if isinstance(axis, tuple):
        ax = int(np.prod([sizes[a] for a in axis]))
    return axis if ax and dim % ax == 0 else None


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _dp_fit(dim: int, mesh: Mesh):
    sizes = dict(mesh.shape)
    axes = dp_axes(mesh)
    if isinstance(axes, tuple):
        total = int(np.prod([sizes[a] for a in axes]))
        if dim % total == 0:
            return axes
        # fall back to the inner data axis alone
        return "data" if dim % sizes["data"] == 0 else None
    return axes if dim % sizes[axes] == 0 else None


def _linear_spec(parent: str, leaf: str, lshape: tuple, mesh: Mesh,
                 head_aligned: bool = True):
    """Spec for one leaf of a linear container (logical shape, no stack dims).

    head_aligned=False (attention projections whose head count doesn't divide
    the model axis, e.g. whisper's 8 heads on model=16) forces the head-fused
    dim to replicate: sharding it would misalign the [.., H, hd] reshape and
    the partitioner would emit score-sized all-reduces per chunk."""
    nd = len(lshape)
    if leaf in PATTERN_LEAVES:
        return (None,) * nd
    if parent in _REPL:
        return ((_fit(lshape[0], "data", mesh),) + (None,) * (nd - 1)
                if nd >= 1 else ())
    if leaf == "b":
        axis = "model" if parent in _OUT_MODEL else "data"
        if not head_aligned:
            axis = None
        return (_fit(lshape[0], axis, mesh),)
    # weights
    if nd == 2:  # dense [in, out]
        if parent in _OUT_MODEL:
            return (_fit(lshape[0], "data", mesh),
                    _fit(lshape[1], "model", mesh) if head_aligned else None)
        return (_fit(lshape[0], "model", mesh) if head_aligned else None,
                _fit(lshape[1], "data", mesh))
    if nd == 4:  # block-sparse [nob, kb, bs, bs]
        return (_fit(lshape[0], "model", mesh), None,
                _fit(lshape[2], "data", mesh), None)
    return (None,) * nd


def _leaf_spec(path: list[str], lshape: tuple, mesh: Mesh,
               cfg: ArchConfig | None = None):
    leaf = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    grandparent = path[-3] if len(path) > 2 else ""
    nd = len(lshape)
    model_size = dict(mesh.shape)["model"]
    # attention projections: shardable only when head counts divide "model"
    head_aligned = True
    if cfg is not None and grandparent in ("attn", "cross", "shared_attn"):
        if parent in ("wq", "wo", "wkv_b"):
            head_aligned = cfg.n_heads % model_size == 0
        elif parent in ("wk", "wv"):
            head_aligned = cfg.kv_heads % model_size == 0
    # norms / small vectors
    if leaf in ("scale",) or (leaf == "bias" and nd == 1 and parent.startswith("norm")):
        return (None,) * nd
    if parent in ("kv_norm", "final_norm") or leaf == "pos":
        return (None,) * nd
    # embeddings
    if leaf == "tok":
        return (_fit(lshape[0], "model", mesh), _fit(lshape[1], "data", mesh))
    if leaf == "out" and nd == 2:
        return (_fit(lshape[0], "data", mesh), _fit(lshape[1], "model", mesh))
    # moe
    if leaf == "router":
        return (_fit(lshape[0], "data", mesh), _fit(lshape[1], "model", mesh))
    if leaf in MOE_PATTERN_LEAVES:
        # shared expert block pattern + its reverse: replicated like every
        # other pattern leaf (scalar-prefetch operands of the unified kernels)
        return (None,) * nd
    if parent == "moe" or (nd in (3, 5) and leaf in ("wi", "wg", "wo")):
        if nd == 5:               # sparse experts [E, nob, kb, bs, bs]: EP only
            return (_fit(lshape[0], "model", mesh), None, None, None, None)
        if leaf in ("wi", "wg"):  # [E, D, F]
            return (_fit(lshape[0], "model", mesh), _fit(lshape[1], "data", mesh), None)
        if leaf == "wo":          # [E, F, D]
            return (_fit(lshape[0], "model", mesh), None, _fit(lshape[2], "data", mesh))
    # ssm extras
    if leaf == "conv_w":
        return (None, _fit(lshape[1], "model", mesh))
    if leaf in ("conv_b", "D", "dt_bias"):
        return (_fit(lshape[0], "model", mesh),)
    if leaf == "A_log":
        return (_fit(lshape[0], "model", mesh),) + (None,) * (nd - 1)
    # linear containers
    if len(path) >= 2:
        return _linear_spec(parent, leaf, lshape, mesh, head_aligned)
    return (None,) * nd


# stack depth of each top-level params subtree
_STACK_DEPTH = {"layers": 1, "dense_layers": 1, "encoder.layers": 1}


def param_specs(cfg: ArchConfig, params_tree: Any, mesh: Mesh):
    """PartitionSpec tree mirroring params (works on ShapeDtypeStructs)."""
    hybrid = cfg.family == "hybrid"
    sp_strategy = cfg.strategy == "sp"

    def rec(tree, path, nstack):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                ns = nstack
                if path == [] and k in ("layers", "dense_layers"):
                    ns = 2 if (hybrid and k == "layers") else 1
                elif path == ["encoder"] and k == "layers":
                    ns = 1
                out[k] = rec(v, path + [k], ns)
            return out
        shape = tuple(tree.shape)
        lshape = shape[nstack:]
        spec = _leaf_spec(path, lshape, mesh, cfg)
        if sp_strategy:  # "model" carries the sequence dim — weights FSDP-only
            spec = tuple(None if s == "model" else s for s in spec)
        return P(*((None,) * nstack + tuple(spec)))

    return rec(params_tree, [], 0)


def batch_specs(cfg: ArchConfig, batch_tree: Any, mesh: Mesh):
    seq_ax = "model" if cfg.strategy == "sp" else None

    def leaf(t):
        nd = len(t.shape)
        if nd == 0:
            return P()
        spec = [_dp_fit(t.shape[0], mesh)] + [None] * (nd - 1)
        if nd >= 2 and seq_ax:
            spec[1] = _fit(t.shape[1], seq_ax, mesh)
        return P(*spec)
    return jax.tree.map(leaf, batch_tree)


def cache_specs(cfg: ArchConfig, cache_tree: Any, mesh: Mesh):
    """Cache leaves all carry ≥1 stack dims then [B, S|state...].

    Rule: first dim(s) = layer stacks -> None; batch -> dp; the sequence /
    d_inner dim -> "model" (seq-sharded KV cache / channel-sharded SSM state).
    """
    def rec(tree, path):
        if isinstance(tree, dict):
            return {k: rec(v, path + [k]) for k, v in tree.items()}
        shape = tuple(tree.shape)
        leaf = path[-1]
        # explicit per-leaf handling (stack dims located by negative indexing)
        if leaf in ("k", "v", "ck", "cv"):          # [L,B,S,H,hd]
            b, s = shape[1], shape[2]
            return P(None, _dp_fit(b, mesh), _fit(s, "model", mesh), None, None)
        if leaf in ("latent", "k_rope"):            # [L,B,S,r]
            b, s = shape[1], shape[2]
            return P(None, _dp_fit(b, mesh), _fit(s, "model", mesh), None)
        if leaf == "conv":                          # [...,B,K-1,C]
            ns = len(shape) - 3
            return P(*([None] * ns), _dp_fit(shape[-3], mesh), None,
                     _fit(shape[-1], "model", mesh))
        if leaf == "ssm":
            if len(shape) >= 4 and cfg.ssm_kind == "mamba1":  # [L,B,di,N]
                return P(None, _dp_fit(shape[1], mesh),
                         _fit(shape[2], "model", mesh), None)
            # mamba2 [ns(,ev),B,H,hd,N]
            ns = len(shape) - 4
            return P(*([None] * ns), _dp_fit(shape[-4], mesh),
                     _fit(shape[-3], "model", mesh), None, None)
        return P(*([None] * len(shape)))

    return rec(cache_tree, [])


def logits_spec(cfg: ArchConfig, batch: int, mesh: Mesh):
    if cfg.strategy == "sp":  # [B, S, V] with seq on model (decode: S=1 -> repl)
        return P(_dp_fit(batch, mesh), None, None)
    vocab_ax = "model" if cfg.vocab % dict(mesh.shape)["model"] == 0 else None
    return P(_dp_fit(batch, mesh), None, vocab_ax)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def attach(shape_tree, spec_tree, mesh: Mesh):
    """ShapeDtypeStruct tree + spec tree -> ShapeDtypeStructs with shardings."""
    return jax.tree.map(
        lambda t, s: jax.ShapeDtypeStruct(t.shape, t.dtype,
                                          sharding=NamedSharding(mesh, s)),
        shape_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, P))
