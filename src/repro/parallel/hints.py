"""Ambient activation-sharding hints.

Model code is mesh-agnostic; the launcher (dryrun/train/serve) installs a
mesh + strategy here and layers call ``constrain`` at a handful of
anchor points (embedding output, block boundaries).  Outside a hints
context every call is a no-op, so smoke tests and single-device runs are
untouched.  Every axis is divisibility-guarded.

Strategies (ArchConfig.strategy):
  tp — tensor parallel: activations (dp, None, ...), weights TP+FSDP.
  sp — sequence parallel: activations (dp, "model", ...) on the seq dim;
       for small models whose head counts don't divide the model axis
       (whisper-base), replicating attention would multiply compute by the
       model-axis size — SP keeps every chip busy on distinct rows instead.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_state = threading.local()


@contextlib.contextmanager
def use_mesh_hints(mesh: Mesh):
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.mesh = prev


def current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


def _resolve(dim: int, axis, sizes) -> object:
    if axis is None:
        return None
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            if a not in sizes:
                return None
            n *= sizes[a]
        return axis if dim % n == 0 else None
    if axis not in sizes:
        return None
    return axis if dim % sizes[axis] == 0 else None


def constrain(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint(x, P(*axes)) guarded by mesh presence and
    per-dim divisibility.  ``axes`` may use "dp" (resolved to ("pod","data")
    when the mesh has a pod axis)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    sizes = dict(mesh.shape)
    resolved = []
    for dim, ax in zip(x.shape, axes):
        if ax == "dp":
            ax = ("pod", "data") if "pod" in sizes else "data"
        resolved.append(_resolve(dim, ax, sizes))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


def constrain_tokens3d(x: jax.Array, cfg) -> jax.Array:
    """Anchor for [B, S, D] residual-stream activations.

    The residual stream is stored *sequence-sharded over the model axis*
    under both strategies: for "sp" it is the compute layout; for "tp" it is
    Megatron-style sequence partitioning of the saved-for-backward carry —
    without it a deep scan stores n_layers full [B,S,D] carries per device
    (qwen2-72b: 80 x 1.07 GiB = 86 GiB; sharded: 5.4 GiB).  XLA turns the
    wo all-reduce into reduce-scatter + all-gather around each block, so
    communication volume is unchanged."""
    return constrain(x, "dp", "model", None)
