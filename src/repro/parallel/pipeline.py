"""Pipeline parallelism — the paper's junction pipelining at mesh scale.

The FPGA runs all L junctions simultaneously on different inputs with FF,
BP and UP overlapped (Fig. 1), updating weights with bounded staleness.
Generalized here to a "stage" mesh axis with shard_map + lax.ppermute:

* ``gpipe_step``  — synchronous microbatch pipeline (the baseline the paper
  implicitly beats): forward streams S+M-1 ticks, autodiff reverses it;
  bubble fraction = (S-1)/(M+S-1) in each direction.

* ``async_pipeline_epoch`` — the paper-faithful schedule: every tick, each
  stage does FF on one microbatch, BP on another, and UP with the gradient
  that just arrived — activations flow right, gradients flow left, weights
  update with staleness 2*(S - s) - 1 ticks, and there is NO bubble: one
  microbatch enters and one update lands per tick per stage (the "3L
  speedup" claim).  PipeDream-style semantics; convergence parity is
  validated in tests/test_pipeline.py.

Stages are homogeneous: ``stage_fn(stage_params, x) -> y`` with x/y of
identical shape; the last stage's output feeds ``loss_grad_fn(y, target)``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def _axis_size(axis_name):
    try:  # jax >= 0.6
        return jax.lax.axis_size(axis_name)
    except AttributeError:  # jax 0.4.x
        return jax.lax.psum(1, axis_name)


def _shift_right(x, axis_name):
    """stage s receives from s-1 (stage 0 receives zeros)."""
    n = _axis_size(axis_name)
    perm = [(i, i + 1) for i in range(n - 1)]
    return jax.lax.ppermute(x, axis_name, perm)


def _shift_left(x, axis_name):
    n = _axis_size(axis_name)
    perm = [(i + 1, i) for i in range(n - 1)]
    return jax.lax.ppermute(x, axis_name, perm)


# ===================================================================== GPipe
def gpipe_forward(stage_fn: Callable, params_stacked, x_microbatches,
                  mesh: Mesh, axis: str = "stage"):
    """Forward pipeline.  params_stacked: leading dim = n_stages;
    x_microbatches: [M, mb, ...].  Returns outputs [M, mb, ...]."""
    n_stages = mesh.shape[axis]

    def per_stage(params, xs):
        params = jax.tree.map(lambda t: t[0], params)   # my stage's slice
        M = xs.shape[0]
        sidx = jax.lax.axis_index(axis)
        T = M + n_stages - 1
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry
            m_in = t - sidx                      # microbatch arriving here
            x_first = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, M - 1), keepdims=False)
            x_in = jnp.where(sidx == 0, x_first, buf)
            y = stage_fn(params, x_in)
            valid = (m_in >= 0) & (m_in < M)
            y = jnp.where(valid, y, jnp.zeros_like(y))
            # last stage writes its result
            outs = jax.lax.cond(
                valid & (sidx == n_stages - 1),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(m_in, 0, M - 1), 0),
                lambda o: o, outs)
            buf = _shift_right(y, axis)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(T))
        # only the last stage holds real outputs (zeros elsewhere) — psum
        # makes the P() out_spec correct on every device
        return jax.lax.psum(outs, axis)

    spec_p = jax.tree.map(lambda _: P(axis), params_stacked)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(spec_p, P()), out_specs=P(),
                   check_rep=False)
    return fn(params_stacked, x_microbatches)


def gpipe_loss(stage_fn, loss_fn, params_stacked, xs, ys, mesh, axis="stage"):
    outs = gpipe_forward(stage_fn, params_stacked, xs, mesh, axis)
    return loss_fn(outs, ys)


def gpipe_step(stage_fn, loss_fn, params_stacked, xs, ys, mesh, lr,
               axis="stage"):
    """One synchronous training step (grad through the pipeline)."""
    l, g = jax.value_and_grad(
        functools.partial(gpipe_loss, stage_fn, loss_fn))(
            params_stacked, xs, ys, mesh, axis)
    new = jax.tree.map(lambda p, gg: p - lr * gg, params_stacked, g)
    return new, l


# ============================================================== async (paper)
def async_pipeline_epoch(stage_fn: Callable, loss_grad_fn: Callable,
                         params_stacked, xs, ys, mesh: Mesh, lr: float,
                         axis: str = "stage"):
    """Paper-faithful asynchronous pipeline (FF/BP/UP overlapped, stale
    updates, zero bubble).

    Per tick, per stage s (all reads at tick start, writes at tick end):
      FF : x from stage s-1, stash it, send activation right
      BP : gradient from stage s+1, pop the matching stash, vjp -> (dparams, dx)
      UP : params -= lr * dparams      (staleness 2*(S-s)-1 ticks)
    """
    n_stages = mesh.shape[axis]

    def per_stage(params, xs, ys):
        params = jax.tree.map(lambda t: t[0], params)
        M = xs.shape[0]
        sidx = jax.lax.axis_index(axis)
        depth = 2 * n_stages          # stash ring depth (>= max staleness)
        stash = jnp.zeros((depth,) + xs.shape[1:], xs.dtype)
        act_buf = jnp.zeros_like(xs[0])     # activation arriving from left
        grad_buf = jnp.zeros_like(xs[0])    # gradient arriving from right
        T = M + 2 * n_stages
        losses = jnp.zeros((T,))

        def tick(carry, t):
            params, stash, act_buf, grad_buf, losses = carry
            # ---------------- FF on microbatch m_f = t - s
            m_f = t - sidx
            x_first = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(m_f, 0, M - 1), keepdims=False)
            x_in = jnp.where(sidx == 0, x_first, act_buf)
            ff_valid = (m_f >= 0) & (m_f < M)
            y = stage_fn(params, x_in)
            stash2 = jax.lax.dynamic_update_index_in_dim(
                stash, x_in, t % depth, 0)
            # last stage: loss gradient for m_f, starts flowing back
            y_t = jax.lax.dynamic_index_in_dim(
                ys, jnp.clip(m_f, 0, M - 1), keepdims=False)
            gy, l = loss_grad_fn(y, y_t)
            losses = jax.lax.dynamic_update_index_in_dim(
                losses, jnp.where(ff_valid & (sidx == n_stages - 1), l, 0.0),
                jnp.clip(t, 0, T - 1), 0)
            # ---------------- BP/UP on microbatch m_b = t - (2S - s - 2)
            m_b = t - (2 * n_stages - sidx - 2)
            bp_valid = (m_b >= 0) & (m_b < M)
            # stash slot where m_b's input was saved: tick t_f = m_b + s
            slot = (m_b + sidx) % depth
            x_saved = jax.lax.dynamic_index_in_dim(stash2, slot, keepdims=False)
            g_in = jnp.where(sidx == n_stages - 1,
                             jnp.where(ff_valid, gy, jnp.zeros_like(gy)),
                             grad_buf)
            _, vjp = jax.vjp(stage_fn, params, x_saved)
            dparams, dx = vjp(g_in)
            upd = jnp.where(bp_valid | (sidx == n_stages - 1), 1.0, 0.0)
            params = jax.tree.map(
                lambda p, g: p - lr * upd * g, params, dparams)
            # ---------------- communicate
            act_buf2 = _shift_right(jnp.where(ff_valid, y, jnp.zeros_like(y)),
                                    axis)
            grad_buf2 = _shift_left(dx, axis)
            return (params, stash2, act_buf2, grad_buf2, losses), None

        carry = (params, stash, act_buf, grad_buf, losses)
        (params, *_, losses), _ = jax.lax.scan(tick, carry, jnp.arange(T))
        return jax.tree.map(lambda t: t[None], params), losses

    spec_p = jax.tree.map(lambda _: P(axis), params_stacked)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(spec_p, P(), P()),
                   out_specs=(spec_p, P(axis)),
                   check_rep=False)
    new_params, losses = fn(params_stacked, xs, ys)
    return new_params, losses


def bubble_fraction(n_stages: int, n_microbatches: int,
                    schedule: str = "gpipe") -> float:
    """Idle fraction per stage — the paper's zero-bubble claim quantified."""
    if schedule == "gpipe":
        return 2.0 * (n_stages - 1) / (n_microbatches + 2.0 * (n_stages - 1))
    return 0.0  # async: every tick does useful FF+BP+UP once warm
