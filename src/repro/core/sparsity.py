"""Pre-defined structured sparsity (paper Sec. II-A).

A junction between layers of widths (n_in, n_out) carries
``W = n_in * d_out = n_out * d_in`` weights with *fixed* in/out degrees —
fixed before training, never discovered or pruned.  Density = W/(n_in*n_out).

Two granularities:

* **neuron-level** (`NeuronPattern`) — the paper's exact scheme: each output
  neuron reads ``d_in`` permuted input neurons through a clash-free
  interleaver.  This is the bit-faithful reference used by the MNIST repro.
* **block-level** (`BlockPattern`) — the TPU-native scheme: fan-in/out fixed
  at MXU-tile granularity (default 128), so each edge-bundle is a dense
  (bs x bs) matmul.  A neuron-level interleaver is composed *inside* blocks
  as a static permutation (cheap gather, fused by XLA); clash-freedom across
  banks becomes grid-step load balance (see DESIGN.md Sec. 2).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import interleaver as il

__all__ = ["SparsityConfig", "NeuronPattern", "BlockPattern", "block_fan_in",
           "make_block_pattern", "make_neuron_pattern"]


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """How the paper's technique is applied inside a model.

    density: fraction of block connections kept (1.0 = dense layer).
    block: MXU tile edge (128 aligns with the systolic array).
    where: which linear families to sparsify ("ffn", "attn", "all").
    """

    density: float = 0.125
    block: int = 128
    where: str = "ffn"
    seed: int = 0

    def applies_to(self, family: str) -> bool:
        if self.density >= 1.0:
            return False
        return self.where == "all" or family in self.where.split("+")


@dataclasses.dataclass(frozen=True)
class NeuronPattern:
    """Paper-exact junction pattern: idx[n_out, d_in] input neuron per edge."""

    n_in: int
    n_out: int
    d_in: int
    idx: np.ndarray  # [n_out, d_in] int32

    @property
    def d_out(self) -> int:
        return self.n_out * self.d_in // self.n_in

    @property
    def n_weights(self) -> int:
        return self.n_out * self.d_in

    @property
    def density(self) -> float:
        return self.n_weights / (self.n_in * self.n_out)


def make_neuron_pattern(n_in: int, n_out: int, d_in: int, z: int | None = None,
                        seed: int = 0) -> NeuronPattern:
    """Build the paper's junction: weights numbered sequentially on the right
    (Sec. III-D-3), traced to left neurons through a clash-free interleaver.

    Weight k (k = j*d_in + f for right neuron j, edge f) connects left neuron
    pi(k) // d_out ... the paper's memory layout maps pi(k) to a (bank, row);
    we map pi(k) onto left neurons round-robin so each left neuron gets
    exactly d_out edges (fixed fan-out by construction).
    """
    W = n_out * d_in
    if W % n_in:
        raise ValueError("W must be divisible by n_in for integral fan-out")
    d_out = W // n_in
    z = z if z is not None else d_in
    pi = il.sv_ss_interleaver(W, z, seed=seed)
    # left neuron of permuted weight slot p: balanced round-robin p -> p % n_in
    # composed with the permutation => every left neuron has exactly d_out edges.
    left = (pi % n_in).astype(np.int32)
    counts = np.bincount(left, minlength=n_in)
    if not np.all(counts == d_out):
        # repair: reassign surplus slots to deficit neurons deterministically
        left = _balance_assignment(left, n_in, d_out)
    idx = left.reshape(n_out, d_in)
    # no duplicate input per output neuron (keeps eq. (1a) a true d_in-sum)
    idx = il._rebalance_rows(idx.astype(np.int64), n_in).astype(np.int32)
    return NeuronPattern(n_in=n_in, n_out=n_out, d_in=d_in, idx=idx)


def _balance_assignment(left: np.ndarray, n_in: int, d_out: int) -> np.ndarray:
    left = left.astype(np.int64).copy()
    counts = np.bincount(left, minlength=n_in)
    surplus = [n for n in range(n_in) for _ in range(max(0, counts[n] - d_out))]
    deficit = [n for n in range(n_in) for _ in range(max(0, d_out - counts[n]))]
    s_pos = {}
    for i, v in enumerate(left):
        s_pos.setdefault(int(v), []).append(i)
    di = 0
    for n in surplus:
        pos = s_pos[n].pop()
        left[pos] = deficit[di]
        di += 1
    return left.astype(np.int32)


@dataclasses.dataclass(frozen=True)
class BlockPattern:
    """TPU-native pattern: block idx[n_out_blocks, fan_in_blocks] (+ reverse)."""

    n_in: int
    n_out: int
    block: int
    idx: np.ndarray        # [nob, kb] int32 — input block per slot
    rev_ob: np.ndarray     # [nib, fb] int32 — output block reading input block
    rev_t: np.ndarray      # [nib, fb] int32 — slot within that output block
    rev_cnt: np.ndarray    # [nib] int32 — valid reverse slots (ragged patterns)

    @property
    def n_in_blocks(self) -> int:
        return self.n_in // self.block

    @property
    def n_out_blocks(self) -> int:
        return self.n_out // self.block

    @property
    def fan_in_blocks(self) -> int:
        return int(self.idx.shape[1])

    @property
    def fan_out_blocks(self) -> int:
        return int(self.rev_ob.shape[1])

    @property
    def density(self) -> float:
        return self.fan_in_blocks / self.n_in_blocks

    @property
    def n_weights(self) -> int:
        return self.n_out_blocks * self.fan_in_blocks * self.block * self.block


def block_fan_in(n_in_blocks: int, density: float) -> int:
    """The fan-in block count kb ~= density * n_in_blocks a junction of
    ``n_in_blocks`` input blocks gets at the requested density — the ONE
    place the density -> structure quantization lives.  Candidates whose
    densities round to the same kb share a pattern exactly (the cohort
    bucketing rule of search/cohorts.py)."""
    return min(n_in_blocks, max(1, round(density * n_in_blocks)))


def make_block_pattern(n_in: int, n_out: int, density: float, block: int = 128,
                       seed: int = 0) -> BlockPattern:
    """Choose fan_in_blocks ~= density * n_in_blocks.  When the paper's
    divisibility identity (integral fan-out) holds at that kb it is exact;
    otherwise fan-out is balanced to +-1 and the reverse pattern is masked
    (forcing exactness would quantize density to multiples of
    nib/gcd(nob, nib) — full density for coprime dims like qwen2's FFN)."""
    if n_in % block or n_out % block:
        raise ValueError(f"dims ({n_in},{n_out}) must be multiples of block={block}")
    nib, nob = n_in // block, n_out // block
    kb = block_fan_in(nib, density)
    idx = il.block_circulant_pattern(nib, nob, kb, seed=seed)
    rev_ob, rev_t, rev_cnt = il.reverse_block_pattern(idx, nib)
    return BlockPattern(n_in=n_in, n_out=n_out, block=block, idx=idx,
                        rev_ob=rev_ob, rev_t=rev_t, rev_cnt=rev_cnt)
