"""The paper's network (Table I), bit- and schedule-faithful.

1024 -> 64 -> 32 with pre-defined sparsity (d1_out=4 / 6.25%, d2_out=16 /
50%), trained with explicit FF/BP/UP passes per eqs. (1)-(3) — NOT autodiff
— in (b_w,b_n,b_f) fixed-point with clipping tree adders and LUT sigmoid.
``fmt=None`` gives the ideal floating-point reference the paper compares
against ("within 1.5 percentage points").

Two training schedules:
  * ``train_epoch``            — sequential online SGD (one input at a time).
  * ``train_epoch_pipelined``  — the paper's junction pipelining (Fig. 1):
    at clock t, J1 does FF(t) and UP(t-3), J2 does FF(t-1), BP(t-2) and
    UP(t-2), all reading start-of-clock state — weight updates are applied
    with the paper's exact staleness.  Throughput: 1 input per block cycle,
    3L operations in flight.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixed_point as fxp
from repro.core.sparsity import NeuronPattern, make_neuron_pattern

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class PaperNetConfig:
    layers: tuple = (1024, 64, 32)          # N_0, N_1, N_2
    d_out: tuple = (4, 16)                  # fan-out per junction (Table I)
    z: tuple = (128, 32)                    # degree of parallelism (Table I)
    fmt: Optional[fxp.FxpFormat] = fxp.PAPER_FMT
    activation: str = "sigmoid"             # sigmoid | relu8 | relu1
    init_mode: str = "random"               # random | shared (Sec. III-C-1)
    seed: int = 0

    @property
    def n_junctions(self) -> int:
        return len(self.layers) - 1

    def d_in(self, i: int) -> int:
        return self.layers[i] * self.d_out[i] // self.layers[i + 1]

    def weights(self, i: int) -> int:
        return self.layers[i] * self.d_out[i]

    def block_cycles(self, i: int) -> int:
        """W_i / z_i (+2 for memory-access stages, Sec. III-D-6)."""
        return self.weights(i) // self.z[i] + 2

    def density(self, i: int) -> float:
        return self.d_out[i] / self.layers[i + 1]

    def overall_density(self) -> float:
        w = sum(self.weights(i) for i in range(self.n_junctions))
        full = sum(self.layers[i] * self.layers[i + 1]
                   for i in range(self.n_junctions))
        return w / full

    def n_params(self) -> int:
        return (sum(self.weights(i) for i in range(self.n_junctions))
                + sum(self.layers[1:]))


def patterns(cfg: PaperNetConfig) -> list[NeuronPattern]:
    return [make_neuron_pattern(cfg.layers[i], cfg.layers[i + 1],
                                cfg.d_in(i), z=cfg.z[i], seed=cfg.seed + i)
            for i in range(cfg.n_junctions)]


def reverse_pattern(pat: NeuronPattern) -> tuple[np.ndarray, np.ndarray]:
    """For BP: per left neuron, the (right neuron, slot) pairs reading it."""
    n_in, d_out = pat.n_in, pat.d_out
    rev_j = np.full((n_in, d_out), -1, np.int32)
    rev_f = np.full((n_in, d_out), -1, np.int32)
    fill = np.zeros(n_in, np.int64)
    for j in range(pat.n_out):
        for f in range(pat.idx.shape[1]):
            k = int(pat.idx[j, f])
            rev_j[k, fill[k]] = j
            rev_f[k, fill[k]] = f
            fill[k] += 1
    assert np.all(fill == d_out), "pattern not fan-out balanced"
    return rev_j, rev_f


def init(cfg: PaperNetConfig, key=None) -> Params:
    """Glorot-normal over actual degrees (Sec. III-C-1); biases initialized
    like weights (stored in the same memories on the FPGA)."""
    key = key if key is not None else jax.random.PRNGKey(cfg.seed)
    pats = patterns(cfg)
    params: Params = {"junctions": []}
    for i, pat in enumerate(pats):
        k1, k2, key = jax.random.split(key, 3)
        std = np.sqrt(2.0 / (cfg.d_out[i] + cfg.d_in(i)))
        if cfg.init_mode == "shared":
            # W_i/z_i unique values replicated across the z_i memories
            n_unique = cfg.weights(i) // cfg.z[i]
            uw = jax.random.normal(k1, (n_unique,)) * std
            w = jnp.tile(uw, cfg.z[i]).reshape(pat.n_out, pat.idx.shape[1])
            b = jnp.tile(uw[: max(1, pat.n_out // n_unique + 1)],
                         n_unique)[: pat.n_out] * 0 + uw[0]
            b = jnp.full((pat.n_out,), uw[0])
        else:
            w = jax.random.normal(k1, pat.idx.shape) * std
            b = jax.random.normal(k2, (pat.n_out,)) * std
        rev_j, rev_f = reverse_pattern(pat)
        if cfg.fmt is not None:
            w = fxp.quantize(w, cfg.fmt)
            b = fxp.quantize(b, cfg.fmt)
        params["junctions"].append({
            "w": w, "b": b,
            "idx": jnp.asarray(pat.idx),
            "rev_j": jnp.asarray(rev_j), "rev_f": jnp.asarray(rev_f),
        })
    return params


# ------------------------------------------------------------------ ops
def _q(x, fmt):
    return x if fmt is None else fxp.quantize(x, fmt)


def _act(s, cfg: PaperNetConfig, tables):
    if cfg.activation == "sigmoid":
        if cfg.fmt is None:
            a = jax.nn.sigmoid(s)
            return a, a * (1 - a)
        return fxp.lut_sigmoid(s, cfg.fmt, tables)
    clip_at = 8.0 if cfg.activation == "relu8" else 1.0
    if cfg.fmt is None:
        return jnp.clip(s, 0, clip_at), ((s > 0) & (s < clip_at)).astype(s.dtype)
    return fxp.relu_clipped(s, cfg.fmt, clip_at)


def ff_junction(jp: Params, a_prev, cfg: PaperNetConfig, i: int, tables):
    """eq. (1): s_j = sum_f w[j,f] * a_prev[idx[j,f]] + b_j  (clipping tree),
    returns (a, a_dot, s)."""
    fmt = cfg.fmt
    gathered = jnp.take(a_prev, jp["idx"], axis=-1)          # [..., N_out, d_in]
    prod = _q(jp["w"] * gathered, fmt)
    if fmt is None:
        s = jnp.sum(prod, axis=-1) + jp["b"]
    else:
        s = fxp.q_add(fxp.tree_sum_clipped(prod, fmt), jp["b"], fmt)
    a, adot = _act(s, cfg, tables)
    return a, adot, s


def forward(params: Params, x, cfg: PaperNetConfig, tables=None):
    """Full FF pass.  x [..., N_0] -> activations list [a_0 .. a_L]."""
    tables = tables or (fxp.sigmoid_tables(cfg.fmt) if cfg.fmt else None)
    acts, adots = [x], [None]
    a = x
    for i, jp in enumerate(params["junctions"]):
        a, adot, _ = ff_junction(jp, a, cfg, i, tables)
        acts.append(a)
        adots.append(adot)
    return acts, adots


def bp_junction(jp: Params, delta_next, adot, cfg: PaperNetConfig):
    """eq. (2b): delta_i[k] = adot[k] * sum over the d_out edges of w*delta."""
    fmt = cfg.fmt
    w_rev = jnp.take_along_axis(
        jnp.take(jp["w"], jp["rev_j"], axis=0),               # [N_in, d_out, d_in]
        jp["rev_f"][..., None], axis=-1)[..., 0]              # [N_in, d_out]
    d_rev = jnp.take(delta_next, jp["rev_j"], axis=-1)        # [..., N_in, d_out]
    prod = _q(w_rev * d_rev, fmt)
    if fmt is None:
        s = jnp.sum(prod, axis=-1)
    else:
        s = fxp.tree_sum_clipped(prod, fmt)
    return _q(adot * s, fmt)


def up_junction(jp: Params, a_prev, delta, eta, cfg: PaperNetConfig) -> Params:
    """eq. (3): w -= eta * a_prev[idx] * delta ; b -= eta * delta.
    eta is a power of two, so eta*x is exact on the grid (a bit shift)."""
    fmt = cfg.fmt
    gathered = jnp.take(a_prev, jp["idx"], axis=-1)
    gw = _q(gathered * delta[..., None], fmt)
    if gw.ndim > jp["w"].ndim:                   # mini-batch: average grads
        gw = gw.mean(axis=tuple(range(gw.ndim - jp["w"].ndim)))
        gd = delta.mean(axis=tuple(range(delta.ndim - jp["b"].ndim)))
    else:
        gd = delta
    new_w = _q(jp["w"] - eta * gw, fmt)
    new_b = _q(jp["b"] - eta * gd, fmt)
    return dict(jp, w=new_w, b=new_b)


def output_delta(a_out, y, cfg: PaperNetConfig):
    """eq. (2a): cross-entropy + sigmoid -> delta_L = a_L - y."""
    return _q(a_out - y, cfg.fmt)


# ------------------------------------------------------------------ training
def sgd_step(params: Params, x, y, eta, cfg: PaperNetConfig, tables=None):
    """One sequential FF -> BP -> UP pass (the non-pipelined reference)."""
    acts, adots = forward(params, x, cfg, tables)
    L = cfg.n_junctions
    deltas = [None] * (L + 1)
    deltas[L] = output_delta(acts[L], y, cfg)
    for i in range(L - 1, 0, -1):
        deltas[i] = bp_junction(params["junctions"][i], deltas[i + 1],
                                adots[i], cfg)
    new_j = [up_junction(params["junctions"][i], acts[i], deltas[i + 1], eta, cfg)
             for i in range(L)]
    loss = -jnp.mean(y * jnp.log(jnp.clip(acts[L], 1e-7, 1.0))
                     + (1 - y) * jnp.log(jnp.clip(1 - acts[L], 1e-7, 1.0)))
    return {"junctions": new_j}, loss, acts[L]


def train_epoch(params: Params, xs, ys, eta, cfg: PaperNetConfig):
    """Online SGD over an epoch, jit-compiled as one scan."""
    tables = fxp.sigmoid_tables(cfg.fmt) if cfg.fmt else None

    def step(p, xy):
        x, y = xy
        p2, loss, out = sgd_step(p, x, y, eta, cfg, tables)
        correct = (jnp.argmax(out, -1) == jnp.argmax(y, -1)).astype(jnp.float32)
        return p2, (loss, correct)

    params, (losses, corrects) = jax.lax.scan(step, params, (xs, ys))
    return params, losses, corrects


def train_epoch_pipelined(params: Params, xs, ys, eta, cfg: PaperNetConfig):
    """Junction-pipelined training for the paper's L=2 network (Fig. 1).

    Clock t (all ops read start-of-clock state; updates land at clock end):
      J1.FF(t)    J2.FF(t-1)+cost    J2.BP(t-2)    J2.UP(t-2)    J1.UP(t-3)
    Weight staleness exactly matches the FPGA schedule; accuracy parity with
    ``train_epoch`` is the paper's implicit claim (validated in
    benchmarks/pipeline_parity.py)."""
    assert cfg.n_junctions == 2, "clocked schedule is specialized to L=2"
    tables = fxp.sigmoid_tables(cfg.fmt) if cfg.fmt else None
    N0, N1, N2 = cfg.layers
    n = xs.shape[0]
    zf = lambda *s: jnp.zeros(s, xs.dtype)
    # FIFO slots for inputs in flight (t, t-1, t-2, t-3)
    fifo0 = {"a0": zf(4, N0), "y": zf(4, N2)}
    fifo1 = {"a1": zf(3, N1), "adot1": zf(3, N1)}      # produced by J1.FF
    fifo2 = {"delta2": zf(1, N2)}                      # produced by J2 cost
    fifo_d1 = {"delta1": zf(1, N1)}                    # produced by J2.BP

    def clock(carry, xy):
        p, f0, f1, f2, fd1, stats = carry
        x, y = xy
        j1, j2 = p["junctions"]
        # shift input fifo
        a0s = jnp.roll(f0["a0"], 1, axis=0).at[0].set(x)
        ys_ = jnp.roll(f0["y"], 1, axis=0).at[0].set(y)
        # J1.FF on input t
        a1_t, adot1_t, _ = ff_junction(j1, x, cfg, 0, tables)
        # J2.FF + cost on input t-1
        a2_tm1, _, _ = ff_junction(j2, f1["a1"][0], cfg, 1, tables)
        delta2_tm1 = output_delta(a2_tm1, ys_[1], cfg)
        # J2.BP on input t-2 (uses delta2 computed last clock)
        delta1_tm2 = bp_junction(j2, f2["delta2"][0], f1["adot1"][1], cfg)
        # J2.UP on input t-2
        j2_new = up_junction(j2, f1["a1"][1], f2["delta2"][0], eta, cfg)
        # J1.UP on input t-3 (uses delta1 computed last clock)
        j1_new = up_junction(j1, a0s[3], fd1["delta1"][0], eta, cfg)
        # advance fifos
        f1n = {"a1": jnp.roll(f1["a1"], 1, 0).at[0].set(a1_t),
               "adot1": jnp.roll(f1["adot1"], 1, 0).at[0].set(adot1_t)}
        f2n = {"delta2": f2["delta2"].at[0].set(delta2_tm1)}
        fd1n = {"delta1": fd1["delta1"].at[0].set(delta1_tm2)}
        correct = (jnp.argmax(a2_tm1, -1) == jnp.argmax(ys_[1], -1)).astype(jnp.float32)
        return ({"junctions": [j1_new, j2_new]},
                {"a0": a0s, "y": ys_}, f1n, f2n, fd1n, stats), correct

    carry = (params, fifo0, fifo1, fifo2, fifo_d1, 0.0)
    (params, *_), corrects = jax.lax.scan(clock, carry, (xs, ys))
    return params, corrects
