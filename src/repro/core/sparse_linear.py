"""Pre-defined-sparse linear layer — the paper's junction as a JAX module.

Storage follows the paper's edge-centric layout: weights live as dense
(block, block) tiles indexed by a static block pattern (core/sparsity.py),
exactly like the FPGA's z-wide weight memories indexed through the
interleaver.  Three apply paths:

* ``engine="jnp"``    — gather + einsum, pure jnp.  Used for lowering/dry-run
                        (correct FLOP accounting) and CPU tests.
* ``engine="pallas"`` — the unified edge-bundle Pallas engine
                        (kernels/ops.junction_matmul, the E=1 case of the
                        E-generic kernel family): kb reduction + bias +
                        activation in one kernel, custom_vjp through the
                        fused dx/dw kernels with the reverse weight
                        bundles DMA'd in-kernel.  TPU target; interpret
                        mode off-TPU (tests).
* ``engine="auto"``   — pallas on TPU backends, jnp elsewhere.  This is
                        the default the whole stack runs through
                        (ArchConfig.engine -> models -> train/serve).
* dense fallback      — when a SparsityConfig does not apply (density 1.0,
                        dims not tileable), an ordinary dense matmul.

The neuron-level interleaver composes with the block pattern as a static
permutation — on TPU a layout choice, not a runtime cost (XLA folds static
gathers into the producing op); the bit-faithful neuron-level path lives in
core/paper_net.py.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparsity import BlockPattern, SparsityConfig, make_block_pattern

Params = dict[str, Any]

# Static pattern leaves of a sparse junction: int32 scalar-prefetch operands
# of the unified kernels — non-trainable, replicated by parallel/sharding.py
# and skipped by the optimizer.  MoE expert FFNs store the same leaves under
# per-junction names (one shared pattern for the in/out junctions).
PATTERN_LEAVES = ("idx", "rev_ob", "rev_t", "rev_cnt")
MOE_PATTERN_LEAVES = ("idx_in", "idx_out",
                      "rev_in_ob", "rev_in_t", "rev_in_cnt",
                      "rev_out_ob", "rev_out_t", "rev_out_cnt")

# Fused BP+UP context leaves (train/steps.py injects them into every
# pattern-bearing junction dict before differentiating; they exist only
# inside the traced fused train step, never in the stored params tree):
# UPDATE_HYP_LEAF carries the optimizer's hyp row — the legacy
# [lr, momentum] pair or the full (HYP_K,) registry row
# (kernels/block_sparse_matmul.py docstring) — or, for E-batched
# population junctions (src/repro/search/), a per-unit [E, 2] / [E, HYP_K]
# table — broadcast over any layer stacking dims so lax.scan slices it
# per layer.  FUSED_SLOT_NAMES maps each optimizer accumulator slot
# (position i = the kernels' slot i: 0 = SGD momentum / Adam m, 1 = Adam
# v) from each trainable junction weight leaf to that slot's injected
# name; WHICH slots are injected is the kernels' static optimizer switch
# (FusedOptimizer.slot_keys()).  The custom_vjp returns the UPDATED
# params / slots as these leaves' cotangents — the "grads" tree of a
# fused step carries new parameters, not gradients, at junction leaves.
UPDATE_HYP_LEAF = "upd_hyp"
FUSED_MOM = {"w": "mom_w", "b": "mom_b",
             "wi": "mom_wi", "wg": "mom_wg", "wo": "mom_wo"}
FUSED_VEL = {"w": "vel_w", "b": "vel_b",
             "wi": "vel_wi", "wg": "vel_wg", "wo": "vel_wo"}
FUSED_SLOT_NAMES = (FUSED_MOM, FUSED_VEL)
# Divergence-detector leaves: dummy f32 [..., E] zeros injected alongside
# upd_hyp; their cotangents carry the update kernels' per-unit non-finite
# counts (kernels/block_sparse_matmul.py with_health contract).  A single
# junction carries "upd_health" (E=1); a MoE expert-FFN dict carries one
# per fused junction (in/out).  train/steps.py sums them into
# metrics["nonfinite"].
UPDATE_HEALTH_LEAF = "upd_health"
MOE_HEALTH_LEAVES = ("upd_health_in", "upd_health_out")
HEALTH_LEAVES = (UPDATE_HEALTH_LEAF,) + MOE_HEALTH_LEAVES


def is_junction(p) -> bool:
    """A pattern-bearing parameter dict: a single sparse junction ("idx")
    or a MoE expert-FFN pair sharing patterns ("idx_in")."""
    return isinstance(p, dict) and ("idx" in p or "idx_in" in p)


def normalize_slots(slots):
    """Lift every accepted optimizer-state shape to the canonical tuple of
    per-slot trees: None → () (plain SGD), a single params-mirroring tree
    → a 1-tuple (the PR 4 momentum contract), a tuple/list of trees →
    itself (Adam passes (m, v)).  The ambiguity between "one tree" and
    "tuple of trees" is static: params trees are dicts or lists of dicts
    at top level, never tuples."""
    if slots is None:
        return ()
    if isinstance(slots, tuple):
        return slots
    return (slots,)


def inject_update_ctx(params, slots, hyp):
    """Copy of ``params`` with the fused-update context added to every
    junction dict: ``upd_hyp`` (broadcast to the junction's stacking dims,
    derived from its idx leaf) plus the junction's optimizer accumulator
    slots from the mirrored trees in ``slots`` (anything
    ``normalize_slots`` accepts: None → plain SGD, one tree → momentum,
    an (m, v) pair → Adam — slot i lands under its ``FUSED_SLOT_NAMES[i]``
    leaf names, which is how the kernels select the optimizer).  ``hyp``
    is the shared hyp row ((2,) legacy pair or (HYP_K,) registry row) or
    — for E-batched population junctions — a per-unit [E, 2] / [E, HYP_K]
    table; any accepted shape rides through to ``junction_train_update``
    unchanged.  Every junction also gets its dummy health leaf(s) (zeros,
    shape stack + (E,)) so the in-kernel divergence flags come back as
    their cotangents.  Dense leaves ride through untouched — the
    optimizer tree-maps them."""
    slots = normalize_slots(slots)
    if len(slots) > len(FUSED_SLOT_NAMES):
        raise ValueError(f"{len(slots)} accumulator slots, but the kernel "
                         f"contract defines {len(FUSED_SLOT_NAMES)}")

    def rec(p, ms):
        if isinstance(p, dict):
            out = {}
            for k, v in p.items():
                if isinstance(v, (dict, list, tuple)):
                    out[k] = rec(v, tuple(m[k] for m in ms))
                else:
                    out[k] = v
            if is_junction(p):
                if is_quantized(p):
                    raise ValueError(
                        "fused-update context injected into a quantized "
                        "junction — the int8/fxp datapath is "
                        "inference-only; reload full-precision weights "
                        "to train")
                idx = p["idx"] if "idx" in p else p["idx_in"]
                stack = idx.shape[:-2]   # leading layer-scan dims
                out[UPDATE_HYP_LEAF] = jnp.broadcast_to(
                    hyp, stack + tuple(jnp.shape(hyp)))
                wl = p["w"] if "w" in p else p["wg"]
                E = (wl.shape[len(stack)]
                     if wl.ndim - len(stack) == 5 else 1)
                zeros = jnp.zeros(stack + (E,), jnp.float32)
                for hk in (MOE_HEALTH_LEAVES if "idx_in" in p
                           else (UPDATE_HEALTH_LEAF,)):
                    out[hk] = zeros
                for m, names in zip(ms, FUSED_SLOT_NAMES):
                    for k, mk in names.items():
                        if k in p and not isinstance(p[k], dict):
                            out[mk] = m[k]
            return out
        if isinstance(p, (list, tuple)):
            return type(p)(rec(v, tuple(m[i] for m in ms))
                           for i, v in enumerate(p))
        return p
    return rec(params, slots)


def is_sparse(params: Params) -> bool:
    return "idx" in params


def is_quantized(params) -> bool:
    """A junction whose fp weight leaves were replaced by integer codes
    at load time (core/quantize.py): inference-only — the fused-update
    injector and the train paths refuse these dicts."""
    return isinstance(params, dict) and ("wq" in params or "wgq" in params)


def init_dense(key, n_in: int, n_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: float | None = None) -> Params:
    scale = float(scale if scale is not None else 1.0 / np.sqrt(n_in))
    p: Params = {"w": jax.random.normal(key, (n_in, n_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((n_out,), dtype)
    return p


def init_sparse(key, n_in: int, n_out: int, sp: SparsityConfig, *,
                bias: bool = False, dtype=jnp.float32,
                seed: int = 0) -> Params:
    """Glorot-normal init over the *kept* edges (paper Sec. III-C-1: variance
    2/(d_out + d_in) over actual degrees, not the dense widths)."""
    pat = make_block_pattern(n_in, n_out, sp.density, sp.block, seed=seed)
    d_in = pat.fan_in_blocks * pat.block          # actual in-degree per neuron
    d_out = pat.fan_out_blocks * pat.block
    scale = float(np.sqrt(2.0 / (d_in + d_out)))
    shape = (pat.n_out_blocks, pat.fan_in_blocks, pat.block, pat.block)
    p: Params = {
        "w": jax.random.normal(key, shape, dtype) * scale,
        "idx": jnp.asarray(pat.idx),              # static, non-trainable
        "rev_ob": jnp.asarray(pat.rev_ob),
        "rev_t": jnp.asarray(pat.rev_t),
        "rev_cnt": jnp.asarray(pat.rev_cnt),
    }
    if bias:
        p["b"] = jnp.zeros((n_out,), dtype)
    return p


def init_linear(key, n_in: int, n_out: int, *, family: str,
                sp: SparsityConfig | None, bias: bool = False,
                dtype=jnp.float32, seed: int = 0) -> Params:
    """Dense unless the paper's technique applies and the dims tile."""
    if (sp is not None and sp.applies_to(family)
            and n_in % sp.block == 0 and n_out % sp.block == 0
            and n_in // sp.block >= 2):
        return init_sparse(key, n_in, n_out, sp, bias=bias, dtype=dtype, seed=seed)
    return init_dense(key, n_in, n_out, bias=bias, dtype=dtype)


def apply_jnp(params: Params, x: jax.Array) -> jax.Array:
    """y[..., n_out] — per fan-in slot: gather one input block per output
    block, rank-bs matmul, accumulate.

    FLOPs = 2 * M * n_out * (fan_in_blocks * block) — density-scaled, which
    is what the roofline accounting must see.  Looping over the (small)
    fan-in keeps peak memory at O(n_out) per step — gathering all slots at
    once materializes a fan_in_blocks-times-larger tensor (29x d_model for
    qwen2's FFN; §Perf iteration S1).
    """
    w = params["w"]                                  # [nob, kb, bs, bs]
    idx = params["idx"]                              # [nob, kb]
    nob, kb, bs, _ = w.shape
    lead = x.shape[:-1]
    xb = x.reshape(*lead, -1, bs)                    # [..., nib, bs]
    wc = w.astype(x.dtype)
    y = None
    for k in range(kb):                              # kb is small and static
        xk = jnp.take(xb, idx[:, k], axis=-2)        # [..., nob, bs]
        part = jnp.einsum("...ob,obc->...oc", xk, wc[:, k])
        y = part if y is None else y + part
    y = y.reshape(*lead, nob * bs)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def apply_dense(params: Params, x: jax.Array) -> jax.Array:
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def resolve_engine(engine: str) -> str:
    """'auto' -> 'pallas' on TPU backends, 'jnp' elsewhere.  Resolve once
    at step-build time (train/steps.py) so the traced graph is stable."""
    from repro.kernels import ops  # local import: kernels optional at runtime
    return ops.resolve_engine(engine)


def _with_act(y: jax.Array, act: str) -> jax.Array:
    """Epilogue for the jnp/dense paths — the single activation table the
    Pallas engine fuses, so the engines can never diverge formula-wise."""
    if act == "none":
        return y
    from repro.kernels import block_sparse_matmul as bsm
    return bsm.act_fwd(y, act).astype(y.dtype)


def apply(params: Params, x: jax.Array, *, engine: str = "auto",
          act: str = "none") -> jax.Array:
    """y = act(x @ W + b) through the configured execution engine.

    A junction dict carrying the injected fused-update context
    (``UPDATE_HYP_LEAF``; only ever present inside a fused train step's
    trace) routes through ``junction_train_update``: forward identical,
    backward returns the updated params as the weight cotangents."""
    if not is_sparse(params):
        return _with_act(apply_dense(params, x), act)
    quantized = is_quantized(params)
    if quantized and UPDATE_HYP_LEAF in params:
        raise ValueError("quantized junction inside a fused train step — "
                         "the int8/fxp datapath is inference-only")
    if resolve_engine(engine) == "pallas":
        from repro.kernels import ops  # local import: kernels optional at runtime
        if UPDATE_HYP_LEAF in params:
            return ops.junction_train_update(
                x, params["w"], params["idx"], params["rev_ob"],
                params["rev_t"], params["rev_cnt"], bias=params.get("b"),
                act=act, hyp=params[UPDATE_HYP_LEAF],
                mom=params.get("mom_w"), mom_b=params.get("mom_b"),
                vel=params.get("vel_w"), vel_b=params.get("vel_b"),
                health=params.get(UPDATE_HEALTH_LEAF))
        if quantized:
            return ops.junction_matmul(
                x, params["wq"], params["idx"], params["rev_ob"],
                params["rev_t"], params["rev_cnt"], bias=params.get("b"),
                act=act, w_scale=params.get("w_scale"),
                x_scale=params.get("x_scale"), qfmt=params.get("qfmt"),
                qlut=params.get("qlut"))
        return ops.junction_matmul(
            x, params["w"], params["idx"], params["rev_ob"], params["rev_t"],
            params["rev_cnt"], bias=params.get("b"), act=act)
    if quantized:
        from repro.core import quantize as qz  # local: avoids import cycle
        return qz.apply_quant_jnp(params, x, act=act)
    return _with_act(apply_jnp(params, x), act)


def density(params: Params) -> float:
    if not is_sparse(params):
        return 1.0
    kb = (params["w"] if "w" in params else params["wq"]).shape[1]
    # rev_ob's leading dim IS n_in_blocks (built per input block by
    # reverse_block_pattern) — a static shape, so no host sync in jitted
    # contexts, and exact even when the highest input block is unused.
    n_in_blocks = params["rev_ob"].shape[0]
    return kb / n_in_blocks


def n_weights(params: Params) -> int:
    return int(np.prod((params["w"] if "w" in params
                        else params["wq"]).shape))
