"""Bit-accurate fixed-point arithmetic (paper Sec. III-C).

The paper's configuration is the bit triplet (b_w, b_n, b_f): total bits,
integer bits, fraction bits, with b_w = b_n + b_f + 1 (sign).  Range is
[-2^b_n, 2^b_n - 2^-b_f], precision 2^-b_f.  All computed values and
trainable parameters — a, a-dot, delta, w, b — share one triplet; adders
and multipliers *clip* (saturate) instead of wrapping (Sec. III-C-3).

We simulate on fp32 numbers constrained to the fixed-point grid: every op
is followed by ``quantize`` (round-to-nearest-even + saturate), and sums
are reduced by a *clipping tree adder* of depth log2(d_in) exactly like the
FPGA's arithmetic (Sec. III-D-3) — intermediate clipping is part of the
semantics, not an afterthought.

The sigmoid LUT mirrors Sec. III-D-1: all 2^b_w possible codes are
pre-evaluated (no interpolation), sigma to b_f fractional bits, sigma' to
b_f - 2 bits (its range is [0, 1/4]).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FxpFormat", "PAPER_TRIPLETS", "quantize", "tree_sum_clipped",
           "sigmoid_tables", "lut_sigmoid", "encode", "decode"]


@dataclasses.dataclass(frozen=True)
class FxpFormat:
    bw: int   # total bits
    bn: int   # integer bits
    bf: int   # fraction bits

    def __post_init__(self):
        assert self.bw == self.bn + self.bf + 1, "b_w = b_n + b_f + 1"

    @property
    def scale(self) -> float:
        return float(2 ** self.bf)

    @property
    def max_val(self) -> float:
        return float(2 ** self.bn) - 1.0 / self.scale

    @property
    def min_val(self) -> float:
        return -float(2 ** self.bn)

    @property
    def n_codes(self) -> int:
        return 2 ** self.bw


# Table II of the paper
PAPER_TRIPLETS = [FxpFormat(8, 2, 5), FxpFormat(10, 2, 7), FxpFormat(10, 3, 6),
                  FxpFormat(12, 3, 8), FxpFormat(16, 4, 11)]
PAPER_FMT = FxpFormat(12, 3, 8)   # the chosen configuration


def quantize(x: jax.Array, fmt: FxpFormat) -> jax.Array:
    """Round to the grid, saturate to [min_val, max_val] (clipping unit)."""
    q = jnp.round(x.astype(jnp.float32) * fmt.scale) / fmt.scale
    return jnp.clip(q, fmt.min_val, fmt.max_val)


def q_mul(a, b, fmt: FxpFormat):
    return quantize(a * b, fmt)


def q_add(a, b, fmt: FxpFormat):
    return quantize(a + b, fmt)


def tree_sum_clipped(x: jax.Array, fmt: FxpFormat, axis: int = -1) -> jax.Array:
    """Pairwise tree reduction with clipping at every adder node — the
    hardware's log2(d_in)-deep tree adder (Sec. III-D-3)."""
    x = jnp.moveaxis(x, axis, -1)
    n = x.shape[-1]
    # pad to a power of two with zeros (zeros are exact on the grid)
    p = 1 << (n - 1).bit_length()
    if p != n:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, p - n)])
    while x.shape[-1] > 1:
        x = q_add(x[..., 0::2], x[..., 1::2], fmt)
    return x[..., 0]


def encode(x: jax.Array, fmt: FxpFormat) -> jax.Array:
    """fp value on the grid -> integer code in [0, 2^bw) (two's complement)."""
    i = jnp.round(jnp.clip(x, fmt.min_val, fmt.max_val) * fmt.scale).astype(jnp.int32)
    return jnp.where(i < 0, i + fmt.n_codes, i)


def decode(code: jax.Array, fmt: FxpFormat) -> jax.Array:
    i = jnp.where(code >= fmt.n_codes // 2, code - fmt.n_codes, code)
    return i.astype(jnp.float32) / fmt.scale


def sigmoid_tables(fmt: FxpFormat) -> tuple[np.ndarray, np.ndarray]:
    """(sigma table, sigma' table), one entry per code (paper: 4096 entries
    for b_w=12).  sigma quantized to b_f bits; sigma' to b_f-2 bits since its
    range is [0, 1/4] (paper uses 6 fractional bits at b_f=8)."""
    codes = np.arange(fmt.n_codes)
    vals = np.where(codes >= fmt.n_codes // 2, codes - fmt.n_codes, codes) / fmt.scale
    sig = 1.0 / (1.0 + np.exp(-vals))
    dsig = sig * (1.0 - sig)
    sig_q = np.round(sig * fmt.scale) / fmt.scale
    dscale = 2 ** max(1, fmt.bf - 2)
    dsig_q = np.round(dsig * dscale) / dscale
    return sig_q.astype(np.float32), dsig_q.astype(np.float32)


def lut_sigmoid(x: jax.Array, fmt: FxpFormat, tables=None):
    """(sigma(x), sigma'(x)) via table lookup on the code of x."""
    if tables is None:
        tables = sigmoid_tables(fmt)
    sig_t, dsig_t = (jnp.asarray(t) for t in tables)
    code = encode(x, fmt)
    return jnp.take(sig_t, code, axis=0), jnp.take(dsig_t, code, axis=0)


def relu_clipped(x: jax.Array, fmt: FxpFormat, clip_at: float):
    """Paper Sec. III-C-4: ReLU clipped at 8 (=2^bn) or 1."""
    y = jnp.clip(x, 0.0, clip_at)
    dy = jnp.where((x > 0) & (x < clip_at), 1.0, 0.0)
    return quantize(y, fmt), dy
