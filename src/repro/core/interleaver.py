"""Clash-free interleavers (paper Sec. II-B / ref [18]) and their TPU analogue.

The FPGA design reads ``z`` weights per cycle; traced back through the
interleaver they must touch ``z`` *distinct* activation memory banks
(Fig. 2).  Ref [18] calls the construction family SV+SS (starting-vector +
sweep-shift).  We implement:

* ``affine_interleaver`` — the classic clash-free family pi(k) = (a*k + b) mod W
  with gcd(a, W) = 1.  With bank(j) = j mod z and a coprime to z, any z
  consecutive k map to z distinct banks (proved in test_interleaver.py).
* ``sv_ss_interleaver`` — SV+SS: per-sweep starting vectors added to a base
  affine sweep, preserving clash freedom as long as each sweep's offsets are
  congruent mod z to a permutation (we use per-sweep rotations).
* ``block_circulant_pattern`` — the TPU-native analogue: sparsity expressed at
  MXU-tile granularity.  Output block ``ob`` connects to input blocks
  ``(ob * stride + t * hop) mod n_in`` for ``t < fan_in_blocks``;  with
  ``gcd(hop, n_in) == 1`` every input block has *exactly* equal fan-out —
  the banking clash-freedom property becomes a load-balance property: every
  model shard and every Pallas grid step does identical work.

All functions are pure numpy (static, pre-computed before training — the
whole point of *pre-defined* sparsity is that connectivity never changes).
"""
from __future__ import annotations

import math

import numpy as np

__all__ = [
    "affine_interleaver",
    "sv_ss_interleaver",
    "is_clash_free",
    "block_circulant_pattern",
    "reverse_block_pattern",
    "pattern_fan_counts",
]


def _coprime_step(n: int, preferred: int) -> int:
    """Smallest a >= preferred with gcd(a, n) == 1."""
    a = max(1, preferred)
    while math.gcd(a, n) != 1:
        a += 1
    return a


def affine_interleaver(n_weights: int, z: int, seed: int = 0) -> np.ndarray:
    """pi(k) = (a*k + b) mod W, gcd(a, W)=1 and gcd(a, z)=1.

    Returns an int32 permutation of [0, W).  Reading weights k, k+1, ..,
    k+z-1 (one cycle's worth) touches banks (a*k+b+a*t) mod z for
    t in [0, z); since gcd(a, z)=1 these are z distinct banks.
    """
    if n_weights % z != 0:
        raise ValueError(f"W={n_weights} must be divisible by z={z}")
    rng = np.random.default_rng(seed)
    # a must be coprime to both W and z for clash freedom at bank size z.
    base = int(rng.integers(1, n_weights))
    a = _coprime_step(n_weights * z // math.gcd(n_weights, z), base)
    # ensure coprime to both by construction: step through candidates
    while math.gcd(a, n_weights) != 1 or math.gcd(a, z) != 1:
        a += 1
    b = int(rng.integers(0, n_weights))
    k = np.arange(n_weights, dtype=np.int64)
    return ((a * k + b) % n_weights).astype(np.int32)


def sv_ss_interleaver(n_weights: int, z: int, seed: int = 0) -> np.ndarray:
    """SV+SS clash-free interleaver (ref [18] family).

    The weight sequence is processed in sweeps of z.  Each sweep s uses the
    base affine map plus a per-sweep starting-vector rotation r_s applied
    *in multiples of z* so bank residues within a sweep stay a permutation
    of Z_z (clash-free), while successive sweeps land on different rows —
    giving the scatter quality the paper's interleaving targets.
    """
    if n_weights % z != 0:
        raise ValueError(f"W={n_weights} must be divisible by z={z}")
    n_sweeps = n_weights // z
    rng = np.random.default_rng(seed + 1)
    base = affine_interleaver(n_weights, z, seed)
    # starting vectors: one multiple-of-z offset per sweep
    sv = (rng.integers(0, n_sweeps, size=n_sweeps) * z).astype(np.int64)
    out = np.empty(n_weights, dtype=np.int32)
    for s in range(n_sweeps):
        sl = slice(s * z, (s + 1) * z)
        out[sl] = (base[sl].astype(np.int64) + sv[s]) % n_weights
    # SV offsets can collide across sweeps; repair to a permutation while
    # preserving within-sweep bank residues (add multiples of z only).
    return _repair_permutation(out, z)


def _repair_permutation(idx: np.ndarray, z: int) -> np.ndarray:
    """Make idx a permutation by remapping duplicate rows (multiples of z)."""
    n = idx.shape[0]
    out = idx.astype(np.int64).copy()
    n_rows = n // z
    # row = idx // z, col(bank residue) = idx % z.  For each bank column,
    # the rows used must be a permutation of [0, n_rows): fix greedily.
    for bank in range(z):
        sel = np.where(out % z == bank)[0]
        rows = out[sel] // z
        used = np.zeros(n_rows, dtype=bool)
        free_rows = []
        order = np.argsort(sel)  # deterministic
        dup_positions = []
        for p in sel[order]:
            r = out[p] // z
            if used[r]:
                dup_positions.append(p)
            else:
                used[r] = True
        free_rows = np.where(~used)[0].tolist()
        for p, r in zip(dup_positions, free_rows):
            out[p] = r * z + bank
    assert len(np.unique(out)) == n, "repair failed to produce a permutation"
    return out.astype(np.int32)


def is_clash_free(pi: np.ndarray, z: int) -> bool:
    """Check Fig.-2 property: each cycle's z accesses hit z distinct banks."""
    n = pi.shape[0]
    if n % z:
        return False
    banks = (pi % z).reshape(n // z, z)
    return all(len(np.unique(row)) == z for row in banks)


# ---------------------------------------------------------------------------
# TPU block-level pattern (the MXU-native re-expression of pre-defined
# sparsity: fixed fan-in / fan-out at 128x128 block granularity).
# ---------------------------------------------------------------------------

def block_circulant_pattern(
    n_in_blocks: int,
    n_out_blocks: int,
    fan_in_blocks: int,
    seed: int = 0,
) -> np.ndarray:
    """Return idx[n_out_blocks, fan_in_blocks] — input block ids per output block.

    Invariants (tested):
      * every output block has exactly ``fan_in_blocks`` inputs (fixed fan-in)
      * every input block appears ``n_out_blocks*fan_in_blocks/n_in_blocks``
        times — exactly when that divides (the paper's N_{i-1}*d_out =
        N_i*d_in identity at block granularity), otherwise within +-1
        (coprime dims, e.g. qwen2's 64x231 FFN junction; the +-1 backward
        imbalance is handled by the masked reverse pattern).
      * no duplicate input block within one output block's list.
    """
    if fan_in_blocks > n_in_blocks:
        raise ValueError("fan_in_blocks cannot exceed n_in_blocks")
    total = n_out_blocks * fan_in_blocks
    if total % n_in_blocks != 0:
        # ragged case: near-balanced deterministic schedule (+-1 fan-out)
        rng = np.random.default_rng(seed)
        reps = total // n_in_blocks
        stride = _coprime_step(n_in_blocks, 1 + int(rng.integers(1, max(2, n_in_blocks))))
        extra = (np.arange(total % n_in_blocks, dtype=np.int64) * stride) % n_in_blocks
        flat = np.concatenate([
            np.tile(np.arange(n_in_blocks, dtype=np.int64), reps), extra])
        perm = (np.arange(total, dtype=np.int64) * _coprime_step(total, stride)) % total
        idx = flat[perm].reshape(n_out_blocks, fan_in_blocks)
        return _rebalance_rows(idx, n_in_blocks).astype(np.int32)
    rng = np.random.default_rng(seed)
    hop = _coprime_step(n_in_blocks, max(1, n_in_blocks // fan_in_blocks))
    start = rng.integers(0, n_in_blocks, size=n_out_blocks)
    # circulant family: ob reads (start[ob] + t*hop) mod n_in.  To guarantee
    # exact fan-out balance we derive start from a balanced residue schedule
    # rather than uniformly: ob -> (ob * fan_in_blocks ... ) pattern.
    ob = np.arange(n_out_blocks, dtype=np.int64)
    t = np.arange(fan_in_blocks, dtype=np.int64)
    # each output block ob starts at a distinct stride so that the multiset
    # of (start + t*hop) mod n_in is perfectly balanced.
    stride = _coprime_step(n_in_blocks, 1 + int(rng.integers(1, n_in_blocks)))
    idx = (ob[:, None] * stride + t[None, :] * hop) % n_in_blocks
    # De-duplicate within rows if hop*t wraps onto the same block (can only
    # happen when fan_in_blocks > n_in_blocks/gcd — guarded by coprimality,
    # but keep a check for safety).
    for r in range(n_out_blocks):
        row = idx[r]
        if len(np.unique(row)) != fan_in_blocks:
            # rotate to the lexicographically next conflict-free row
            offset = 1
            while True:
                cand = (row + offset) % n_in_blocks
                if len(np.unique(cand)) == fan_in_blocks:
                    idx[r] = cand
                    break
                offset += 1
    counts = np.bincount(idx.reshape(-1), minlength=n_in_blocks)
    if not np.all(counts == total // n_in_blocks):
        # fall back to an exactly-balanced deterministic schedule
        flat = np.tile(np.arange(n_in_blocks, dtype=np.int64), total // n_in_blocks)
        # interleave with a coprime stride for scatter quality
        perm = (np.arange(total, dtype=np.int64) * stride) % total
        flat = flat[perm]
        idx = flat.reshape(n_out_blocks, fan_in_blocks)
        for r in range(n_out_blocks):
            row, seen, pool = idx[r], set(), []
            for v in row:
                if v in seen:
                    pool.append(v)
                seen.add(int(v))
        idx = _rebalance_rows(idx, n_in_blocks)
    return idx.astype(np.int32)


def _rebalance_rows(idx: np.ndarray, n_in: int) -> np.ndarray:
    """Swap duplicated in-row entries between rows until all rows are sets."""
    idx = idx.copy()
    n_out, k = idx.shape
    for _ in range(4 * n_out):
        bad = None
        for r in range(n_out):
            u, c = np.unique(idx[r], return_counts=True)
            if np.any(c > 1):
                bad = (r, int(u[np.argmax(c > 1)]))
                break
        if bad is None:
            return idx
        r, v = bad
        # find a row that doesn't contain v and has an element not in row r
        for r2 in range(n_out):
            if r2 == r or v in idx[r2]:
                continue
            for j2 in range(k):
                w = idx[r2, j2]
                if w not in idx[r]:
                    j = int(np.where(idx[r] == v)[0][0])
                    idx[r, j], idx[r2, j2] = w, v
                    break
            else:
                continue
            break
    return idx


def reverse_block_pattern(
        idx: np.ndarray, n_in_blocks: int,
        strict: bool = False) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Transpose a block pattern for the backward pass.

    Returns (rev_ob, rev_t, rev_cnt): for each input block ib, the
    (output block, slot) pairs that read it, padded to the max fan-out with
    (0, 0) sentinels; rev_cnt[ib] is the valid count.  Exactly-balanced
    patterns (the paper's equal-contribution property, eq. (2b)) have
    constant rev_cnt; ragged (+-1) patterns carry at most one padded slot
    per input block, masked out by the dx kernel.

    strict=True enforces the paper's exact balance and raises otherwise.
    """
    n_out, k = idx.shape
    counts = np.bincount(idx.reshape(-1), minlength=n_in_blocks)
    fan_out = int(counts.max())
    if strict and (counts.min() != counts.max()):
        raise ValueError("pattern is not fan-out balanced")
    rev_ob = np.zeros((n_in_blocks, fan_out), dtype=np.int32)
    rev_t = np.zeros((n_in_blocks, fan_out), dtype=np.int32)
    fill = np.zeros(n_in_blocks, dtype=np.int64)
    for ob in range(n_out):
        for t in range(k):
            ib = int(idx[ob, t])
            rev_ob[ib, fill[ib]] = ob
            rev_t[ib, fill[ib]] = t
            fill[ib] += 1
    return rev_ob, rev_t, fill.astype(np.int32)


def pattern_fan_counts(idx: np.ndarray, n_in_blocks: int) -> tuple[np.ndarray, np.ndarray]:
    """(fan-in per output block, fan-out per input block) for invariant tests."""
    fan_in = np.full(idx.shape[0], idx.shape[1], dtype=np.int64)
    fan_out = np.bincount(idx.reshape(-1), minlength=n_in_blocks)
    return fan_in, fan_out
