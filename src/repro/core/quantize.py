"""Post-training quantization of junction weights — the int8/fixed-point
inference datapath (paper Sec. III-C/III-D re-expressed for the MXU).

Two modes, one storage contract.  ``quantize_junction`` REPLACES a
junction's fp weight leaf ``"w"`` with integer codes under ``"wq"`` (MoE
expert dicts: ``wg``/``wi``/``wo`` → ``wgq``/``wiq``/``woq``), so a
quantized tree provably cannot reach the fp kernels — there is no fp
weight left to dot.  Detection everywhere is structural: ``"wq" in
params`` (``"wgq"`` for expert dicts).

* ``mode="int8"`` — symmetric absmax weight quantization per
  ``[nob, kb]`` block (``granularity="block"``) or one scale per
  junction unit (``granularity="unit"``, broadcast into the SAME
  ``[..., nob, kb]`` scale layout so the kernel has one contract).
  Codes are an int8 container for any ``bits <= 8`` (sub-8 widths clip
  to ±(2^(bits-1)-1) — the quality-vs-speed sweep axis).  Activations
  are quantized DYNAMICALLY per row per gathered fan-in slot (absmax /
  127) unless a calibrated static per-unit ``x_scale`` rides along
  (``calibrate_layer_scales``: absmax over a calibration batch).  The
  dequant epilogue rescales the int32 dot back to fp32, then the
  ordinary fused activation applies — quality loss is the quantization
  error only.
* ``mode="fxp"`` — the paper's full fixed-point pipeline: weights (and
  in-kernel, activations) become bit-triplet codes (value * 2^bf,
  saturated to the ``FxpFormat`` range), products accumulate exactly in
  int32, one round-half-up shift by bf + saturate replaces the fp
  epilogue, and the activation is a VMEM-resident LUT over all 2^bw
  codes (``core/fixed_point.sigmoid_tables``) — bit-exact against the
  ``core/fixed_point.py`` clipping-tree reference whenever no
  intermediate adder clips and products land on the grid.  The LUT
  bakes the activation at quantize time (``qlut``), so the runtime
  ``act`` argument is ignored on this path; ``qfmt = [bf, bn]`` rides
  as a traced i32 scalar-prefetch leaf (the saturate bound comes from
  the static LUT length: 2^(bn+bf) == len(lut)/2).

Both modes are INFERENCE-ONLY: ``ops.junction_train_update`` and
``sparse_linear.inject_update_ctx`` refuse integer-code weights.

The jnp sims here (``apply_quant_jnp`` / ``expert_apply_int8``) are the
``engine="jnp"`` twins of the quantized Pallas kernels and intentionally
mirror their op-for-op arithmetic (same scale grouping, same per-slot
accumulation order) so engine parity is exact, not approximate.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixed_point as fp
from repro.core.fixed_point import PAPER_FMT, FxpFormat

Params = dict[str, Any]

# Leaves a quantized junction may carry on top of the pattern leaves.
QUANT_LEAVES = ("wq", "w_scale", "x_scale", "qfmt", "qlut")
MOE_QUANT_LEAVES = ("wgq", "wg_scale", "wiq", "wi_scale", "woq", "wo_scale",
                    "x_scale_in", "x_scale_out")

# activations the fxp LUT can bake (act_lut below)
FXP_LUT_ACTS = ("sigmoid", "none", "relu")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """One quantization configuration — a population-sweep member
    (launch/quant_sweep.py sweeps bits x granularity as the E axis).

    mode: "int8" (scaled integer codes, fp32 dequant epilogue + fp act)
        or "fxp" (the paper's full fixed-point pipeline + LUT act).
    bits: int8 mode weight code width, 2..8 (codes stay in the int8
        container; sub-8 widths just clip tighter).
    granularity: "block" (one scale per [nob, kb] weight block) or
        "unit" (one scale per junction unit, broadcast to block layout).
    fmt: fxp mode bit triplet (Table II).
    act: fxp mode LUT activation, baked at quantize time.
    """
    mode: str = "int8"
    bits: int = 8
    granularity: str = "block"
    fmt: FxpFormat = PAPER_FMT
    act: str = "sigmoid"

    def __post_init__(self):
        if self.mode not in ("int8", "fxp"):
            raise ValueError(f"unknown quant mode {self.mode!r} (int8 | fxp)")
        if self.mode == "int8" and not 2 <= self.bits <= 8:
            raise ValueError(f"int8 mode bits must be 2..8, got {self.bits}")
        if self.granularity not in ("block", "unit"):
            raise ValueError(f"granularity {self.granularity!r} "
                             "(block | unit)")
        if self.mode == "fxp" and self.act not in FXP_LUT_ACTS:
            raise ValueError(f"fxp LUT activation {self.act!r} "
                             f"(one of {FXP_LUT_ACTS})")

    def to_dict(self) -> dict:
        d = {"mode": self.mode, "bits": self.bits,
             "granularity": self.granularity}
        if self.mode == "fxp":
            d.update(fmt=[self.fmt.bw, self.fmt.bn, self.fmt.bf],
                     act=self.act)
        return d


def structure_key(q: QuantConfig) -> tuple:
    """The cohort key for E-batched quant sweeps (search/cohorts
    .bucket_quant): what changes the stacked array layout / kernel
    configuration, nothing that doesn't.  int8 bits and granularity are
    NOT structural — codes share the int8 container and scales share the
    [nob, kb] layout, so they vary freely within a cohort; the fxp
    triplet and baked LUT are structural (int32 codes, per-format
    table)."""
    if q.mode == "int8":
        return ("int8",)
    return ("fxp", q.fmt.bw, q.fmt.bn, q.fmt.bf, q.act)


def is_quantized(p) -> bool:
    return isinstance(p, dict) and ("wq" in p or "wgq" in p)


def quant_mode(p: Params) -> str:
    return "fxp" if "qfmt" in p else "int8"


# ------------------------------------------------------------ weight codes
def quantize_weights(w, *, bits: int = 8, granularity: str = "block"):
    """w [..., nob, kb, bs, bs] -> (codes int8 same shape, scales f32
    [..., nob, kb]).  Symmetric absmax per weight block; "unit"
    granularity computes one absmax per leading unit and broadcasts it
    into the per-block layout (one kernel contract for both)."""
    w = jnp.asarray(w, jnp.float32)
    qmax = float(2 ** (bits - 1) - 1)
    if granularity == "block":
        absmax = jnp.max(jnp.abs(w), axis=(-2, -1))          # [..., nob, kb]
    else:  # one scale per unit, broadcast to the block layout
        absmax = jnp.max(jnp.abs(w), axis=(-4, -3, -2, -1), keepdims=True)
        absmax = jnp.broadcast_to(absmax[..., 0, 0], w.shape[:-2])
    scale = jnp.where(absmax == 0.0, 1.0, absmax / qmax)
    codes = jnp.clip(jnp.round(w / scale[..., None, None]), -qmax, qmax)
    return codes.astype(jnp.int8), scale.astype(jnp.float32)


def fxp_encode_weights(w, fmt: FxpFormat):
    """fp weights -> int32 bit-triplet codes (value * 2^bf, saturated)."""
    lim = fmt.n_codes // 2
    codes = jnp.round(jnp.asarray(w, jnp.float32) * fmt.scale)
    return jnp.clip(codes, -lim, lim - 1).astype(jnp.int32)


def act_lut(fmt: FxpFormat, act: str = "sigmoid") -> jax.Array:
    """The VMEM activation table: one fp32 entry per two's-complement
    code (index = code & (2^bw - 1)), activation pre-applied and
    re-quantized to the grid like the FPGA's BRAM tables."""
    if act == "sigmoid":
        table = fp.sigmoid_tables(fmt)[0]
    else:
        codes = np.arange(fmt.n_codes)
        vals = np.where(codes >= fmt.n_codes // 2,
                        codes - fmt.n_codes, codes) / fmt.scale
        if act == "none":
            table = vals
        elif act == "relu":
            table = np.clip(vals, 0.0, fmt.max_val)
        else:
            raise ValueError(f"fxp LUT activation {act!r} "
                             f"(one of {FXP_LUT_ACTS})")
    return jnp.asarray(table, jnp.float32)


# -------------------------------------------------------- tree conversion
def _quantize_single(p: Params, q: QuantConfig, x_scale=None) -> Params:
    out = {k: v for k, v in p.items() if k != "w"}
    if q.mode == "int8":
        out["wq"], out["w_scale"] = quantize_weights(
            p["w"], bits=q.bits, granularity=q.granularity)
        if x_scale is not None:
            out["x_scale"] = jnp.asarray(x_scale, jnp.float32)
    else:
        out["wq"] = fxp_encode_weights(p["w"], q.fmt)
        out["qfmt"] = jnp.asarray([q.fmt.bf, q.fmt.bn], jnp.int32)
        out["qlut"] = act_lut(q.fmt, q.act)
        if "b" in p:   # snap the bias to the triplet grid (q_add operand)
            out["b"] = fp.quantize(p["b"], q.fmt)
    return out


def _quantize_moe(p: Params, q: QuantConfig, x_scale_in=None,
                  x_scale_out=None) -> Params:
    if q.mode != "int8":
        raise ValueError(
            "fxp quantization covers plain junctions only — the MoE "
            "expert gate (silu(g) * u) has no single-LUT fixed-point "
            "epilogue; quantize expert FFNs with mode='int8'")
    out = {k: v for k, v in p.items() if k not in ("wg", "wi", "wo")}
    for name in ("wg", "wi", "wo"):
        out[name + "q"], out[name + "_scale"] = quantize_weights(
            p[name], bits=q.bits, granularity=q.granularity)
    if x_scale_in is not None:
        out["x_scale_in"] = jnp.asarray(x_scale_in, jnp.float32)
    if x_scale_out is not None:
        out["x_scale_out"] = jnp.asarray(x_scale_out, jnp.float32)
    return out


def quantize_junction(p: Params, q: QuantConfig, **x_scales) -> Params:
    """Quantize ONE junction dict (single "w"/"idx" or MoE expert
    "wg"/"idx_in") at checkpoint-load time.  Pattern leaves, bias and any
    other metadata ride through; the fp weight leaves are REMOVED.
    Optional calibrated activation scales: ``x_scale=`` (single),
    ``x_scale_in=`` / ``x_scale_out=`` (MoE)."""
    if "idx_in" in p:
        return _quantize_moe(p, q, x_scales.get("x_scale_in"),
                             x_scales.get("x_scale_out"))
    return _quantize_single(p, q, x_scales.get("x_scale"))


def quantize_tree(params, q: QuantConfig):
    """Walk an arbitrary params tree (the serve engine's quantize-at-load
    entry) and quantize every SPARSE junction dict in place; dense
    layers (attention projections, embeddings, junctions whose dims
    didn't tile) stay full-precision — quantization rides the paper
    datapath only."""
    from repro.core import sparse_linear as sl

    def rec(p):
        if isinstance(p, dict):
            if sl.is_junction(p) and ("w" in p or "wg" in p):
                return quantize_junction(p, q)
            return {k: rec(v) for k, v in p.items()}
        if isinstance(p, (list, tuple)):
            return type(p)(rec(v) for v in p)
        return p
    return rec(params)


def calibrate_layer_scales(layers: Sequence[Params], x, *, act: str,
                           engine: str = "jnp") -> list[float]:
    """PTQ calibration (absmax over a calibration batch): run ``x``
    through the fp layer stack, recording each junction's input absmax;
    returns the static per-layer activation scales (absmax / 127) for
    ``x_scale``.  Layer-iterable models only (the MNIST / population
    path); serve models quantize without calibration and use dynamic
    per-row activation scales instead."""
    from repro.core import sparse_linear as sl
    scales = []
    for p in layers:
        ax = float(jnp.max(jnp.abs(x)))
        scales.append(ax / 127.0 if ax > 0.0 else 1.0)
        x = sl.apply(p, x, engine=engine, act=act)
    return scales


# ------------------------------------------------------------- jnp engine
def _slot_scales(xk, x_scale):
    """The activation quantization scale for one gathered fan-in slot —
    the kernel's exact formula: dynamic per-row absmax/127 (shared
    between engines because it never looks across the row tile), or the
    calibrated static per-unit scale."""
    if x_scale is None:
        ax = jnp.max(jnp.abs(xk), axis=-1, keepdims=True)
        return jnp.where(ax == 0.0, 1.0, ax / 127.0)
    return jnp.asarray(x_scale, jnp.float32)


def _int8_apply(x, wq, idx, w_scale, b=None, x_scale=None):
    """Single-junction int8 sim: x [..., nib*bs] -> pre-activation
    [..., nob*bs] in fp32.  Op-for-op the Pallas kernel's arithmetic:
    per-slot activation codes, int32 dot, dequant by (sx * w_scale)."""
    nob, kb, bs, _ = wq.shape
    lead = x.shape[:-1]
    xb = jnp.asarray(x, jnp.float32).reshape(*lead, -1, bs)
    y = None
    for k in range(kb):
        xk = jnp.take(xb, idx[:, k], axis=-2)              # [..., nob, bs]
        sx = _slot_scales(xk, x_scale)
        xq = jnp.clip(jnp.round(xk / sx), -127, 127).astype(jnp.int32)
        prod = jnp.einsum("...ob,obc->...oc", xq,
                          wq[:, k].astype(jnp.int32))      # exact int32
        part = prod.astype(jnp.float32) * (sx * w_scale[:, k][:, None])
        y = part if y is None else y + part
    y = y.reshape(*lead, nob * bs)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y


def _fxp_apply(x, wq, idx, qfmt, lut, b=None):
    """Single-junction fixed-point sim: int32 code accumulation,
    round-half-up shift, saturate, bias q_add, LUT activation — the
    fwd_fxp kernel's exact integer pipeline (bf traced via qfmt; the
    saturate bound is static from the LUT length)."""
    nob, kb, bs, _ = wq.shape
    T = lut.shape[0]
    lim = T // 2
    bf = qfmt[0]
    scale = jnp.exp2(bf.astype(jnp.float32))
    lead = x.shape[:-1]
    xb = jnp.asarray(x, jnp.float32).reshape(*lead, -1, bs)
    acc = None
    for k in range(kb):
        xk = jnp.take(xb, idx[:, k], axis=-2)
        xq = jnp.clip(jnp.round(xk * scale), -lim, lim - 1).astype(jnp.int32)
        prod = jnp.einsum("...ob,obc->...oc", xq, wq[:, k])
        acc = prod if acc is None else acc + prod
    half = jnp.left_shift(jnp.int32(1), bf - 1)
    s = jnp.right_shift(acc + half, bf)
    s = jnp.clip(s, -lim, lim - 1).reshape(*lead, nob * bs)
    if b is not None:
        bcode = jnp.clip(jnp.round(b.astype(jnp.float32) * scale),
                         -lim, lim - 1).astype(jnp.int32)
        s = jnp.clip(s + bcode, -lim, lim - 1)
    return jnp.take(lut, jnp.bitwise_and(s, T - 1), axis=0)


def apply_quant_jnp(params: Params, x, *, act: str = "none"):
    """engine="jnp" forward of a quantized junction dict — 4-D single or
    5-D E-stacked (vmapped over the unit axis, patterns shared).  int8
    applies the runtime ``act`` on the dequantized fp32 pre-activation;
    fxp ignores ``act`` (the LUT baked it at quantize time)."""
    from repro.kernels import block_sparse_matmul as bsm
    wq = params["wq"]
    single = wq.ndim == 4
    fxp_mode = "qfmt" in params

    if fxp_mode:
        def f(wq, b, x):
            return _fxp_apply(x, wq, params["idx"], params["qfmt"],
                              params["qlut"], b)
    else:
        def f(wq, sc, xs, b, x):
            s = _int8_apply(x, wq, params["idx"], sc, b, xs)
            return bsm.act_fwd(s, act)

    b = params.get("b")
    if fxp_mode:
        if single:
            y = f(wq, b, x)
        else:
            y = jax.vmap(f, in_axes=(0, None if b is None else 0, 0))(
                wq, b, x)
    else:
        xs = params.get("x_scale")
        if single:
            y = f(wq, params["w_scale"], xs, b, x)
        else:
            y = jax.vmap(f, in_axes=(0, 0, None if xs is None else 0,
                                     None if b is None else 0, 0))(
                wq, params["w_scale"], xs, b, x)
    return y.astype(x.dtype)


def expert_apply_int8(wq, w_scale, idx, x, x_scale=None):
    """MoE expert-batched int8 sim (the quantized twin of
    models/moe._expert_apply): x [G,E,C,din] -> fp32 pre-activation
    [G,E,C,dout], per-expert scales on the leading E dim."""
    E, nob, kb, bs, _ = wq.shape
    G, _, C, din = x.shape
    xb = jnp.asarray(x, jnp.float32).reshape(G, E, C, din // bs, bs)
    y = None
    for k in range(kb):
        xk = jnp.take(xb, idx[:, k], axis=3)               # [G,E,C,nob,bs]
        if x_scale is None:
            sx = _slot_scales(xk, None)
        else:
            sx = jnp.asarray(x_scale, jnp.float32).reshape(1, E, 1, 1, 1)
        xq = jnp.clip(jnp.round(xk / sx), -127, 127).astype(jnp.int32)
        prod = jnp.einsum("GECob,Eobc->GECoc", xq,
                          wq[:, :, k].astype(jnp.int32))
        part = prod.astype(jnp.float32) * (
            sx * w_scale[:, :, k][None, :, None, :, None])
        y = part if y is None else y + part
    return y.reshape(G, E, C, nob * bs)
