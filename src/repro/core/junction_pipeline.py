"""Junction pipelining: the paper's operational model, quantified.

Ties together the two implementations:
  * ``core.paper_net.train_epoch_pipelined`` — clocked, bit-faithful, L=2.
  * ``parallel.pipeline``                    — mesh-scale generalization
    (shard_map + ppermute; GPipe baseline vs the paper's async schedule).

Plus the paper's resource/throughput model (Secs. III-D-3, III-D-6, III-E):
multiplier/adder counts as functions of the degrees of parallelism z_i, and
the block-cycle throughput model behind Fig. 8 — the reconfiguration
trade-off that is the paper's headline feature.  On TPU the analogous knob
is (tile sizes x model-axis shards); benchmarks/z_sweep.py reports both.
"""
from __future__ import annotations

import dataclasses

from repro.core.paper_net import PaperNetConfig

CLOCK_HZ = 15e6     # the paper's achieved clock (Sec. III-D-6)


@dataclasses.dataclass(frozen=True)
class ResourceModel:
    """Arithmetic-unit counts from Sec. III-D-3."""
    ff_multipliers: int        # sum_i z_i
    bp_multipliers: int        # 2 * sum_{i>=2} z_i
    up_multipliers: int        # sum_i z_i
    up_adders: int             # sum_i (z_i + z_i/d_in_i)
    sigmoid_luts: int          # sum_i z_i / d_in_i
    bp_partial_sums: int       # sum_{i>=2} z_i

    @property
    def total_multipliers(self) -> int:
        return self.ff_multipliers + self.bp_multipliers + self.up_multipliers


def resources(cfg: PaperNetConfig) -> ResourceModel:
    zs = cfg.z
    d_ins = [cfg.d_in(i) for i in range(cfg.n_junctions)]
    return ResourceModel(
        ff_multipliers=sum(zs),
        bp_multipliers=2 * sum(zs[1:]),
        up_multipliers=sum(zs),
        up_adders=sum(z + z // d for z, d in zip(zs, d_ins)),
        sigmoid_luts=sum(z // d for z, d in zip(zs, d_ins)),
        bp_partial_sums=sum(zs[1:]),
    )


def block_cycle_s(cfg: PaperNetConfig, clock_hz: float = CLOCK_HZ) -> float:
    """Seconds per input at ideal throughput (pipeline full): the longest
    junction block cycle (all junctions are tuned equal in Table I)."""
    return max(cfg.block_cycles(i) for i in range(cfg.n_junctions)) / clock_hz


def throughput_inputs_per_s(cfg: PaperNetConfig,
                            clock_hz: float = CLOCK_HZ) -> float:
    return 1.0 / block_cycle_s(cfg, clock_hz)


def speedup_vs_sequential(cfg: PaperNetConfig) -> float:
    """The 3L factor: FF+BP+UP x L junctions run concurrently."""
    return 3.0 * cfg.n_junctions


def z_sweep_configs(base: PaperNetConfig, factors=(0.25, 0.5, 1.0, 2.0, 4.0)):
    """Fig. 8: scale all z_i (keeping z_i <= W_i and z_i >= d_in_i where
    possible), returning (config, total_z, block_cycle_s, resources)."""
    rows = []
    for f in factors:
        zs = []
        ok = True
        for i in range(base.n_junctions):
            z = int(base.z[i] * f)
            z = max(1, min(z, base.weights(i)))
            if base.weights(i) % z:
                ok = False
                break
            zs.append(z)
        if not ok:
            continue
        cfg = dataclasses.replace(base, z=tuple(zs))
        rows.append({
            "factor": f,
            "total_z": sum(zs),
            "block_cycle_s": block_cycle_s(cfg),
            "throughput_per_s": throughput_inputs_per_s(cfg),
            "multipliers": resources(cfg).total_multipliers,
        })
    return rows
