import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (``python -m repro.launch.dryrun ...``) — the
first two lines above force 512 host-platform devices BEFORE jax
initializes.  Tests and benchmarks never import this module.

Per cell:
  * build the production mesh (16,16) or (2,16,16),
  * abstract-init params/optimizer/cache (ShapeDtypeStruct, no allocation),
  * attach NamedShardings from parallel/sharding.py,
  * jit(...).lower(...).compile(),
  * record memory_analysis / cost_analysis / roofline walker output as JSON.

Results land in ``results/dryrun/<cell>.json`` and are skipped when present
(crash-safe sweep; delete a file to redo a cell).
"""

import argparse
import dataclasses
import functools
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import SHAPES, ArchConfig, ShapeSpec, valid_cells
from repro.core.sparsity import SparsityConfig
from repro.launch import specs as specs_mod
from repro.launch.mesh import make_production_mesh
from repro.parallel import hints
from repro.models import model as M
from repro.optim import adam, constant_schedule
from repro.parallel import sharding as sh
from repro.roofline import analysis as roofline
from repro.train.steps import make_decode_step, make_prefill_step, make_train_step

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# sweep order: small archs first so results accumulate fast
SWEEP_ORDER = [
    "whisper-base", "stablelm-3b", "zamba2-2.7b", "deepseek-7b",
    "llava-next-mistral-7b", "falcon-mamba-7b", "deepseek-v2-lite-16b",
    "qwen3-moe-30b-a3b", "qwen2-72b", "command-r-plus-104b",
]


def cell_id(arch: str, shape: str, mesh_kind: str, variant: str) -> str:
    v = "" if variant == "dense" else f"+{variant}"
    return f"{arch}{v}__{shape}__{mesh_kind}"


def _apply_variant(cfg: ArchConfig, variant: str) -> ArchConfig:
    import dataclasses
    if variant == "dense":
        return cfg
    if variant == "sparse":   # the paper's technique on FFN projections
        return cfg.with_sparsity(SparsityConfig(density=0.125, block=128,
                                                where="ffn"))
    if variant == "sparse-all":
        return cfg.with_sparsity(SparsityConfig(density=0.125, block=128,
                                                where="ffn+attn"))
    if variant == "perf":     # beyond-paper knobs (§Perf): bf16-resident
        # params (fp32 masters in adam -> bf16 FSDP gathers) + chunked CE
        # (logits never fully materialize) + bf16 selective-scan elements
        # (ssm_chunk=16 tried and REFUTED — carry r/w per chunk dominates at
        # small chunks, t_m 104 -> 258 s; see EXPERIMENTS.md §Perf F2)
        return dataclasses.replace(cfg, param_dtype="bfloat16",
                                   loss_chunk=2048,
                                   ssm_scan_dtype="bfloat16")
    if variant == "perf-sparse":
        return dataclasses.replace(
            cfg.with_sparsity(SparsityConfig(density=0.125, block=128,
                                             where="ffn")),
            param_dtype="bfloat16", loss_chunk=2048,
            ssm_scan_dtype="bfloat16")
    raise ValueError(variant)


def lower_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, microbatches: int = 1):
    """Returns the lowered computation for one cell."""
    pshapes = jax.eval_shape(functools.partial(M.init, cfg),
                             jax.random.PRNGKey(0))
    pspecs = sh.param_specs(cfg, pshapes, mesh)
    pstruct = sh.attach(pshapes, pspecs, mesh)

    if shape.kind == "train":
        opt = adam(constant_schedule(1e-4),
                   master_copy=(cfg.param_dtype != "float32"))
        oshapes = jax.eval_shape(opt.init, pshapes)
        # opt state mirrors params: reuse param specs where shaped, P() for
        # the scalar placeholders on non-trainable (pattern) leaves
        ospecs = {k: jax.tree.map(
                      lambda t, s: sh.P() if len(t.shape) == 0 else s,
                      oshapes[k], pspecs)
                  for k in oshapes}
        ostruct = sh.attach(oshapes, ospecs, mesh)
        batch = specs_mod.batch_struct(cfg, shape)
        bspecs = sh.batch_specs(cfg, batch, mesh)
        bstruct = sh.attach(batch, bspecs, mesh)
        step = jax.ShapeDtypeStruct((), jnp.int32)
        fn = make_train_step(cfg, opt, microbatches=microbatches, jit=False)
        jitted = jax.jit(fn, donate_argnums=(0, 1),
                         out_shardings=(sh.to_shardings(pspecs, mesh),
                                        sh.to_shardings(ospecs, mesh), None))
        return jitted.lower(pstruct, ostruct, bstruct, step)

    if shape.kind == "prefill":
        batch = specs_mod.batch_struct(cfg, shape)
        bstruct = sh.attach(batch, sh.batch_specs(cfg, batch, mesh), mesh)
        cshapes = jax.eval_shape(
            lambda: M.make_cache(cfg, shape.global_batch, shape.seq_len))
        cspecs = sh.cache_specs(cfg, cshapes, mesh)
        lspec = sh.logits_spec(cfg, shape.global_batch, mesh)
        fn = make_prefill_step(cfg)
        jitted = jax.jit(fn, out_shardings=(
            sh.to_shardings(lspec, mesh), sh.to_shardings(cspecs, mesh)))
        return jitted.lower(pstruct, bstruct)

    # decode
    cshapes = jax.eval_shape(
        lambda: M.make_cache(cfg, shape.global_batch, shape.seq_len))
    cspecs = sh.cache_specs(cfg, cshapes, mesh)
    cstruct = sh.attach(cshapes, cspecs, mesh)
    tok, pos = specs_mod.decode_inputs_struct(cfg, shape)
    tspec = sh.batch_specs(cfg, tok, mesh)
    tstruct = sh.attach(tok, tspec, mesh)
    lspec = sh.logits_spec(cfg, shape.global_batch, mesh)
    fn = make_decode_step(cfg)
    jitted = jax.jit(fn, donate_argnums=(1,), out_shardings=(
        sh.to_shardings(lspec, mesh), sh.to_shardings(cspecs, mesh)))
    return jitted.lower(pstruct, cstruct, tstruct, pos)


def run_cell(arch: str, shape_name: str, mesh_kind: str, variant: str,
             out_dir: Path, force: bool = False) -> dict:
    cid = cell_id(arch, shape_name, mesh_kind, variant)
    out_path = out_dir / f"{cid}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    # force the jnp gather+einsum path: the dry-run exists for FLOP/bytes
    # accounting, which must see the density-scaled einsums, not opaque
    # pallas_call ops the roofline walker can't cost
    cfg = dataclasses.replace(_apply_variant(registry.get(arch), variant),
                              engine="jnp")
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    rec: dict = {"cell": cid, "arch": arch, "shape": shape_name,
                 "mesh": mesh_kind, "variant": variant,
                 "n_chips": int(n_chips), "params": cfg.param_count(),
                 "active_params": cfg.active_param_count()}
    t0 = time.time()
    try:
        # training cells auto-scale microbatches (gradient accumulation)
        # until the per-device footprint fits a v5e's 16 GiB
        mb_plan = [1, 2, 4, 8] if shape.kind == "train" else [1]
        attempts = []
        for mb in mb_plan:
            if mb > 1 and shape.global_batch % mb:
                continue
            t0 = time.time()
            with mesh, hints.use_mesh_hints(mesh):
                lowered = lower_cell(cfg, shape, mesh, microbatches=mb)
                rec["lower_s"] = round(time.time() - t0, 1)
                t1 = time.time()
                compiled = lowered.compile()
                rec["compile_s"] = round(time.time() - t1, 1)
            rl = roofline.analyze_compiled(compiled)
            mem = rl.memory_stats
            per_dev_gb = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
                          + mem.get("output_bytes", 0)
                          - mem.get("alias_bytes", 0)) / 2**30
            # corrected: minus the XLA-CPU f32 loop-widening artifact
            # (roofline/analysis.py::widened_f32_loop_state)
            corr_gb = per_dev_gb - rl.spurious_f32_bytes / 2**30
            attempts.append({"microbatches": mb,
                             "per_device_gb": round(per_dev_gb, 3),
                             "corrected_gb": round(corr_gb, 3)})
            rec["microbatches"] = mb
            if corr_gb < 16.0 or mb == mb_plan[-1]:
                break
        rec["fit_attempts"] = attempts
        rec["roofline"] = rl.to_json()
        rec["model_flops"] = roofline.model_flops(cfg, shape)
        rec["useful_fraction"] = roofline.useful_fraction(
            cfg, shape, rl.dot_flops, n_chips)
        rec["per_device_gb"] = round(per_dev_gb, 3)
        rec["per_device_gb_corrected"] = round(corr_gb, 3)
        rec["fits_16gb"] = corr_gb < 16.0
        rec["ok"] = True
        print(f"[dryrun] {cid}: ok lower={rec['lower_s']}s "
              f"compile={rec['compile_s']}s perdev={per_dev_gb:.2f}GiB "
              f"mb={rec.get('microbatches',1)} "
              f"dom={rec['roofline']['dominant']}", flush=True)
    except Exception as e:  # record failure — these are bugs to fix
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {cid}: FAIL {rec['error'][:200]}", flush=True)
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1, default=float))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="dense",
                    choices=["dense", "sparse", "sparse-all", "perf",
                             "perf-sparse"])
    ap.add_argument("--out", default=str(RESULTS))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out_dir = Path(args.out)

    archs = [args.arch] if args.arch else SWEEP_ORDER
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    n_ok = n_fail = 0
    for arch in archs:
        cfg = registry.get(arch)
        cells = ([SHAPES[args.shape]] if args.shape
                 else list(valid_cells(cfg)))
        for shape in cells:
            for mk in meshes:
                rec = run_cell(arch, shape.name, mk, args.variant, out_dir,
                               force=args.force)
                n_ok += rec.get("ok", False)
                n_fail += not rec.get("ok", False)
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
