"""Batched serving driver.

Static batch (one prefill, lockstep decode):

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b --reduce \
        --requests 8 --prompt-len 32 --max-new 16

Continuous batching (paged KV cache, admission loop, chunked prefill):

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b --reduce \
        --continuous --slots 4 --page-size 16 --prefill-chunk 32 \
        --requests 12 --prompt-len 32 --max-new 16 --arrival-every 2
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt", default=None,
                    help="restore params from a training checkpoint dir")
    ap.add_argument("--sparse", action="store_true",
                    help="apply the paper's pre-defined FFN sparsity")
    ap.add_argument("--density", type=float, default=0.25)
    ap.add_argument("--quantize", default=None, choices=["int8"],
                    help="quantize sparse junction weights at load "
                         "(int8 codes + per-block scales)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching engine over the paged KV "
                         "cache (admission loop + chunked prefill)")
    ap.add_argument("--slots", type=int, default=4,
                    help="[continuous] decode batch width")
    ap.add_argument("--page-size", type=int, default=16,
                    help="[continuous] tokens per KV page")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="[continuous] KV pool budget (0: full residency)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="[continuous] prefill chunk width")
    ap.add_argument("--arrival-every", type=int, default=0,
                    help="[continuous] synthetic trace: one request every "
                         "N scheduler ticks (0: all arrive at tick 0)")
    ap.add_argument("--obs", default=None, metavar="PATH",
                    help="flight-recorder JSONL sink: per-request spans + "
                         "TTFT/ITL histograms + occupancy gauges "
                         "(continuous engine); render with "
                         "repro.launch.obs_report")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="wrap the run in a jax.profiler trace written to "
                         "DIR (kernels show up named by KernelSpec)")
    args = ap.parse_args()

    import numpy as np
    import jax

    from repro.configs import registry
    from repro.core.sparsity import SparsityConfig
    from repro.models import model as M
    from repro.obs import Recorder, percentile, profile_ctx
    from repro.serve.engine import (ContinuousEngine, Engine, Request,
                                    ServeConfig)
    from repro.train import checkpoint as ckpt_mod

    cfg = registry.get(args.arch)
    if args.reduce:
        cfg = cfg.reduced()
    if args.sparse:
        block = 32 if args.reduce else 128
        cfg = cfg.with_sparsity(SparsityConfig(
            density=args.density, block=block, where="ffn"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    if args.ckpt:
        opt_like = None
        step, tree, _ = ckpt_mod.restore_latest(
            args.ckpt, {"params": params, "opt": opt_like})
        if tree is not None:
            params = tree["params"]
            print(f"[serve] restored params from step {step}")

    rng = np.random.default_rng(0)
    V = cfg.raw_vocab or cfg.vocab
    prompts = rng.integers(0, V, size=(args.requests, args.prompt_len)).astype(np.int32)
    extra = {}
    if cfg.family == "vlm":
        extra["patches"] = rng.standard_normal(
            (args.requests, min(cfg.num_patches, args.prompt_len // 2),
             cfg.d_model)).astype(np.float32)
    if cfg.family == "audio":
        extra["frames"] = rng.standard_normal(
            (args.requests, cfg.enc_frames, cfg.d_model)).astype(np.float32)

    quant = args.quantize if (args.quantize and cfg.sparsity) else None
    why = ("int8 junction kernels (per-block scales)" if quant
           else "no sparse junctions to quantize" if args.quantize
           else "full precision")
    print(f"[serve] quantize={args.quantize or 'off'} datapath: {why}")
    import time

    if args.continuous:
        ok, reason = M.paged_supported(cfg)
        if not ok:
            raise SystemExit(f"[serve] --continuous unsupported: {reason}")
        if extra:
            raise SystemExit("[serve] --continuous does not take encoder "
                             "side inputs (vlm/audio)")
        scfg = ServeConfig(
            max_new_tokens=args.max_new, temperature=args.temperature,
            quantize=quant, slots=args.slots, page_size=args.page_size,
            num_pages=args.num_pages, prefill_chunk=args.prefill_chunk,
            max_seq=min(cfg.max_seq, args.prompt_len + args.max_new))
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=args.max_new,
                        arrival=i * args.arrival_every)
                for i in range(args.requests)]
        recorder = (Recorder(args.obs, meta={"launcher": "serve",
                                             "arch": args.arch})
                    if args.obs else None)
        eng = ContinuousEngine(cfg, params, scfg, recorder=recorder)
        t0 = time.perf_counter()
        try:
            with profile_ctx(args.profile):
                outs = eng.serve(reqs)
        finally:
            if recorder is not None:
                recorder.close()
                print(f"[serve] telemetry -> {args.obs} "
                      f"({recorder.n_events} events)")
        dt = time.perf_counter() - t0
        st = eng.stats
        n_tok = sum(len(v) for v in outs.values())
        waits = [v["wall_s"] for v in st["latency"].values()]
        print(f"[serve] continuous: {len(outs)}/{args.requests} requests, "
              f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s)")
        print(f"[serve] decode_ticks={st['decode_ticks']} "
              f"prefill_chunks={st['prefill_chunks']} "
              f"peak_pages={st['peak_pages']}/{st['num_pages']} "
              f"traces={st['decode_traces']}/{st['prefill_traces']} "
              f"p50_lat={percentile(waits, 50) * 1e3:.1f}ms "
              f"p99_lat={percentile(waits, 99) * 1e3:.1f}ms")
        print("[serve] first sequence:", outs[0][:16].tolist())
        return outs

    eng = Engine(cfg, params, ServeConfig(max_new_tokens=args.max_new,
                                          temperature=args.temperature,
                                          quantize=quant))
    t0 = time.perf_counter()
    out = eng.generate(prompts, extra)
    dt = time.perf_counter() - t0
    tps = args.requests * args.max_new / dt
    print(f"[serve] generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    print("[serve] first sequence:", out[0][:16].tolist())
    return out


if __name__ == "__main__":
    main()
