"""Batched serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b --reduce \
        --requests 8 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt", default=None,
                    help="restore params from a training checkpoint dir")
    ap.add_argument("--sparse", action="store_true",
                    help="apply the paper's pre-defined FFN sparsity")
    ap.add_argument("--density", type=float, default=0.25)
    ap.add_argument("--quantize", default=None, choices=["int8"],
                    help="quantize sparse junction weights at load "
                         "(int8 codes + per-block scales)")
    args = ap.parse_args()

    import numpy as np
    import jax

    from repro.configs import registry
    from repro.core.sparsity import SparsityConfig
    from repro.models import model as M
    from repro.serve.engine import Engine, ServeConfig
    from repro.train import checkpoint as ckpt_mod

    cfg = registry.get(args.arch)
    if args.reduce:
        cfg = cfg.reduced()
    if args.sparse:
        block = 32 if args.reduce else 128
        cfg = cfg.with_sparsity(SparsityConfig(
            density=args.density, block=block, where="ffn"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    if args.ckpt:
        opt_like = None
        step, tree, _ = ckpt_mod.restore_latest(
            args.ckpt, {"params": params, "opt": opt_like})
        if tree is not None:
            params = tree["params"]
            print(f"[serve] restored params from step {step}")

    rng = np.random.default_rng(0)
    V = cfg.raw_vocab or cfg.vocab
    prompts = rng.integers(0, V, size=(args.requests, args.prompt_len)).astype(np.int32)
    extra = {}
    if cfg.family == "vlm":
        extra["patches"] = rng.standard_normal(
            (args.requests, min(cfg.num_patches, args.prompt_len // 2),
             cfg.d_model)).astype(np.float32)
    if cfg.family == "audio":
        extra["frames"] = rng.standard_normal(
            (args.requests, cfg.enc_frames, cfg.d_model)).astype(np.float32)

    quant = args.quantize if (args.quantize and cfg.sparsity) else None
    why = ("int8 junction kernels (per-block scales)" if quant
           else "no sparse junctions to quantize" if args.quantize
           else "full precision")
    print(f"[serve] quantize={args.quantize or 'off'} datapath: {why}")
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=args.max_new,
                                          temperature=args.temperature,
                                          quantize=quant))
    import time
    t0 = time.perf_counter()
    out = eng.generate(prompts, extra)
    dt = time.perf_counter() - t0
    tps = args.requests * args.max_new / dt
    print(f"[serve] generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    print("[serve] first sequence:", out[0][:16].tolist())
    return out


if __name__ == "__main__":
    main()
