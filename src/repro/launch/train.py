"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b --reduce \
        --steps 200 --batch 8 --seq 256 --sparse --ckpt /tmp/run1

Assembles config -> params -> sharded jit train_step -> restartable data
pipeline -> fault-tolerant loop.  ``--devices N`` forces N host devices for
local multi-device runs (must be first — device count locks at jax init,
which is why this flag is parsed before importing jax).
"""
from __future__ import annotations

import argparse
import os
import sys


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--reduce", action="store_true",
                    help="use the reduced (smoke-size) config")
    ap.add_argument("--width", type=int, default=0,
                    help="override d_model (custom scale, e.g. ~100M runs)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optim", choices=("sgd", "adam"), default="adam",
                    help="fused-capable optimizer: fused_sgd(momentum=0.9) "
                         "or fused_adam (two-pass adam reference when the "
                         "config is ineligible)")
    ap.add_argument("--sparse", action="store_true",
                    help="enable the paper's pre-defined sparsity on FFNs")
    ap.add_argument("--density", type=float, default=0.25)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--data", type=int, default=1, help="data-parallel size")
    ap.add_argument("--model", type=int, default=1, help="model-parallel size")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a crash at this step (restart test)")
    ap.add_argument("--obs", default=None, metavar="PATH",
                    help="flight-recorder JSONL sink (obs/telemetry.py): "
                         "per-step records + guardian/checkpoint events; "
                         "render with repro.launch.obs_report")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="wrap the run in a jax.profiler trace written to "
                         "DIR (kernels show up named by KernelSpec)")
    return ap.parse_args()


def main():
    args = _parse()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import registry
    from repro.core.sparsity import SparsityConfig
    from repro.data.pipeline import LMTokenPipeline
    from repro.launch.mesh import make_local_mesh
    from repro.models import model as M
    from repro.obs import Recorder, profile_ctx
    from repro.optim import cosine_schedule, fused_adam, fused_sgd
    from repro.parallel import hints
    from repro.parallel import sharding as sh
    from repro.train import grad_compress
    from repro.train.steps import fused_update_eligible, make_train_step
    from repro.train.train_loop import TrainLoopConfig, run

    cfg = registry.get(args.arch)
    if args.reduce:
        cfg = cfg.reduced()
    if args.width:
        cfg = dataclasses.replace(cfg, d_model=args.width,
                                  d_ff=args.width * 3,
                                  head_dim=args.width // max(1, cfg.n_heads))
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    if args.sparse:
        block = 32 if args.reduce else 128
        cfg = cfg.with_sparsity(SparsityConfig(density=args.density,
                                               block=block, where="ffn"))

    sched = cosine_schedule(args.lr, warmup=20, total=args.steps)
    if args.optim == "sgd":
        opt = fused_sgd(sched, momentum=0.9)
    else:
        opt = fused_adam(sched, grad_clip=1.0)
    if args.compress_grads:
        opt = grad_compress.compressed(opt)

    # resolved ONCE at step build — say which path we're on (and why not,
    # when the fused BP+UP refuses) so runs are attributable
    ok, why = fused_update_eligible(cfg, opt, args.microbatches)
    print(f"[train] optim={args.optim} update path: "
          f"{'fused BP+UP' if ok else f'two-pass ({why})'}")
    # quantization is inference-only (core/quantize.py): training always
    # runs full-precision weights — state the datapath like the fused log
    print("[train] quantize=off datapath: full precision "
          "(int8/fxp junctions are inference-only — see launch/serve.py)")

    params = M.init(cfg, jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    # raw fn: the mesh/sharding branch below attaches its own jit+donation
    step_fn = make_train_step(cfg, opt, microbatches=args.microbatches,
                              jit=False)

    n_dev = args.data * args.model
    if n_dev > 1:
        mesh = make_local_mesh(args.data, args.model)
        pspecs = sh.param_specs(cfg, params, mesh)
        psh = sh.to_shardings(pspecs, mesh)
        params = jax.tree.map(jax.device_put, params, psh)
        with mesh, hints.use_mesh_hints(mesh):
            train_step = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        train_step = jax.jit(step_fn, donate_argnums=(0, 1))

    pipeline = LMTokenPipeline(cfg, args.batch, args.seq)
    loop_cfg = TrainLoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                               ckpt_every=args.ckpt_every,
                               fail_at_step=args.fail_at)
    recorder = (Recorder(args.obs, meta={"launcher": "train",
                                         "arch": args.arch})
                if args.obs else None)
    try:
        with profile_ctx(args.profile):
            result = run(loop_cfg, train_step, params, opt_state, pipeline,
                         recorder=recorder)
    finally:
        if recorder is not None:
            recorder.close()
            print(f"[train] telemetry -> {args.obs} "
                  f"({recorder.n_events} events)")
    print(f"[train] finished at step {result['step']}; "
          f"stragglers={result['straggler_count']}")
    if result["history"]:
        print(f"[train] first loss {result['history'][0]['loss']:.4f} "
              f"-> last {result['history'][-1]['loss']:.4f}")
    return result


if __name__ == "__main__":
    main()
