"""Render flight-recorder JSONL runs into a human summary.

    PYTHONPATH=src python -m repro.launch.obs_report \
        OBS_train.jsonl OBS_serve.jsonl --check-spans --json OBS_report.json

Takes one or more ``--obs`` sink files (obs/telemetry.py) — a train
run, a serve trace, a sweep, or any mix — and prints the merged
timeline as four sections: train throughput curve, guardian/checkpoint
event log, per-request serve latency table (p50/p99 via the shared
nearest-rank ``obs.percentile``), and the sweep round table.

``--check-spans`` additionally validates every ``serve.span`` event's
lifecycle (enqueue ≤ admit ≤ first token ≤ finish, tokens produced,
guard-terminated requests allowed a missing first token) and exits
non-zero on any violation — the CI obs smoke gate.

``--json OUT`` writes the machine-readable report stamped with the
``repro.artifacts.artifact_meta`` schema, same as BENCH_*.json and
SWEEP_*.json: every results artifact this repo emits carries the one
meta block.
"""
from __future__ import annotations

import argparse
import json
import sys


def _downsample(xs: list, n: int) -> list:
    """At most n entries, evenly spaced, always keeping first and last."""
    if len(xs) <= n:
        return xs
    idx = [round(i * (len(xs) - 1) / (n - 1)) for i in range(n)]
    return [xs[i] for i in dict.fromkeys(idx)]


def check_span(ev: dict) -> str | None:
    """One serve.span lifecycle violation (str) or None when valid."""
    rid = ev.get("rid")
    if ev.get("outcome") not in ("eos", "max_new", "guard"):
        return f"span rid={rid}: unknown outcome {ev.get('outcome')!r}"
    if not ev.get("enqueue_tick", 0) <= ev.get("admit_tick", -1):
        return (f"span rid={rid}: admitted (tick {ev.get('admit_tick')}) "
                f"before enqueue (tick {ev.get('enqueue_tick')})")
    if ev.get("admit_tick", 0) > ev.get("finish_tick", -1):
        return (f"span rid={rid}: finished (tick {ev.get('finish_tick')}) "
                f"before admit (tick {ev.get('admit_tick')})")
    ft = ev.get("first_token_tick", -1)
    if ft >= 0:
        if not ev.get("admit_tick", 0) <= ft <= ev.get("finish_tick", 0):
            return (f"span rid={rid}: first token (tick {ft}) outside "
                    f"[admit, finish]")
        if ev.get("ttft_s", -1.0) < 0:
            return f"span rid={rid}: first token at tick {ft} but no ttft"
    elif ev.get("outcome") != "guard":
        return (f"span rid={rid}: no first token on a "
                f"{ev.get('outcome')}-finished request")
    if ev.get("n_tokens", 0) <= 0:
        return f"span rid={rid}: finished with no output tokens"
    if ev.get("prefill_chunks", 0) <= 0:
        return f"span rid={rid}: finished without prefilling"
    return None


def build_report(events: list[dict]) -> dict:
    """The merged report dict from a (possibly multi-file) event list."""
    from repro.obs import percentile

    by_kind: dict[str, list[dict]] = {}
    for ev in events:
        by_kind.setdefault(ev.get("kind", "?"), []).append(ev)

    report: dict = {"n_events": len(events)}

    steps = by_kind.get("train.step", [])
    if steps:
        dts = [e["dt_s"] for e in steps if e.get("dt_s", 0) > 0]
        report["train"] = {
            "steps": len(steps),
            "first_loss": steps[0]["loss"], "last_loss": steps[-1]["loss"],
            "dt_p50_s": percentile(dts, 50) if dts else None,
            "dt_p99_s": percentile(dts, 99) if dts else None,
            "tokens_per_s_last_ema": (steps[-1]["tokens_per_s"]
                                      if steps else None),
            "curve": [{"step": e["step"], "loss": e["loss"],
                       "tokens_per_s": e["tokens_per_s"],
                       "dt_ema_s": e["dt_ema_s"]}
                      for e in _downsample(steps, 20)],
        }

    glog = by_kind.get("guardian", []) + by_kind.get("checkpoint", [])
    if glog:
        glog.sort(key=lambda e: e.get("seq", 0))
        report["guardian"] = [
            {"kind": e["kind"], "action": e["action"], "step": e["step"],
             "detail": e.get("detail", {})} for e in glog]

    spans = by_kind.get("serve.span", [])
    if spans:
        walls = [e["wall_s"] for e in spans]
        ttfts = [e["ttft_s"] for e in spans if e.get("ttft_s", -1) >= 0]
        outcomes: dict[str, int] = {}
        for e in spans:
            outcomes[e["outcome"]] = outcomes.get(e["outcome"], 0) + 1
        report["serve"] = {
            "requests": len(spans), "outcomes": outcomes,
            "wall_p50_s": percentile(walls, 50),
            "wall_p99_s": percentile(walls, 99),
            "ttft_p50_s": percentile(ttfts, 50) if ttfts else None,
            "ttft_p99_s": percentile(ttfts, 99) if ttfts else None,
            "spans": sorted(spans, key=lambda e: e["rid"]),
        }

    rounds = by_kind.get("sweep.round", [])
    if rounds:
        tbl = []
        for e in sorted(rounds, key=lambda e: (e["round"],
                                               e.get("seq", 0))):
            row = {"round": e["round"], "action": e["action"]}
            if e.get("member", -1) >= 0:
                row.update(member=e["member"], cohort=e["cohort"],
                           slot=e["slot"])
            if e.get("action") == "rank":
                row["live"] = e.get("detail", {}).get("live")
            tbl.append(row)
        report["sweep"] = tbl

    summaries = by_kind.get("summary", [])
    if summaries:
        report["recorder_summary"] = summaries[-1]
    return report


def _print_report(report: dict, log=print) -> None:
    tr = report.get("train")
    if tr:
        log(f"[obs] train: {tr['steps']} steps, loss "
            f"{tr['first_loss']:.4f} -> {tr['last_loss']:.4f}, "
            f"step p50 {tr['dt_p50_s']*1e3:.1f}ms "
            f"p99 {tr['dt_p99_s']*1e3:.1f}ms")
        for p in tr["curve"]:
            log(f"[obs]   step {p['step']:>6} loss {p['loss']:.4f} "
                f"{p['tokens_per_s']:.0f} tok/s "
                f"(ema {p['dt_ema_s']*1e3:.1f}ms)")
    for e in report.get("guardian", []):
        log(f"[obs] {e['kind']:>10} {e['action']:<9} step {e['step']:>6} "
            f"{e['detail']}")
    sv = report.get("serve")
    if sv:
        t50 = (f"{sv['ttft_p50_s']*1e3:.1f}" if sv["ttft_p50_s"] is not None
               else "-")
        t99 = (f"{sv['ttft_p99_s']*1e3:.1f}" if sv["ttft_p99_s"] is not None
               else "-")
        log(f"[obs] serve: {sv['requests']} requests {sv['outcomes']}, "
            f"wall p50 {sv['wall_p50_s']*1e3:.1f}ms "
            f"p99 {sv['wall_p99_s']*1e3:.1f}ms, "
            f"ttft p50 {t50}ms p99 {t99}ms")
        for s in sv["spans"]:
            log(f"[obs]   rid {s['rid']:>4} {s['outcome']:<8} "
                f"enq {s['enqueue_tick']:>4} adm {s['admit_tick']:>4} "
                f"tok1 {s['first_token_tick']:>4} "
                f"fin {s['finish_tick']:>4} "
                f"chunks {s['prefill_chunks']} n {s['n_tokens']} "
                f"wall {s['wall_s']*1e3:.1f}ms")
    for r in report.get("sweep", []):
        who = (f" member {r['member']} (cohort {r['cohort']} "
               f"slot {r['slot']})" if "member" in r else
               f" live={r.get('live')}")
        log(f"[obs] sweep round {r['round']}: {r['action']}{who}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="+",
                    help="flight-recorder JSONL sink file(s)")
    ap.add_argument("--check-spans", action="store_true",
                    help="validate every serve.span lifecycle; exit 1 on "
                         "any violation")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write the artifact_meta-stamped report JSON")
    ap.add_argument("--tag", default="obs",
                    help="artifact meta tag for --json")
    args = ap.parse_args(argv)

    from repro.obs import read_events

    events: list[dict] = []
    for p in args.paths:
        meta, evs = read_events(p)
        print(f"[obs] {p}: {len(evs)} events "
              f"(meta: {meta.get('launcher', '?')})")
        events.extend(evs)

    report = build_report(events)
    _print_report(report)

    rc = 0
    if args.check_spans:
        spans = [e for e in events if e.get("kind") == "serve.span"]
        bad = [v for v in (check_span(e) for e in spans) if v]
        for v in bad:
            print(f"[obs] SPAN VIOLATION: {v}", file=sys.stderr)
        if not spans:
            print("[obs] SPAN VIOLATION: --check-spans with no serve.span "
                  "events", file=sys.stderr)
            rc = 1
        elif bad:
            rc = 1
        else:
            print(f"[obs] spans OK: {len(spans)}/{len(spans)} requests "
                  "reconstruct a full lifecycle")

    if args.json:
        from repro.artifacts import artifact_meta
        with open(args.json, "w") as f:
            json.dump({"meta": artifact_meta(args.tag), "report": report},
                      f, indent=1)
        print(f"[obs] report -> {args.json}")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
