"""PTQ quality-vs-speed sweep riding the population engine.

    PYTHONPATH=src python -m repro.launch.quant_sweep \
        --bits 8,6,4 --granularities block,unit --steps 30 --out quant.json

Trains ONE fp32 paper MLP briefly on MNIST (the population machinery's
E=1 case), calibrates activation scales on a calibration batch (absmax /
127), then sweeps quantization configs as POPULATIONS: every config in a
cohort (search/cohorts.bucket_quant — int8 bit widths and scale
granularities share array layouts) becomes one member of a stacked
quantized population, evaluated E-at-once through the same
``make_population_eval`` the hyperparameter sweep uses.  ``--fxp`` adds
the paper's full fixed-point triplets (Table II) as their own cohorts
(the int32 codes + per-format LUT are structural).

The JSON ledger records per-config eval loss and timed eval latency and
names the WINNER — the lowest finite-loss config — which ci.sh's
quantized smoke stage asserts exists.
"""
from __future__ import annotations

import argparse
import json
import time


def _floats(s):
    return tuple(float(v) for v in s.split(",") if v)


def _ints(s):
    return tuple(int(v) for v in s.split(",") if v)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", default="8,6,4", help="int8-container code "
                    "widths to sweep (comma-separated, 2..8)")
    ap.add_argument("--granularities", default="block,unit")
    ap.add_argument("--fxp", action="store_true",
                    help="also sweep the paper's fixed-point triplets")
    ap.add_argument("--calibrate", action="store_true",
                    help="static per-unit activation scales from a "
                         "calibration batch (default: dynamic per-row)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--lr", type=float, default=0.5)
    ap.add_argument("--density", type=float, default=0.25)
    ap.add_argument("--hidden", type=int, default=512)
    ap.add_argument("--block", type=int, default=128)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--samples", type=int, default=2048)
    ap.add_argument("--eval-samples", type=int, default=512)
    ap.add_argument("--calib-samples", type=int, default=256)
    ap.add_argument("--engine", default="auto")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tag", default="quant")
    ap.add_argument("--out", default=None, help="JSON ledger path")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import quantize as qz
    from repro.core import sparse_linear as sl
    from repro.core.fixed_point import PAPER_TRIPLETS
    from repro.data.mnist import paper_dataset
    from repro.search import (CandidateSpec, bucket_quant, hyp_table,
                              init_population, init_slots,
                              make_population_eval, make_population_step)

    engine = sl.resolve_engine(args.engine)
    act = "sigmoid"
    out_w = -(-32 // args.block) * args.block
    layers = (1024, args.hidden, out_w)

    # ---------------------------------------------- 1. brief fp training
    spec = CandidateSpec(lr=args.lr, momentum=0.9, density=args.density,
                         layers=layers, block=args.block, act=act,
                         seed=args.seed)
    pop = init_population(jax.random.PRNGKey(args.seed), [spec])
    slots = init_slots(pop, [spec])
    hyp = hyp_table([spec])
    mask = jnp.ones((1,), jnp.float32)
    n = args.samples + args.eval_samples + args.calib_samples
    x, t, _ = paper_dataset(n=n, seed=args.seed)
    if t.shape[1] < out_w:   # zero-pad the one-hot to the output width
        t = np.concatenate(
            [t, np.zeros((t.shape[0], out_w - t.shape[1]), t.dtype)], axis=1)
    xtr, ttr = x[:args.samples], t[:args.samples]
    xev, tev = (x[args.samples:args.samples + args.eval_samples],
                t[args.samples:args.samples + args.eval_samples])
    xcal = x[args.samples + args.eval_samples:]
    step = make_population_step(act, engine=engine, fused=True)
    rng = np.random.default_rng(args.seed)
    print(f"[quant-sweep] fp pre-train: {args.steps} steps, "
          f"layers={layers}, engine={engine}")
    for _ in range(args.steps):
        sel = rng.integers(0, args.samples, size=args.batch)
        pop, slots, _ = step(pop, slots, hyp, mask, xtr[sel], ttr[sel])
    fp_layers = [jax.tree.map(lambda v: v, layer) for layer in pop]
    fp_layers = [{k: (v[0] if k in ("w", "b") else v)
                  for k, v in layer.items()} for layer in fp_layers]

    evaluate = make_population_eval(act, engine=engine)
    fp_loss = float(evaluate(pop, xev, tev)[0])
    print(f"[quant-sweep] fp32 eval loss {fp_loss:.5f}")

    # ---------------------------------------------------- 2. calibration
    x_scales = (qz.calibrate_layer_scales(fp_layers, xcal, act=act)
                if args.calibrate else None)
    if x_scales is not None:
        print(f"[quant-sweep] calibrated x scales: "
              f"{[round(s, 5) for s in x_scales]}")

    # ------------------------------------------------ 3. the config grid
    configs = [qz.QuantConfig(mode="int8", bits=b, granularity=g)
               for b in _ints(args.bits)
               for g in args.granularities.split(",")]
    if args.fxp:
        configs += [qz.QuantConfig(mode="fxp", fmt=f, act=act)
                    for f in PAPER_TRIPLETS]
    cohorts = bucket_quant(configs)
    print(f"[quant-sweep] {len(configs)} configs in {len(cohorts)} "
          f"cohort(s); datapath: quantized junction kernels "
          f"({'static' if args.calibrate else 'dynamic'} activation "
          f"scales)")

    def quantize_member(q):
        out = []
        for li, layer in enumerate(fp_layers):
            xs = None
            if q.mode == "int8" and x_scales is not None:
                xs = x_scales[li]
            out.append(qz.quantize_junction(layer, q, x_scale=xs))
        return out

    def stack_members(members):
        """E per-config quantized layer lists -> one stacked population
        (codes/scales/bias per member, patterns + fxp format shared)."""
        popq = []
        for li in range(len(members[0])):
            base = members[0][li]
            layer = {k: base[k] for k in sl.PATTERN_LEAVES}
            for k in ("qfmt", "qlut"):       # structural: cohort-shared
                if k in base:
                    layer[k] = base[k]
            for k in ("wq", "w_scale", "b", "x_scale"):
                if k in base:
                    layer[k] = jnp.stack([m[li][k] for m in members])
            popq.append(layer)
        return popq

    # ------------------------------------- 4. E-at-once eval per cohort
    records = []
    for co in cohorts:
        popq = stack_members([quantize_member(q) for q in co.configs])
        losses = evaluate(popq, xev, tev)
        jax.block_until_ready(losses)
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(evaluate(popq, xev, tev))
        us = (time.perf_counter() - t0) / 3 * 1e6 / co.size
        for slot, (q, cid) in enumerate(zip(co.configs, co.member_ids)):
            loss = float(losses[slot])
            records.append({"id": cid, "config": q.to_dict(),
                            "cohort": list(map(str, co.key)),
                            "eval_loss": loss,
                            "us_per_member_eval": us,
                            "delta_vs_fp32": loss - fp_loss})
            print(f"[quant-sweep] {q.to_dict()} loss={loss:.5f} "
                  f"({loss - fp_loss:+.5f} vs fp) {us:.0f}us/member")

    finite = [r for r in records if np.isfinite(r["eval_loss"])]
    winner = min(finite, key=lambda r: r["eval_loss"]) if finite else None
    if winner is not None:
        print(f"[quant-sweep] winner: {winner['config']} "
              f"loss={winner['eval_loss']:.5f}")
    else:
        print("[quant-sweep] winner: none (no finite member)")

    ledger = {"tag": args.tag, "engine": engine, "layers": list(layers),
              "fp32_eval_loss": fp_loss, "calibrated": args.calibrate,
              "records": records, "winner": winner}
    if args.out:
        with open(args.out, "w") as f:
            json.dump(ledger, f, indent=1)
        print(f"[quant-sweep] ledger -> {args.out}")
    return ledger


if __name__ == "__main__":
    main()
