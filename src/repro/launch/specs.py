"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns the batch pytree for train/prefill; the
modality frontends are stubs per the assignment: whisper gets precomputed
frame embeddings, llava gets patch embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec

S = jax.ShapeDtypeStruct


def batch_struct(cfg: ArchConfig, shape: ShapeSpec):
    """Abstract train/prefill batch."""
    B, L = shape.global_batch, shape.seq_len
    batch = {}
    if cfg.family == "vlm":
        npatch = min(cfg.num_patches, L // 2)
        batch["patches"] = S((B, npatch, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = S((B, L - npatch), jnp.int32)
    else:
        batch["tokens"] = S((B, L), jnp.int32)
    if cfg.family == "audio":
        batch["frames"] = S((B, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
    return batch


def decode_inputs_struct(cfg: ArchConfig, shape: ShapeSpec):
    B = shape.global_batch
    return S((B, 1), jnp.int32), S((), jnp.int32)   # token, pos


def concrete_batch(cfg: ArchConfig, batch_size: int, seq_len: int, key):
    """Small concrete batch (smoke tests / examples)."""
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.family == "vlm":
        npatch = min(cfg.num_patches, seq_len // 2)
        batch["patches"] = jax.random.normal(ks[1], (batch_size, npatch, cfg.d_model),
                                             jnp.float32)
        batch["tokens"] = jax.random.randint(ks[0], (batch_size, seq_len - npatch),
                                             0, cfg.raw_vocab or cfg.vocab)
    else:
        batch["tokens"] = jax.random.randint(ks[0], (batch_size, seq_len),
                                             0, cfg.raw_vocab or cfg.vocab)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(ks[2], (batch_size, cfg.enc_frames,
                                                    cfg.d_model), jnp.float32)
    return batch
