"""Production meshes.

A function, not a module-level constant — importing this module never
touches jax device state.  The dry-run process forces 512 host-platform
devices (launch/dryrun.py sets XLA_FLAGS before any jax import); everything
else (tests, benches) sees the real single CPU device and uses small meshes.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

try:  # jax >= 0.5 wants explicit Auto axis types; 0.4.x has no kwarg
    from jax.sharding import AxisType

    def _axis_kw(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:  # jax 0.4.x: every axis is Auto already
    def _axis_kw(n: int) -> dict:
        return {}


def compat_mesh(shape, axes, devices=None) -> Mesh:
    """jax.make_mesh across jax versions (with/without axis_types)."""
    return jax.make_mesh(shape, axes, devices=devices, **_axis_kw(len(axes)))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devs)} — run under "
            "launch/dryrun.py (it forces 512 host devices)")
    return compat_mesh(shape, axes, devices=devs[:n])


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = data * model
    return compat_mesh((data, model), ("data", "model"),
                       devices=jax.devices()[:n])
