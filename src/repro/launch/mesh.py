"""Production meshes.

A function, not a module-level constant — importing this module never
touches jax device state.  The dry-run process forces 512 host-platform
devices (launch/dryrun.py sets XLA_FLAGS before any jax import); everything
else (tests, benches) sees the real single CPU device and uses small meshes.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devs)} — run under "
            "launch/dryrun.py (it forces 512 host devices)")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes),
                         devices=devs[:n])


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = data * model
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto),
                         devices=jax.devices()[:n])
