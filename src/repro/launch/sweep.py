"""Population-sweep driver: a hyperparameter grid on MNIST, end to end.

    PYTHONPATH=src python -m repro.launch.sweep \
        --densities 0.25,0.5 --lrs 0.02,0.05,0.1 --rounds 3 \
        --steps-per-round 20 --out SWEEP_mnist.json

The default grid is density x lr under SGD; ``--optim adam`` switches
every member to the in-kernel Adam epilogue and opens the ``--b1s`` /
``--wds`` axes (grid = density x lr x b1 x wd, with per-member rows in
the ``[E, HYP_K]`` hyp table).  One optimizer kind per sweep — the
accumulator-slot layout is structural.

Builds the candidate grid, buckets it into same-structure cohorts
(candidates sharing a quantized fan-in train as ONE E-batched
population), runs successive halving (search/scheduler.py), and writes
the lineage ledger JSON — per-member config, loss curves, rounds
survived, and the winning configuration.  ``--tag`` stamps the artifact
meta exactly like ``benchmarks/run.py --tag`` stamps BENCH_*.json.
"""
from __future__ import annotations

import argparse


def _floats(s: str) -> list[float]:
    return [float(v) for v in s.split(",") if v]


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--densities", default="0.25,0.5", metavar="D1,D2,...")
    ap.add_argument("--lrs", default="0.02,0.05,0.1", metavar="L1,L2,...")
    ap.add_argument("--momentum", type=float, default=0.0)
    ap.add_argument("--optim", choices=("sgd", "adam"), default="sgd",
                    help="per-member update rule (one kind per sweep: the "
                         "slot layout is structural)")
    ap.add_argument("--b1s", default="0.9", metavar="B1,B2,...",
                    help="Adam b1 sweep axis (--optim adam only)")
    ap.add_argument("--wds", default="0.0", metavar="W1,W2,...",
                    help="Adam weight-decay sweep axis (--optim adam only)")
    ap.add_argument("--hidden", type=int, default=512)
    ap.add_argument("--block", type=int, default=128)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--steps-per-round", type=int, default=20)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--samples", type=int, default=4096,
                    help="train samples drawn from the MNIST epoch")
    ap.add_argument("--eval-samples", type=int, default=512)
    ap.add_argument("--engine", default="auto",
                    help="pallas | jnp | auto (fused BP+UP on pallas)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tag", default="sweep",
                    help="artifact meta tag (ledger meta.tag)")
    ap.add_argument("--out", default="SWEEP_mnist.json")
    ap.add_argument("--obs", default=None, metavar="PATH",
                    help="flight-recorder JSONL sink: rank/prune/"
                         "quarantine round events; render with "
                         "repro.launch.obs_report")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="wrap the sweep in a jax.profiler trace written "
                         "to DIR (kernels show up named by KernelSpec)")
    return ap.parse_args()


def main():
    args = _parse()
    import numpy as np

    from repro.configs.base import SweepConfig
    from repro.data.mnist import paper_dataset
    from repro.obs import Recorder, profile_ctx
    from repro.search import CandidateSpec, bucket, run_sweep

    # output width = smallest block multiple holding the 32 padded classes
    out_w = -(-32 // args.block) * args.block
    layers = (1024, args.hidden, out_w)
    if args.optim == "adam":
        # adam grid: density x lr x b1 x wd (momentum field carries b1)
        grid = [(d, lr, b1, wd)
                for d in _floats(args.densities)
                for lr in _floats(args.lrs)
                for b1 in _floats(args.b1s)
                for wd in _floats(args.wds)]
        specs = [CandidateSpec(lr=lr, momentum=b1, opt="adam",
                               weight_decay=wd, density=d,
                               layers=layers, block=args.block,
                               init_seed=i)
                 for i, (d, lr, b1, wd) in enumerate(grid)]
    else:
        specs = [CandidateSpec(lr=lr, momentum=args.momentum, density=d,
                               layers=layers, block=args.block,
                               init_seed=i)
                 for i, (d, lr) in enumerate(
                     (d, lr) for d in _floats(args.densities)
                     for lr in _floats(args.lrs))]

    n = args.samples + args.eval_samples
    x, t, _ = paper_dataset(n=n, seed=args.seed)
    x_train, t_train = x[:args.samples], t[:args.samples]
    x_eval, t_eval = x[args.samples:], t[args.samples:]

    cfg = SweepConfig(rounds=args.rounds,
                      steps_per_round=args.steps_per_round,
                      batch_size=args.batch,
                      eval_samples=args.eval_samples,
                      seed=args.seed, engine=args.engine)
    n_cohorts = len(bucket(specs))
    # resolved ONCE, same rule as search.population.make_population_step:
    # the in-kernel per-member update needs the pallas engine
    from repro.core.sparse_linear import resolve_engine
    eng = resolve_engine(cfg.engine)
    path = ("fused BP+UP" if cfg.fused and eng == "pallas"
            else "two-pass (materialized grads)")
    print(f"[sweep] {len(specs)} candidates in {n_cohorts} cohort(s), "
          f"{cfg.rounds} rounds x {cfg.steps_per_round} steps, "
          f"engine={eng}")
    print(f"[sweep] optim={args.optim} update path: {path}")
    recorder = (Recorder(args.obs, meta={"launcher": "sweep",
                                         "tag": args.tag})
                if args.obs else None)
    try:
        with profile_ctx(args.profile):
            result = run_sweep(specs, x_train, t_train, x_eval, t_eval, cfg,
                               tag=args.tag, recorder=recorder)
    finally:
        if recorder is not None:
            recorder.close()
            print(f"[sweep] telemetry -> {args.obs} "
                  f"({recorder.n_events} events)")
    led = result.ledger
    led.save(args.out)

    for m in sorted(led.members, key=lambda m: (m.pruned_at is None,
                                                m.rounds_survived)):
        ev = f"{m.eval_losses[-1]:.5f}" if m.eval_losses else "-"
        status = ("WINNER" if m.winner else
                  "live" if m.pruned_at is None else
                  f"quarantined@r{m.quarantined_at['round']}"
                  if m.quarantined_at is not None else
                  f"pruned@r{m.pruned_at}")
        hyps = f"density={m.config['density']} lr={m.config['lr']}"
        if m.config.get("opt") == "adam":
            hyps += (f" b1={m.config['momentum']} "
                     f"wd={m.config['weight_decay']}")
        print(f"[sweep]   member {m.member}: {hyps} eval={ev} {status}")
    w = led.winner()
    if w is None:
        import math
        survived = [m for m in led.members
                    if m.pruned_at is None and m.eval_losses]
        if survived and all(not math.isfinite(m.eval_losses[-1])
                            for m in survived):
            raise SystemExit("[sweep] no winner: every surviving candidate "
                             "diverged (non-finite eval loss) — lower the "
                             "lr grid")
        raise SystemExit("[sweep] no winner — sweep ran no rounds?")
    whyp = f"density={w.config['density']} lr={w.config['lr']}"
    if w.config.get("opt") == "adam":
        whyp += f" b1={w.config['momentum']} wd={w.config['weight_decay']}"
    print(f"[sweep] winner: {whyp} "
          f"eval_loss={w.eval_losses[-1]:.5f} -> {args.out}")
    return result


if __name__ == "__main__":
    main()
