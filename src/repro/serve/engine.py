"""Batched serving engine: continuous batched decode over a shared cache.

Requests arrive with prompts; the engine prefills them as a batch, then
decodes step-locked (one ``decode_step`` per tick for the whole batch),
sampling greedily or by temperature.  Slot management is static-batch
(the dry-run shapes fix the batch); a finished sequence's slot keeps
decoding into a scratch position and is masked out — the standard
fixed-shape TPU serving pattern (shape stability = no recompiles).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.train.steps import make_decode_step, make_prefill_step


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_token: int = -1     # -1: never stop early
    seed: int = 0
    # execution engine override for the sparse linears ("pallas"|"jnp"|
    # "auto"); None keeps the ArchConfig's setting.  The step builders
    # resolve "auto" to the Pallas engine on TPU backends.
    engine: str | None = None
    # quantize-at-load: "int8" converts every sparse junction's weights
    # to int8 codes + per-block scales (core/quantize.quantize_tree) the
    # moment the engine takes the params — decode then runs the int8
    # junction kernels; dense layers (attention, embeddings) stay fp.
    # None serves full precision.  "fxp" is refused here: the LUT bakes
    # ONE activation per junction at quantize time, which fits the paper
    # MLP / population path (launch/quant_sweep.py), not a transformer
    # FFN stack.
    quantize: str | None = None
    # divergence guard: a slot whose logits go non-finite (corrupted
    # weights, poisoned cache) is terminated — EOS-filled and masked out
    # like a finished sequence — instead of sampling garbage into the
    # batch (categorical over NaN logits returns arbitrary token ids and
    # argmax propagates index 0 silently).  Other slots are untouched.
    guard_nonfinite: bool = True


class Engine:
    def __init__(self, cfg: ArchConfig, params, serve_cfg: ServeConfig | None = None):
        self.scfg = serve_cfg or ServeConfig()
        if self.scfg.engine is not None:
            cfg = dataclasses.replace(cfg, engine=self.scfg.engine)
        self.cfg = cfg
        if self.scfg.quantize:
            if self.scfg.quantize != "int8":
                raise ValueError(
                    f"ServeConfig.quantize={self.scfg.quantize!r} — serving "
                    "supports 'int8' only (fxp bakes one LUT activation "
                    "per junction; see launch/quant_sweep.py for that "
                    "path)")
            from repro.core import quantize as qz
            params = qz.quantize_tree(params, qz.QuantConfig(mode="int8"))
        self.params = params
        self._prefill = jax.jit(make_prefill_step(cfg))
        self._decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
        # slots terminated by the non-finite-logit guard in the LAST
        # generate() call (host int, refreshed per call)
        self.nonfinite_terminated = 0

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)

    @staticmethod
    def _guard(logits2d):
        """(bad [B] bool, sanitized logits): a slot with ANY non-finite
        logit is flagged and its row zeroed so sampling stays defined."""
        bad = jnp.any(~jnp.isfinite(logits2d), axis=-1)
        safe = jnp.where(bad[:, None], jnp.zeros_like(logits2d), logits2d)
        return bad, safe

    def generate(self, prompts: np.ndarray, extra_inputs: dict | None = None):
        """prompts [B, S_prompt] int32 (right-aligned, padded with 0).
        Returns tokens [B, max_new_tokens]."""
        B, S = prompts.shape
        total = S + self.scfg.max_new_tokens
        batch = {"tokens": jnp.asarray(prompts)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
        logits, cache = self._prefill(self.params, batch)
        # re-home the prefill cache into a decode-capacity cache
        cache = self._grow_cache(cache, B, total, S)
        # split before the first sample: the root key is only ever split,
        # never consumed (sampling the first token with `key` and then
        # splitting the same `key` reused it — correlated samples)
        key, sub = jax.random.split(jax.random.PRNGKey(self.scfg.seed))
        guard = self.scfg.guard_nonfinite
        # terminated slots are filled with eos (or 0 when eos is unset —
        # the guard must still be able to mask a slot out)
        fill = self.scfg.eos_token if self.scfg.eos_token >= 0 else 0
        nf_slots = jnp.zeros((B,), bool)
        step_logits = logits[:, -1]
        if guard:
            bad, step_logits = self._guard(step_logits)
            nf_slots = nf_slots | bad
        tok = self._sample(step_logits, sub)[:, None]
        if guard:
            tok = jnp.where(nf_slots[:, None], fill, tok)
        out = [tok]
        done = nf_slots if guard else jnp.zeros((B,), bool)
        for i in range(self.scfg.max_new_tokens - 1):
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.asarray(S + i, jnp.int32))
            step_logits = logits[:, -1]
            if guard:
                bad, step_logits = self._guard(step_logits)
                nf_slots = nf_slots | bad
                done = done | bad
            nxt = self._sample(step_logits, sub)[:, None]
            if self.scfg.eos_token >= 0:
                done = done | (tok[:, 0] == self.scfg.eos_token)
            if self.scfg.eos_token >= 0 or guard:
                nxt = jnp.where(done[:, None], fill, nxt)
            tok = nxt
            out.append(tok)
        if guard:
            self.nonfinite_terminated = int(np.asarray(nf_slots).sum())
        return np.asarray(jnp.concatenate(out, axis=1))

    def _grow_cache(self, cache, B, total, S):
        """Copy the prefill cache (seq length S) into a total-capacity one.

        Placement is driven by ``M.cache_seq_axes`` metadata: leaves with a
        seq axis are written at position 0 of that axis, same-shape state
        leaves (conv/ssm state, cross-attn KV) are copied wholesale.  (The
        previous shape-coincidence heuristic guessed axis 2 whenever
        ndim >= 3 and the leading dims matched.)"""
        full = M.make_cache(self.cfg, B, total)
        axes = M.cache_seq_axes(self.cfg)

        def place(ax, dst, src):
            src = src.astype(dst.dtype)
            if ax < 0:  # same-shape state leaf
                assert dst.shape == src.shape, (dst.shape, src.shape)
                return src
            if src.shape[ax] > dst.shape[ax]:  # sliding window: keep tail
                src = jax.lax.slice_in_dim(
                    src, src.shape[ax] - dst.shape[ax], src.shape[ax], axis=ax)
            return jax.lax.dynamic_update_slice_in_dim(dst, src, 0, ax)

        return jax.tree.map(place, axes, full, cache)
