"""Serving engines: static step-locked batch decode and the
continuous-batching engine over a block-paged KV cache.

Two engines share the ServeConfig surface:

``Engine`` — the static-batch baseline: one prefill, then every slot
decodes in lockstep until the longest request finishes, with finished
slots burning compute into a masked scratch position.  Fixed shapes, no
recompiles — the right kernel pattern but the wrong scheduler for heavy
traffic (a batch is as slow as its longest member).

``ContinuousEngine`` — the production scheduler (PR 9).  Requests carry
their own prompt/max_new/arrival; an admission loop refills finished
slots from the queue mid-flight (the per-slot liveness masks from the
guard/EOS machinery become the free-slot signal), long prompts prefill
in fixed-size chunks interleaved with decode ticks, and the KV cache is
a block-paged pool (models/model.make_paged_cache) where a slot refill
is a page-table swap, never a cache copy.  Scheduler invariants:

* every jitted step has ONE shape: the decode tick is always
  (token [B,1], positions [B], page_table [B,maxp]) and the prefill
  chunk always [1, C] — admission, refill, and completion change only
  the integers riding scalar prefetch, so each step compiles exactly
  once (``decode_traces`` / ``prefill_traces`` count retraces);
* page accounting is all-or-nothing at admission (serve/paged.PagePool):
  a request is admitted only when its whole worst-case page span is
  free, so no mid-flight exhaustion and no preemption;
* pool page 0 is the scratch page — free and still-prefilling slots are
  pointed at it during a decode tick, so their masked garbage writes
  never touch live pages;
* decode attends through kernels/flash_attention.flash_decode under
  engine="pallas" (page table on scalar prefetch, double-buffered
  per-page HBM→VMEM DMA — the junction engine's prefetch+DMA idiom
  applied to attention) and the gather+masked-softmax reference on jnp.

Sampling is greedy or by temperature (one fold_in subkey per tick); a
slot whose logits go non-finite is terminated and counted
(``nonfinite_terminated``), like the static engine's guard.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.obs import telemetry as obs
from repro.serve.paged import PagePool
from repro.train.steps import (make_decode_step, make_paged_prefill_step,
                               make_prefill_step)


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_token: int = -1     # -1: never stop early
    seed: int = 0
    # execution engine override for the sparse linears ("pallas"|"jnp"|
    # "auto"); None keeps the ArchConfig's setting.  The step builders
    # resolve "auto" to the Pallas engine on TPU backends.
    engine: str | None = None
    # quantize-at-load: "int8" converts every sparse junction's weights
    # to int8 codes + per-block scales (core/quantize.quantize_tree) the
    # moment the engine takes the params — decode then runs the int8
    # junction kernels; dense layers (attention, embeddings) stay fp.
    # None serves full precision.  "fxp" is refused here: the LUT bakes
    # ONE activation per junction at quantize time, which fits the paper
    # MLP / population path (launch/quant_sweep.py), not a transformer
    # FFN stack.
    quantize: str | None = None
    # divergence guard: a slot whose logits go non-finite (corrupted
    # weights, poisoned cache) is terminated — EOS-filled and masked out
    # like a finished sequence — instead of sampling garbage into the
    # batch (categorical over NaN logits returns arbitrary token ids and
    # argmax propagates index 0 silently).  Other slots are untouched.
    guard_nonfinite: bool = True
    # ---- continuous-batching knobs (ContinuousEngine only) ----
    slots: int = 4          # decode batch width (fixed tick shape)
    page_size: int = 16     # tokens per KV page
    num_pages: int = 0      # pool budget; 0: full residency
                            # (slots * ceil(max_seq/page_size) + scratch)
    prefill_chunk: int = 32 # chunked-prefill width (fixed [1, C] shape)
    max_seq: int = 0        # per-request prompt+new cap; 0: cfg.max_seq


@dataclasses.dataclass
class Request:
    """One serving request.  ``arrival`` is in scheduler ticks (one tick
    per scheduler iteration): the request becomes admissible once the
    engine's tick counter reaches it."""
    rid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int
    arrival: int = 0


class Engine:
    def __init__(self, cfg: ArchConfig, params, serve_cfg: ServeConfig | None = None):
        self.scfg = serve_cfg or ServeConfig()
        if self.scfg.engine is not None:
            cfg = dataclasses.replace(cfg, engine=self.scfg.engine)
        self.cfg = cfg
        if self.scfg.quantize:
            if self.scfg.quantize != "int8":
                raise ValueError(
                    f"ServeConfig.quantize={self.scfg.quantize!r} — serving "
                    "supports 'int8' only (fxp bakes one LUT activation "
                    "per junction; see launch/quant_sweep.py for that "
                    "path)")
            from repro.core import quantize as qz
            params = qz.quantize_tree(params, qz.QuantConfig(mode="int8"))
        self.params = params
        self._prefill = jax.jit(make_prefill_step(cfg))
        self._decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
        # slots terminated by the non-finite-logit guard in the LAST
        # generate() call (host int, refreshed per call)
        self.nonfinite_terminated = 0

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)

    @staticmethod
    def _guard(logits2d):
        """(bad [B] bool, sanitized logits): a slot with ANY non-finite
        logit is flagged and its row zeroed so sampling stays defined."""
        bad = jnp.any(~jnp.isfinite(logits2d), axis=-1)
        safe = jnp.where(bad[:, None], jnp.zeros_like(logits2d), logits2d)
        return bad, safe

    def generate(self, prompts: np.ndarray, extra_inputs: dict | None = None):
        """prompts [B, S_prompt] int32 (right-aligned, padded with 0).
        Returns tokens [B, max_new_tokens]."""
        # refreshed-per-call contract: reset BEFORE the guard branch so a
        # guard-off engine never serves a stale count from a prior call
        self.nonfinite_terminated = 0
        B, S = prompts.shape
        total = S + self.scfg.max_new_tokens
        batch = {"tokens": jnp.asarray(prompts)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
        logits, cache = self._prefill(self.params, batch)
        # re-home the prefill cache into a decode-capacity cache
        cache = self._grow_cache(cache, B, total, S)
        # split before the first sample: the root key is only ever split,
        # never consumed (sampling the first token with `key` and then
        # splitting the same `key` reused it — correlated samples)
        key, sub = jax.random.split(jax.random.PRNGKey(self.scfg.seed))
        guard = self.scfg.guard_nonfinite
        # terminated slots are filled with eos (or 0 when eos is unset —
        # the guard must still be able to mask a slot out)
        fill = self.scfg.eos_token if self.scfg.eos_token >= 0 else 0
        nf_slots = jnp.zeros((B,), bool)
        step_logits = logits[:, -1]
        if guard:
            bad, step_logits = self._guard(step_logits)
            nf_slots = nf_slots | bad
        tok = self._sample(step_logits, sub)[:, None]
        if guard:
            tok = jnp.where(nf_slots[:, None], fill, tok)
        out = [tok]
        done = nf_slots if guard else jnp.zeros((B,), bool)
        for i in range(self.scfg.max_new_tokens - 1):
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.asarray(S + i, jnp.int32))
            step_logits = logits[:, -1]
            if guard:
                bad, step_logits = self._guard(step_logits)
                nf_slots = nf_slots | bad
                done = done | bad
            nxt = self._sample(step_logits, sub)[:, None]
            if self.scfg.eos_token >= 0:
                done = done | (tok[:, 0] == self.scfg.eos_token)
            if self.scfg.eos_token >= 0 or guard:
                nxt = jnp.where(done[:, None], fill, nxt)
            tok = nxt
            out.append(tok)
        if guard:
            self.nonfinite_terminated = int(np.asarray(nf_slots).sum())
        return np.asarray(jnp.concatenate(out, axis=1))

    def _grow_cache(self, cache, B, total, S):
        """Copy the prefill cache (seq length S) into a total-capacity one.

        Placement is driven by ``M.cache_seq_axes`` metadata: leaves with a
        seq axis are written at position 0 of that axis, same-shape state
        leaves (conv/ssm state, cross-attn KV) are copied wholesale.  (The
        previous shape-coincidence heuristic guessed axis 2 whenever
        ndim >= 3 and the leading dims matched.)"""
        full = M.make_cache(self.cfg, B, total)
        axes = M.cache_seq_axes(self.cfg)

        def place(ax, dst, src):
            src = src.astype(dst.dtype)
            if ax < 0:  # same-shape state leaf
                assert dst.shape == src.shape, (dst.shape, src.shape)
                return src
            if src.shape[ax] > dst.shape[ax]:  # sliding window: keep tail
                src = jax.lax.slice_in_dim(
                    src, src.shape[ax] - dst.shape[ax], src.shape[ax], axis=ax)
            return jax.lax.dynamic_update_slice_in_dim(dst, src, 0, ax)

        return jax.tree.map(place, axes, full, cache)


# =============================================================== continuous
_FREE, _PREFILL, _DECODE = 0, 1, 2


class _Slot:
    __slots__ = ("state", "req", "pages", "cache_len", "prefill_pos", "out",
                 "last_tok", "t_admit", "t_wall", "t_first", "t_last",
                 "first_tick", "chunks")

    def __init__(self):
        self.state = _FREE
        self.req: Request | None = None
        self.pages: list[int] = []
        self.cache_len = 0        # tokens written to the paged cache
        self.prefill_pos = 0      # prompt tokens prefilled so far
        self.out: list[int] = []
        self.last_tok = 0         # sampled, not yet fed through decode
        self.t_admit = 0
        self.t_wall = 0.0
        # span bookkeeping (obs.RequestSpan): first-token wall time /
        # tick, previous-token wall time (inter-token latency), and the
        # number of fixed-shape prefill chunks this request consumed
        self.t_first = -1.0
        self.t_last = -1.0
        self.first_tick = -1
        self.chunks = 0


class ContinuousEngine:
    """Continuous-batching serve engine over the block-paged KV cache.

    ``serve(requests)`` drives the admission/prefill/decode loop until
    every request completes; returns {rid: np.ndarray of generated
    tokens} (variable length: a slot frees the moment its request hits
    EOS or its own max_new — that freed capacity is the throughput win
    over the static engine).  ``stats`` carries per-request latencies
    and the page accounting afterwards.

    With a ``recorder`` (obs.Recorder) attached, every finished request
    emits one ``obs.RequestSpan`` reconstructing its whole lifecycle
    (enqueue → admit → prefill chunks → first token → finish, with the
    outcome eos | max_new | guard), TTFT and inter-token latencies land
    in histograms, and page-pool / slot-occupancy gauges refresh every
    scheduler tick.  All of it rides values the scheduler already
    pulled to host (the sampled token, the guard flag) — no extra
    syncs, no traced ops, and the ``decode_traces == 1`` /
    ``prefill_traces == 1`` compile-once contract holds with telemetry
    on (regression-tested)."""

    def __init__(self, cfg: ArchConfig, params,
                 serve_cfg: ServeConfig | None = None,
                 recorder: "obs.Recorder | None" = None):
        self.rec = recorder
        self.scfg = serve_cfg or ServeConfig()
        if self.scfg.engine is not None:
            cfg = dataclasses.replace(cfg, engine=self.scfg.engine)
        ok, why = M.paged_supported(cfg)
        if not ok:
            raise ValueError(f"ContinuousEngine: {why}")
        if self.scfg.quantize:
            if self.scfg.quantize != "int8":
                raise ValueError("ContinuousEngine supports quantize='int8' "
                                 "only (same contract as Engine)")
            from repro.core import quantize as qz
            params = qz.quantize_tree(params, qz.QuantConfig(mode="int8"))
        self.cfg = cfg
        self.params = params
        self.max_seq = self.scfg.max_seq or cfg.max_seq
        ps = self.scfg.page_size
        self.pages_per_slot = -(-self.max_seq // ps)
        # retrace counters: the fixed-shape contract says each stays 1
        # across an entire serve() run (asserted by tests and CI)
        self.decode_traces = 0
        self.prefill_traces = 0
        self.nonfinite_terminated = 0
        self.stats: dict = {}

        decode_fn = make_decode_step(cfg, paged=True)
        prefill_fn = make_paged_prefill_step(cfg)
        greedy = self.scfg.temperature <= 0.0
        temp = self.scfg.temperature

        def tick(params, pool, token, positions, page_table, key):
            self.decode_traces += 1     # traced-time side effect
            logits, pool = decode_fn(params, pool, token, positions,
                                     page_table)
            lg = logits[:, -1].astype(jnp.float32)
            bad = jnp.any(~jnp.isfinite(lg), axis=-1)
            lg = jnp.where(bad[:, None], jnp.zeros_like(lg), lg)
            if greedy:
                tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            else:
                tok = jax.random.categorical(key, lg / temp,
                                             axis=-1).astype(jnp.int32)
            return tok, bad, pool

        def prefill_chunk(params, pool, tokens, base, ptrow, chunk_len):
            self.prefill_traces += 1    # traced-time side effect
            logits, pool = prefill_fn(params, pool, tokens, base, ptrow,
                                      chunk_len)
            return logits[:, -1].astype(jnp.float32), pool

        self._tick = jax.jit(tick, donate_argnums=(1,))
        self._prefill_chunk = jax.jit(prefill_chunk, donate_argnums=(1,))

    # ---------------------------------------------------------- sampling
    def _sample_host(self, logits_row: np.ndarray, key) -> int:
        if self.scfg.temperature <= 0.0:
            return int(np.argmax(logits_row))
        draw = jax.random.categorical(
            key, jnp.asarray(logits_row) / self.scfg.temperature, axis=-1)
        return int(draw)

    # ---------------------------------------------------------- scheduler
    def serve(self, requests: list[Request]) -> dict[int, np.ndarray]:
        scfg = self.scfg
        B, ps = scfg.slots, scfg.page_size
        maxp = self.pages_per_slot
        num_pages = scfg.num_pages or (B * maxp + 1)
        for r in requests:
            need = len(r.prompt) + r.max_new_tokens
            if need > self.max_seq:
                raise ValueError(
                    f"request {r.rid}: prompt+max_new = {need} exceeds "
                    f"max_seq {self.max_seq}")
            if -(-need // ps) > num_pages - 1:
                raise ValueError(
                    f"request {r.rid} needs more pages than the pool holds")
        pool_acct = PagePool(num_pages, ps)
        pool = M.make_paged_cache(self.cfg, num_pages, ps)
        slots = [_Slot() for _ in range(B)]
        # FIFO within arrival order (stable sort keeps submission order)
        queue = collections.deque(sorted(requests, key=lambda r: r.arrival))
        root = jax.random.PRNGKey(scfg.seed)
        self.nonfinite_terminated = 0
        eos = scfg.eos_token
        guard = scfg.guard_nonfinite
        outputs: dict[int, np.ndarray] = {}
        lat: dict[int, dict] = {}
        tick = 0
        decode_ticks = prefill_chunks = 0
        pf_cursor = 0               # round-robin over prefilling slots
        t_serve0 = time.perf_counter()

        rec = self.rec

        def finish(s: _Slot, outcome: str):
            r = s.req
            outputs[r.rid] = np.asarray(s.out, np.int32)
            ttft = s.t_first - s.t_wall if s.t_first >= 0 else -1.0
            lat[r.rid] = {"arrival": r.arrival, "admitted": s.t_admit,
                          "finished": tick, "outcome": outcome,
                          "ttft_s": ttft, "first_token_tick": s.first_tick,
                          "prefill_chunks": s.chunks,
                          "n_tokens": len(s.out),
                          "wall_s": time.perf_counter() - s.t_wall}
            if rec is not None:
                rec.count(f"serve.finish.{outcome}")
                if ttft >= 0:
                    rec.observe("serve.ttft_s", ttft)
                rec.emit(obs.RequestSpan(
                    rid=r.rid, outcome=outcome, enqueue_tick=r.arrival,
                    admit_tick=s.t_admit, first_token_tick=s.first_tick,
                    finish_tick=tick, prefill_chunks=s.chunks,
                    n_tokens=len(s.out), ttft_s=ttft,
                    wall_s=lat[r.rid]["wall_s"]))
            pool_acct.release(s.pages)
            s.__init__()            # back to FREE

        def step_done(s: _Slot, tok: int) -> str | None:
            """Record one sampled token; the outcome string ("eos" |
            "max_new") when the request completed, else None."""
            now = time.perf_counter()
            if not s.out:           # first token of the request
                s.t_first = now
                s.first_tick = tick
            elif rec is not None and s.t_last >= 0:
                rec.observe("serve.itl_s", now - s.t_last)
            s.t_last = now
            s.out.append(tok)
            s.last_tok = tok
            if eos >= 0 and tok == eos:
                return "eos"
            return "max_new" if len(s.out) >= s.req.max_new_tokens else None

        while queue or any(s.state != _FREE for s in slots):
            # ---- admission: refill free slots from the arrival queue
            for s in slots:
                if s.state != _FREE or not queue:
                    continue
                if queue[0].arrival > tick:
                    break
                need = pool_acct.pages_for(
                    len(queue[0].prompt) + queue[0].max_new_tokens)
                pages = pool_acct.alloc(need)
                if pages is None:
                    break           # pool full: stays queued, retry next tick
                r = queue.popleft()
                s.state = _PREFILL
                s.req = r
                s.pages = pages
                s.cache_len = 0
                s.prefill_pos = 0
                s.out = []
                s.t_admit = tick
                s.t_wall = time.perf_counter()

            # ---- one prefill chunk (round-robin), interleaved with decode
            pf_slots = [i for i, s in enumerate(slots) if s.state == _PREFILL]
            if pf_slots:
                i = pf_slots[pf_cursor % len(pf_slots)]
                pf_cursor += 1
                s = slots[i]
                prompt = s.req.prompt
                C = scfg.prefill_chunk
                cl = min(C, len(prompt) - s.prefill_pos)
                buf = np.zeros((1, C), np.int32)
                buf[0, :cl] = prompt[s.prefill_pos:s.prefill_pos + cl]
                ptrow = self._page_row(s, maxp)
                last_logits, pool = self._prefill_chunk(
                    self.params, pool, jnp.asarray(buf),
                    jnp.asarray(s.prefill_pos, jnp.int32),
                    jnp.asarray(ptrow), jnp.asarray(cl, jnp.int32))
                prefill_chunks += 1
                s.chunks += 1
                s.prefill_pos += cl
                s.cache_len = s.prefill_pos
                if s.prefill_pos == len(prompt):
                    row = np.asarray(last_logits)[0]
                    bad = not np.all(np.isfinite(row))
                    if guard and bad:
                        self.nonfinite_terminated += 1
                        s.out.append(eos if eos >= 0 else 0)
                        finish(s, "guard")
                    else:
                        key = jax.random.fold_in(root, 2 * tick)
                        oc = step_done(s, self._sample_host(row, key))
                        if oc:
                            finish(s, oc)
                        else:
                            s.state = _DECODE

            # ---- decode tick: ONE fixed-shape call for the whole batch
            dec = [i for i, s in enumerate(slots) if s.state == _DECODE]
            if dec:
                tokens = np.zeros((B, 1), np.int32)
                positions = np.zeros((B,), np.int32)
                pt = np.zeros((B, maxp), np.int32)   # scratch page default
                for i in dec:
                    s = slots[i]
                    tokens[i, 0] = s.last_tok
                    positions[i] = s.cache_len
                    pt[i] = self._page_row(s, maxp)
                key = jax.random.fold_in(root, 2 * tick + 1)
                tok, bad, pool = self._tick(
                    self.params, pool, jnp.asarray(tokens),
                    jnp.asarray(positions), jnp.asarray(pt), key)
                decode_ticks += 1
                tok, bad = np.asarray(tok), np.asarray(bad)
                for i in dec:
                    s = slots[i]
                    s.cache_len += 1
                    if guard and bad[i]:
                        self.nonfinite_terminated += 1
                        s.out.append(eos if eos >= 0 else 0)
                        finish(s, "guard")
                    else:
                        oc = step_done(s, int(tok[i]))
                        if oc:
                            finish(s, oc)
            elif not pf_slots and queue:
                # idle: jump the clock to the next arrival
                tick = max(tick, queue[0].arrival - 1)
            if rec is not None:
                # occupancy gauges every tick: host dict writes off
                # accounting the scheduler keeps anyway
                rec.gauge("serve.pages_in_use", pool_acct.in_use)
                rec.gauge("serve.pages_free", pool_acct.free_pages)
                states = [s.state for s in slots]
                rec.gauge("serve.slots_decode", states.count(_DECODE))
                rec.gauge("serve.slots_prefill", states.count(_PREFILL))
                rec.gauge("serve.slots_free", states.count(_FREE))
                rec.count("serve.ticks")
            tick += 1

        self.stats = {
            "ticks": tick, "decode_ticks": decode_ticks,
            "prefill_chunks": prefill_chunks,
            "peak_pages": pool_acct.peak_in_use,
            "num_pages": num_pages, "page_size": ps,
            "wall_s": time.perf_counter() - t_serve0,
            "latency": lat,
            "decode_traces": self.decode_traces,
            "prefill_traces": self.prefill_traces,
        }
        return outputs

    @staticmethod
    def _page_row(s: _Slot, maxp: int) -> np.ndarray:
        row = np.zeros((maxp,), np.int32)       # sentinel: scratch page 0
        row[:len(s.pages)] = s.pages
        return row
