"""Host-side page-pool accounting for the continuous-batching engine.

The device side is a fixed [L, P, ps, ...] pool per cache leaf
(models/model.make_paged_cache); this module owns which of the P pages
belong to which request.  Page 0 is reserved as the scratch page: free
and still-prefilling slots are pointed at it during a decode tick, so
their masked garbage writes never touch live pages.

Admission is all-or-nothing: a request is admitted only when every page
it can ever need (ceil((prompt + max_new) / ps)) is free, so a running
request can never hit pool exhaustion mid-flight (no preemption).  The
``in_use`` / ``peak_in_use`` counters are the page-accounting contract
the memory-bound test asserts: peak footprint tracks tokens-in-flight,
not slots x max_len.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class PagePool:
    num_pages: int          # total pool pages, page 0 reserved for scratch
    page_size: int

    def __post_init__(self):
        if self.num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the scratch page)")
        # LIFO free list keeps recently-freed (cache-warm) pages hot
        self._free = list(range(self.num_pages - 1, 0, -1))
        self.in_use = 0
        self.peak_in_use = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def alloc(self, n: int) -> list[int] | None:
        """n pages, or None (caller keeps the request queued)."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self.in_use += n
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return pages

    def release(self, pages: list[int]) -> None:
        assert 0 not in pages, "scratch page is never allocated"
        self._free.extend(pages)
        self.in_use -= len(pages)
        assert self.in_use >= 0
