"""Assigned architecture config — see registry.py for source notes."""
from repro.configs.registry import DEEPSEEK_7B as CONFIG

__all__ = ["CONFIG"]
