"""Assigned architecture config — see registry.py for source notes."""
from repro.configs.registry import LLAVA_NEXT_MISTRAL_7B as CONFIG

__all__ = ["CONFIG"]
