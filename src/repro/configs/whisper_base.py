"""Assigned architecture config — see registry.py for source notes."""
from repro.configs.registry import WHISPER_BASE as CONFIG

__all__ = ["CONFIG"]
