"""Assigned architecture config — see registry.py for source notes."""
from repro.configs.registry import DEEPSEEK_V2_LITE_16B as CONFIG

__all__ = ["CONFIG"]
