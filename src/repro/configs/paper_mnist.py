"""The paper's own configuration (Table I): 1024-64-32 pre-defined-sparse
MLP, (12,3,8) fixed point, z=(128,32), trained on (synthetic) MNIST.

    from repro.configs.paper_mnist import CONFIG, FC_BASELINE
"""
from repro.core import fixed_point as fxp
from repro.core.paper_net import PaperNetConfig

# Table I exactly: d_out=(4,16) -> densities 6.25 % / 50 %, 7.576 % overall
CONFIG = PaperNetConfig(
    layers=(1024, 64, 32),
    d_out=(4, 16),
    z=(128, 32),
    fmt=fxp.PAPER_FMT,          # (b_w, b_n, b_f) = (12, 3, 8)
    activation="sigmoid",
)

# the fully-connected baseline the paper compares against (Fig. 5)
FC_BASELINE = PaperNetConfig(
    layers=(1024, 64, 32),
    d_out=(64, 32),             # d_out = N_i -> dense
    z=(1024, 64),
    fmt=fxp.PAPER_FMT,
    activation="sigmoid",
)

__all__ = ["CONFIG", "FC_BASELINE"]
