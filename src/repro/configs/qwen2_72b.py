"""Assigned architecture config — see registry.py for source notes."""
from repro.configs.registry import QWEN2_72B as CONFIG

__all__ = ["CONFIG"]
