"""All assigned architecture configs, exact per the assignment table.

``[source; verified-tier]`` notes live next to each config.  Discrepancy
notes (e.g. deepseek-v2-lite expert count) are in DESIGN.md Sec. 4.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

# --------------------------------------------------------------------------
# [ssm] falcon-mamba-7b — 64L d4096, attn-free, vocab 65024, state 16 (mamba1)
# [arXiv:2410.05355; unverified]
FALCON_MAMBA_7B = ArchConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    n_heads=0, kv_heads=0, head_dim=0, d_ff=0, vocab=65024, raw_vocab=65024,
    attn_kind="none", ssm_kind="mamba1", ssm_state=16, d_inner=8192,
    dt_rank=256, act="silu", norm="rmsnorm",
)

# [dense] stablelm-3b — 32L d2560 32H MHA ff6912 vocab 50304
# [hf:stabilityai/stablelm-2-1_6b family; unverified]  partial rotary 25%
STABLELM_3B = ArchConfig(
    name="stablelm-3b", family="dense", n_layers=32, d_model=2560,
    n_heads=32, kv_heads=32, head_dim=80, d_ff=6912, vocab=50304,
    raw_vocab=50304, partial_rotary=0.25, rope_theta=1e4, norm="layernorm",
)

# [dense] qwen2-72b — 80L d8192 64H kv8 ff29568 vocab 152064, QKV bias
# [arXiv:2407.10671; hf]
QWEN2_72B = ArchConfig(
    name="qwen2-72b", family="dense", n_layers=80, d_model=8192,
    n_heads=64, kv_heads=8, head_dim=128, d_ff=29568, vocab=152064,
    raw_vocab=152064, qkv_bias=True, rope_theta=1e6,
)

# [dense] deepseek-7b — 30L d4096 32H MHA ff11008 vocab 102400 (llama arch)
# [arXiv:2401.02954; hf]
DEEPSEEK_7B = ArchConfig(
    name="deepseek-7b", family="dense", n_layers=30, d_model=4096,
    n_heads=32, kv_heads=32, head_dim=128, d_ff=11008, vocab=102400,
    raw_vocab=102400, rope_theta=1e4,
)

# [dense] command-r-plus-104b — 64L d12288 96H kv8 ff33792 vocab 256000,
# no-bias, tied embeddings  [hf:CohereForAI/c4ai-command-r-v01 family; unverified]
COMMAND_R_PLUS_104B = ArchConfig(
    name="command-r-plus-104b", family="dense", n_layers=64, d_model=12288,
    n_heads=96, kv_heads=8, head_dim=128, d_ff=33792, vocab=256000,
    raw_vocab=256000, tie_embeddings=True, rope_theta=1e4, norm="layernorm",
)

# [hybrid] zamba2-2.7b — 54 mamba2 layers d2560 state 64 + shared attention
# block every 6 layers (32H MHA hd80, ff 10240)  [arXiv:2411.15242; hf]
ZAMBA2_2P7B = ArchConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, kv_heads=32, head_dim=80, d_ff=10240, vocab=32000,
    raw_vocab=32000, ssm_kind="mamba2", ssm_state=64, d_inner=5120,
    ssm_head_dim=64, hybrid_attn_every=6, rope_theta=1e4,
)

# [vlm] llava-next-mistral-7b — mistral backbone, sliding window 4096,
# anyres patch frontend STUBBED (input_specs supplies patch embeddings)
# [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
LLAVA_NEXT_MISTRAL_7B = ArchConfig(
    name="llava-next-mistral-7b", family="vlm", n_layers=32, d_model=4096,
    n_heads=32, kv_heads=8, head_dim=128, d_ff=14336, vocab=32000,
    raw_vocab=32000, attn_kind="sliding", window=4096, num_patches=576,
    rope_theta=1e4,
)

# [moe] deepseek-v2-lite-16b — 27L d2048 16H MLA(kv_lora 512), 64 routed +
# 2 shared experts top-6, expert ff 1408, first layer dense (ff 10944)
# [arXiv:2405.04434; hf]  (assignment aside says "160 routed" — that is the
# full V2; Lite is 64. See DESIGN.md.)
DEEPSEEK_V2_LITE_16B = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe", n_layers=27, d_model=2048,
    n_heads=16, kv_heads=16, head_dim=128, d_ff=10944, vocab=102400,
    raw_vocab=102400, attn_kind="mla",
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, num_shared=2,
                  d_shared=2816, first_dense_layers=1),
    rope_theta=1e4,
)

# [moe] qwen3-moe-30b-a3b — 48L d2048 32H kv4, 128 experts top-8, expert ff 768
# [hf:Qwen/Qwen3-30B-A3B; hf]
QWEN3_MOE_30B_A3B = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=32, kv_heads=4, head_dim=128, d_ff=768, vocab=151936,
    raw_vocab=151936,
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=768),
    rope_theta=1e6,
)

# [audio] whisper-base — 6L enc + 6L dec, d512 8H ff2048, conv frontend STUB
# (input_specs supplies 1500 frame embeddings).  vocab 51865 padded to 51968
# (multiple of 128) for sharding — the paper's own pad-to-power-of-2 trick.
# [arXiv:2212.04356; unverified]
WHISPER_BASE = ArchConfig(
    name="whisper-base", family="audio", n_layers=6, d_model=512,
    n_heads=8, kv_heads=8, head_dim=64, d_ff=2048, vocab=51968,
    raw_vocab=51865, enc_layers=6, enc_frames=1500, act="gelu",
    norm="layernorm", max_seq=32768 + 8, strategy="sp",
)

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in [
        FALCON_MAMBA_7B, STABLELM_3B, QWEN2_72B, DEEPSEEK_7B,
        COMMAND_R_PLUS_104B, ZAMBA2_2P7B, LLAVA_NEXT_MISTRAL_7B,
        DEEPSEEK_V2_LITE_16B, QWEN3_MOE_30B_A3B, WHISPER_BASE,
    ]
}


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]
