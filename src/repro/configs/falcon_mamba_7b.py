"""Assigned architecture config — see registry.py for source notes."""
from repro.configs.registry import FALCON_MAMBA_7B as CONFIG

__all__ = ["CONFIG"]
