"""Architecture / run configuration dataclasses.

Every assigned architecture gets one file in this package exporting
``CONFIG: ArchConfig``; ``registry.get(name)`` resolves them.  Reduced
variants for CPU smoke tests come from ``ArchConfig.reduced()``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.core.sparsity import SparsityConfig


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden width
    num_shared: int = 0           # shared (always-on) experts
    d_shared: int = 0             # hidden width of the shared expert block
    capacity_factor: float = 1.25
    group_size: int = 2048        # GShard dispatch group
    aux_loss_weight: float = 1e-2
    first_dense_layers: int = 0   # deepseek-v2: layer 0 is a dense FFN


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | ssm | hybrid | moe | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int                    # padded to shardable multiple
    raw_vocab: int = 0
    # attention
    attn_kind: str = "full"       # full | sliding | mla | none
    window: int = 0               # sliding window size
    qkv_bias: bool = False
    partial_rotary: float = 1.0   # fraction of head_dim rotated (stablelm 0.25)
    rope_theta: float = 1e6
    mla: Optional[MLAConfig] = None
    # ssm
    ssm_kind: str = ""            # mamba1 | mamba2
    ssm_state: int = 0
    d_inner: int = 0
    conv_width: int = 4
    ssm_head_dim: int = 64        # mamba2
    dt_rank: int = 0              # mamba1 (0 -> ceil(d_model/16))
    # hybrid (zamba2): shared attention block every k ssm layers
    hybrid_attn_every: int = 0
    # moe
    moe: Optional[MoEConfig] = None
    # enc-dec (whisper): encoder layers + stub frame count
    enc_layers: int = 0
    enc_frames: int = 0
    # vlm (llava): stub patch count
    num_patches: int = 0
    # misc
    act: str = "silu"
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq: int = 8192           # decode position-table bound (pos-emb archs)
    # distribution: "tp" (tensor parallel) or "sp" (sequence parallel —
    # small models whose head counts don't divide the model axis)
    strategy: str = "tp"
    # cast fp32 master params to bf16 once per step (outside the layer scan)
    # so FSDP all-gathers move bf16, not fp32 — perf knob, see §Perf
    cast_params_once: bool = False
    # compute the CE loss in sequence chunks of this many tokens (0 = off):
    # the [tokens, vocab] logits tensor never fully materializes — perf knob
    loss_chunk: int = 0
    # dtype of the selective-scan associative elements ([B,c,d_inner,N]
    # decay/input tensors): bf16 halves the dominant HBM traffic of SSM
    # training; the inter-chunk carry stays fp32 — perf knob, see §Perf
    ssm_scan_dtype: str = "float32"
    # numerics / memory
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    attn_chunk: int = 1024        # online-softmax kv chunk
    ssm_chunk: int = 128          # selective-scan chunk
    # the paper's technique
    sparsity: Optional[SparsityConfig] = None
    # execution engine for pre-defined-sparse linears:
    #   "pallas" — fused edge-bundle Pallas kernels (TPU; interpret off-TPU)
    #   "jnp"    — gather+einsum fallback (dry-run FLOP accounting, CPU)
    #   "auto"   — pallas on TPU backends, jnp elsewhere (default)
    # resolved once at step-build time (train/steps.py, serve/engine.py)
    engine: str = "auto"
    # fused BP+UP: apply the optimizer update to pre-defined-sparse
    # junction weights INSIDE the backward kernels (the paper's concurrent
    # update stage) so weight gradients never materialize in HBM —
    # SGD+momentum or Adam, per the FusedOptimizer's [E, HYP_K] hyp row
    # (grad clipping folds into the gs column via a norm pre-pass;
    # microbatches>1 runs as the full batch).  Takes effect only when
    # train/steps.py resolves the step as eligible (pallas engine, an
    # optim.FusedOptimizer — fused_sgd / fused_adam — and
    # param_dtype == dtype); otherwise — and always for the jnp engine
    # and launch/dryrun.py — the two-pass reference path runs.
    fused_update: bool = False

    # ---------------------------------------------------------------- helpers
    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def d_inner_(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner_ // self.ssm_head_dim

    def with_sparsity(self, sp: SparsityConfig) -> "ArchConfig":
        return dataclasses.replace(self, sparsity=sp)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=4,
            kv_heads=min(self.kv_heads, 4) if self.kv_heads >= self.n_heads else 2,
            head_dim=32,
            d_ff=256,
            vocab=256,
            raw_vocab=256,
            d_inner=256,
            dt_rank=8,
            ssm_head_dim=32,
            enc_layers=min(self.enc_layers, 2),
            enc_frames=16 if self.enc_frames else 0,
            num_patches=8 if self.num_patches else 0,
            window=min(self.window, 64) if self.window else 0,
            max_seq=512,
            attn_chunk=32,
            ssm_chunk=16,
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=8, top_k=2, d_expert=64,
                d_shared=64 if self.moe.num_shared else 0, group_size=64)
        if self.mla is not None:
            kw["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=32,
                                  qk_rope_head_dim=16, v_head_dim=32)
        if self.sparsity is not None:
            kw["sparsity"] = dataclasses.replace(self.sparsity, block=32)
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count N for MODEL_FLOPS = 6*N*D."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "vlm", "moe") or self.attn_kind != "none":
            if self.attn_kind == "mla":
                m = self.mla
                qd = self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                per_attn = (d * qd + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                            + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                            + self.n_heads * m.v_head_dim * d)
            else:
                per_attn = (d * self.n_heads * self.head_dim
                            + 2 * d * self.kv_heads * self.head_dim
                            + self.n_heads * self.head_dim * d)
        else:
            per_attn = 0
        gated = 3 if self.act == "silu" else 2
        if self.family == "moe":
            mo = self.moe
            ffn = mo.num_experts * gated * d * mo.d_expert
            if mo.num_shared:
                ffn += gated * d * mo.d_shared
            per_layer = per_attn + ffn
        elif self.family in ("ssm", "hybrid"):
            di, N = self.d_inner_, self.ssm_state
            if self.ssm_kind == "mamba1":
                ssm = (d * 2 * di + self.conv_width * di
                       + di * (self.dt_rank_ + 2 * N) + self.dt_rank_ * di
                       + di * N + di + di * d)
            else:  # mamba2
                H = self.ssm_heads
                ssm = (d * (2 * di + 2 * N + H) + self.conv_width * (di + 2 * N)
                       + H + di + di * d)
            per_layer = ssm
            if self.family == "hybrid":
                # shared attention block params amortized once, added below
                pass
        else:
            per_layer = per_attn + gated * d * f
        total = emb + L * per_layer
        if self.family == "hybrid" and self.hybrid_attn_every:
            total += (d * self.n_heads * self.head_dim * 2
                      + 2 * d * self.kv_heads * self.head_dim
                      + gated * d * self.d_ff)
        if self.family == "audio":
            # encoder layers (self-attn + mlp) + decoder cross-attn
            total += self.enc_layers * (4 * d * d + 2 * d * f)
            total += self.n_layers * 4 * d * d  # cross-attn per decoder layer
        if self.family == "moe" and self.moe.first_dense_layers:
            total += self.moe.first_dense_layers * (gated * d * f - self.moe.num_experts * gated * d * self.moe.d_expert)
        return int(total)

    def active_param_count(self) -> int:
        """N_active for MoE MODEL_FLOPS."""
        if self.family != "moe":
            return self.param_count()
        mo = self.moe
        d, L = self.d_model, self.n_layers
        gated = 3
        full = self.param_count()
        all_experts = L * mo.num_experts * gated * d * mo.d_expert
        active = L * mo.top_k * gated * d * mo.d_expert
        return int(full - all_experts + active)


@dataclasses.dataclass(frozen=True)
class SweepConfig:
    """Population-search run configuration (src/repro/search/): the
    paper's resource-vs-training-time trade as user-facing knobs —
    cohort size E comes from the candidate list, this fixes the rounds
    side (successive halving) and the execution engine.

    rounds: successive-halving rounds; after each, the live population
        is ranked by eval loss and pruned to keep_fraction (pruned slots
        are masked + hyp-zeroed in place — fixed shapes, no recompiles).
    steps_per_round: fused E-batched train steps between prunes.
    batch_size / eval_samples: shared-data minibatch and held-out sizes.
    engine: "pallas" | "jnp" | "auto" (resolved once at step build);
        fused applies only on the pallas engine.
    quarantine: trip-wire fault isolation: a member whose loss or
        in-kernel health flag goes non-finite is masked + hyp-zeroed
        MID-round (the prune mechanism applied immediately) and recorded
        in the ledger, so sweeping lr×density into the divergent regime
        cannot poison the rest of the cohort's run.
    """
    rounds: int = 3
    steps_per_round: int = 20
    batch_size: int = 128
    eval_samples: int = 512
    keep_fraction: float = 0.5
    seed: int = 0
    engine: str = "auto"
    fused: bool = True
    quarantine: bool = True


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def long_context_ok(cfg: ArchConfig) -> bool:
    """long_500k runs only for sub-quadratic attention (DESIGN.md Sec. 4)."""
    return (cfg.family in ("ssm", "hybrid")
            or cfg.attn_kind == "sliding")


def valid_cells(cfg: ArchConfig):
    for s in SHAPES.values():
        if s.name == "long_500k" and not long_context_ok(cfg):
            continue
        yield s
