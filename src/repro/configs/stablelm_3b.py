"""Assigned architecture config — see registry.py for source notes."""
from repro.configs.registry import STABLELM_3B as CONFIG

__all__ = ["CONFIG"]
