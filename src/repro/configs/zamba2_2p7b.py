"""Assigned architecture config — see registry.py for source notes."""
from repro.configs.registry import ZAMBA2_2P7B as CONFIG

__all__ = ["CONFIG"]
