"""Assigned architecture config — see registry.py for source notes."""
from repro.configs.registry import COMMAND_R_PLUS_104B as CONFIG

__all__ = ["CONFIG"]
