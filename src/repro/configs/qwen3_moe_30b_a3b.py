"""Assigned architecture config — see registry.py for source notes."""
from repro.configs.registry import QWEN3_MOE_30B_A3B as CONFIG

__all__ = ["CONFIG"]
