"""MNIST-class data for the paper repro (Sec. III-A).

No network access in this environment: ``synthetic_mnist`` generates a
deterministic 10-class dataset of 28x28 8-bit grayscale images (smooth
class prototypes + per-sample deformation + noise), padded exactly like the
paper: inputs 784 -> 1024 with zeros, labels one-hot 10 -> 32.  Real MNIST
idx files are used transparently when present (data/mnist/ or $MNIST_DIR).

The paper's relative claims (sparse-vs-FC clipping, bit-width ordering,
activation comparison, density sweep) are dataset-robust; absolute
accuracies are reported on this synthetic set next to the paper's numbers.
"""
from __future__ import annotations

import gzip
import os
import struct
from pathlib import Path

import numpy as np

PAPER_EPOCH = 12544    # inputs per epoch (Sec. III-B)


def _prototypes(rng: np.random.Generator) -> np.ndarray:
    """10 smooth, well-separated 28x28 prototypes (digit stand-ins)."""
    yy, xx = np.mgrid[0:28, 0:28] / 27.0
    protos = []
    for c in range(10):
        rngc = np.random.default_rng(1000 + c)
        img = np.zeros((28, 28))
        for _ in range(4):  # a few gaussian strokes per class
            cx, cy = rngc.uniform(0.15, 0.85, 2)
            sx, sy = rngc.uniform(0.04, 0.18, 2)
            amp = rngc.uniform(0.6, 1.0)
            img += amp * np.exp(-((xx - cx) ** 2 / (2 * sx ** 2)
                                  + (yy - cy) ** 2 / (2 * sy ** 2)))
        protos.append(img / img.max())
    return np.stack(protos)


def synthetic_mnist(n: int = PAPER_EPOCH, seed: int = 0,
                    noise: float = 0.15) -> tuple[np.ndarray, np.ndarray]:
    """Returns (images [n,784] float in [0,1], labels [n] int)."""
    rng = np.random.default_rng(seed)
    protos = _prototypes(rng)
    labels = rng.integers(0, 10, size=n)
    imgs = protos[labels]
    # per-sample shift (up to 2px) + multiplicative jitter + noise
    out = np.empty((n, 28, 28), np.float32)
    shifts = rng.integers(-2, 3, size=(n, 2))
    for i in range(n):
        out[i] = np.roll(imgs[i], tuple(shifts[i]), axis=(0, 1))
    out *= rng.uniform(0.7, 1.0, size=(n, 1, 1)).astype(np.float32)
    out += noise * rng.standard_normal((n, 28, 28)).astype(np.float32)
    out = np.clip(out, 0.0, 1.0)
    # 8-bit grayscale quantization, like the real dataset
    out = np.round(out * 255.0) / 255.0
    return out.reshape(n, 784), labels.astype(np.int32)


def _read_idx(path: Path) -> np.ndarray:
    opener = gzip.open if path.suffix == ".gz" else open
    with opener(path, "rb") as f:
        magic = struct.unpack(">I", f.read(4))[0]
        ndim = magic & 0xFF
        dims = [struct.unpack(">I", f.read(4))[0] for _ in range(ndim)]
        return np.frombuffer(f.read(), np.uint8).reshape(dims)


def real_mnist(root: str | None = None):
    """(images [N,784] in [0,1], labels [N]) or None if files absent."""
    root = Path(root or os.environ.get("MNIST_DIR", "data/mnist"))
    for imgs_name, lbl_name in [
            ("train-images-idx3-ubyte", "train-labels-idx1-ubyte"),
            ("train-images-idx3-ubyte.gz", "train-labels-idx1-ubyte.gz")]:
        ip, lp = root / imgs_name, root / lbl_name
        if ip.exists() and lp.exists():
            x = _read_idx(ip).astype(np.float32).reshape(-1, 784) / 255.0
            y = _read_idx(lp).astype(np.int32)
            return x, y
    return None


def paper_dataset(n: int = PAPER_EPOCH, seed: int = 0):
    """Padded per Sec. III-A: x [n,1024], y one-hot [n,32]."""
    real = real_mnist()
    if real is not None:
        x, y = real
        x, y = x[:n], y[:n]
    else:
        x, y = synthetic_mnist(n, seed)
    xp = np.zeros((x.shape[0], 1024), np.float32)
    xp[:, :784] = x
    yp = np.zeros((x.shape[0], 32), np.float32)
    yp[np.arange(x.shape[0]), y] = 1.0
    return xp, yp, y
