"""Deterministic, restartable data pipeline.

The FPGA streams inputs over UART because they don't fit on-chip
(Sec. III-D-4); the cluster-scale analogue is a host pipeline feeding
sharded device batches.  Key property for fault tolerance: the iterator is
a pure function of (seed, step) — checkpoints store just two integers and
restart resumes bit-identically (tests/test_checkpoint.py).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass
class LMTokenPipeline:
    """Synthetic language-model token stream (markov-ish structure so the
    loss actually falls).  State = (seed, step)."""
    cfg: ArchConfig
    batch_size: int
    seq_len: int
    seed: int = 0
    step: int = 0

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_state(cls, cfg, batch_size, seq_len, state):
        return cls(cfg, batch_size, seq_len, seed=state["seed"],
                   step=state["step"])

    def _make(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        V = self.cfg.raw_vocab or self.cfg.vocab
        B, S = self.batch_size, self.seq_len
        # structured stream: blocks of arithmetic token runs + noise — gives
        # next-token structure a model can learn quickly
        base = rng.integers(0, V - S - 2, size=(B, 1))
        runs = base + np.arange(S)[None, :]
        noise = rng.integers(0, V, size=(B, S))
        mask = rng.random((B, S)) < 0.15
        tokens = np.where(mask, noise, runs % V).astype(np.int32)
        batch = {"tokens": tokens}
        if self.cfg.family == "vlm":
            P = min(self.cfg.num_patches, S // 2)
            batch["patches"] = rng.standard_normal(
                (B, P, self.cfg.d_model)).astype(np.float32)
            batch["tokens"] = tokens[:, : S - P]
        if self.cfg.family == "audio":
            batch["frames"] = rng.standard_normal(
                (B, self.cfg.enc_frames, self.cfg.d_model)).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = self._make(self.step)
        self.step += 1
        return b


def device_put_batch(batch: dict, shardings=None):
    if shardings is None:
        return jax.tree.map(jnp.asarray, batch)
    return jax.tree.map(
        lambda t, s: jax.device_put(jnp.asarray(t), s), batch, shardings)
