"""Attention: GQA / MLA / sliding-window, train+prefill+decode paths.

Memory discipline: full-sequence attention is computed with an
online-softmax scan over KV chunks (flash-attention semantics in plain
lax.scan — the Pallas kernel in kernels/flash_attention.py is the TPU
drop-in).  Decode attends over the whole cache with masked softmax; with
the cache sequence dimension sharded over the "model" mesh axis the XLA
SPMD partitioner turns the softmax/contraction reductions into tiny
all-reduces — flash-decoding for free (DESIGN.md Sec. 5).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import sparse_linear as sl
from repro.models.layers import norm_apply, norm_init, rope

NEG_INF = -1e30
Params = dict[str, Any]


# =============================================================== init
def attn_init(key, cfg: ArchConfig, dtype=jnp.float32, cross: bool = False,
              seed: int = 0) -> Params:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim
    sp = cfg.sparsity
    ks = jax.random.split(key, 6)
    if cfg.attn_kind == "mla" and not cross:
        m = cfg.mla
        qd = H * (m.qk_nope_head_dim + m.qk_rope_head_dim)
        p: Params = {
            "wq": sl.init_linear(ks[0], d, qd, family="attn", sp=sp, dtype=dtype, seed=seed),
            "wkv_a": sl.init_dense(ks[1], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype=dtype),
            "kv_norm": norm_init(m.kv_lora_rank, "rmsnorm", dtype),
            "wkv_b": sl.init_dense(ks[2], m.kv_lora_rank,
                                   H * (m.qk_nope_head_dim + m.v_head_dim), dtype=dtype),
            "wo": sl.init_linear(ks[3], H * m.v_head_dim, d, family="attn", sp=sp,
                                 dtype=dtype, seed=seed + 1),
        }
        return p
    p = {
        "wq": sl.init_linear(ks[0], d, H * hd, family="attn", sp=sp,
                             bias=cfg.qkv_bias, dtype=dtype, seed=seed),
        "wk": sl.init_dense(ks[1], d, Hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": sl.init_dense(ks[2], d, Hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": sl.init_linear(ks[3], H * hd, d, family="attn", sp=sp,
                             dtype=dtype, seed=seed + 1),
    }
    return p


# =============================================================== core math
def _split_heads(x, n_heads, hd):
    return x.reshape(*x.shape[:-1], n_heads, hd)


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      chunk: int = 1024, q_pos=None, kv_pos=None):
    """Online-softmax attention.  q [B,Sq,H,D]; k,v [B,Sk,Hkv,D].

    Scans KV chunks carrying (running max, normalizer, weighted acc) in fp32
    — numerically identical to monolithic softmax, O(Sq*chunk) live memory.
    """
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    scale = 1.0 / np.sqrt(D)
    chunk = min(chunk, Sk)
    if Sk % chunk:  # pad KV to a chunk multiple; padding masked below
        pad = chunk - Sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nck = k.shape[1] // chunk
    if q_pos is None:
        q_pos = jnp.arange(Sq)
    if kv_pos is None:
        kv_pos = jnp.arange(Sk)
    kv_pos = jnp.pad(kv_pos, (0, k.shape[1] - Sk), constant_values=Sk + 10**9)

    q5 = q.reshape(B, Sq, Hkv, rep, D)
    kc = k.reshape(B, nck, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nck, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(nck, chunk)

    def step(carry, inp):
        m, l, acc = carry
        kj, vj, pj = inp
        s = jnp.einsum("bqgrd,bkgd->bgrqk", q5, kj,
                       preferred_element_type=jnp.float32) * scale
        mask = pj[None, None, None, None, :] <= Sk + 10**8  # padding mask
        if causal:
            mask = mask & (q_pos[None, None, None, :, None]
                           >= pj[None, None, None, None, :])
        if window:
            mask = mask & (q_pos[None, None, None, :, None]
                           - pj[None, None, None, None, :] < window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        upd = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(q.dtype), vj,
                         preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + upd
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, rep, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, rep, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, rep, Sq, D), jnp.float32)
    # recompute scores in the backward pass (flash-attention style): without
    # this the scan stashes per-chunk [B,H,Sq,ck] score tensors for autodiff
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step), (m0, l0, a0),
                                  (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0):
    """q [B,1,H,D]; caches [B,S,Hkv,D]; pos: scalar current position.

    With S sharded over the model axis this lowers to local partial
    softmax + tiny all-reduces (flash-decoding).  For ring-buffer (sliding
    window) caches S == window and every slot written so far is valid.
    """
    B, _, H, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    rep = H // Hkv
    scale = 1.0 / np.sqrt(D)
    q5 = q.reshape(B, 1, Hkv, rep, D)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", q5, k_cache,
                   preferred_element_type=jnp.float32) * scale
    idx = jnp.arange(S)
    if window:  # ring buffer: slots 0..min(pos, S-1) valid
        valid = (idx <= pos) | (pos >= S)
    else:
        valid = idx <= pos
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bgrqk,bkgd->bgrqd", (p / l).astype(q.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)


# =============================================================== GQA paths
def gqa_forward(p: Params, x, cfg: ArchConfig, *, positions, causal=True,
                kv_override=None):
    """Train/prefill/encoder self-attention (full sequence).

    Returns (out, (k, v)) — k/v handed to the caller for cache building.
    ``kv_override`` supplies encoder K/V for cross-attention.
    """
    B, S, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    q = _split_heads(sl.apply(p["wq"], x, engine=cfg.engine), H, hd)
    if kv_override is None:
        k = _split_heads(sl.apply(p["wk"], x, engine=cfg.engine), Hkv, hd)
        v = _split_heads(sl.apply(p["wv"], x, engine=cfg.engine), Hkv, hd)
        if cfg.family != "audio":  # whisper uses absolute positions, no rope
            q = rope(q, positions, cfg.rope_theta, cfg.partial_rotary)
            k = rope(k, positions, cfg.rope_theta, cfg.partial_rotary)
    else:
        k, v = kv_override
        causal = False
    window = cfg.window if cfg.attn_kind == "sliding" else 0
    kv_pos = positions if kv_override is None else None
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            chunk=cfg.attn_chunk, q_pos=positions, kv_pos=kv_pos)
    out = sl.apply(p["wo"], out.reshape(B, S, H * hd), engine=cfg.engine)
    return out, (k, v)


def gqa_decode(p: Params, x, cfg: ArchConfig, cache: dict, pos,
               cross: bool = False):
    """Single-token decode.  cache: {"k": [B,S,Hkv,hd], "v": ...}.

    Sliding-window archs use a ring buffer (S == window, slot = pos % S).
    Returns (out, new_cache).
    """
    B = x.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    q = _split_heads(sl.apply(p["wq"], x, engine=cfg.engine), H, hd)
    if not cross:
        k_new = _split_heads(sl.apply(p["wk"], x, engine=cfg.engine), Hkv, hd)
        v_new = _split_heads(sl.apply(p["wv"], x, engine=cfg.engine), Hkv, hd)
        if cfg.family != "audio":
            pos_arr = jnp.full((1,), pos)
            q = rope(q, pos_arr, cfg.rope_theta, cfg.partial_rotary)
            k_new = rope(k_new, pos_arr, cfg.rope_theta, cfg.partial_rotary)
        S = cache["k"].shape[1]
        sliding = cfg.attn_kind == "sliding"
        slot = pos % S if sliding else pos
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, 1)
        out = decode_attention(q, k_cache, v_cache, pos, window=S if sliding else 0)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        # cross attention: every encoder slot valid, cache is read-only
        S = cache["k"].shape[1]
        out = decode_attention(q, cache["k"], cache["v"], jnp.asarray(S - 1))
        new_cache = cache
    out = sl.apply(p["wo"], out.reshape(B, 1, H * hd), engine=cfg.engine)
    return out, new_cache


# =============================================================== paged paths
def paged_kv_update(cache: dict, k_new, v_new, positions, page_table,
                    keys=("k", "v")):
    """Scatter new KV rows into the block-paged pool.

    cache: {"k": [P, ps, Hkv, hd], "v": ...} (one layer's pool slice);
    k_new/v_new [B, S, Hkv, hd] — tokens to write; positions [B, S] —
    their absolute positions; page_table [B, maxp] — pool page ids in
    token order.  Token at position t lands in page page_table[b, t//ps]
    at offset t % ps, so a slot refill is a page-table swap, never a
    cache copy.  Free/prefilling slots are pointed at the reserved
    scratch page by the engine, so their writes are harmless."""
    ps = cache[keys[0]].shape[1]
    pid = jnp.take_along_axis(page_table, positions // ps, axis=1)   # [B, S]
    off = positions % ps
    pid, off = pid.reshape(-1), off.reshape(-1)
    out = dict(cache)
    for key, new in zip(keys, (k_new, v_new)):
        flat = new.reshape(-1, *new.shape[2:]).astype(cache[key].dtype)
        out[key] = cache[key].at[pid, off].set(flat)
    return out


def paged_decode_attention(q, k_pool, v_pool, page_table, seq_lens, *,
                           engine: str = "jnp"):
    """q [B,1,H,D]; pools [P,ps,Hkv,D]; page_table [B,maxp];
    seq_lens [B] (valid tokens per slot).  Routes through the Pallas
    flash_decode kernel under engine="pallas" (page table on scalar
    prefetch, per-page HBM→VMEM DMA) and the gather+masked-softmax
    reference otherwise.  Returns [B,1,H,D]."""
    from repro.kernels import flash_attention as fa
    B, _, H, D = q.shape
    Hkv = k_pool.shape[2]
    rep = H // Hkv
    qf = q.reshape(B, Hkv, rep, D)
    if engine == "pallas":
        out = fa.flash_decode(qf, k_pool, v_pool, page_table, seq_lens)
    else:
        out = fa.paged_decode_ref(qf, k_pool, v_pool, page_table, seq_lens)
    return out.reshape(B, 1, H, D)


def gqa_decode_paged(p: Params, x, cfg: ArchConfig, cache: dict, positions,
                     page_table):
    """Continuous-batching single-token decode over the paged pool.

    x [B,1,d]; positions [B] — per-slot write position (the cache holds
    ``positions[b]`` tokens before this call); page_table [B, maxp].
    Returns (out, new_cache).  Unlike gqa_decode there is no scalar
    step: every slot carries its own counter, so a mid-tick refill only
    changes the prefetched integers."""
    B = x.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    q = _split_heads(sl.apply(p["wq"], x, engine=cfg.engine), H, hd)
    k_new = _split_heads(sl.apply(p["wk"], x, engine=cfg.engine), Hkv, hd)
    v_new = _split_heads(sl.apply(p["wv"], x, engine=cfg.engine), Hkv, hd)
    pos2d = positions[:, None]                                   # [B, 1]
    q = rope(q, pos2d, cfg.rope_theta, cfg.partial_rotary)
    k_new = rope(k_new, pos2d, cfg.rope_theta, cfg.partial_rotary)
    new_cache = paged_kv_update(cache, k_new, v_new, pos2d, page_table)
    out = paged_decode_attention(q, new_cache["k"], new_cache["v"],
                                 page_table, positions + 1, engine=cfg.engine)
    out = sl.apply(p["wo"], out.reshape(B, 1, H * hd), engine=cfg.engine)
    return out, new_cache


def gqa_prefill_paged(p: Params, x, cfg: ArchConfig, cache: dict, positions,
                      page_table):
    """Chunked-prefill attention for one slot: x [1,C,d] (a fixed-size
    prompt chunk, possibly tail-padded), positions [C] absolute chunk
    positions, page_table [1, maxp].  Writes the chunk's KV into the
    slot's pages, then attends causally over the gathered pages (earlier
    chunks included) via chunked_attention with the gathered index as
    kv position — padded tail tokens land past the prompt and are
    overwritten by decode before they are ever unmasked."""
    B, C, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    q = _split_heads(sl.apply(p["wq"], x, engine=cfg.engine), H, hd)
    k_new = _split_heads(sl.apply(p["wk"], x, engine=cfg.engine), Hkv, hd)
    v_new = _split_heads(sl.apply(p["wv"], x, engine=cfg.engine), Hkv, hd)
    q = rope(q, positions, cfg.rope_theta, cfg.partial_rotary)
    k_new = rope(k_new, positions, cfg.rope_theta, cfg.partial_rotary)
    new_cache = paged_kv_update(cache, k_new, v_new, positions[None, :],
                                page_table)
    ps = new_cache["k"].shape[1]
    maxp = page_table.shape[1]
    kg = new_cache["k"][page_table[0]].reshape(1, maxp * ps, Hkv, hd)
    vg = new_cache["v"][page_table[0]].reshape(1, maxp * ps, Hkv, hd)
    out = chunked_attention(q, kg, vg, causal=True, chunk=cfg.attn_chunk,
                            q_pos=positions, kv_pos=jnp.arange(maxp * ps))
    out = sl.apply(p["wo"], out.reshape(B, C, H * hd), engine=cfg.engine)
    return out, new_cache


# =============================================================== MLA paths
def mla_forward(p: Params, x, cfg: ArchConfig, *, positions):
    """DeepSeek-V2 multi-head latent attention, expanded form (train/prefill).

    Returns (out, (latent, k_rope)) for the compressed cache."""
    B, S, _ = x.shape
    m, H = cfg.mla, cfg.n_heads
    nope, rd, vd, lora = (m.qk_nope_head_dim, m.qk_rope_head_dim,
                          m.v_head_dim, m.kv_lora_rank)
    q = _split_heads(sl.apply(p["wq"], x, engine=cfg.engine), H, nope + rd)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    a = sl.apply_dense(p["wkv_a"], x)                       # [B,S,lora+rd]
    latent = norm_apply(p["kv_norm"], a[..., :lora], "rmsnorm", cfg.norm_eps)
    k_rope = rope(a[..., lora:][:, :, None, :], positions, cfg.rope_theta)  # [B,S,1,rd]

    kvb = sl.apply_dense(p["wkv_b"], latent)                # [B,S,H*(nope+vd)]
    kvb = kvb.reshape(B, S, H, nope + vd)
    k_nope, v = kvb[..., :nope], kvb[..., nope:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, rd))], -1)
    qf = jnp.concatenate([q_nope, q_rope], -1)
    # pad v to qk dim for the shared chunked kernel, slice after
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, nope + rd - vd)))
    out = chunked_attention(qf, k, v_pad, causal=True, chunk=cfg.attn_chunk,
                            q_pos=positions, kv_pos=positions)[..., :vd]
    out = sl.apply(p["wo"], out.reshape(B, S, H * vd), engine=cfg.engine)
    return out, (latent, k_rope[:, :, 0, :])


def mla_decode(p: Params, x, cfg: ArchConfig, cache: dict, pos):
    """Absorbed-form MLA decode: attention scored directly in latent space —
    the cache is [B,S,lora] + [B,S,rd] (the paper-stated memory win)."""
    B = x.shape[0]
    m, H = cfg.mla, cfg.n_heads
    nope, rd, vd, lora = (m.qk_nope_head_dim, m.qk_rope_head_dim,
                          m.v_head_dim, m.kv_lora_rank)
    q = _split_heads(sl.apply(p["wq"], x, engine=cfg.engine), H, nope + rd)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    pos_arr = jnp.full((1,), pos)
    q_rope = rope(q_rope, pos_arr, cfg.rope_theta)

    a = sl.apply_dense(p["wkv_a"], x)
    lat_new = norm_apply(p["kv_norm"], a[..., :lora], "rmsnorm", cfg.norm_eps)
    kr_new = rope(a[..., lora:][:, :, None, :], pos_arr, cfg.rope_theta)[:, :, 0, :]
    lat = jax.lax.dynamic_update_slice_in_dim(
        cache["latent"], lat_new.astype(cache["latent"].dtype), pos, 1)
    kr = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), pos, 1)

    wkv_b = p["wkv_b"]["w"].reshape(lora, H, nope + vd).astype(x.dtype)
    w_uk, w_uv = wkv_b[..., :nope], wkv_b[..., nope:]
    # absorb W_UK into q: [B,1,H,lora]
    q_abs = jnp.einsum("bqhn,lhn->bqhl", q_nope, w_uk)
    s = (jnp.einsum("bqhl,bsl->bhqs", q_abs, lat, preferred_element_type=jnp.float32)
         + jnp.einsum("bqhr,bsr->bhqs", q_rope, kr, preferred_element_type=jnp.float32))
    s = s / np.sqrt(nope + rd)
    valid = jnp.arange(lat.shape[1]) <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhqs,bsl->bqhl", pr, lat)
    out = jnp.einsum("bqhl,lhv->bqhv", o_lat, w_uv)
    out = sl.apply(p["wo"], out.reshape(B, 1, H * vd), engine=cfg.engine)
    return out, {"latent": lat, "k_rope": kr}
