"""State-space blocks: Mamba-1 (falcon-mamba) and Mamba-2 / SSD (zamba2).

Training/prefill use a *chunked* parallel scan: an outer ``lax.scan`` over
sequence chunks carries the recurrent state; inside a chunk the recurrence
is solved in parallel (associative scan for Mamba-1, the matmul-form SSD
for Mamba-2).  Live memory is O(B * chunk * d_inner * d_state) per step —
the reason falcon-mamba train_4k fits (DESIGN.md Sec. 5).

Decode is the O(1) recurrent step on (conv_state, ssm_state).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import sparse_linear as sl

Params = dict[str, Any]


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv.  x [B,S,C]; w [K,C]; returns (y, new_state).

    conv_state [B,K-1,C] carries the last K-1 inputs for decode."""
    K = w.shape[0]
    if conv_state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    y = y + b[None, None, :]
    new_state = xp[:, -(K - 1):, :] if K > 1 else None
    return y.astype(x.dtype), new_state


# ====================================================================
# Mamba-1 (selective scan, diagonal A per channel, d_state = N)
# ====================================================================
def mamba1_init(key, cfg: ArchConfig, dtype=jnp.float32, seed: int = 0) -> Params:
    d, di, N, R = cfg.d_model, cfg.d_inner_, cfg.ssm_state, cfg.dt_rank_
    ks = jax.random.split(key, 6)
    sp = cfg.sparsity
    p: Params = {
        "in_proj": sl.init_linear(ks[0], d, 2 * di, family="ffn", sp=sp, dtype=dtype, seed=seed),
        "conv_w": jax.random.normal(ks[1], (cfg.conv_width, di), dtype) / float(np.sqrt(cfg.conv_width)),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": sl.init_dense(ks[2], di, R + 2 * N, dtype=dtype),
        "dt_proj": sl.init_dense(ks[3], R, di, bias=True, dtype=dtype),
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, N + 1, dtype=dtype), (di, N))),
        "D": jnp.ones((di,), dtype),
        "out_proj": sl.init_linear(ks[4], di, d, family="ffn", sp=sp, dtype=dtype, seed=seed + 1),
    }
    return p


def _ssm_chunk_scan(decay, inp, h0):
    """Solve h_t = decay_t * h_{t-1} + inp_t within a chunk, in parallel.

    decay/inp: [B, c, ...state dims...]; h0 same without c."""
    def combine(a, b):
        (da, xa), (db, xb) = a, b
        return da * db, xa * db + xb
    d_cum, x_cum = jax.lax.associative_scan(combine, (decay, inp), axis=1)
    h = d_cum * h0[:, None] + x_cum
    return h, h[:, -1]


def mamba1_apply(p: Params, x, cfg: ArchConfig, cache: dict | None = None,
                 decode: bool = False):
    """x [B,S,d_model] -> (y, new_cache).  Cache: conv [B,K-1,di], ssm [B,di,N]."""
    B, S, _ = x.shape
    di, N, R = cfg.d_inner_, cfg.ssm_state, cfg.dt_rank_
    xz = sl.apply(p["in_proj"], x, engine=cfg.engine)
    xs, z = jnp.split(xz, 2, axis=-1)

    conv_state = cache["conv"] if cache is not None else None
    xs, new_conv = _causal_conv(xs, p["conv_w"].astype(x.dtype),
                                p["conv_b"].astype(x.dtype), conv_state)
    xs = jax.nn.silu(xs)

    dbc = sl.apply_dense(p["x_proj"], xs)
    dt, Bc, Cc = jnp.split(dbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(sl.apply_dense(p["dt_proj"], dt).astype(jnp.float32))  # [B,S,di]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                               # [di,N]
    Bc = Bc.astype(jnp.float32)
    Cc = Cc.astype(jnp.float32)
    xf = xs.astype(jnp.float32)

    if decode:  # S == 1 recurrent step
        h_prev = cache["ssm"]                                   # [B,di,N]
        decay = jnp.exp(dt[:, 0, :, None] * A[None])            # [B,di,N]
        inp = (dt[:, 0, :, None] * Bc[:, 0, None, :]) * xf[:, 0, :, None]
        h = decay * h_prev + inp
        y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0])[:, None, :]
        new_ssm = h
    else:
        c = min(cfg.ssm_chunk, S)
        assert S % c == 0, f"seq {S} not divisible by ssm chunk {c}"
        nc = S // c
        scan_dt = jnp.dtype(cfg.ssm_scan_dtype)

        def chunk_step(h0, args):
            dt_c, B_c, C_c, x_c = args                           # [B,c,...]
            decay = jnp.exp(dt_c[..., None] * A[None, None])     # [B,c,di,N]
            inp = (dt_c[..., None] * B_c[:, :, None, :]) * x_c[..., None]
            # the [B,c,di,N] associative-scan elements dominate SSM-training
            # HBM traffic; bf16 here halves it, carry stays fp32 (§Perf F1)
            h, h_last = _ssm_chunk_scan(decay.astype(scan_dt),
                                        inp.astype(scan_dt),
                                        h0.astype(scan_dt))
            y = jnp.einsum("bcdn,bcn->bcd", h.astype(jnp.float32), C_c)
            return h_last.astype(jnp.float32), y

        if cfg.remat:
            chunk_step = jax.checkpoint(chunk_step)
        h0 = (cache["ssm"].astype(jnp.float32) if cache is not None
              else jnp.zeros((B, di, N), jnp.float32))
        resh = lambda t: t.reshape(B, nc, c, *t.shape[2:]).swapaxes(0, 1)
        h_last, ys = jax.lax.scan(
            chunk_step, h0, (resh(dt), resh(Bc), resh(Cc), resh(xf)))
        y = ys.swapaxes(0, 1).reshape(B, S, di)
        new_ssm = h_last

    y = y + p["D"].astype(jnp.float32)[None, None] * xf
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = sl.apply(p["out_proj"], y, engine=cfg.engine)
    new_cache = ({"conv": new_conv, "ssm": new_ssm.astype(
        cache["ssm"].dtype if cache is not None else jnp.float32)}
        if (cache is not None or decode) else None)
    return out, new_cache


# ====================================================================
# Mamba-2 / SSD (scalar decay per head, matmul-form chunk algorithm)
# ====================================================================
def mamba2_init(key, cfg: ArchConfig, dtype=jnp.float32, seed: int = 0) -> Params:
    d, di, N = cfg.d_model, cfg.d_inner_, cfg.ssm_state
    H = cfg.ssm_heads
    ks = jax.random.split(key, 6)
    sp = cfg.sparsity
    # separate projections (z | x,B,C | dt) so every out-dim shards cleanly
    # on the model axis — a fused [d, 2di+2N+H] weight has split boundaries
    # that misalign with the shard grid and forces resharding per layer
    p: Params = {
        "in_z": sl.init_linear(ks[0], d, di, family="ffn", sp=sp,
                               dtype=dtype, seed=seed),
        "in_xbc": sl.init_linear(ks[3], d, di + 2 * N, family="ffn", sp=sp,
                                 dtype=dtype, seed=seed + 2),
        "in_dt": sl.init_dense(ks[4], d, H, dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (cfg.conv_width, di + 2 * N), dtype)
                  / float(np.sqrt(cfg.conv_width)),
        "conv_b": jnp.zeros((di + 2 * N,), dtype),
        "A_log": jnp.zeros((H,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "D": jnp.ones((H,), dtype),
        "out_proj": sl.init_linear(ks[2], di, d, family="ffn", sp=sp,
                                   dtype=dtype, seed=seed + 1),
    }
    return p


def mamba2_apply(p: Params, x, cfg: ArchConfig, cache: dict | None = None,
                 decode: bool = False):
    """SSD.  Cache: conv [B,K-1,di+2N], ssm [B,H,hd,N]."""
    B, S, _ = x.shape
    di, N = cfg.d_inner_, cfg.ssm_state
    H, hd = cfg.ssm_heads, cfg.ssm_head_dim
    z = sl.apply(p["in_z"], x, engine=cfg.engine)
    xbc = sl.apply(p["in_xbc"], x, engine=cfg.engine)
    dt = sl.apply_dense(p["in_dt"], x)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                                 p["conv_b"].astype(x.dtype), conv_state)
    xbc = jax.nn.silu(xbc)
    xs, Bc, Cc = jnp.split(xbc, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # [B,S,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                                      # [H]
    xh = xs.reshape(B, S, H, hd).astype(jnp.float32)
    Bf = Bc.astype(jnp.float32)                                                       # [B,S,N]
    Cf = Cc.astype(jnp.float32)

    if decode:
        h_prev = cache["ssm"].astype(jnp.float32)               # [B,H,hd,N]
        decay = jnp.exp(dt[:, 0] * A[None])                     # [B,H]
        inp = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0], xh[:, 0], Bf[:, 0])
        h = decay[..., None, None] * h_prev + inp
        y = jnp.einsum("bhpn,bn->bhp", h, Cf[:, 0]).reshape(B, 1, di)
        new_ssm = h
    else:
        c = min(cfg.ssm_chunk, S)
        assert S % c == 0
        nc = S // c

        def chunk_step(h0, args):
            dt_c, B_c, C_c, x_c = args       # [B,c,H] [B,c,N] [B,c,N] [B,c,H,hd]
            la = dt_c * A[None, None]        # log decay per step  [B,c,H]
            cum = jnp.cumsum(la, axis=1)     # [B,c,H]
            # intra-chunk: L[t,s] = exp(cum_t - cum_s - la_s ... ) using
            # h_t = sum_{s<=t} exp(cum_t - cum_s) dt_s B_s x_s
            diff = cum[:, :, None, :] - cum[:, None, :, :]       # [B,t,s,H]
            L = jnp.where(jnp.arange(c)[:, None] >= jnp.arange(c)[None, :],
                          jnp.exp(diff.transpose(0, 3, 1, 2)), 0.0)  # [B,H,t,s]
            G = jnp.einsum("btn,bsn->bts", C_c, B_c)             # [B,t,s]
            M = L * G[:, None]                                   # [B,H,t,s]
            y_intra = jnp.einsum("bhts,bsh,bshp->bthp", M, dt_c, x_c)
            # contribution of incoming state
            y_inter = jnp.einsum("bth,bhpn,btn->bthp", jnp.exp(cum), h0, C_c)
            # new state
            w = jnp.exp(cum[:, -1:, :] - cum)                    # decay to end
            h_new = (jnp.exp(cum[:, -1])[:, :, None, None] * h0
                     + jnp.einsum("bsh,bsh,bshp,bsn->bhpn", w, dt_c, x_c, B_c))
            return h_new, y_intra + y_inter

        if cfg.remat:
            chunk_step = jax.checkpoint(chunk_step)
        h0 = (cache["ssm"].astype(jnp.float32) if cache is not None
              else jnp.zeros((B, H, hd, N), jnp.float32))
        resh = lambda t: t.reshape(B, nc, c, *t.shape[2:]).swapaxes(0, 1)
        h_last, ys = jax.lax.scan(chunk_step, h0, (resh(dt), resh(Bf), resh(Cf), resh(xh)))
        y = ys.swapaxes(0, 1).reshape(B, S, H, hd).reshape(B, S, di)
        new_ssm = h_last

    if not decode:
        y = y + (p["D"].astype(jnp.float32)[None, None, :, None]
                 * xh.reshape(B, S, H, hd)).reshape(B, S, di)
    else:
        y = y + (p["D"].astype(jnp.float32)[None, :, None] * xh[:, 0]).reshape(B, 1, di)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = sl.apply(p["out_proj"], y, engine=cfg.engine)
    new_cache = ({"conv": new_conv, "ssm": new_ssm.astype(
        cache["ssm"].dtype if cache is not None else jnp.float32)}
        if (cache is not None or decode) else None)
    return out, new_cache
