"""Common model pieces: norms, rotary embeddings, token embedding, MLP.

Pure-functional: ``*_init(key, ...) -> params dict`` and ``*_apply``.
Compute runs in ``cfg.dtype`` (bf16), parameters live in ``param_dtype``
(fp32 master copies for the optimizer).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparse_linear as sl
from repro.configs.base import ArchConfig


# ------------------------------------------------------------------ norms
def norm_init(d: int, kind: str, dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(p, x, kind: str, eps: float):
    """f32 *accumulation* (reduction dtype), bf16 elementwise.

    Materializing ``x.astype(f32)`` looks equivalent, but under scan+remat
    XLA hoists that convert out of the backward loop, materializing an f32
    image of the whole [L, B, S, D] saved-carry stack (10 GiB for qwen2
    train — §Perf iteration C3).  Reduction-dtype accumulation keeps every
    full-size tensor bf16."""
    if kind == "layernorm":
        mu = jnp.mean(x, axis=-1, keepdims=True, dtype=jnp.float32)
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
        var = ms - jnp.square(mu)
        inv = jax.lax.rsqrt(var + eps)
        y = (x - mu.astype(x.dtype)) * inv.astype(x.dtype)
        y = y * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
        inv = jax.lax.rsqrt(ms + eps)
        y = x * inv.astype(x.dtype) * p["scale"].astype(x.dtype)
    return y.astype(x.dtype)


# ------------------------------------------------------------------ rotary
def rope(x: jax.Array, positions: jax.Array, theta: float,
         partial: float = 1.0) -> jax.Array:
    """x [..., S, H, D]; positions [..., S] (broadcastable).  Rotates the
    first ``partial * D`` dims (stablelm-style partial rotary)."""
    d = x.shape[-1]
    rot = int(d * partial)
    rot -= rot % 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = jnp.exp(-np.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs     # [..., S, half]
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)           # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = xr[..., :half], xr[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out, xp], axis=-1) if rot < d else out


def sinusoidal_pos(seq: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ------------------------------------------------------------------ embed
def embed_init(key, cfg: ArchConfig, dtype=jnp.float32):
    scale = float(1.0 / np.sqrt(cfg.d_model))
    p = {"tok": jax.random.normal(key, (cfg.vocab, cfg.d_model), dtype) * scale}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["out"] = jax.random.normal(k2, (cfg.d_model, cfg.vocab), dtype) * scale
    if cfg.family == "audio":  # learned decoder positions (whisper)
        k3 = jax.random.fold_in(key, 2)
        p["pos"] = jax.random.normal(k3, (cfg.max_seq, cfg.d_model), dtype) * 0.02
    return p


def embed_tokens(p, tokens, cfg: ArchConfig):
    return jnp.take(p["tok"], tokens, axis=0).astype(cfg.compute_dtype)


def unembed(p, x, cfg: ArchConfig):
    w = p["tok"].T if cfg.tie_embeddings else p["out"]
    return jnp.einsum("...d,dv->...v", x, w.astype(x.dtype))


# ------------------------------------------------------------------ MLP
def mlp_init(key, cfg: ArchConfig, d_ff: int | None = None, dtype=jnp.float32,
             seed: int = 0):
    """(Gated) MLP; projections become pre-defined-sparse when the paper's
    technique is enabled for the 'ffn' family."""
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    sp = cfg.sparsity
    p = {"wi": sl.init_linear(ks[0], d, f, family="ffn", sp=sp, dtype=dtype, seed=seed),
         "wo": sl.init_linear(ks[2], f, d, family="ffn", sp=sp, dtype=dtype, seed=seed + 1)}
    if cfg.act == "silu":
        p["wg"] = sl.init_linear(ks[1], d, f, family="ffn", sp=sp, dtype=dtype, seed=seed + 2)
    return p


def mlp_apply(p, x, cfg: ArchConfig):
    """The activation rides as a fused epilogue of the producing linear —
    on the Pallas engine it runs inside the kernel (the paper's FF-stage
    activation fused into the edge pipeline); on the jnp/dense paths it is
    the same formula applied after the matmul."""
    eng = cfg.engine
    if "wg" in p:
        g = sl.apply(p["wg"], x, engine=eng, act="silu")
        h = g * sl.apply(p["wi"], x, engine=eng)
    else:
        h = sl.apply(p["wi"], x, engine=eng, act="gelu")
    return sl.apply(p["wo"], h, engine=eng)
