"""Mixture-of-Experts with GShard-style capacity dispatch.

Tokens are processed in groups of ``group_size``; dispatch/combine tensors
are [G, g, E, C] einsums, so with experts sharded over the "model" axis
(EP) and groups over "data" the per-device footprint stays bounded and the
expert matmuls are dense MXU work.  Dropped tokens (over capacity) fall
through on the residual path — standard GShard semantics.

When the paper's pre-defined sparsity applies to the expert FFNs, one
block pattern (same junction shape) is shared by all experts with
per-expert weights — and the expert matmuls run through the unified
edge-bundle engine entry point ``kernels/ops.junction_matmul`` (the same
custom_vjp the dense-model junctions use, here with 5-D weights
[E, nob, kb, bs, bs] and grid (E, M/bm, nob/bn); ``wi=`` fuses the
SwiGLU gate into one pass) when ``ArchConfig.engine`` resolves to
"pallas".  The vmapped gather+einsum loop (``_expert_apply``) remains
the reference path and the path the dry-run FLOP accounting sees
(launch/dryrun.py pins engine="jnp").

Aux load-balance loss follows Switch/GShard: E * sum_e f_e * p_e.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import sparse_linear as sl
from repro.models.layers import mlp_apply, mlp_init

Params = dict[str, Any]


def moe_dispatch_dims(mo, T: int) -> tuple[int, int, int]:
    """(g, G, C) for T tokens: dispatch group size, group count, and the
    per-expert capacity (rounded up to a multiple of 4).  Single source of
    the capacity formula — benchmarks derive their metadata from it."""
    g = min(mo.group_size, T)
    G = T // g
    C = int(np.ceil(g * mo.top_k * mo.capacity_factor / mo.num_experts))
    C = max(4, -(-C // 4) * 4)
    return g, G, C


def _expert_sparse_ok(cfg: ArchConfig) -> bool:
    sp = cfg.sparsity
    return (sp is not None and sp.applies_to("ffn")
            and cfg.d_model % sp.block == 0 and cfg.moe.d_expert % sp.block == 0
            and cfg.d_model // sp.block >= 2 and cfg.moe.d_expert // sp.block >= 2)


def moe_init(key, cfg: ArchConfig, dtype=jnp.float32, seed: int = 0) -> Params:
    mo, d = cfg.moe, cfg.d_model
    E, F = mo.num_experts, mo.d_expert
    ks = jax.random.split(key, 7)
    scale_in = float(1.0 / np.sqrt(d))
    scale_out = float(1.0 / np.sqrt(F))
    p: Params = {"router": jax.random.normal(ks[0], (d, E), dtype) * scale_in}
    if _expert_sparse_ok(cfg):
        # the paper's technique on the expert FFNs: one block pattern shared
        # by all experts (same junction shape), per-expert weights
        from repro.core.sparsity import make_block_pattern
        sp = cfg.sparsity
        pat_in = make_block_pattern(d, F, sp.density, sp.block, seed=sp.seed)
        pat_out = make_block_pattern(F, d, sp.density, sp.block, seed=sp.seed + 1)
        s_in = float(np.sqrt(2.0 / ((pat_in.fan_in_blocks + pat_in.fan_out_blocks) * sp.block)))
        s_out = float(np.sqrt(2.0 / ((pat_out.fan_in_blocks + pat_out.fan_out_blocks) * sp.block)))
        shp_in = (E, pat_in.n_out_blocks, pat_in.fan_in_blocks, sp.block, sp.block)
        shp_out = (E, pat_out.n_out_blocks, pat_out.fan_in_blocks, sp.block, sp.block)
        p.update({
            "wi": jax.random.normal(ks[1], shp_in, dtype) * s_in,
            "wg": jax.random.normal(ks[2], shp_in, dtype) * s_in,
            "wo": jax.random.normal(ks[3], shp_out, dtype) * s_out,
            "idx_in": jnp.asarray(pat_in.idx),
            "idx_out": jnp.asarray(pat_out.idx),
            # reverse patterns for the Pallas engine's expert dx kernels
            # (static, non-trainable, shared by all experts like idx_*)
            "rev_in_ob": jnp.asarray(pat_in.rev_ob),
            "rev_in_t": jnp.asarray(pat_in.rev_t),
            "rev_in_cnt": jnp.asarray(pat_in.rev_cnt),
            "rev_out_ob": jnp.asarray(pat_out.rev_ob),
            "rev_out_t": jnp.asarray(pat_out.rev_t),
            "rev_out_cnt": jnp.asarray(pat_out.rev_cnt),
        })
    else:
        p.update({
            "wi": jax.random.normal(ks[1], (E, d, F), dtype) * scale_in,
            "wg": jax.random.normal(ks[2], (E, d, F), dtype) * scale_in,
            "wo": jax.random.normal(ks[3], (E, F, d), dtype) * scale_out,
        })
    if mo.num_shared:
        # d_shared is the *combined* hidden width of the always-on experts
        p["shared"] = mlp_init(ks[4], cfg, d_ff=mo.d_shared, dtype=dtype, seed=seed + 7)
    return p


def _expert_apply(w, idx, x):
    """Batched block-sparse expert matmul (jnp reference path):
    x [G,E,C,din] -> [G,E,C,dout].  Accumulates over fan-in slots to avoid
    the kb-times gather blow-up.  This is also the path the dry-run FLOP
    accounting sees (density-scaled einsums)."""
    E, nob, kb, bs, _ = w.shape
    G, _, C, din = x.shape
    xb = x.reshape(G, E, C, din // bs, bs)
    wc = w.astype(x.dtype)
    y = None
    for k in range(kb):
        xk = jnp.take(xb, idx[:, k], axis=3)          # [G,E,C,nob,bs]
        # slot k of every output block: wc[:, :, k] [E, nob, bs, bs] — the
        # seed sliced axis 1 (the *output-block* axis), which only shaped
        # up when nob == kb and silently transposed the weight layout
        part = jnp.einsum("GECob,Eobc->GECoc", xk, wc[:, :, k])
        y = part if y is None else y + part
    return y.reshape(G, E, C, nob * bs)


def _expert_ffn_pallas(p: Params, xd, E: int):
    """Expert FFN stack through the unified junction engine:
    xd [G,E,C,d] -> [G,E,C,d].  Both junctions go through the same
    ``junction_matmul`` custom_vjp the dense-model layers use — the gate
    (silu(x@wg) * (x@wi)) as ONE fused pass via ``wi=``, wo as the plain
    E-batched configuration.  When the fused-update context rides in the
    params dict (train/steps.py injection), both junctions run through
    ``junction_train_update`` instead: the per-expert weight gradients
    are consumed by the in-kernel optimizer epilogue (SGD+momentum, or
    Adam when the vel_* slots ride along) and the updated wg/wi/wo come
    back as their cotangents."""
    from repro.kernels import ops  # local import: kernels optional at runtime
    G, _, C, D = xd.shape
    xe = jnp.moveaxis(xd, 1, 0).reshape(E, G * C, D)
    if "wgq" in p:   # quantized experts (core/quantize.py): inference-only
        if sl.UPDATE_HYP_LEAF in p:
            raise ValueError("quantized expert FFN inside a fused train "
                             "step — the int8 datapath is inference-only")
        h = ops.junction_matmul(
            xe, p["wgq"], p["idx_in"],
            p["rev_in_ob"], p["rev_in_t"], p["rev_in_cnt"], wi=p["wiq"],
            w_scale=p["wg_scale"], wi_scale=p["wi_scale"],
            x_scale=p.get("x_scale_in"))
        ye = ops.junction_matmul(
            h, p["woq"], p["idx_out"],
            p["rev_out_ob"], p["rev_out_t"], p["rev_out_cnt"],
            w_scale=p["wo_scale"], x_scale=p.get("x_scale_out"))
        return jnp.moveaxis(ye.reshape(E, G, C, -1), 0, 1)
    if sl.UPDATE_HYP_LEAF in p:
        hyp = p[sl.UPDATE_HYP_LEAF]
        h = ops.junction_train_update(
            xe, p["wg"], p["idx_in"],
            p["rev_in_ob"], p["rev_in_t"], p["rev_in_cnt"], wi=p["wi"],
            hyp=hyp, mom=p.get("mom_wg"), mom_wi=p.get("mom_wi"),
            vel=p.get("vel_wg"), vel_wi=p.get("vel_wi"),
            health=p.get("upd_health_in"))
        ye = ops.junction_train_update(
            h, p["wo"], p["idx_out"],
            p["rev_out_ob"], p["rev_out_t"], p["rev_out_cnt"],
            hyp=hyp, mom=p.get("mom_wo"), vel=p.get("vel_wo"),
            health=p.get("upd_health_out"))
        return jnp.moveaxis(ye.reshape(E, G, C, -1), 0, 1)
    h = ops.junction_matmul(
        xe, p["wg"], p["idx_in"],
        p["rev_in_ob"], p["rev_in_t"], p["rev_in_cnt"], wi=p["wi"])
    ye = ops.junction_matmul(
        h, p["wo"], p["idx_out"],
        p["rev_out_ob"], p["rev_out_t"], p["rev_out_cnt"])
    return jnp.moveaxis(ye.reshape(E, G, C, -1), 0, 1)


def moe_apply(p: Params, x, cfg: ArchConfig):
    """x [B,S,D] -> (y, aux_loss).  The expert matmuls run through the
    engine ``ArchConfig.engine`` resolves to: "pallas" selects the
    expert-batched fused kernels, "jnp" the reference gather+einsum loop."""
    mo = cfg.moe
    B, S, D = x.shape
    E, K = mo.num_experts, mo.top_k
    T = B * S
    g, G, C = moe_dispatch_dims(mo, T)
    assert T % g == 0, f"tokens {T} not divisible by moe group {g}"

    xt = x.reshape(G, g, D)
    logits = jnp.einsum("Ggd,de->Gge", xt, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)      # [G,g,E]
    top_p, top_e = jax.lax.top_k(probs, K)                           # [G,g,K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)           # renorm

    # position-in-expert via cumsum over tokens (slot k-major then token)
    mask = jax.nn.one_hot(top_e, E, dtype=jnp.float32)               # [G,g,K,E]
    mask_flat = mask.transpose(0, 2, 1, 3).reshape(G, K * g, E)      # k-major
    pos = jnp.cumsum(mask_flat, axis=1) - 1.0                        # [G,Kg,E]
    keep = (pos < C) * mask_flat
    pos = pos.reshape(G, K, g, E).transpose(0, 2, 1, 3)              # [G,g,K,E]
    keep = keep.reshape(G, K, g, E).transpose(0, 2, 1, 3)

    # aux load-balance loss (fraction routed vs mean prob), Switch-style
    f_e = jnp.mean(mask[..., 0, :] if K == 1 else jnp.sum(mask, axis=2), axis=(0, 1)) / K
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f_e * p_e) * mo.aux_loss_weight

    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)  # [G,g,K,E,C]
    dispatch = jnp.einsum("GgKE,GgKEC->GgEC", keep, pos_oh)
    combine = jnp.einsum("GgK,GgKE,GgKEC->GgEC", top_p, keep, pos_oh)

    xd = jnp.einsum("GgEC,Ggd->GECd", dispatch.astype(x.dtype), xt)
    if "idx_in" in p:   # pre-defined-sparse experts (the paper's technique)
        if sl.resolve_engine(cfg.engine) == "pallas":
            ye = _expert_ffn_pallas(p, xd, E)
        elif "wgq" in p:   # quantized experts, jnp twin of the int8 kernels
            from repro.core import quantize as qz
            gq = qz.expert_apply_int8(p["wgq"], p["wg_scale"], p["idx_in"],
                                      xd, p.get("x_scale_in"))
            uq = qz.expert_apply_int8(p["wiq"], p["wi_scale"], p["idx_in"],
                                      xd, p.get("x_scale_in"))
            h = (jax.nn.silu(gq) * uq).astype(x.dtype)
            ye = qz.expert_apply_int8(p["woq"], p["wo_scale"], p["idx_out"],
                                      h, p.get("x_scale_out")).astype(x.dtype)
        else:
            h = (jax.nn.silu(_expert_apply(p["wg"], p["idx_in"], xd))
                 * _expert_apply(p["wi"], p["idx_in"], xd))
            ye = _expert_apply(p["wo"], p["idx_out"], h)
    else:
        h = (jax.nn.silu(jnp.einsum("GECd,Edf->GECf", xd, p["wg"].astype(x.dtype)))
             * jnp.einsum("GECd,Edf->GECf", xd, p["wi"].astype(x.dtype)))
        ye = jnp.einsum("GECf,Efd->GECd", h, p["wo"].astype(x.dtype))
    y = jnp.einsum("GgEC,GECd->Ggd", combine.astype(x.dtype), ye)
    y = y.reshape(B, S, D)

    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, cfg)
    return y, aux
