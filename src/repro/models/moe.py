"""Mixture-of-Experts with GShard-style capacity dispatch.

Tokens are processed in groups of ``group_size``; dispatch/combine tensors
are [G, g, E, C] einsums, so with experts sharded over the "model" axis
(EP) and groups over "data" the per-device footprint stays bounded and the
expert matmuls are dense MXU work.  Dropped tokens (over capacity) fall
through on the residual path — standard GShard semantics.

Aux load-balance loss follows Switch/GShard: E * sum_e f_e * p_e.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import sparse_linear as sl
from repro.models.layers import mlp_apply, mlp_init

Params = dict[str, Any]


def _expert_sparse_ok(cfg: ArchConfig) -> bool:
    sp = cfg.sparsity
    return (sp is not None and sp.applies_to("ffn")
            and cfg.d_model % sp.block == 0 and cfg.moe.d_expert % sp.block == 0
            and cfg.d_model // sp.block >= 2 and cfg.moe.d_expert // sp.block >= 2)


def moe_init(key, cfg: ArchConfig, dtype=jnp.float32, seed: int = 0) -> Params:
    mo, d = cfg.moe, cfg.d_model
    E, F = mo.num_experts, mo.d_expert
    ks = jax.random.split(key, 7)
    scale_in = float(1.0 / np.sqrt(d))
    scale_out = float(1.0 / np.sqrt(F))
    p: Params = {"router": jax.random.normal(ks[0], (d, E), dtype) * scale_in}
    if _expert_sparse_ok(cfg):
        # the paper's technique on the expert FFNs: one block pattern shared
        # by all experts (same junction shape), per-expert weights
        from repro.core.sparsity import make_block_pattern
        sp = cfg.sparsity
        pat_in = make_block_pattern(d, F, sp.density, sp.block, seed=sp.seed)
        pat_out = make_block_pattern(F, d, sp.density, sp.block, seed=sp.seed + 1)
        s_in = float(np.sqrt(2.0 / ((pat_in.fan_in_blocks + pat_in.fan_out_blocks) * sp.block)))
        s_out = float(np.sqrt(2.0 / ((pat_out.fan_in_blocks + pat_out.fan_out_blocks) * sp.block)))
        shp_in = (E, pat_in.n_out_blocks, pat_in.fan_in_blocks, sp.block, sp.block)
        shp_out = (E, pat_out.n_out_blocks, pat_out.fan_in_blocks, sp.block, sp.block)
        p.update({
            "wi": jax.random.normal(ks[1], shp_in, dtype) * s_in,
            "wg": jax.random.normal(ks[2], shp_in, dtype) * s_in,
            "wo": jax.random.normal(ks[3], shp_out, dtype) * s_out,
            "idx_in": jnp.asarray(pat_in.idx),
            "idx_out": jnp.asarray(pat_out.idx),
        })
    else:
        p.update({
            "wi": jax.random.normal(ks[1], (E, d, F), dtype) * scale_in,
            "wg": jax.random.normal(ks[2], (E, d, F), dtype) * scale_in,
            "wo": jax.random.normal(ks[3], (E, F, d), dtype) * scale_out,
        })
    if mo.num_shared:
        # d_shared is the *combined* hidden width of the always-on experts
        p["shared"] = mlp_init(ks[4], cfg, d_ff=mo.d_shared, dtype=dtype, seed=seed + 7)
    return p


def _expert_apply(w, idx, x):
    """Batched block-sparse expert matmul: x [G,E,C,din] -> [G,E,C,dout].
    Accumulates over fan-in slots to avoid the kb-times gather blow-up."""
    E, nob, kb, bs, _ = w.shape
    G, _, C, din = x.shape
    xb = x.reshape(G, E, C, din // bs, bs)
    wc = w.astype(x.dtype)
    y = None
    for k in range(kb):
        xk = jnp.take(xb, idx[:, k], axis=3)          # [G,E,C,nob,bs]
        part = jnp.einsum("GECob,Eobc->GECoc", xk, wc[:, k])
        y = part if y is None else y + part
    return y.reshape(G, E, C, nob * bs)


def moe_apply(p: Params, x, cfg: ArchConfig):
    """x [B,S,D] -> (y, aux_loss)."""
    mo = cfg.moe
    B, S, D = x.shape
    E, K = mo.num_experts, mo.top_k
    T = B * S
    g = min(mo.group_size, T)
    assert T % g == 0, f"tokens {T} not divisible by moe group {g}"
    G = T // g
    C = int(np.ceil(g * K * mo.capacity_factor / E))
    C = max(4, -(-C // 4) * 4)  # round up to a multiple of 4

    xt = x.reshape(G, g, D)
    logits = jnp.einsum("Ggd,de->Gge", xt, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)      # [G,g,E]
    top_p, top_e = jax.lax.top_k(probs, K)                           # [G,g,K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)           # renorm

    # position-in-expert via cumsum over tokens (slot k-major then token)
    mask = jax.nn.one_hot(top_e, E, dtype=jnp.float32)               # [G,g,K,E]
    mask_flat = mask.transpose(0, 2, 1, 3).reshape(G, K * g, E)      # k-major
    pos = jnp.cumsum(mask_flat, axis=1) - 1.0                        # [G,Kg,E]
    keep = (pos < C) * mask_flat
    pos = pos.reshape(G, K, g, E).transpose(0, 2, 1, 3)              # [G,g,K,E]
    keep = keep.reshape(G, K, g, E).transpose(0, 2, 1, 3)

    # aux load-balance loss (fraction routed vs mean prob), Switch-style
    f_e = jnp.mean(mask[..., 0, :] if K == 1 else jnp.sum(mask, axis=2), axis=(0, 1)) / K
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f_e * p_e) * mo.aux_loss_weight

    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)  # [G,g,K,E,C]
    dispatch = jnp.einsum("GgKE,GgKEC->GgEC", keep, pos_oh)
    combine = jnp.einsum("GgK,GgKE,GgKEC->GgEC", top_p, keep, pos_oh)

    xd = jnp.einsum("GgEC,Ggd->GECd", dispatch.astype(x.dtype), xt)
    if "idx_in" in p:   # pre-defined-sparse experts (the paper's technique)
        h = (jax.nn.silu(_expert_apply(p["wg"], p["idx_in"], xd))
             * _expert_apply(p["wi"], p["idx_in"], xd))
        ye = _expert_apply(p["wo"], p["idx_out"], h)
    else:
        h = (jax.nn.silu(jnp.einsum("GECd,Edf->GECf", xd, p["wg"].astype(x.dtype)))
             * jnp.einsum("GECd,Edf->GECf", xd, p["wi"].astype(x.dtype)))
        ye = jnp.einsum("GECf,Efd->GECd", h, p["wo"].astype(x.dtype))
    y = jnp.einsum("GgEC,GECd->Ggd", combine.astype(x.dtype), ye)
    y = y.reshape(B, S, D)

    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, cfg)
    return y, aux
