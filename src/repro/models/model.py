"""Model builder: one entry point for all assigned architectures.

``init(cfg, key)``            -> params pytree (fp32 masters, stacked layers)
``forward(cfg, params, batch)``-> (logits, aux) for training
``prefill(cfg, params, batch)``-> (last_logits, cache)
``decode_step(cfg, params, cache, token, pos)`` -> (logits, cache)
``make_cache(cfg, batch, seq)``-> zeroed cache pytree (decode dry-run spec)

Repeated layers are stacked on a leading axis and driven by ``lax.scan`` so
the lowered HLO is O(1) in depth (critical for the 512-device dry-run), with
``jax.checkpoint`` around the block body as the baseline remat policy.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (embed_init, embed_tokens, mlp_apply,
                                 mlp_init, norm_apply, norm_init,
                                 sinusoidal_pos, unembed)
from repro.parallel import hints

Params = dict[str, Any]


# ===================================================================== init
def _block_init(key, cfg: ArchConfig, dtype, kind: str):
    ks = jax.random.split(key, 4)
    if kind == "attn_mlp":
        return {"norm1": norm_init(cfg.d_model, cfg.norm, dtype),
                "attn": attn.attn_init(ks[0], cfg, dtype),
                "norm2": norm_init(cfg.d_model, cfg.norm, dtype),
                "mlp": mlp_init(ks[1], cfg, dtype=dtype)}
    if kind == "attn_moe":
        return {"norm1": norm_init(cfg.d_model, cfg.norm, dtype),
                "attn": attn.attn_init(ks[0], cfg, dtype),
                "norm2": norm_init(cfg.d_model, cfg.norm, dtype),
                "moe": moe_mod.moe_init(ks[1], cfg, dtype)}
    if kind == "mamba1":
        return {"norm": norm_init(cfg.d_model, cfg.norm, dtype),
                "ssm": ssm_mod.mamba1_init(ks[0], cfg, dtype)}
    if kind == "mamba2":
        return {"norm": norm_init(cfg.d_model, cfg.norm, dtype),
                "ssm": ssm_mod.mamba2_init(ks[0], cfg, dtype)}
    if kind == "enc":
        return {"norm1": norm_init(cfg.d_model, cfg.norm, dtype),
                "attn": attn.attn_init(ks[0], cfg, dtype),
                "norm2": norm_init(cfg.d_model, cfg.norm, dtype),
                "mlp": mlp_init(ks[1], cfg, dtype=dtype)}
    if kind == "dec":
        return {"norm1": norm_init(cfg.d_model, cfg.norm, dtype),
                "attn": attn.attn_init(ks[0], cfg, dtype),
                "norm_x": norm_init(cfg.d_model, cfg.norm, dtype),
                "cross": attn.attn_init(ks[1], cfg, dtype, cross=True),
                "norm2": norm_init(cfg.d_model, cfg.norm, dtype),
                "mlp": mlp_init(ks[2], cfg, dtype=dtype)}
    raise ValueError(kind)


def init(cfg: ArchConfig, key) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_layers, k_extra = jax.random.split(key, 3)
    params: Params = {"embed": embed_init(k_emb, cfg, dtype),
                      "final_norm": norm_init(cfg.d_model, cfg.norm, dtype)}

    def stacked(k, n, kind):
        return jax.vmap(lambda kk: _block_init(kk, cfg, dtype, kind))(
            jax.random.split(k, n))

    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["layers"] = stacked(k_layers, cfg.n_layers, "attn_mlp")
    elif fam == "moe":
        nd = cfg.moe.first_dense_layers
        if nd:
            params["dense_layers"] = stacked(k_extra, nd, "attn_mlp")
        params["layers"] = stacked(k_layers, cfg.n_layers - nd, "attn_moe")
    elif fam == "ssm":
        params["layers"] = stacked(k_layers, cfg.n_layers, "mamba1")
    elif fam == "hybrid":
        ev = cfg.hybrid_attn_every
        n_super = cfg.n_layers // ev
        ks = jax.random.split(k_layers, n_super)
        inner = jax.vmap(lambda kk: jax.vmap(
            lambda k2: _block_init(k2, cfg, dtype, "mamba2"))(
                jax.random.split(kk, ev)))(ks)
        params["layers"] = inner                      # [n_super, ev, ...]
        params["shared_attn"] = _block_init(k_extra, cfg, dtype, "attn_mlp")
    elif fam == "audio":
        params["layers"] = stacked(k_layers, cfg.n_layers, "dec")
        params["encoder"] = {
            "layers": stacked(k_extra, cfg.enc_layers, "enc"),
            "norm": norm_init(cfg.d_model, cfg.norm, dtype)}
    else:
        raise ValueError(fam)
    return params


# ============================================================= block applies
def _attn_mlp_block(lp, x, cfg: ArchConfig, positions, cache=None, pos=None,
                    decode=False, kv_override=None):
    """Standard decoder block.  Returns (x, new_cache)."""
    h = norm_apply(lp["norm1"], x, cfg.norm, cfg.norm_eps)
    if decode:
        if cfg.attn_kind == "mla":
            a, new_cache = attn.mla_decode(lp["attn"], h, cfg, cache, pos)
        else:
            a, new_cache = attn.gqa_decode(lp["attn"], h, cfg, cache, pos)
    else:
        if cfg.attn_kind == "mla":
            a, kv = attn.mla_forward(lp["attn"], h, cfg, positions=positions)
            new_cache = {"latent": kv[0], "k_rope": kv[1]}
        else:
            a, kv = attn.gqa_forward(lp["attn"], h, cfg, positions=positions,
                                     kv_override=kv_override)
            new_cache = {"k": kv[0], "v": kv[1]}
    x = x + a
    h = norm_apply(lp["norm2"], x, cfg.norm, cfg.norm_eps)
    if "moe" in lp:
        m, aux = moe_mod.moe_apply(lp["moe"], h, cfg)
    else:
        m, aux = mlp_apply(lp["mlp"], h, cfg), 0.0
    return x + m, new_cache, aux


def _enc_block(lp, x, cfg: ArchConfig):
    h = norm_apply(lp["norm1"], x, cfg.norm, cfg.norm_eps)
    a, _ = attn.gqa_forward(lp["attn"], h, cfg,
                            positions=jnp.arange(x.shape[1]), causal=False)
    x = x + a
    h = norm_apply(lp["norm2"], x, cfg.norm, cfg.norm_eps)
    return x + mlp_apply(lp["mlp"], h, cfg)


def _dec_block(lp, x, cfg: ArchConfig, positions, enc_kv=None, cache=None,
               pos=None, decode=False):
    """Whisper decoder block: self-attn + cross-attn + mlp."""
    h = norm_apply(lp["norm1"], x, cfg.norm, cfg.norm_eps)
    if decode:
        a, self_cache = attn.gqa_decode(lp["attn"], h, cfg,
                                        {"k": cache["k"], "v": cache["v"]}, pos)
    else:
        a, kv = attn.gqa_forward(lp["attn"], h, cfg, positions=positions)
        self_cache = {"k": kv[0], "v": kv[1]}
    x = x + a
    h = norm_apply(lp["norm_x"], x, cfg.norm, cfg.norm_eps)
    if decode:
        c, _ = attn.gqa_decode(lp["cross"], h, cfg,
                               {"k": cache["ck"], "v": cache["cv"]}, pos,
                               cross=True)
        cross_kv = (cache["ck"], cache["cv"])
    else:
        ck = attn._split_heads(
            jax.numpy.einsum("bsd,df->bsf", enc_kv, lp["cross"]["wk"]["w"].astype(h.dtype)),
            cfg.kv_heads, cfg.head_dim)
        cv = attn._split_heads(
            jax.numpy.einsum("bsd,df->bsf", enc_kv, lp["cross"]["wv"]["w"].astype(h.dtype)),
            cfg.kv_heads, cfg.head_dim)
        c, _ = attn.gqa_forward(lp["cross"], h, cfg, positions=positions,
                                kv_override=(ck, cv))
        cross_kv = (ck, cv)
    x = x + c
    h = norm_apply(lp["norm2"], x, cfg.norm, cfg.norm_eps)
    new_cache = {"k": self_cache["k"], "v": self_cache["v"],
                 "ck": cross_kv[0], "cv": cross_kv[1]}
    return x + mlp_apply(lp["mlp"], h, cfg), new_cache


# ============================================================= full forward
def _maybe_ckpt(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat else fn


def _scan_layers(fn, x, layer_params, cfg, with_cache=None):
    """scan fn over stacked layers; fn(x, lp, cache_i) -> (x, new_cache_i, aux)."""
    def body(carry, inp):
        x, aux_sum = carry
        lp, cache_i = inp
        x, new_cache, aux = fn(x, lp, cache_i)
        x = hints.constrain_tokens3d(x, cfg)   # store carry seq-sharded
        return (x, aux_sum + aux), new_cache
    body = _maybe_ckpt(body, cfg)
    (x, aux), caches = jax.lax.scan(body, (x, 0.0), (layer_params, with_cache))
    return x, caches, aux


def _scan_layers_inplace_cache(fn, x, layer_params, cfg, cache):
    """Decode-path layer scan: the cache rides in the scan *carry* and is
    updated in place per layer (dynamic-update-slice on the stacked dim).

    Passing the cache as scan xs/ys makes XLA allocate a second, stacked
    output cache — for decode_32k that doubles the resident KV bytes
    (§Perf iteration D1: deepseek-7b decode temp 20.8 -> ~4 GiB)."""
    L = jax.tree.leaves(layer_params)[0].shape[0]

    def body(carry, inp):
        x, cache = carry
        lp, i = inp
        ci = jax.tree.map(
            lambda t: jax.lax.dynamic_index_in_dim(t, i, 0, keepdims=False),
            cache)
        x, nc, _ = fn(x, lp, ci)
        cache = jax.tree.map(
            lambda t, u: jax.lax.dynamic_update_index_in_dim(
                t, u.astype(t.dtype), i, 0), cache, nc)
        return (x, cache), None

    (x, cache), _ = jax.lax.scan(body, (x, cache),
                                 (layer_params, jnp.arange(L)))
    return x, cache


def _embed_in(cfg: ArchConfig, params, batch, pos0: int = 0):
    """Token (+modality stub) embedding.  Returns (x, positions, text_offset)."""
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cfg)
    off = 0
    if cfg.family == "vlm" and "patches" in batch:
        patches = batch["patches"].astype(cfg.compute_dtype)
        x = jnp.concatenate([patches, x], axis=1)
        off = patches.shape[1]
    S = x.shape[1]
    positions = jnp.arange(pos0, pos0 + S)
    if cfg.family == "audio":
        x = x + jax.lax.dynamic_slice_in_dim(
            params["embed"]["pos"], pos0, S, 0).astype(x.dtype)[None]
    x = hints.constrain_tokens3d(x, cfg)   # anchor: (dp, seq?, None)
    return x, positions, off


def _encode_audio(cfg, params, frames):
    x = frames.astype(cfg.compute_dtype)
    x = x + sinusoidal_pos(x.shape[1], cfg.d_model, x.dtype)[None]
    def body(carry, lp):
        return _enc_block(lp, carry, cfg), None
    body = _maybe_ckpt(body, cfg)
    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    return norm_apply(params["encoder"]["norm"], x, cfg.norm, cfg.norm_eps)


def forward(cfg: ArchConfig, params: Params, batch, *, return_cache=False,
            last_only=False, return_hidden=False):
    """Training / prefill forward.  Returns (logits_or_hidden, cache, aux)."""
    x, positions, off = _embed_in(cfg, params, batch)
    fam = cfg.family
    caches = None
    aux = 0.0

    if fam in ("dense", "vlm", "moe"):
        def fn(x, lp, _):
            x, cache, aux = _attn_mlp_block(lp, x, cfg, positions)
            return x, (cache if return_cache else 0), aux
        if fam == "moe" and cfg.moe.first_dense_layers:
            dcaches = []
            for i in range(cfg.moe.first_dense_layers):
                lp = jax.tree.map(lambda t: t[i], params["dense_layers"])
                x, dc, _ = _attn_mlp_block(lp, x, cfg, positions)
                dcaches.append(dc)
        x, caches, aux = _scan_layers(fn, x, params["layers"], cfg)
        if fam == "moe" and cfg.moe.first_dense_layers and return_cache:
            dstack = jax.tree.map(lambda *t: jnp.stack(t), *dcaches)
            caches = {"dense": dstack, "moe": caches}
    elif fam == "ssm":
        def fn(x, lp, _):
            h = norm_apply(lp["norm"], x, cfg.norm, cfg.norm_eps)
            B = x.shape[0]
            zero = {"conv": jnp.zeros((B, cfg.conv_width - 1, cfg.d_inner_), x.dtype),
                    "ssm": jnp.zeros((B, cfg.d_inner_, cfg.ssm_state), jnp.float32)}
            y, cache = ssm_mod.mamba1_apply(lp["ssm"], h, cfg, cache=zero)
            return x + y, (cache if return_cache else 0), 0.0
        x, caches, aux = _scan_layers(fn, x, params["layers"], cfg)
    elif fam == "hybrid":
        shared = params["shared_attn"]
        di, N = cfg.d_inner_, cfg.ssm_state
        H2, hd2 = cfg.ssm_heads, cfg.ssm_head_dim
        def super_fn(carry, lp_super):
            x, aux_s = carry
            h = norm_apply(shared["norm1"], x, cfg.norm, cfg.norm_eps)
            a, kv = attn.gqa_forward(shared["attn"], h, cfg, positions=positions)
            x = x + a
            h = norm_apply(shared["norm2"], x, cfg.norm, cfg.norm_eps)
            x = x + mlp_apply(shared["mlp"], h, cfg)
            def inner(x, lp, _):
                h = norm_apply(lp["norm"], x, cfg.norm, cfg.norm_eps)
                B = x.shape[0]
                zero = {"conv": jnp.zeros((B, cfg.conv_width - 1, di + 2 * N), x.dtype),
                        "ssm": jnp.zeros((B, H2, hd2, N), jnp.float32)}
                y, cache = ssm_mod.mamba2_apply(lp["ssm"], h, cfg, cache=zero)
                return x + y, (cache if return_cache else 0), 0.0
            x, inner_caches, _ = _scan_layers(inner, x, lp_super, cfg)
            x = hints.constrain_tokens3d(x, cfg)
            out = ({"attn": {"k": kv[0], "v": kv[1]}, "ssm": inner_caches}
                   if return_cache else 0)
            return (x, aux_s), out
        (x, aux), caches = jax.lax.scan(super_fn, (x, 0.0), params["layers"])
    elif fam == "audio":
        enc_out = _encode_audio(cfg, params, batch["frames"])
        def fn(x, lp, _):
            x, cache = _dec_block(lp, x, cfg, positions, enc_kv=enc_out)
            return x, (cache if return_cache else 0), 0.0
        x, caches, aux = _scan_layers(fn, x, params["layers"], cfg)
    else:
        raise ValueError(fam)

    x = norm_apply(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    if return_hidden:
        return x, caches, (aux, off)
    logits = unembed(params["embed"], x, cfg)
    return logits, caches, (aux, off)


# ============================================================= decode step
def decode_step(cfg: ArchConfig, params: Params, cache, token, pos):
    """One serve step: token [B,1] int32, pos scalar int32.  Returns
    (logits [B,1,V], new_cache)."""
    x = embed_tokens(params["embed"], token, cfg)
    if cfg.family == "audio":
        x = x + jax.lax.dynamic_slice_in_dim(params["embed"]["pos"], pos, 1, 0
                                             ).astype(x.dtype)[None]
    fam = cfg.family
    positions = None

    if fam in ("dense", "vlm", "moe"):
        def fn(x, lp, cache_i):
            x, nc, aux = _attn_mlp_block(lp, x, cfg, positions, cache=cache_i,
                                         pos=pos, decode=True)
            return x, nc, aux
        if fam == "moe" and cfg.moe.first_dense_layers:
            new_d = []
            for i in range(cfg.moe.first_dense_layers):
                lp = jax.tree.map(lambda t: t[i], params["dense_layers"])
                ci = jax.tree.map(lambda t: t[i], cache["dense"])
                x, nc, _ = _attn_mlp_block(lp, x, cfg, positions, cache=ci,
                                           pos=pos, decode=True)
                new_d.append(nc)
            x, moe_cache = _scan_layers_inplace_cache(
                fn, x, params["layers"], cfg, cache["moe"])
            new_cache = {"dense": jax.tree.map(lambda *t: jnp.stack(t), *new_d),
                         "moe": moe_cache}
        else:
            x, new_cache = _scan_layers_inplace_cache(
                fn, x, params["layers"], cfg, cache)
    elif fam == "ssm":
        def fn(x, lp, cache_i):
            h = norm_apply(lp["norm"], x, cfg.norm, cfg.norm_eps)
            y, nc = ssm_mod.mamba1_apply(lp["ssm"], h, cfg, cache=cache_i,
                                         decode=True)
            return x + y, nc, 0.0
        x, new_cache = _scan_layers_inplace_cache(
            fn, x, params["layers"], cfg, cache)
    elif fam == "hybrid":
        shared = params["shared_attn"]
        ns = jax.tree.leaves(params["layers"])[0].shape[0]

        def super_fn(carry, inp):
            x, cache = carry
            lp_super, i = inp
            ci = jax.tree.map(
                lambda t: jax.lax.dynamic_index_in_dim(t, i, 0, keepdims=False),
                cache)
            h = norm_apply(shared["norm1"], x, cfg.norm, cfg.norm_eps)
            a, ac = attn.gqa_decode(shared["attn"], h, cfg, ci["attn"], pos)
            x = x + a
            h = norm_apply(shared["norm2"], x, cfg.norm, cfg.norm_eps)
            x = x + mlp_apply(shared["mlp"], h, cfg)
            def inner(x, lp, cci):
                h = norm_apply(lp["norm"], x, cfg.norm, cfg.norm_eps)
                y, nc = ssm_mod.mamba2_apply(lp["ssm"], h, cfg, cache=cci,
                                             decode=True)
                return x + y, nc, 0.0
            x, ssm_cache = _scan_layers_inplace_cache(
                inner, x, lp_super, cfg, ci["ssm"])
            new_ci = {"attn": ac, "ssm": ssm_cache}
            cache = jax.tree.map(
                lambda t, u: jax.lax.dynamic_update_index_in_dim(
                    t, u.astype(t.dtype), i, 0), cache, new_ci)
            return (x, cache), None

        (x, new_cache), _ = jax.lax.scan(
            super_fn, (x, cache), (params["layers"], jnp.arange(ns)))
    elif fam == "audio":
        def fn(x, lp, cache_i):
            x, nc = _dec_block(lp, x, cfg, positions, cache=cache_i, pos=pos,
                               decode=True)
            return x, nc, 0.0
        x, new_cache = _scan_layers_inplace_cache(
            fn, x, params["layers"], cfg, cache)
    else:
        raise ValueError(fam)

    x = norm_apply(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg)
    return logits, new_cache


# ============================================================= paged decode
def paged_supported(cfg: ArchConfig) -> tuple[bool, str]:
    """(ok, reason) — whether the continuous-batching paged-KV decode
    path can serve this config.  Families whose cache carries same-shape
    state leaves (ssm/hybrid conv+ssm state, audio cross-attn KV) and
    the non-GQA cache layouts (MLA latent, sliding ring buffer) stay on
    the static step-locked engine."""
    if cfg.family not in ("dense", "moe"):
        return False, (f"family {cfg.family!r} carries non-seq cache state "
                       "(see cache_seq_axes) — static engine only")
    if cfg.attn_kind != "full":
        return False, (f"attn_kind {cfg.attn_kind!r} — paged decode covers "
                       "the full-attention GQA cache layout")
    if cfg.family == "moe" and cfg.moe.first_dense_layers:
        return False, "moe first_dense_layers splits the cache tree"
    return True, "paged"


def make_paged_cache(cfg: ArchConfig, num_pages: int, page_size: int):
    """Zeroed block-paged KV pool: every seq-axis cache leaf (per
    ``cache_seq_axes``) [L, B, S, ...] becomes a pool [L, P, ps, ...] —
    memory scales with the page budget (tokens-in-flight), not
    batch x max_len.  Slot state (page tables, lengths) lives outside
    the tree, in the serve engine."""
    ok, why = paged_supported(cfg)
    if not ok:
        raise ValueError(f"paged cache unsupported: {why}")
    axes = cache_seq_axes(cfg)
    template = make_cache(cfg, 1, 1)

    def mk(ax, t):
        assert ax == 2, (ax, t.shape)
        return jnp.zeros((t.shape[0], num_pages, page_size) + t.shape[3:],
                         t.dtype)

    return jax.tree.map(mk, axes, template)


def _attn_block_paged(lp, x, cfg: ArchConfig, cache_i, positions, page_table,
                      *, decode: bool):
    """Paged twin of _attn_mlp_block: attention through the paged pool
    slice, FFN/MoE unchanged.  Returns (x, new_cache_i, aux)."""
    h = norm_apply(lp["norm1"], x, cfg.norm, cfg.norm_eps)
    if decode:
        a, new_cache = attn.gqa_decode_paged(lp["attn"], h, cfg, cache_i,
                                             positions, page_table)
    else:
        a, new_cache = attn.gqa_prefill_paged(lp["attn"], h, cfg, cache_i,
                                              positions, page_table)
    x = x + a
    h = norm_apply(lp["norm2"], x, cfg.norm, cfg.norm_eps)
    if "moe" in lp:
        m, aux = moe_mod.moe_apply(lp["moe"], h, cfg)
    else:
        m, aux = mlp_apply(lp["mlp"], h, cfg), 0.0
    return x + m, new_cache, aux


def paged_decode_step(cfg: ArchConfig, params: Params, pool, token, positions,
                      page_table):
    """One continuous-batching decode tick: token [B,1] int32, positions
    [B] int32 (per-slot write position — the scalar ``S + i`` of the
    step-locked path replaced by per-slot counters), page_table
    [B, maxp] int32.  Returns (logits [B,1,V], new_pool).  All shapes
    are fixed: slot refills and page-table swaps change data only, so
    the tick compiles exactly once."""
    ok, why = paged_supported(cfg)
    if not ok:
        raise ValueError(f"paged decode unsupported: {why}")
    x = embed_tokens(params["embed"], token, cfg)

    def fn(x, lp, ci):
        return _attn_block_paged(lp, x, cfg, ci, positions, page_table,
                                 decode=True)

    x, pool = _scan_layers_inplace_cache(fn, x, params["layers"], cfg, pool)
    x = norm_apply(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg)
    return logits, pool


def paged_prefill_chunk(cfg: ArchConfig, params: Params, pool, tokens, base,
                        page_table_row, chunk_len):
    """Prefill one fixed-size chunk of ONE slot's prompt into the paged
    pool: tokens [1, C] (tail-padded past ``chunk_len``), base scalar
    int32 (absolute position of tokens[0]), page_table_row [maxp].
    Returns (last_logits [1,1,V], new_pool) where last_logits is taken
    at the chunk's final valid position — the seed logits once the last
    chunk lands.  Fixed [1, C] shape: a long prompt becomes several
    chunk calls interleaved with decode ticks instead of one batch-wide
    stall."""
    ok, why = paged_supported(cfg)
    if not ok:
        raise ValueError(f"paged prefill unsupported: {why}")
    C = tokens.shape[1]
    x = embed_tokens(params["embed"], tokens, cfg)
    positions = base + jnp.arange(C)
    pt = page_table_row[None, :]

    def fn(x, lp, ci):
        return _attn_block_paged(lp, x, cfg, ci, positions, pt, decode=False)

    x, pool = _scan_layers_inplace_cache(fn, x, params["layers"], cfg, pool)
    x = norm_apply(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    last = jax.lax.dynamic_slice_in_dim(x, chunk_len - 1, 1, axis=1)
    logits = unembed(params["embed"], last, cfg)
    return logits, pool


# ============================================================= cache specs
def make_cache(cfg: ArchConfig, batch: int, seq: int):
    """Zeroed cache pytree for decode (dry-run ShapeDtypeStruct source)."""
    dt = cfg.compute_dtype
    L = cfg.n_layers
    fam = cfg.family
    if fam in ("dense", "vlm"):
        S = min(seq, cfg.window) if cfg.attn_kind == "sliding" else seq
        kv = lambda: jnp.zeros((L, batch, S, cfg.kv_heads, cfg.head_dim), dt)
        return {"k": kv(), "v": kv()}
    if fam == "moe":
        nd = cfg.moe.first_dense_layers
        if cfg.attn_kind == "mla":
            m = cfg.mla
            mk = lambda n: {"latent": jnp.zeros((n, batch, seq, m.kv_lora_rank), dt),
                            "k_rope": jnp.zeros((n, batch, seq, m.qk_rope_head_dim), dt)}
        else:
            mk = lambda n: {"k": jnp.zeros((n, batch, seq, cfg.kv_heads, cfg.head_dim), dt),
                            "v": jnp.zeros((n, batch, seq, cfg.kv_heads, cfg.head_dim), dt)}
        if nd:
            return {"dense": mk(nd), "moe": mk(L - nd)}
        return mk(L)
    if fam == "ssm":
        return {"conv": jnp.zeros((L, batch, cfg.conv_width - 1, cfg.d_inner_), dt),
                "ssm": jnp.zeros((L, batch, cfg.d_inner_, cfg.ssm_state), jnp.float32)}
    if fam == "hybrid":
        ev = cfg.hybrid_attn_every
        ns = cfg.n_layers // ev
        return {"attn": {"k": jnp.zeros((ns, batch, seq, cfg.kv_heads, cfg.head_dim), dt),
                         "v": jnp.zeros((ns, batch, seq, cfg.kv_heads, cfg.head_dim), dt)},
                "ssm": {"conv": jnp.zeros((ns, ev, batch, cfg.conv_width - 1,
                                           cfg.d_inner_ + 2 * cfg.ssm_state), dt),
                        "ssm": jnp.zeros((ns, ev, batch, cfg.ssm_heads,
                                          cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)}}
    if fam == "audio":
        return {"k": jnp.zeros((L, batch, seq, cfg.kv_heads, cfg.head_dim), dt),
                "v": jnp.zeros((L, batch, seq, cfg.kv_heads, cfg.head_dim), dt),
                "ck": jnp.zeros((L, batch, cfg.enc_frames, cfg.kv_heads, cfg.head_dim), dt),
                "cv": jnp.zeros((L, batch, cfg.enc_frames, cfg.kv_heads, cfg.head_dim), dt)}
    raise ValueError(fam)


def cache_seq_axes(cfg: ArchConfig):
    """Per-leaf placement metadata mirroring ``make_cache``'s structure:
    the axis holding the sequence dimension for leaves that grow with
    decode capacity, or ``-1`` for same-shape state leaves (conv/ssm
    state, cross-attn KV) that are copied wholesale.  Consumed by
    serve/engine.Engine._grow_cache when re-homing a prefill cache — an
    explicit contract instead of guessing the seq dim from shapes."""
    SEQ, STATE = 2, -1
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return {"k": SEQ, "v": SEQ}
    if fam == "moe":
        if cfg.attn_kind == "mla":
            mk = lambda: {"latent": SEQ, "k_rope": SEQ}
        else:
            mk = lambda: {"k": SEQ, "v": SEQ}
        if cfg.moe.first_dense_layers:
            return {"dense": mk(), "moe": mk()}
        return mk()
    if fam == "ssm":
        return {"conv": STATE, "ssm": STATE}
    if fam == "hybrid":
        return {"attn": {"k": SEQ, "v": SEQ},
                "ssm": {"conv": STATE, "ssm": STATE}}
    if fam == "audio":
        return {"k": SEQ, "v": SEQ, "ck": STATE, "cv": STATE}
    raise ValueError(fam)


# ============================================================= loss
def softmax_xent(logits, labels):
    """Vocab-sharding-friendly CE: label logit extracted by fused mask-sum
    (no [T,V] one-hot materialization)."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, len(lf.shape) - 1)
    ll = jnp.sum(jnp.where(iota == labels[..., None], lf, 0.0), axis=-1)
    return lse - ll


def loss_fn(cfg: ArchConfig, params: Params, batch):
    tokens = batch["tokens"]
    labels = tokens[:, 1:]
    T = labels.shape[1]
    chunk = cfg.loss_chunk
    if chunk:
        c = min(chunk, T)
        while T % c:        # largest divisor of T <= chunk (T=4095 -> 1365)
            c -= 1
        chunk = c if c > 1 else 0
    if not chunk:
        logits, _, (aux, off) = forward(cfg, params, batch)
        lg = logits[:, off:off + T] if off else logits[:, :-1]
        ce = jnp.mean(softmax_xent(lg, labels))
        return ce + aux, {"ce": ce, "aux": aux}

    # chunked CE: run the trunk once, unembed + CE per sequence chunk under
    # checkpoint so [tokens, vocab] logits never fully materialize (§Perf C2)
    hidden, _, (aux, off) = forward(cfg, params, batch, last_only=False,
                                    return_hidden=True)
    hs = hidden[:, off:off + T] if off else hidden[:, :-1]
    c = chunk
    nc = T // c
    B = hs.shape[0]
    hs = hs.reshape(B, nc, c, -1).swapaxes(0, 1)          # [nc, B, c, D]
    lb = labels.reshape(B, nc, c).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_ce(carry, inp):
        h, l = inp
        logits = unembed(params["embed"], h, cfg)
        return carry + jnp.sum(softmax_xent(logits, l)), None

    total, _ = jax.lax.scan(chunk_ce, jnp.zeros((), jnp.float32), (hs, lb))
    ce = total / (B * T)
    return ce + aux, {"ce": ce, "aux": aux}
