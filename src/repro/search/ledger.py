"""JSON results ledger for population sweeps: per-member lineage.

One record per candidate: its full config, which cohort/slot it trained
in, the per-step train-loss curve and per-round eval losses while live,
how many rounds it survived, and whether it won.  ``Ledger.save`` writes
a single stamped artifact (the sweep-side sibling of the BENCH_*.json
schema — same ``meta.tag`` contract as ``benchmarks/run.py --tag``) that
``Ledger.load`` round-trips, so sweep outcomes are machine-comparable
across PRs like the perf trajectory is.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro.artifacts import artifact_meta


def make_meta(tag: str = "") -> dict:
    """The ONE artifact stamp (repro.artifacts) — identical schema to
    BENCH_*.json meta, so sweep and bench artifacts are equally
    commit-attributable."""
    return artifact_meta(tag)


@dataclasses.dataclass
class MemberRecord:
    member: int                 # caller-side candidate index
    config: dict                # CandidateSpec.to_dict()
    cohort: int                 # cohort index (bucket order)
    slot: int                   # population slot within the cohort
    loss_curve: list = dataclasses.field(default_factory=list)
    eval_losses: list = dataclasses.field(default_factory=list)
    rounds_survived: int = 0
    pruned_at: Optional[int] = None   # round index, None = never pruned
    # {"round": r, "step": global_step} when the scheduler quarantined the
    # member MID-round for diverging (non-finite loss / in-kernel health
    # flag) — fault isolation, distinct from rank-based pruning (which
    # only happens at round boundaries and leaves pruned_at alone)
    quarantined_at: Optional[dict] = None
    winner: bool = False

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class Ledger:
    def __init__(self, meta: dict | None = None,
                 members: list[MemberRecord] | None = None):
        self.meta = meta or {}
        self.members = members or []

    def add(self, record: MemberRecord) -> MemberRecord:
        self.members.append(record)
        return record

    def winner(self) -> MemberRecord | None:
        for m in self.members:
            if m.winner:
                return m
        return None

    def survivors(self) -> list[MemberRecord]:
        return [m for m in self.members if m.pruned_at is None]

    def to_dict(self) -> dict:
        w = self.winner()
        return {
            "meta": self.meta,
            "members": [m.to_dict() for m in self.members],
            "winner": w.to_dict() if w is not None else None,
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "Ledger":
        with open(path) as f:
            data = json.load(f)
        members = [MemberRecord(**m) for m in data.get("members", [])]
        return cls(meta=data.get("meta", {}), members=members)
