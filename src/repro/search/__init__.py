"""Population engine: on-device hyperparameter & structure exploration.

The paper closes on "complexity reduction and easy reconfigurability
enable significantly greater exploration of network hyperparameters and
structures on-chip" — this package is that claim as a subsystem.  It
rides the junction engine's existing expert axis, adding NO new kernels.

The E-axis reuse contract
-------------------------

Every kernel in ``kernels/block_sparse_matmul.py`` is E-generic: grid
``(E, ...)`` over weights ``[E, nob, kb, bs, bs]`` with ONE block
pattern in scalar prefetch shared by all E units.  PRs 2–4 used that
axis for MoE experts (same model, E parallel units); this package
re-addresses it as a *population* (E models, one structure):

* **Members must share structure.**  An E-batched launch fixes every
  static kernel input — layer widths, block size, pattern seed,
  activation, optimizer kind (the accumulator-slot layout is static),
  and the per-junction fan-in ``kb`` the density quantizes to
  (``core/sparsity.block_fan_in``).  ``cohorts.bucket`` groups
  candidates by exactly that key; anything else (lr, momentum/b1, b2,
  eps, weight_decay, init seed) varies within a cohort.
* **Hyperparameters ride the ``[E, HYP_K]`` hyp table.**  The fused
  BP+UP epilogue (``update_dw``/``update_gated_dw``) reads registry row
  ``program_id(0)`` (``kernels/block_sparse_matmul.HYP_COLS``: lr, b1,
  b2, eps, wd, t, gs), so each member updates under its own
  hyperparameters — SGD+momentum or Adam — in the same launch; a plain
  ``(2,)`` pair or ``(HYP_K,)`` row (the single-model and MoE path)
  broadcasts to all rows in ``kernels/ops.junction_train_update``.
* **Members never interact.**  The objective is a live-mask-weighted
  sum of per-member losses over a SHARED batch, so the population
  gradient is the stacked single-model gradients — training E members
  population-parallel is numerically the independent runs (the parity
  contract of tests/test_search.py).
* **Pruning is in place.**  Successive halving (``scheduler.run_sweep``)
  zeroes a pruned member's mask entry and hyp row: gradients become
  exact zeros and the in-kernel update rewrites ``w' = w`` — fixed
  shapes, zero recompiles, the serve engine's finished-slot masking
  applied to training.

Modules: ``population`` (stacking, per-member hyp, E-batched steps),
``cohorts`` (structure bucketing), ``scheduler`` (successive halving),
``ledger`` (JSON lineage artifact).  ``launch/sweep.py`` is the CLI;
``configs.base.SweepConfig`` the knob set.
"""
from repro.search.cohorts import Cohort, QuantCohort, bucket, bucket_quant
from repro.search.ledger import Ledger, MemberRecord
from repro.search.population import (CandidateSpec, hyp_table,
                                     init_population, init_slots,
                                     make_population_eval,
                                     make_population_step, member_slice,
                                     structure_key)
from repro.search.scheduler import SweepResult, run_sweep

__all__ = ["CandidateSpec", "Cohort", "Ledger", "MemberRecord",
           "QuantCohort", "SweepResult", "bucket", "bucket_quant",
           "hyp_table", "init_population", "init_slots",
           "make_population_eval", "make_population_step",
           "member_slice", "run_sweep", "structure_key"]
