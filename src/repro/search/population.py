"""Population-parallel candidate training on the junction engine's E axis.

A *population* is E candidate MLPs that share one network structure —
the same layer widths, block size, pattern seed and per-junction fan-in
(so the SAME scalar-prefetched block patterns) — stacked member-by-member
into the engine's expert dimension: junction weights ``[E, nob, kb, bs,
bs]``, biases ``[E, n_out]``, one pattern riding once in scalar prefetch
for all members.  One fused E-batched train step then advances ALL E
candidates: the forward/backward kernels iterate the expert grid axis,
and the fused BP+UP epilogue reads each member's own ``[lr, momentum]``
row from the per-unit ``[E, 2]`` hyp table — E distinct hyperparameter
settings, one kernel launch per junction per pass.

Because members never interact (the loss is a live-mask-weighted SUM of
per-member losses and every parameter leaf is E-leading), training the
population is mathematically identical to training E single models
independently — the parity contract tests/test_search.py pins down.

Batches are shared: x ``[M, n_in]`` is broadcast to ``[E, M, n_in]``, so
every member sees the same data and differs only in init, structure
cohort, and hyp row.  Pruning (search/scheduler.py) zeroes a member's
mask entry AND its hyp row: masked loss makes its gradients exact zeros,
lr = momentum = 0 freezes its parameters — fixed shapes, no recompiles,
the serve-engine slot-masking pattern applied to training.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import sparse_linear as sl
from repro.core.sparsity import SparsityConfig, block_fan_in


@dataclasses.dataclass(frozen=True)
class CandidateSpec:
    """One candidate network + its training hyperparameters.

    (layers, block, seed, act, density-derived fan-ins) define the
    *structure* — candidates agreeing on all of those share patterns and
    can ride one population (search/cohorts.py buckets by exactly that
    key); lr / momentum / init_seed vary freely WITHIN a population.
    """
    lr: float
    momentum: float = 0.0
    density: float = 0.25
    layers: tuple[int, ...] = (1024, 512, 128)   # widths incl. in/out
    block: int = 128
    act: str = "sigmoid"       # every junction's epilogue (paper Sec. III)
    seed: int = 0              # pattern seed (structure, not init)
    init_seed: int = 0         # weight-init stream for this member

    def fan_in_blocks(self) -> tuple[int, ...]:
        """kb per junction at this density — the structure the density
        quantizes to (core/sparsity.block_fan_in)."""
        return tuple(block_fan_in(n_in // self.block, self.density)
                     for n_in, _ in zip(self.layers[:-1], self.layers[1:]))

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["layers"] = list(self.layers)   # JSON-canonical (round-trips)
        return d


def structure_key(spec: CandidateSpec) -> tuple:
    """The shared-pattern cohort key: everything that shapes the stacked
    arrays and scalar-prefetch patterns, nothing that doesn't."""
    return (spec.layers, spec.block, spec.seed, spec.act,
            spec.fan_in_blocks())


def _init_member(key, spec: CandidateSpec):
    """Single-model params for one candidate: a list of 4-D junction
    dicts (one per layer pair), patterns deterministic in the spec."""
    sp = SparsityConfig(density=spec.density, block=spec.block, where="all")
    layers = []
    for i, (n_in, n_out) in enumerate(zip(spec.layers[:-1], spec.layers[1:])):
        key, sub = jax.random.split(key)
        layers.append(sl.init_sparse(sub, n_in, n_out, sp, bias=True,
                                     seed=spec.seed))
    return layers


def init_population(key, specs: Sequence[CandidateSpec]):
    """Stack E candidates into population params: a list of junction
    dicts with E-leading trainable leaves and SHARED pattern leaves.

    Each member is initialized exactly as its standalone single model
    would be (fold_in by init_seed) — ``member_slice`` recovers it
    bit-for-bit, which is what makes population-vs-independent parity a
    meaningful test rather than a tautology."""
    if not specs:
        raise ValueError("empty population")
    key0 = structure_key(specs[0])
    for s in specs[1:]:
        if structure_key(s) != key0:
            raise ValueError(
                f"population members must share structure: {structure_key(s)} "
                f"!= {key0} — bucket with search/cohorts.py first")
    members = [_init_member(jax.random.fold_in(key, s.init_seed), s)
               for s in specs]
    pop = []
    for li in range(len(members[0])):
        layer = {k: members[0][li][k] for k in sl.PATTERN_LEAVES}
        layer["w"] = jnp.stack([m[li]["w"] for m in members])
        layer["b"] = jnp.stack([m[li]["b"] for m in members])
        pop.append(layer)
    return pop


def member_slice(params, e: int):
    """Member e's standalone single-model params (4-D junction dicts) —
    the squeeze-path view of one population slot."""
    return [{k: (v[e] if k in ("w", "b") else v) for k, v in layer.items()}
            for layer in params]


def population_size(params) -> int:
    return params[0]["w"].shape[0]


def hyp_table(specs: Sequence[CandidateSpec]) -> jax.Array:
    """The per-member [E, 2] [lr, momentum] table the fused update
    kernels index by expert grid coordinate."""
    return jnp.asarray([[s.lr, s.momentum] for s in specs], jnp.float32)


def init_momentum(params, specs: Sequence[CandidateSpec] | None = None):
    """fp32 momentum accumulators mirroring the trainable leaves (zeros
    for int pattern leaves, which the fused ctx injection skips).  When
    ``specs`` is given and NO member uses momentum, returns None — the
    steps then run the plain-SGD kernels, skipping a weight-sized fp32
    read+write per junction per step (zeros-with-beta-0 computes the
    same numbers, just slower)."""
    if specs is not None and not any(s.momentum for s in specs):
        return None
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32)
        if jnp.issubdtype(p.dtype, jnp.inexact) else jnp.zeros((), jnp.float32),
        params)


# ------------------------------------------------------------------ forward
def _apply_jnp(layer, x):
    """E-batched junction reference: core/sparse_linear.apply_jnp (the
    ONE gather+einsum reduction) vmapped over the member axis — trainable
    leaves map per member, the shared pattern leaves broadcast
    (x [E, M, n_in] -> [E, M, n_out], bias included, no activation)."""
    in_axes = ({k: (0 if k in ("w", "b") else None) for k in layer}, 0)
    return jax.vmap(sl.apply_jnp, in_axes=in_axes)(layer, x)


def _layer_apply(layer, x, act: str, engine: str):
    if engine == "pallas":
        # sl.apply dispatches junction_matmul / junction_train_update
        # (when the fused ctx rides in the dict) on the 5-D expert path
        return sl.apply(layer, x, engine="pallas", act=act)
    from repro.kernels import block_sparse_matmul as bsm
    y = _apply_jnp(layer, x)
    return bsm.act_fwd(y, act).astype(y.dtype) if act != "none" else y


def population_forward(params, x, *, act: str, engine: str):
    """y [E, M, n_out] for shared input x [M, n_in] (or pre-broadcast
    [E, M, n_in]) through every junction of the stacked population."""
    E = population_size(params)
    if x.ndim == 2:
        x = jnp.broadcast_to(x[None], (E, *x.shape))
    for layer in params:
        x = _layer_apply(layer, x, act, engine)
    return x


def member_losses(y, targets):
    """Per-member mean-squared error [E] against the shared one-hot
    targets [M, n_out] — the paper's output-MSE objective, one scalar per
    candidate.  Members are independent, so d(sum_e mask_e*loss_e)/d w_e
    = mask_e * d loss_e / d w_e: the population gradient IS the stacked
    single-model gradients."""
    t = targets[None].astype(y.dtype)
    return jnp.mean(jnp.square(y - t), axis=(1, 2))


# --------------------------------------------------------------- train step
def _two_pass_update(params, mom, grads, hyp):
    """Per-member SGD(+momentum) over the E-leading leaves: lr/beta come
    from each member's hyp row, broadcast over the trailing dims — the
    materialized-gradient reference of the fused in-kernel epilogue."""
    def _row(col, p):
        return hyp[:, col].reshape((-1,) + (1,) * (p.ndim - 1))

    def mv_fn(p, m, g):
        if not jnp.issubdtype(p.dtype, jnp.inexact):
            return m
        gf = g.astype(jnp.float32)
        return _row(1, p) * m + gf if mom is not None else gf

    def p_fn(p, m):
        if not jnp.issubdtype(p.dtype, jnp.inexact):
            return p
        return (p.astype(jnp.float32) - _row(0, p) * m).astype(p.dtype)

    mv = jax.tree.map(mv_fn, params, mom if mom is not None else params,
                      grads)
    new_params = jax.tree.map(p_fn, params, mv)
    return new_params, (mv if mom is not None else None)


def _merge_updated(grads, params, mom):
    """Fused-step merge: the cotangents of the augmented tree's junction
    leaves ARE the updated params / momenta (every population leaf is a
    junction leaf — no dense remainder to tree-map).  mom None = plain
    SGD, no momentum leaves to adopt."""
    new_params, new_mom = [], []
    for li, (g, p) in enumerate(zip(grads, params)):
        layer = dict(p)
        mlayer = dict(mom[li]) if mom is not None else None
        for k, mk in sl.FUSED_MOM.items():
            if k in p and not isinstance(p[k], dict):
                layer[k] = g[k]
                if mom is not None:
                    mlayer[k] = g[mk]
        new_params.append(layer)
        new_mom.append(mlayer)
    return new_params, (new_mom if mom is not None else None)


def _member_health_fused(grads) -> jax.Array:
    """[E] per-member non-finite-update counts from the injected health
    leaves' cotangents (the update kernels' in-kernel detector) — summed
    across layers."""
    h = None
    for g in grads:
        v = g[sl.UPDATE_HEALTH_LEAF].astype(jnp.float32)
        h = v if h is None else h + v
    return h


def _member_health_jnp(grads) -> jax.Array:
    """[E] two-pass twin: per-member any-non-finite flags over the
    materialized E-leading gradient leaves (one count per bad leaf)."""
    h = None
    for g in grads:
        for k in ("w", "b"):
            f = jnp.any(~jnp.isfinite(g[k].reshape(g[k].shape[0], -1)),
                        axis=1).astype(jnp.float32)
            h = f if h is None else h + f
    return h


def make_population_step(act: str = "sigmoid", *, engine: str = "auto",
                         fused: bool = True, jit: bool = True,
                         donate: bool = True, with_health: bool = False):
    """step(params, mom, hyp, mask, x, t) -> (params, mom, losses[E])
    — or (params, mom, losses, health[E]) with ``with_health``.

    One call trains ALL E members on the shared batch (x [M, n_in],
    t [M, n_out] one-hot): objective sum(mask * member_losses).  On the
    pallas engine with ``fused`` the junction custom_vjp applies each
    member's update in the backward kernels against its own hyp row (dw
    never in HBM); otherwise the two-pass reference materializes grads
    and applies the identical per-member formula here.  mom None = plain
    SGD end to end (no momentum buffers allocated or streamed; the step
    then also returns None).  hyp [E, 2] and mask [E] are traced
    operands — pruning a member (zero mask + zero hyp row) never
    recompiles.

    ``with_health`` adds the per-member divergence signal the scheduler's
    quarantine uses: health[e] > 0 ⇔ member e's update just went
    non-finite.  Fused path: the in-kernel [E] health flags (the grads
    never exist in HBM to inspect); two-pass path: a non-finite scan over
    the materialized per-member grads.  Member independence means a bad
    member flags ONLY its own slot."""
    engine = sl.resolve_engine(engine)
    use_fused = fused and engine == "pallas"

    def step(params, mom, hyp, mask, x, t):
        if use_fused:
            aug = sl.inject_update_ctx(params, mom, hyp)

            def loss_fn(aug):
                y = population_forward(aug, x, act=act, engine=engine)
                losses = member_losses(y, t)
                return jnp.sum(losses * mask), losses

            grads, losses = jax.grad(loss_fn, has_aux=True,
                                     allow_int=True)(aug)
            new_params, new_mom = _merge_updated(grads, params, mom)
            if with_health:
                return new_params, new_mom, losses, _member_health_fused(grads)
            return new_params, new_mom, losses

        def loss_fn(params):
            y = population_forward(params, x, act=act, engine=engine)
            losses = member_losses(y, t)
            return jnp.sum(losses * mask), losses

        grads, losses = jax.grad(loss_fn, has_aux=True, allow_int=True)(params)
        new_params, new_mom = _two_pass_update(params, mom, grads, hyp)
        if with_health:
            return new_params, new_mom, losses, _member_health_jnp(grads)
        return new_params, new_mom, losses

    if jit:
        return jax.jit(step, donate_argnums=(0, 1) if donate else ())
    return step


def make_population_eval(act: str = "sigmoid", *, engine: str = "auto",
                         jit: bool = True):
    """eval(params, x, t) -> per-member losses [E] (no update, no mask —
    the scheduler ranks live members and ignores pruned slots)."""
    engine = sl.resolve_engine(engine)

    def evaluate(params, x, t):
        y = population_forward(params, x, act=act, engine=engine)
        return member_losses(y, t)

    return jax.jit(evaluate) if jit else evaluate
