"""Population-parallel candidate training on the junction engine's E axis.

A *population* is E candidate MLPs that share one network structure —
the same layer widths, block size, pattern seed and per-junction fan-in
(so the SAME scalar-prefetched block patterns) — stacked member-by-member
into the engine's expert dimension: junction weights ``[E, nob, kb, bs,
bs]``, biases ``[E, n_out]``, one pattern riding once in scalar prefetch
for all members.  One fused E-batched train step then advances ALL E
candidates: the forward/backward kernels iterate the expert grid axis,
and the fused BP+UP epilogue reads each member's own registry row from
the per-unit ``[E, HYP_K]`` hyp table (kernels/block_sparse_matmul
.HYP_COLS: lr, b1, b2, eps, wd, t, gs) — E distinct hyperparameter
settings, SGD+momentum or Adam (one optimizer kind per population: the
accumulator-slot layout is static), one kernel launch per junction per
pass.

Because members never interact (the loss is a live-mask-weighted SUM of
per-member losses and every parameter leaf is E-leading), training the
population is mathematically identical to training E single models
independently — the parity contract tests/test_search.py pins down.

Batches are shared: x ``[M, n_in]`` is broadcast to ``[E, M, n_in]``, so
every member sees the same data and differs only in init, structure
cohort, and hyp row.  Pruning (search/scheduler.py) zeroes a member's
mask entry AND its hyp row: masked loss makes its gradients exact zeros,
lr = momentum = 0 freezes its parameters — fixed shapes, no recompiles,
the serve-engine slot-masking pattern applied to training.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import sparse_linear as sl
from repro.core.sparsity import SparsityConfig, block_fan_in


@dataclasses.dataclass(frozen=True)
class CandidateSpec:
    """One candidate network + its training hyperparameters.

    (layers, block, seed, act, opt, density-derived fan-ins) define the
    *structure* — candidates agreeing on all of those share patterns AND
    accumulator-slot layout, so they can ride one population
    (search/cohorts.py buckets by exactly that key); lr / momentum / b2 /
    eps / weight_decay / init_seed vary freely WITHIN a population.

    ``momentum`` is the hyp row's slot-0 decay column: SGD momentum, or
    Adam's b1 when ``opt="adam"`` — the kernels make no distinction.
    """
    lr: float
    momentum: float = 0.0      # slot-0 decay: SGD momentum / Adam b1
    density: float = 0.25
    layers: tuple[int, ...] = (1024, 512, 128)   # widths incl. in/out
    block: int = 128
    act: str = "sigmoid"       # every junction's epilogue (paper Sec. III)
    seed: int = 0              # pattern seed (structure, not init)
    init_seed: int = 0         # weight-init stream for this member
    opt: str = "sgd"           # "sgd" | "adam" (structural: slot layout)
    b2: float = 0.95           # Adam only
    eps: float = 1e-8          # Adam only
    weight_decay: float = 0.0  # Adam only

    def fan_in_blocks(self) -> tuple[int, ...]:
        """kb per junction at this density — the structure the density
        quantizes to (core/sparsity.block_fan_in)."""
        return tuple(block_fan_in(n_in // self.block, self.density)
                     for n_in, _ in zip(self.layers[:-1], self.layers[1:]))

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["layers"] = list(self.layers)   # JSON-canonical (round-trips)
        return d


def structure_key(spec: CandidateSpec) -> tuple:
    """The shared-pattern cohort key: everything that shapes the stacked
    arrays, scalar-prefetch patterns and accumulator-slot layout, nothing
    that doesn't.  ``opt`` is structural: an Adam member needs the v slot
    allocated and the kernels' optimizer switch is static per launch."""
    return (spec.layers, spec.block, spec.seed, spec.act, spec.opt,
            spec.fan_in_blocks())


def _init_member(key, spec: CandidateSpec):
    """Single-model params for one candidate: a list of 4-D junction
    dicts (one per layer pair), patterns deterministic in the spec."""
    sp = SparsityConfig(density=spec.density, block=spec.block, where="all")
    layers = []
    for i, (n_in, n_out) in enumerate(zip(spec.layers[:-1], spec.layers[1:])):
        key, sub = jax.random.split(key)
        layers.append(sl.init_sparse(sub, n_in, n_out, sp, bias=True,
                                     seed=spec.seed))
    return layers


def init_population(key, specs: Sequence[CandidateSpec]):
    """Stack E candidates into population params: a list of junction
    dicts with E-leading trainable leaves and SHARED pattern leaves.

    Each member is initialized exactly as its standalone single model
    would be (fold_in by init_seed) — ``member_slice`` recovers it
    bit-for-bit, which is what makes population-vs-independent parity a
    meaningful test rather than a tautology."""
    if not specs:
        raise ValueError("empty population")
    key0 = structure_key(specs[0])
    for s in specs[1:]:
        if structure_key(s) != key0:
            raise ValueError(
                f"population members must share structure: {structure_key(s)} "
                f"!= {key0} — bucket with search/cohorts.py first")
    members = [_init_member(jax.random.fold_in(key, s.init_seed), s)
               for s in specs]
    pop = []
    for li in range(len(members[0])):
        layer = {k: members[0][li][k] for k in sl.PATTERN_LEAVES}
        layer["w"] = jnp.stack([m[li]["w"] for m in members])
        layer["b"] = jnp.stack([m[li]["b"] for m in members])
        pop.append(layer)
    return pop


def member_slice(params, e: int):
    """Member e's standalone single-model params (4-D junction dicts) —
    the squeeze-path view of one population slot."""
    return [{k: (v[e] if k in ("w", "b") else v) for k, v in layer.items()}
            for layer in params]


def population_size(params) -> int:
    p0 = params[0]
    return (p0["w"] if "w" in p0 else p0["wq"]).shape[0]


def hyp_table(specs: Sequence[CandidateSpec]) -> jax.Array:
    """The per-member [E, HYP_K] registry table the fused update kernels
    index by expert grid coordinate.  Adam members get t = 1 as a
    placeholder — the scheduler stamps the real per-step time into
    COL_T before every step (harmless on SGD/zeroed rows: t is dead
    there)."""
    from repro.kernels import block_sparse_matmul as bsm
    rows = []
    for s in specs:
        row = [0.0] * bsm.HYP_K
        row[bsm.COL_LR] = s.lr
        row[bsm.COL_B1] = s.momentum
        row[bsm.COL_GS] = 1.0
        if s.opt == "adam":
            row[bsm.COL_B2] = s.b2
            row[bsm.COL_EPS] = s.eps
            row[bsm.COL_WD] = s.weight_decay
            row[bsm.COL_T] = 1.0
        rows.append(row)
    return jnp.asarray(rows, jnp.float32)


def _zeros_like_slots(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32)
        if jnp.issubdtype(p.dtype, jnp.inexact) else jnp.zeros((), jnp.float32),
        params)


def init_slots(params, specs: Sequence[CandidateSpec] | None = None):
    """The population's fp32 accumulator-slot trees, kernel slot order:
    () for plain SGD, (mom,) with momentum, (mom, vel) for Adam.  The
    kernels' optimizer switch is static, so opt must be homogeneous
    (structure_key / cohorts enforce this upstream).  Plain SGD returns
    () — skipping a weight-sized fp32 read+write per junction per step
    (zeros-with-beta-0 computes the same numbers, just slower)."""
    if specs is not None:
        kinds = {s.opt for s in specs}
        if len(kinds) > 1:
            raise ValueError(
                f"population mixes optimizer kinds {sorted(kinds)} — the "
                "slot layout is static; bucket with search/cohorts.py first")
        if kinds == {"adam"}:
            return (_zeros_like_slots(params), _zeros_like_slots(params))
        if not any(s.momentum for s in specs):
            return ()
    return (_zeros_like_slots(params),)


def init_momentum(params, specs: Sequence[CandidateSpec] | None = None):
    """Back-compat shim for the pre-Adam API: the slot-0 tree or None.
    New code should use :func:`init_slots` (handles the Adam v slot)."""
    slots = init_slots(params, specs)
    return slots[0] if slots else None


# ------------------------------------------------------------------ forward
def _apply_jnp(layer, x):
    """E-batched junction reference: core/sparse_linear.apply_jnp (the
    ONE gather+einsum reduction) vmapped over the member axis — trainable
    leaves map per member, the shared pattern leaves broadcast
    (x [E, M, n_in] -> [E, M, n_out], bias included, no activation)."""
    in_axes = ({k: (0 if k in ("w", "b") else None) for k in layer}, 0)
    return jax.vmap(sl.apply_jnp, in_axes=in_axes)(layer, x)


def _layer_apply(layer, x, act: str, engine: str):
    if engine == "pallas" or sl.is_quantized(layer):
        # sl.apply dispatches junction_matmul / junction_train_update
        # (when the fused ctx rides in the dict) on the 5-D expert path;
        # quantized layers (launch/quant_sweep.py populations) route
        # through it on EITHER engine — it owns the int8/fxp dispatch
        return sl.apply(layer, x, engine=engine, act=act)
    from repro.kernels import block_sparse_matmul as bsm
    y = _apply_jnp(layer, x)
    return bsm.act_fwd(y, act).astype(y.dtype) if act != "none" else y


def population_forward(params, x, *, act: str, engine: str):
    """y [E, M, n_out] for shared input x [M, n_in] (or pre-broadcast
    [E, M, n_in]) through every junction of the stacked population."""
    E = population_size(params)
    if x.ndim == 2:
        x = jnp.broadcast_to(x[None], (E, *x.shape))
    for layer in params:
        x = _layer_apply(layer, x, act, engine)
    return x


def member_losses(y, targets):
    """Per-member mean-squared error [E] against the shared one-hot
    targets [M, n_out] — the paper's output-MSE objective, one scalar per
    candidate.  Members are independent, so d(sum_e mask_e*loss_e)/d w_e
    = mask_e * d loss_e / d w_e: the population gradient IS the stacked
    single-model gradients."""
    t = targets[None].astype(y.dtype)
    return jnp.mean(jnp.square(y - t), axis=(1, 2))


# --------------------------------------------------------------- train step
def _two_pass_update(params, slots, grads, hyp):
    """Per-member optimizer step over the E-leading leaves: every column
    comes from each member's [E, HYP_K] hyp row, broadcast over the
    trailing dims — the materialized-gradient reference of the fused
    in-kernel epilogue.  len(slots) picks the rule: 0/1 slots = SGD
    (+momentum), 2 slots = Adam, with the SAME t/den guards as the kernel
    so a zeroed hyp row freezes a member EXACTLY on this path too."""
    from repro.kernels import block_sparse_matmul as bsm
    is_adam = len(slots) == 2

    def _row(col, p):
        return hyp[:, col].reshape((-1,) + (1,) * (p.ndim - 1))

    def upd(p, g, *ms):
        if not jnp.issubdtype(p.dtype, jnp.inexact):
            return (p,) + ms
        gf = _row(bsm.COL_GS, p) * g.astype(jnp.float32)
        p32 = p.astype(jnp.float32)
        lr = _row(bsm.COL_LR, p)
        if is_adam:
            b1, b2 = _row(bsm.COL_B1, p), _row(bsm.COL_B2, p)
            eps, wd = _row(bsm.COL_EPS, p), _row(bsm.COL_WD, p)
            t = _row(bsm.COL_T, p)
            m = b1 * ms[0] + (1.0 - b1) * gf
            v = b2 * ms[1] + (1.0 - b2) * jnp.square(gf)
            c1 = 1.0 - jnp.power(b1, t)
            c2 = 1.0 - jnp.power(b2, t)
            c1 = jnp.where(c1 == 0.0, 1.0, c1)
            c2 = jnp.where(c2 == 0.0, 1.0, c2)
            den = jnp.sqrt(v / c2) + eps
            step_ = jnp.where(den == 0.0, 0.0, (m / c1) / den) + wd * p32
            return (p32 - lr * step_).astype(p.dtype), m, v
        if slots:
            mv = _row(bsm.COL_B1, p) * ms[0] + gf
            return (p32 - lr * mv).astype(p.dtype), mv
        return ((p32 - lr * gf).astype(p.dtype),)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_ms = [treedef.flatten_up_to(s) for s in slots]
    out = [upd(*a) for a in zip(flat_p, flat_g, *flat_ms)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_slots = tuple(treedef.unflatten([o[1 + i] for o in out])
                      for i in range(len(slots)))
    return new_params, new_slots


def _merge_updated(grads, params, slots):
    """Fused-step merge: the cotangents of the augmented tree's junction
    leaves ARE the updated params / slot buffers (every population leaf
    is a junction leaf — no dense remainder to tree-map)."""
    new_params = []
    new_slots = tuple([] for _ in slots)
    for li, (g, p) in enumerate(zip(grads, params)):
        layer = dict(p)
        slayers = tuple(dict(s[li]) for s in slots)
        for k in sl.FUSED_MOM:
            if k in p and not isinstance(p[k], dict):
                layer[k] = g[k]
                for i, names in enumerate(sl.FUSED_SLOT_NAMES[:len(slots)]):
                    slayers[i][k] = g[names[k]]
        new_params.append(layer)
        for i in range(len(slots)):
            new_slots[i].append(slayers[i])
    return new_params, new_slots


def _member_health_fused(grads) -> jax.Array:
    """[E] per-member non-finite-update counts from the injected health
    leaves' cotangents (the update kernels' in-kernel detector) — summed
    across layers."""
    h = None
    for g in grads:
        v = g[sl.UPDATE_HEALTH_LEAF].astype(jnp.float32)
        h = v if h is None else h + v
    return h


def _member_health_jnp(grads) -> jax.Array:
    """[E] two-pass twin: per-member any-non-finite flags over the
    materialized E-leading gradient leaves (one count per bad leaf)."""
    h = None
    for g in grads:
        for k in ("w", "b"):
            f = jnp.any(~jnp.isfinite(g[k].reshape(g[k].shape[0], -1)),
                        axis=1).astype(jnp.float32)
            h = f if h is None else h + f
    return h


def _repack_slots(new_slots: tuple, like):
    """Return the updated slots in the caller's convention: None in =
    None out, single tree in = single tree out, tuple in = tuple out."""
    if like is None:
        return None
    if isinstance(like, tuple):
        return new_slots
    return new_slots[0]


def make_population_step(act: str = "sigmoid", *, engine: str = "auto",
                         fused: bool = True, jit: bool = True,
                         donate: bool = True, with_health: bool = False):
    """step(params, slots, hyp, mask, x, t) -> (params, slots, losses[E])
    — or (params, slots, losses, health[E]) with ``with_health``.

    One call trains ALL E members on the shared batch (x [M, n_in],
    t [M, n_out] one-hot): objective sum(mask * member_losses).  On the
    pallas engine with ``fused`` the junction custom_vjp applies each
    member's update in the backward kernels against its own hyp row (dw
    never in HBM); otherwise the two-pass reference materializes grads
    and applies the identical per-member formula here.  ``slots`` is the
    accumulator-slot convention of :func:`init_slots` — None/() = plain
    SGD end to end (no buffers allocated or streamed), a single tree =
    SGD momentum (back-compat), (mom, vel) = Adam — and comes back in
    the same convention.  hyp (legacy [E, 2] pair or [E, HYP_K] registry
    table) and mask [E] are traced operands — pruning a member (zero
    mask + zero hyp row) never recompiles.

    ``with_health`` adds the per-member divergence signal the scheduler's
    quarantine uses: health[e] > 0 ⇔ member e's update just went
    non-finite.  Fused path: the in-kernel [E] health flags (the grads
    never exist in HBM to inspect); two-pass path: a non-finite scan over
    the materialized per-member grads.  Member independence means a bad
    member flags ONLY its own slot."""
    engine = sl.resolve_engine(engine)
    use_fused = fused and engine == "pallas"

    def step(params, mom, hyp, mask, x, t):
        slots = sl.normalize_slots(mom)
        if use_fused:
            aug = sl.inject_update_ctx(params, slots, hyp)

            def loss_fn(aug):
                y = population_forward(aug, x, act=act, engine=engine)
                losses = member_losses(y, t)
                return jnp.sum(losses * mask), losses

            grads, losses = jax.grad(loss_fn, has_aux=True,
                                     allow_int=True)(aug)
            new_params, new_slots = _merge_updated(grads, params, slots)
            new_mom = _repack_slots(new_slots, mom)
            if with_health:
                return new_params, new_mom, losses, _member_health_fused(grads)
            return new_params, new_mom, losses

        def loss_fn(params):
            y = population_forward(params, x, act=act, engine=engine)
            losses = member_losses(y, t)
            return jnp.sum(losses * mask), losses

        from repro.kernels import block_sparse_matmul as bsm
        hyp_k = bsm.normalize_hyp(hyp, population_size(params))
        grads, losses = jax.grad(loss_fn, has_aux=True, allow_int=True)(params)
        new_params, new_slots = _two_pass_update(params, slots, grads, hyp_k)
        new_mom = _repack_slots(new_slots, mom)
        if with_health:
            return new_params, new_mom, losses, _member_health_jnp(grads)
        return new_params, new_mom, losses

    if jit:
        return jax.jit(step, donate_argnums=(0, 1) if donate else ())
    return step


def make_population_eval(act: str = "sigmoid", *, engine: str = "auto",
                         jit: bool = True):
    """eval(params, x, t) -> per-member losses [E] (no update, no mask —
    the scheduler ranks live members and ignores pruned slots)."""
    engine = sl.resolve_engine(engine)

    def evaluate(params, x, t):
        y = population_forward(params, x, act=act, engine=engine)
        return member_losses(y, t)

    return jax.jit(evaluate) if jit else evaluate
