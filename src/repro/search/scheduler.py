"""Successive-halving scheduler over E-batched population cohorts.

``run_sweep`` takes an arbitrary candidate list, buckets it into
same-structure cohorts (search/cohorts.py), stacks each cohort into one
population (search/population.py), and runs ``SweepConfig.rounds`` of

    train steps_per_round E-batched steps
      -> vectorized per-member eval loss on the held-out split
      -> rank ALL live members globally, keep the top keep_fraction,
         prune the rest

Cross-cohort ranking is width-normalized: cohorts can differ in output
width (zero-padded targets), and a per-element MSE mean would dilute
with padding — so members rank on the per-sample TOTAL squared error
(``loss * n_out``), and a non-finite eval loss (a diverged candidate)
ranks as +inf: diverged members are pruned first and can never be named
winner.

Pruning is in place and shape-stable: a pruned member's mask entry goes
to 0 (its loss drops out of the objective, so its gradients are exact
zeros) and its hyp row goes to all zeros (the kernels' guarded epilogue
makes an all-zero registry row an exact freeze for SGD and Adam alike:
w' = w, slots' = 0).  The arrays
the jitted step sees never change shape, so a sweep compiles each cohort
step exactly once — the serve engine's finished-slot masking applied to
training, and the paper's "greater exploration ... on-chip" claim as a
subsystem: exploration cost scales with rounds, not candidates.

The same mechanism doubles as FAULT ISOLATION (``SweepConfig.quarantine``,
on by default): exploring lr×density means routinely training members at
hyperparameters that diverge, and a diverged member's non-finite loss
would otherwise sit inside the cohort's shared-batch objective every
step.  After every train step the scheduler checks each live member's
loss and per-member health flag (population.make_population_step
``with_health`` — the fused path's in-kernel detector, since those
gradients never reach HBM) and quarantines diverged members MID-round:
mask + hyp zeroed immediately, the event recorded in the ledger
(``quarantined_at``).  Member independence makes this exact: survivors'
gradient trajectories are bitwise identical to a cohort that never
contained the diverged member (tests/test_guardian.py).

The returned ``SweepResult`` carries the lineage ``Ledger`` (winner,
loss curves, rounds survived) plus the live cohort states for callers
that want the winning weights.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SweepConfig
from repro.obs import telemetry as obs
from repro.search import cohorts as ch
from repro.search import population as pop
from repro.search.ledger import Ledger, MemberRecord, make_meta


@dataclasses.dataclass
class CohortState:
    cohort: ch.Cohort
    params: list
    mom: tuple              # accumulator-slot trees (population.init_slots)
    hyp: jax.Array          # [E, HYP_K], zeroed rows = pruned
    mask: jax.Array         # [E] f32, 0 = pruned
    records: list[MemberRecord]
    step: callable
    evaluate: callable
    t_train_pad: jax.Array  # train targets padded to this cohort's width
    t_eval_pad: jax.Array   # eval targets, ditto (constant per cohort)

    @property
    def out_width(self) -> int:
        return self.cohort.specs[0].layers[-1]

    @property
    def is_adam(self) -> bool:
        # homogeneous per cohort: opt is part of the structure key
        return self.cohort.specs[0].opt == "adam"


@dataclasses.dataclass
class SweepResult:
    ledger: Ledger
    states: list[CohortState]

    def winning_params(self):
        """The winner's standalone single-model params."""
        w = self.ledger.winner()
        if w is None:
            return None
        st = self.states[w.cohort]
        return pop.member_slice(st.params, w.slot)


def _pad_targets(t: np.ndarray, width: int) -> np.ndarray:
    """One-hot targets padded with zero columns to a cohort's output
    width (the paper pads 10 MNIST classes to its 32-wide output)."""
    if t.shape[1] > width:
        raise ValueError(f"targets wider ({t.shape[1]}) than the output "
                         f"layer ({width})")
    if t.shape[1] == width:
        return t
    out = np.zeros((t.shape[0], width), t.dtype)
    out[:, :t.shape[1]] = t
    return out


def _batch_indices(n: int, batch: int, step: int) -> np.ndarray:
    """Deterministic wrapping minibatch of the shared train split —
    every cohort sees the same data stream."""
    start = (step * batch) % n
    return (np.arange(start, start + batch) % n).astype(np.int64)


def _score(loss: float, out_width: int) -> float:
    """Cross-cohort comparable rank key: per-sample total squared error
    (mean * width undoes the padding dilution of wider outputs); any
    non-finite loss — a diverged candidate — ranks strictly last."""
    s = float(loss) * out_width
    return s if math.isfinite(s) else math.inf


def _quarantine(st: CohortState, rec: MemberRecord, rnd: int,
                global_step: int, recorder: "obs.Recorder | None" = None):
    """Fault-isolate a diverged member MID-round: zero its mask entry
    (its — possibly non-finite — loss drops out of the shared-batch
    objective, and member independence makes the surviving members'
    gradients exactly what they'd be without it) and its hyp row (lr =
    momentum = 0 freezes whatever parameter state remains).  The same
    in-place mechanism as round-boundary pruning, applied the moment the
    divergence is detected rather than at the next eval; recorded
    distinctly in the ledger."""
    st.mask = st.mask.at[rec.slot].set(0.0)
    st.hyp = st.hyp.at[rec.slot].set(0.0)
    rec.pruned_at = rnd
    rec.quarantined_at = {"round": rnd, "step": global_step}
    if recorder is not None:
        recorder.count("sweep.quarantined")
        recorder.emit(obs.SweepRound(
            action="quarantine", round=rnd, member=rec.member,
            cohort=rec.cohort, slot=rec.slot,
            detail={"step": global_step}))


def run_sweep(specs: Sequence[pop.CandidateSpec], x_train, t_train,
              x_eval, t_eval, cfg: SweepConfig, *,
              tag: str = "",
              recorder: "obs.Recorder | None" = None) -> SweepResult:
    """Train all candidates population-parallel and successively halve.

    x_* [N, n_in] float, t_* [N, n_classes] one-hot (padded per cohort to
    its output width).  Returns the lineage ledger (winner marked) and
    the final cohort states.

    ``recorder`` (obs.Recorder) gets one ``obs.SweepRound`` event per
    scheduler decision — rank (once per round, the scored table in
    ``detail``), prune and quarantine (one per affected member, its
    cohort/slot attached), winner — so a sweep's ledger and its
    telemetry share one timeline.  All values are host floats the
    scheduler already fetched for ranking."""
    specs = list(specs)
    x_train = np.asarray(x_train, np.float32)
    t_train = np.asarray(t_train, np.float32)
    x_eval = np.asarray(x_eval, np.float32)[:cfg.eval_samples]
    t_eval = np.asarray(t_eval, np.float32)[:cfg.eval_samples]

    ledger = Ledger(meta=dict(make_meta(tag), engine=cfg.engine,
                              rounds=cfg.rounds,
                              steps_per_round=cfg.steps_per_round,
                              n_candidates=len(specs)))
    key = jax.random.PRNGKey(cfg.seed)
    x_train_d = jnp.asarray(x_train)
    x_eval_d = jnp.asarray(x_eval)
    states: list[CohortState] = []
    for ci, cohort in enumerate(ch.bucket(specs)):
        spec0 = cohort.specs[0]
        if x_train.shape[1] != spec0.layers[0]:
            raise ValueError(
                f"cohort {ci}: input width {spec0.layers[0]} != data "
                f"width {x_train.shape[1]}")
        params = pop.init_population(jax.random.fold_in(key, ci),
                                     cohort.specs)
        records = [ledger.add(MemberRecord(
            member=mid, config=s.to_dict(), cohort=ci, slot=slot))
            for slot, (mid, s) in enumerate(zip(cohort.member_ids,
                                                cohort.specs))]
        states.append(CohortState(
            cohort=cohort, params=params,
            mom=pop.init_slots(params, cohort.specs),
            hyp=pop.hyp_table(cohort.specs),
            mask=jnp.ones((cohort.size,), jnp.float32),
            records=records,
            step=pop.make_population_step(spec0.act, engine=cfg.engine,
                                          fused=cfg.fused,
                                          with_health=cfg.quarantine),
            evaluate=pop.make_population_eval(spec0.act,
                                              engine=cfg.engine),
            # targets are constant per cohort: pad + upload once, slice
            # per minibatch on device
            t_train_pad=jnp.asarray(_pad_targets(t_train, spec0.layers[-1])),
            t_eval_pad=jnp.asarray(_pad_targets(t_eval, spec0.layers[-1]))))

    n_train = x_train.shape[0]
    global_step = 0
    n_live = len(specs)
    for rnd in range(cfg.rounds):
        # -- train: steps_per_round E-batched steps per cohort, shared data
        for _ in range(cfg.steps_per_round):
            bi = jnp.asarray(_batch_indices(
                n_train, min(cfg.batch_size, n_train), global_step))
            xb = jnp.take(x_train_d, bi, axis=0)
            for st in states:
                if not any(r.pruned_at is None for r in st.records):
                    continue        # whole cohort pruned: steps are no-ops
                if st.is_adam:
                    # stamp the per-step bias-correction time into every
                    # row: all live members step in lockstep, and on a
                    # quarantined (zeroed) row t is harmless — lr = 0 and
                    # the masked gradients are exact zeros, so the
                    # kernels still write w' = w, m' = v' = 0
                    from repro.kernels import block_sparse_matmul as bsm
                    st.hyp = st.hyp.at[:, bsm.COL_T].set(
                        jnp.float32(global_step + 1))
                out = st.step(
                    st.params, st.mom, st.hyp, st.mask, xb,
                    jnp.take(st.t_train_pad, bi, axis=0))
                if cfg.quarantine:
                    st.params, st.mom, losses, health = out
                    health = np.asarray(health)
                else:
                    st.params, st.mom, losses = out
                    health = None
                for rec, loss in zip(st.records, np.asarray(losses)):
                    if rec.pruned_at is None:
                        rec.loss_curve.append(float(loss))
                        if cfg.quarantine and (
                                not math.isfinite(float(loss))
                                or health[rec.slot] > 0):
                            _quarantine(st, rec, rnd, global_step,
                                        recorder=recorder)
            global_step += 1

        # -- eval: vectorized per-member loss, live members only ranked
        scored = []      # (width-normalized score, cohort_idx, slot)
        for ci, st in enumerate(states):
            if not any(r.pruned_at is None for r in st.records):
                continue
            ev = np.asarray(st.evaluate(st.params, x_eval_d, st.t_eval_pad))
            for rec, loss in zip(st.records, ev):
                if rec.pruned_at is None:
                    rec.eval_losses.append(float(loss))
                    rec.rounds_survived = rnd + 1
                    scored.append((_score(loss, st.out_width), ci, rec.slot))
        if recorder is not None and scored:
            recorder.emit(obs.SweepRound(
                action="rank", round=rnd,
                detail={"live": len(scored), "scores": [
                    {"member": states[ci].records[slot].member,
                     "cohort": ci, "slot": slot,
                     "score": s if math.isfinite(s) else None}
                    for s, ci, slot in sorted(scored)]}))

        # -- halve: keep the globally best keep_fraction, zero the rest
        if rnd < cfg.rounds - 1 and len(scored) > 1:
            scored.sort()
            n_keep = max(1, int(math.ceil(len(scored) * cfg.keep_fraction)))
            for sc, ci, slot in scored[n_keep:]:
                st = states[ci]
                st.mask = st.mask.at[slot].set(0.0)
                st.hyp = st.hyp.at[slot].set(0.0)
                st.records[slot].pruned_at = rnd
                if recorder is not None:
                    recorder.count("sweep.pruned")
                    recorder.emit(obs.SweepRound(
                        action="prune", round=rnd,
                        member=st.records[slot].member, cohort=ci,
                        slot=slot,
                        detail={"score": sc if math.isfinite(sc)
                                else None}))
            n_live = n_keep

    # -- winner: best width-normalized final eval score among survivors
    best = min(((_score(m.eval_losses[-1], st.out_width), m.member)
                for st in states for m in st.records
                if m.pruned_at is None and m.eval_losses), default=None)
    if best is not None and math.isfinite(best[0]):
        for m in ledger.members:
            m.winner = m.member == best[1]
        if recorder is not None:
            w = next(m for m in ledger.members if m.winner)
            recorder.emit(obs.SweepRound(
                action="winner", round=cfg.rounds - 1, member=w.member,
                cohort=w.cohort, slot=w.slot,
                detail={"score": best[0]}))
    ledger.meta["live_at_end"] = n_live
    ledger.meta["quarantined"] = sum(
        1 for m in ledger.members if m.quarantined_at is not None)
    return SweepResult(ledger=ledger, states=states)
