"""Cohort bucketing: candidate list -> same-structure populations.

The engine trains E candidates in one launch ONLY when they share every
static input of the kernels — layer widths (array shapes), block size,
pattern seed, activation, the optimizer kind (the accumulator-slot
layout and the epilogue's optimizer switch are static), and the
per-junction fan-in ``kb`` the density quantizes to
(``core/sparsity.block_fan_in``).  ``bucket`` groups an arbitrary
candidate list by exactly that ``structure_key``: each bucket is a
*cohort*, one stacked population, one jitted E-batched train step.
Hyperparameters (lr, momentum/b1, b2, eps, weight_decay) and init seeds
vary freely within a cohort — they ride the ``[E, HYP_K]`` hyp table
and the member axis, not the compile key.

Bucketing rules (pinned by tests/test_search.py):

* candidates whose densities round to the SAME kb at the same widths
  land in one cohort — they are literally the same structure;
* a different layer tuple, block size, pattern seed, activation, or a
  density that rounds to a different kb splits the cohort;
* candidate order is preserved: ``Cohort.member_ids[slot]`` maps a
  population slot back to the caller's candidate index (the ledger's
  lineage key).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.search.population import CandidateSpec, structure_key


@dataclasses.dataclass(frozen=True)
class Cohort:
    """One same-structure bucket: specs[slot] / member_ids[slot] are the
    population's slot-aligned candidate specs and original indices."""
    key: tuple
    specs: tuple[CandidateSpec, ...]
    member_ids: tuple[int, ...]

    @property
    def size(self) -> int:
        return len(self.specs)


def bucket(specs: Sequence[CandidateSpec]) -> list[Cohort]:
    """Group candidates into cohorts by structure_key, preserving first-
    appearance order of cohorts and candidate order within each."""
    groups: dict[tuple, list[int]] = {}
    for i, s in enumerate(specs):
        groups.setdefault(structure_key(s), []).append(i)
    return [Cohort(key=k,
                   specs=tuple(specs[i] for i in ids),
                   member_ids=tuple(ids))
            for k, ids in groups.items()]


@dataclasses.dataclass(frozen=True)
class QuantCohort:
    """Same idea for quantization configs (launch/quant_sweep.py): the E
    axis of one stacked quantized population is the set of configs that
    share array layouts — int8 bit width and scale granularity vary
    freely within a cohort (codes share the int8 container, scales share
    the [E, nob, kb] layout), while the fxp bit triplet and baked LUT
    activation are structural (int32 codes, per-format table)."""
    key: tuple
    configs: tuple
    member_ids: tuple[int, ...]

    @property
    def size(self) -> int:
        return len(self.configs)


def bucket_quant(configs: Sequence) -> list[QuantCohort]:
    """Group core/quantize.QuantConfig candidates by their quant
    structure key, preserving order like :func:`bucket`."""
    from repro.core.quantize import structure_key as quant_structure_key
    groups: dict[tuple, list[int]] = {}
    for i, q in enumerate(configs):
        groups.setdefault(quant_structure_key(q), []).append(i)
    return [QuantCohort(key=k,
                        configs=tuple(configs[i] for i in ids),
                        member_ids=tuple(ids))
            for k, ids in groups.items()]
