"""Optimized-HLO walker: per-device FLOPs / bytes / collective traffic.

Why not ``compiled.cost_analysis()`` alone?  XLA's HloCostAnalysis counts a
``while`` body ONCE — our models scan over layers, so raw cost_analysis
under-reports by ~n_layers (verified in tests/test_roofline.py).  This
walker builds the computation call graph (while bodies/conds, fusions,
calls), extracts scan trip counts from the loop conditions, and multiplies.

Counted per device (the module is post-SPMD-partitioning):
  * dot_flops      — 2 * prod(out) * prod(contracting)  for every dot,
                     times call-graph multiplicity.  Elementwise FLOPs are
                     excluded (they are roofline-irrelevant next to dots;
                     the memory term covers their traffic).
  * mem_bytes      — Σ (operand + output bytes) over *materializing* ops
                     (fusion boundaries, dots, copies, collectives,
                     dynamic-(update-)slice, ...), times multiplicity.
                     A fusion's internals stay in registers/VMEM — this is
                     the standard HBM-traffic approximation.
  * coll_bytes     — Σ output bytes of all-reduce / all-gather /
                     reduce-scatter / all-to-all / collective-permute
                     (+ async -start forms), times multiplicity; all-reduce
                     costs 2x (reduce-scatter + all-gather on a ring).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DT_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
# ops whose operands/outputs hit HBM (plus every fusion/dot/collective)
_MATERIALIZING = {
    "fusion", "dot", "copy", "convert", "broadcast", "transpose", "reshape",
    "dynamic-slice", "dynamic-update-slice", "concatenate", "slice", "pad",
    "reduce", "reduce-window", "scatter", "gather", "iota", "sort", "select",
    "convolution", "rng", "cholesky", "triangular-solve", "custom-call",
}


def type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DT_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


def shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    attrs: str
    inside: str = ""          # raw text between the opcode's parens


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    shapes: dict[str, str]          # symbol table: %name -> type string


def _parse_operands(rest: str) -> tuple[list[str], str, str]:
    """rest starts right after 'opcode(' — split operands at matching paren."""
    depth, i = 1, 0
    while i < len(rest) and depth:
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
        i += 1
    inside, attrs = rest[: i - 1], rest[i:]
    ops = re.findall(r"%([\w\.\-]+)", inside)
    return ops, attrs, inside


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(2), [], {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            # parameter decls inside signature etc.
            continue
        name, type_str, opcode = m.group(1), m.group(2), m.group(3)
        after = line[m.end():]
        operands, attrs, inside = _parse_operands(after)
        cur.instrs.append(Instr(name, type_str, opcode, operands, attrs, inside))
        cur.shapes[name] = type_str
    return comps


# slicing ops: the data operand's HBM traffic is the slice, not the tensor
_SLICING = {"dynamic-slice", "slice", "gather"}


def _effective_read_bytes(comp: Computation, operand: str) -> float:
    """HBM bytes read from ``operand`` within ``comp``.

    If every use is the data operand of a slicing op (the scan pattern:
    dynamic-slice of stacked layer params), charge the slice outputs, not
    the whole tensor — otherwise a [L, ...] stack gets charged L times per
    loop trip.  dynamic-update-slice writes charge the update operand."""
    total, any_full = 0.0, False
    used = False
    for ins in comp.instrs:
        for pos, o in enumerate(ins.operands):
            if o != operand:
                continue
            used = True
            if ins.opcode in _SLICING and pos == 0:
                total += type_bytes(ins.type_str)
            elif ins.opcode == "dynamic-update-slice" and pos == 0:
                upd = ins.operands[1] if len(ins.operands) > 1 else None
                total += type_bytes(comp.shapes.get(upd, "")) if upd else 0.0
            else:
                any_full = True
    if not used:
        return 0.0
    if any_full:
        return float(type_bytes(comp.shapes.get(operand, "")))
    return total


def _fusion_param_bytes(comps: dict, fused_name: str, arg_types: list[str]) -> float:
    """Effective read bytes of a fusion's args, slice-aware inside the body."""
    comp = comps.get(fused_name)
    if comp is None:
        return sum(type_bytes(t) for t in arg_types)
    # map parameter index -> internal name
    pnames: dict[int, str] = {}
    for ins in comp.instrs:
        if ins.opcode == "parameter":
            m = re.match(r"\s*(\d+)", ins.inside)
            if m:
                pnames[int(m.group(1))] = ins.name
    total = 0.0
    for i, t in enumerate(arg_types):
        pname = pnames.get(i)
        if pname is None:
            total += type_bytes(t)
            continue
        eff = _effective_read_bytes(comp, pname)
        total += min(eff if eff else type_bytes(t), type_bytes(t))
    return total


def parse_trip_counts(text: str) -> dict[str, int]:
    """cond computation name -> trip count, parsed from raw text."""
    counts: dict[str, int] = {}
    cur = None
    consts: list[int] = []
    for raw in text.splitlines():
        line = raw.strip()
        m = _COMP_HDR.match(line)
        if m and line.endswith("{"):
            cur, consts = m.group(2), []
            continue
        if line == "}":
            if cur is not None:
                counts[cur] = max(consts) if consts else 1
            cur = None
            continue
        mm = re.search(r"=\s*s32\[\]\s*constant\((\d+)\)", line)
        if mm:
            consts.append(int(mm.group(1)))
    return counts


@dataclasses.dataclass
class HloCosts:
    dot_flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_detail: dict = dataclasses.field(default_factory=dict)
    n_while: int = 0
    trip_counts: list = dataclasses.field(default_factory=list)

    def merge_scaled(self, other: "HloCosts", k: float):
        self.dot_flops += k * other.dot_flops
        self.mem_bytes += k * other.mem_bytes
        self.coll_bytes += k * other.coll_bytes
        for op, (b, c) in other.coll_detail.items():
            b0, c0 = self.coll_detail.get(op, (0.0, 0.0))
            self.coll_detail[op] = (b0 + k * b, c0 + k * c)


def analyze(text: str) -> HloCosts:
    comps = parse_module(text)
    trips = parse_trip_counts(text)
    memo: dict[tuple, HloCosts] = {}

    def comp_cost(name: str, stack: tuple = (), count_mem: bool = True) -> HloCosts:
        key = (name, count_mem)
        if key in memo:
            return memo[key]
        if name not in comps or name in stack:
            return HloCosts()
        c = comps[name]
        out = HloCosts()
        for ins in c.instrs:
            op = ins.opcode
            # ---- control flow / call graph
            if op == "while":
                m_body = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
                m_cond = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
                trip = trips.get(m_cond.group(1), 1) if m_cond else 1
                out.n_while += 1
                out.trip_counts.append(trip)
                if m_body:
                    sub = comp_cost(m_body.group(1), stack + (name,), count_mem)
                    out.merge_scaled(sub, trip)
                    out.n_while += sub.n_while
                continue
            if op in ("fusion", "call", "async-start"):
                m_calls = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", ins.attrs)
                if m_calls:
                    # fusion internals: flops/collectives yes, HBM traffic no
                    # (internal values live in registers/VMEM)
                    out.merge_scaled(
                        comp_cost(m_calls.group(1), stack + (name,),
                                  count_mem=(op != "fusion")), 1.0)
            if op == "conditional":
                for branch in re.findall(r"branch_computations=\{([^}]*)\}", ins.attrs):
                    for b in re.findall(r"%([\w\.\-]+)", branch):
                        out.merge_scaled(comp_cost(b, stack + (name,), count_mem), 1.0)
                m2 = re.findall(r"(?:true_computation|false_computation)=%?([\w\.\-]+)",
                                ins.attrs)
                for b in m2:
                    out.merge_scaled(comp_cost(b, stack + (name,), count_mem), 1.0)
            # ---- dot flops
            if op == "dot":
                dims_out = shape_dims(ins.type_str)
                flops = 2.0
                for d in dims_out:
                    flops *= d
                m_c = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
                if m_c and ins.operands:
                    lhs_shape = shape_dims(c.shapes.get(ins.operands[0], ""))
                    for ci in m_c.group(1).split(","):
                        if ci and lhs_shape:
                            idx = int(ci)
                            if idx < len(lhs_shape):
                                flops *= lhs_shape[idx]
                out.dot_flops += flops
            # ---- collectives
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVES:
                b = type_bytes(ins.type_str)
                factor = 2.0 if base == "all-reduce" else 1.0
                out.coll_bytes += factor * b
                b0, c0 = out.coll_detail.get(base, (0.0, 0.0))
                out.coll_detail[base] = (b0 + factor * b, c0 + 1)
            # ---- memory traffic at materialization boundaries (slice-aware)
            if count_mem and (op in _MATERIALIZING or base in COLLECTIVES
                              or op == "dot"):
                if op in _SLICING:
                    b = 2.0 * type_bytes(ins.type_str)       # read + write slice
                elif op == "dynamic-update-slice":
                    upd = ins.operands[1] if len(ins.operands) > 1 else None
                    b = 2.0 * type_bytes(c.shapes.get(upd, "")) if upd else 0.0
                elif op == "fusion":
                    m_calls = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
                    arg_types = [c.shapes.get(o, "") for o in ins.operands]
                    b = type_bytes(ins.type_str)
                    if m_calls:
                        b += _fusion_param_bytes(comps, m_calls.group(1), arg_types)
                    else:
                        b += sum(type_bytes(t) for t in arg_types)
                else:
                    b = type_bytes(ins.type_str)
                    for o in ins.operands:
                        b += type_bytes(c.shapes.get(o, ""))
                out.mem_bytes += b
        memo[key] = out
        return out

    entry = None
    for raw in text.splitlines():
        s = raw.strip()
        if s.startswith("ENTRY"):
            m = _COMP_HDR.match(s)
            if m:
                entry = m.group(2)
                break
    if entry is None:
        # fall back: biggest computation
        entry = max(comps, key=lambda k: len(comps[k].instrs))
    return comp_cost(entry)
