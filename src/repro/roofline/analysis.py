"""Three-term roofline from a compiled dry-run artifact (TPU v5e constants).

    compute    = dot_FLOPs_per_device / PEAK_FLOPS
    memory     = HBM_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

The compiled module is post-SPMD, so all walker numbers are already
per-device — chips divide out.  ``raw cost_analysis`` values are recorded
alongside for cross-checking (they under-count scan bodies; see hlo.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.roofline import hlo as hlo_mod

# TPU v5e, per chip
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link


@dataclasses.dataclass
class Roofline:
    dot_flops: float
    mem_bytes: float
    coll_bytes: float
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    coll_detail: dict
    raw_cost: dict
    memory_stats: dict
    n_while: int
    trip_counts: list
    spurious_f32_bytes: int = 0   # XLA-CPU loop widening artifact (see below)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def widened_f32_loop_state(text: str) -> int:
    """Bytes of f32 while-loop state that duplicate a bf16 twin.

    The CPU HLO pipeline widens some bf16 saved-carry stacks to f32 inside
    the autodiff loops (verified minimal repro in tests/test_roofline.py:
    the jaxpr stores bf16; the optimized CPU module carries BOTH a bf16 and
    an f32 copy, each slice converted straight back to bf16).  This is a
    backend artifact, not program-required memory — per-device footprints
    are reported raw and corrected (EXPERIMENTS.md §Dry-run note)."""
    import re
    bf16_dims: set[str] = set()
    f32_sizes: dict[str, int] = {}
    for m in re.finditer(r"=\s*\(([^)]*)\)\s*while\(", text):
        for dt, dims in re.findall(r"(\w+)\[([\d,]+)\]", m.group(1)):
            if len(dims.split(",")) < 3:
                continue
            if dt == "bf16":
                bf16_dims.add(dims)
            elif dt == "f32":
                n = 1
                for d in dims.split(","):
                    n *= int(d)
                f32_sizes[dims] = max(f32_sizes.get(dims, 0), 4 * n)
    return sum(b for dims, b in f32_sizes.items() if dims in bf16_dims)


def analyze_compiled(compiled, lowered=None) -> Roofline:
    text = compiled.as_text()
    costs = hlo_mod.analyze(text)
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # some backends return [dict]
        ca = ca[0]
    raw = {k: float(v) for k, v in ca.items()
           if k in ("flops", "bytes accessed", "transcendentals")} if ca else {}
    try:
        ms = compiled.memory_analysis()
        mem_stats = {
            "argument_bytes": int(ms.argument_size_in_bytes),
            "output_bytes": int(ms.output_size_in_bytes),
            "temp_bytes": int(ms.temp_size_in_bytes),
            "alias_bytes": int(ms.alias_size_in_bytes),
        }
    except Exception:  # pragma: no cover
        mem_stats = {}

    t_c = costs.dot_flops / PEAK_FLOPS
    t_m = costs.mem_bytes / HBM_BW
    t_l = costs.coll_bytes / ICI_BW
    dominant = max(("compute", t_c), ("memory", t_m), ("collective", t_l),
                   key=lambda kv: kv[1])[0]
    return Roofline(
        dot_flops=costs.dot_flops, mem_bytes=costs.mem_bytes,
        coll_bytes=costs.coll_bytes, t_compute=t_c, t_memory=t_m,
        t_collective=t_l, dominant=dominant,
        coll_detail={k: {"bytes": b, "count": c}
                     for k, (b, c) in costs.coll_detail.items()},
        raw_cost=raw, memory_stats=mem_stats,
        n_while=costs.n_while, trip_counts=costs.trip_counts,
        spurious_f32_bytes=widened_f32_loop_state(text))


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE), D = tokens processed.

    For decode shapes D = global_batch (one token each); train/prefill
    D = seq*batch.  Training costs 3x the forward pass (fwd + 2x bwd)."""
    n = cfg.active_param_count()
    if shape.kind == "decode":
        toks = shape.global_batch
        return 2.0 * n * toks
    toks = shape.tokens
    mult = 3.0 if shape.kind == "train" else 1.0
    return 2.0 * n * toks * mult


def useful_fraction(cfg, shape, per_device_dot_flops: float, n_chips: int) -> float:
    """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is 'useful'."""
    total_hlo = per_device_dot_flops * n_chips
    mf = model_flops(cfg, shape)
    return mf / total_hlo if total_hlo else 0.0
