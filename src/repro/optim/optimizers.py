"""Minimal optimizer library (no optax in this environment).

Optimizers are (init, update) pairs over pytrees.  Integer leaves — the
pre-defined sparsity patterns (``idx``/``rev_ob``/``rev_t``) — are
*structural*, not trainable: they are skipped by construction, mirroring
the paper's fixed connectivity.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


def _is_trainable(leaf) -> bool:
    return jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact)


def trainable_mask(params):
    return jax.tree.map(_is_trainable, params)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def clip_by_global_norm(grads, max_norm: float):
    leaves = [g for g in jax.tree.leaves(grads) if _is_trainable(g)]
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(
        lambda g: g * scale if _is_trainable(g) else g, grads), gn


def sgd(lr_fn: Callable[[jax.Array], jax.Array]) -> Optimizer:
    """Plain gradient descent — the paper's eq. (3) update rule."""
    def init(params):
        return ()

    def update(grads, state, params, step):
        lr = lr_fn(step)
        new_params = jax.tree.map(
            lambda p, g: (p - lr * g.astype(p.dtype)) if _is_trainable(p) else p,
            params, grads)
        return new_params, state
    return Optimizer(init, update)


def adam(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0,
         grad_clip: float | None = 1.0, master_copy: bool = False) -> Optimizer:
    """Adam with optional fp32 master copies.

    master_copy=True supports bf16-resident params: the model tree (what the
    compute graph — and therefore the FSDP all-gathers — sees) stays bf16,
    while full-precision masters live in the optimizer state.  XLA's SPMD
    partitioner re-orders convert-after-gather, so casting inside the step
    cannot shrink gather traffic — storing bf16 params is the reliable way
    (§Perf iteration C1)."""
    def init(params):
        zeros = lambda p: (jnp.zeros_like(p, dtype=jnp.float32)
                           if _is_trainable(p) else jnp.zeros((), jnp.float32))
        st = {"m": jax.tree.map(zeros, params),
              "v": jax.tree.map(zeros, params)}
        if master_copy:
            st["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32) if _is_trainable(p)
                else jnp.zeros((), jnp.float32), params)
        return st

    def update(grads, state, params, step):
        if grad_clip is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - jnp.power(b1, t)
        c2 = 1.0 - jnp.power(b2, t)

        def upd(p, g, m, v, master):
            if not _is_trainable(p):
                return p, m, v, master
            gf = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * jnp.square(gf)
            ref = master if master_copy else p.astype(jnp.float32)
            step_ = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                step_ = step_ + weight_decay * ref
            new_master = ref - lr * step_
            return (new_master.astype(p.dtype), m, v,
                    new_master if master_copy else master)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_ma = (treedef.flatten_up_to(state["master"]) if master_copy
                   else [None] * len(flat_p))
        out = [upd(*a) for a in zip(flat_p, flat_g, flat_m, flat_v, flat_ma)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_st = {"m": treedef.unflatten([o[1] for o in out]),
                  "v": treedef.unflatten([o[2] for o in out])}
        if master_copy:
            new_st["master"] = treedef.unflatten([o[3] for o in out])
        return new_p, new_st
    return Optimizer(init, update)
