"""Minimal optimizer library (no optax in this environment).

Optimizers are (init, update) pairs over pytrees.  Integer leaves — the
pre-defined sparsity patterns (``idx``/``rev_ob``/``rev_t``) — are
*structural*, not trainable: they are skipped by construction, mirroring
the paper's fixed connectivity.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


def _is_trainable(leaf) -> bool:
    return jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact)


def trainable_mask(params):
    return jax.tree.map(_is_trainable, params)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def clip_by_global_norm(grads, max_norm: float):
    leaves = [g for g in jax.tree.leaves(grads) if _is_trainable(g)]
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(
        lambda g: g * scale if _is_trainable(g) else g, grads), gn


def sgd(lr_fn: Callable[[jax.Array], jax.Array]) -> Optimizer:
    """Plain gradient descent — the paper's eq. (3) update rule."""
    def init(params):
        return ()

    def update(grads, state, params, step):
        lr = lr_fn(step)
        new_params = jax.tree.map(
            lambda p, g: (p - lr * g.astype(p.dtype)) if _is_trainable(p) else p,
            params, grads)
        return new_params, state
    return Optimizer(init, update)


@dataclasses.dataclass(frozen=True)
class FusedSGD(Optimizer):
    """SGD(+momentum) that can run fused with the backward pass.

    ``update`` is the ordinary TWO-PASS reference (clip → momentum →
    apply, tree-mapped over materialized gradients) — the path the jnp
    engine, dry-run and any ineligible config use.  A fused train step
    (train/steps.py, behind ``ArchConfig.fused_update``) instead injects
    ``hyp(step)`` + the momentum buffers into the junction dicts before
    differentiating, lets the ``junction_train_update`` kernels apply the
    update in the backward epilogue, and calls :meth:`merge` to adopt the
    updated junction leaves and tree-map only the dense remainder.
    ``grad_clip`` is incompatible with fusing (it needs the full gradient
    tree first) — setting it forces the two-pass path.
    """
    lr_fn: Callable[[jax.Array], jax.Array] = None
    momentum: float = 0.0
    grad_clip: float | None = None

    def hyp(self, step) -> jax.Array:
        """The (2,)-f32 [lr, momentum] operand the update kernels stream
        through scalar prefetch."""
        lr = jnp.asarray(self.lr_fn(step), jnp.float32)
        return jnp.stack([lr, jnp.asarray(self.momentum, jnp.float32)])

    def merge(self, grads, state, params, step, lr_scale=None):
        """Fused-step merge: ``grads`` is the cotangent tree of the
        *augmented* params (core/sparse_linear.inject_update_ctx) — its
        junction weight/momentum leaves already ARE the updated values
        (and its injected health leaves, absent from ``params``, are
        skipped by construction); every other trainable leaf still
        carries a real gradient and gets the same two-pass formula
        applied here.  ``lr_scale`` (guardian backoff) must match the
        factor already folded into the injected hyp table so dense and
        junction leaves back off together."""
        from repro.core import sparse_linear as sl
        lr = self.lr_fn(step)
        if lr_scale is not None:
            lr = lr * lr_scale
        mom = state["mom"] if self.momentum else None

        def dense(p, g, m):
            if not _is_trainable(p):
                return p, m
            mv = g.astype(jnp.float32)
            if self.momentum:
                mv = self.momentum * m + mv
            return (p.astype(jnp.float32) - lr * mv).astype(p.dtype), mv

        def rec(g, p, m):
            if isinstance(p, dict):
                junction = sl.is_junction(p)
                new_p, new_m = {}, {}
                for k, v in p.items():
                    mk = m[k] if m is not None else None
                    if isinstance(v, (dict, list, tuple)):
                        new_p[k], new_m[k] = rec(g[k], v, mk)
                    elif (junction and k in sl.FUSED_MOM
                          and _is_trainable(v)):
                        new_p[k] = g[k]                       # updated param
                        new_m[k] = (g[sl.FUSED_MOM[k]]        # updated buffer
                                    if m is not None else None)
                    else:
                        new_p[k], new_m[k] = dense(v, g[k], mk)
                return new_p, new_m
            if isinstance(p, (list, tuple)):
                pairs = [rec(g[i], v, m[i] if m is not None else None)
                         for i, v in enumerate(p)]
                return (type(p)(a for a, _ in pairs),
                        type(p)(b for _, b in pairs))
            return dense(p, g, m)

        new_params, new_mom = rec(grads, params, mom)
        return new_params, ({"mom": new_mom} if self.momentum else state)


def fused_sgd(lr_fn: Callable[[jax.Array], jax.Array], momentum: float = 0.0,
              grad_clip: float | None = None) -> FusedSGD:
    """SGD with optional momentum, fusable into the backward kernels.

    Reference semantics (what both paths compute, in fp32):
        m' = momentum * m + g
        p' = (p - lr * m').astype(p.dtype)
    Momentum accumulators are fp32 even for bf16 params."""
    def init(params):
        if not momentum:
            return ()
        zeros = lambda p: (jnp.zeros(jnp.shape(p), jnp.float32)
                           if _is_trainable(p) else jnp.zeros((), jnp.float32))
        return {"mom": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        if grad_clip is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        lr = lr_fn(step)
        if momentum:
            mv = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32)
                if _is_trainable(g) else m, state["mom"], grads)
            new_params = jax.tree.map(
                lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype)
                if _is_trainable(p) else p, params, mv)
            return new_params, {"mom": mv}
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype)
            if _is_trainable(p) else p, params, grads)
        return new_params, state
    return FusedSGD(init=init, update=update, lr_fn=lr_fn,
                    momentum=momentum, grad_clip=grad_clip)


def adam(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0,
         grad_clip: float | None = 1.0, master_copy: bool = False) -> Optimizer:
    """Adam with optional fp32 master copies.

    master_copy=True supports bf16-resident params: the model tree (what the
    compute graph — and therefore the FSDP all-gathers — sees) stays bf16,
    while full-precision masters live in the optimizer state.  XLA's SPMD
    partitioner re-orders convert-after-gather, so casting inside the step
    cannot shrink gather traffic — storing bf16 params is the reliable way
    (§Perf iteration C1)."""
    def init(params):
        zeros = lambda p: (jnp.zeros_like(p, dtype=jnp.float32)
                           if _is_trainable(p) else jnp.zeros((), jnp.float32))
        st = {"m": jax.tree.map(zeros, params),
              "v": jax.tree.map(zeros, params)}
        if master_copy:
            st["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32) if _is_trainable(p)
                else jnp.zeros((), jnp.float32), params)
        return st

    def update(grads, state, params, step):
        if grad_clip is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - jnp.power(b1, t)
        c2 = 1.0 - jnp.power(b2, t)

        def upd(p, g, m, v, master):
            if not _is_trainable(p):
                return p, m, v, master
            gf = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * jnp.square(gf)
            ref = master if master_copy else p.astype(jnp.float32)
            step_ = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                step_ = step_ + weight_decay * ref
            new_master = ref - lr * step_
            return (new_master.astype(p.dtype), m, v,
                    new_master if master_copy else master)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_ma = (treedef.flatten_up_to(state["master"]) if master_copy
                   else [None] * len(flat_p))
        out = [upd(*a) for a in zip(flat_p, flat_g, flat_m, flat_v, flat_ma)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_st = {"m": treedef.unflatten([o[1] for o in out]),
                  "v": treedef.unflatten([o[2] for o in out])}
        if master_copy:
            new_st["master"] = treedef.unflatten([o[3] for o in out])
        return new_p, new_st
    return Optimizer(init, update)
