"""Minimal optimizer library (no optax in this environment).

Optimizers are (init, update) pairs over pytrees.  Integer leaves — the
pre-defined sparsity patterns (``idx``/``rev_ob``/``rev_t``) — are
*structural*, not trainable: they are skipped by construction, mirroring
the paper's fixed connectivity.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


def _is_trainable(leaf) -> bool:
    return jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact)


def trainable_mask(params):
    return jax.tree.map(_is_trainable, params)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def global_norm_scale(grads, max_norm: float):
    """(scale, global_norm) of the trainable leaves — THE clip formula,
    shared by ``clip_by_global_norm`` (two-pass) and the fused path's
    norm pre-pass (train/steps.py folds ``scale`` into the hyp table's
    gs column), so the two paths can never drift."""
    leaves = [g for g in jax.tree.leaves(grads) if _is_trainable(g)]
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    return jnp.minimum(1.0, max_norm / (gn + 1e-9)), gn


def clip_by_global_norm(grads, max_norm: float):
    scale, gn = global_norm_scale(grads, max_norm)
    return jax.tree.map(
        lambda g: g * scale if _is_trainable(g) else g, grads), gn


def sgd(lr_fn: Callable[[jax.Array], jax.Array]) -> Optimizer:
    """Plain gradient descent — the paper's eq. (3) update rule."""
    def init(params):
        return ()

    def update(grads, state, params, step):
        lr = lr_fn(step)
        new_params = jax.tree.map(
            lambda p, g: (p - lr * g.astype(p.dtype)) if _is_trainable(p) else p,
            params, grads)
        return new_params, state
    return Optimizer(init, update)


@dataclasses.dataclass(frozen=True)
class FusedOptimizer(Optimizer):
    """Contract of an optimizer that can run fused with the backward pass.

    ``update`` is always the ordinary TWO-PASS reference (tree-mapped over
    materialized gradients) — the path the jnp engine, dry-run and any
    ineligible config use.  A fused train step (train/steps.py, behind
    ``ArchConfig.fused_update``) instead:

      1. streams :meth:`hyp`'s ``(HYP_K,)`` registry row ([lr, b1, b2,
         eps, wd, t, gs] — ``kernels/block_sparse_matmul.HYP_COLS``) into
         the update kernels via scalar prefetch,
      2. injects :meth:`slots`' accumulator trees into the junction dicts
         (core/sparse_linear.inject_update_ctx) before differentiating,
         so the backward epilogue updates weights + slots in place, and
      3. calls :meth:`merge` to adopt the updated junction leaves and
         tree-map the same reference formula over the dense remainder.

    Subclasses define ``slot_keys`` (which state entries are in-kernel
    accumulators, in the kernels' slot order), ``hyp``, and ``_dense_fn``
    (the per-leaf reference step).  ``grad_clip`` no longer forces the
    two-pass path: steps.py runs a norm pre-pass and folds the clip scale
    into the hyp row's gs column (and into ``merge``'s ``grad_scale``).
    """
    lr_fn: Callable[[jax.Array], jax.Array] = None
    grad_clip: float | None = None

    def slot_keys(self) -> tuple[str, ...]:
        """State keys holding in-kernel accumulator trees, in the
        kernels' slot order (slot 0 = SGD momentum / Adam m, ...)."""
        raise NotImplementedError

    def slots(self, state) -> tuple:
        """The accumulator trees to inject, kernel slot order."""
        return tuple(state[k] for k in self.slot_keys())

    def hyp(self, step) -> jax.Array:
        """The (HYP_K,)-f32 registry row the update kernels stream
        through scalar prefetch."""
        raise NotImplementedError

    def _dense_fn(self, step, lr_scale, grad_scale):
        """leaf(p, g, slot_vals) -> (p', *slot_vals') — the reference
        update applied to non-junction trainable leaves in merge()."""
        raise NotImplementedError

    def merge(self, grads, state, params, step, lr_scale=None,
              grad_scale=None):
        """Fused-step merge: ``grads`` is the cotangent tree of the
        *augmented* params (core/sparse_linear.inject_update_ctx) — its
        junction weight/slot leaves already ARE the updated values (and
        its injected health leaves, absent from ``params``, are skipped
        by construction); every other trainable leaf still carries a
        real gradient and gets the same two-pass formula applied here.
        ``lr_scale`` (guardian backoff) and ``grad_scale`` (global-norm
        clip) must match the factors already folded into the injected
        hyp table's lr / gs columns so dense and junction leaves move
        together."""
        from repro.core import sparse_linear as sl
        keys = self.slot_keys()
        ms = tuple(state[k] for k in keys)
        dense = self._dense_fn(step, lr_scale, grad_scale)
        nslots = len(ms)

        def rec(g, p, ms):
            if isinstance(p, dict):
                junction = sl.is_junction(p)
                new_p = {}
                new_ms = tuple({} for _ in range(nslots))
                for k, v in p.items():
                    mks = tuple(m[k] for m in ms)
                    if isinstance(v, (dict, list, tuple)):
                        out = rec(g[k], v, mks)
                    elif (junction and k in sl.FUSED_MOM
                          and _is_trainable(v)):
                        # kernel already wrote param + slot buffers
                        out = (g[k],) + tuple(
                            g[names[k]]
                            for names in sl.FUSED_SLOT_NAMES[:nslots])
                    else:
                        out = dense(v, g[k], mks)
                    new_p[k] = out[0]
                    for i in range(nslots):
                        new_ms[i][k] = out[1 + i]
                return (new_p,) + new_ms
            if isinstance(p, (list, tuple)):
                subs = [rec(g[i], v, tuple(m[i] for m in ms))
                        for i, v in enumerate(p)]
                return (type(p)(s[0] for s in subs),) + tuple(
                    type(p)(s[1 + i] for s in subs)
                    for i in range(nslots))
            return dense(p, g, ms)

        out = rec(grads, params, ms)
        if not keys:
            return out[0], state
        new_state = dict(state)
        for i, k in enumerate(keys):
            new_state[k] = out[1 + i]
        return out[0], new_state


@dataclasses.dataclass(frozen=True)
class FusedSGD(FusedOptimizer):
    """SGD(+momentum) on the :class:`FusedOptimizer` contract.

    Reference semantics (what both paths compute, in fp32):
        m' = momentum * m + gs * g
        p' = (p - lr * m').astype(p.dtype)
    """
    momentum: float = 0.0

    def slot_keys(self):
        return ("mom",) if self.momentum else ()

    def hyp(self, step) -> jax.Array:
        from repro.kernels import block_sparse_matmul as bsm
        lr = jnp.asarray(self.lr_fn(step), jnp.float32)
        row = [jnp.float32(0.0)] * bsm.HYP_K
        row[bsm.COL_LR] = lr
        row[bsm.COL_B1] = jnp.float32(self.momentum)
        row[bsm.COL_GS] = jnp.float32(1.0)
        return jnp.stack(row)

    def _dense_fn(self, step, lr_scale, grad_scale):
        lr = self.lr_fn(step)
        if lr_scale is not None:
            lr = lr * lr_scale

        def dense(p, g, ms):
            if not _is_trainable(p):
                return (p,) + ms
            mv = g.astype(jnp.float32)
            if grad_scale is not None:
                mv = grad_scale * mv
            if self.momentum:
                mv = self.momentum * ms[0] + mv
                return (p.astype(jnp.float32) - lr * mv).astype(p.dtype), mv
            return ((p.astype(jnp.float32) - lr * mv).astype(p.dtype),)
        return dense


def fused_sgd(lr_fn: Callable[[jax.Array], jax.Array], momentum: float = 0.0,
              grad_clip: float | None = None) -> FusedSGD:
    """SGD with optional momentum, fusable into the backward kernels.

    Reference semantics (what both paths compute, in fp32):
        m' = momentum * m + g
        p' = (p - lr * m').astype(p.dtype)
    Momentum accumulators are fp32 even for bf16 params."""
    def init(params):
        if not momentum:
            return ()
        zeros = lambda p: (jnp.zeros(jnp.shape(p), jnp.float32)
                           if _is_trainable(p) else jnp.zeros((), jnp.float32))
        return {"mom": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        if grad_clip is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        lr = lr_fn(step)
        if momentum:
            mv = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32)
                if _is_trainable(g) else m, state["mom"], grads)
            new_params = jax.tree.map(
                lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype)
                if _is_trainable(p) else p, params, mv)
            return new_params, {"mom": mv}
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype)
            if _is_trainable(p) else p, params, grads)
        return new_params, state
    return FusedSGD(init=init, update=update, lr_fn=lr_fn,
                    momentum=momentum, grad_clip=grad_clip)


@dataclasses.dataclass(frozen=True)
class FusedAdam(FusedOptimizer):
    """Adam on the :class:`FusedOptimizer` contract.

    ``update`` delegates to the two-pass :func:`adam` — THE reference the
    fused path must match.  Slot 0 is the first moment (m), slot 1 the
    second (v), both fp32 even for bf16 params.  The hyp row carries the
    per-step bias-correction time t = step + 1; weight decay is the
    decoupled-into-the-step form ``step += wd * p`` the reference uses.
    """
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0

    def slot_keys(self):
        return ("m", "v")

    def hyp(self, step) -> jax.Array:
        from repro.kernels import block_sparse_matmul as bsm
        row = [jnp.float32(0.0)] * bsm.HYP_K
        row[bsm.COL_LR] = jnp.asarray(self.lr_fn(step), jnp.float32)
        row[bsm.COL_B1] = jnp.float32(self.b1)
        row[bsm.COL_B2] = jnp.float32(self.b2)
        row[bsm.COL_EPS] = jnp.float32(self.eps)
        row[bsm.COL_WD] = jnp.float32(self.weight_decay)
        row[bsm.COL_T] = jnp.asarray(step, jnp.float32) + 1.0
        row[bsm.COL_GS] = jnp.float32(1.0)
        return jnp.stack(row)

    def _dense_fn(self, step, lr_scale, grad_scale):
        lr = self.lr_fn(step)
        if lr_scale is not None:
            lr = lr * lr_scale
        t = jnp.asarray(step, jnp.float32) + 1.0
        c1 = 1.0 - jnp.power(self.b1, t)
        c2 = 1.0 - jnp.power(self.b2, t)

        def dense(p, g, ms):
            if not _is_trainable(p):
                return (p,) + ms
            gf = g.astype(jnp.float32)
            if grad_scale is not None:
                gf = grad_scale * gf
            m = self.b1 * ms[0] + (1 - self.b1) * gf
            v = self.b2 * ms[1] + (1 - self.b2) * jnp.square(gf)
            ref = p.astype(jnp.float32)
            step_ = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            if self.weight_decay:
                step_ = step_ + self.weight_decay * ref
            return (ref - lr * step_).astype(p.dtype), m, v
        return dense


def fused_adam(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0,
               grad_clip: float | None = None) -> FusedAdam:
    """Adam, fusable into the backward kernels.

    ``update`` IS the two-pass :func:`adam` (master_copy=False) so the
    fused path has an exact reference; note the different ``grad_clip``
    default (None here, 1.0 there) — pass it explicitly when comparing."""
    ref = adam(lr_fn, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
               grad_clip=grad_clip, master_copy=False)
    return FusedAdam(init=ref.init, update=ref.update, lr_fn=lr_fn,
                     grad_clip=grad_clip, b1=b1, b2=b2, eps=eps,
                     weight_decay=weight_decay)


def adam(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0,
         grad_clip: float | None = 1.0, master_copy: bool = False) -> Optimizer:
    """Adam with optional fp32 master copies.

    master_copy=True supports bf16-resident params: the model tree (what the
    compute graph — and therefore the FSDP all-gathers — sees) stays bf16,
    while full-precision masters live in the optimizer state.  XLA's SPMD
    partitioner re-orders convert-after-gather, so casting inside the step
    cannot shrink gather traffic — storing bf16 params is the reliable way
    (§Perf iteration C1)."""
    def init(params):
        zeros = lambda p: (jnp.zeros_like(p, dtype=jnp.float32)
                           if _is_trainable(p) else jnp.zeros((), jnp.float32))
        st = {"m": jax.tree.map(zeros, params),
              "v": jax.tree.map(zeros, params)}
        if master_copy:
            st["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32) if _is_trainable(p)
                else jnp.zeros((), jnp.float32), params)
        return st

    def update(grads, state, params, step):
        if grad_clip is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - jnp.power(b1, t)
        c2 = 1.0 - jnp.power(b2, t)

        def upd(p, g, m, v, master):
            if not _is_trainable(p):
                return p, m, v, master
            gf = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * jnp.square(gf)
            ref = master if master_copy else p.astype(jnp.float32)
            step_ = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                step_ = step_ + weight_decay * ref
            new_master = ref - lr * step_
            return (new_master.astype(p.dtype), m, v,
                    new_master if master_copy else master)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_ma = (treedef.flatten_up_to(state["master"]) if master_copy
                   else [None] * len(flat_p))
        out = [upd(*a) for a in zip(flat_p, flat_g, flat_m, flat_v, flat_ma)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_st = {"m": treedef.unflatten([o[1] for o in out]),
                  "v": treedef.unflatten([o[2] for o in out])}
        if master_copy:
            new_st["master"] = treedef.unflatten([o[3] for o in out])
        return new_p, new_st
    return Optimizer(init, update)
