from repro.optim.optimizers import (FusedAdam, FusedOptimizer, FusedSGD,
                                    Optimizer, adam, fused_adam, fused_sgd,
                                    sgd, clip_by_global_norm,
                                    global_norm_scale, trainable_mask)
from repro.optim.schedule import (paper_halving_schedule, cosine_schedule,
                                  constant_schedule)
