from repro.optim.optimizers import (FusedSGD, Optimizer, adam, fused_sgd,
                                    sgd, clip_by_global_norm, trainable_mask)
from repro.optim.schedule import (paper_halving_schedule, cosine_schedule,
                                  constant_schedule)
