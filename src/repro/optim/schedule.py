"""Learning-rate schedules.

``paper_halving_schedule`` is the paper's exact recipe (Sec. III-B): eta
starts at 2^-3, halves after the first 2 epochs, then every 4 epochs, floored
at 2^-7.  Keeping eta a power of two turns the eq. (3) multiplies into bit
shifts on the FPGA; here it keeps the fixed-point update exact on the
(b_w, b_n, b_f) grid.
"""
from __future__ import annotations

import jax.numpy as jnp


def paper_halving_schedule(steps_per_epoch: int):
    def lr(step):
        epoch = step // steps_per_epoch
        halvings = jnp.where(epoch < 2, 0, 1 + (epoch - 2) // 4)
        exp = jnp.clip(3 + halvings, 3, 7)
        return jnp.power(2.0, -exp.astype(jnp.float32))
    return lr


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.0):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(1, warmup)
        prog = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return lr


def constant_schedule(v: float):
    return lambda step: jnp.full((), v, jnp.float32)
