"""The shared artifact stamp: one meta schema for every results file.

``BENCH_*.json`` (benchmarks/run.py) and ``SWEEP_*.json``
(search/ledger.py) carry the same ``meta`` block so artifacts are
commit-attributable and comparable across PRs regardless of kind:

    {git_sha, backend, jax_version, tag, timestamp}

Both writers stamp through :func:`artifact_meta` — the schema and the
-dirty detection live HERE, nowhere else.
"""
from __future__ import annotations

import subprocess
import time


def git_sha() -> str:
    """Short HEAD sha, with a -dirty marker when the tree has uncommitted
    changes — numbers measured on a dirty tree must not be attributed to
    the clean commit."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10, check=True,
        ).stdout.strip()
        dirty = subprocess.run(
            ["git", "status", "--porcelain"],
            capture_output=True, text=True, timeout=10, check=True,
        ).stdout.strip()
        return f"{sha}-dirty" if dirty else sha
    except Exception:
        return "unknown"


def artifact_meta(tag: str) -> dict:
    import jax  # deferred: keep --help paths jax-free
    return {
        "git_sha": git_sha(),
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "tag": tag,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }
