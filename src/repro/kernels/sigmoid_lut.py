"""Pallas LUT activation — the paper's BRAM sigmoid tables in VMEM.

The FPGA pre-computes sigma / sigma' for all 2^b_w codes (4096 entries at
b_w=12; Sec. III-D-1) and looks activations up instead of evaluating exp.
On TPU the 4096-entry fp32 table is 16 KiB — it sits in VMEM for the whole
kernel and every element of the tile gathers from it.  (DESIGN.md notes
that on TPU the VPU's native exp is competitive; this kernel exists for
bit-exact parity with the hardware and as the repro's activation path.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(code_ref, table_ref, o_ref):
    codes = code_ref[...]
    o_ref[...] = jnp.take(table_ref[...], codes, axis=0)


def lut_lookup(codes, table, *, bm: int = 256, interpret: bool = False):
    """codes [M, N] int32 in [0, len(table)); table [T] f32 -> [M, N] f32.

    A ragged M pads to the row tile and slices back (padding code 0 just
    gathers table[0] into rows that are discarded)."""
    M, N = codes.shape
    T = table.shape[0]
    pm = (-M) % bm
    if pm:
        codes = jnp.pad(codes, ((0, pm), (0, 0)))
    Mp = M + pm
    grid = (Mp // bm,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, N), lambda m: (m, 0)),
            pl.BlockSpec((T,), lambda m: (0,)),   # whole table resident
        ],
        out_specs=pl.BlockSpec((bm, N), lambda m: (m, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, N), table.dtype),
        interpret=interpret,
    )(codes, table)
    return out[:M] if pm else out
