"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ----------------------------------------------------------- block-sparse
def block_sparse_matmul(x, w, idx):
    """x [M, nib*bs]; w [nob, kb, bs, bs]; idx [nob, kb] -> y [M, nob*bs]."""
    nob, kb, bs, _ = w.shape
    M = x.shape[0]
    xb = x.reshape(M, -1, bs)
    xg = jnp.take(xb, idx.reshape(-1), axis=1).reshape(M, nob, kb, bs)
    y = jnp.einsum("mokb,okbc->moc", xg, w.astype(x.dtype),
                   preferred_element_type=jnp.float32)
    return y.reshape(M, nob * bs).astype(x.dtype)


def block_sparse_dx(dy, w, idx, n_in_blocks):
    """dy [M, nob*bs] -> dx [M, nib*bs] (scatter-add through the pattern)."""
    nob, kb, bs, _ = w.shape
    M = dy.shape[0]
    dyb = dy.reshape(M, nob, bs)
    # contributions per (ob, t): dy[:, ob] @ w[ob, t].T into block idx[ob, t]
    contrib = jnp.einsum("mob,okbc->mokb" if False else "moc,okbc->mokb",
                         dyb, w.astype(dy.dtype),
                         preferred_element_type=jnp.float32)  # [M,nob,kb,bs]
    dx = jnp.zeros((M, n_in_blocks, bs), jnp.float32)
    dx = dx.at[:, idx.reshape(-1)].add(
        contrib.reshape(M, nob * kb, bs))
    return dx.reshape(M, n_in_blocks * bs).astype(dy.dtype)


def block_sparse_dw(x, dy, idx):
    """dw [nob, kb, bs, bs] = x_block^T @ dy_block per kept edge-bundle."""
    nob, kb = idx.shape
    M = x.shape[0]
    bs = dy.shape[1] // nob
    xb = x.reshape(M, -1, bs)
    dyb = dy.reshape(M, nob, bs)
    xg = jnp.take(xb, idx.reshape(-1), axis=1).reshape(M, nob, kb, bs)
    return jnp.einsum("mokb,moc->okbc", xg, dyb,
                      preferred_element_type=jnp.float32)


# ----------------------------------------------------------- fixed point
def fxp_qmatmul(a_code, w_code, bf: int, bn: int):
    """Integer fixed-point matmul: int32 accumulate, round-half-up shift by
    bf, saturate to the (bw=bn+bf+1) two's-complement range."""
    acc = jnp.dot(a_code.astype(jnp.int32), w_code.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    rounded = (acc + (1 << (bf - 1))) >> bf
    lo, hi = -(1 << (bn + bf)), (1 << (bn + bf)) - 1
    return jnp.clip(rounded, lo, hi).astype(jnp.int32)


# ----------------------------------------------------------- LUT sigmoid
def sigmoid_lut(codes, table):
    """codes int32 in [0, len(table)) -> table[codes]."""
    return jnp.take(table, codes, axis=0)


# ----------------------------------------------------------- selective scan
def selective_scan(dt, x, bc, cc, a, h0):
    """Sequential oracle for the fused Mamba-1 scan kernel."""
    def step(h, args):
        dt_t, x_t, b_t, c_t = args                    # [B,di],[B,di],[B,N],[B,N]
        decay = jnp.exp(dt_t[..., None] * a[None])    # [B,di,N]
        inp = (dt_t * x_t)[..., None] * b_t[:, None, :]
        h = decay * h + inp
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y
    sw = lambda t: jnp.swapaxes(t, 0, 1)
    h, ys = jax.lax.scan(step, h0, (sw(dt), sw(x), sw(bc), sw(cc)))
    return sw(ys), h
