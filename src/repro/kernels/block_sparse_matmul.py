"""Pallas TPU kernels for pre-defined block-sparse matmul — ONE E-generic
edge-bundle engine, the paper's reconfigurable junction datapath.

The FPGA's core claim is that a single edge-processing datapath serves
every junction — reconfigured, not re-implemented, per layer.  Here that
is literal: there is exactly one kernel family, generic over a leading
expert dimension ``E``.  A single dense-model junction is the ``E=1``
case (``kernels/ops.junction_matmul`` squeezes it in and out); MoE expert
FFNs are ``E>1`` with per-expert weights ``[E, nob, kb, bs, bs]`` sharing
ONE block pattern that rides once in scalar prefetch — the paper's
"one junction shape, replicated units" reuse claim.

* **fwd** — grid ``(E, M/bm, nob/bn)``: one step computes ``bn`` output
  tiles for one expert.  The whole ``kb`` fan-in reduction runs *inside*
  the kernel body against an fp32 VMEM scratch accumulator (no output
  revisiting), and the bias + activation epilogue (the paper's FF-stage
  sigmoid fused into the edge pipeline) is applied before the single
  output write.  The activation row block ``[bm, nib*bs]`` stays
  VMEM-resident across the ``nob/bn`` bundle steps — the banked
  activation memory — while weight bundles stream through; the block
  index array rides in as a scalar-prefetch operand and drives in-kernel
  dynamic slices (the interleaver in SMEM).
* **dx** — grid ``(E, M/bm, nib)``: the reverse (fan-out) pattern
  reduction over ``fb`` runs in-body.  The reverse weight bundles are
  **DMA'd in-kernel**: the forward-layout weights stay in HBM
  (``memory_space=ANY``, viewed flat as ``[E, nob*kb, bs, bs]``) and the
  tiles at linear slot ``rev_ob[i,f]*kb + rev_t[i,f]`` are copied
  HBM→VMEM through double-buffered ``make_async_copy`` descriptors whose
  offsets come from the scalar-prefetched reverse pattern — no XLA
  ``w[rev_ob, rev_t]`` pre-gather, no w-sized HBM round-trip per
  backward step.  Reverse slots are consumed in **pairs**: when two
  consecutive slots are contiguous in the flat slot layout (``s1 ==
  s0 + 1`` — e.g. the last fan-in slot of one output block followed by
  the first of the next), ONE two-tile descriptor fetches both, halving
  descriptor overhead for high-fan-out patterns; non-contiguous pairs
  fall back to two single-tile descriptors, so scattered patterns pay
  exactly the pre-coalescing descriptor count.  The bundle is consumed
  un-transposed (the dot contracts both operands on their last dim).
  Padded reverse slots (``f >= rev_cnt[i]``, including whole input
  blocks with zero fan-out) carry in-bounds ``(0, 0)`` sentinels and
  their contribution is ``where``-masked — exact zeros even against
  non-finite upstream gradients.  The activation gradient is recomputed
  in the prologue from the saved residual (output y, or pre-activation s
  for silu/gelu), so the elementwise grad tensor ``dz`` never
  materializes in HBM.
* **dw** — grid ``(E, nob, M/bm)`` with the M reduction innermost into
  fp32 VMEM scratch, written once on the last step.  The ``kb`` gathered
  input blocks arrive through scalar-prefetch-driven BlockSpec
  index_maps (the interleaver as DMA descriptor), and the bias gradient
  accumulates in the same pass.
* **update_dw / update_gated_dw** — the fused **BP+UP** variants (the
  paper's concurrent backprop + update pipeline): same grid and the same
  M-innermost VMEM-scratch gradient reduction as ``dw``/``gated_dw``,
  but instead of flushing the weight gradient to HBM the flush epilogue
  applies the optimizer update **in-kernel** on the last M step.  The
  optimizer is a STATIC switch keyed on which fp32 accumulator slots
  ride along (``_epilogue_step``): momentum-only runs SGD(+momentum),
  a second (m, v) slot pair runs Adam with per-step bias correction and
  decoupled weight decay — the hyperparameters come from the per-unit
  ``[E, HYP_K]`` hyp table in scalar prefetch (registry below).
  Every parameter and accumulator operand comes in as a per-(e, ob)
  resident tile and leaves as an output declared with
  ``input_output_aliases``, so XLA rewrites the buffers in place —
  neither ``dw`` nor a second copy of ``w`` ever touches HBM.  The
  aliasing contract: every parameter operand maps to the output at
  the same relative position, the input/output BlockSpecs are identical,
  and each (e, ob) tile is read and written exactly once (the M loop is
  innermost), so no grid step can observe a partially-updated tile.
  Accumulator slots are fp32 even for bf16 params.

  With ``with_health=True`` the update kernels additionally emit a tiny
  **non-aliased** ``[E, 1]`` int32 health output — the in-kernel
  divergence detector.  Because the in-place update means a non-finite
  ``dw`` silently destroys the parameter state (there is no HBM gradient
  to inspect downstream), the flush epilogue OR-reduces ``isfinite``
  over each post-momentum update tile (both branches for the gated
  kernel, plus the bias update for biased layers) and accumulates a
  per-unit count of bad (e, ob) tiles: ``health[e] > 0`` ⇔ unit e wrote
  at least one non-finite parameter tile this step.  The slot is a
  single revisited ``(1, 1)`` block per unit (zeroed at the first
  (ob, m) step, written only at flushes) — one VMEM compare per tile,
  no gradient materialization, and the parameter outputs' aliasing
  contract is untouched.  ``ops.junction_train_update`` surfaces it as
  the cotangent of a dummy ``[E]`` health operand; ``train/steps.py``
  aggregates it into ``metrics["nonfinite"]``.
* **gated_{fwd,dx,dw}** — the GShard/SwiGLU gate
  ``silu(x @ Wg) * (x @ Wi)`` fused into single passes: both fan-in
  reductions accumulate side by side in VMEM scratch in the forward, and
  the backward kernels recompute both branch gradients
  (``dz_g = dh * u * silu'(g)``, ``dz_u = dh * silu(g)``) from the saved
  ``(g, u)`` residuals, ``gated_dx`` double-buffering BOTH reverse
  weight streams.

Hyp-column registry and accumulator-slot layout
-----------------------------------------------

``hyp`` is the per-unit ``[E, HYP_K]`` f32 hyperparameter table riding
scalar prefetch; the flush epilogue reads row ``e = program_id(0)``, so
every junction unit sharing the pattern trains under DIFFERENT
hyperparameters in the same launch (the population-search contract,
src/repro/search/; a single model is the ``E=1`` row).  The columns
(``HYP_COLS`` / ``COL_*`` constants — a cross-layer ABI shared with
``optim.FusedOptimizer.hyp`` rows, ``train/steps.py``'s lr/clip folds
and the population engine's sweep axes; append-only):

    col 0  lr    learning rate.  The guardian's backoff and any other
                 post-hoc lr scale multiply THIS column (no retrace).
    col 1  b1    SGD: momentum coefficient; Adam: first-moment decay.
    col 2  b2    Adam second-moment decay (ignored by the SGD branch).
    col 3  eps   Adam denominator epsilon.
    col 4  wd    Adam decoupled weight decay, applied as ``+ wd * w``.
    col 5  t     Adam 1-based step count for bias correction
                 (``c_i = 1 - b_i ** t``); the caller re-stamps it per
                 step (``FusedAdam.hyp`` / the sweep scheduler).
    col 6  gs    gradient pre-scale: the accumulated fp32 gradient is
                 multiplied by ``gs`` BEFORE the optimizer formula.
                 Global-norm grad clipping folds in here EXACTLY —
                 folding a clip scale into lr instead would warp the
                 momentum/Adam accumulator state.  1 on the unscaled
                 path; 0 (with the whole row zeroed) freezes a
                 pruned/quarantined unit in place.

A legacy ``[lr, momentum]`` pair — ``(2,)`` or ``[E, 2]`` — normalizes
to ``[lr, momentum, 0, 0, 0, 0, 1]`` (``normalize_hyp``), bitwise
identical SGD numerics.

Accumulator slots are fp32 tensors shaped like the weight (bias)
operand they accompany, aliased in place exactly like the weights;
WHICH slots ride along is the static optimizer switch — no hyp column
selects the optimizer, the operand list does:

    SGD            w [, b]                          (no slots)
    SGD+momentum   w, mom [, b, mom_b]              slot 0 = velocity
    Adam           w, mom, vel [, b, mom_b, vel_b]  slot 0 = first
                   moment m, slot 1 = second moment v

Operand order (and the mirrored output order) is always
``w, slots..., b, bias slots...``; the gated kernel interleaves
``wg, wi, mg, mi, vg, vi``.  To add an optimizer: append its columns
to ``HYP_COLS``, add its slot(s) to this layout (and to
``core/sparse_linear.FUSED_SLOT_NAMES``), and give ``_epilogue_step``
a new statically-selected branch.  The Adam branch's guards (zero
bias-correction denominators and a zero update denominator resolve to
an exact-zero update) exist so an all-zero hyp row freezes a unit under
EITHER optimizer; with real hyperparameters the guards are inert and
the math matches ``optim.adam``'s two-pass update to fp32 round-off.

Quantized inference variants (PR 8)
-----------------------------------

``fwd_int8`` / ``gated_fwd_int8`` / ``fwd_fxp`` are forward-only twins
of ``fwd``/``gated_fwd`` for post-training-quantized weights
(``core/quantize.py`` builds the operands at checkpoint-load time; no
custom_vjp — ``junction_train_update`` refuses integer codes):

* **fwd_int8** — weights arrive as int8 codes with symmetric per-block
  scales ``w_scale [E, nob, kb]`` riding scalar prefetch EXACTLY like
  the pattern leaves (per-unit "unit" granularity is the same layout,
  broadcast at quantize time — one kernel contract).  Per fan-in slot
  the activation tile is quantized in-body (dynamic per-row absmax/127,
  or a calibrated static per-unit ``x_scale [E]`` prefetch leaf), the
  int8×int8 dot accumulates exactly in int32 on the MXU, and the
  dequant ``p * (sx * w_scale[e, ob, k])`` lands in the SAME fp32 VMEM
  scratch reduction slot the fp forward uses — bias + activation
  epilogue unchanged.  The multiplication grouping and per-k
  accumulation order are mirrored op-for-op by the jnp sim
  (``core/quantize.apply_quant_jnp``) so engine parity is exact.
* **fwd_fxp** — the paper's full fixed-point pipeline: activations are
  encoded in-body to the bit-triplet grid (``round(x * 2^bf)``,
  saturated), products accumulate in an **int32** VMEM scratch, and the
  epilogue is round-half-up shift by bf → saturate → bias ``q_add`` →
  VMEM-resident LUT activation (``jnp.take`` over the full 2^bw-entry
  table, indexed by two's-complement code — the BRAM sigmoid table).
  ``qfmt = [bf, bn_bits]`` rides as a traced i32 scalar-prefetch leaf;
  the saturate bound is static from the LUT length.  The runtime
  ``act`` is ignored — the LUT (baked at quantize time) IS the
  activation.
* **gated_fwd_int8** — both expert branches dotted in int8 with
  per-branch scale prefetch leaves, shared in-body activation codes,
  two fp32 scratch accumulators, ``silu(g) * u`` epilogue unchanged.

Tile tuning — one table for every configuration
-----------------------------------------------

``TUNE_TABLE`` maps a canonical 6-key

    (E, M, nob, kb, bs, n_weight_operands) -> (bm, bn)

where ``E`` is the expert count (1 for single junctions), ``M`` the
*unpadded* row count the public wrapper sees, ``nob``/``kb``/``bs`` the
output-block/fan-in/block-size shape, and ``n_weight_operands`` the
number of weight tensors streamed per step (2 for the gated kernel —
its entries are tuned for double the weight-bundle residency).
``n_weight_operands`` counts *forward* weight streams only: the fused
update kernels keep their extra parameter tiles (w + fp32 momentum, and
their aliased outputs) resident per (e, ob) rather than streaming them
per step, and they reuse the forward's tune entry for the row tile via
the ``bwd_bm`` clamp — deliberately the SAME default ``bm`` as the
plain ``dw`` kernels so the fp32 gradient accumulation order matches
the two-pass reference (updated params agree to fp32 round-off; only
XLA's fma fusion of the epilogue differs between the two programs).

To add a measured entry: run ``benchmarks/run.py --json`` on real
hardware, pick the winning tiles for an ``engine.*`` row, and add the
key to ``_SEED_ENTRIES`` below.  Legacy key schemas keep working —
``canonical_tune_key`` migrates PR 1's 4-key ``(M, nob, kb, bs)`` and
the transitional 5-key ``(E, M, nob, kb, bs)`` by pinning the missing
dims to ``E=1`` / ``n_weight_operands=1`` — so entries derived from old
``BENCH_*.json`` artifacts can be pasted in their original form.
Misses fall back to a VMEM-budget heuristic (``choose_tiles``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 128

# Hyp-column registry: the [E, HYP_K] table's cross-layer ABI.  Append
# only — see the module docstring's registry section before changing.
HYP_COLS = ("lr", "b1", "b2", "eps", "wd", "t", "gs")
HYP_K = len(HYP_COLS)
COL_LR, COL_B1, COL_B2, COL_EPS, COL_WD, COL_T, COL_GS = range(HYP_K)

# Activations whose gradient needs the pre-activation s (saved as a second
# forward output); the rest reconstruct the gradient from y itself.
ACT_NEEDS_PRE = ("silu", "gelu")
ACTIVATIONS = ("none", "relu", "sigmoid", "silu", "gelu")

_GELU_C = 0.7978845608028654  # sqrt(2/pi)
_GELU_A = 0.044715


def act_fwd(s, act: str):
    """Epilogue activation on the fp32 accumulator.  gelu is the tanh
    approximation — the same formula jax.nn.gelu(approximate=True) uses,
    so engine="pallas" and engine="jnp" agree bit-for-bit in structure."""
    if act == "none":
        return s
    if act == "relu":
        return jnp.maximum(s, 0.0)
    if act == "sigmoid":
        return jax.nn.sigmoid(s)
    if act == "silu":
        return s * jax.nn.sigmoid(s)
    if act == "gelu":
        u = _GELU_C * (s + _GELU_A * s * s * s)
        return 0.5 * s * (1.0 + jnp.tanh(u))
    raise ValueError(f"unknown activation {act!r}")


def act_bwd(res, act: str):
    """d act/d s from the residual: y for relu/sigmoid, s for silu/gelu."""
    if act == "none":
        return None  # caller skips the multiply entirely
    if act == "relu":
        return (res > 0.0).astype(jnp.float32)
    if act == "sigmoid":
        return res * (1.0 - res)
    if act == "silu":
        sg = jax.nn.sigmoid(res)
        return sg * (1.0 + res * (1.0 - sg))
    if act == "gelu":
        s = res
        u = _GELU_C * (s + _GELU_A * s * s * s)
        t = jnp.tanh(u)
        du = _GELU_C * (1.0 + 3.0 * _GELU_A * s * s)
        return 0.5 * (1.0 + t) + 0.5 * s * (1.0 - t * t) * du
    raise ValueError(f"unknown activation {act!r}")


# ------------------------------------------------------------- tile tuning
VMEM_BUDGET = 8 * 1024 * 1024   # conservative per-kernel working-set bound
MAX_BN = 8
WEIGHT_BUNDLE_BUDGET = 2 * 1024 * 1024  # per-step streamed-weight bound


def canonical_tune_key(key) -> tuple[int, int, int, int, int, int]:
    """Normalize a tune-table key to the canonical 6-tuple
    ``(E, M, nob, kb, bs, n_weight_operands)``.

    Migration shim for pre-unification schemas: PR 1 keyed single-junction
    entries ``(M, nob, kb, bs)`` (implicitly E=1, one weight operand) and
    PR 2 keyed expert entries ``(E, M, nob, kb, bs, n_weight_operands)``;
    a transitional 5-key ``(E, M, nob, kb, bs)`` pins one weight operand.
    """
    key = tuple(int(v) for v in key)
    if len(key) == 4:        # PR 1: (M, nob, kb, bs)
        return (1, *key, 1)
    if len(key) == 5:        # transitional: (E, M, nob, kb, bs)
        return (*key, 1)
    if len(key) == 6:        # canonical (PR 2 expert schema)
        return key
    raise ValueError(f"tune key {key!r}: expected 4, 5 or 6 ints")


# Measured entries (BENCH_*.json artifacts are the data source).  Keys may
# be written in any historical schema — canonical_tune_key migrates them.
_SEED_ENTRIES: dict[tuple, tuple[int, int]] = {
    # PR 1, paper MNIST junction (12544-sample epoch, 1024->512 @ kb=2)
    (12544, 4, 2, 128): (512, 4),
    # PR 1, transformer FFN up-projection bench shape (1024->4096 @ kb=2)
    (4096, 32, 2, 128): (256, 8),
    # PR 2, engine.moe bench gated entry kernel: E=4 experts, top-2 routed
    # 2048 tokens (capacity rows M=1280), 1024->512 @ kb=2, two weight
    # operands (wg + wi streamed per step)
    (4, 1280, 4, 2, 128, 2): (256, 4),
}

TUNE_TABLE: dict[tuple[int, int, int, int, int, int], tuple[int, int]] = {
    canonical_tune_key(k): v for k, v in _SEED_ENTRIES.items()
}


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def _choose_bm(M: int, row_blocks: int, bs: int, itemsize: int) -> int:
    """Largest row-tile (multiple of 16 sublanes) whose resident row block
    ``[bm, row_blocks*bs]`` fits half the VMEM budget."""
    row_bytes = max(1, row_blocks * bs * itemsize)
    bm = 512
    while bm > 16 and bm * row_bytes > VMEM_BUDGET // 2:
        bm //= 2
    return max(16, min(bm, _round_up(M, 16)))


def _choose_bn(nob: int, kb: int, bs: int, itemsize: int,
               budget: int) -> int:
    """Largest power-of-two divisor of nob whose weight bundle fits the
    per-step VMEM budget."""
    bn = 1
    while (bn < MAX_BN and nob % (2 * bn) == 0
           and 2 * bn * kb * bs * bs * itemsize <= budget):
        bn *= 2
    return bn


def choose_tiles(M: int, nob: int, kb: int, bs: int, nib: int,
                 itemsize: int = 4, *, E: int = 1,
                 n_weight_operands: int = 1) -> tuple[int, int]:
    """(bm, bn) for the fused forward of ANY junction configuration:
    TUNE_TABLE first (canonical 6-key, legacy keys migrated), then the
    VMEM heuristic — bm bounded by the resident x row block (one expert's
    row block is resident per grid step, so the bound is E-independent),
    bn the largest power-of-two divisor of nob whose weight bundle fits
    the per-step budget split across the streamed weight tensors."""
    hit = TUNE_TABLE.get(canonical_tune_key((E, M, nob, kb, bs,
                                             n_weight_operands)))
    if hit is not None:
        bm, bn = hit
        return max(16, min(bm, _round_up(M, 16))), bn
    bm = _choose_bm(M, nib, bs, itemsize)
    budget = WEIGHT_BUNDLE_BUDGET // max(1, n_weight_operands)
    return bm, _choose_bn(nob, kb, bs, itemsize, budget)


def bwd_bm(M: int, row_blocks: int, bs: int, itemsize: int) -> int:
    """Row tile for the backward kernels: the forward's VMEM-residency
    bound, gcd-clamped to divide the (pre-padded by the forward's bm, a
    multiple of 16) row count M exactly."""
    return math.gcd(_choose_bm(M, row_blocks, bs, itemsize), M)


def fwd_grid(M: int, nob: int, kb: int, bs: int, nib: int,
             itemsize: int = 4, E: int = 1) -> tuple[int, int]:
    """Per-expert grid of the fused forward for padded row count M — the
    acceptance bound: exactly (M/bm) * (nob/bn) steps per expert, kb
    fully in-kernel."""
    bm, bn = choose_tiles(M, nob, kb, bs, nib, itemsize, E=E)
    return (_round_up(M, bm) // bm, nob // bn)


# ------------------------------------------------------------------ forward
def fwd(x, w, idx, bias, *, act: str = "none", bm: int | None = None,
        bn: int | None = None, save_pre: bool = False,
        interpret: bool = False):
    """x [E, M, nib*bs], w [E, nob, kb, bs, bs], shared idx [nob, kb],
    bias [E, nob*bs] -> act(x_e @ W_e + b_e) [E, M, nob*bs] per junction
    unit (+ pre-activation if save_pre).

    Grid (E, M/bm, nob/bn): the expert dimension is the outermost grid
    axis; the pattern rides once in scalar prefetch and is reused by every
    unit.  One step computes bn output tiles — the kb fan-in slots reduce
    in-body into fp32 VMEM scratch, epilogue fused, single output write."""
    E, M, _ = x.shape
    _, nob, kb, bs, _ = w.shape
    nib = x.shape[2] // bs
    cbm, cbn = choose_tiles(M, nob, kb, bs, nib, x.dtype.itemsize, E=E)
    bm = cbm if bm is None else bm
    bn = cbn if bn is None else bn
    if nob % bn:
        bn = 1
    assert M % bm == 0, f"M={M} must be a multiple of bm={bm} (pad in ops.py)"

    def fwd_kernel(idx_ref, x_ref, w_ref, b_ref, *rest):
        acc_ref = rest[-1]
        o_ref = rest[0]
        ob0 = pl.program_id(2) * bn
        for j in range(bn):
            acc = jnp.zeros((bm, bs), jnp.float32)
            for k in range(kb):
                ib = idx_ref[ob0 + j, k]
                xk = x_ref[0, :, pl.ds(ib * bs, bs)]
                acc = acc + jnp.dot(xk, w_ref[0, j, k],
                                    preferred_element_type=jnp.float32)
            acc_ref[:, j * bs:(j + 1) * bs] = acc
        s = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if save_pre:
            rest[1][0] = s.astype(rest[1].dtype)
        o_ref[0] = act_fwd(s, act).astype(o_ref.dtype)

    out_shape = [jax.ShapeDtypeStruct((E, M, nob * bs), x.dtype)]
    out_specs = [pl.BlockSpec((1, bm, bn * bs), lambda e, m, o, idx: (e, m, o))]
    if save_pre:
        out_shape.append(jax.ShapeDtypeStruct((E, M, nob * bs), x.dtype))
        out_specs.append(pl.BlockSpec((1, bm, bn * bs),
                                      lambda e, m, o, idx: (e, m, o)))

    outs = pl.pallas_call(
        fwd_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(E, M // bm, nob // bn),
            in_specs=[
                # full activation row block, resident across bundle steps
                pl.BlockSpec((1, bm, nib * bs), lambda e, m, o, idx: (e, m, 0)),
                pl.BlockSpec((1, bn, kb, bs, bs),
                             lambda e, m, o, idx: (e, o, 0, 0, 0)),
                pl.BlockSpec((1, bn * bs), lambda e, m, o, idx: (e, o)),
            ],
            out_specs=out_specs,
            scratch_shapes=[pltpu.VMEM((bm, bn * bs), jnp.float32)],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(idx, x, w, bias)
    return (outs[0], outs[1]) if save_pre else (outs[0], None)


def gated_fwd(x, wg, wi, idx, *, bm: int | None = None,
              bn: int | None = None, save_res: bool = False,
              interpret: bool = False):
    """Fused SiLU-gate FFN entry: silu(x_e @ Wg_e) * (x_e @ Wi_e) in one
    pass — both kb fan-in reductions accumulate side by side in VMEM
    scratch, the gate epilogue fuses before the single output write.
    Returns (h, g_pre, u) — the pre-activation g and the linear branch u
    are emitted only when save_res (backward residuals)."""
    E, M, _ = x.shape
    _, nob, kb, bs, _ = wg.shape
    nib = x.shape[2] // bs
    cbm, cbn = choose_tiles(M, nob, kb, bs, nib, x.dtype.itemsize, E=E,
                            n_weight_operands=2)
    bm = cbm if bm is None else bm
    bn = cbn if bn is None else bn
    if nob % bn:
        bn = 1
    assert M % bm == 0, f"M={M} must be a multiple of bm={bm} (pad in ops.py)"

    def gated_fwd_kernel(idx_ref, x_ref, wg_ref, wi_ref, *rest):
        accg_ref, accu_ref = rest[-2], rest[-1]
        h_ref = rest[0]
        ob0 = pl.program_id(2) * bn
        for j in range(bn):
            ag = jnp.zeros((bm, bs), jnp.float32)
            au = jnp.zeros((bm, bs), jnp.float32)
            for k in range(kb):
                ib = idx_ref[ob0 + j, k]
                xk = x_ref[0, :, pl.ds(ib * bs, bs)]
                ag = ag + jnp.dot(xk, wg_ref[0, j, k],
                                  preferred_element_type=jnp.float32)
                au = au + jnp.dot(xk, wi_ref[0, j, k],
                                  preferred_element_type=jnp.float32)
            accg_ref[:, j * bs:(j + 1) * bs] = ag
            accu_ref[:, j * bs:(j + 1) * bs] = au
        g = accg_ref[...]
        u = accu_ref[...]
        if save_res:
            rest[1][0] = g.astype(rest[1].dtype)
            rest[2][0] = u.astype(rest[2].dtype)
        h_ref[0] = (act_fwd(g, "silu") * u).astype(h_ref.dtype)

    out_shape = [jax.ShapeDtypeStruct((E, M, nob * bs), x.dtype)]
    out_specs = [pl.BlockSpec((1, bm, bn * bs), lambda e, m, o, idx: (e, m, o))]
    if save_res:
        for _ in range(2):
            out_shape.append(jax.ShapeDtypeStruct((E, M, nob * bs), x.dtype))
            out_specs.append(pl.BlockSpec((1, bm, bn * bs),
                                          lambda e, m, o, idx: (e, m, o)))

    outs = pl.pallas_call(
        gated_fwd_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(E, M // bm, nob // bn),
            in_specs=[
                pl.BlockSpec((1, bm, nib * bs), lambda e, m, o, idx: (e, m, 0)),
                pl.BlockSpec((1, bn, kb, bs, bs),
                             lambda e, m, o, idx: (e, o, 0, 0, 0)),
                pl.BlockSpec((1, bn, kb, bs, bs),
                             lambda e, m, o, idx: (e, o, 0, 0, 0)),
            ],
            out_specs=out_specs,
            scratch_shapes=[pltpu.VMEM((bm, bn * bs), jnp.float32),
                            pltpu.VMEM((bm, bn * bs), jnp.float32)],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(idx, x, wg, wi)
    return (outs[0], outs[1], outs[2]) if save_res else (outs[0], None, None)


# ------------------------------------------------------ quantized forward
def _slot_x_scale(xk, xs):
    """In-kernel activation quantization scale for one gathered fan-in
    slot: dynamic per-row absmax/127 (never looks across the row tile,
    so it is bitwise engine-independent), or the calibrated static
    per-unit scale."""
    if xs is None:
        ax = jnp.max(jnp.abs(xk), axis=-1, keepdims=True)
        return jnp.where(ax == 0.0, 1.0, ax / 127.0)
    return xs


def fwd_int8(x, wq, idx, w_scale, bias, *, act: str = "none",
             x_scale=None, bm: int | None = None, bn: int | None = None,
             interpret: bool = False):
    """int8 forward: x [E, M, nib*bs] fp, wq [E, nob, kb, bs, bs] int8,
    shared idx [nob, kb], w_scale [E, nob, kb] f32 on scalar prefetch,
    bias [E, nob*bs] -> act(dequant(xq @ wq) + b) [E, M, nob*bs].
    Optional x_scale [E] f32 switches activation quantization from
    dynamic per-row to calibrated static per-unit."""
    E, M, _ = x.shape
    _, nob, kb, bs, _ = wq.shape
    nib = x.shape[2] // bs
    cbm, cbn = choose_tiles(M, nob, kb, bs, nib, x.dtype.itemsize, E=E)
    bm = cbm if bm is None else bm
    bn = cbn if bn is None else bn
    if nob % bn:
        bn = 1
    assert M % bm == 0, f"M={M} must be a multiple of bm={bm} (pad in ops.py)"
    has_xs = x_scale is not None

    def fwd_int8_kernel(*refs):
        if has_xs:
            idx_ref, sc_ref, xs_ref, x_ref, w_ref, b_ref, o_ref, acc_ref = refs
        else:
            idx_ref, sc_ref, x_ref, w_ref, b_ref, o_ref, acc_ref = refs
        e = pl.program_id(0)
        ob0 = pl.program_id(2) * bn
        for j in range(bn):
            acc = jnp.zeros((bm, bs), jnp.float32)
            for k in range(kb):
                ib = idx_ref[ob0 + j, k]
                xk = x_ref[0, :, pl.ds(ib * bs, bs)].astype(jnp.float32)
                sx = _slot_x_scale(xk, xs_ref[e] if has_xs else None)
                xq = jnp.clip(jnp.round(xk / sx), -127, 127
                              ).astype(jnp.int8)
                p = jnp.dot(xq, w_ref[0, j, k],
                            preferred_element_type=jnp.int32)
                # dequant into the fp32 reduction slot; grouping matches
                # the jnp sim exactly (see core/quantize._int8_apply)
                acc = acc + p.astype(jnp.float32) * (
                    sx * sc_ref[e, ob0 + j, k])
            acc_ref[:, j * bs:(j + 1) * bs] = acc
        s = acc_ref[...] + b_ref[...].astype(jnp.float32)
        o_ref[0] = act_fwd(s, act).astype(o_ref.dtype)

    prefetch = (idx, w_scale) + ((x_scale,) if has_xs else ())
    out = pl.pallas_call(
        fwd_int8_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(prefetch),
            grid=(E, M // bm, nob // bn),
            in_specs=[
                pl.BlockSpec((1, bm, nib * bs), lambda e, m, o, *_: (e, m, 0)),
                pl.BlockSpec((1, bn, kb, bs, bs),
                             lambda e, m, o, *_: (e, o, 0, 0, 0)),
                pl.BlockSpec((1, bn * bs), lambda e, m, o, *_: (e, o)),
            ],
            out_specs=[pl.BlockSpec((1, bm, bn * bs),
                                    lambda e, m, o, *_: (e, m, o))],
            scratch_shapes=[pltpu.VMEM((bm, bn * bs), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((E, M, nob * bs), x.dtype)],
        interpret=interpret,
    )(*prefetch, x, wq, bias)
    return out[0]


def fwd_fxp(x, wq, idx, qfmt, lut, bias, *, bm: int | None = None,
            bn: int | None = None, interpret: bool = False):
    """Full fixed-point forward: wq [E, nob, kb, bs, bs] int32 triplet
    codes, qfmt [2] i32 = [bf, bn_bits] on scalar prefetch, lut [2^bw]
    f32 VMEM-resident activation table, bias [E, nob*bs] fp (snapped to
    the grid at quantize time).  Activations encode in-body; the int32
    accumulation + round-half-up shift + saturate + bias q_add + LUT
    epilogue is bit-exact fixed-point arithmetic — no runtime act."""
    E, M, _ = x.shape
    _, nob, kb, bs, _ = wq.shape
    nib = x.shape[2] // bs
    T = lut.shape[0]
    lim = T // 2   # static saturate bound: 2^(bn_bits + bf)
    cbm, cbn = choose_tiles(M, nob, kb, bs, nib, x.dtype.itemsize, E=E)
    bm = cbm if bm is None else bm
    bn = cbn if bn is None else bn
    if nob % bn:
        bn = 1
    assert M % bm == 0, f"M={M} must be a multiple of bm={bm} (pad in ops.py)"

    def fwd_fxp_kernel(idx_ref, qf_ref, x_ref, w_ref, b_ref, lut_ref,
                       o_ref, acc_ref):
        bf = qf_ref[0]
        scale = jnp.exp2(bf.astype(jnp.float32))
        ob0 = pl.program_id(2) * bn
        for j in range(bn):
            acc = jnp.zeros((bm, bs), jnp.int32)
            for k in range(kb):
                ib = idx_ref[ob0 + j, k]
                xk = x_ref[0, :, pl.ds(ib * bs, bs)].astype(jnp.float32)
                xq = jnp.clip(jnp.round(xk * scale), -lim, lim - 1
                              ).astype(jnp.int32)
                acc = acc + jnp.dot(xq, w_ref[0, j, k],
                                    preferred_element_type=jnp.int32)
            acc_ref[:, j * bs:(j + 1) * bs] = acc
        half = jnp.left_shift(jnp.int32(1), bf - 1)
        s = jnp.right_shift(acc_ref[...] + half, bf)   # round half up
        s = jnp.clip(s, -lim, lim - 1)                 # saturating adder
        bcode = jnp.clip(jnp.round(b_ref[...].astype(jnp.float32) * scale),
                         -lim, lim - 1).astype(jnp.int32)
        s = jnp.clip(s + bcode, -lim, lim - 1)         # q_add
        o_ref[0] = jnp.take(lut_ref[...], jnp.bitwise_and(s, T - 1),
                            axis=0).astype(o_ref.dtype)

    out = pl.pallas_call(
        fwd_fxp_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(E, M // bm, nob // bn),
            in_specs=[
                pl.BlockSpec((1, bm, nib * bs), lambda e, m, o, *_: (e, m, 0)),
                pl.BlockSpec((1, bn, kb, bs, bs),
                             lambda e, m, o, *_: (e, o, 0, 0, 0)),
                pl.BlockSpec((1, bn * bs), lambda e, m, o, *_: (e, o)),
                # the whole activation table, VMEM-resident every step
                pl.BlockSpec((T,), lambda e, m, o, *_: (0,)),
            ],
            out_specs=[pl.BlockSpec((1, bm, bn * bs),
                                    lambda e, m, o, *_: (e, m, o))],
            scratch_shapes=[pltpu.VMEM((bm, bn * bs), jnp.int32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((E, M, nob * bs), x.dtype)],
        interpret=interpret,
    )(idx, qfmt, x, wq, bias, lut)
    return out[0]


def gated_fwd_int8(x, wgq, wiq, idx, wg_scale, wi_scale, *, x_scale=None,
                   bm: int | None = None, bn: int | None = None,
                   interpret: bool = False):
    """int8 twin of gated_fwd: silu(dequant(xq @ wgq)) * dequant(xq @
    wiq) — shared in-body activation codes, per-branch scale prefetch
    leaves [E, nob, kb], two fp32 scratch accumulators."""
    E, M, _ = x.shape
    _, nob, kb, bs, _ = wgq.shape
    nib = x.shape[2] // bs
    cbm, cbn = choose_tiles(M, nob, kb, bs, nib, x.dtype.itemsize, E=E,
                            n_weight_operands=2)
    bm = cbm if bm is None else bm
    bn = cbn if bn is None else bn
    if nob % bn:
        bn = 1
    assert M % bm == 0, f"M={M} must be a multiple of bm={bm} (pad in ops.py)"
    has_xs = x_scale is not None

    def gated_fwd_int8_kernel(*refs):
        if has_xs:
            (idx_ref, scg_ref, sci_ref, xs_ref, x_ref, wg_ref, wi_ref,
             h_ref, accg_ref, accu_ref) = refs
        else:
            (idx_ref, scg_ref, sci_ref, x_ref, wg_ref, wi_ref,
             h_ref, accg_ref, accu_ref) = refs
        e = pl.program_id(0)
        ob0 = pl.program_id(2) * bn
        for j in range(bn):
            ag = jnp.zeros((bm, bs), jnp.float32)
            au = jnp.zeros((bm, bs), jnp.float32)
            for k in range(kb):
                ib = idx_ref[ob0 + j, k]
                xk = x_ref[0, :, pl.ds(ib * bs, bs)].astype(jnp.float32)
                sx = _slot_x_scale(xk, xs_ref[e] if has_xs else None)
                xq = jnp.clip(jnp.round(xk / sx), -127, 127
                              ).astype(jnp.int8)
                pg = jnp.dot(xq, wg_ref[0, j, k],
                             preferred_element_type=jnp.int32)
                pu = jnp.dot(xq, wi_ref[0, j, k],
                             preferred_element_type=jnp.int32)
                ag = ag + pg.astype(jnp.float32) * (
                    sx * scg_ref[e, ob0 + j, k])
                au = au + pu.astype(jnp.float32) * (
                    sx * sci_ref[e, ob0 + j, k])
            accg_ref[:, j * bs:(j + 1) * bs] = ag
            accu_ref[:, j * bs:(j + 1) * bs] = au
        g = accg_ref[...]
        u = accu_ref[...]
        h_ref[0] = (act_fwd(g, "silu") * u).astype(h_ref.dtype)

    prefetch = (idx, wg_scale, wi_scale) + ((x_scale,) if has_xs else ())
    out = pl.pallas_call(
        gated_fwd_int8_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(prefetch),
            grid=(E, M // bm, nob // bn),
            in_specs=[
                pl.BlockSpec((1, bm, nib * bs), lambda e, m, o, *_: (e, m, 0)),
                pl.BlockSpec((1, bn, kb, bs, bs),
                             lambda e, m, o, *_: (e, o, 0, 0, 0)),
                pl.BlockSpec((1, bn, kb, bs, bs),
                             lambda e, m, o, *_: (e, o, 0, 0, 0)),
            ],
            out_specs=[pl.BlockSpec((1, bm, bn * bs),
                                    lambda e, m, o, *_: (e, m, o))],
            scratch_shapes=[pltpu.VMEM((bm, bn * bs), jnp.float32),
                            pltpu.VMEM((bm, bn * bs), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((E, M, nob * bs), x.dtype)],
        interpret=interpret,
    )(*prefetch, x, wgq, wiq)
    return out[0]


# ------------------------------------------------------------------ dx
def _rev_dot(dz, wb):
    """dz [bm, bs_out] x forward-layout bundle wb [bs_in, bs_out] ->
    [bm, bs_in]: contract both on their LAST dim (dz @ wb.T without a
    transpose copy of the DMA'd tile)."""
    return jax.lax.dot_general(dz, wb, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _pair_copies(w_hbm, wbuf, sems, e, s0, s1, buf):
    """The (descriptor, condition) list fetching the reverse slot pair
    (s0, s1) of the flat [E, nob*kb, bs, bs] weight view into buffer line
    ``wbuf[buf]``: ONE two-tile descriptor when the slots are contiguous
    in the flat layout, else one single-tile descriptor per slot.  s1 is
    None for the trailing half-pair of an odd fan-out.  Called with
    identical arguments from the start and the wait sides so the
    conditional descriptors always match their semaphores."""
    if s1 is None:
        return [(pltpu.make_async_copy(w_hbm.at[e, pl.ds(s0, 1)],
                                       wbuf.at[buf, pl.ds(0, 1)],
                                       sems.at[buf, 0]), None)]
    contig = s1 == s0 + 1
    apart = jnp.logical_not(contig)
    return [
        (pltpu.make_async_copy(w_hbm.at[e, pl.ds(s0, 2)], wbuf.at[buf],
                               sems.at[buf, 0]), contig),
        (pltpu.make_async_copy(w_hbm.at[e, pl.ds(s0, 1)],
                               wbuf.at[buf, pl.ds(0, 1)],
                               sems.at[buf, 0]), apart),
        (pltpu.make_async_copy(w_hbm.at[e, pl.ds(s1, 1)],
                               wbuf.at[buf, pl.ds(1, 1)],
                               sems.at[buf, 1]), apart),
    ]


def _run_copies(copies, method: str):
    for copy, cond in copies:
        fn = getattr(copy, method)
        if cond is None:
            fn()
        else:
            pl.when(cond)(fn)


def dx(dy, w, rev_ob, rev_t, rev_cnt, res, *, act: str = "none",
       bm: int | None = None, interpret: bool = False):
    """dy [E, M, nob*bs] -> dx [E, M, nib*bs] via the shared reverse
    (fan-out) pattern against the forward-layout weights w
    [E, nob, kb, bs, bs].

    The reverse weight bundles are DMA'd in-kernel: w stays in HBM
    (memory_space=ANY, viewed flat over the (nob, kb) slot dims) and the
    tiles at linear slot rev_ob[i,f]*kb + rev_t[i,f] are double-buffered
    HBM→VMEM with make_async_copy, offsets from the scalar-prefetched
    reverse pattern — the XLA w[rev_ob, rev_t] pre-gather (a w-sized
    round-trip per backward call) is gone.  Slots are fetched in PAIRS:
    contiguous runs in the flat slot layout coalesce into one two-tile
    descriptor (halved descriptor overhead for high-fan-out patterns),
    scattered pairs fall back to two single-tile descriptors.  Padded
    slots (f >= rev_cnt[i], (0,0) sentinels) prefetch an in-bounds bundle
    whose contribution is where-masked, so zero-fan-out input blocks
    yield exact-zero dx rows even for non-finite dy.  The activation
    gradient is recomputed per dy block from the residual."""
    E, M, _ = dy.shape
    _, nob, kb, bs, _ = w.shape
    nib, fb = rev_ob.shape
    has_res = act != "none"
    if bm is None:
        bm = bwd_bm(M, nob * (2 if has_res else 1), bs, dy.dtype.itemsize)
    assert M % bm == 0
    npair = (fb + 1) // 2
    w_flat = w.reshape(E, nob * kb, bs, bs)

    def dx_kernel(rev_ob_ref, rev_t_ref, rev_cnt_ref, *refs):
        if has_res:
            dy_ref, res_ref, w_hbm, o_ref, wbuf, sems = refs
        else:
            dy_ref, w_hbm, o_ref, wbuf, sems = refs
            res_ref = None
        e = pl.program_id(0)
        i = pl.program_id(2)
        cnt = rev_cnt_ref[i]

        def slot(f):
            return rev_ob_ref[i, f] * kb + rev_t_ref[i, f]

        def copies(buf, p):
            f0 = 2 * p
            s1 = slot(f0 + 1) if f0 + 1 < fb else None
            return _pair_copies(w_hbm, wbuf, sems, e, slot(f0), s1, buf)

        _run_copies(copies(0, 0), "start")
        acc = jnp.zeros((bm, bs), jnp.float32)
        for p in range(npair):
            if p + 1 < npair:
                _run_copies(copies((p + 1) % 2, p + 1), "start")
            _run_copies(copies(p % 2, p), "wait")
            for j in range(min(2, fb - 2 * p)):
                f = 2 * p + j
                ob = rev_ob_ref[i, f]
                dyb = dy_ref[0, :, pl.ds(ob * bs, bs)]
                if has_res:
                    gr = act_bwd(
                        res_ref[0, :, pl.ds(ob * bs, bs)].astype(jnp.float32),
                        act)
                    dz = (dyb.astype(jnp.float32) * gr).astype(dyb.dtype)
                else:
                    dz = dyb
                acc = acc + jnp.where(f < cnt,
                                      _rev_dot(dz, wbuf[p % 2, j]), 0.0)
        o_ref[0] = acc.astype(o_ref.dtype)

    in_specs = [pl.BlockSpec((1, bm, nob * bs),
                             lambda e, m, i, *_: (e, m, 0))]
    inputs = [dy]
    if has_res:
        in_specs.append(pl.BlockSpec((1, bm, nob * bs),
                                     lambda e, m, i, *_: (e, m, 0)))
        inputs.append(res)
    in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
    inputs.append(w_flat)

    return pl.pallas_call(
        dx_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(E, M // bm, nib),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bm, bs),
                                   lambda e, m, i, *_: (e, m, i)),
            scratch_shapes=[pltpu.VMEM((2, 2, bs, bs), w.dtype),
                            pltpu.SemaphoreType.DMA((2, 2))],
        ),
        out_shape=jax.ShapeDtypeStruct((E, M, nib * bs), dy.dtype),
        interpret=interpret,
    )(rev_ob, rev_t, rev_cnt, *inputs)


def gated_dx(dh, wg, wi, rev_ob, rev_t, rev_cnt, g, u, *,
             bm: int | None = None, interpret: bool = False):
    """Fused two-branch dx for the gated FFN: both branch grads
    (dz_g = dh * u * silu'(g), dz_u = dh * silu(g)) are recomputed per dy
    block from the saved residuals and reduced against their reverse
    bundles in the same fb loop — one pass over dh/g/u per input block,
    with BOTH weight streams double-buffered HBM→VMEM in-kernel and the
    same pairwise contiguous-run descriptor coalescing as ``dx``."""
    E, M, _ = dh.shape
    _, nob, kb, bs, _ = wg.shape
    nib, fb = rev_ob.shape
    if bm is None:
        bm = bwd_bm(M, 3 * nob, bs, dh.dtype.itemsize)
    assert M % bm == 0
    npair = (fb + 1) // 2
    wg_flat = wg.reshape(E, nob * kb, bs, bs)
    wi_flat = wi.reshape(E, nob * kb, bs, bs)

    def gated_dx_kernel(rev_ob_ref, rev_t_ref, rev_cnt_ref, dh_ref, g_ref,
                        u_ref, wg_hbm, wi_hbm, o_ref, wgbuf, wibuf, sems):
        e = pl.program_id(0)
        i = pl.program_id(2)
        cnt = rev_cnt_ref[i]

        def slot(f):
            return rev_ob_ref[i, f] * kb + rev_t_ref[i, f]

        def copies(buf, p):
            f0 = 2 * p
            s0 = slot(f0)
            s1 = slot(f0 + 1) if f0 + 1 < fb else None
            return (_pair_copies(wg_hbm, wgbuf, sems.at[0], e, s0, s1, buf)
                    + _pair_copies(wi_hbm, wibuf, sems.at[1], e, s0, s1, buf))

        _run_copies(copies(0, 0), "start")
        acc = jnp.zeros((bm, bs), jnp.float32)
        for p in range(npair):
            if p + 1 < npair:
                _run_copies(copies((p + 1) % 2, p + 1), "start")
            _run_copies(copies(p % 2, p), "wait")
            for j in range(min(2, fb - 2 * p)):
                f = 2 * p + j
                cols = pl.ds(rev_ob_ref[i, f] * bs, bs)
                dhb = dh_ref[0, :, cols].astype(jnp.float32)
                gb = g_ref[0, :, cols].astype(jnp.float32)
                ub = u_ref[0, :, cols].astype(jnp.float32)
                dzg = (dhb * ub * act_bwd(gb, "silu")).astype(dh_ref.dtype)
                dzu = (dhb * act_fwd(gb, "silu")).astype(dh_ref.dtype)
                part = (_rev_dot(dzg, wgbuf[p % 2, j])
                        + _rev_dot(dzu, wibuf[p % 2, j]))
                acc = acc + jnp.where(f < cnt, part, 0.0)
        o_ref[0] = acc.astype(o_ref.dtype)

    row = pl.BlockSpec((1, bm, nob * bs), lambda e, m, i, *_: (e, m, 0))
    hbm = pl.BlockSpec(memory_space=pltpu.ANY)
    return pl.pallas_call(
        gated_dx_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(E, M // bm, nib),
            in_specs=[row, row, row, hbm, hbm],
            out_specs=pl.BlockSpec((1, bm, bs),
                                   lambda e, m, i, *_: (e, m, i)),
            scratch_shapes=[pltpu.VMEM((2, 2, bs, bs), wg.dtype),
                            pltpu.VMEM((2, 2, bs, bs), wi.dtype),
                            pltpu.SemaphoreType.DMA((2, 2, 2))],
        ),
        out_shape=jax.ShapeDtypeStruct((E, M, nib * bs), dh.dtype),
        interpret=interpret,
    )(rev_ob, rev_t, rev_cnt, dh, g, u, wg_flat, wi_flat)


# ------------------------------------------------------------------ dw (+db)
def dw(x, dy, idx, res, *, act: str = "none", with_bias: bool = True,
       bm: int | None = None, interpret: bool = False):
    """(dw [E, nob, kb, bs, bs] fp32, db [E, nob*bs] fp32 or None) — grid
    (E, nob, M/bm) with the M reduction innermost into fp32 VMEM scratch,
    flushed once per (unit, output block).  The kb gathered input blocks
    arrive through scalar-prefetch BlockSpec index_maps — the interleaver
    as a DMA descriptor — and, for biased layers, db accumulates from the
    same fused dz prologue (with_bias=False skips it entirely)."""
    E, M, _ = x.shape
    nob, kb = idx.shape
    bs = dy.shape[2] // nob
    has_res = act != "none"
    if bm is None:
        bm = bwd_bm(M, kb + 3, bs, x.dtype.itemsize)
    assert M % bm == 0
    nm = M // bm

    def dw_kernel(idx_ref, *refs):
        n_in = (2 if has_res else 1) + kb
        dy_ref = refs[0]
        res_ref = refs[1] if has_res else None
        x_refs = refs[n_in - kb:n_in]
        if with_bias:
            dw_ref, db_ref, accw_ref, accb_ref = refs[n_in:]
        else:
            dw_ref, accw_ref = refs[n_in:]
        m = pl.program_id(2)

        @pl.when(m == 0)
        def _zero():
            accw_ref[...] = jnp.zeros((kb, bs, bs), jnp.float32)
            if with_bias:
                accb_ref[...] = jnp.zeros((1, bs), jnp.float32)

        if has_res:
            grad = act_bwd(res_ref[0].astype(jnp.float32), act)
            dzf = dy_ref[0].astype(jnp.float32) * grad
            dz = dzf.astype(dy_ref.dtype)
        else:
            dzf = None
            dz = dy_ref[0]
        for k in range(kb):
            accw_ref[k] = accw_ref[k] + jnp.dot(
                x_refs[k][0].T, dz, preferred_element_type=jnp.float32)
        if with_bias:
            s = dzf if dzf is not None else dy_ref[0].astype(jnp.float32)
            accb_ref[...] = accb_ref[...] + jnp.sum(s, axis=0, keepdims=True)

        @pl.when(m == nm - 1)
        def _flush():
            dw_ref[...] = accw_ref[...][None, None]
            if with_bias:
                db_ref[...] = accb_ref[...][None]

    in_specs = [pl.BlockSpec((1, bm, bs), lambda e, o, m, idx: (e, m, o))]
    inputs = [dy]
    if has_res:
        in_specs.append(pl.BlockSpec((1, bm, bs),
                                     lambda e, o, m, idx: (e, m, o)))
        inputs.append(res)
    for k in range(kb):
        in_specs.append(pl.BlockSpec(
            (1, bm, bs), lambda e, o, m, idx, k=k: (e, m, idx[o, k])))
        inputs.append(x)

    out_specs = [pl.BlockSpec((1, 1, kb, bs, bs),
                              lambda e, o, m, idx: (e, o, 0, 0, 0))]
    out_shape = [jax.ShapeDtypeStruct((E, nob, kb, bs, bs), jnp.float32)]
    scratch = [pltpu.VMEM((kb, bs, bs), jnp.float32)]
    if with_bias:
        out_specs.append(pl.BlockSpec((1, 1, bs), lambda e, o, m, idx: (e, o, 0)))
        out_shape.append(jax.ShapeDtypeStruct((E, nob, bs), jnp.float32))
        scratch.append(pltpu.VMEM((1, bs), jnp.float32))

    outs = pl.pallas_call(
        dw_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(E, nob, nm),
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=scratch,
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(idx, *inputs)
    if with_bias:
        return outs[0], outs[1].reshape(E, -1)
    return outs[0], None


def gated_dw(x, dh, idx, g, u, *, bm: int | None = None,
             interpret: bool = False):
    """(dwg, dwi) [E, nob, kb, bs, bs] fp32 for the fused gated FFN — the
    two branch grads are recomputed in the prologue from the (g, u)
    residuals and both M reductions accumulate innermost into separate
    VMEM scratch buffers, flushed once per (unit, output block)."""
    E, M, _ = x.shape
    nob, kb = idx.shape
    bs = dh.shape[2] // nob
    if bm is None:
        bm = bwd_bm(M, kb + 5, bs, x.dtype.itemsize)
    assert M % bm == 0
    nm = M // bm

    def gated_dw_kernel(idx_ref, dh_ref, g_ref, u_ref, *refs):
        x_refs = refs[:kb]
        dwg_ref, dwi_ref, accg_ref, accu_ref = refs[kb:]
        m = pl.program_id(2)

        @pl.when(m == 0)
        def _zero():
            accg_ref[...] = jnp.zeros((kb, bs, bs), jnp.float32)
            accu_ref[...] = jnp.zeros((kb, bs, bs), jnp.float32)

        dhb = dh_ref[0].astype(jnp.float32)
        gb = g_ref[0].astype(jnp.float32)
        ub = u_ref[0].astype(jnp.float32)
        dzg = (dhb * ub * act_bwd(gb, "silu")).astype(dh_ref.dtype)
        dzu = (dhb * act_fwd(gb, "silu")).astype(dh_ref.dtype)
        for k in range(kb):
            xT = x_refs[k][0].T
            accg_ref[k] = accg_ref[k] + jnp.dot(
                xT, dzg, preferred_element_type=jnp.float32)
            accu_ref[k] = accu_ref[k] + jnp.dot(
                xT, dzu, preferred_element_type=jnp.float32)

        @pl.when(m == nm - 1)
        def _flush():
            dwg_ref[...] = accg_ref[...][None, None]
            dwi_ref[...] = accu_ref[...][None, None]

    row = pl.BlockSpec((1, bm, bs), lambda e, o, m, idx: (e, m, o))
    in_specs = [row, row, row]
    inputs = [dh, g, u]
    for k in range(kb):
        in_specs.append(pl.BlockSpec(
            (1, bm, bs), lambda e, o, m, idx, k=k: (e, m, idx[o, k])))
        inputs.append(x)

    wout = pl.BlockSpec((1, 1, kb, bs, bs), lambda e, o, m, idx: (e, o, 0, 0, 0))
    outs = pl.pallas_call(
        gated_dw_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(E, nob, nm),
            in_specs=in_specs,
            out_specs=[wout, wout],
            scratch_shapes=[pltpu.VMEM((kb, bs, bs), jnp.float32),
                            pltpu.VMEM((kb, bs, bs), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((E, nob, kb, bs, bs), jnp.float32),
                   jax.ShapeDtypeStruct((E, nob, kb, bs, bs), jnp.float32)],
        interpret=interpret,
    )(idx, *inputs)
    return outs[0], outs[1]


# --------------------------------------------------- fused BP+UP (update_dw)
N_SCALAR_PREFETCH_UPDATE = 2    # (idx, hyp) — alias indices count these


def normalize_hyp(hyp, E: int, *, name: str = "hyp"):
    """Normalize every accepted hyp shape to the canonical ``[E, HYP_K]``
    f32 table: a ``(HYP_K,)`` row broadcasts to all units, and a legacy
    ``(2,)`` / ``[E, 2]`` [lr, momentum] pair pads to
    ``[lr, momentum, 0, 0, 0, 0, 1]`` — bitwise-identical SGD numerics
    (gs=1 is an exact no-op, b2..t are ignored by the SGD branch)."""
    hyp = jnp.asarray(hyp, jnp.float32)
    if hyp.shape in ((2,), (HYP_K,)):
        hyp = jnp.broadcast_to(hyp, (E,) + hyp.shape)
    if hyp.shape == (E, 2):
        hyp = jnp.concatenate(
            [hyp, jnp.zeros((E, HYP_K - 3), jnp.float32),
             jnp.ones((E, 1), jnp.float32)], axis=1)
    if hyp.shape != (E, HYP_K):
        raise ValueError(
            f"{name} must be a (2,) [lr, momentum] pair, a ({HYP_K},) "
            f"[{', '.join(HYP_COLS)}] row, or a per-unit [E={E}, 2] / "
            f"[E={E}, {HYP_K}] table, got {hyp.shape}")
    return hyp


def _epilogue_step(h, acc, w32, mom, vel, with_health):
    """One tile's in-kernel optimizer step from the fp32 gradient
    accumulator ``acc``: SGD(+momentum) when ``vel`` is None, Adam when
    the second accumulator rides along (the static slot switch of the
    module docstring).  ``h(col)`` reads the unit's hyp row; returns
    ``(new_w32, new_mom, new_vel, ok)`` with ``ok`` the tile's isfinite
    health verdict (None unless with_health).

    The Adam guards make an all-zero hyp row an exact freeze: pow(0, 0)
    is 1, so both bias-correction denominators hit the ``c == 0 -> 1``
    guard, and eps=0 makes the update denominator 0, which resolves to a
    zero update — w' = w bitwise.  With real hyperparameters every guard
    predicate is false and the selected values are the reference
    formula's, so parity with ``optim.adam`` is unaffected.  Health
    checks the raw accumulators (m', v'), never the guarded update — a
    ``where`` would mask NaNs (NaN comparisons are false)."""
    g = h(COL_GS) * acc
    if vel is None:
        mv = g if mom is None else h(COL_B1) * mom + g
        new_w32 = w32 - h(COL_LR) * mv
        ok = jnp.all(jnp.isfinite(mv)) if with_health else None
        return new_w32, (mv if mom is not None else None), None, ok
    b1, b2 = h(COL_B1), h(COL_B2)
    m1 = b1 * mom + (1.0 - b1) * g
    v2 = b2 * vel + (1.0 - b2) * jnp.square(g)
    t = h(COL_T)
    c1 = 1.0 - jnp.power(b1, t)
    c2 = 1.0 - jnp.power(b2, t)
    c1 = jnp.where(c1 == 0.0, 1.0, c1)
    c2 = jnp.where(c2 == 0.0, 1.0, c2)
    den = jnp.sqrt(v2 / c2) + h(COL_EPS)
    upd = jnp.where(den == 0.0, 0.0, (m1 / c1) / den)
    upd = upd + h(COL_WD) * w32
    new_w32 = w32 - h(COL_LR) * upd
    ok = (jnp.logical_and(jnp.all(jnp.isfinite(m1)),
                          jnp.all(jnp.isfinite(v2)))
          if with_health else None)
    return new_w32, m1, v2, ok


def update_dw(x, dy, idx, res, w, b, mom, mom_b, hyp, *, vel=None,
              vel_b=None, act: str = "none", with_bias: bool = True,
              bm: int | None = None, with_health: bool = False,
              interpret: bool = False):
    """The fused UP stage: the ``dw`` gradient reduction with the
    optimizer update applied in the flush epilogue — returns
    ``(new_w, new_b, new_mom, new_mom_b, new_vel, new_vel_b, health)``
    (None where the operand is absent) instead of ``(dw, db)``, with
    every parameter operand aliased to its output
    (``input_output_aliases``), so the weight gradient never leaves VMEM
    scratch and the parameters are rewritten in place.

    hyp is the scalar-prefetched per-unit ``[E, HYP_K]`` table of the
    module docstring's column registry (any shape ``normalize_hyp``
    accepts) — the epilogue reads row ``e = program_id(0)``, so each
    junction unit updates under its own hyperparameters.  The
    accumulator slots select the optimizer statically: mom/mom_b alone
    → SGD(+momentum), plus vel/vel_b → Adam (m, v); all slots fp32.
    Same grid, BlockSpecs and default row tile as ``dw``, so the fp32
    accumulation order matches the two-pass path exactly (parity to
    fp32 round-off).

    ``with_health=True`` adds a tiny non-aliased ``[E, 1]`` int32 output
    riding the same flush: each (e, ob) epilogue OR-reduces
    ``isfinite`` over the accumulator tiles it just wrote (both m and v
    for Adam, and the bias update for biased layers) and accumulates one
    count into unit e's slot — the in-kernel divergence detector (one
    VMEM compare per tile; the gradient still never materializes in
    HBM).  health[e] > 0 means unit e wrote at least one non-finite
    parameter tile this step."""
    E, M, _ = x.shape
    nob, kb = idx.shape
    bs = dy.shape[2] // nob
    has_res = act != "none"
    has_mom = mom is not None
    has_vel = vel is not None
    assert not has_vel or has_mom, "Adam (vel) requires the mom slot too"
    assert not (has_vel and with_bias) or vel_b is not None
    hyp = normalize_hyp(hyp, E)
    if bm is None:
        bm = bwd_bm(M, kb + 3, bs, x.dtype.itemsize)
    assert M % bm == 0
    nm = M // bm

    def fused_update_dw(idx_ref, hyp_ref, *refs):
        n_lead = 2 if has_res else 1
        dy_ref = refs[0]
        res_ref = refs[1] if has_res else None
        x_refs = refs[n_lead:n_lead + kb]
        pos = n_lead + kb
        w_ref = refs[pos]
        pos += 1
        mom_ref = refs[pos] if has_mom else None
        pos += int(has_mom)
        vel_ref = refs[pos] if has_vel else None
        pos += int(has_vel)
        b_ref = refs[pos] if with_bias else None
        pos += int(with_bias)
        mom_b_ref = refs[pos] if (has_mom and with_bias) else None
        pos += int(has_mom and with_bias)
        vel_b_ref = refs[pos] if (has_vel and with_bias) else None
        pos += int(has_vel and with_bias)
        outs = list(refs[pos:])
        new_w_ref = outs.pop(0)
        new_mom_ref = outs.pop(0) if has_mom else None
        new_vel_ref = outs.pop(0) if has_vel else None
        new_b_ref = outs.pop(0) if with_bias else None
        new_mom_b_ref = outs.pop(0) if (has_mom and with_bias) else None
        new_vel_b_ref = outs.pop(0) if (has_vel and with_bias) else None
        health_ref = outs.pop(0) if with_health else None
        if with_bias:
            accw_ref, accb_ref = outs
        else:
            (accw_ref,) = outs
        e = pl.program_id(0)
        o = pl.program_id(1)
        m = pl.program_id(2)

        @pl.when(m == 0)
        def _zero():
            accw_ref[...] = jnp.zeros((kb, bs, bs), jnp.float32)
            if with_bias:
                accb_ref[...] = jnp.zeros((1, bs), jnp.float32)

        if with_health:
            # health slot e is revisited across every (o, m) step: init once
            @pl.when(jnp.logical_and(o == 0, m == 0))
            def _zero_health():
                health_ref[0, 0] = 0

        if has_res:
            grad = act_bwd(res_ref[0].astype(jnp.float32), act)
            dzf = dy_ref[0].astype(jnp.float32) * grad
            dz = dzf.astype(dy_ref.dtype)
        else:
            dzf = None
            dz = dy_ref[0]
        for k in range(kb):
            accw_ref[k] = accw_ref[k] + jnp.dot(
                x_refs[k][0].T, dz, preferred_element_type=jnp.float32)
        if with_bias:
            s = dzf if dzf is not None else dy_ref[0].astype(jnp.float32)
            accb_ref[...] = accb_ref[...] + jnp.sum(s, axis=0, keepdims=True)

        @pl.when(m == nm - 1)
        def _apply():
            def h(col):
                return hyp_ref[e, col]

            new_w32, nmv, nvv, ok = _epilogue_step(
                h, accw_ref[...], w_ref[0, 0].astype(jnp.float32),
                mom_ref[0, 0] if has_mom else None,
                vel_ref[0, 0] if has_vel else None, with_health)
            if has_mom:
                new_mom_ref[0, 0] = nmv
            if has_vel:
                new_vel_ref[0, 0] = nvv
            new_w_ref[0, 0] = new_w32.astype(new_w_ref.dtype)
            if with_bias:
                new_b32, nmb, nvb, okb = _epilogue_step(
                    h, accb_ref[...], b_ref[...].astype(jnp.float32),
                    mom_b_ref[...] if has_mom else None,
                    vel_b_ref[...] if has_vel else None, with_health)
                if has_mom:
                    new_mom_b_ref[...] = nmb
                if has_vel:
                    new_vel_b_ref[...] = nvb
                new_b_ref[...] = new_b32.astype(new_b_ref.dtype)
                if with_health:
                    ok = jnp.logical_and(ok, okb)
            if with_health:
                health_ref[0, 0] += jnp.where(ok, 0, 1).astype(jnp.int32)

    in_specs = [pl.BlockSpec((1, bm, bs), lambda e, o, m, *_: (e, m, o))]
    inputs = [dy]
    if has_res:
        in_specs.append(pl.BlockSpec((1, bm, bs),
                                     lambda e, o, m, *_: (e, m, o)))
        inputs.append(res)
    for k in range(kb):
        in_specs.append(pl.BlockSpec(
            (1, bm, bs), lambda e, o, m, idx, hyp, k=k: (e, m, idx[o, k])))
        inputs.append(x)

    wspec = pl.BlockSpec((1, 1, kb, bs, bs), lambda e, o, m, *_: (e, o, 0, 0, 0))
    bspec = pl.BlockSpec((1, bs), lambda e, o, m, *_: (e, o))
    aliases: dict[int, int] = {}
    out_specs, out_shape = [], []

    def alias_io(arr, spec):
        """Parameter operand riding in AND out through the same BlockSpec —
        the in-place update contract."""
        aliases[N_SCALAR_PREFETCH_UPDATE + len(inputs)] = len(out_shape)
        in_specs.append(spec)
        inputs.append(arr)
        out_specs.append(spec)
        out_shape.append(jax.ShapeDtypeStruct(arr.shape, arr.dtype))

    alias_io(w, wspec)
    if has_mom:
        alias_io(mom, wspec)
    if has_vel:
        alias_io(vel, wspec)
    if with_bias:
        alias_io(b, bspec)
        if has_mom:
            alias_io(mom_b, bspec)
        if has_vel:
            alias_io(vel_b, bspec)
    if with_health:
        # non-aliased [E, 1] detector output: one slot per unit, revisited
        # across every (ob, m) step of that unit
        out_specs.append(pl.BlockSpec((1, 1), lambda e, o, m, *_: (e, 0)))
        out_shape.append(jax.ShapeDtypeStruct((E, 1), jnp.int32))

    scratch = [pltpu.VMEM((kb, bs, bs), jnp.float32)]
    if with_bias:
        scratch.append(pltpu.VMEM((1, bs), jnp.float32))

    outs = pl.pallas_call(
        fused_update_dw,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=N_SCALAR_PREFETCH_UPDATE,
            grid=(E, nob, nm),
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=scratch,
        ),
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(idx, hyp, *inputs)
    outs = list(outs)
    new_w = outs.pop(0)
    new_mom = outs.pop(0) if has_mom else None
    new_vel = outs.pop(0) if has_vel else None
    new_b = outs.pop(0) if with_bias else None
    new_mom_b = outs.pop(0) if (has_mom and with_bias) else None
    new_vel_b = outs.pop(0) if (has_vel and with_bias) else None
    health = outs.pop(0) if with_health else None
    return new_w, new_b, new_mom, new_mom_b, new_vel, new_vel_b, health


def update_gated_dw(x, dh, idx, g, u, wg, wi, mg, mi, hyp, *, vg=None,
                    vi=None, bm: int | None = None,
                    with_health: bool = False, interpret: bool = False):
    """Fused BP+UP for the gated junction: both branch gradients reduce
    into VMEM scratch exactly as in ``gated_dw`` and the flush epilogue
    applies the optimizer update to BOTH weight streams in place —
    returns ``(new_wg, new_wi, new_mg, new_mi, new_vg, new_vi, health)``
    (absent slots None), all parameter outputs aliased to their inputs.
    hyp is the per-unit ``[E, HYP_K]`` table (any shape ``normalize_hyp``
    accepts), row ``e`` read in the epilogue; the slots select the
    optimizer statically — mg/mi alone → SGD(+momentum), plus vg/vi →
    Adam.  ``with_health=True`` appends the non-aliased ``[E, 1]``
    int32 divergence detector (see ``update_dw``): the epilogue checks
    BOTH branch update tiles for non-finites."""
    E, M, _ = x.shape
    nob, kb = idx.shape
    bs = dh.shape[2] // nob
    has_mom = mg is not None
    has_vel = vg is not None
    assert not has_vel or (has_mom and vi is not None), \
        "Adam (vg/vi) requires the mg/mi slots too"
    hyp = normalize_hyp(hyp, E)
    if bm is None:
        bm = bwd_bm(M, kb + 5, bs, x.dtype.itemsize)
    assert M % bm == 0
    nm = M // bm

    def fused_update_gated_dw(idx_ref, hyp_ref, dh_ref, g_ref, u_ref, *refs):
        x_refs = refs[:kb]
        pos = kb
        wg_ref, wi_ref = refs[pos], refs[pos + 1]
        pos += 2
        if has_mom:
            mg_ref, mi_ref = refs[pos], refs[pos + 1]
            pos += 2
        if has_vel:
            vg_ref, vi_ref = refs[pos], refs[pos + 1]
            pos += 2
        outs = list(refs[pos:])
        new_wg_ref = outs.pop(0)
        new_wi_ref = outs.pop(0)
        if has_mom:
            new_mg_ref = outs.pop(0)
            new_mi_ref = outs.pop(0)
        if has_vel:
            new_vg_ref = outs.pop(0)
            new_vi_ref = outs.pop(0)
        health_ref = outs.pop(0) if with_health else None
        accg_ref, accu_ref = outs
        e = pl.program_id(0)
        o = pl.program_id(1)
        m = pl.program_id(2)

        @pl.when(m == 0)
        def _zero():
            accg_ref[...] = jnp.zeros((kb, bs, bs), jnp.float32)
            accu_ref[...] = jnp.zeros((kb, bs, bs), jnp.float32)

        if with_health:
            @pl.when(jnp.logical_and(o == 0, m == 0))
            def _zero_health():
                health_ref[0, 0] = 0

        dhb = dh_ref[0].astype(jnp.float32)
        gb = g_ref[0].astype(jnp.float32)
        ub = u_ref[0].astype(jnp.float32)
        dzg = (dhb * ub * act_bwd(gb, "silu")).astype(dh_ref.dtype)
        dzu = (dhb * act_fwd(gb, "silu")).astype(dh_ref.dtype)
        for k in range(kb):
            xT = x_refs[k][0].T
            accg_ref[k] = accg_ref[k] + jnp.dot(
                xT, dzg, preferred_element_type=jnp.float32)
            accu_ref[k] = accu_ref[k] + jnp.dot(
                xT, dzu, preferred_element_type=jnp.float32)

        @pl.when(m == nm - 1)
        def _apply():
            def h(col):
                return hyp_ref[e, col]

            new_g32, nmg, nvg, okg = _epilogue_step(
                h, accg_ref[...], wg_ref[0, 0].astype(jnp.float32),
                mg_ref[0, 0] if has_mom else None,
                vg_ref[0, 0] if has_vel else None, with_health)
            new_i32, nmi, nvi, oki = _epilogue_step(
                h, accu_ref[...], wi_ref[0, 0].astype(jnp.float32),
                mi_ref[0, 0] if has_mom else None,
                vi_ref[0, 0] if has_vel else None, with_health)
            if has_mom:
                new_mg_ref[0, 0] = nmg
                new_mi_ref[0, 0] = nmi
            if has_vel:
                new_vg_ref[0, 0] = nvg
                new_vi_ref[0, 0] = nvi
            new_wg_ref[0, 0] = new_g32.astype(new_wg_ref.dtype)
            new_wi_ref[0, 0] = new_i32.astype(new_wi_ref.dtype)
            if with_health:
                ok = jnp.logical_and(okg, oki)
                health_ref[0, 0] += jnp.where(ok, 0, 1).astype(jnp.int32)

    row = pl.BlockSpec((1, bm, bs), lambda e, o, m, *_: (e, m, o))
    in_specs = [row, row, row]
    inputs = [dh, g, u]
    for k in range(kb):
        in_specs.append(pl.BlockSpec(
            (1, bm, bs), lambda e, o, m, idx, hyp, k=k: (e, m, idx[o, k])))
        inputs.append(x)

    wspec = pl.BlockSpec((1, 1, kb, bs, bs), lambda e, o, m, *_: (e, o, 0, 0, 0))
    aliases: dict[int, int] = {}
    out_specs, out_shape = [], []

    def alias_io(arr):
        aliases[N_SCALAR_PREFETCH_UPDATE + len(inputs)] = len(out_shape)
        in_specs.append(wspec)
        inputs.append(arr)
        out_specs.append(wspec)
        out_shape.append(jax.ShapeDtypeStruct(arr.shape, arr.dtype))

    alias_io(wg)
    alias_io(wi)
    if has_mom:
        alias_io(mg)
        alias_io(mi)
    if has_vel:
        alias_io(vg)
        alias_io(vi)
    if with_health:
        out_specs.append(pl.BlockSpec((1, 1), lambda e, o, m, *_: (e, 0)))
        out_shape.append(jax.ShapeDtypeStruct((E, 1), jnp.int32))

    outs = pl.pallas_call(
        fused_update_gated_dw,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=N_SCALAR_PREFETCH_UPDATE,
            grid=(E, nob, nm),
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=[pltpu.VMEM((kb, bs, bs), jnp.float32),
                            pltpu.VMEM((kb, bs, bs), jnp.float32)],
        ),
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(idx, hyp, *inputs)
    outs = list(outs)
    new_wg = outs.pop(0)
    new_wi = outs.pop(0)
    new_mg = outs.pop(0) if has_mom else None
    new_mi = outs.pop(0) if has_mom else None
    new_vg = outs.pop(0) if has_vel else None
    new_vi = outs.pop(0) if has_vel else None
    health = outs.pop(0) if with_health else None
    return new_wg, new_wi, new_mg, new_mi, new_vg, new_vi, health
