"""Pallas TPU kernels for pre-defined block-sparse matmul — the paper's
edge processing on the MXU.

The FPGA processes z edges/cycle against z clash-free memory banks; here
one grid step processes one (128 x 128) edge-bundle as a dense MXU matmul,
and the clash-freedom property becomes the balanced block pattern: every
output tile has exactly ``kb`` bundles (fixed fan-in) and every input tile
feeds exactly ``fb`` bundles (fixed fan-out), so *every grid step does
identical work* — no load imbalance, no indirection stalls.

The block index arrays ride in as scalar-prefetch operands so the x/w
BlockSpec index_maps can depend on them (the TPU DMA engine resolves the
gather at tile granularity — the paper's interleaver in BlockSpec form).

Grids iterate the reduction dim innermost and accumulate into the output
block (revisiting), the canonical Pallas TPU pattern.  VMEM per step:
3 tiles of (bm x 128) + (128 x 128) — bounded and hardware-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 128


# ------------------------------------------------------------------ forward
def _fwd_kernel(idx_ref, x_ref, w_ref, o_ref):
    k = pl.program_id(2)
    part = jnp.dot(x_ref[...], w_ref[0, 0],
                   preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = part.astype(o_ref.dtype)

    @pl.when(k != 0)
    def _acc():
        o_ref[...] = (o_ref[...].astype(jnp.float32) + part).astype(o_ref.dtype)


def fwd(x, w, idx, *, bm: int = DEFAULT_BM, interpret: bool = False):
    """x [M, nib*bs], w [nob, kb, bs, bs], idx [nob, kb] -> [M, nob*bs]."""
    M = x.shape[0]
    nob, kb, bs, _ = w.shape
    assert M % bm == 0, f"M={M} must be a multiple of bm={bm} (pad in ops.py)"
    grid = (M // bm, nob, kb)
    return pl.pallas_call(
        _fwd_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bs), lambda m, o, k, idx: (m, idx[o, k])),
                pl.BlockSpec((1, 1, bs, bs), lambda m, o, k, idx: (o, k, 0, 0)),
            ],
            out_specs=pl.BlockSpec((bm, bs), lambda m, o, k, idx: (m, o)),
        ),
        out_shape=jax.ShapeDtypeStruct((M, nob * bs), x.dtype),
        interpret=interpret,
    )(idx, x, w)


# ------------------------------------------------------------------ dx
def _dx_kernel(rev_ob_ref, rev_t_ref, rev_cnt_ref, dy_ref, w_ref, o_ref):
    i = pl.program_id(1)
    f = pl.program_id(2)
    # dy block [bm, bs] @ w[ob, t]^T ; padded reverse slots (ragged fan-out)
    # contribute zero via the valid-count mask
    valid = (f < rev_cnt_ref[i]).astype(jnp.float32)
    part = jnp.dot(dy_ref[...], w_ref[0, 0].T,
                   preferred_element_type=jnp.float32) * valid

    @pl.when(f == 0)
    def _init():
        o_ref[...] = part.astype(o_ref.dtype)

    @pl.when(f != 0)
    def _acc():
        o_ref[...] = (o_ref[...].astype(jnp.float32) + part).astype(o_ref.dtype)


def dx(dy, w, rev_ob, rev_t, rev_cnt, *, bm: int = DEFAULT_BM,
       interpret: bool = False):
    """dy [M, nob*bs] -> dx [M, nib*bs] via the reverse (fan-out) pattern —
    balanced by construction (to +-1 for ragged densities), so the backward
    grid is as regular as the forward (the paper's equal-contribution
    invariant, eq. (2b))."""
    M = dy.shape[0]
    nib, fb = rev_ob.shape
    nob, kb, bs, _ = w.shape
    assert M % bm == 0
    grid = (M // bm, nib, fb)
    return pl.pallas_call(
        _dx_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bs),
                             lambda m, i, f, rob, rt, rc: (m, rob[i, f])),
                pl.BlockSpec((1, 1, bs, bs),
                             lambda m, i, f, rob, rt, rc: (rob[i, f], rt[i, f], 0, 0)),
            ],
            out_specs=pl.BlockSpec((bm, bs),
                                   lambda m, i, f, rob, rt, rc: (m, i)),
        ),
        out_shape=jax.ShapeDtypeStruct((M, nib * bs), dy.dtype),
        interpret=interpret,
    )(rev_ob, rev_t, rev_cnt, dy, w)


# ------------------------------------------------------------------ dw
def _dw_kernel(idx_ref, x_ref, dy_ref, o_ref):
    m = pl.program_id(2)
    part = jnp.dot(x_ref[...].T, dy_ref[...],
                   preferred_element_type=jnp.float32)

    @pl.when(m == 0)
    def _init():
        o_ref[...] = part[None, None].astype(o_ref.dtype)

    @pl.when(m != 0)
    def _acc():
        o_ref[...] = (o_ref[...].astype(jnp.float32)
                      + part[None, None]).astype(o_ref.dtype)


def dw(x, dy, idx, *, bm: int = DEFAULT_BM, interpret: bool = False):
    """dw [nob, kb, bs, bs] — reduction over M tiles innermost."""
    M = x.shape[0]
    nob, kb = idx.shape
    bs = dy.shape[1] // nob
    assert M % bm == 0
    grid = (nob, kb, M // bm)
    return pl.pallas_call(
        _dw_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bs), lambda o, k, m, idx: (m, idx[o, k])),
                pl.BlockSpec((bm, bs), lambda o, k, m, idx: (m, o)),
            ],
            out_specs=pl.BlockSpec((1, 1, bs, bs),
                                   lambda o, k, m, idx: (o, k, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((nob, kb, bs, bs), jnp.float32),
        interpret=interpret,
    )(idx, x, dy)
