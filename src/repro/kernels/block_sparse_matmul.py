"""Pallas TPU kernels for pre-defined block-sparse matmul — the paper's
edge processing on the MXU, as a *fused edge-bundle engine*.

The FPGA processes z clash-free edges/cycle against banked weight memories
and fuses FF/BP/UP into one pipeline.  Here the analogue is:

* **forward** — grid ``(M/bm, nob/bn)``: one step computes ``bn`` output
  tiles.  The whole ``kb`` fan-in reduction runs *inside* the kernel body
  against an fp32 VMEM scratch accumulator (no read-modify-write through
  the output ref, no revisiting), and the bias + activation epilogue (the
  paper's FF-stage sigmoid fused into the edge pipeline) is applied before
  the single output write.  The activation row block ``[bm, nib*bs]``
  stays resident in VMEM across the ``nob/bn`` bundle steps — the banked
  activation memory — while weight bundles stream through; the block
  index array rides in as a scalar-prefetch operand and drives in-kernel
  dynamic slices (the interleaver in SMEM).
* **dx** — grid ``(M/bm, nib)``: the reverse (fan-out) pattern reduction
  over ``fb`` runs in-body with the ragged valid-count mask applied per
  slot.  The activation gradient is recomputed in the prologue from the
  saved residual (output y, or pre-activation s for silu/gelu), so the
  elementwise grad tensor ``dz`` never materializes in HBM.
* **dw** — grid ``(nob, M/bm)`` with the M reduction innermost into fp32
  VMEM scratch, written once on the last step.  The ``kb`` gathered input
  blocks arrive through scalar-prefetch-driven BlockSpec index_maps (the
  interleaver as DMA descriptor), and the bias gradient accumulates in
  the same pass.

Tile sizes come from ``choose_tiles`` — a small autotune table keyed on
``(M, nob, kb, bs)`` with a VMEM-budget heuristic fallback (see
ROADMAP.md "Kernel engine" for the table format).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 128

# Activations whose gradient needs the pre-activation s (saved as a second
# forward output); the rest reconstruct the gradient from y itself.
ACT_NEEDS_PRE = ("silu", "gelu")
ACTIVATIONS = ("none", "relu", "sigmoid", "silu", "gelu")

_GELU_C = 0.7978845608028654  # sqrt(2/pi)
_GELU_A = 0.044715


def act_fwd(s, act: str):
    """Epilogue activation on the fp32 accumulator.  gelu is the tanh
    approximation — the same formula jax.nn.gelu(approximate=True) uses,
    so engine="pallas" and engine="jnp" agree bit-for-bit in structure."""
    if act == "none":
        return s
    if act == "relu":
        return jnp.maximum(s, 0.0)
    if act == "sigmoid":
        return jax.nn.sigmoid(s)
    if act == "silu":
        return s * jax.nn.sigmoid(s)
    if act == "gelu":
        u = _GELU_C * (s + _GELU_A * s * s * s)
        return 0.5 * s * (1.0 + jnp.tanh(u))
    raise ValueError(f"unknown activation {act!r}")


def act_bwd(res, act: str):
    """d act/d s from the residual: y for relu/sigmoid, s for silu/gelu."""
    if act == "none":
        return None  # caller skips the multiply entirely
    if act == "relu":
        return (res > 0.0).astype(jnp.float32)
    if act == "sigmoid":
        return res * (1.0 - res)
    if act == "silu":
        sg = jax.nn.sigmoid(res)
        return sg * (1.0 + res * (1.0 - sg))
    if act == "gelu":
        s = res
        u = _GELU_C * (s + _GELU_A * s * s * s)
        t = jnp.tanh(u)
        du = _GELU_C * (1.0 + 3.0 * _GELU_A * s * s)
        return 0.5 * (1.0 + t) + 0.5 * s * (1.0 - t * t) * du
    raise ValueError(f"unknown activation {act!r}")


# ------------------------------------------------------------- tile tuning
VMEM_BUDGET = 8 * 1024 * 1024   # conservative per-kernel working-set bound
MAX_BN = 8

# Autotune table: (M, nob, kb, bs) -> (bm, bn).  Entries are measured on
# real hardware and override the heuristic; the benchmark JSON artifacts
# (BENCH_*.json) are the data source for adding entries.
TUNE_TABLE: dict[tuple[int, int, int, int], tuple[int, int]] = {
    # paper MNIST junction (12544-sample epoch, 1024->512 @ kb=2, bs=128)
    (12544, 4, 2, 128): (512, 4),
    # transformer FFN up-projection bench shape (1024->4096 @ kb=2, bs=128)
    (4096, 32, 2, 128): (256, 8),
}


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def _choose_bm(M: int, row_blocks: int, bs: int, itemsize: int) -> int:
    """Largest row-tile (multiple of 16 sublanes) whose resident row block
    ``[bm, row_blocks*bs]`` fits half the VMEM budget."""
    row_bytes = max(1, row_blocks * bs * itemsize)
    bm = 512
    while bm > 16 and bm * row_bytes > VMEM_BUDGET // 2:
        bm //= 2
    return max(16, min(bm, _round_up(M, 16)))


def choose_tiles(M: int, nob: int, kb: int, bs: int, nib: int,
                 itemsize: int = 4) -> tuple[int, int]:
    """(bm, bn) for the fused forward: autotune table first, then a VMEM
    heuristic — bm bounded by the resident x row block, bn the largest
    power-of-two divisor of nob whose weight bundle fits 2 MB."""
    hit = TUNE_TABLE.get((M, nob, kb, bs))
    if hit is not None:
        bm, bn = hit
        return max(16, min(bm, _round_up(M, 16))), bn
    bm = _choose_bm(M, nib, bs, itemsize)
    bn = 1
    while (bn < MAX_BN and nob % (2 * bn) == 0
           and 2 * bn * kb * bs * bs * itemsize <= 2 * 1024 * 1024):
        bn *= 2
    return bm, bn


def fwd_grid(M: int, nob: int, kb: int, bs: int, nib: int,
             itemsize: int = 4) -> tuple[int, int]:
    """Grid of the fused forward for padded row count M — the acceptance
    bound: exactly (M/bm) * (nob/bn) steps, kb fully in-kernel."""
    bm, bn = choose_tiles(M, nob, kb, bs, nib, itemsize)
    return (_round_up(M, bm) // bm, nob // bn)


# ------------------------------------------------------------------ forward
def fwd(x, w, idx, bias, *, act: str = "none", bm: int | None = None,
        bn: int | None = None, save_pre: bool = False,
        interpret: bool = False):
    """x [M, nib*bs], w [nob, kb, bs, bs], idx [nob, kb], bias [nob*bs]
    -> act(x @ W_sparse + bias) [M, nob*bs] (+ pre-activation if save_pre).

    One grid step = one (row-tile x output-bundle): kb fan-in slots reduced
    in-body into fp32 VMEM scratch, epilogue fused, single output write.
    """
    M = x.shape[0]
    nob, kb, bs, _ = w.shape
    nib = x.shape[1] // bs
    cbm, cbn = choose_tiles(M, nob, kb, bs, nib, x.dtype.itemsize)
    bm = cbm if bm is None else bm
    bn = cbn if bn is None else bn
    if nob % bn:
        bn = 1
    assert M % bm == 0, f"M={M} must be a multiple of bm={bm} (pad in ops.py)"

    def kernel(idx_ref, x_ref, w_ref, b_ref, *rest):
        acc_ref = rest[-1]
        o_ref = rest[0]
        ob0 = pl.program_id(1) * bn
        for j in range(bn):
            acc = jnp.zeros((bm, bs), jnp.float32)
            for k in range(kb):
                ib = idx_ref[ob0 + j, k]
                xk = x_ref[:, pl.ds(ib * bs, bs)]
                acc = acc + jnp.dot(xk, w_ref[j, k],
                                    preferred_element_type=jnp.float32)
            acc_ref[:, j * bs:(j + 1) * bs] = acc
        s = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if save_pre:
            rest[1][...] = s.astype(rest[1].dtype)
        o_ref[...] = act_fwd(s, act).astype(o_ref.dtype)

    out_shape = [jax.ShapeDtypeStruct((M, nob * bs), x.dtype)]
    out_specs = [pl.BlockSpec((bm, bn * bs), lambda m, o, idx: (m, o))]
    if save_pre:
        out_shape.append(jax.ShapeDtypeStruct((M, nob * bs), x.dtype))
        out_specs.append(pl.BlockSpec((bm, bn * bs), lambda m, o, idx: (m, o)))

    outs = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(M // bm, nob // bn),
            in_specs=[
                # full activation row block, resident across bundle steps
                pl.BlockSpec((bm, nib * bs), lambda m, o, idx: (m, 0)),
                pl.BlockSpec((bn, kb, bs, bs), lambda m, o, idx: (o, 0, 0, 0)),
                pl.BlockSpec((1, bn * bs), lambda m, o, idx: (0, o)),
            ],
            out_specs=out_specs,
            scratch_shapes=[pltpu.VMEM((bm, bn * bs), jnp.float32)],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(idx, x, w, bias.reshape(1, -1))
    return (outs[0], outs[1]) if save_pre else (outs[0], None)


# ------------------------------------------------------------------ dx
def dx(dy, wrT, rev_ob, rev_cnt, res, *, act: str = "none",
       bm: int | None = None, interpret: bool = False):
    """dy [M, nob*bs] -> dx [M, nib*bs] via the reverse (fan-out) pattern.

    wrT [nib, fb, bs, bs] is the reverse-gathered, pre-transposed weight
    bundle (wrT[i, f] = w[rev_ob[i,f], rev_t[i,f]].T).  The fb reduction
    runs in-body with the ragged valid-count mask; the activation gradient
    is recomputed per dy block from the residual (fused epilogue grad)."""
    M = dy.shape[0]
    nib, fb, bs, _ = wrT.shape
    nob = dy.shape[1] // bs
    has_res = act != "none"
    row_blocks = nob * (2 if has_res else 1)
    if bm is None:
        # M arrives pre-padded by the forward's bm (a multiple of 16);
        # gcd keeps our (possibly different) choice an exact divisor
        bm = math.gcd(_choose_bm(M, row_blocks, bs, dy.dtype.itemsize), M)
    assert M % bm == 0

    def kernel(rev_ob_ref, rev_cnt_ref, *refs):
        if has_res:
            dy_ref, res_ref, wrt_ref, o_ref = refs
        else:
            dy_ref, wrt_ref, o_ref = refs
        i = pl.program_id(1)
        cnt = rev_cnt_ref[i]
        acc = jnp.zeros((bm, bs), jnp.float32)
        for f in range(fb):
            ob = rev_ob_ref[i, f]
            dyb = dy_ref[:, pl.ds(ob * bs, bs)]
            if has_res:
                g = act_bwd(res_ref[:, pl.ds(ob * bs, bs)].astype(jnp.float32),
                            act)
                dz = (dyb.astype(jnp.float32) * g).astype(dyb.dtype)
            else:
                dz = dyb
            part = jnp.dot(dz, wrt_ref[0, f],
                           preferred_element_type=jnp.float32)
            valid = (f < cnt).astype(jnp.float32)
            acc = acc + part * valid
        o_ref[...] = acc.astype(o_ref.dtype)

    in_specs = [pl.BlockSpec((bm, nob * bs), lambda m, i, rob, rc: (m, 0))]
    inputs = [dy]
    if has_res:
        in_specs.append(pl.BlockSpec((bm, nob * bs),
                                     lambda m, i, rob, rc: (m, 0)))
        inputs.append(res)
    in_specs.append(pl.BlockSpec((1, fb, bs, bs),
                                 lambda m, i, rob, rc: (i, 0, 0, 0)))
    inputs.append(wrT)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(M // bm, nib),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bs), lambda m, i, rob, rc: (m, i)),
        ),
        out_shape=jax.ShapeDtypeStruct((M, nib * bs), dy.dtype),
        interpret=interpret,
    )(rev_ob, rev_cnt, *inputs)


# ------------------------------------------------------------------ dw (+db)
def dw(x, dy, idx, res, *, act: str = "none", with_bias: bool = True,
       bm: int | None = None, interpret: bool = False):
    """(dw [nob, kb, bs, bs] fp32, db [nob*bs] fp32 or None) — the M
    reduction runs innermost into fp32 VMEM scratch (single output write
    per output block, no read-modify-write).  The kb gathered input blocks
    arrive through scalar-prefetch BlockSpec index_maps — the interleaver
    as a DMA descriptor — and, for biased layers, db accumulates from the
    same fused dz prologue (with_bias=False skips it entirely)."""
    M = x.shape[0]
    nob, kb = idx.shape
    bs = dy.shape[1] // nob
    has_res = act != "none"
    if bm is None:
        bm = math.gcd(_choose_bm(M, kb + 3, bs, x.dtype.itemsize), M)
    assert M % bm == 0
    nm = M // bm

    def kernel(idx_ref, *refs):
        n_in = (2 if has_res else 1) + kb
        dy_ref = refs[0]
        res_ref = refs[1] if has_res else None
        x_refs = refs[n_in - kb:n_in]
        if with_bias:
            dw_ref, db_ref, accw_ref, accb_ref = refs[n_in:]
        else:
            dw_ref, accw_ref = refs[n_in:]
        m = pl.program_id(1)

        @pl.when(m == 0)
        def _zero():
            accw_ref[...] = jnp.zeros((kb, bs, bs), jnp.float32)
            if with_bias:
                accb_ref[...] = jnp.zeros((1, bs), jnp.float32)

        if has_res:
            g = act_bwd(res_ref[...].astype(jnp.float32), act)
            dzf = dy_ref[...].astype(jnp.float32) * g
            dz = dzf.astype(dy_ref.dtype)
        else:
            dzf = None
            dz = dy_ref[...]
        for k in range(kb):
            accw_ref[k] = accw_ref[k] + jnp.dot(
                x_refs[k][...].T, dz, preferred_element_type=jnp.float32)
        if with_bias:
            s = dzf if dzf is not None else dy_ref[...].astype(jnp.float32)
            accb_ref[...] = accb_ref[...] + jnp.sum(s, axis=0, keepdims=True)

        @pl.when(m == nm - 1)
        def _flush():
            dw_ref[...] = accw_ref[...][None]
            if with_bias:
                db_ref[...] = accb_ref[...]

    in_specs = [pl.BlockSpec((bm, bs), lambda o, m, idx: (m, o))]
    inputs = [dy]
    if has_res:
        in_specs.append(pl.BlockSpec((bm, bs), lambda o, m, idx: (m, o)))
        inputs.append(res)
    for k in range(kb):
        in_specs.append(pl.BlockSpec(
            (bm, bs), lambda o, m, idx, k=k: (m, idx[o, k])))
        inputs.append(x)

    out_specs = [pl.BlockSpec((1, kb, bs, bs), lambda o, m, idx: (o, 0, 0, 0))]
    out_shape = [jax.ShapeDtypeStruct((nob, kb, bs, bs), jnp.float32)]
    scratch = [pltpu.VMEM((kb, bs, bs), jnp.float32)]
    if with_bias:
        out_specs.append(pl.BlockSpec((1, bs), lambda o, m, idx: (o, 0)))
        out_shape.append(jax.ShapeDtypeStruct((nob, bs), jnp.float32))
        scratch.append(pltpu.VMEM((1, bs), jnp.float32))

    outs = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nob, nm),
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=scratch,
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(idx, *inputs)
    if with_bias:
        return outs[0], outs[1].reshape(-1)
    return outs[0], None
