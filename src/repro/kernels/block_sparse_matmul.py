"""Pallas TPU kernels for pre-defined block-sparse matmul — the paper's
edge processing on the MXU, as a *fused edge-bundle engine*.

The FPGA processes z clash-free edges/cycle against banked weight memories
and fuses FF/BP/UP into one pipeline.  Here the analogue is:

* **forward** — grid ``(M/bm, nob/bn)``: one step computes ``bn`` output
  tiles.  The whole ``kb`` fan-in reduction runs *inside* the kernel body
  against an fp32 VMEM scratch accumulator (no read-modify-write through
  the output ref, no revisiting), and the bias + activation epilogue (the
  paper's FF-stage sigmoid fused into the edge pipeline) is applied before
  the single output write.  The activation row block ``[bm, nib*bs]``
  stays resident in VMEM across the ``nob/bn`` bundle steps — the banked
  activation memory — while weight bundles stream through; the block
  index array rides in as a scalar-prefetch operand and drives in-kernel
  dynamic slices (the interleaver in SMEM).
* **dx** — grid ``(M/bm, nib)``: the reverse (fan-out) pattern reduction
  over ``fb`` runs in-body with the ragged valid-count mask applied per
  slot.  The activation gradient is recomputed in the prologue from the
  saved residual (output y, or pre-activation s for silu/gelu), so the
  elementwise grad tensor ``dz`` never materializes in HBM.
* **dw** — grid ``(nob, M/bm)`` with the M reduction innermost into fp32
  VMEM scratch, written once on the last step.  The ``kb`` gathered input
  blocks arrive through scalar-prefetch-driven BlockSpec index_maps (the
  interleaver as DMA descriptor), and the bias gradient accumulates in
  the same pass.

Tile sizes come from ``choose_tiles`` — a small autotune table keyed on
``(M, nob, kb, bs)`` with a VMEM-budget heuristic fallback (see
ROADMAP.md "Kernel engine" for the table format).

**Expert-batched variants** (``expert_*``) extend every kernel with a
leading expert grid dimension — grid ``(E, M/bm, nob/bn)`` over per-expert
weights ``[E, nob, kb, bs, bs]``.  This is the paper's reuse claim made
literal: one pre-defined junction shape (the block pattern, riding once in
scalar prefetch) shared by all E replicated units, only the weights differ
per expert.  ``expert_gated_fwd`` additionally fuses the GShard/SwiGLU
gate — ``silu(x @ Wg) * (x @ Wi)`` — into a single pass: both fan-in
reductions accumulate side by side in VMEM scratch and the gate epilogue
is applied before the one output write, so the two pre-activations never
round-trip HBM in the forward (they are emitted only as backward
residuals).  ``expert_gated_dx``/``expert_gated_dw`` recompute both branch
gradients (``dz_g = dh * u * silu'(g)``, ``dz_u = dh * silu(g)``) in their
prologues from those residuals and run the two reverse/update reductions
in the same kernel body.  Expert tile sizes come from
``choose_expert_tiles`` / ``EXPERT_TUNE_TABLE`` keyed on
``(E, M, nob, kb, bs)``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 128

# Activations whose gradient needs the pre-activation s (saved as a second
# forward output); the rest reconstruct the gradient from y itself.
ACT_NEEDS_PRE = ("silu", "gelu")
ACTIVATIONS = ("none", "relu", "sigmoid", "silu", "gelu")

_GELU_C = 0.7978845608028654  # sqrt(2/pi)
_GELU_A = 0.044715


def act_fwd(s, act: str):
    """Epilogue activation on the fp32 accumulator.  gelu is the tanh
    approximation — the same formula jax.nn.gelu(approximate=True) uses,
    so engine="pallas" and engine="jnp" agree bit-for-bit in structure."""
    if act == "none":
        return s
    if act == "relu":
        return jnp.maximum(s, 0.0)
    if act == "sigmoid":
        return jax.nn.sigmoid(s)
    if act == "silu":
        return s * jax.nn.sigmoid(s)
    if act == "gelu":
        u = _GELU_C * (s + _GELU_A * s * s * s)
        return 0.5 * s * (1.0 + jnp.tanh(u))
    raise ValueError(f"unknown activation {act!r}")


def act_bwd(res, act: str):
    """d act/d s from the residual: y for relu/sigmoid, s for silu/gelu."""
    if act == "none":
        return None  # caller skips the multiply entirely
    if act == "relu":
        return (res > 0.0).astype(jnp.float32)
    if act == "sigmoid":
        return res * (1.0 - res)
    if act == "silu":
        sg = jax.nn.sigmoid(res)
        return sg * (1.0 + res * (1.0 - sg))
    if act == "gelu":
        s = res
        u = _GELU_C * (s + _GELU_A * s * s * s)
        t = jnp.tanh(u)
        du = _GELU_C * (1.0 + 3.0 * _GELU_A * s * s)
        return 0.5 * (1.0 + t) + 0.5 * s * (1.0 - t * t) * du
    raise ValueError(f"unknown activation {act!r}")


# ------------------------------------------------------------- tile tuning
VMEM_BUDGET = 8 * 1024 * 1024   # conservative per-kernel working-set bound
MAX_BN = 8

# Autotune table: (M, nob, kb, bs) -> (bm, bn).  Entries are measured on
# real hardware and override the heuristic; the benchmark JSON artifacts
# (BENCH_*.json) are the data source for adding entries.
TUNE_TABLE: dict[tuple[int, int, int, int], tuple[int, int]] = {
    # paper MNIST junction (12544-sample epoch, 1024->512 @ kb=2, bs=128)
    (12544, 4, 2, 128): (512, 4),
    # transformer FFN up-projection bench shape (1024->4096 @ kb=2, bs=128)
    (4096, 32, 2, 128): (256, 8),
}


# Expert-batched autotune table:
# (E, M, nob, kb, bs, n_weight_operands) -> (bm, bn).  Same contract as
# TUNE_TABLE with two extra key dims: the expert count, and the number of
# weight tensors the kernel streams per step (2 for the gated kernel, so
# its entries are tuned for double the weight-bundle residency).  Entries
# come from measured engine.moe.* rows in BENCH_*.json artifacts.
EXPERT_TUNE_TABLE: dict[tuple[int, int, int, int, int, int],
                        tuple[int, int]] = {
    # engine.moe bench full shape, gated entry kernel: E=4 experts, top-2
    # routed 2048 tokens (capacity rows M=1280), 1024->512 @ kb=2, bs=128
    (4, 1280, 4, 2, 128, 2): (256, 4),
}


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def _choose_bm(M: int, row_blocks: int, bs: int, itemsize: int) -> int:
    """Largest row-tile (multiple of 16 sublanes) whose resident row block
    ``[bm, row_blocks*bs]`` fits half the VMEM budget."""
    row_bytes = max(1, row_blocks * bs * itemsize)
    bm = 512
    while bm > 16 and bm * row_bytes > VMEM_BUDGET // 2:
        bm //= 2
    return max(16, min(bm, _round_up(M, 16)))


def _choose_bn(nob: int, kb: int, bs: int, itemsize: int,
               budget: int) -> int:
    """Largest power-of-two divisor of nob whose weight bundle fits the
    per-step VMEM budget."""
    bn = 1
    while (bn < MAX_BN and nob % (2 * bn) == 0
           and 2 * bn * kb * bs * bs * itemsize <= budget):
        bn *= 2
    return bn


def choose_tiles(M: int, nob: int, kb: int, bs: int, nib: int,
                 itemsize: int = 4) -> tuple[int, int]:
    """(bm, bn) for the fused forward: autotune table first, then a VMEM
    heuristic — bm bounded by the resident x row block, bn the largest
    power-of-two divisor of nob whose weight bundle fits 2 MB."""
    hit = TUNE_TABLE.get((M, nob, kb, bs))
    if hit is not None:
        bm, bn = hit
        return max(16, min(bm, _round_up(M, 16))), bn
    bm = _choose_bm(M, nib, bs, itemsize)
    return bm, _choose_bn(nob, kb, bs, itemsize, 2 * 1024 * 1024)


def choose_expert_tiles(E: int, M: int, nob: int, kb: int, bs: int, nib: int,
                        itemsize: int = 4, n_weight_operands: int = 1
                        ) -> tuple[int, int]:
    """(bm, bn) for the expert-batched kernels: EXPERT_TUNE_TABLE first,
    then the same VMEM heuristic as ``choose_tiles`` — one expert's row
    block is resident per grid step, so bm is bounded exactly as in the
    single-junction case; bn's weight-bundle budget is split across the
    ``n_weight_operands`` streamed weight tensors (2 for the gated
    kernel, which is also part of the table key)."""
    hit = EXPERT_TUNE_TABLE.get((E, M, nob, kb, bs, n_weight_operands))
    if hit is not None:
        bm, bn = hit
        return max(16, min(bm, _round_up(M, 16))), bn
    bm = _choose_bm(M, nib, bs, itemsize)
    budget = 2 * 1024 * 1024 // max(1, n_weight_operands)
    return bm, _choose_bn(nob, kb, bs, itemsize, budget)


def fwd_grid(M: int, nob: int, kb: int, bs: int, nib: int,
             itemsize: int = 4) -> tuple[int, int]:
    """Grid of the fused forward for padded row count M — the acceptance
    bound: exactly (M/bm) * (nob/bn) steps, kb fully in-kernel."""
    bm, bn = choose_tiles(M, nob, kb, bs, nib, itemsize)
    return (_round_up(M, bm) // bm, nob // bn)


# ------------------------------------------------------------------ forward
def fwd(x, w, idx, bias, *, act: str = "none", bm: int | None = None,
        bn: int | None = None, save_pre: bool = False,
        interpret: bool = False):
    """x [M, nib*bs], w [nob, kb, bs, bs], idx [nob, kb], bias [nob*bs]
    -> act(x @ W_sparse + bias) [M, nob*bs] (+ pre-activation if save_pre).

    One grid step = one (row-tile x output-bundle): kb fan-in slots reduced
    in-body into fp32 VMEM scratch, epilogue fused, single output write.
    """
    M = x.shape[0]
    nob, kb, bs, _ = w.shape
    nib = x.shape[1] // bs
    cbm, cbn = choose_tiles(M, nob, kb, bs, nib, x.dtype.itemsize)
    bm = cbm if bm is None else bm
    bn = cbn if bn is None else bn
    if nob % bn:
        bn = 1
    assert M % bm == 0, f"M={M} must be a multiple of bm={bm} (pad in ops.py)"

    def kernel(idx_ref, x_ref, w_ref, b_ref, *rest):
        acc_ref = rest[-1]
        o_ref = rest[0]
        ob0 = pl.program_id(1) * bn
        for j in range(bn):
            acc = jnp.zeros((bm, bs), jnp.float32)
            for k in range(kb):
                ib = idx_ref[ob0 + j, k]
                xk = x_ref[:, pl.ds(ib * bs, bs)]
                acc = acc + jnp.dot(xk, w_ref[j, k],
                                    preferred_element_type=jnp.float32)
            acc_ref[:, j * bs:(j + 1) * bs] = acc
        s = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if save_pre:
            rest[1][...] = s.astype(rest[1].dtype)
        o_ref[...] = act_fwd(s, act).astype(o_ref.dtype)

    out_shape = [jax.ShapeDtypeStruct((M, nob * bs), x.dtype)]
    out_specs = [pl.BlockSpec((bm, bn * bs), lambda m, o, idx: (m, o))]
    if save_pre:
        out_shape.append(jax.ShapeDtypeStruct((M, nob * bs), x.dtype))
        out_specs.append(pl.BlockSpec((bm, bn * bs), lambda m, o, idx: (m, o)))

    outs = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(M // bm, nob // bn),
            in_specs=[
                # full activation row block, resident across bundle steps
                pl.BlockSpec((bm, nib * bs), lambda m, o, idx: (m, 0)),
                pl.BlockSpec((bn, kb, bs, bs), lambda m, o, idx: (o, 0, 0, 0)),
                pl.BlockSpec((1, bn * bs), lambda m, o, idx: (0, o)),
            ],
            out_specs=out_specs,
            scratch_shapes=[pltpu.VMEM((bm, bn * bs), jnp.float32)],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(idx, x, w, bias.reshape(1, -1))
    return (outs[0], outs[1]) if save_pre else (outs[0], None)


# ------------------------------------------------------------------ dx
def dx(dy, wrT, rev_ob, rev_cnt, res, *, act: str = "none",
       bm: int | None = None, interpret: bool = False):
    """dy [M, nob*bs] -> dx [M, nib*bs] via the reverse (fan-out) pattern.

    wrT [nib, fb, bs, bs] is the reverse-gathered, pre-transposed weight
    bundle (wrT[i, f] = w[rev_ob[i,f], rev_t[i,f]].T).  The fb reduction
    runs in-body with the ragged valid-count mask; the activation gradient
    is recomputed per dy block from the residual (fused epilogue grad)."""
    M = dy.shape[0]
    nib, fb, bs, _ = wrT.shape
    nob = dy.shape[1] // bs
    has_res = act != "none"
    row_blocks = nob * (2 if has_res else 1)
    if bm is None:
        # M arrives pre-padded by the forward's bm (a multiple of 16);
        # gcd keeps our (possibly different) choice an exact divisor
        bm = math.gcd(_choose_bm(M, row_blocks, bs, dy.dtype.itemsize), M)
    assert M % bm == 0

    def kernel(rev_ob_ref, rev_cnt_ref, *refs):
        if has_res:
            dy_ref, res_ref, wrt_ref, o_ref = refs
        else:
            dy_ref, wrt_ref, o_ref = refs
        i = pl.program_id(1)
        cnt = rev_cnt_ref[i]
        acc = jnp.zeros((bm, bs), jnp.float32)
        for f in range(fb):
            ob = rev_ob_ref[i, f]
            dyb = dy_ref[:, pl.ds(ob * bs, bs)]
            if has_res:
                g = act_bwd(res_ref[:, pl.ds(ob * bs, bs)].astype(jnp.float32),
                            act)
                dz = (dyb.astype(jnp.float32) * g).astype(dyb.dtype)
            else:
                dz = dyb
            part = jnp.dot(dz, wrt_ref[0, f],
                           preferred_element_type=jnp.float32)
            valid = (f < cnt).astype(jnp.float32)
            acc = acc + part * valid
        o_ref[...] = acc.astype(o_ref.dtype)

    in_specs = [pl.BlockSpec((bm, nob * bs), lambda m, i, rob, rc: (m, 0))]
    inputs = [dy]
    if has_res:
        in_specs.append(pl.BlockSpec((bm, nob * bs),
                                     lambda m, i, rob, rc: (m, 0)))
        inputs.append(res)
    in_specs.append(pl.BlockSpec((1, fb, bs, bs),
                                 lambda m, i, rob, rc: (i, 0, 0, 0)))
    inputs.append(wrT)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(M // bm, nib),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bs), lambda m, i, rob, rc: (m, i)),
        ),
        out_shape=jax.ShapeDtypeStruct((M, nib * bs), dy.dtype),
        interpret=interpret,
    )(rev_ob, rev_cnt, *inputs)


# ------------------------------------------------------------------ dw (+db)
def dw(x, dy, idx, res, *, act: str = "none", with_bias: bool = True,
       bm: int | None = None, interpret: bool = False):
    """(dw [nob, kb, bs, bs] fp32, db [nob*bs] fp32 or None) — the M
    reduction runs innermost into fp32 VMEM scratch (single output write
    per output block, no read-modify-write).  The kb gathered input blocks
    arrive through scalar-prefetch BlockSpec index_maps — the interleaver
    as a DMA descriptor — and, for biased layers, db accumulates from the
    same fused dz prologue (with_bias=False skips it entirely)."""
    M = x.shape[0]
    nob, kb = idx.shape
    bs = dy.shape[1] // nob
    has_res = act != "none"
    if bm is None:
        bm = math.gcd(_choose_bm(M, kb + 3, bs, x.dtype.itemsize), M)
    assert M % bm == 0
    nm = M // bm

    def kernel(idx_ref, *refs):
        n_in = (2 if has_res else 1) + kb
        dy_ref = refs[0]
        res_ref = refs[1] if has_res else None
        x_refs = refs[n_in - kb:n_in]
        if with_bias:
            dw_ref, db_ref, accw_ref, accb_ref = refs[n_in:]
        else:
            dw_ref, accw_ref = refs[n_in:]
        m = pl.program_id(1)

        @pl.when(m == 0)
        def _zero():
            accw_ref[...] = jnp.zeros((kb, bs, bs), jnp.float32)
            if with_bias:
                accb_ref[...] = jnp.zeros((1, bs), jnp.float32)

        if has_res:
            g = act_bwd(res_ref[...].astype(jnp.float32), act)
            dzf = dy_ref[...].astype(jnp.float32) * g
            dz = dzf.astype(dy_ref.dtype)
        else:
            dzf = None
            dz = dy_ref[...]
        for k in range(kb):
            accw_ref[k] = accw_ref[k] + jnp.dot(
                x_refs[k][...].T, dz, preferred_element_type=jnp.float32)
        if with_bias:
            s = dzf if dzf is not None else dy_ref[...].astype(jnp.float32)
            accb_ref[...] = accb_ref[...] + jnp.sum(s, axis=0, keepdims=True)

        @pl.when(m == nm - 1)
        def _flush():
            dw_ref[...] = accw_ref[...][None]
            if with_bias:
                db_ref[...] = accb_ref[...]

    in_specs = [pl.BlockSpec((bm, bs), lambda o, m, idx: (m, o))]
    inputs = [dy]
    if has_res:
        in_specs.append(pl.BlockSpec((bm, bs), lambda o, m, idx: (m, o)))
        inputs.append(res)
    for k in range(kb):
        in_specs.append(pl.BlockSpec(
            (bm, bs), lambda o, m, idx, k=k: (m, idx[o, k])))
        inputs.append(x)

    out_specs = [pl.BlockSpec((1, kb, bs, bs), lambda o, m, idx: (o, 0, 0, 0))]
    out_shape = [jax.ShapeDtypeStruct((nob, kb, bs, bs), jnp.float32)]
    scratch = [pltpu.VMEM((kb, bs, bs), jnp.float32)]
    if with_bias:
        out_specs.append(pl.BlockSpec((1, bs), lambda o, m, idx: (o, 0)))
        out_shape.append(jax.ShapeDtypeStruct((nob, bs), jnp.float32))
        scratch.append(pltpu.VMEM((1, bs), jnp.float32))

    outs = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nob, nm),
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=scratch,
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(idx, *inputs)
    if with_bias:
        return outs[0], outs[1].reshape(-1)
    return outs[0], None


# ==================================================== expert-batched kernels
def expert_fwd(x, w, idx, bias, *, act: str = "none", bm: int | None = None,
               bn: int | None = None, save_pre: bool = False,
               interpret: bool = False):
    """x [E, M, nib*bs], w [E, nob, kb, bs, bs], shared idx [nob, kb],
    bias [E, nob*bs] -> act(x_e @ W_e + b_e) [E, M, nob*bs] per expert.

    Grid (E, M/bm, nob/bn): the expert dimension is the outermost grid
    axis; the pattern rides once in scalar prefetch and is reused by every
    expert — the paper's "one junction shape, replicated units" claim."""
    E, M, _ = x.shape
    _, nob, kb, bs, _ = w.shape
    nib = x.shape[2] // bs
    cbm, cbn = choose_expert_tiles(E, M, nob, kb, bs, nib, x.dtype.itemsize)
    bm = cbm if bm is None else bm
    bn = cbn if bn is None else bn
    if nob % bn:
        bn = 1
    assert M % bm == 0, f"M={M} must be a multiple of bm={bm} (pad in ops.py)"

    def kernel(idx_ref, x_ref, w_ref, b_ref, *rest):
        acc_ref = rest[-1]
        o_ref = rest[0]
        ob0 = pl.program_id(2) * bn
        for j in range(bn):
            acc = jnp.zeros((bm, bs), jnp.float32)
            for k in range(kb):
                ib = idx_ref[ob0 + j, k]
                xk = x_ref[0, :, pl.ds(ib * bs, bs)]
                acc = acc + jnp.dot(xk, w_ref[0, j, k],
                                    preferred_element_type=jnp.float32)
            acc_ref[:, j * bs:(j + 1) * bs] = acc
        s = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if save_pre:
            rest[1][0] = s.astype(rest[1].dtype)
        o_ref[0] = act_fwd(s, act).astype(o_ref.dtype)

    out_shape = [jax.ShapeDtypeStruct((E, M, nob * bs), x.dtype)]
    out_specs = [pl.BlockSpec((1, bm, bn * bs), lambda e, m, o, idx: (e, m, o))]
    if save_pre:
        out_shape.append(jax.ShapeDtypeStruct((E, M, nob * bs), x.dtype))
        out_specs.append(pl.BlockSpec((1, bm, bn * bs),
                                      lambda e, m, o, idx: (e, m, o)))

    outs = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(E, M // bm, nob // bn),
            in_specs=[
                pl.BlockSpec((1, bm, nib * bs), lambda e, m, o, idx: (e, m, 0)),
                pl.BlockSpec((1, bn, kb, bs, bs),
                             lambda e, m, o, idx: (e, o, 0, 0, 0)),
                pl.BlockSpec((1, bn * bs), lambda e, m, o, idx: (e, o)),
            ],
            out_specs=out_specs,
            scratch_shapes=[pltpu.VMEM((bm, bn * bs), jnp.float32)],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(idx, x, w, bias)
    return (outs[0], outs[1]) if save_pre else (outs[0], None)


def expert_gated_fwd(x, wg, wi, idx, *, bm: int | None = None,
                     bn: int | None = None, save_res: bool = False,
                     interpret: bool = False):
    """Fused SiLU-gate expert FFN entry: silu(x_e @ Wg_e) * (x_e @ Wi_e)
    in one pass — both kb fan-in reductions accumulate side by side in
    VMEM scratch, the gate epilogue fuses before the single output write.
    Returns (h, g_pre, u) — the pre-activation g and the linear branch u
    are emitted only when save_res (backward residuals)."""
    E, M, _ = x.shape
    _, nob, kb, bs, _ = wg.shape
    nib = x.shape[2] // bs
    cbm, cbn = choose_expert_tiles(E, M, nob, kb, bs, nib, x.dtype.itemsize,
                                   n_weight_operands=2)
    bm = cbm if bm is None else bm
    bn = cbn if bn is None else bn
    if nob % bn:
        bn = 1
    assert M % bm == 0, f"M={M} must be a multiple of bm={bm} (pad in ops.py)"

    def kernel(idx_ref, x_ref, wg_ref, wi_ref, *rest):
        accg_ref, accu_ref = rest[-2], rest[-1]
        h_ref = rest[0]
        ob0 = pl.program_id(2) * bn
        for j in range(bn):
            ag = jnp.zeros((bm, bs), jnp.float32)
            au = jnp.zeros((bm, bs), jnp.float32)
            for k in range(kb):
                ib = idx_ref[ob0 + j, k]
                xk = x_ref[0, :, pl.ds(ib * bs, bs)]
                ag = ag + jnp.dot(xk, wg_ref[0, j, k],
                                  preferred_element_type=jnp.float32)
                au = au + jnp.dot(xk, wi_ref[0, j, k],
                                  preferred_element_type=jnp.float32)
            accg_ref[:, j * bs:(j + 1) * bs] = ag
            accu_ref[:, j * bs:(j + 1) * bs] = au
        g = accg_ref[...]
        u = accu_ref[...]
        if save_res:
            rest[1][0] = g.astype(rest[1].dtype)
            rest[2][0] = u.astype(rest[2].dtype)
        h_ref[0] = (act_fwd(g, "silu") * u).astype(h_ref.dtype)

    out_shape = [jax.ShapeDtypeStruct((E, M, nob * bs), x.dtype)]
    out_specs = [pl.BlockSpec((1, bm, bn * bs), lambda e, m, o, idx: (e, m, o))]
    if save_res:
        for _ in range(2):
            out_shape.append(jax.ShapeDtypeStruct((E, M, nob * bs), x.dtype))
            out_specs.append(pl.BlockSpec((1, bm, bn * bs),
                                          lambda e, m, o, idx: (e, m, o)))

    outs = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(E, M // bm, nob // bn),
            in_specs=[
                pl.BlockSpec((1, bm, nib * bs), lambda e, m, o, idx: (e, m, 0)),
                pl.BlockSpec((1, bn, kb, bs, bs),
                             lambda e, m, o, idx: (e, o, 0, 0, 0)),
                pl.BlockSpec((1, bn, kb, bs, bs),
                             lambda e, m, o, idx: (e, o, 0, 0, 0)),
            ],
            out_specs=out_specs,
            scratch_shapes=[pltpu.VMEM((bm, bn * bs), jnp.float32),
                            pltpu.VMEM((bm, bn * bs), jnp.float32)],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(idx, x, wg, wi)
    return (outs[0], outs[1], outs[2]) if save_res else (outs[0], None, None)


def expert_dx(dy, wrT, rev_ob, rev_cnt, res, *, act: str = "none",
              bm: int | None = None, interpret: bool = False):
    """dy [E, M, nob*bs] -> dx [E, M, nib*bs] via the shared reverse
    pattern; wrT [E, nib, fb, bs, bs] per-expert reverse-gathered
    pre-transposed bundles.  Grid (E, M/bm, nib)."""
    E, M, _ = dy.shape
    _, nib, fb, bs, _ = wrT.shape
    nob = dy.shape[2] // bs
    has_res = act != "none"
    row_blocks = nob * (2 if has_res else 1)
    if bm is None:
        bm = math.gcd(_choose_bm(M, row_blocks, bs, dy.dtype.itemsize), M)
    assert M % bm == 0

    def kernel(rev_ob_ref, rev_cnt_ref, *refs):
        if has_res:
            dy_ref, res_ref, wrt_ref, o_ref = refs
        else:
            dy_ref, wrt_ref, o_ref = refs
        i = pl.program_id(2)
        cnt = rev_cnt_ref[i]
        acc = jnp.zeros((bm, bs), jnp.float32)
        for f in range(fb):
            ob = rev_ob_ref[i, f]
            dyb = dy_ref[0, :, pl.ds(ob * bs, bs)]
            if has_res:
                g = act_bwd(
                    res_ref[0, :, pl.ds(ob * bs, bs)].astype(jnp.float32), act)
                dz = (dyb.astype(jnp.float32) * g).astype(dyb.dtype)
            else:
                dz = dyb
            part = jnp.dot(dz, wrt_ref[0, 0, f],
                           preferred_element_type=jnp.float32)
            valid = (f < cnt).astype(jnp.float32)
            acc = acc + part * valid
        o_ref[0] = acc.astype(o_ref.dtype)

    in_specs = [pl.BlockSpec((1, bm, nob * bs),
                             lambda e, m, i, rob, rc: (e, m, 0))]
    inputs = [dy]
    if has_res:
        in_specs.append(pl.BlockSpec((1, bm, nob * bs),
                                     lambda e, m, i, rob, rc: (e, m, 0)))
        inputs.append(res)
    in_specs.append(pl.BlockSpec((1, 1, fb, bs, bs),
                                 lambda e, m, i, rob, rc: (e, i, 0, 0, 0)))
    inputs.append(wrT)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(E, M // bm, nib),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bm, bs),
                                   lambda e, m, i, rob, rc: (e, m, i)),
        ),
        out_shape=jax.ShapeDtypeStruct((E, M, nib * bs), dy.dtype),
        interpret=interpret,
    )(rev_ob, rev_cnt, *inputs)


def expert_gated_dx(dh, wgrT, wirT, rev_ob, rev_cnt, g, u, *,
                    bm: int | None = None, interpret: bool = False):
    """Fused two-branch dx for the gated expert FFN: both branch grads
    (dz_g = dh * u * silu'(g), dz_u = dh * silu(g)) are recomputed per dy
    block from the saved residuals and reduced against their reverse
    bundles in the same fb loop — one pass over dh/g/u per input block."""
    E, M, _ = dh.shape
    _, nib, fb, bs, _ = wgrT.shape
    nob = dh.shape[2] // bs
    if bm is None:
        bm = math.gcd(_choose_bm(M, 3 * nob, bs, dh.dtype.itemsize), M)
    assert M % bm == 0

    def kernel(rev_ob_ref, rev_cnt_ref, dh_ref, g_ref, u_ref, wgrt_ref,
               wirt_ref, o_ref):
        i = pl.program_id(2)
        cnt = rev_cnt_ref[i]
        acc = jnp.zeros((bm, bs), jnp.float32)
        for f in range(fb):
            ob = rev_ob_ref[i, f]
            cols = pl.ds(ob * bs, bs)
            dhb = dh_ref[0, :, cols].astype(jnp.float32)
            gb = g_ref[0, :, cols].astype(jnp.float32)
            ub = u_ref[0, :, cols].astype(jnp.float32)
            dzg = (dhb * ub * act_bwd(gb, "silu")).astype(dh_ref.dtype)
            dzu = (dhb * act_fwd(gb, "silu")).astype(dh_ref.dtype)
            part = (jnp.dot(dzg, wgrt_ref[0, 0, f],
                            preferred_element_type=jnp.float32)
                    + jnp.dot(dzu, wirt_ref[0, 0, f],
                              preferred_element_type=jnp.float32))
            valid = (f < cnt).astype(jnp.float32)
            acc = acc + part * valid
        o_ref[0] = acc.astype(o_ref.dtype)

    row = pl.BlockSpec((1, bm, nob * bs), lambda e, m, i, rob, rc: (e, m, 0))
    wspec = pl.BlockSpec((1, 1, fb, bs, bs),
                         lambda e, m, i, rob, rc: (e, i, 0, 0, 0))
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(E, M // bm, nib),
            in_specs=[row, row, row, wspec, wspec],
            out_specs=pl.BlockSpec((1, bm, bs),
                                   lambda e, m, i, rob, rc: (e, m, i)),
        ),
        out_shape=jax.ShapeDtypeStruct((E, M, nib * bs), dh.dtype),
        interpret=interpret,
    )(rev_ob, rev_cnt, dh, g, u, wgrT, wirT)


def expert_dw(x, dy, idx, res, *, act: str = "none", with_bias: bool = True,
              bm: int | None = None, interpret: bool = False):
    """(dw [E, nob, kb, bs, bs] fp32, db [E, nob*bs] fp32 or None) — grid
    (E, nob, M/bm) with the M reduction innermost into fp32 VMEM scratch,
    flushed once per (expert, output block); per-expert db accumulates in
    the same pass."""
    E, M, _ = x.shape
    nob, kb = idx.shape
    bs = dy.shape[2] // nob
    has_res = act != "none"
    if bm is None:
        bm = math.gcd(_choose_bm(M, kb + 3, bs, x.dtype.itemsize), M)
    assert M % bm == 0
    nm = M // bm

    def kernel(idx_ref, *refs):
        n_in = (2 if has_res else 1) + kb
        dy_ref = refs[0]
        res_ref = refs[1] if has_res else None
        x_refs = refs[n_in - kb:n_in]
        if with_bias:
            dw_ref, db_ref, accw_ref, accb_ref = refs[n_in:]
        else:
            dw_ref, accw_ref = refs[n_in:]
        m = pl.program_id(2)

        @pl.when(m == 0)
        def _zero():
            accw_ref[...] = jnp.zeros((kb, bs, bs), jnp.float32)
            if with_bias:
                accb_ref[...] = jnp.zeros((1, bs), jnp.float32)

        if has_res:
            grad = act_bwd(res_ref[0].astype(jnp.float32), act)
            dzf = dy_ref[0].astype(jnp.float32) * grad
            dz = dzf.astype(dy_ref.dtype)
        else:
            dzf = None
            dz = dy_ref[0]
        for k in range(kb):
            accw_ref[k] = accw_ref[k] + jnp.dot(
                x_refs[k][0].T, dz, preferred_element_type=jnp.float32)
        if with_bias:
            s = dzf if dzf is not None else dy_ref[0].astype(jnp.float32)
            accb_ref[...] = accb_ref[...] + jnp.sum(s, axis=0, keepdims=True)

        @pl.when(m == nm - 1)
        def _flush():
            dw_ref[...] = accw_ref[...][None, None]
            if with_bias:
                db_ref[...] = accb_ref[...][None]

    in_specs = [pl.BlockSpec((1, bm, bs), lambda e, o, m, idx: (e, m, o))]
    inputs = [dy]
    if has_res:
        in_specs.append(pl.BlockSpec((1, bm, bs),
                                     lambda e, o, m, idx: (e, m, o)))
        inputs.append(res)
    for k in range(kb):
        in_specs.append(pl.BlockSpec(
            (1, bm, bs), lambda e, o, m, idx, k=k: (e, m, idx[o, k])))
        inputs.append(x)

    out_specs = [pl.BlockSpec((1, 1, kb, bs, bs),
                              lambda e, o, m, idx: (e, o, 0, 0, 0))]
    out_shape = [jax.ShapeDtypeStruct((E, nob, kb, bs, bs), jnp.float32)]
    scratch = [pltpu.VMEM((kb, bs, bs), jnp.float32)]
    if with_bias:
        out_specs.append(pl.BlockSpec((1, 1, bs), lambda e, o, m, idx: (e, o, 0)))
        out_shape.append(jax.ShapeDtypeStruct((E, nob, bs), jnp.float32))
        scratch.append(pltpu.VMEM((1, bs), jnp.float32))

    outs = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(E, nob, nm),
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=scratch,
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(idx, *inputs)
    if with_bias:
        return outs[0], outs[1].reshape(E, -1)
    return outs[0], None


def expert_gated_dw(x, dh, idx, g, u, *, bm: int | None = None,
                    interpret: bool = False):
    """(dwg, dwi) [E, nob, kb, bs, bs] fp32 for the fused gated FFN — the
    two branch grads are recomputed in the prologue from the (g, u)
    residuals and both M reductions accumulate innermost into separate
    VMEM scratch buffers, flushed once per (expert, output block)."""
    E, M, _ = x.shape
    nob, kb = idx.shape
    bs = dh.shape[2] // nob
    if bm is None:
        bm = math.gcd(_choose_bm(M, kb + 5, bs, x.dtype.itemsize), M)
    assert M % bm == 0
    nm = M // bm

    def kernel(idx_ref, dh_ref, g_ref, u_ref, *refs):
        x_refs = refs[:kb]
        dwg_ref, dwi_ref, accg_ref, accu_ref = refs[kb:]
        m = pl.program_id(2)

        @pl.when(m == 0)
        def _zero():
            accg_ref[...] = jnp.zeros((kb, bs, bs), jnp.float32)
            accu_ref[...] = jnp.zeros((kb, bs, bs), jnp.float32)

        dhb = dh_ref[0].astype(jnp.float32)
        gb = g_ref[0].astype(jnp.float32)
        ub = u_ref[0].astype(jnp.float32)
        dzg = (dhb * ub * act_bwd(gb, "silu")).astype(dh_ref.dtype)
        dzu = (dhb * act_fwd(gb, "silu")).astype(dh_ref.dtype)
        for k in range(kb):
            xT = x_refs[k][0].T
            accg_ref[k] = accg_ref[k] + jnp.dot(
                xT, dzg, preferred_element_type=jnp.float32)
            accu_ref[k] = accu_ref[k] + jnp.dot(
                xT, dzu, preferred_element_type=jnp.float32)

        @pl.when(m == nm - 1)
        def _flush():
            dwg_ref[...] = accg_ref[...][None, None]
            dwi_ref[...] = accu_ref[...][None, None]

    row = pl.BlockSpec((1, bm, bs), lambda e, o, m, idx: (e, m, o))
    in_specs = [row, row, row]
    inputs = [dh, g, u]
    for k in range(kb):
        in_specs.append(pl.BlockSpec(
            (1, bm, bs), lambda e, o, m, idx, k=k: (e, m, idx[o, k])))
        inputs.append(x)

    wout = pl.BlockSpec((1, 1, kb, bs, bs), lambda e, o, m, idx: (e, o, 0, 0, 0))
    outs = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(E, nob, nm),
            in_specs=in_specs,
            out_specs=[wout, wout],
            scratch_shapes=[pltpu.VMEM((kb, bs, bs), jnp.float32),
                            pltpu.VMEM((kb, bs, bs), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((E, nob, kb, bs, bs), jnp.float32),
                   jax.ShapeDtypeStruct((E, nob, kb, bs, bs), jnp.float32)],
        interpret=interpret,
    )(idx, *inputs)
    return outs[0], outs[1]
