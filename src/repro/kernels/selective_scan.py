"""Pallas fused selective scan (Mamba-1) — §Perf iteration F4.

The XLA path (models/ssm.py) materializes every associative-scan log-stage
as a distinct [B, c, d_inner, N] HBM tensor; two measured attempts to cut
that traffic (bf16 elements, smaller chunks) were refuted (EXPERIMENTS.md
§Perf F1/F2) because the stage materialization itself is the cost.  This
kernel removes it structurally: the recurrence runs *inside* VMEM.

Layout: grid (B, d_inner/bd, S/c), sequence innermost so the state tile
``h [bd, N]`` lives in a VMEM scratch across sequence chunks of one
(batch, channel-tile) lane; per grid step the kernel loads
(dt, x) [c, bd] and (Bc, Cc) [c, N] tiles and runs the c-step recurrence
with a fori_loop:

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) outer B_t ;  y_t = h_t . C_t

HBM traffic per element: read dt, x, B, C + write y (+ state at chunk
boundaries) — no intermediate [.., c, d, N] tensors ever leave VMEM.
VMEM per step: (2c*bd + 2c*N + bd*N) * 4 B  ~= 0.6 MiB at c=128, bd=512,
N=16.  Matches the pure-jnp oracle (ref.selective_scan) to fp32 tolerance
in interpret mode (tests/test_kernels.py::test_selective_scan_kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(nc: int, dt_ref, x_ref, b_ref, c_ref, a_ref, h0_ref,
            y_ref, hout_ref, h_scratch):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        h_scratch[...] = h0_ref[0]                    # [bd, N]

    dt = dt_ref[0].astype(jnp.float32)                # [c, bd]
    xv = x_ref[0].astype(jnp.float32)                 # [c, bd]
    bv = b_ref[0].astype(jnp.float32)                 # [c, N]
    cv = c_ref[0].astype(jnp.float32)                 # [c, N]
    a = a_ref[...].astype(jnp.float32)                # [bd, N]
    c_len = dt.shape[0]

    def step(t, carry):
        h, ys = carry
        decay = jnp.exp(dt[t][:, None] * a)           # [bd, N]
        inp = (dt[t] * xv[t])[:, None] * bv[t][None, :]
        h = decay * h + inp
        y_t = jnp.sum(h * cv[t][None, :], axis=1)     # [bd]
        ys = jax.lax.dynamic_update_index_in_dim(ys, y_t, t, 0)
        return h, ys

    ys0 = jnp.zeros((c_len, dt.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, c_len, step, (h_scratch[...], ys0))
    h_scratch[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)

    @pl.when(s == nc - 1)
    def _finish():
        hout_ref[0] = h.astype(hout_ref.dtype)


def selective_scan(dt, x, bc, cc, a, h0, *, chunk: int = 128,
                   bd: int = 512, interpret: bool = False):
    """dt,x [B,S,di]; bc,cc [B,S,N]; a [di,N]; h0 [B,di,N].
    Returns (y [B,S,di], h_last [B,di,N])."""
    B, S, di = dt.shape
    N = bc.shape[-1]
    bd = min(bd, di)
    chunk = min(chunk, S)
    assert di % bd == 0 and S % chunk == 0
    grid = (B, di // bd, S // chunk)
    nc = S // chunk
    return pl.pallas_call(
        functools.partial(_kernel, nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b, d, s: (b, s, d)),   # dt
            pl.BlockSpec((1, chunk, bd), lambda b, d, s: (b, s, d)),   # x
            pl.BlockSpec((1, chunk, N), lambda b, d, s: (b, s, 0)),    # B
            pl.BlockSpec((1, chunk, N), lambda b, d, s: (b, s, 0)),    # C
            pl.BlockSpec((bd, N), lambda b, d, s: (d, 0)),             # A
            pl.BlockSpec((1, bd, N), lambda b, d, s: (b, d, 0)),       # h0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b, d, s: (b, s, d)),   # y
            pl.BlockSpec((1, bd, N), lambda b, d, s: (b, d, 0)),       # h_last
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), dt.dtype),
            jax.ShapeDtypeStruct((B, di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(dt, x, bc, cc, a, h0)


def hbm_bytes(B: int, S: int, di: int, N: int, elt: int = 4) -> int:
    """Analytic HBM traffic of the fused kernel (the §Perf F4 model)."""
    return elt * (2 * B * S * di          # dt, x reads
                  + 2 * B * S * N         # B, C reads
                  + B * S * di            # y write
                  + 2 * B * di * N)       # h0 read + h_last write
