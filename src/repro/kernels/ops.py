"""jit'd public wrappers around the Pallas kernels.

``block_sparse_matmul`` carries a custom_vjp wired to the dx/dw kernels —
the full paper pipeline (FF eq. (1), BP eq. (2), UP gradient of eq. (3))
runs through Pallas.  Kernels execute in interpret mode off-TPU (the
container is CPU-only); on TPU set ``interpret=False`` (the default
auto-detects the backend).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import block_sparse_matmul as bsm
from repro.kernels import fxp_qmatmul as fxpk
from repro.kernels import sigmoid_lut as slut


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x, bm):
    M = x.shape[0]
    pad = (-M) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, M


# ------------------------------------------------------------ block sparse
@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _bsm_core(x, w, idx, rev_ob, rev_t, rev_cnt, interpret):
    return bsm.fwd(x, w, idx, interpret=interpret)


def _bsm_fwd(x, w, idx, rev_ob, rev_t, rev_cnt, interpret):
    y = bsm.fwd(x, w, idx, interpret=interpret)
    return y, (x, w, idx, rev_ob, rev_t, rev_cnt)


def _bsm_bwd(interpret, res, dy):
    x, w, idx, rev_ob, rev_t, rev_cnt = res
    dxv = bsm.dx(dy, w, rev_ob, rev_t, rev_cnt, interpret=interpret)
    dwv = bsm.dw(x, dy, idx, interpret=interpret).astype(w.dtype)
    return dxv, dwv, None, None, None, None


_bsm_core.defvjp(_bsm_fwd, _bsm_bwd)


def block_sparse_matmul(x, w, idx, rev_ob, rev_t, rev_cnt, bias=None,
                        interpret: bool | None = None):
    """x [..., n_in] -> [..., n_out] through the pre-defined block pattern."""
    interpret = _auto_interpret() if interpret is None else interpret
    lead = x.shape[:-1]
    x2, M = _pad_rows(x.reshape(-1, x.shape[-1]), bsm.DEFAULT_BM)
    y = _bsm_core(x2, w.astype(x.dtype), idx, rev_ob, rev_t, rev_cnt, interpret)
    y = y[:M].reshape(*lead, -1)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


# ------------------------------------------------------------ fixed point
def fxp_qmatmul(a_code, w_code, *, bf: int, bn: int,
                interpret: bool | None = None):
    interpret = _auto_interpret() if interpret is None else interpret
    a2, M = _pad_rows(a_code, 128)
    K = a2.shape[1]
    pad_k = (-K) % 128
    if pad_k:
        a2 = jnp.pad(a2, ((0, 0), (0, pad_k)))
        w_code = jnp.pad(w_code, ((0, pad_k), (0, 0)))
    N = w_code.shape[1]
    pad_n = (-N) % 128
    if pad_n:
        w_code = jnp.pad(w_code, ((0, 0), (0, pad_n)))
    y = fxpk.qmatmul(a2, w_code, bf=bf, bn=bn, interpret=interpret)
    return y[:M, :N]


# ------------------------------------------------------------ LUT sigmoid
def sigmoid_lut(codes, table, interpret: bool | None = None):
    interpret = _auto_interpret() if interpret is None else interpret
    lead = codes.shape[:-1]
    c2, M = _pad_rows(codes.reshape(-1, codes.shape[-1]), 256)
    y = slut.lut_lookup(c2, table, interpret=interpret)
    return y[:M].reshape(*lead, codes.shape[-1])
