"""jit'd public wrappers around the Pallas kernels.

``junction_matmul`` is the ONE entry point for every pre-defined-sparse
junction — the paper's reconfigurable edge datapath as a single
custom_vjp.  A ``KernelSpec`` (expert count E, gate flag, activation,
tiles) selects the configuration; the kernels themselves are E-generic
(kernels/block_sparse_matmul.py), so:

* a single dense-model junction (``core/sparse_linear.apply``) is the
  ``E=1`` case — 4-D weights are squeezed in, the result squeezed out;
* MoE expert FFNs (``models/moe.moe_apply``) pass 5-D per-expert weights
  ``[E, nob, kb, bs, bs]`` sharing one block pattern;
* ``wi=`` switches on the fused SwiGLU gate ``silu(x@w) * (x@wi)`` with
  both branch grads recomputed from the saved (g, u) residuals.

The backward runs the full paper pipeline in Pallas: BP (eq. (2))
through ``dx`` — whose reverse weight bundles are DMA'd HBM→VMEM inside
the kernel (double-buffered, offsets from the scalar-prefetched reverse
pattern), NOT pre-gathered in XLA — and UP (gradient of eq. (3)) through
``dw``, with the activation gradient recomputed in the kernel prologues
from the saved residual so the elementwise grad tensor never round-trips
HBM.

``junction_train_update`` is the fused BP+UP twin: same forward, but the
backward consumes the weight gradient *inside* the update kernels —
``w -= lr * (momentum * m + dw)`` applied in the kernel epilogue with the
updated params/momenta returned as the weight operands' cotangents
through ``input_output_aliasing`` — so ``dw`` never round-trips HBM (the
paper's concurrent BP/UP pipeline; Dey et al. 2017's interleaved FF/BP/UP
edge processor).

``block_sparse_matmul`` / ``expert_block_sparse_matmul`` /
``expert_gated_matmul`` remain as thin aliases over ``junction_matmul``.

Kernels execute in interpret mode off-TPU (the container is CPU-only);
on TPU ``interpret=False`` (the default auto-detects the backend).

``resolve_engine`` maps the config-level ``engine`` switch
("pallas" | "jnp" | "auto") to a concrete path: auto picks the Pallas
engine on TPU backends and the jnp gather+einsum fallback elsewhere
(interpret-mode Pallas is an emulator — correct, but only suitable for
tests; CPU *tests* opt in with engine="pallas" explicitly).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import block_sparse_matmul as bsm
from repro.kernels import fxp_qmatmul as fxpk
from repro.kernels import sigmoid_lut as slut


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_engine(engine: str) -> str:
    """'auto' -> 'pallas' on TPU backends, 'jnp' elsewhere."""
    if engine in ("pallas", "jnp"):
        return engine
    if engine == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    raise ValueError(f"unknown engine {engine!r} (pallas | jnp | auto)")


# --------------------------------------------------------- junction matmul
class KernelSpec(NamedTuple):
    """Static (hashable) configuration of the unified junction custom_vjp:
    the paper's 'reconfigure the one datapath per junction' knob set."""
    E: int              # junction units sharing the pattern (1 = single)
    gated: bool         # fused SwiGLU gate (two weight operands, silu fixed)
    act: str            # fused epilogue activation ("none" when gated)
    bm: int             # row tile
    bn: int             # output-bundle tile
    has_bias: bool
    interpret: bool
    with_health: bool = False   # fused update emits the [E] divergence flags
    # "none" | "int8" | "fxp" — the quantized-inference configurations
    # (core/quantize.py).  Quantized specs are forward-only: they bypass
    # the custom_vjp entirely and junction_train_update refuses them.
    quant: str = "none"


def _kernel_scope(name: str, spec: KernelSpec):
    """Profiler attribution for the junction entry points: a
    ``jax.named_scope`` keyed off the KernelSpec knobs (E / gated / act /
    quant), so a ``jax.profiler`` trace (``--profile`` on the launchers)
    shows e.g. ``junction_train_update_E16_gated`` instead of an
    anonymous pallas_call.  Pure metadata on the jaxpr scope stack — adds
    no ops and changes no jaxpr equations (regression-tested in
    tests/test_obs.py)."""
    tag = f"{name}_E{spec.E}"
    if spec.gated:
        tag += "_gated"
    elif spec.act != "none":
        tag += f"_{spec.act}"
    if spec.quant != "none":
        tag += f"_{spec.quant}"
    return jax.named_scope(tag)


def _fwd_call(spec, x, ws, b, idx, save: bool):
    """(y, res) through the forward kernels; res is the backward residual
    ((g, u) for gated, pre-activation or y for plain activations, None
    otherwise) — emitted only when ``save``."""
    if spec.gated:
        h, g, u = bsm.gated_fwd(x, ws[0], ws[1], idx, bm=spec.bm, bn=spec.bn,
                                save_res=save, interpret=spec.interpret)
        return h, ((g, u) if save else None)
    needs_pre = spec.act in bsm.ACT_NEEDS_PRE
    y, pre = bsm.fwd(x, ws[0], idx, b, act=spec.act, bm=spec.bm, bn=spec.bn,
                     save_pre=save and needs_pre, interpret=spec.interpret)
    if not save:
        return y, None
    return y, (pre if needs_pre else (y if spec.act != "none" else None))


def _dx_call(spec, ws, res, dy, rev_ob, rev_t, rev_cnt):
    """BP through the reverse pattern — the reverse weight bundles are
    DMA'd HBM→VMEM inside the kernel from the forward-layout weights (no
    XLA w[rev_ob, rev_t] pre-gather)."""
    if spec.gated:
        g, u = res
        return bsm.gated_dx(dy, ws[0], ws[1], rev_ob, rev_t, rev_cnt, g, u,
                            interpret=spec.interpret)
    return bsm.dx(dy, ws[0], rev_ob, rev_t, rev_cnt, res, act=spec.act,
                  interpret=spec.interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _junction_core(spec, x, ws, b, idx, rev_ob, rev_t, rev_cnt):
    """x [E, M, nib*bs], ws tuple of 1 (plain) or 2 (gated) weight tensors
    [E, nob, kb, bs, bs], b [E, nob*bs] -> y [E, M, nob*bs]."""
    y, _ = _fwd_call(spec, x, ws, b, idx, save=False)
    return y


def _junction_fwd(spec, x, ws, b, idx, rev_ob, rev_t, rev_cnt):
    y, res = _fwd_call(spec, x, ws, b, idx, save=True)
    return y, (x, ws, res, idx, rev_ob, rev_t, rev_cnt)


def _junction_bwd(spec, saved, dy):
    x, ws, res, idx, rev_ob, rev_t, rev_cnt = saved
    dxv = _dx_call(spec, ws, res, dy, rev_ob, rev_t, rev_cnt)
    if spec.gated:
        g, u = res
        dwg, dwi = bsm.gated_dw(x, dy, idx, g, u, interpret=spec.interpret)
        dws = (dwg.astype(ws[0].dtype), dwi.astype(ws[1].dtype))
        db = jnp.zeros((dy.shape[0], dy.shape[2]), jnp.float32)
        return dxv, dws, db, None, None, None, None
    dwv, dbv = bsm.dw(x, dy, idx, res, act=spec.act,
                      with_bias=spec.has_bias, interpret=spec.interpret)
    if dbv is None:  # bias-free layer: the zero-bias operand gets zeros
        dbv = jnp.zeros((dy.shape[0], dy.shape[2]), jnp.float32)
    return dxv, (dwv.astype(ws[0].dtype),), dbv, None, None, None, None


_junction_core.defvjp(_junction_fwd, _junction_bwd)


# ------------------------------------------------- fused BP+UP custom_vjp
@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _junction_update_core(spec, x, ws, b, moms, mom_b, vels, vel_b, hyp,
                          health, idx, rev_ob, rev_t, rev_cnt):
    """Forward identical to _junction_core; the vjp's cotangents for the
    parameter operands are the optimizer-UPDATED values computed by the
    fused update_dw kernels (kernels/block_sparse_matmul.py) — the
    paper's concurrent BP+UP pipeline.  moms/vels are accumulator-slot
    tuples mirroring ws (both empty = plain SGD, moms alone =
    SGD+momentum, both = Adam m/v — the kernels' static slot switch),
    mom_b/vel_b the matching 0/1-tuples for the bias, hyp the per-unit
    [E, HYP_K] f32 table of the kernel module's column registry.  The
    weight gradient never materializes in HBM: it lives in VMEM scratch
    and is consumed by the in-kernel update, whose outputs alias the
    parameter inputs.

    ``health`` is a dummy f32 [E] operand riding the same cotangent
    channel: when ``spec.with_health`` the update kernels' non-aliased
    [E, 1] int32 divergence flags come back as its cotangent (count of
    non-finite update tiles per unit), so the in-kernel detector
    surfaces through an ordinary jax.grad without materializing any
    gradient — the forward ignores the operand entirely."""
    y, _ = _fwd_call(spec, x, ws, b, idx, save=False)
    return y


def _junction_update_fwd(spec, x, ws, b, moms, mom_b, vels, vel_b, hyp,
                         health, idx, rev_ob, rev_t, rev_cnt):
    y, res = _fwd_call(spec, x, ws, b, idx, save=True)
    return y, (x, ws, b, res, moms, mom_b, vels, vel_b, hyp, idx, rev_ob,
               rev_t, rev_cnt)


def _junction_update_bwd(spec, saved, dy):
    (x, ws, b, res, moms, mom_b, vels, vel_b, hyp, idx, rev_ob, rev_t,
     rev_cnt) = saved
    dxv = _dx_call(spec, ws, res, dy, rev_ob, rev_t, rev_cnt)
    if spec.gated:
        g, u = res
        nwg, nwi, nmg, nmi, nvg, nvi, flags = bsm.update_gated_dw(
            x, dy, idx, g, u, ws[0], ws[1],
            moms[0] if moms else None, moms[1] if moms else None,
            hyp, vg=vels[0] if vels else None,
            vi=vels[1] if vels else None,
            with_health=spec.with_health, interpret=spec.interpret)
        new_ws = (nwg, nwi)
        new_moms = (nmg, nmi) if moms else ()
        new_vels = (nvg, nvi) if vels else ()
        new_b = jnp.zeros_like(b)    # gated junctions carry no bias
        new_mom_b = ()
        new_vel_b = ()
    else:
        nw, nb, nm, nmb, nv, nvb, flags = bsm.update_dw(
            x, dy, idx, res, ws[0], b if spec.has_bias else None,
            moms[0] if moms else None,
            mom_b[0] if mom_b else None,
            hyp, vel=vels[0] if vels else None,
            vel_b=vel_b[0] if vel_b else None,
            act=spec.act, with_bias=spec.has_bias,
            with_health=spec.with_health, interpret=spec.interpret)
        new_ws = (nw,)
        new_moms = (nm,) if moms else ()
        new_vels = (nv,) if vels else ()
        new_b = nb if spec.has_bias else jnp.zeros_like(b)
        new_mom_b = (nmb,) if mom_b else ()
        new_vel_b = (nvb,) if vel_b else ()
    d_health = (flags.reshape(spec.E).astype(jnp.float32)
                if spec.with_health else jnp.zeros((spec.E,), jnp.float32))
    return (dxv, new_ws, new_b, new_moms, new_mom_b, new_vels, new_vel_b,
            jnp.zeros_like(hyp), d_health, None, None, None, None)


_junction_update_core.defvjp(_junction_update_fwd, _junction_update_bwd)


def junction_matmul(x, w, idx, rev_ob, rev_t, rev_cnt, *, wi=None, bias=None,
                    act: str = "none", interpret: bool | None = None,
                    bm: int | None = None, bn: int | None = None,
                    w_scale=None, wi_scale=None, x_scale=None,
                    qfmt=None, qlut=None):
    """The unified junction: y = act(x @ W_sparse + bias) through the
    pre-defined block pattern, every configuration through ONE custom_vjp.

    * ``w.ndim == 4`` (``[nob, kb, bs, bs]``): single junction.  x may
      carry any leading dims ``[..., n_in]``; runs as the kernels' E=1
      case and is squeezed back to ``[..., n_out]``.
    * ``w.ndim == 5`` (``[E, nob, kb, bs, bs]``): E junction units
      sharing the pattern (MoE experts).  x ``[E, M, n_in]``, bias
      ``[E, n_out]`` -> y ``[E, M, n_out]``.
    * ``wi=`` (same shape as w): fused SwiGLU gate
      ``silu(x @ w) * (x @ wi)`` — one forward pass, two-branch fused
      backward; ``act``/``bias`` must stay at their defaults.
    * quantized inference (``core/quantize.py`` leaves): ``w_scale``
      (``[nob, kb]`` / ``[E, nob, kb]`` — with ``wi_scale`` for the
      gate) selects the int8 path with optional calibrated ``x_scale``;
      ``qfmt`` + ``qlut`` select full fixed-point (plain junctions
      only, LUT replaces ``act``).  These specs are FORWARD-ONLY — no
      custom_vjp; differentiate the fp junction instead.
    """
    interpret = _auto_interpret() if interpret is None else interpret
    gated = wi is not None
    if gated and (bias is not None or act != "none"):
        raise ValueError("gated junction fixes act=silu-gate and takes no bias")
    if qfmt is not None or w_scale is not None:
        return _junction_quant(x, w, idx, wi=wi, bias=bias, act=act,
                               interpret=interpret, bm=bm, bn=bn,
                               w_scale=w_scale, wi_scale=wi_scale,
                               x_scale=x_scale, qfmt=qfmt, qlut=qlut)
    if jnp.issubdtype(w.dtype, jnp.integer):
        raise ValueError(
            "integer-code weights need their quantization leaves "
            "(w_scale for int8, qfmt+qlut for fixed point) — refusing to "
            "cast codes to floats silently")
    single, lead, x3, w5, wi5, b2, E, M, nob, bs, bm, bn = _prep_junction(
        x, w, wi, bias, bm, bn, gated)
    b = (jnp.zeros((E, nob * bs), x.dtype) if b2 is None
         else b2.astype(x.dtype))
    ws = ((w5.astype(x.dtype), wi5.astype(x.dtype)) if gated
          else (w5.astype(x.dtype),))
    spec = KernelSpec(E=E, gated=gated, act=act, bm=bm, bn=bn,
                      has_bias=bias is not None, interpret=interpret)
    with _kernel_scope("junction_matmul", spec):
        y = _junction_core(spec, x3, ws, b, idx, rev_ob, rev_t, rev_cnt)
    y = y[:, :M]
    return y.reshape(*lead, nob * bs) if single else y


def _junction_quant(x, w, idx, *, wi, bias, act, interpret, bm, bn,
                    w_scale, wi_scale, x_scale, qfmt, qlut):
    """Forward-only dispatch of the quantized KernelSpec configurations:
    same shape lifting / tile selection / row padding as the fp path,
    scales and codes lifted alongside, then a DIRECT call into the
    quantized forward kernels — no custom_vjp, nothing to differentiate."""
    gated = wi is not None
    fxp_mode = qfmt is not None
    if fxp_mode and gated:
        raise ValueError("fxp quantization covers plain junctions only — "
                         "the gate epilogue has no single-LUT fixed-point "
                         "form; use the int8 path for gated junctions")
    if fxp_mode and qlut is None:
        raise ValueError("fxp mode needs the baked activation table (qlut)")
    if not fxp_mode and gated and wi_scale is None:
        raise ValueError("gated int8 junction needs wi_scale for the "
                         "second branch")
    single, lead, x3, w5, wi5, b2, E, M, nob, bs, bm, bn = _prep_junction(
        x, w, wi, bias, bm, bn, gated)
    spec = KernelSpec(E=E, gated=gated, act=act, bm=bm, bn=bn,
                      has_bias=bias is not None, interpret=interpret,
                      quant="fxp" if fxp_mode else "int8")
    lift = lambda s: None if s is None else (s[None] if single else s)
    # bias stays fp32 into the quant kernels (the fxp epilogue re-encodes
    # it on the triplet grid; a compute-dtype cast could move the code)
    b = (jnp.zeros((E, nob * bs), jnp.float32) if b2 is None
         else b2.astype(jnp.float32))
    xs = (None if x_scale is None
          else jnp.asarray(x_scale, jnp.float32).reshape(-1))
    with _kernel_scope("junction_matmul", spec):
        if spec.quant == "fxp":
            y = bsm.fwd_fxp(x3, w5, idx, qfmt, qlut, b, bm=spec.bm,
                            bn=spec.bn, interpret=spec.interpret)
        elif spec.gated:
            y = bsm.gated_fwd_int8(x3, w5, wi5, idx, lift(w_scale),
                                   lift(wi_scale), x_scale=xs, bm=spec.bm,
                                   bn=spec.bn, interpret=spec.interpret)
        else:
            y = bsm.fwd_int8(x3, w5, idx, lift(w_scale), b, act=spec.act,
                             x_scale=xs, bm=spec.bm, bn=spec.bn,
                             interpret=spec.interpret)
    y = y[:, :M]
    return y.reshape(*lead, nob * bs) if single else y


def _prep_junction(x, w, wi, bias, bm, bn, gated):
    """Shared shape/tile/pad preprocessing of the junction wrappers: the
    4-D (single) vs 5-D (expert-batched) squeeze, tile selection and row
    padding."""
    single = w.ndim == 4
    if single:
        lead = x.shape[:-1]
        x3 = x.reshape(1, -1, x.shape[-1])
        w5 = w[None]
        wi5 = wi[None] if gated else None
        b2 = None if bias is None else bias[None]
    else:
        lead = None
        x3, w5, wi5, b2 = x, w, wi, bias
    E, M0, _ = x3.shape
    _, nob, kb, bs, _ = w5.shape
    nib = x3.shape[-1] // bs
    if bm is None or bn is None:
        cbm, cbn = bsm.choose_tiles(M0, nob, kb, bs, nib, x.dtype.itemsize,
                                    E=E, n_weight_operands=2 if gated else 1)
        bm = cbm if bm is None else bm
        bn = cbn if bn is None else bn
    if nob % bn:
        bn = 1
    x3, M = _pad_junction_rows(x3, bm)
    return single, lead, x3, w5, wi5, b2, E, M, nob, bs, bm, bn


def _pad_junction_rows(x, bm):
    M = x.shape[1]
    pad = (-M) % bm
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x, M


def junction_train_update(x, w, idx, rev_ob, rev_t, rev_cnt, *, hyp,
                          wi=None, bias=None, act: str = "none",
                          mom=None, mom_wi=None, mom_b=None, vel=None,
                          vel_wi=None, vel_b=None, health=None,
                          interpret: bool | None = None,
                          bm: int | None = None, bn: int | None = None):
    """The fused BP+UP junction — forward y = act(x @ W_sparse + bias)
    exactly like ``junction_matmul``, but the custom_vjp's cotangents for
    the parameter operands (w / wi / bias and their accumulator slots)
    are the optimizer-UPDATED values: the backward runs BP through the
    in-kernel-DMA ``dx`` kernels against the OLD weights, reduces the
    weight gradient into VMEM scratch, and applies the optimizer update
    in the same kernel epilogue, writing the new params/slots through
    ``input_output_aliasing`` — ``dw`` never materializes in HBM (the
    paper's concurrent edge-processor UP stage).  A fused train step
    treats these cotangents as the new parameters (train/steps.py);
    ``optim.FusedOptimizer.merge`` adopts them and tree-maps the dense
    leaves.

    hyp: a hyperparameter row shared by every junction unit — the legacy
    ``[lr, momentum]`` (2,) pair or the full ``(HYP_K,)`` registry row —
    OR, for 5-D expert-batched weights, a per-unit ``[E, 2]`` /
    ``[E, HYP_K]`` table so each unit trains under its own
    hyperparameters in the same launch (the population-search contract:
    E candidate networks sharing one pattern, one kernel grid, E
    distinct hyperparameter rows).  Normalized by
    ``kernels.block_sparse_matmul.normalize_hyp`` and streamed through
    scalar prefetch; the update epilogue reads row ``program_id(0)``.

    The accumulator slots select the optimizer statically (the kernel
    module's slot layout): mom/mom_wi/mom_b alone → SGD(+momentum),
    plus vel/vel_wi/vel_b → Adam (first/second moments m, v); all slots
    fp32 even for bf16 params, all None → plain SGD.

    health: optional f32 zeros of shape ``(E,)`` (``(1,)`` for a single
    4-D junction) switching on the in-kernel divergence detector — the
    operand's *cotangent* under jax.grad is the kernels' per-unit count
    of non-finite update tiles (``> 0`` ⇔ that unit's parameters were
    just destroyed by a non-finite dw).  The forward never reads it; the
    two-pass path has materialized grads to inspect, so the flag only
    exists on this fused path where the gradient otherwise vanishes into
    VMEM.  Requires ``w.dtype == x.dtype``:
    the fused path must not cast weights (a cast would re-materialize
    them and its vjp would corrupt the updated-params contract).
    """
    interpret = _auto_interpret() if interpret is None else interpret
    gated = wi is not None
    if gated and (bias is not None or act != "none"):
        raise ValueError("gated junction fixes act=silu-gate and takes no bias")
    if jnp.issubdtype(w.dtype, jnp.integer) or (
            gated and jnp.issubdtype(wi.dtype, jnp.integer)):
        raise ValueError(
            "junction_train_update refuses quantized (integer-code) "
            "weights — the int8/fxp datapath is inference-only; reload "
            "full-precision weights to train")
    if w.dtype != x.dtype or (gated and wi.dtype != x.dtype) or (
            bias is not None and bias.dtype != x.dtype):
        raise ValueError(
            "junction_train_update requires param dtype == activation dtype "
            f"(got w={w.dtype}, x={x.dtype}) — run the two-pass path for "
            "mixed-precision casts")
    if (mom is None) != (mom_wi is None) and gated:
        raise ValueError("gated junction needs momentum for both branches")
    if (vel is None) != (vel_wi is None) and gated:
        raise ValueError("gated junction needs the Adam v slot for both "
                         "branches")
    if vel is not None and mom is None:
        raise ValueError("the Adam vel slot requires the mom slot too "
                         "(slot layout: w, mom, vel)")
    for name, m in (("mom", mom), ("mom_wi", mom_wi), ("mom_b", mom_b),
                    ("vel", vel), ("vel_wi", vel_wi), ("vel_b", vel_b)):
        if m is not None and m.dtype != jnp.float32:
            raise ValueError(f"{name} must be an fp32 accumulator "
                             f"(got {m.dtype}) — the optimizer state stays "
                             "full-precision even for bf16 params")
    single, lead, x3, w5, wi5, b2, E, M, nob, bs, bm, bn = _prep_junction(
        x, w, wi, bias, bm, bn, gated)
    hyp = bsm.normalize_hyp(hyp, E)
    b = jnp.zeros((E, nob * bs), x.dtype) if b2 is None else b2
    ws = (w5, wi5) if gated else (w5,)

    def _slots(sw, swi, sb):
        """Lift one accumulator-slot family (w slot, gated wi slot, bias
        slot) to the core's tuples, adding the E=1 axis for 4-D calls."""
        if sw is None:
            return (), ()
        sw5 = sw[None] if single else sw
        t = (sw5, swi[None] if single else swi) if gated else (sw5,)
        tb = () if (sb is None or bias is None) else (
            (sb[None] if single else sb),)
        return t, tb

    moms, mom_b_t = _slots(mom, mom_wi, mom_b)
    vels, vel_b_t = _slots(vel, vel_wi, vel_b)
    with_health = health is not None
    if with_health:
        health = jnp.asarray(health, jnp.float32).reshape(-1)
        if health.shape != (E,):
            raise ValueError(
                f"health must be ({E},) f32 zeros (one slot per junction "
                f"unit), got shape {health.shape}")
    else:
        health = jnp.zeros((E,), jnp.float32)
    spec = KernelSpec(E=E, gated=gated, act=act, bm=bm, bn=bn,
                      has_bias=bias is not None, interpret=interpret,
                      with_health=with_health)
    with _kernel_scope("junction_train_update", spec):
        y = _junction_update_core(spec, x3, ws, b, moms, mom_b_t, vels,
                                  vel_b_t, hyp, health, idx, rev_ob, rev_t,
                                  rev_cnt)
    y = y[:, :M]
    return y.reshape(*lead, nob * bs) if single else y


def block_sparse_matmul(x, w, idx, rev_ob, rev_t, rev_cnt, bias=None,
                        act: str = "none", interpret: bool | None = None,
                        bm: int | None = None, bn: int | None = None):
    """Single-junction alias: x [..., n_in], w [nob, kb, bs, bs]."""
    return junction_matmul(x, w, idx, rev_ob, rev_t, rev_cnt, bias=bias,
                           act=act, interpret=interpret, bm=bm, bn=bn)


def expert_block_sparse_matmul(x, w, idx, rev_ob, rev_t, rev_cnt, bias=None,
                               act: str = "none",
                               interpret: bool | None = None,
                               bm: int | None = None, bn: int | None = None):
    """Expert-batched alias: x [E, M, n_in], w [E, nob, kb, bs, bs]."""
    return junction_matmul(x, w, idx, rev_ob, rev_t, rev_cnt, bias=bias,
                           act=act, interpret=interpret, bm=bm, bn=bn)


def expert_gated_matmul(x, wg, wi, idx, rev_ob, rev_t, rev_cnt,
                        interpret: bool | None = None,
                        bm: int | None = None, bn: int | None = None):
    """Gated-expert alias: silu(x_e @ Wg_e) * (x_e @ Wi_e) in one pass."""
    return junction_matmul(x, wg, idx, rev_ob, rev_t, rev_cnt, wi=wi,
                           interpret=interpret, bm=bm, bn=bn)


# ------------------------------------------------------------ fixed point
def fxp_qmatmul(a_code, w_code, *, bf: int, bn: int,
                interpret: bool | None = None):
    interpret = _auto_interpret() if interpret is None else interpret
    # ragged shapes pad to the tile inside the kernel wrapper
    return fxpk.qmatmul(a_code, w_code, bf=bf, bn=bn, interpret=interpret)


# ------------------------------------------------------------ LUT sigmoid
def sigmoid_lut(codes, table, interpret: bool | None = None):
    interpret = _auto_interpret() if interpret is None else interpret
    lead = codes.shape[:-1]
    y = slut.lut_lookup(codes.reshape(-1, codes.shape[-1]), table,
                        interpret=interpret)
    return y.reshape(*lead, codes.shape[-1])
