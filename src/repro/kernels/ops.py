"""jit'd public wrappers around the Pallas kernels.

``block_sparse_matmul`` carries a custom_vjp wired to the fused dx/dw
kernels — the full paper pipeline (FF eq. (1) with the activation fused
into the edge pipeline, BP eq. (2), UP gradient of eq. (3)) runs through
Pallas.  The activation gradient is recomputed inside the backward
kernels' prologues from the saved residual (y, or the pre-activation for
silu/gelu), so the elementwise grad tensor never round-trips HBM.

``expert_block_sparse_matmul`` / ``expert_gated_matmul`` are the
expert-batched counterparts for MoE expert FFNs (models/moe.py): one
shared block pattern, per-expert weights [E, nob, kb, bs, bs], grid
(E, M/bm, nob/bn), with the SwiGLU gate fused into a single forward pass
and matching custom_vjps through the expert dx/dw kernels.

Kernels execute in interpret mode off-TPU (the container is CPU-only);
on TPU ``interpret=False`` (the default auto-detects the backend).

``resolve_engine`` maps the config-level ``engine`` switch
("pallas" | "jnp" | "auto") to a concrete path: auto picks the Pallas
engine on TPU backends and the jnp gather+einsum fallback elsewhere
(interpret-mode Pallas is an emulator — correct, but only suitable for
tests; CPU *tests* opt in with engine="pallas" explicitly).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import block_sparse_matmul as bsm
from repro.kernels import fxp_qmatmul as fxpk
from repro.kernels import sigmoid_lut as slut


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_engine(engine: str) -> str:
    """'auto' -> 'pallas' on TPU backends, 'jnp' elsewhere."""
    if engine in ("pallas", "jnp"):
        return engine
    if engine == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    raise ValueError(f"unknown engine {engine!r} (pallas | jnp | auto)")


def _pad_rows(x, bm):
    M = x.shape[0]
    pad = (-M) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, M


# ------------------------------------------------------------ block sparse
class _Spec(NamedTuple):
    """Static (hashable) kernel configuration for the custom_vjp."""
    act: str
    bm: int
    bn: int
    interpret: bool
    has_bias: bool


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _bsm_core(spec, x, w, b, idx, rev_ob, rev_t, rev_cnt):
    y, _ = bsm.fwd(x, w, idx, b, act=spec.act, bm=spec.bm, bn=spec.bn,
                   save_pre=False, interpret=spec.interpret)
    return y


def _bsm_fwd(spec, x, w, b, idx, rev_ob, rev_t, rev_cnt):
    needs_pre = spec.act in bsm.ACT_NEEDS_PRE
    y, pre = bsm.fwd(x, w, idx, b, act=spec.act, bm=spec.bm, bn=spec.bn,
                     save_pre=needs_pre, interpret=spec.interpret)
    res = pre if needs_pre else (y if spec.act != "none" else None)
    return y, (x, w, res, idx, rev_ob, rev_t, rev_cnt)


def _bsm_bwd(spec, saved, dy):
    x, w, res, idx, rev_ob, rev_t, rev_cnt = saved
    # reverse-gathered, pre-transposed weight bundles: one XLA tile-gather
    # per backward call (w-sized traffic, dominated by the activation
    # streams the kernels save by fusing dz).
    wrT = jnp.swapaxes(w[rev_ob, rev_t], -1, -2).astype(dy.dtype)
    dxv = bsm.dx(dy, wrT, rev_ob, rev_cnt, res, act=spec.act,
                 interpret=spec.interpret)
    dwv, dbv = bsm.dw(x, dy, idx, res, act=spec.act,
                      with_bias=spec.has_bias, interpret=spec.interpret)
    if dbv is None:  # bias-free layer: the zero-bias operand gets zeros
        dbv = jnp.zeros((dy.shape[1],), jnp.float32)
    return dxv, dwv.astype(w.dtype), dbv, None, None, None, None


_bsm_core.defvjp(_bsm_fwd, _bsm_bwd)


def block_sparse_matmul(x, w, idx, rev_ob, rev_t, rev_cnt, bias=None,
                        act: str = "none", interpret: bool | None = None,
                        bm: int | None = None, bn: int | None = None):
    """x [..., n_in] -> act(x @ W_sparse + bias) [..., n_out] through the
    pre-defined block pattern, bias + activation fused into the kernel
    epilogue."""
    interpret = _auto_interpret() if interpret is None else interpret
    lead = x.shape[:-1]
    nob, kb, bs, _ = w.shape
    nib = x.shape[-1] // bs
    x2 = x.reshape(-1, x.shape[-1])
    if bm is None or bn is None:
        cbm, cbn = bsm.choose_tiles(x2.shape[0], nob, kb, bs, nib,
                                    x.dtype.itemsize)
        bm = cbm if bm is None else bm
        bn = cbn if bn is None else bn
    if nob % bn:
        bn = 1
    x2, M = _pad_rows(x2, bm)
    b = (jnp.zeros((nob * bs,), x.dtype) if bias is None
         else bias.astype(x.dtype))
    spec = _Spec(act=act, bm=bm, bn=bn, interpret=interpret,
                 has_bias=bias is not None)
    y = _bsm_core(spec, x2, w.astype(x.dtype), b, idx, rev_ob, rev_t, rev_cnt)
    return y[:M].reshape(*lead, -1)


# ------------------------------------------------ expert-batched block sparse
def _pad_expert_rows(x, bm):
    M = x.shape[1]
    pad = (-M) % bm
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x, M


def _rev_weight_bundles(w, rev_ob, rev_t, dtype):
    """Per-expert reverse-gathered, pre-transposed bundles
    [E, nib, fb, bs, bs] (one XLA tile-gather per backward call)."""
    return jnp.swapaxes(w[:, rev_ob, rev_t], -1, -2).astype(dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ebsm_core(spec, x, w, b, idx, rev_ob, rev_t, rev_cnt):
    y, _ = bsm.expert_fwd(x, w, idx, b, act=spec.act, bm=spec.bm, bn=spec.bn,
                          save_pre=False, interpret=spec.interpret)
    return y


def _ebsm_fwd(spec, x, w, b, idx, rev_ob, rev_t, rev_cnt):
    needs_pre = spec.act in bsm.ACT_NEEDS_PRE
    y, pre = bsm.expert_fwd(x, w, idx, b, act=spec.act, bm=spec.bm,
                            bn=spec.bn, save_pre=needs_pre,
                            interpret=spec.interpret)
    res = pre if needs_pre else (y if spec.act != "none" else None)
    return y, (x, w, res, idx, rev_ob, rev_t, rev_cnt)


def _ebsm_bwd(spec, saved, dy):
    x, w, res, idx, rev_ob, rev_t, rev_cnt = saved
    wrT = _rev_weight_bundles(w, rev_ob, rev_t, dy.dtype)
    dxv = bsm.expert_dx(dy, wrT, rev_ob, rev_cnt, res, act=spec.act,
                        interpret=spec.interpret)
    dwv, dbv = bsm.expert_dw(x, dy, idx, res, act=spec.act,
                             with_bias=spec.has_bias,
                             interpret=spec.interpret)
    if dbv is None:  # bias-free experts: the zero-bias operand gets zeros
        dbv = jnp.zeros((dy.shape[0], dy.shape[2]), jnp.float32)
    return dxv, dwv.astype(w.dtype), dbv, None, None, None, None


_ebsm_core.defvjp(_ebsm_fwd, _ebsm_bwd)


def expert_block_sparse_matmul(x, w, idx, rev_ob, rev_t, rev_cnt, bias=None,
                               act: str = "none",
                               interpret: bool | None = None,
                               bm: int | None = None, bn: int | None = None):
    """x [E, M, n_in] -> act(x_e @ W_e + b_e) [E, M, n_out]: per-expert
    weights w [E, nob, kb, bs, bs] through ONE shared block pattern, grid
    (E, M/bm, nob/bn), custom_vjp through the expert dx/dw kernels."""
    interpret = _auto_interpret() if interpret is None else interpret
    E, M0, _ = x.shape
    _, nob, kb, bs, _ = w.shape
    nib = x.shape[-1] // bs
    if bm is None or bn is None:
        cbm, cbn = bsm.choose_expert_tiles(E, M0, nob, kb, bs, nib,
                                           x.dtype.itemsize)
        bm = cbm if bm is None else bm
        bn = cbn if bn is None else bn
    if nob % bn:
        bn = 1
    x2, M = _pad_expert_rows(x, bm)
    b = (jnp.zeros((E, nob * bs), x.dtype) if bias is None
         else bias.astype(x.dtype))
    spec = _Spec(act=act, bm=bm, bn=bn, interpret=interpret,
                 has_bias=bias is not None)
    y = _ebsm_core(spec, x2, w.astype(x.dtype), b, idx, rev_ob, rev_t, rev_cnt)
    return y[:, :M]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _egated_core(spec, x, wg, wi, idx, rev_ob, rev_t, rev_cnt):
    h, _, _ = bsm.expert_gated_fwd(x, wg, wi, idx, bm=spec.bm, bn=spec.bn,
                                   save_res=False, interpret=spec.interpret)
    return h


def _egated_fwd(spec, x, wg, wi, idx, rev_ob, rev_t, rev_cnt):
    h, g, u = bsm.expert_gated_fwd(x, wg, wi, idx, bm=spec.bm, bn=spec.bn,
                                   save_res=True, interpret=spec.interpret)
    return h, (x, wg, wi, g, u, idx, rev_ob, rev_t, rev_cnt)


def _egated_bwd(spec, saved, dh):
    x, wg, wi, g, u, idx, rev_ob, rev_t, rev_cnt = saved
    wgrT = _rev_weight_bundles(wg, rev_ob, rev_t, dh.dtype)
    wirT = _rev_weight_bundles(wi, rev_ob, rev_t, dh.dtype)
    dxv = bsm.expert_gated_dx(dh, wgrT, wirT, rev_ob, rev_cnt, g, u,
                              interpret=spec.interpret)
    dwg, dwi = bsm.expert_gated_dw(x, dh, idx, g, u, interpret=spec.interpret)
    return dxv, dwg.astype(wg.dtype), dwi.astype(wi.dtype), None, None, None, None


_egated_core.defvjp(_egated_fwd, _egated_bwd)


def expert_gated_matmul(x, wg, wi, idx, rev_ob, rev_t, rev_cnt,
                        interpret: bool | None = None,
                        bm: int | None = None, bn: int | None = None):
    """x [E, M, n_in] -> silu(x_e @ Wg_e) * (x_e @ Wi_e) [E, M, n_out] in
    ONE fused kernel pass (GShard/SwiGLU expert FFN entry); the backward
    runs through the fused two-branch expert_gated_dx/dw kernels with both
    branch grads recomputed from the saved (g, u) residuals."""
    interpret = _auto_interpret() if interpret is None else interpret
    E, M0, _ = x.shape
    _, nob, kb, bs, _ = wg.shape
    nib = x.shape[-1] // bs
    if bm is None or bn is None:
        cbm, cbn = bsm.choose_expert_tiles(E, M0, nob, kb, bs, nib,
                                           x.dtype.itemsize,
                                           n_weight_operands=2)
        bm = cbm if bm is None else bm
        bn = cbn if bn is None else bn
    if nob % bn:
        bn = 1
    x2, M = _pad_expert_rows(x, bm)
    spec = _Spec(act="silu", bm=bm, bn=bn, interpret=interpret,
                 has_bias=False)
    h = _egated_core(spec, x2, wg.astype(x.dtype), wi.astype(x.dtype), idx,
                     rev_ob, rev_t, rev_cnt)
    return h[:, :M]


# ------------------------------------------------------------ fixed point
def fxp_qmatmul(a_code, w_code, *, bf: int, bn: int,
                interpret: bool | None = None):
    interpret = _auto_interpret() if interpret is None else interpret
    a2, M = _pad_rows(a_code, 128)
    K = a2.shape[1]
    pad_k = (-K) % 128
    if pad_k:
        a2 = jnp.pad(a2, ((0, 0), (0, pad_k)))
        w_code = jnp.pad(w_code, ((0, pad_k), (0, 0)))
    N = w_code.shape[1]
    pad_n = (-N) % 128
    if pad_n:
        w_code = jnp.pad(w_code, ((0, 0), (0, pad_n)))
    y = fxpk.qmatmul(a2, w_code, bf=bf, bn=bn, interpret=interpret)
    return y[:M, :N]


# ------------------------------------------------------------ LUT sigmoid
def sigmoid_lut(codes, table, interpret: bool | None = None):
    interpret = _auto_interpret() if interpret is None else interpret
    lead = codes.shape[:-1]
    c2, M = _pad_rows(codes.reshape(-1, codes.shape[-1]), 256)
    y = slut.lut_lookup(c2, table, interpret=interpret)
    return y[:M].reshape(*lead, codes.shape[-1])
