"""Pallas flash attention (beyond-paper) — the TPU drop-in for
models/attention.chunked_attention.

Online-softmax attention with the (m, l, acc) running state in VMEM
scratch: grid (B*H, Sq/bq, Sk/bk), KV blocks innermost so one q-tile's
state never leaves VMEM; scores/probability tiles [bq, bk] are never
written to HBM (the lax.scan version materializes them per chunk — the
same stage-materialization cost structure the selective-scan kernel
removes for SSMs).  GQA: the kv head for grid row h is h // rep via the
BlockSpec index maps — no repeated K/V in memory.

Causal masking from absolute block offsets; fully-masked tiles contribute
exp(-inf)=0 naturally.  Validated against a naive oracle over
(heads, GQA ratio, seq, window) sweeps in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(nk: int, scale: float, causal: bool, window: int,
            q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0].astype(jnp.float32)                  # [bq, D]
    k = k_ref[0].astype(jnp.float32)                  # [bk, D]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [bq, bk]

    bq, bk = s.shape
    qpos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask = mask & (qpos >= kpos)
    if window:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_s[...], l_s[...], acc_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot(p, v)
    m_s[...], l_s[...], acc_s[...] = m_new, l_new, acc_new

    @pl.when(kb == nk - 1)
    def _finish():
        o_ref[0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False):
    """q [BH, Sq, D]; k, v [BHkv, Sk, D] with BH % BHkv == 0 (GQA).
    Returns [BH, Sq, D]."""
    BH, Sq, D = q.shape
    BHkv, Sk, _ = k.shape
    assert BH % BHkv == 0
    rep = BH // BHkv
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    grid = (BH, Sq // bq, Sk // bk)
    scale = float(1.0 / (D ** 0.5))
    return pl.pallas_call(
        functools.partial(_kernel, Sk // bk, scale, causal, window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j: (h // rep, j, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j: (h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max
            pltpu.VMEM((bq,), jnp.float32),       # running denominator
            pltpu.VMEM((bq, D), jnp.float32),     # weighted accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def mha(q, k, v, *, causal: bool = True, window: int = 0,
        interpret: bool = False, **kw):
    """Convenience wrapper: q [B,Sq,H,D], k/v [B,Sk,Hkv,D] -> [B,Sq,H,D]."""
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    o = flash_attention(qf, kf, vf, causal=causal, window=window,
                        interpret=interpret, **kw)
    return o.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
