"""Pallas flash attention (beyond-paper) — the TPU drop-in for
models/attention.chunked_attention, plus the paged single-query decode
kernel the continuous-batching serve engine ticks through.

``flash_attention`` — online-softmax attention with the (m, l, acc)
running state in VMEM scratch: grid (B*H, Sq/bq, Sk/bk), KV blocks
innermost so one q-tile's state never leaves VMEM; scores/probability
tiles [bq, bk] are never written to HBM (the lax.scan version
materializes them per chunk — the same stage-materialization cost
structure the selective-scan kernel removes for SSMs).  GQA: the kv head
for grid row h is h // rep via the BlockSpec index maps — no repeated
K/V in memory.  Ragged Sq/Sk are padded to the tile internally (padded
query rows are sliced off, padded KV rows masked by an explicit
kpos < Sk term), mirroring the fxp_qmatmul pad-to-tile contract.

``flash_decode`` — the serve-path variant: one query per slot against a
block-paged KV pool.  The per-slot page table and sequence lengths ride
scalar prefetch exactly like the junction kernels' pattern indices; the
KV pool stays in HBM (memory_space=ANY) and each page is gathered
HBM→VMEM with the same double-buffered ``make_async_copy`` idiom as the
reverse-weight DMA in block_sparse_matmul.dx — while page j is reduced
into the online-softmax state, page j+1 is in flight.  Pages past a
slot's length are skipped entirely (matching-predicate start/wait), so
a ragged batch does no DMA for dead tail pages; a zero-length slot
(free/prefilling — the engine points it at the scratch page) produces
exact zeros.  Fixed shapes throughout: slot refill and page-table swaps
change only the prefetched integers, never the traced graph.

Causal masking from absolute block offsets; fully-masked tiles contribute
exp(-inf)=0 naturally.  Validated against naive oracles over
(heads, GQA ratio, seq, window, ragged lengths) sweeps in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(nk: int, scale: float, causal: bool, window: int, kv_len: int,
            q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0].astype(jnp.float32)                  # [bq, D]
    k = k_ref[0].astype(jnp.float32)                  # [bk, D]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [bq, bk]

    bq, bk = s.shape
    qpos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    # ragged Sk: tile-padded key rows carry garbage — mask them for every
    # mode (the causal term only covers them when qpos < kpos)
    mask = kpos < kv_len
    if causal:
        mask = mask & (qpos >= kpos)
    if window:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_s[...], l_s[...], acc_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot(p, v)
    m_s[...], l_s[...], acc_s[...] = m_new, l_new, acc_new

    @pl.when(kb == nk - 1)
    def _finish():
        o_ref[0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def _pad_dim(x, axis, to):
    if x.shape[axis] == to:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, to - x.shape[axis])
    return jnp.pad(x, pad)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False):
    """q [BH, Sq, D]; k, v [BHkv, Sk, D] with BH % BHkv == 0 (GQA).
    Returns [BH, Sq, D].  Ragged Sq/Sk are padded to the tile internally:
    padded query rows are computed and sliced off, padded key rows are
    masked inside the kernel (kpos < Sk), so callers never need
    tile-multiple sequence lengths."""
    BH, Sq, D = q.shape
    BHkv, Sk, _ = k.shape
    assert BH % BHkv == 0
    rep = BH // BHkv
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    sq_p = pl.cdiv(Sq, bq) * bq
    sk_p = pl.cdiv(Sk, bk) * bk
    q = _pad_dim(q, 1, sq_p)
    k = _pad_dim(k, 1, sk_p)
    v = _pad_dim(v, 1, sk_p)
    grid = (BH, sq_p // bq, sk_p // bk)
    scale = float(1.0 / (D ** 0.5))
    out = pl.pallas_call(
        functools.partial(_kernel, sk_p // bk, scale, causal, window, Sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j: (h // rep, j, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j: (h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max
            pltpu.VMEM((bq,), jnp.float32),       # running denominator
            pltpu.VMEM((bq, D), jnp.float32),     # weighted accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq] if sq_p != Sq else out


def mha(q, k, v, *, causal: bool = True, window: int = 0,
        interpret: bool = False, **kw):
    """Convenience wrapper: q [B,Sq,H,D], k/v [B,Sk,Hkv,D] -> [B,Sq,H,D]."""
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    o = flash_attention(qf, kf, vf, causal=causal, window=window,
                        interpret=interpret, **kw)
    return o.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


# ===================================================== paged decode kernel
def _decode_kernel(maxp: int, ps: int, hkv: int, scale: float,
                   pt_ref, len_ref, q_ref, k_hbm, v_hbm, o_ref,
                   kbuf, vbuf, sems, m_s, l_s, acc_s):
    b = pl.program_id(0)
    j = pl.program_id(1)
    n = len_ref[b]

    def start(buf, page):
        pid = pt_ref[b, page]
        pltpu.make_async_copy(k_hbm.at[pid], kbuf.at[buf], sems.at[buf, 0]).start()
        pltpu.make_async_copy(v_hbm.at[pid], vbuf.at[buf], sems.at[buf, 1]).start()

    def wait(buf, page):
        pid = pt_ref[b, page]
        pltpu.make_async_copy(k_hbm.at[pid], kbuf.at[buf], sems.at[buf, 0]).wait()
        pltpu.make_async_copy(v_hbm.at[pid], vbuf.at[buf], sems.at[buf, 1]).wait()

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)
        pl.when(n > 0)(lambda: start(0, 0))

    # prefetch page j+1 while page j is reduced; predicate matches the
    # wait below so skipped tail pages never touch the semaphores
    @pl.when(jnp.logical_and(j + 1 < maxp, (j + 1) * ps < n))
    def _next():
        start((j + 1) % 2, j + 1)

    @pl.when(j * ps < n)
    def _compute():
        wait(j % 2, j)
        q = q_ref[0].astype(jnp.float32)              # [Hkv, rep, D]
        kp = kbuf[j % 2].astype(jnp.float32)          # [ps, Hkv, D]
        vp = vbuf[j % 2].astype(jnp.float32)
        rep = q.shape[1]
        kpos = j * ps + jax.lax.broadcasted_iota(jnp.int32, (rep, ps), 1)
        valid = kpos < n
        for h in range(hkv):
            s = jax.lax.dot_general(q[h], kp[:, h],
                                    (((1,), (1,)), ((), ()))) * scale  # [rep, ps]
            s = jnp.where(valid, s, NEG_INF)
            m_prev, l_prev, acc_prev = m_s[h], l_s[h], acc_s[h]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
            p = jnp.exp(s - m_new[:, None])
            corr = jnp.exp(m_prev - m_new)
            m_s[h] = m_new
            l_s[h] = l_prev * corr + jnp.sum(p, axis=1)
            acc_s[h] = acc_prev * corr[:, None] + jax.lax.dot(p, vp[:, h])

    @pl.when(j == maxp - 1)
    def _finish():
        o_ref[0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)[..., None]
                    ).astype(o_ref.dtype)


def flash_decode(q, k_pool, v_pool, page_table, seq_lens, *,
                 interpret: bool | None = None):
    """Single-query decode attention over a block-paged KV pool.

    q [B, Hkv, rep, D] — one query token per slot, grouped by kv head;
    k_pool / v_pool [P, ps, Hkv, D] — the page pool (one layer's slice);
    page_table [B, maxp] int32 — pool page ids per slot, in token order
    (entry t covers positions [t*ps, (t+1)*ps));
    seq_lens [B] int32 — valid tokens per slot (0 for free slots).

    Returns [B, Hkv, rep, D].  The page table and lengths ride scalar
    prefetch; pages are DMA'd HBM→VMEM double-buffered, with tail pages
    past a slot's length skipped.  seq_lens == 0 yields exact zeros.
    """
    B, Hkv, rep, D = q.shape
    P, ps, hkv2, _ = k_pool.shape
    assert hkv2 == Hkv
    maxp = page_table.shape[1]
    if interpret is None:
        from repro.kernels import ops
        interpret = ops._auto_interpret()
    scale = float(1.0 / (D ** 0.5))
    # profiler attribution (same convention as ops._kernel_scope): the
    # decode-tick hot kernel shows up named, not as an anonymous
    # pallas_call, in a --profile trace
    with jax.named_scope(f"flash_decode_B{B}_H{Hkv}x{rep}_ps{ps}"):
        return pl.pallas_call(
            functools.partial(_decode_kernel, maxp, ps, Hkv, scale),
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=2,
                grid=(B, maxp),
                in_specs=[
                    pl.BlockSpec((1, Hkv, rep, D),
                                 lambda b, j, *_: (b, 0, 0, 0)),
                    pl.BlockSpec(memory_space=pltpu.ANY),
                    pl.BlockSpec(memory_space=pltpu.ANY),
                ],
                out_specs=pl.BlockSpec((1, Hkv, rep, D),
                                       lambda b, j, *_: (b, 0, 0, 0)),
                scratch_shapes=[
                    pltpu.VMEM((2, ps, Hkv, D), k_pool.dtype),  # k page bufs
                    pltpu.VMEM((2, ps, Hkv, D), v_pool.dtype),  # v page bufs
                    pltpu.SemaphoreType.DMA((2, 2)),
                    pltpu.VMEM((Hkv, rep), jnp.float32),        # running max
                    pltpu.VMEM((Hkv, rep), jnp.float32),        # running denom
                    pltpu.VMEM((Hkv, rep, D), jnp.float32),     # weighted acc
                ],
            ),
            out_shape=jax.ShapeDtypeStruct((B, Hkv, rep, D), q.dtype),
            interpret=interpret,
        )(page_table, seq_lens, q, k_pool, v_pool)


def paged_decode_ref(q, k_pool, v_pool, page_table, seq_lens):
    """jnp oracle for flash_decode (also the serve engine's jnp path):
    gather the slot's pages, monolithic masked softmax in fp32.  Same
    shapes/contract as flash_decode."""
    B, Hkv, rep, D = q.shape
    ps = k_pool.shape[1]
    maxp = page_table.shape[1]
    kg = k_pool[page_table].reshape(B, maxp * ps, Hkv, D)
    vg = v_pool[page_table].reshape(B, maxp * ps, Hkv, D)
    scale = 1.0 / (D ** 0.5)
    s = jnp.einsum("bgrd,bkgd->bgrk", q.astype(jnp.float32),
                   kg.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(maxp * ps)[None, :] < seq_lens[:, None]     # [B, K]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bgrk,bkgd->bgrd", p / jnp.maximum(l, 1e-30),
                     vg.astype(jnp.float32))
    out = jnp.where((seq_lens > 0)[:, None, None, None], out, 0.0)
    return out.astype(q.dtype)
