"""Pallas fixed-point matmul with saturating post-accumulation clip.

The paper's arithmetic units keep one bit triplet (b_w, b_n, b_f) end to
end by clipping adder/multiplier outputs (Sec. III-C-3).  The TPU-native
re-expression: operands are integer *codes* (value * 2^b_f), products
accumulate exactly in int32 (codes fit 16 bits, so a 128-deep dot is
exact), then one round-half-up shift by b_f and a saturate to the triplet
range.  This is what an int8/int16 MXU path does on real hardware — the
FPGA's per-node clipping tree is kept bit-exact in core/fixed_point.py and
the two are compared in benchmarks/paper_benches.py (the Table II
bit-width rows, ``table2_bitwidth``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(bf: int, bn: int, nk: int, a_ref, w_ref, o_ref, acc_ref):
    # signature: inputs..., outputs..., scratch...
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], w_ref[...],
                            preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _finish():
        acc = acc_ref[...]
        rounded = (acc + (1 << (bf - 1))) >> bf
        lo, hi = -(1 << (bn + bf)), (1 << (bn + bf)) - 1
        o_ref[...] = jnp.clip(rounded, lo, hi).astype(jnp.int32)


def qmatmul(a_code, w_code, *, bf: int, bn: int, bm: int = 128,
            bn_tile: int = 128, bk: int = 128, interpret: bool = False):
    """a [M, K] int32 codes, w [K, N] int32 codes -> [M, N] int32 codes.

    Ragged shapes pad to the tile and slice back (zero codes contribute
    exact zeros to the integer accumulation, so padding is free)."""
    M, K = a_code.shape
    N = w_code.shape[1]
    pm, pk, pn = (-M) % bm, (-K) % bk, (-N) % bn_tile
    if pm or pk:
        a_code = jnp.pad(a_code, ((0, pm), (0, pk)))
    if pk or pn:
        w_code = jnp.pad(w_code, ((0, pk), (0, pn)))
    Mp, Kp = a_code.shape
    Np = w_code.shape[1]
    grid = (Mp // bm, Np // bn_tile, Kp // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, bf, bn, Kp // bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn_tile), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn_tile), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn_tile), jnp.int32)],
        interpret=interpret,
    )(a_code, w_code)
    return out[:M, :N] if (pm or pn) else out
