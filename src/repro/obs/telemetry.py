"""Flight recorder: ONE telemetry layer for train, sweep, and serve.

Every subsystem that used to emit its own scattered signals — the train
loop's ``metrics`` dict, ``ContinuousEngine.stats``, the sweep ledger
prints, the percentiles computed privately inside
``benchmarks/serve_benches.py`` — records through a :class:`Recorder`
instead, so one run produces one machine-readable timeline that
``repro.launch.obs_report`` can render and future scale-out PRs can read
their numbers from.

The recorder carries three aggregate families plus an event stream:

* **counters** — monotonically increasing ints (``count``): steps run,
  requests finished per outcome, checkpoints written;
* **gauges** — latest-value floats (``gauge``): pages in use, slots
  decoding, current lr_scale;
* **histograms** — bounded sample windows (``observe``) with
  nearest-rank percentiles (:func:`percentile`): step latency, TTFT,
  inter-token latency;
* **events** — typed frozen dataclasses (:class:`TrainStep`,
  :class:`Guardian`, :class:`Checkpoint`, :class:`RequestSpan`,
  :class:`SweepRound`) appended to a bounded in-memory ring and, when a
  ``path`` is given, streamed as one JSON line each (JSONL).  The sink
  opens with a ``meta`` header line and :meth:`Recorder.close` appends a
  ``summary`` line holding the final counters/gauges/histogram digests.

No-extra-device-sync contract
-----------------------------
The recorder is HOST-ONLY instrumentation.  It never forces a
``block_until_ready``, never adds a traced op, and never triggers a
device→host transfer of its own: producers hand it values the step
ALREADY returned to host (the ``float(metrics["loss"])`` the train loop
does for honest step timing, the ``np.asarray(tok)`` the serve scheduler
needs anyway).  This is enforced, not just documented — every recorded
value passes :func:`_ensure_host`, which raises ``TypeError`` on a
``jax.Array`` — and regression-tested: the jaxpr of a fused train step
is identical with and without a recorder attached, and
``ContinuousEngine`` still reports ``decode_traces == 1`` /
``prefill_traces == 1`` with telemetry on (tests/test_obs.py, the ci.sh
serve smoke).  A value a producer did not already sync is recorded as
the sentinel ``-1.0`` ("not sampled on this path"), never fetched.

Event schema
------------
Each JSONL line is ``{"kind": ..., "ts": ..., "seq": ..., **fields}``;
``kind`` names the dataclass (``train.step``, ``guardian``,
``checkpoint``, ``serve.span``, ``sweep.round``, plus the ``meta`` /
``summary`` frame lines).  ``seq`` is the per-recorder emission index,
``ts`` host wall-clock seconds.  ``read_events`` round-trips a file.

Span lifecycle (``serve.span``)
-------------------------------
One event per finished request, emitted by ``ContinuousEngine`` at
slot-free time, reconstructing the whole request timeline:
``enqueue_tick`` (arrival) → ``admit_tick`` (pages allocated, slot
taken) → ``prefill_chunks`` fixed-shape chunks → ``first_token_tick`` /
``ttft_s`` (sampled off the final prefill chunk's logits) →
``finish_tick`` with ``outcome`` ∈ {``eos``, ``max_new``, ``guard``}.
``ttft_s`` / ``first_token_tick`` are ``-1`` when the request never
produced a token (guard-terminated during prefill).
"""
from __future__ import annotations

import dataclasses
import json
import math
import sys
import time
from collections import deque
from typing import Any, ClassVar, IO, Iterable, Optional

__all__ = [
    "Checkpoint", "Guardian", "Histogram", "Recorder", "RequestSpan",
    "SweepRound", "TrainStep", "percentile", "profile_ctx", "read_events",
]

#: histogram value meaning "producer did not sync this value on this
#: path" — recorded instead of forcing a device→host transfer
NOT_SAMPLED = -1.0


def percentile(samples: Iterable[float], q: float) -> float:
    """Nearest-rank percentile: the q-th percentile of n samples is the
    ``ceil(q/100 * n)``-th smallest OBSERVED value.

    Unlike linear interpolation (``np.percentile``'s default), this never
    invents a value between samples, and the small-sample behavior is the
    honest one: p99 of fewer than 100 samples is the max — with 2 latency
    measurements there is no evidence for anything between them, and an
    SLO check must see the worst observed, not an interpolation past it.
    """
    xs = sorted(float(v) for v in samples)
    if not xs:
        raise ValueError("percentile of an empty sample set")
    if not 0.0 < q <= 100.0:
        raise ValueError(f"percentile q must be in (0, 100], got {q}")
    rank = math.ceil(q / 100.0 * len(xs))   # 1-based nearest rank
    return xs[max(rank, 1) - 1]


def profile_ctx(trace_dir: str | None):
    """``jax.profiler.trace`` context for the launchers' ``--profile
    <dir>`` flag (None: no-op).  Combined with the named scopes in
    kernels/ops.py and kernels/flash_attention.py, the resulting trace
    attributes device time to junction kernels by KernelSpec.  jax is
    imported lazily so ``--help`` paths stay jax-free."""
    import contextlib
    if trace_dir is None:
        return contextlib.nullcontext()
    import jax
    return jax.profiler.trace(trace_dir)


def _ensure_host(name: str, v: Any) -> Any:
    """The no-extra-device-sync guard: recording a live ``jax.Array``
    would force a device→host transfer the step didn't already pay for —
    refuse it and make the producer convert at its own sync point.
    (Lazy ``sys.modules`` lookup: if jax was never imported there is
    nothing to guard, and ``--help`` paths stay jax-free.)"""
    jax = sys.modules.get("jax")
    if jax is not None and isinstance(v, jax.Array):
        raise TypeError(
            f"telemetry value {name!r} is a jax.Array — the recorder only "
            "consumes values already returned to host (no-extra-device-sync "
            "contract, obs/telemetry.py); convert with float()/int()/"
            "np.asarray() at the step's own sync point")
    return v


# ------------------------------------------------------------- event types
@dataclasses.dataclass(frozen=True)
class TrainStep:
    """One adopted train step (train/train_loop.py).  ``nonfinite`` is
    the in-kernel health count when the guardian already fetched it,
    else the ``NOT_SAMPLED`` sentinel."""
    KIND: ClassVar[str] = "train.step"
    step: int
    loss: float
    nonfinite: float
    lr_scale: float
    dt_s: float
    dt_ema_s: float
    tokens_per_s: float


@dataclasses.dataclass(frozen=True)
class Guardian:
    """Guardian lifecycle: ``action`` ∈ trip | rollback | backoff |
    recovery, in that order per incident.  ``step`` is the train-loop
    step the action refers to (trip: the step whose update was
    discarded; rollback/backoff/recovery: the healthy step training
    resumed from)."""
    KIND: ClassVar[str] = "guardian"
    action: str
    step: int
    detail: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class Checkpoint:
    """Checkpoint lifecycle: ``action`` ∈ save | promote | gc (promote =
    the healthy mark after surviving the guardian's health window)."""
    KIND: ClassVar[str] = "checkpoint"
    action: str
    step: int
    detail: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class RequestSpan:
    """One finished serve request — the whole lifecycle in one event
    (see the module docstring's span section)."""
    KIND: ClassVar[str] = "serve.span"
    rid: int
    outcome: str            # eos | max_new | guard
    enqueue_tick: int
    admit_tick: int
    first_token_tick: int   # -1: never produced a token
    finish_tick: int
    prefill_chunks: int
    n_tokens: int
    ttft_s: float           # admit -> first token wall time; -1: no token
    wall_s: float           # admit -> finish wall time


@dataclasses.dataclass(frozen=True)
class SweepRound:
    """Population-sweep scheduler event (search/scheduler.py):
    ``action`` ∈ rank (one per round, scores in ``detail``) | prune |
    quarantine | winner (one per affected member, its cohort/slot
    attached so the sweep ledger and the telemetry share one
    timeline)."""
    KIND: ClassVar[str] = "sweep.round"
    action: str
    round: int
    member: int = -1
    cohort: int = -1
    slot: int = -1
    detail: dict = dataclasses.field(default_factory=dict)


EVENT_TYPES = (TrainStep, Guardian, Checkpoint, RequestSpan, SweepRound)


# --------------------------------------------------------------- histogram
class Histogram:
    """Bounded sample window: the newest ``cap`` observations (deque) plus
    lifetime count/sum, so percentiles cover the recent window while the
    mean stays exact over the whole run."""

    __slots__ = ("samples", "count", "total")

    def __init__(self, cap: int = 65536):
        self.samples: deque = deque(maxlen=cap)
        self.count = 0
        self.total = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.samples.append(v)
        self.count += 1
        self.total += v

    def percentile(self, q: float) -> float:
        return percentile(self.samples, q)

    def summary(self) -> dict:
        if not self.samples:
            return {"count": 0}
        return {
            "count": self.count,
            "mean": self.total / self.count,
            "min": min(self.samples),
            "max": max(self.samples),
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }


# ---------------------------------------------------------------- recorder
class Recorder:
    """The flight recorder.  Construct with ``path=`` for a JSONL sink
    (or ``None`` for in-memory only), hand it to the producers
    (``train_loop.run(recorder=)``, ``ContinuousEngine(recorder=)``,
    ``run_sweep(recorder=)``), and ``close()`` — or use it as a context
    manager — when the run ends.  Multiple producers may share one
    recorder: a sweep's round events and its cohorts' telemetry land on
    one timeline, ordered by ``seq``."""

    def __init__(self, path: str | None = None, *, ring: int = 4096,
                 meta: dict | None = None, hist_cap: int = 65536):
        self.counters: dict[str, int] = {}
        self.gauges: dict[str, float] = {}
        self.hists: dict[str, Histogram] = {}
        self.ring: deque = deque(maxlen=ring)
        self.n_events = 0
        self._hist_cap = hist_cap
        self._t0 = time.time()
        self._sink: Optional[IO[str]] = None
        if path is not None:
            self._sink = open(path, "w")
            self._write_frame("meta", dict(meta or {}, t0=self._t0))

    # -- aggregates
    def count(self, name: str, n: int = 1) -> None:
        _ensure_host(name, n)
        self.counters[name] = self.counters.get(name, 0) + int(n)

    def gauge(self, name: str, value: float) -> None:
        _ensure_host(name, value)
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        _ensure_host(name, value)
        h = self.hists.get(name)
        if h is None:
            h = self.hists[name] = Histogram(self._hist_cap)
        h.observe(value)

    # -- events
    def emit(self, event) -> None:
        """Record one typed event (an EVENT_TYPES dataclass instance):
        append to the ring, stream to the JSONL sink."""
        if not isinstance(event, EVENT_TYPES):
            raise TypeError(f"emit() takes a telemetry event dataclass, "
                            f"got {type(event).__name__}")
        fields = dataclasses.asdict(event)
        for k, v in fields.items():
            _ensure_host(f"{event.KIND}.{k}", v)
        self.ring.append(event)
        if self._sink is not None:
            self._write_frame(event.KIND, fields)
        else:
            self.n_events += 1

    def events(self, kind: str | None = None) -> list:
        """Ring contents (newest-``ring`` events), optionally filtered."""
        return [e for e in self.ring if kind is None or e.KIND == kind]

    # -- lifecycle
    def summary(self) -> dict:
        return {
            "n_events": self.n_events,
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {k: h.summary() for k, h in self.hists.items()},
        }

    def close(self) -> None:
        if self._sink is not None:
            self._write_frame("summary", self.summary())
            self._sink.close()
            self._sink = None

    def _write_frame(self, kind: str, fields: dict) -> None:
        rec = {"kind": kind, "ts": time.time(), "seq": self.n_events}
        rec.update(fields)
        self.n_events += 1
        self._sink.write(json.dumps(rec) + "\n")

    def __enter__(self) -> "Recorder":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # best-effort: never leak an unsummarized sink
        try:
            self.close()
        except Exception:
            pass


def read_events(path: str) -> tuple[dict, list[dict]]:
    """(meta, events) from a JSONL sink file.  ``meta`` is the header
    frame's fields ({} for a truncated file); ``events`` every non-frame
    line as a dict, in ``seq`` order.  The trailing ``summary`` frame, if
    the recorder was closed cleanly, is returned as the last event with
    ``kind == "summary"`` so reports can cross-check their own
    aggregation against the recorder's."""
    meta: dict = {}
    events: list[dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if rec.get("kind") == "meta":
                meta = {k: v for k, v in rec.items()
                        if k not in ("kind", "ts", "seq")}
            else:
                events.append(rec)
    return meta, events
