"""Flight-recorder telemetry (PR 10).  See obs/telemetry.py."""
from repro.obs.telemetry import (  # noqa: F401
    Checkpoint,
    Guardian,
    Histogram,
    NOT_SAMPLED,
    Recorder,
    RequestSpan,
    SweepRound,
    TrainStep,
    percentile,
    profile_ctx,
    read_events,
)

__all__ = [
    "Checkpoint", "Guardian", "Histogram", "NOT_SAMPLED", "Recorder",
    "RequestSpan", "SweepRound", "TrainStep", "percentile", "profile_ctx",
    "read_events",
]
